"""Batched/grouped ftIMM GEMM vs the einsum oracle (interpret mode), the
batch-aware CMR planner, and the planner routing of the MoE / attention
call sites (the paper's irregular-shape producers)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _prop import given, settings, st

from repro.core.gemm import (batched_matmul, clear_plan_cache, estimate_batched,
                             grouped_matmul, plan_batched_gemm, TPU_V5E)
from repro.kernels.ftimm import batched_gemm

KEY = jax.random.PRNGKey(5)


def _mk3(trans, g, m, k, n, dtype, shared=None):
    shapes = {"nn": ((m, k), (k, n)), "tn": ((k, m), (k, n)),
              "nt": ((m, k), (n, k))}[trans]
    sa = shapes[0] if shared == "a" else (g,) + shapes[0]
    sb = shapes[1] if shared == "b" else (g,) + shapes[1]
    ka, kb = jax.random.split(
        jax.random.fold_in(KEY, g * 131 + m * 31 + k * 7 + n))
    return jax.random.normal(ka, sa, dtype), jax.random.normal(kb, sb, dtype)


def _oracle(a, b, trans):
    al = "gmk" if a.ndim == 3 else "mk"
    bl = "gkn" if b.ndim == 3 else "kn"
    if trans == "tn":
        al = al.replace("mk", "km")
    if trans == "nt":
        bl = bl.replace("kn", "nk")
    return jnp.einsum(f"{al},{bl}->gmn", a, b,
                      preferred_element_type=jnp.float32)


def _check(a, b, out, trans, dtype):
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(_oracle(a, b, trans), np.float32),
                               rtol=tol, atol=tol)


# Per-entry shapes spanning the paper's taxonomy + unaligned E/C/D:
#   (G, M, K, N)
SHAPES = [
    (4, 256, 32, 32),     # T1 per entry: M >> K ~ N
    (2, 16, 512, 32),     # T2 per entry: decode-attention shape
    (3, 128, 128, 32),    # T3-ish per entry
    (8, 20, 32, 48),      # MoE (E, C, D, F), unaligned capacity
    (5, 33, 57, 65),      # unaligned everything
]


@pytest.mark.parametrize("g,m,k,n", SHAPES)
@pytest.mark.parametrize("trans", ["nn", "tn", "nt"])
def test_batched_vs_oracle_fp32(g, m, k, n, trans):
    a, b = _mk3(trans, g, m, k, n, jnp.float32)
    out = batched_gemm(a, b, trans=trans, interpret=True)
    _check(a, b, out, trans, jnp.float32)


@pytest.mark.parametrize("g,m,k,n", SHAPES[:4])
def test_batched_vs_oracle_bf16(g, m, k, n):
    a, b = _mk3("nn", g, m, k, n, jnp.bfloat16)
    out = batched_gemm(a, b, trans="nn", interpret=True)
    _check(a, b, out, "nn", jnp.bfloat16)


@pytest.mark.parametrize("shared", ["a", "b"])
def test_grouped_shared_operand(shared):
    a, b = _mk3("nn", 4, 24, 40, 56, jnp.float32, shared=shared)
    out = batched_gemm(a, b, trans="nn", interpret=True)
    _check(a, b, out, "nn", jnp.float32)


def test_moe_backward_shapes():
    """dW of the grouped MoE GEMM: (E, C, D)^T @ (E, C, F) with the capacity
    dim contracted — the T2-shaped grouped GEMM, including unaligned C."""
    for e, c, dm, f in [(4, 20, 32, 64), (8, 104, 16, 48)]:
        x, dy = _mk3("tn", e, dm, c, f, jnp.float32)   # x: (E, C, D)
        out = batched_gemm(x, dy, trans="tn", interpret=True)
        _check(x, dy, out, "tn", jnp.float32)


def test_batched_matches_stacked_2d():
    from repro.kernels.ftimm import gemm
    a, b = _mk3("nn", 3, 48, 64, 96, jnp.float32)
    out = batched_gemm(a, b, interpret=True)
    for g in range(3):
        np.testing.assert_allclose(np.asarray(out[g]),
                                   np.asarray(gemm(a[g], b[g], interpret=True)),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dim_order", ["mn", "nm"])
def test_batched_dim_order_equivalence(dim_order):
    a, b = _mk3("nn", 2, 40, 64, 160, jnp.float32)
    out = batched_gemm(a, b, dim_order=dim_order, interpret=True)
    _check(a, b, out, "nn", jnp.float32)


@settings(max_examples=10, deadline=None)
@given(g=st.integers(1, 6), m=st.integers(1, 48), k=st.integers(1, 64),
       n=st.integers(1, 48))
def test_batched_property_random_shapes(g, m, k, n):
    a, b = _mk3("nn", g, m, k, n, jnp.float32)
    out = batched_gemm(a, b, interpret=True)
    _check(a, b, out, "nn", jnp.float32)


def test_grouped_vjp_grads_match_xla():
    x, w = _mk3("nn", 3, 16, 24, 32, jnp.float32)

    def loss(backend):
        return lambda x, w: jnp.sum(
            grouped_matmul(x, w, backend=backend) ** 2)

    g_pl = jax.grad(loss("pallas_interpret"), argnums=(0, 1))(x, w)
    g_x = jax.grad(loss("xla"), argnums=(0, 1))(x, w)
    for u, v in zip(g_pl, g_x):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   rtol=3e-4, atol=3e-4)


def test_shared_weight_vjp_is_flat_t2():
    """Shared-weight grouped GEMM grads equal the einsum autodiff (the dW
    path collapses to one flat T2 GEMM over all G*M rows)."""
    x, w = _mk3("nn", 4, 24, 32, 48, jnp.float32, shared="b")

    def loss_gm(x, w):
        return jnp.sum(batched_matmul(x, w, backend="xla") ** 2)

    def loss_ein(x, w):
        return jnp.sum(_oracle(x, w, "nn") ** 2)

    g1 = jax.grad(loss_gm, argnums=(0, 1))(x, w)
    g2 = jax.grad(loss_ein, argnums=(0, 1))(x, w)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Batch-aware planner
# ---------------------------------------------------------------------------

def test_plan_batched_respects_budget_and_alignment():
    for g, m, k, n in SHAPES:
        p = plan_batched_gemm(g, m, k, n)
        assert p.est.vmem_bytes <= TPU_V5E.vmem_budget
        assert p.bn % TPU_V5E.lane == 0
        assert p.bm % TPU_V5E.sublane_fp32 == 0 or p.bm >= m


def test_plan_batched_deterministic_and_cached():
    a = plan_batched_gemm(8, 64, 32, 128)
    b = plan_batched_gemm(8, 64, 32, 128)
    assert a is b   # lru cache


def test_shared_operand_residency_rewarded():
    """A shared small weight panel (grouped attention-style) must model less
    HBM traffic than re-fetching it per batch entry, once the tiling keeps a
    single resident block (gk == gn == 1)."""
    g, m, k, n = 16, 512, 64, 64
    kw = dict(bm=128, bn=128, bk=128, dim_order="mn")
    shared = estimate_batched(g, m, k, n, shared_b=True, **kw)
    refetch = estimate_batched(g, m, k, n, **kw)
    assert shared.hbm_bytes < refetch.hbm_bytes
    # B counted once vs once per (batch entry x M-row block): the delta is
    # exactly (g * gm - 1) panel reads.
    panel = 128 * 128 * 4
    gm = m // 128
    assert refetch.hbm_bytes - shared.hbm_bytes == (g * gm - 1) * panel


# ---------------------------------------------------------------------------
# Call-site routing: MoE experts and attention BMMs hit the planner
# ---------------------------------------------------------------------------

def test_moe_routes_through_planner():
    """Router + all three expert projections go through core.gemm entry
    points: one MoE forward/backward must populate the batched-plan cache
    and re-hit it (gate/up share a shape; backward re-plans forward shapes)."""
    from repro.models.moe import init_moe_params, moe_mlp
    d, f, e = 32, 64, 4
    params = init_moe_params(jax.random.PRNGKey(0), d, f, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, d))

    clear_plan_cache()

    def loss(p, x):
        y, aux = moe_mlp(x, p, num_experts=e, top_k=2,
                         compute_dtype=jnp.float32)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params, x)
    assert all(np.all(np.isfinite(np.asarray(leaf))) for leaf in jax.tree.leaves(g))

    info = plan_batched_gemm.cache_info()
    assert info.currsize >= 2, info   # fwd (C,D,F) + (C,F,D) at least
    assert info.hits >= 3, info       # up reuses gate's plan; bwd reuses fwd


def test_attention_bmm_routes_through_planner():
    from repro.models.attention import blockwise_attention
    b, s, h, kvh, d = 2, 32, 4, 2, 16
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kvh, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kvh, d))
    clear_plan_cache()
    blockwise_attention(q, k, v, q_positions=jnp.arange(s),
                        kv_positions=jnp.arange(s), block_kv=16)
    info = plan_batched_gemm.cache_info()
    # qk ("nt") and pv ("nn") both planned (same (g, m, k, n) signature at
    # this size, so one miss + at least one hit).
    assert info.currsize >= 1 and info.hits >= 1, info
