"""Property-test API with a deterministic fallback when hypothesis is absent.

Test modules do ``from _prop import given, settings, st``: with hypothesis
installed they get the real thing; on a bare interpreter the same decorators
run a fixed-seed pseudo-random sweep over the declared strategies (integers
and lists-of-integers — enough for shape/distribution properties), so the
property tests still collect, run, and cover the same shape space —
deterministically (every run draws the identical examples).

Failing examples: hypothesis shrinks and persists its own database
(``.hypothesis/``); the fallback sweep appends the exact failing draw to
``$PROP_FAILURE_FILE`` (default ``.prop-failures.log``) and prints it before
re-raising, so CI can upload the seed either way.
"""
from __future__ import annotations

import os
import sys

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import random

    _DEFAULT_EXAMPLES = 12

    class _Integers:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def sample(self, rng: random.Random) -> int:
            # Bias towards the bounds — the cases property tests care about.
            r = rng.random()
            if r < 0.15:
                return self.lo
            if r < 0.3:
                return self.hi
            return rng.randint(self.lo, self.hi)

    class _Lists:
        """Fallback for ``st.lists(st.integers(...), ...)`` — the ragged
        group-size distributions.  Biases toward the degenerate shapes the
        ragged GEMM cares about: all-minimum (e.g. all-empty groups beside
        one), single-element, and max-length draws."""

        def __init__(self, elements: _Integers, min_size: int, max_size: int):
            self.elements, self.min_size, self.max_size = \
                elements, min_size, max_size

        def sample(self, rng: random.Random) -> list[int]:
            r = rng.random()
            if r < 0.15:
                n = self.min_size
            elif r < 0.3:
                n = self.max_size
            else:
                n = rng.randint(self.min_size, self.max_size)
            out = [self.elements.sample(rng) for _ in range(n)]
            if out and rng.random() < 0.2:   # one-giant-group-style skew
                out[rng.randrange(len(out))] = self.elements.hi
            return out

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> "_Integers":
            return _Integers(min_value, max_value)

        @staticmethod
        def lists(elements: _Integers, *, min_size: int = 0,
                  max_size: int = 10) -> "_Lists":
            return _Lists(elements, min_size, max_size)

    st = _Strategies()

    def _record_failure(name: str, draw: dict) -> None:
        path = os.environ.get("PROP_FAILURE_FILE", ".prop-failures.log")
        line = f"{name}(**{draw!r})"
        print(f"Falsifying example (deterministic fallback sweep): {line}",
              file=sys.stderr)
        try:
            with open(path, "a") as f:
                f.write(line + "\n")
        except OSError:
            pass

    def given(**strategies):
        def deco(fn):
            # No functools.wraps: exposing the wrapped signature would make
            # pytest treat the strategy parameters as fixtures.
            def wrapper():
                rng = random.Random(0xF71)
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for _ in range(n):
                    draw = {k: s.sample(rng) for k, s in strategies.items()}
                    try:
                        fn(**draw)
                    except Exception:
                        _record_failure(fn.__name__, draw)
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.hypothesis_fallback = True
            return wrapper
        return deco

    def settings(max_examples: int | None = None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = min(max_examples, _DEFAULT_EXAMPLES)
            return fn
        return deco
