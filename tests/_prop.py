"""Property-test API with a deterministic fallback when hypothesis is absent.

Test modules do ``from _prop import given, settings, st``: with hypothesis
installed they get the real thing; on a bare interpreter the same decorators
run a fixed-seed pseudo-random sweep over the declared integer strategies, so
the property tests still collect, run, and cover the same shape space —
deterministically (every run draws the identical examples).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import random

    _DEFAULT_EXAMPLES = 12

    class _Integers:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def sample(self, rng: random.Random) -> int:
            # Bias towards the bounds — the cases property tests care about.
            r = rng.random()
            if r < 0.15:
                return self.lo
            if r < 0.3:
                return self.hi
            return rng.randint(self.lo, self.hi)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> "_Integers":
            return _Integers(min_value, max_value)

    st = _Strategies()

    def given(**strategies):
        def deco(fn):
            # No functools.wraps: exposing the wrapped signature would make
            # pytest treat the strategy parameters as fixtures.
            def wrapper():
                rng = random.Random(0xF71)
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for _ in range(n):
                    draw = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(**draw)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.hypothesis_fallback = True
            return wrapper
        return deco

    def settings(max_examples: int | None = None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = min(max_examples, _DEFAULT_EXAMPLES)
            return fn
        return deco
