"""Placement-aware planning: the unified Plan hierarchy — the paper's
strategy-selection rule (§IV-C) decided *jointly* with block sizes, for all
three plan families, at mesh scale."""
import pytest

from repro.core.gemm import (DistPlan, estimate_ep, plan_batched_gemm,
                             plan_distributed, plan_gemm, plan_moe_dispatch,
                             plan_ragged_gemm)


def test_unplaced_plans_carry_no_placement():
    """No expert/mesh axis (num_shards == 1): every plan family returns the
    single-device plan — placement None, t_total == the local estimate."""
    for p in (plan_gemm(4096, 512, 64),
              plan_batched_gemm(8, 256, 64, 128),
              plan_ragged_gemm(8, 1024, 64, 128)):
        assert p.placement is None
        assert p.strategy == "single"
        assert p.t_total == p.est.t_total


def test_placed_plan_consistent_with_unplaced():
    """num_shards=1 must be byte-identical to the legacy spelling, and a
    placed plan's t_total must decompose per its schedule: local x waste +
    collective for the gather schedule, max(local x waste, collective) for
    the overlapped ring."""
    assert plan_ragged_gemm(16, 4096, 512, 1024) == \
        plan_ragged_gemm(16, 4096, 512, 1024, num_shards=1)
    assert plan_gemm(4096, 512, 64) == plan_gemm(4096, 512, 64, num_shards=1)
    p = plan_ragged_gemm(64, 512, 2048, 2048, 2, 2, num_shards=8)
    pl = p.placement
    if pl.schedule == "ring":
        assert p.t_total == pytest.approx(
            max(p.est.t_total * pl.waste, pl.t_collective))
    else:
        assert p.t_total == pytest.approx(
            p.est.t_total * pl.waste + pl.t_collective)


def test_dense_placed_strategy_crossover():
    """Paper §IV-C via the unified API: K-parallel iff M and N are both
    small and K is large.  The ring (overlapped) schedule hides the psum
    behind compute, so it may legitimately extend K-parallel's territory
    onto boundary shapes — but the UNOVERLAPPED crossover keeps the paper's
    rule: on a boundary shape only the ring schedule is allowed to steal
    the win from m_parallel."""
    assert plan_gemm(2**20, 64, 32,
                     num_shards=8).placement.strategy == "m_parallel"
    p = plan_gemm(32, 2**20, 32, num_shards=8)
    assert p.placement.strategy == "k_parallel"
    assert p.placement.t_collective > 0      # the psum is priced
    assert p.placement.ici_bytes > 0
    b = plan_gemm(20480, 20480, 32, num_shards=8).placement
    assert (b.strategy, b.schedule) in (("m_parallel", "gather"),
                                        ("k_parallel", "ring"))


def test_plan_distributed_is_the_placed_plan():
    """The dense compat view and the unified spelling are the same plan."""
    d = plan_distributed(32, 2**20, 32, 8)
    p = plan_gemm(32, 2**20, 32, num_shards=8)
    assert isinstance(d, DistPlan)
    assert d.strategy == p.placement.strategy == "k_parallel"
    assert d.t_total == p.t_total
    assert d.t_collective == p.placement.t_collective
    assert d.num_cores == 8
    assert d.local.kernel_kwargs() == p.kernel_kwargs()


def test_ragged_ep_only_when_exchange_amortized():
    """expert_parallel must win exactly when the per-shard panel-traffic
    saving (G -> G/nc panels) amortizes the all-to-all token exchange:
    few tokens against many large expert panels (the MoE decode regime)."""
    p = plan_ragged_gemm(64, 512, 2048, 2048, 2, 2, num_shards=8)
    assert p.placement.strategy == "expert_parallel"
    assert p.placement.t_collective > 0
    assert p.placement.ici_bytes > 0
    # Huge token stream against small panels: the exchange dwarfs the
    # panel saving -> token-parallel (replicated panels, no collective).
    p = plan_ragged_gemm(8, 1 << 20, 256, 256, 2, 2, num_shards=8)
    assert p.placement.strategy == "m_parallel"
    assert p.placement.t_collective == 0.0


def test_batched_ep_only_when_exchange_amortized():
    """Same crossover for the batched/grouped (capacity-mode) family."""
    p = plan_batched_gemm(64, 64, 2048, 2048, 2, 2, "none", num_shards=8)
    assert p.placement.strategy == "expert_parallel"
    p = plan_batched_gemm(4, 1 << 18, 256, 256, 2, 2, "none", num_shards=8)
    assert p.placement.strategy == "m_parallel"


def test_estimate_ep_prices_like_the_psum():
    """The a2a term follows the (nc-1)/nc send-fraction shape of the psum
    pricing, scales with rows x width, and vanishes on one shard."""
    e1 = estimate_ep(4096, 1024, 1)
    assert e1.ici_bytes == 0.0 and e1.t_exchange == 0.0
    e4 = estimate_ep(4096, 1024, 4, elt_bytes=2)
    e8 = estimate_ep(4096, 1024, 8, elt_bytes=2)
    assert 0 < e4.ici_bytes < e8.ici_bytes          # (nc-1)/nc grows
    assert estimate_ep(8192, 1024, 8, elt_bytes=2).ici_bytes == \
        pytest.approx(2 * e8.ici_bytes)
    tot = e4 + e8
    assert tot.ici_bytes == e4.ici_bytes + e8.ici_bytes
    assert tot.t_exchange == e4.t_exchange + e8.t_exchange


def test_plan_moe_dispatch_rows_and_placement():
    """The roofline's single source of truth: exact dispatch-buffer rows per
    mode, EP placement priced only when shards are requested."""
    cap = plan_moe_dispatch(1024, 8, 2, 512, 1024, dispatch="capacity")
    # E x capacity: int(1024*2*1.25/8) = 320, already a bf16-sublane multiple
    assert cap.rows == 8 * 320
    assert cap.placement is None
    # min-capacity clamp (tiny decode batches still pay E x sublane slots)
    tiny = plan_moe_dispatch(4, 8, 1, 512, 1024, dispatch="capacity",
                             capacity_factor=1.0)
    assert tiny.rows == 8 * 16
    rag = plan_moe_dispatch(1024, 8, 2, 512, 1024, dispatch="ragged")
    assert rag.rows == 2048 and rag.placement is None
    ep = plan_moe_dispatch(1024, 8, 2, 512, 1024, dispatch="ragged",
                           num_shards=8)
    assert ep.rows == 2048
    assert ep.placement.strategy == "expert_parallel"
    assert ep.placement.t_collective > 0 and ep.placement.ici_bytes > 0
    with pytest.raises(ValueError):
        plan_moe_dispatch(64, 8, 1, 16, 16, dispatch="nope")


def test_kernel_kwargs_unchanged_by_placement():
    """The placed plan's tiling is the LOCAL shard's tiling: executors feed
    kernel_kwargs() straight to the per-shard kernel."""
    p = plan_ragged_gemm(64, 512, 2048, 2048, 2, 2, num_shards=8)
    local = plan_ragged_gemm(8, 64, 2048, 2048, 2, 2)
    assert p.kernel_kwargs() == local.kernel_kwargs()
