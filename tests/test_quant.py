"""Low-precision GEMM family (ISSUE 8): quantizer units, int8/fp8
conformance inside the analytic error bound on T1/T2/T3 archetype shapes,
straight-through VJPs, per-expert bias epilogues (fwd + grad parity),
zero-drop quantized MoE parity, and the dtype axis of the plan-store key
(mixed-width round-trip + split-K quarantine)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts
from repro.core import quant
from repro.core.gemm import autotune, plan_store, tuner
from repro.core.gemm import batched_matmul, matmul, ragged_matmul
from repro.kernels.ftimm.epilogue import Epilogue

KEY = jax.random.PRNGKey(7)


def _mk(shape, seed=0, scale=1.0):
    return jax.random.normal(jax.random.fold_in(KEY, seed), shape,
                             jnp.float32) * scale


# ---------------------------------------------------------------------------
# core.quant units
# ---------------------------------------------------------------------------

def test_quant_config_validation():
    with pytest.raises(ValueError, match="unknown quant mode"):
        quant.QuantConfig(mode="int3")
    assert quant.resolve(None).is_noop
    cfg = quant.resolve("w8")
    assert cfg.weight_only and cfg.weight_bytes == 1
    assert quant.resolve("w4").levels == quant.INT4_LEVELS
    assert not quant.resolve("int8").weight_only
    assert quant.resolve(cfg) is cfg


def test_pack_int4_roundtrip():
    q = jax.random.randint(jax.random.fold_in(KEY, 3), (5, 16), -7, 8,
                           jnp.int32).astype(jnp.int8)
    packed = quant.pack_int4(q)
    assert packed.shape == (5, 8) and packed.dtype == jnp.int8
    np.testing.assert_array_equal(quant.unpack_int4(packed), q)
    with pytest.raises(ValueError, match="even"):
        quant.pack_int4(q[:, :15])


@pytest.mark.parametrize("mode", ["w8", "w4", "int8"])
def test_quantize_weights_scale_shapes_and_step(mode):
    cfg = quant.QuantConfig(mode=mode)
    w2 = _mk((24, 16), 4)
    q2, s2 = quant.quantize_weights(w2, cfg)
    assert s2.shape == (16,) and s2.dtype == jnp.float32
    # round-to-nearest: per-element decode error <= half a step
    err = jnp.abs(quant.dequantize(q2, s2) - w2)
    assert float(jnp.max(err - s2 / 2)) <= 1e-6

    w3 = _mk((3, 24, 16), 5)
    q3, s3 = quant.quantize_weights(w3, cfg)
    assert s3.shape == (3, 16)
    err3 = jnp.abs(quant.dequantize(q3, s3[:, None, :]) - w3)
    assert float(jnp.max(err3 - s3[:, None, :] / 2)) <= 1e-6

    # per-tensor: one scalar step broadcast to the (N,) operand layout
    qt, st = quant.quantize_weights(
        w2, quant.QuantConfig(mode=mode, per_channel=False))
    assert st.shape == (16,) and float(jnp.ptp(st)) == 0.0


@pytest.mark.parametrize("fmt", ["e4m3", "e5m2"])
def test_fp8_cast_within_step(fmt):
    x = _mk((32, 16), 6, scale=3.0)
    q, s = quant.quantize_fp8(x, fmt)
    assert q.dtype == quant.FP8_FORMATS[fmt][0]
    amax = float(jnp.max(jnp.abs(x)))
    err = jnp.abs(q.astype(jnp.float32) * s - x)
    assert float(jnp.max(err)) <= quant.fp8_step(amax, fmt)


def test_dot_error_bound_shape():
    # weight-only: zero activation step removes the activation term entirely
    assert quant.dot_error_bound(128, 1.0, 1.0, 0.0, 0.01) == \
        pytest.approx(128 * 1.0 * 0.005)
    # bound is linear in K and monotone in the steps
    assert quant.dot_error_bound(256, 1.0, 1.0, 0.1, 0.1) == \
        pytest.approx(2 * quant.dot_error_bound(128, 1.0, 1.0, 0.1, 0.1))
    assert quant.dot_error_bound(64, 1.0, 1.0, 0.2, 0.1) > \
        quant.dot_error_bound(64, 1.0, 1.0, 0.1, 0.1)


# ---------------------------------------------------------------------------
# Conformance: quantized matmul vs fp32 oracle within the analytic bound,
# on scaled instances of the paper's three irregular archetypes.
# ---------------------------------------------------------------------------

ARCHETYPES = [
    ("t1", 2048, 64, 32),      # M >> K ~ N
    ("t2", 32, 2048, 32),      # K >> M ~ N
    ("t3", 512, 512, 64),      # M ~ K >> N
]

QUANT_MODES = ["w8", "w4", "int8", "fp8_e4m3", "fp8_e5m2"]


def _analytic_bound(mode: str, a, b) -> float:
    k = a.shape[1]
    amax_a = float(jnp.max(jnp.abs(a)))
    amax_b = float(jnp.max(jnp.abs(b)))
    cfg = quant.QuantConfig(mode=mode)
    if mode in ("w8", "w4"):
        _, s = quant.quantize_weights(b, cfg)
        return quant.dot_error_bound(k, amax_a, amax_b, 0.0,
                                     float(jnp.max(s)))
    if mode == "int8":
        _, sw = quant.quantize_weights(b, cfg)
        sa = float(quant.symmetric_scale(a))
        return quant.dot_error_bound(k, amax_a, amax_b, sa,
                                     float(jnp.max(sw)))
    fmt = mode[4:]
    return quant.dot_error_bound(k, amax_a, amax_b,
                                 quant.fp8_step(amax_a, fmt),
                                 quant.fp8_step(amax_b, fmt))


@pytest.mark.parametrize("mode", QUANT_MODES)
@pytest.mark.parametrize("name,m,k,n", ARCHETYPES)
def test_quantized_matmul_within_bound(name, m, k, n, mode):
    a = _mk((m, k), 10, scale=0.5)
    b = _mk((k, n), 11, scale=0.3)
    got = matmul(a, b, quant=mode, out_dtype=jnp.float32)
    want = a @ b
    err = float(jnp.max(jnp.abs(got - want)))
    bound = _analytic_bound(mode, a, b)
    assert err <= bound, (name, mode, err, bound)
    # and the bound is not vacuous: quantization DID perturb the result
    assert err > 0.0


@pytest.mark.parametrize("mode", ["w8", "int8"])
def test_quantized_matmul_interpret_matches_xla(mode):
    a = _mk((48, 40), 12, scale=0.5)
    b = _mk((40, 24), 13, scale=0.3)
    ref = matmul(a, b, quant=mode, out_dtype=jnp.float32, backend="xla")
    got = matmul(a, b, quant=mode, out_dtype=jnp.float32,
                 backend="pallas_interpret")
    # same quantized operands either way; only the accumulator walk differs
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_quant_rejects_bad_spellings():
    a, b = _mk((16, 8)), _mk((8, 16), 1)
    with pytest.raises(ValueError, match="trans='nn'"):
        matmul(a, b.T, trans="nt", quant="w8")
    with pytest.raises(ValueError, match="dequant scale"):
        matmul(a, b, quant="w8", epilogue=Epilogue(scale_vec=True),
               scale=jnp.ones((16,)))


# ---------------------------------------------------------------------------
# Straight-through VJP: backward runs against the DEQUANTIZED panel
# ---------------------------------------------------------------------------

def test_quant_vjp_straight_through():
    a = _mk((64, 32), 20, scale=0.5)
    b = _mk((32, 48), 21, scale=0.3)
    ga, gb = jax.grad(
        lambda a_, b_: matmul(a_, b_, quant="w8",
                              out_dtype=jnp.float32).sum(),
        argnums=(0, 1))(a, b)
    q, s = quant.quantize_weights(b, quant.QuantConfig(mode="w8"))
    w_dq = quant.dequantize(q, s)
    ones = jnp.ones((64, 48), jnp.float32)
    np.testing.assert_allclose(ga, ones @ w_dq.T, rtol=1e-5, atol=1e-5)
    # dW is straight-through: the cotangent of the full-precision panel
    np.testing.assert_allclose(gb, a.T @ ones, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", ["int8", "fp8_e4m3"])
def test_quant_grads_finite(mode):
    a = _mk((32, 16), 22, scale=0.5)
    b = _mk((16, 24), 23, scale=0.3)
    ga, gb = jax.grad(
        lambda a_, b_: (matmul(a_, b_, quant=mode,
                               out_dtype=jnp.float32) ** 2).sum(),
        argnums=(0, 1))(a, b)
    assert bool(jnp.all(jnp.isfinite(ga))) and bool(jnp.all(jnp.isfinite(gb)))


# ---------------------------------------------------------------------------
# Per-expert bias epilogue: ragged + batched, forward and VJP parity
# ---------------------------------------------------------------------------

def _ragged_operands(rows=(5, 0, 7), k=16, n=24):
    g = len(rows)
    offsets = jnp.array(np.concatenate([[0], np.cumsum(rows)]), jnp.int32)
    t = int(offsets[-1])
    x = _mk((t, k), 30, scale=0.5)
    w = _mk((g, k, n), 31, scale=0.3)
    gid = np.repeat(np.arange(g), rows)
    return x, w, offsets, gid


def test_ragged_bias_forward_matches_oracle():
    x, w, offsets, gid = _ragged_operands()
    bias = _mk((w.shape[0], w.shape[2]), 32)
    got = ragged_matmul(x, w, offsets, bias=bias, out_dtype=jnp.float32)
    want = np.stack([np.asarray(x[i] @ w[gid[i]] + bias[gid[i]])
                     for i in range(x.shape[0])])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ragged_bias_grad_segment_sums():
    x, w, offsets, gid = _ragged_operands(rows=(5, 0, 7))
    bias = _mk((w.shape[0], w.shape[2]), 33)
    gx, gw, gbias = jax.grad(
        lambda x_, w_, b_: ragged_matmul(x_, w_, offsets, bias=b_,
                                         out_dtype=jnp.float32).sum(),
        argnums=(0, 1, 2))(x, w, bias)
    # d bias[e] = number of rows expert e saw (sum cotangent = ones)
    want = np.zeros(bias.shape, np.float32)
    for i, e in enumerate(gid):
        want[e] += 1.0
    np.testing.assert_allclose(gbias, want, rtol=1e-6, atol=1e-6)
    # dx/dw unchanged by the bias epilogue
    gx0, gw0 = jax.grad(
        lambda x_, w_: ragged_matmul(x_, w_, offsets,
                                     out_dtype=jnp.float32).sum(),
        argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, gx0, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gw, gw0, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("per_group", [False, True])
def test_batched_bias_forward_and_grad(per_group):
    g, m, k, n = 3, 8, 16, 24
    a = _mk((g, m, k), 34, scale=0.5)
    b = _mk((g, k, n), 35, scale=0.3)
    bias = _mk((g, n), 36) if per_group else _mk((n,), 36)
    got = batched_matmul(a, b, bias=bias, out_dtype=jnp.float32)
    bb = bias[:, None, :] if per_group else bias
    want = jnp.einsum("gmk,gkn->gmn", a, b) + bb
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    gbias = jax.grad(
        lambda b_: batched_matmul(a, b, bias=b_,
                                  out_dtype=jnp.float32).sum())(bias)
    want_g = np.full(bias.shape, float(m if per_group else g * m),
                     np.float32)
    np.testing.assert_allclose(gbias, want_g, rtol=1e-6, atol=1e-6)


def test_bias_shape_contract_raises():
    x, w, offsets, _ = _ragged_operands()
    with pytest.raises(contracts.ContractError, match="bad_bias_shape"):
        ragged_matmul(x, w, offsets, bias=jnp.ones((w.shape[2] + 1,)),
                      out_dtype=jnp.float32)
    a, b = _mk((2, 8, 16)), _mk((2, 16, 24), 1)
    with pytest.raises(contracts.ContractError, match="bad_bias_shape"):
        batched_matmul(a, b, bias=jnp.ones((3, 24)), out_dtype=jnp.float32)


def test_check_epilogue_vectors_units():
    epi = Epilogue(bias=True, scale_vec=True)
    vs = contracts.errors(contracts.check_epilogue_vectors(
        "dense", (64, 32, 16), epi, bias_shape=(8,), scale_shape=(16,)))
    assert [v.code for v in vs] == ["bad_bias_shape"]
    # ragged: both the shared (N,) and per-expert (G, N) layouts are legal
    ok = contracts.errors(contracts.check_epilogue_vectors(
        "ragged", (4, 100, 32, 16), epi, bias_shape=(4, 16),
        scale_shape=(16,)))
    assert not ok
    bad = contracts.errors(contracts.check_epilogue_vectors(
        "ragged", (4, 100, 32, 16), epi, scale_shape=(5, 16)))
    assert [v.code for v in bad] == ["bad_scale_shape"]


# ---------------------------------------------------------------------------
# Quantized ragged GEMM (the zero-drop MoE expert path)
# ---------------------------------------------------------------------------

def test_ragged_quant_within_bound_and_grads():
    x, w, offsets, gid = _ragged_operands(rows=(10, 6, 4), k=32, n=24)
    got = ragged_matmul(x, w, offsets, quant="w8", out_dtype=jnp.float32)
    want = np.stack([np.asarray(x[i] @ w[gid[i]])
                     for i in range(x.shape[0])])
    cfg = quant.QuantConfig(mode="w8")
    _, s = quant.quantize_weights(w, cfg)
    bound = quant.dot_error_bound(
        x.shape[1], float(jnp.max(jnp.abs(x))), float(jnp.max(jnp.abs(w))),
        0.0, float(jnp.max(s)))
    assert float(np.max(np.abs(np.asarray(got) - want))) <= bound

    # straight-through dx: cotangent against the DEQUANTIZED panels
    gx = jax.grad(lambda x_: ragged_matmul(x_, w, offsets, quant="w8",
                                           out_dtype=jnp.float32).sum())(x)
    q, s = quant.quantize_weights(w, cfg)
    w_dq = quant.dequantize(q, s[:, None, :])
    want_gx = np.stack([np.asarray(jnp.ones((w.shape[2],)) @ w_dq[e].T)
                        for e in gid])
    np.testing.assert_allclose(gx, want_gx, rtol=1e-5, atol=1e-5)
    gw = jax.grad(lambda w_: (ragged_matmul(x, w_, offsets, quant="w8",
                                            out_dtype=jnp.float32)
                              ** 2).sum())(w)
    assert bool(jnp.all(jnp.isfinite(gw)))


def test_ragged_quant_rejects_bias():
    x, w, offsets, _ = _ragged_operands()
    with pytest.raises(ValueError, match="does not take a bias"):
        ragged_matmul(x, w, offsets, quant="w8",
                      bias=jnp.ones((w.shape[0], w.shape[2])))


# ---------------------------------------------------------------------------
# Zero-drop quantized MoE parity
# ---------------------------------------------------------------------------

def test_moe_quant_parity_and_identical_routing():
    from repro.models.moe import init_moe_params, moe_mlp
    d, f, e = 32, 64, 4
    params = init_moe_params(KEY, d, f, e)
    x = _mk((24, d), 40, scale=0.5)
    ref, aux_ref = moe_mlp(x, params, num_experts=e, top_k=2,
                           dispatch="ragged", compute_dtype=jnp.float32)
    got, aux = moe_mlp(x, params, num_experts=e, top_k=2,
                       dispatch="ragged", compute_dtype=jnp.float32,
                       quant="w8")
    # the router is NEVER quantized: identical routing, identical aux loss
    np.testing.assert_allclose(aux, aux_ref, rtol=0, atol=0)
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel < 0.05, rel

    gx = jax.grad(lambda x_: moe_mlp(x_, params, num_experts=e, top_k=2,
                                     dispatch="ragged",
                                     compute_dtype=jnp.float32,
                                     quant="int8")[0].sum())(x)
    assert bool(jnp.all(jnp.isfinite(gx)))


def test_moe_capacity_quant_rejected():
    from repro.models.moe import init_moe_params, moe_mlp
    params = init_moe_params(KEY, 32, 64, 4)
    x = _mk((16, 32), 41)
    with pytest.raises(ValueError, match="ragged"):
        moe_mlp(x, params, num_experts=4, top_k=1, dispatch="capacity",
                quant="w8")


def test_registry_quant_suffixes():
    from repro.configs.registry import get_config
    cfg = get_config("llama4-scout-17b-a16e-w8-smoke")
    assert cfg.quant == "w8"
    assert cfg.moe_dispatch == "ragged" or cfg.num_experts > 0
    assert get_config("gemma3-4b-int8").quant == "int8"
    assert get_config("gemma3-4b").quant == "none"


# ---------------------------------------------------------------------------
# Plan-store dtype axis: mixed-width keys round-trip; split-K quarantine
# ---------------------------------------------------------------------------

@pytest.fixture(autouse=True)
def _clean_plan_state(monkeypatch):
    monkeypatch.delenv(plan_store.ENV_VAR, raising=False)
    tuner.clear_plan_cache()
    yield
    tuner.clear_plan_cache()


def test_dtype_keyed_plan_roundtrip(tmp_path):
    kw = dict(top_k=2, repeats=1, engine="xla", max_elements=1 << 16)
    r = autotune.autotune_gemm(4096, 256, 64, 2, 2, b_bytes=1, **kw)
    assert r.plan.mode == "measured"
    assert r.in_bytes == 2 and r.b_bytes == 1

    served = tuner.plan_gemm(4096, 256, 64, 2, 2, b_bytes=1)
    assert served.mode == "cached"
    # the homogeneous (legacy) key is a DIFFERENT shape signature: the
    # mixed-width winner must not leak into wide planning
    assert tuner.plan_gemm(4096, 256, 64, 2, 2).mode == "analytic"

    path = tmp_path / "plans.json"
    autotune.save_plan_cache(str(path))
    blob = json.load(open(path))
    assert any(key.endswith("|bb1") for key in blob["entries"])
    autotune.clear_plan_store()
    assert tuner.plan_gemm(4096, 256, 64, 2, 2, b_bytes=1).mode == "analytic"
    assert autotune.load_plan_cache(str(path)) >= 1
    again = tuner.plan_gemm(4096, 256, 64, 2, 2, b_bytes=1)
    assert again.mode == "cached"
    assert (again.bm, again.bn, again.bk) == (r.plan.bm, r.plan.bn,
                                              r.plan.bk)


def test_int8_key_and_calibration_fraction(tmp_path):
    kw = dict(top_k=2, repeats=1, engine="xla", max_elements=1 << 16)
    wide = autotune.autotune_gemm(4096, 256, 64, 4, 4, **kw)
    narrow = autotune.autotune_gemm(4096, 256, 64, 1, 4, **kw)
    assert narrow.in_bytes == 1 and narrow.b_bytes is None
    cal = autotune.calibrate([wide, narrow], store=False)
    assert cal.flops_frac_int8 is not None and cal.flops_frac_int8 > 0
    # the int8 fraction survives the JSON round-trip
    back = plan_store.Calibration.from_json(cal.to_json())
    assert back.flops_frac_int8 == pytest.approx(cal.flops_frac_int8)


def test_mixed_dtype_splitk_record_quarantined(tmp_path):
    key = "dense|4096x4096x128|ib2|ob2|bb1"
    good = {"bm": 128, "bn": 128, "bk": 128}
    assert not contracts.errors(contracts.check_record(key, good))
    bad = dict(good, nsplit=2)
    codes = [v.code for v in contracts.errors(
        contracts.check_record(key, bad))]
    assert codes == ["splitk_mixed_dtype"]

    path = tmp_path / "cache.json"
    path.write_text(json.dumps({
        "schema": plan_store.SCHEMA_VERSION,
        "device_kind": plan_store.device_kind(),
        "entries": {key: bad}}))
    st = plan_store.PlanStore()
    assert st.load(str(path)) == 0
    assert st.quarantined[key] == ["splitk_mixed_dtype"]
