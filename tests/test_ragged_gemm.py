"""Ragged (capacity-free) grouped ftIMM GEMM conformance suite.

Property-based: randomized ragged group-size distributions (empty groups,
one-giant-group, all-singletons, sublane-unaligned totals) checked against a
dense numpy reference for fp32/bf16, forward and VJP-vs-autodiff, on both the
Pallas-interpret and XLA backends — plus planner regressions (distribution-
signature cache hits, estimate_ragged monotonicity in total rows)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _prop import given, settings, st

from repro.core.gemm import (clear_plan_cache, estimate_ragged,
                             plan_ragged_gemm, ragged_matmul, ragged_swiglu,
                             TPU_V5E)
from repro.kernels.ftimm import (ragged_gemm, ragged_gemm_dw,
                                 ragged_gemm_swiglu, ref)

KEY = jax.random.PRNGKey(7)

# Ragged group-size distributions spanning the degenerate shapes:
# empty groups, one-giant-group, all-singletons, sublane-unaligned totals.
DISTS = [
    [5, 0, 17, 3],        # interior empty group, unaligned total (25)
    [0, 0, 40],           # leading empties + one giant group
    [1, 1, 1, 1, 1, 1, 1],  # all singletons, unaligned total
    [64],                 # single group, aligned total
    [0, 33, 0, 0],        # trailing empties
    [8, 16, 24, 32],      # aligned sizes, shared-boundary-free
]


def _offsets(sizes):
    return jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)


def _mk(sizes, d, f, dtype, seed=0):
    g, t = len(sizes), int(sum(sizes))
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, seed + 131 * t), 3)
    x = jax.random.normal(k1, (t, d), dtype)
    wg = jax.random.normal(k2, (g, d, f), dtype)
    wu = jax.random.normal(k3, (g, d, f), dtype)
    return x, wg, wu, _offsets(sizes)


def _np_ragged(x, w, sizes, trans="nn"):
    """Dense per-group numpy reference — the conformance ground truth."""
    x = np.asarray(x, np.float32)
    w = np.asarray(w, np.float32)
    n = w.shape[2] if trans == "nn" else w.shape[1]
    out = np.zeros((x.shape[0], n), np.float32)
    o = 0
    for g, s in enumerate(sizes):
        wg = w[g] if trans == "nn" else w[g].T
        out[o:o + s] = x[o:o + s] @ wg
        o += s
    return out


def _np_ragged_dw(x, dy, sizes):
    x = np.asarray(x, np.float32)
    dy = np.asarray(dy, np.float32)
    panels, o = [], 0
    for s in sizes:
        panels.append(x[o:o + s].T @ dy[o:o + s])
        o += s
    return np.stack(panels)


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 3e-4


# ---------------------------------------------------------------------------
# Kernel conformance: forward, both trans, both dtypes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes", DISTS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_kernel_vs_dense_reference(sizes, dtype):
    x, w, _, offs = _mk(sizes, 24, 40, dtype)
    got = ragged_gemm(x, w, offs, bm=16, bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               _np_ragged(x, w, sizes),
                               rtol=_tol(dtype), atol=_tol(dtype))


@pytest.mark.parametrize("sizes", DISTS[:3])
def test_ragged_kernel_nt(sizes):
    """The dX layout: rows against transposed panels (w read as (G, N, K))."""
    x, w, _, offs = _mk(sizes, 24, 40, jnp.float32)
    dy = jax.random.normal(KEY, (x.shape[0], 40), jnp.float32)
    got = ragged_gemm(dy, w, offs, bm=8, trans="nt", interpret=True)
    np.testing.assert_allclose(np.asarray(got), _np_ragged(dy, w, sizes, "nt"),
                               rtol=3e-4, atol=3e-4)


def test_ragged_kernel_multiblock_grid():
    """K and N both span several blocks (gk > 1, gn > 1) with shared
    boundary tiles (bm smaller than most groups)."""
    sizes = [37, 0, 3, 91, 1]
    x, w, _, offs = _mk(sizes, 200, 300, jnp.float32)
    got = ragged_gemm(x, w, offs, bm=16, bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), _np_ragged(x, w, sizes),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("sizes", DISTS)
def test_ragged_dw_kernel_vs_dense_reference(sizes):
    x, _, _, offs = _mk(sizes, 24, 40, jnp.float32)
    dy = jax.random.normal(KEY, (x.shape[0], 40), jnp.float32)
    got = ragged_gemm_dw(x, dy, offs, bk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), _np_ragged_dw(x, dy, sizes),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_swiglu_fused_matches_unfused_pair(dtype):
    """The fused epilogue must equal silu(gate) * up of the unfused pair."""
    sizes = [5, 0, 17, 3, 11]
    x, wg, wu, offs = _mk(sizes, 24, 40, dtype)
    fused = ragged_gemm_swiglu(x, wg, wu, offs, bm=8, interpret=True)
    a = ragged_gemm(x, wg, offs, bm=8, out_dtype=jnp.float32, interpret=True)
    b = ragged_gemm(x, wu, offs, bm=8, out_dtype=jnp.float32, interpret=True)
    want = jax.nn.silu(a) * b
    np.testing.assert_allclose(np.asarray(fused, np.float32),
                               np.asarray(want, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype))


# ---------------------------------------------------------------------------
# Property sweep: randomized distributions on both backends
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(sizes=st.lists(st.integers(0, 24), min_size=1, max_size=6))
def test_ragged_property_random_distributions(sizes):
    if sum(sizes) == 0:
        sizes = sizes + [1]   # contract: offsets[G] == T > 0
    x, w, _, offs = _mk(sizes, 16, 24, jnp.float32, seed=sum(sizes))
    want = _np_ragged(x, w, sizes)
    got_k = ragged_gemm(x, w, offs, bm=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got_k), want, rtol=3e-4, atol=3e-4)
    got_x = ragged_matmul(x, w, offs, backend="xla")
    np.testing.assert_allclose(np.asarray(got_x), want, rtol=3e-4, atol=3e-4)


@settings(max_examples=4, deadline=None)
@given(sizes=st.lists(st.integers(0, 16), min_size=1, max_size=4))
def test_ragged_property_grads_match_autodiff(sizes):
    """VJP (custom, planned) vs autodiff through the pure-jnp oracle."""
    if sum(sizes) == 0:
        sizes = sizes + [1]
    x, w, _, offs = _mk(sizes, 12, 16, jnp.float32, seed=7 * sum(sizes))

    def loss(backend):
        return lambda x, w: jnp.sum(
            ragged_matmul(x, w, offs, backend=backend) ** 2)

    def loss_ref(x, w):
        return jnp.sum(ref.ragged_matmul_ref(x, w, offs) ** 2)

    rx, rw = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    for backend in ("xla", "pallas_interpret"):
        gx, gw = jax.grad(loss(backend), argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(rx),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(rw),
                                   rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_ragged_swiglu_grads_match_autodiff(backend):
    sizes = [5, 0, 17, 3]
    x, wg, wu, offs = _mk(sizes, 16, 24, jnp.float32)

    def loss(x, a, b):
        return jnp.sum(ragged_swiglu(x, a, b, offs, backend=backend) ** 2)

    def loss_ref(x, a, b):
        return jnp.sum(ref.ragged_swiglu_ref(x, a, b, offs) ** 2)

    got = jax.grad(loss, argnums=(0, 1, 2))(x, wg, wu)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, wg, wu)
    for u, v in zip(got, want):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   rtol=3e-4, atol=3e-4)


def test_ragged_matmul_backends_agree():
    sizes = [9, 0, 22, 2]
    x, w, _, offs = _mk(sizes, 24, 40, jnp.float32)
    y_xla = ragged_matmul(x, w, offs, backend="xla")
    y_pal = ragged_matmul(x, w, offs, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_xla),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Planner regressions: distribution-signature cache + CMR monotonicity
# ---------------------------------------------------------------------------

def test_ragged_plan_deterministic_and_cached():
    a = plan_ragged_gemm(8, 256, 32, 64)
    b = plan_ragged_gemm(8, 256, 32, 64)
    assert a is b   # lru cache — the distribution signature IS the key


def test_ragged_plan_respects_budget_and_alignment():
    for g, total, k, n in [(4, 25, 24, 40), (16, 4096, 512, 1024),
                           (8, 7, 32, 48), (2, 100000, 128, 64)]:
        for ragged in ("m", "k"):
            p = plan_ragged_gemm(g, total, k, n, ragged=ragged)
            assert p.est.vmem_bytes <= TPU_V5E.vmem_budget
            assert p.bn % TPU_V5E.lane == 0
            assert p.bm % TPU_V5E.sublane_fp32 == 0
            assert p.bk % TPU_V5E.sublane_fp32 == 0


def test_ragged_plan_cache_hit_across_moe_calls():
    """Two moe_mlp ragged calls with the same distribution signature must
    re-use the cached plans (hit, not re-tune) — and the forward + backward
    GEMMs must all be visibly routed through the planner."""
    from repro.models.moe import init_moe_params, moe_mlp
    d, f, e = 32, 64, 4
    params = init_moe_params(jax.random.PRNGKey(0), d, f, e)

    def loss(p, x):
        y, aux = moe_mlp(x, p, num_experts=e, top_k=2,
                         compute_dtype=jnp.float32, dispatch="ragged")
        return jnp.sum(y ** 2) + 0.01 * aux

    clear_plan_cache()
    x1 = jax.random.normal(jax.random.PRNGKey(1), (64, d))
    jax.grad(loss)(params, x1)
    info1 = plan_ragged_gemm.cache_info()
    # swiglu fwd + down fwd + dX's + dW's: at least 2 distinct fwd signatures
    # and at least one ragged-K (dW) signature.
    assert info1.currsize >= 3, info1
    assert info1.hits >= 1, info1        # gate/up share one plan at minimum

    # Same signature (same T, E, D, F), different routing distribution: the
    # per-expert counts are dynamic — they must NOT re-key the planner.
    x2 = jax.random.normal(jax.random.PRNGKey(2), (64, d))
    jax.grad(loss)(params, x2)
    info2 = plan_ragged_gemm.cache_info()
    assert info2.currsize == info1.currsize, (info1, info2)
    assert info2.hits > info1.hits, (info1, info2)


def test_estimate_ragged_monotone_in_total_rows():
    """Guards the max-vs-sum pricing bug class: the ragged estimate must
    price the actual total, so more rows never gets cheaper."""
    kw = dict(bm=64, bn=128, bk=128, in_bytes=4, out_bytes=4)
    for ragged in ("m", "k"):
        prev_bytes, prev_flops, prev_t = -1.0, -1.0, -1.0
        for total in (1, 7, 64, 100, 512, 4096, 65536):
            e = estimate_ragged(8, total, 64, 128, ragged=ragged, **kw)
            assert e.hbm_bytes >= prev_bytes
            assert e.flops_padded >= prev_flops
            assert e.t_total >= prev_t
            prev_bytes, prev_flops, prev_t = \
                e.hbm_bytes, e.flops_padded, e.t_total


def test_estimate_ragged_prices_distribution_not_max():
    """The whole point vs capacity: G groups totalling T rows must be priced
    like ~T rows (+ boundary tiles), far below G x max_group_rows when the
    distribution is skewed."""
    g, k, n = 16, 128, 256
    kw = dict(bm=128, bn=128, bk=128, in_bytes=4, out_bytes=4)
    # Skewed: one giant group of 4096 rows, 15 empty -> total 4096.
    skew = estimate_ragged(g, 4096, k, n, ragged="m", **kw)
    # What a max-based (capacity) pricing would charge: 16 x 4096 rows.
    max_based = estimate_ragged(g, g * 4096, k, n, ragged="m", **kw)
    assert skew.hbm_bytes < 0.2 * max_based.hbm_bytes
    assert skew.flops_padded < 0.2 * max_based.flops_padded


@settings(max_examples=10, deadline=None)
@given(g=st.integers(1, 32), total=st.integers(1, 1 << 16),
       k=st.integers(1, 1024), n=st.integers(1, 1024))
def test_ragged_plan_property_budget(g, total, k, n):
    for ragged in ("m", "k"):
        p = plan_ragged_gemm(g, total, k, n, ragged=ragged)
        assert p.est.vmem_bytes <= TPU_V5E.vmem_budget
        assert p.est.flops_padded >= p.est.flops_useful
