"""Serving engine: batched continuous decoding matches single-request decode."""
import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine


def test_engine_greedy_matches_single():
    cfg = get_config("qwen3-1.7b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(3)]

    def run(reqs, slots):
        eng = ServeEngine(cfg, params, batch_slots=slots, max_len=32)
        return eng.run([Request(rid=i, prompt=p, max_new_tokens=5)
                        for i, p in enumerate(reqs)])

    single = [run([p], slots=1)[0].out_tokens for p in prompts]
    batched = [r.out_tokens for r in run(prompts, slots=3)]
    for s, b in zip(single, batched):
        assert s == b, (s, b)


def test_engine_slot_reuse_mixed_lengths():
    """Regression: freed-slot reuse with MIXED prompt lengths / depths.
    The fused decode used to run every slot at ``max(pos)`` — the shallower
    slot wrote the wrong KV row and masked under the deeper slot's horizon,
    so a short request sharing a batch with a long one diverged from its
    solo decode.  Per-slot position vectors fix it; this pins the fix."""
    cfg = get_config("qwen3-1.7b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    lens, mnts = [5, 12, 9, 7], [3, 10, 6, 8]
    prompts = [rng.integers(2, cfg.vocab_size, s).astype(np.int32)
               for s in lens]

    def run(reqs, slots):
        eng = ServeEngine(cfg, params, batch_slots=slots, max_len=48)
        return eng.run(reqs)

    single = [run([Request(rid=0, prompt=p, max_new_tokens=m)],
                  slots=1)[0].out_tokens
              for p, m in zip(prompts, mnts)]
    batched = run([Request(rid=i, prompt=p, max_new_tokens=m)
                   for i, (p, m) in enumerate(zip(prompts, mnts))], slots=2)
    for r, ref in zip(batched, single):
        assert r.out_tokens == ref, (r.rid, r.out_tokens, ref)


def test_engine_queues_beyond_slots():
    cfg = get_config("mamba2-370m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(2, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=24)
    done = eng.run(reqs)
    assert all(len(r.out_tokens) == 4 for r in done)
