"""Serving engine: batched continuous decoding matches single-request decode,
and the overload-safety machinery (admission, shedding, preemption, the
bucket-miss rung, off-loop detokenization) behaves under pressure."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.runtime import chaos
from repro.serve.engine import Overloaded, Request, ServeEngine


def test_engine_greedy_matches_single():
    cfg = get_config("qwen3-1.7b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(3)]

    def run(reqs, slots):
        eng = ServeEngine(cfg, params, batch_slots=slots, max_len=32)
        return eng.run([Request(rid=i, prompt=p, max_new_tokens=5)
                        for i, p in enumerate(reqs)])

    single = [run([p], slots=1)[0].out_tokens for p in prompts]
    batched = [r.out_tokens for r in run(prompts, slots=3)]
    for s, b in zip(single, batched):
        assert s == b, (s, b)


def test_engine_slot_reuse_mixed_lengths():
    """Regression: freed-slot reuse with MIXED prompt lengths / depths.
    The fused decode used to run every slot at ``max(pos)`` — the shallower
    slot wrote the wrong KV row and masked under the deeper slot's horizon,
    so a short request sharing a batch with a long one diverged from its
    solo decode.  Per-slot position vectors fix it; this pins the fix."""
    cfg = get_config("qwen3-1.7b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    lens, mnts = [5, 12, 9, 7], [3, 10, 6, 8]
    prompts = [rng.integers(2, cfg.vocab_size, s).astype(np.int32)
               for s in lens]

    def run(reqs, slots):
        eng = ServeEngine(cfg, params, batch_slots=slots, max_len=48)
        return eng.run(reqs)

    single = [run([Request(rid=0, prompt=p, max_new_tokens=m)],
                  slots=1)[0].out_tokens
              for p, m in zip(prompts, mnts)]
    batched = run([Request(rid=i, prompt=p, max_new_tokens=m)
                   for i, (p, m) in enumerate(zip(prompts, mnts))], slots=2)
    for r, ref in zip(batched, single):
        assert r.out_tokens == ref, (r.rid, r.out_tokens, ref)


def test_engine_queues_beyond_slots():
    cfg = get_config("mamba2-370m-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(2, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=24)
    done = eng.run(reqs)
    assert all(len(r.out_tokens) == 4 for r in done)


# ----------------------- overload-safety machinery -------------------------

def _bits(seed=0):
    cfg = get_config("qwen3-1.7b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    return cfg, params, rng


def test_page_exhaustion_preempts_and_recovers_bit_identical():
    """Forced page exhaustion at a decode-growth allocation preempts the
    lowest-priority (youngest) victim; after re-queue + re-prefill of
    prompt + generated-so-far, BOTH requests finish with exactly the
    tokens of the undisturbed run (greedy decode)."""
    cfg, params, rng = _bits(5)
    prompts = [rng.integers(2, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(2)]
    mk = lambda: [Request(rid=i, prompt=p, max_new_tokens=8)
                  for i, p in enumerate(prompts)]
    ref = [r.out_tokens for r in ServeEngine(
        cfg, params, batch_slots=2, max_len=32, page_size=4).run(mk())]
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32, page_size=4)
    # occurrences 0/1 are the two admission allocs (never preempt); 2 is
    # the first decode-growth alloc -> the preemption path.
    with chaos.chaos(chaos.FaultPlan(
            [chaos.Fault("page_exhaustion", at=2)])):
        out = eng.run(mk())
    assert [r.out_tokens for r in out] == ref
    assert eng.faults["preemptions"] == 1
    assert eng.health()["degraded_mode"]
    eng.alloc.check()
    assert eng.alloc.available == eng.alloc.total   # drained clean


def test_bucket_miss_falls_back_to_exact_prefill():
    cfg, params, rng = _bits(6)
    prompt = rng.integers(2, cfg.vocab_size, 9).astype(np.int32)
    mk = lambda: [Request(rid=0, prompt=prompt, max_new_tokens=4)]
    ref = ServeEngine(cfg, params, batch_slots=1,
                      max_len=32).run(mk())[0].out_tokens
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32)
    with chaos.chaos(chaos.FaultPlan([chaos.Fault("bucket_miss", at=0)])):
        out = eng.run(mk())[0].out_tokens
    assert out == ref
    assert eng.faults["bucket_misses"] == 1
    assert len(eng._prefill_cache) == 1     # the legacy rung compiled


def test_admission_rejects_with_typed_overloaded():
    """Once the cost model is calibrated, a deadline the projected
    completion cannot meet is rejected at submit() — typed, immediate,
    nothing queued.  Uncalibrated engines admit unconditionally."""
    cfg, params, rng = _bits(7)
    prompt = rng.integers(2, cfg.vocab_size, 6).astype(np.int32)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4,
                       deadline_s=1e-9))   # uncalibrated: admitted
    eng.queue.clear()
    # Two calibration requests: the first prefill/step walls per compiled
    # shape are compile time and deliberately not fed to the cost model.
    eng.run([Request(rid=1, prompt=prompt, max_new_tokens=4),
             Request(rid=11, prompt=prompt, max_new_tokens=4)])
    assert eng.cost.calibrated()
    with pytest.raises(Overloaded) as ei:
        eng.submit(Request(rid=2, prompt=prompt, max_new_tokens=40,
                           deadline_s=1e-9))
    assert ei.value.projected_s is not None
    assert ei.value.projected_s > ei.value.deadline_s
    assert eng.faults["admission_rejected"] == 1
    assert eng.queue == []                 # rejected, not queued


def test_oversized_request_rejected_up_front():
    cfg, params, rng = _bits(8)
    prompt = rng.integers(2, cfg.vocab_size, 6).astype(np.int32)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64,
                      page_size=4, num_pages=2)   # pool: 8 KV rows
    with pytest.raises(Overloaded, match="KV pages"):
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=60))


def test_shedding_drops_infeasible_queued_work_oldest_first():
    cfg, params, rng = _bits(9)
    prompt = rng.integers(2, cfg.vocab_size, 6).astype(np.int32)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=64)
    # Two calibration requests (first walls per shape are compile time and
    # skipped); shedding is estimate-gated so it needs a calibrated model.
    eng.run([Request(rid=0, prompt=prompt, max_new_tokens=4),
             Request(rid=10, prompt=prompt, max_new_tokens=4)])
    # Hand-queue around submit(): two deadline-infeasible requests and one
    # feasible one behind them — the infeasible pair sheds, the feasible
    # survives and completes.
    # Deadlines NOT yet expired (2s out) but infeasible: 100k tokens of
    # remaining work prices far beyond 2s at any measured step time.
    now = time.monotonic()
    doomed = [Request(rid=1, prompt=prompt, max_new_tokens=100_000,
                      deadline_s=2.0),
              Request(rid=2, prompt=prompt, max_new_tokens=100_000,
                      deadline_s=2.0)]
    ok = Request(rid=3, prompt=prompt, max_new_tokens=2, deadline_s=60.0)
    for r in doomed + [ok]:
        r.submitted_at = now
        eng.queue.append(r)
    while eng.queue or any(a is not None for a in eng.active):
        eng.step()
    assert all(r.shed and r.done for r in doomed)
    assert eng.faults["shed"] == 2
    assert not ok.shed and len(ok.out_tokens) == 2


def test_detokenize_runs_off_the_decode_loop():
    """slow_step-style timing proof: a deliberately slow detokenizer must
    not stall the decode loop — the worker thread absorbs it, and drain()
    delivers the complete text afterwards."""
    cfg, params, rng = _bits(10)
    prompt = rng.integers(2, cfg.vocab_size, 6).astype(np.int32)
    per_tok = 0.05
    slow = lambda t: (time.sleep(per_tok), f"<{t}>")[1]
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32,
                      detokenize=slow)
    eng.run([Request(rid=0, prompt=prompt, max_new_tokens=3)])  # warm/compile
    req = Request(rid=1, prompt=prompt, max_new_tokens=9)
    eng.submit(req)
    t0 = time.monotonic()
    while eng.queue or any(a is not None for a in eng.active):
        eng.step()
    loop_wall = time.monotonic() - t0
    total_sleep = per_tok * (req.max_new_tokens + 1)
    assert loop_wall < total_sleep * 0.8, (loop_wall, total_sleep)
    eng.drain_detok()
    assert req.text == "".join(f"<{t}>" for t in req.out_tokens)
    eng.close()


def test_priority_protects_high_priority_from_preemption():
    """Under forced exhaustion the LOWER-priority active request is the
    victim, even when it is older."""
    cfg, params, rng = _bits(11)
    prompts = [rng.integers(2, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(2)]
    lo = Request(rid=0, prompt=prompts[0], max_new_tokens=8, priority=0)
    hi = Request(rid=1, prompt=prompts[1], max_new_tokens=8, priority=5)
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32, page_size=4)
    # occ 0/1: admission allocs; occ 2: lo's growth (succeeds untouched);
    # occ 3: HI's growth forced-exhausted -> victim must be lo (priority 0)
    # even though lo is the older request.
    with chaos.chaos(chaos.FaultPlan(
            [chaos.Fault("page_exhaustion", at=3)])):
        eng.run([lo, hi])
    assert eng.faults["preemptions"] == 1
    assert len(lo.out_tokens) == 8 and len(hi.out_tokens) == 8
