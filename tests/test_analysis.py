"""Static kernel-contract verifier: mutation tests + shipped-candidate proof.

Every deliberately corrupted plan/BlockSpec/visit-list must be FLAGGED, and
every plan the shipped generators produce must PASS — plus the load-time
quarantine, the ``REPRO_VERIFY=1`` dispatch mode, the ragged zero-copy edge
path, and the committed-plan-cache round-trip (candidate pruning changes no
chosen plan)."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.analysis import contracts
from repro.analysis.sweep import run_sweep
from repro.core.gemm import dispatch, plan_store, tuner
from repro.core.gemm.cmr import TPU_V5E
from repro.core.gemm.shapes import PAPER_IRREGULAR_SHAPES
from repro.kernels.ftimm.epilogue import Epilogue

REPO = os.path.join(os.path.dirname(__file__), "..")
COMMITTED_CACHE = os.path.join(REPO, "results", "plan_cache.json")


def _codes(violations):
    return {v.code for v in contracts.errors(violations)}


# ---------------------------------------------------------------------------
# Every currently shipped candidate passes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (65536, 32, 32),        # paper T1
    (32, 1048576, 32),      # paper T2
    (20480, 20480, 96),     # paper T3
    (4097, 999, 31),        # worst-case unaligned edge
    (128, 4096, 14336),     # decode MLP
])
@pytest.mark.parametrize("width", [4, 2])
def test_shipped_dense_candidates_pass(m, k, n, width):
    for epi_ops in (0, 2):
        cands = tuner.gemm_candidates(m, k, n, width, width, TPU_V5E,
                                      epi_ops)
        assert cands
        for p in cands:
            vs = contracts.check_plan("dense", (m, k, n), p, in_bytes=width,
                                      out_bytes=width, coverage=True)
            assert not contracts.errors(vs), (p, [str(v) for v in vs])


def test_shipped_batched_and_ragged_candidates_pass():
    for g, m, k, n in [(8, 128, 4096, 14336), (16, 96, 1000, 31)]:
        for p in tuner.batched_candidates(g, m, k, n, 4, 4, "none", TPU_V5E):
            vs = contracts.check_plan("batched", (g, m, k, n), p,
                                      coverage=True)
            assert not contracts.errors(vs), (p, [str(v) for v in vs])
    for g, t, k, n in [(8, 1024, 4096, 14336), (64, 0, 4096, 1024),
                       (16, 100, 64, 31)]:
        for ragged in ("m", "k"):
            for p in tuner.ragged_candidates(g, t, k, n, 4, 4, ragged,
                                             TPU_V5E):
                vs = contracts.check_plan("ragged", (g, t, k, n), p,
                                          ragged=ragged)
                assert not contracts.errors(vs), (p, [str(v) for v in vs])


def test_kernel_bodies_mask_all_operands():
    assert contracts.check_contraction_masking() == []


def test_shipped_ragged_metadata_sorted():
    for offsets in ([0, 100, 228, 1024], [0, 0, 64, 64, 640], [0, 7],
                    [0, 16, 16], [0, 512]):
        for bm in (8, 64, 128):
            vs = contracts.check_ragged_visit_plan(offsets, bm)
            assert not contracts.errors(vs), (offsets, bm,
                                              [str(v) for v in vs])


# ---------------------------------------------------------------------------
# Mutation tests: each corruption must be flagged
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def base_plan():
    return tuner.plan_gemm(4096, 4096, 4096)


def test_mutation_unclamped_bk(base_plan):
    # The PR 5 bug class: cached bk=512 against K=64 pads K 8-fold.
    p = dataclasses.replace(base_plan, bk=512)
    assert "unclamped_block" in _codes(
        contracts.check_plan("dense", (4096, 64, 4096), p))


def test_mutation_misaligned_blocks(base_plan):
    p = dataclasses.replace(base_plan, bm=100)
    assert "misaligned_block" in _codes(
        contracts.check_plan("dense", (4096, 4096, 4096), p))
    p = dataclasses.replace(base_plan, bn=96)
    assert "misaligned_block" in _codes(
        contracts.check_plan("dense", (4096, 4096, 4096), p))


def test_mutation_over_budget_accumulator(base_plan):
    p = dataclasses.replace(base_plan, bm=4096, bn=4096, bk=128)
    assert "vmem_budget" in _codes(
        contracts.check_plan("dense", (4096, 4096, 4096), p))


def test_mutation_splitk_nonlinear_epilogue(base_plan):
    p = dataclasses.replace(base_plan, nsplit=2, bk=128, fuse=True)
    vs = contracts.check_plan("dense", (4096, 4096, 4096), p,
                              epilogue=Epilogue(activation="silu"))
    assert "splitk_nonlinear_epilogue" in _codes(vs)
    # The linear tail stays legal (applied post-reduction).
    vs = contracts.check_plan("dense", (4096, 4096, 4096), p,
                              epilogue=Epilogue(bias=True))
    assert "splitk_nonlinear_epilogue" not in _codes(vs)


def test_mutation_nonpositive_and_bad_order(base_plan):
    p = dataclasses.replace(base_plan, bk=0)
    assert "nonpositive_block" in _codes(
        contracts.check_plan("dense", (4096, 4096, 4096), p))
    p = dataclasses.replace(base_plan, dim_order="km")
    assert "bad_dim_order" in _codes(
        contracts.check_plan("dense", (4096, 4096, 4096), p))


def test_mutation_overlapping_index_map(base_plan):
    # Corrupted BlockSpec: two parallel grid points store the same block.
    c = contracts.variant_contract("dense", (4096, 4096, 4096),
                                   dataclasses.replace(base_plan, bk=128))
    bad = dataclasses.replace(c, out_index_map=lambda i, j, k: (i // 2, j))
    codes = {v.code for v in contracts.verify_contract(bad)}
    assert "write_race" in codes and "coverage_gap" in codes


def test_mutation_store_moves_with_reduction(base_plan):
    c = contracts.variant_contract("dense", (4096, 4096, 4096),
                                   dataclasses.replace(base_plan, bk=128))
    bad = dataclasses.replace(
        c, out_index_map=lambda i, j, k: (i, (j + k) % c.out_extent[1]))
    codes = {v.code for v in contracts.verify_contract(bad)}
    assert "store_moves_with_reduction" in codes


def test_mutation_out_of_range_store(base_plan):
    c = contracts.variant_contract("dense", (4096, 4096, 4096),
                                   dataclasses.replace(base_plan, bk=128))
    bad = dataclasses.replace(c, out_index_map=lambda i, j, k: (i + 1, j))
    assert "out_of_range_store" in {v.code
                                    for v in contracts.verify_contract(bad)}


def _single_masked_body(a_blk, b_blk, k_lim):
    # Deliberately unsound: masks A only; 0 * NaN from B's remainder leaks.
    a_blk = _mask_contract(a_blk, k_lim, 1)     # noqa: F821
    return a_blk @ b_blk


def test_mutation_missing_k_mask():
    vs = contracts.check_contraction_masking(accum_body=_single_masked_body)
    assert "missing_k_mask" in {v.code for v in vs}
    assert contracts.masked_operand_count(_single_masked_body) == 1


def test_mutation_shuffled_visit_list():
    # A reordering regression in the sorted visit list must be caught
    # statically: the masked read-modify-write is the ordered exception.
    vs = contracts.check_ragged_visits([0, 100, 228], 2, 128,
                                       gids=[1, 0], tids=[1, 0],
                                       valid=[1, 1])
    codes = _codes(vs)
    assert "unsorted_visits" in codes and "unsorted_groups" in codes
    vs = contracts.check_ragged_visits([0, 100, 228], 2, 128,
                                       gids=[0, 0], tids=[0, 0],
                                       valid=[1, 1])
    codes = _codes(vs)
    assert "duplicate_visit" in codes and "ragged_row_uncovered" in codes


def test_mutation_ep_indivisible():
    placement = tuner.Placement(strategy="expert_parallel", num_shards=3)
    assert "ep_indivisible" in _codes(
        contracts.check_placement("ragged", (8, 1024, 256, 256), placement))
    ok = tuner.Placement(strategy="expert_parallel", num_shards=4)
    assert not contracts.errors(
        contracts.check_placement("ragged", (8, 1024, 256, 256), ok))


# ---------------------------------------------------------------------------
# Plan-store quarantine + telemetry
# ---------------------------------------------------------------------------

def test_plan_store_quarantines_bad_records(tmp_path):
    path = tmp_path / "cache.json"
    blob = {"schema": plan_store.SCHEMA_VERSION,
            "device_kind": plan_store.device_kind(),
            "entries": {
                "dense|4096x64x4096|ib4|ob4":
                    {"bm": 128, "bn": 128, "bk": 512},    # unclamped bk
                "dense|4096x4096x4096|ib4|ob4":
                    {"bm": 128, "bn": 128, "bk": 128},    # fine
                "garbage-key": {"bm": 128, "bn": 128, "bk": 128},
            }}
    path.write_text(json.dumps(blob))
    st = plan_store.PlanStore()
    n = st.load(str(path))
    assert n == 1
    assert set(st.quarantined) == {"dense|4096x64x4096|ib4|ob4",
                                   "garbage-key"}
    assert st.quarantined["dense|4096x64x4096|ib4|ob4"] == \
        ["unclamped_block"]
    assert st.lookup("dense|4096x4096x4096|ib4|ob4") is not None
    assert st.lookup("dense|4096x64x4096|ib4|ob4") is None
    st.clear()
    assert not st.quarantined


def test_quarantine_counted_in_plan_mode_stats(tmp_path):
    path = tmp_path / "cache.json"
    blob = {"schema": plan_store.SCHEMA_VERSION,
            "device_kind": plan_store.device_kind(),
            "entries": {"dense|512x64x512|ib4|ob4":
                        {"bm": 128, "bn": 128, "bk": 1024}}}
    path.write_text(json.dumps(blob))
    tuner.clear_plan_cache()
    try:
        plan_store.get_store().load(str(path))
        stats = tuner.plan_mode_stats()
        assert stats["dense"]["quarantined"] == 1
    finally:
        tuner.clear_plan_cache()


# ---------------------------------------------------------------------------
# Committed-cache round-trip: pruning changes no chosen plan
# ---------------------------------------------------------------------------

def test_committed_cache_records_all_pass():
    blob = json.load(open(COMMITTED_CACHE))
    assert blob["entries"]
    for key, rec in blob["entries"].items():
        vs = contracts.errors(contracts.check_record(key, rec))
        assert not vs, (key, [str(v) for v in vs])


def test_candidate_pruning_roundtrip_on_committed_cache():
    blob = json.load(open(COMMITTED_CACHE))
    for key in blob["entries"]:
        pk = contracts.parse_key(key)
        assert pk is not None and pk.family == "dense"
        m, k, n = pk.dims
        for epi_ops in (0, 2):
            with_check = tuner.gemm_candidates(
                m, k, n, pk.in_bytes, pk.out_bytes, TPU_V5E, epi_ops,
                verify=True)
            without = tuner.gemm_candidates(
                m, k, n, pk.in_bytes, pk.out_bytes, TPU_V5E, epi_ops,
                verify=False)
            pick = lambda cs: min(cs, key=lambda p: p.est.t_total)  # noqa: E731
            assert pick(with_check) == pick(without), key
            assert set(with_check) == set(without), key


# ---------------------------------------------------------------------------
# REPRO_VERIFY=1 dispatch mode
# ---------------------------------------------------------------------------

def test_repro_verify_accepts_planned_calls(monkeypatch):
    import jax.numpy as jnp
    monkeypatch.setenv("REPRO_VERIFY", "1")
    a = jnp.ones((100, 70), jnp.float32)
    b = jnp.ones((70, 50), jnp.float32)
    y = dispatch.matmul(a, b, epilogue=Epilogue(bias=True),
                        bias=jnp.ones((50,), jnp.float32))
    np.testing.assert_allclose(np.asarray(y), 71.0)


def test_repro_verify_rejects_corrupt_plan(monkeypatch):
    import jax.numpy as jnp
    monkeypatch.setenv("REPRO_VERIFY", "1")
    good = tuner.plan_gemm(96, 64, 48, 4, 4)
    corrupt = dataclasses.replace(good, bk=2048)    # unclamped vs K=64
    monkeypatch.setattr(dispatch, "plan_gemm",
                        lambda *a, **kw: corrupt)
    dispatch._verify_cached.cache_clear()
    with pytest.raises(contracts.ContractError, match="unclamped_block"):
        dispatch.matmul(jnp.ones((96, 64), jnp.float32),
                        jnp.ones((64, 48), jnp.float32))
    dispatch._verify_cached.cache_clear()


def test_repro_verify_off_skips_checks(monkeypatch):
    import jax.numpy as jnp
    monkeypatch.delenv("REPRO_VERIFY", raising=False)
    good = tuner.plan_gemm(96, 64, 48, 4, 4)
    corrupt = dataclasses.replace(good, bk=2048)
    monkeypatch.setattr(dispatch, "plan_gemm", lambda *a, **kw: corrupt)
    # XLA backend ignores blocks; without REPRO_VERIFY the bad plan is
    # only a bad *decision*, not an assertion failure.
    y = dispatch.matmul(jnp.ones((96, 64), jnp.float32),
                        jnp.ones((64, 48), jnp.float32))
    assert y.shape == (96, 48)


# ---------------------------------------------------------------------------
# Ragged zero-copy edge path (satellite bugfix)
# ---------------------------------------------------------------------------

def test_ragged_wrappers_skip_pad_when_aligned(monkeypatch):
    import jax.numpy as jnp
    from repro.kernels.ftimm import ops

    calls = []
    orig = ops._pad_to

    def counting(x, shape):
        calls.append(shape)
        return orig(x, shape)

    monkeypatch.setattr(ops, "_pad_to", counting)
    # Unique block-aligned shapes (fresh jit trace so the counter sees it).
    x = jnp.ones((384, 256), jnp.float32)
    w = jnp.ones((3, 256, 384), jnp.float32)
    off = jnp.asarray([0, 128, 200, 384], jnp.int32)
    y = ops.ragged_gemm(x, w, off, bm=64, bn=128, bk=128)
    assert calls == [] and y.shape == (384, 384)
    dw = ops.ragged_gemm_dw(x, jnp.ones((384, 128), jnp.float32), off,
                            bm=128, bn=128, bk=64)
    assert calls == [] and dw.shape == (3, 256, 128)
    # Unaligned rows still pad (and still compute correctly).
    xu = jnp.ones((250, 256), jnp.float32)
    offu = jnp.asarray([0, 128, 200, 250], jnp.int32)
    yu = ops.ragged_gemm(xu, w, offu, bm=64, bn=128, bk=128)
    assert calls and yu.shape == (250, 384)
    np.testing.assert_allclose(np.asarray(yu), 256.0)


def test_ragged_aligned_matches_unaligned_numerics(rng_key):
    import jax
    import jax.numpy as jnp
    from repro.kernels.ftimm import ops
    k1, k2 = jax.random.split(rng_key)
    x = jax.random.normal(k1, (256, 128), jnp.float32)
    w = jax.random.normal(k2, (4, 128, 256), jnp.float32)
    off = jnp.asarray([0, 64, 100, 200, 256], jnp.int32)
    y = ops.ragged_gemm(x, w, off, bm=64, bn=128, bk=128)
    bounds = np.asarray(off)
    ref = np.concatenate([
        np.asarray(x)[s:e] @ np.asarray(w)[i]
        for i, (s, e) in enumerate(zip(bounds[:-1], bounds[1:]))])
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# The sweep itself
# ---------------------------------------------------------------------------

def test_run_sweep_quick_zero_violations():
    report = run_sweep(shapes=PAPER_IRREGULAR_SHAPES[:3],
                       archs=["qwen3-1.7b", "mixtral-8x7b"],
                       cache_path=COMMITTED_CACHE)
    assert report["violations"] == [], report["violations"][:5]
    assert report["candidates_checked"] > 100
    assert report["plan_cache"]["entries"] == 28
    assert report["plan_cache"]["quarantine_candidates"] == 0
