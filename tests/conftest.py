"""Shared fixtures. NOTE: device count stays 1 here by design — multi-device
behaviour is tested via subprocesses (tests/helpers.py) so the dry-run's 512
fake devices never leak into smoke tests."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Kernel tests run in interpret mode on CPU.
os.environ.setdefault("REPRO_GEMM_BACKEND", "xla")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: subprocess tests that boot a fresh interpreter with fake "
        "devices (tests/helpers.py); deselect with -m 'not slow'")


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)
