"""Blockwise attention vs naive softmax oracle under every mask type, and
the flash-decode (K-parallel) path on a fake multi-device mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import blockwise_attention

from helpers import run_with_devices

KEY = jax.random.PRNGKey(3)


def naive_attention(q, k, v, q_pos, kv_pos, window, causal):
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    qf = q.reshape(b, sq, kvh, g, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32)) * d**-0.5
    qq = q_pos[:, None]
    kk = kv_pos[None, :]
    ok = (kk <= qq) if causal else jnp.ones((sq, skv), bool)
    if window > 0:
        ok &= kk > qq - window
    if window < 0:
        ok &= (qq // (-window)) == (kk // (-window))
    s = jnp.where(ok[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d)


@pytest.mark.parametrize("window", [0, 7, -8])
@pytest.mark.parametrize("causal", [True, False])
def test_blockwise_matches_naive(window, causal):
    if window and not causal:
        pytest.skip("windows only used causally in the stack")
    b, s, h, kvh, d = 2, 48, 4, 2, 16
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kvh, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kvh, d))
    pos = jnp.arange(s)
    got = blockwise_attention(q, k, v, q_positions=pos, kv_positions=pos,
                              window=window, causal=causal, block_kv=16)
    want = naive_attention(q, k, v, pos, pos, window, causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_blockwise_kv_valid_len():
    """Masked tail of a cache buffer must not contribute."""
    b, s, h, d = 1, 32, 2, 8
    q = jax.random.normal(KEY, (b, 4, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, h, d))
    qpos = jnp.arange(12, 16)
    got = blockwise_attention(q, k, v, q_positions=qpos,
                              kv_positions=jnp.arange(s), window=0,
                              causal=True, kv_valid_len=16, block_kv=8)
    want = naive_attention(q, k[:, :16], v[:, :16], qpos, jnp.arange(16),
                           0, True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_flash_decode_matches_single_device():
    run_with_devices("""
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.core.dist import DistContext, use_dist
from repro.models import model as M

cfg = get_config("gemma3-4b-smoke")   # windows + qk_norm exercise masks
key = jax.random.PRNGKey(0)
params = M.init_params(cfg, key)
B, S = 4, 32
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
cache = M.make_cache(cfg, B, S + 4)
lg, cache = M.prefill(params, cfg, batch, cache)
tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
ref, _ = M.decode_step(params, cfg, tok, cache, jnp.int32(S))
from repro.core.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
with use_dist(DistContext(mesh=mesh, dp_axes=("data",), model_axis="model")):
    sp, _ = jax.jit(lambda p, t, c, i: M.decode_step(p, cfg, t, c, i))(
        params, tok, cache, jnp.int32(S))
np.testing.assert_allclose(np.asarray(ref, np.float32),
                           np.asarray(sp, np.float32), rtol=3e-2, atol=3e-2)
print("OK")
""", n_devices=8)
