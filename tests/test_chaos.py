"""Chaos-tested graceful degradation: seeded fault injection, the dispatch
fallback ladder (every rung oracle-checked, degraded counter exactly once
per fault), elastic re-planned recovery, plan-store crash/corruption
atomicity, and serve-engine containment."""
import json
import warnings

import numpy as np
import pytest
from helpers import run_with_devices

import jax
import jax.numpy as jnp

from repro.core.gemm import (batched_matmul, matmul, plan_mode_stats,
                             ragged_matmul, ragged_swiglu)
from repro.core.gemm import dispatch as _dispatch
from repro.core.gemm import plan_store
from repro.core.gemm.tuner import clear_plan_cache, clear_planner_caches
from repro.runtime import chaos


# ----------------------------- the harness --------------------------------

def test_fault_plan_occurrence_windows():
    p = chaos.FaultPlan([chaos.Fault("kernel", at=1, count=2)])
    fired = [p.should_fire("kernel") is not None for _ in range(5)]
    assert fired == [False, True, True, False, False]
    assert p.counters["kernel"] == 5 and p.fired["kernel"] == 2
    # other sites are independent
    assert p.should_fire("ep_ring") is None


def test_parse_env_spec():
    p = chaos.parse_env(
        "kernel@2x3; shard_loss@1:chips=4 ;slow_step@0:delay_s=0.5;seed=7")
    assert p.seed == 7
    k = [f for f in p.faults if f.site == "kernel"][0]
    assert (k.at, k.count) == (2, 3)
    s = [f for f in p.faults if f.site == "shard_loss"][0]
    assert s.chips == 4
    d = [f for f in p.faults if f.site == "slow_step"][0]
    assert d.delay_s == 0.5


@pytest.mark.parametrize("spec,needle", [
    ("kernel@0;", "trailing ';'"),
    ("kernel@0;;slow_step@1", "empty segment"),
    ("kernle@0", "unknown site 'kernle'"),
    ("kernel@x", "occurrence 'x' is not an integer"),
    ("kernel@0x1.5", "count '1.5' is not an integer"),
    ("shard_loss@0:chps=4", "unknown payload key 'chps'"),
    ("slow_step@0:delay_s=fast", "payload delay_s='fast' is not numeric"),
    ("shard_loss@0:chips", "'chips' is not key=value"),
    ("seed=pi", "seed must be an integer"),
])
def test_parse_env_rejects_malformed_specs(spec, needle):
    """A typo'd REPRO_CHAOS must fail loudly at startup, naming the
    offending segment — a chaos CI leg that silently arms nothing would
    pass while testing nothing."""
    with pytest.raises(ValueError) as ei:
        chaos.parse_env(spec)
    msg = str(ei.value)
    assert "malformed REPRO_CHAOS segment" in msg
    assert needle in msg, (needle, msg)


def test_parse_env_empty_spec_is_no_plan():
    assert chaos.parse_env("").faults == []
    assert chaos.parse_env("   ").faults == []


def test_parse_env_new_sites_and_burst_payload():
    p = chaos.parse_env(
        "page_exhaustion@2;bucket_miss@0x3;burst_arrival@1:burst=8")
    sites = {f.site: f for f in p.faults}
    assert sites["page_exhaustion"].at == 2
    assert sites["bucket_miss"].count == 3
    assert sites["burst_arrival"].burst == 8


def test_clear_plan_cache_resets_degraded_and_warn_once_state():
    """Regression: ``clear_plan_cache`` is documented as THE single reset
    entry point, but the dispatch ladder's warn-once dedup set used to
    survive it — after a reset, a recurring degradation was silently
    swallowed instead of logged again."""
    from repro.core.gemm import dispatch, tuner
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        dispatch._degraded("dense", "pallas->xla", RuntimeError("boom"))
    assert tuner.DEGRADED_COUNTS
    assert dispatch._WARNED_RUNGS
    tuner.clear_plan_cache()
    assert not tuner.DEGRADED_COUNTS
    assert not dispatch._WARNED_RUNGS


def test_context_manager_restores_state():
    with chaos.chaos(chaos.FaultPlan([chaos.Fault("kernel")])):
        assert chaos.active() is not None
        with pytest.raises(chaos.KernelLaunchFailure):
            chaos.fire("kernel")
    assert chaos.should_fire("kernel") is None   # no plan outside the block


# ------------------------- dispatch fallback ladder ------------------------

def _degraded_counts() -> dict:
    return dict(plan_mode_stats().get("degraded", {}))


def _rng(seed, *shape):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape), jnp.float32)


def test_ladder_dense_pallas_to_xla():
    a, b = _rng(0, 24, 16), _rng(1, 16, 20)
    oracle = matmul(a, b, backend="xla")
    before = _degraded_counts().get("dense:pallas->xla", 0)
    with chaos.chaos(chaos.FaultPlan([chaos.Fault("kernel", at=0)])):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            out = matmul(a, b, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)
    assert _degraded_counts()["dense:pallas->xla"] == before + 1


def test_ladder_batched_pallas_to_xla():
    a, b = _rng(2, 3, 24, 16), _rng(3, 3, 16, 20)
    oracle = batched_matmul(a, b, backend="xla")
    before = _degraded_counts().get("batched:pallas->xla", 0)
    with chaos.chaos(chaos.FaultPlan([chaos.Fault("kernel", at=0)])):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            out = batched_matmul(a, b, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)
    assert _degraded_counts()["batched:pallas->xla"] == before + 1


def test_ladder_ragged_pallas_to_xla():
    x, w = _rng(4, 24, 16), _rng(5, 2, 16, 20)
    offs = jnp.asarray([0, 10, 24], jnp.int32)
    oracle = ragged_matmul(x, w, offs, backend="xla")
    before = _degraded_counts().get("ragged:pallas->xla", 0)
    with chaos.chaos(chaos.FaultPlan([chaos.Fault("kernel", at=0)])):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            out = ragged_matmul(x, w, offs, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)
    assert _degraded_counts()["ragged:pallas->xla"] == before + 1


def test_ladder_fused_to_unfused_swiglu():
    x = _rng(6, 24, 16)
    wg, wu = _rng(7, 2, 16, 20), _rng(8, 2, 16, 20)
    offs = jnp.asarray([0, 10, 24], jnp.int32)
    oracle = ragged_swiglu(x, wg, wu, offs, backend="xla")
    before = _degraded_counts().get("ragged:fused->unfused", 0)
    with chaos.chaos(chaos.FaultPlan([chaos.Fault("kernel_fused", at=0)])):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            out = ragged_swiglu(x, wg, wu, offs, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)
    assert _degraded_counts()["ragged:fused->unfused"] == before + 1


def test_ladder_counts_once_per_fault_and_warns_once():
    a, b = _rng(9, 24, 16), _rng(10, 16, 20)
    _dispatch._WARNED_RUNGS.discard(("dense", "pallas->xla"))
    before = _degraded_counts().get("dense:pallas->xla", 0)
    with chaos.chaos(chaos.FaultPlan([chaos.Fault("kernel", at=0, count=2)])):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            matmul(a, b, backend="pallas_interpret")
            matmul(a[:23], b, backend="pallas_interpret")  # new shape: retrace
    assert _degraded_counts()["dense:pallas->xla"] == before + 2
    ladder = [r for r in rec if "gemm dispatch degraded" in str(r.message)]
    assert len(ladder) == 1    # first occurrence logged, repeats silent


# -------------------- stale-shard plans after a re-mesh --------------------

def test_stale_shard_cached_plans_not_served():
    """Placed plans are keyed with a ``|shards{n}`` suffix: a measured
    winner recorded at 8 shards must not be served when the elastic shrink
    re-plans at 4."""
    from repro.core.gemm.tuner import plan_gemm
    clear_plan_cache()
    try:
        p8 = plan_gemm(4096, 1024, 2048, num_shards=8, axis="data")
        store = plan_store.get_store()
        store.put(
            plan_store.shape_key("dense", (4096, 1024, 2048), 4, 4,
                                 num_shards=8),
            {"bm": p8.bm, "bn": p8.bn, "bk": p8.bk,
             "dim_order": p8.dim_order,
             "strategy": p8.placement.strategy,
             "schedule": p8.placement.schedule,
             "mode": "measured"})
        clear_planner_caches()
        assert plan_gemm(4096, 1024, 2048,
                         num_shards=8, axis="data").mode == "cached"
        p4 = plan_gemm(4096, 1024, 2048, num_shards=4, axis="data")
        assert p4.mode == "analytic"
        assert p4.placement.num_shards == 4
    finally:
        clear_plan_cache()


# ---------------------- plan-store crash & corruption ----------------------

def test_crash_mid_save_leaves_store_intact(tmp_path):
    path = str(tmp_path / "plans.json")
    st = plan_store.PlanStore()
    st.put("dense|64x64x64|ib4|ob4", {"bm": 64, "bn": 64, "bk": 64})
    st.save(path)
    st.put("dense|128x64x64|ib4|ob4", {"bm": 128, "bn": 64, "bk": 64})
    with chaos.chaos(chaos.FaultPlan([chaos.Fault("plan_save_crash")])):
        with pytest.raises(chaos.ChaosError):
            st.save(path)
    # the crash hit between temp-write and rename: the original file is
    # byte-for-byte valid JSON with the OLD contents, and no temp litter
    blob = json.loads(open(path).read())
    assert list(blob["entries"]) == ["dense|64x64x64|ib4|ob4"]
    assert not [p for p in tmp_path.iterdir()
                if p.name.startswith(".plan_cache.")]
    # the next (un-faulted) save succeeds and lands both entries
    st.save(path)
    assert len(json.loads(open(path).read())["entries"]) == 2


@pytest.mark.parametrize("mode", ["truncate", "scramble"])
def test_corrupt_plan_cache_degrades_gracefully(tmp_path, mode):
    path = str(tmp_path / "plans.json")
    st = plan_store.PlanStore()
    st.put("dense|64x64x64|ib4|ob4", {"bm": 64, "bn": 64, "bk": 64})
    st.save(path)
    chaos.corrupt_json(path, seed=3, mode=mode)
    fresh = plan_store.PlanStore()
    n = fresh.load(path)         # never raises, whatever the damage
    assert n == 0 and fresh.entries == {}
    assert fresh.lookup("dense|64x64x64|ib4|ob4") is None


def test_corrupt_json_deterministic(tmp_path):
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    for p in (a, b):
        p.write_text(json.dumps({"k": list(range(64))}))
        chaos.corrupt_json(str(p), seed=11, mode="truncate")
    assert a.read_bytes() == b.read_bytes()


# -------------------------- serve-engine containment -----------------------

def _serve_bits():
    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.serve.engine import Request, ServeEngine
    cfg = get_config("qwen3-1.7b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.random.default_rng(0).integers(
        2, cfg.vocab_size, 6).astype(np.int32)
    return cfg, params, prompt, Request, ServeEngine


def test_serve_transient_retry_is_transparent():
    cfg, params, prompt, Request, ServeEngine = _serve_bits()
    ref = ServeEngine(cfg, params, batch_slots=2, max_len=32).run(
        [Request(rid=0, prompt=prompt, max_new_tokens=4)])[0].out_tokens
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    with chaos.chaos(chaos.FaultPlan(
            [chaos.Fault("transient_decode", at=1)])):
        out = eng.run([Request(rid=0, prompt=prompt,
                               max_new_tokens=4)])[0].out_tokens
    assert out == ref
    assert eng.faults["transient_retries"] == 1
    assert eng.health()["degraded_mode"]


def test_serve_nan_quarantine_reprefills():
    cfg, params, prompt, Request, ServeEngine = _serve_bits()
    ref = ServeEngine(cfg, params, batch_slots=2, max_len=32).run(
        [Request(rid=0, prompt=prompt, max_new_tokens=4)])[0].out_tokens
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    with chaos.chaos(chaos.FaultPlan(
            [chaos.Fault("nan_logits", at=1, slot=0)])):
        req = eng.run([Request(rid=0, prompt=prompt,
                               max_new_tokens=4)])[0]
    assert req.out_tokens == ref          # no garbage token emitted
    assert all(t >= 0 for t in req.out_tokens)
    assert eng.faults["nonfinite_quarantined"] == 1


def test_serve_deadline_expires_and_frees_slot():
    cfg, params, prompt, Request, ServeEngine = _serve_bits()
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=32)
    doomed = Request(rid=0, prompt=prompt, max_new_tokens=10_000,
                     deadline_s=0.0)
    ok = Request(rid=1, prompt=prompt, max_new_tokens=2)
    out = eng.run([doomed, ok])
    assert out[0].timed_out and out[0].done
    assert len(out[1].out_tokens) == 2 and not out[1].timed_out
    assert eng.faults["deadline_expired"] == 1


def test_serve_prefill_cache_lru_bounded():
    # paged=False pins the legacy exact-length rung: bucketed prefill
    # would fold all four lengths into one compiled bucket (no LRU churn).
    cfg, params, _, Request, ServeEngine = _serve_bits()
    rng = np.random.default_rng(1)
    eng = ServeEngine(cfg, params, batch_slots=1, max_len=48,
                      prefill_cache_size=2, paged=False)
    eng.run([Request(rid=i,
                     prompt=rng.integers(2, cfg.vocab_size,
                                         4 + i).astype(np.int32),
                     max_new_tokens=1) for i in range(4)])
    h = eng.health()
    assert h["prefill_cache_size"] <= 2
    assert h["faults"]["prefill_evictions"] == 2


# ------------------------ trainer failure semantics ------------------------

def test_trainer_no_final_checkpoint_on_failure(tmp_path):
    """A mid-run HostFailure must NOT leave a checkpoint labelled with the
    final step — the elastic restart would resume past steps that never
    ran."""
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.optim.adamw import OptConfig
    from repro.runtime.fault_tolerance import HostFailure
    from repro.train.trainer import Trainer
    cfg = get_config("qwen3-1.7b-smoke")
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    tr = Trainer(cfg, shape, OptConfig(lr=1e-3, total_steps=8),
                 ckpt_dir=str(tmp_path), ckpt_every=2, log_every=100)
    with chaos.chaos(chaos.FaultPlan(
            [chaos.Fault("shard_loss", at=5, chips=2)])):
        with pytest.raises(HostFailure):
            tr.run(8)
    tr.ckpt.wait()                 # join the async periodic writer
    latest = tr.ckpt.latest_step()
    assert latest == 4             # periodic saves only, never step 7


# ----------------------- multi-device (subprocess) legs --------------------

@pytest.mark.slow
def test_ep_ladder_multidevice():
    """Every EP rung (ring->gather, gather->single, and the full ladder)
    under injected collective faults on an 8-shard mesh: numerically equal
    to the healthy run, degraded counter exactly once per fault."""
    run_with_devices("""
import numpy as np
import jax.numpy as jnp
from repro.core.gemm import ep_ragged_matmul, ep_ragged_moe, plan_mode_stats
from repro.launch.mesh import make_mesh
from repro.runtime import chaos

mesh = make_mesh((8,), ("data",))
rs = np.random.RandomState(0)
x = jnp.asarray(rs.randn(64, 16), jnp.float32)
w = jnp.asarray(rs.randn(8, 16, 24), jnp.float32)
offs = jnp.asarray(np.linspace(0, 64, 9, dtype=np.int32))
ref = np.concatenate([np.asarray(x)[offs[g]:offs[g+1]] @ np.asarray(w)[g]
                      for g in range(8)])

def deg():
    return dict(plan_mode_stats().get("degraded", {}))

with chaos.chaos(chaos.FaultPlan([chaos.Fault("ep_ring", at=0)])):
    y = ep_ragged_matmul(x, w, offs, mesh=mesh, schedule="ring")
np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)
assert deg()["ep:ring->gather"] == 1, deg()

with chaos.chaos(chaos.FaultPlan([chaos.Fault("ep_gather", at=0)])):
    y = ep_ragged_matmul(x, w, offs, mesh=mesh, schedule="gather")
np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)
assert deg()["ep:gather->single"] == 1, deg()

with chaos.chaos(chaos.FaultPlan([chaos.Fault("ep_ring", at=0),
                                  chaos.Fault("ep_gather", at=0)])):
    y = ep_ragged_matmul(x, w, offs, mesh=mesh, schedule="ring")
np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-4)
assert deg() == {"ep:ring->gather": 2, "ep:gather->single": 2}, deg()

wg = jnp.asarray(rs.randn(8, 16, 24), jnp.float32)
wu = jnp.asarray(rs.randn(8, 16, 24), jnp.float32)
wd = jnp.asarray(rs.randn(8, 24, 16), jnp.float32)
healthy = ep_ragged_moe(x, wg, wu, wd, offs, mesh=mesh, schedule="gather")
with chaos.chaos(chaos.FaultPlan([chaos.Fault("ep_gather", at=0)])):
    m = ep_ragged_moe(x, wg, wu, wd, offs, mesh=mesh, schedule="gather")
np.testing.assert_allclose(np.asarray(m), np.asarray(healthy),
                           rtol=1e-4, atol=1e-4)
assert deg()["ep:gather->single"] == 3, deg()
print("OK")
""", n_devices=8, timeout=560)


@pytest.mark.slow
def test_elastic_replan_recovery_deterministic():
    """The acceptance-criterion test: an injected single-shard loss mid-run
    re-meshes via ElasticPlan, invalidates the executor caches (re-planning
    every placed GEMM on the new mesh — visible as fresh plan servings in
    plan_mode_stats), restores the checkpoint onto the shrunken mesh, and
    replays data deterministically: the post-recovery loss trajectory
    matches the same seed run WITHOUT the fault, and two identical faulted
    runs are bitwise identical."""
    run_with_devices("""
import tempfile
import numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.gemm.tuner import PLAN_MODE_COUNTS, clear_plan_cache
from repro.optim.adamw import OptConfig
from repro.runtime import chaos
from repro.runtime.elastic import ElasticRunner

cfg = get_config("qwen3-1.7b-smoke")
shape = ShapeConfig("elastic", seq_len=32, global_batch=8, kind="train")
opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=12)

def run(fault):
    clear_plan_cache()
    r = ElasticRunner(cfg, shape, opt, ckpt_dir=tempfile.mkdtemp(),
                      model_parallel=1, seed=0, ckpt_every=4, log_every=1)
    plan = (chaos.FaultPlan([chaos.Fault("shard_loss", at=6, chips=2)])
            if fault else chaos.FaultPlan())
    with chaos.chaos(plan):
        r.run(12)
    return r, sum(PLAN_MODE_COUNTS.values())

clean, plans_clean = run(False)
faulted, plans_faulted = run(True)

assert len(clean.history) == 1
assert [h.get("failure") for h in faulted.history] == \
    [None, "HostFailure", None]
assert faulted.history[0]["mesh"] == (8, 1)
assert faulted.history[2]["mesh"] == (4, 1)        # 6 survivors -> dp 4
assert faulted.history[2]["start"] == 5            # ckpt_every=4 -> step 4
# the shrink re-planned the placed GEMMs: a second trace's worth of plan
# servings on top of the clean run's single trace
assert plans_faulted > plans_clean, (plans_faulted, plans_clean)

ref = {m["step"]: m["loss"] for m in clean.metrics_log}
got = {m["step"]: m["loss"] for m in faulted.metrics_log}
post = sorted(s for s in got if s >= 6)
assert post == list(range(6, 12))
for s in post:   # identical trajectory modulo mesh-shape reduction order
    assert abs(ref[s] - got[s]) < 5e-3, (s, ref[s], got[s])

faulted2, _ = run(True)
got2 = {m["step"]: m["loss"] for m in faulted2.metrics_log}
assert got == got2      # replay is exactly deterministic
print("OK")
""", n_devices=8, timeout=560)


@pytest.mark.slow
def test_chaos_ep_train_step_and_serve_smoke():
    """The CI chaos leg: a seeded FaultPlan driven through the 8-device EP
    train step (collective fault -> single-device rung inside the jitted
    step) and a serve loop (transient + NaN faults) — everything degrades,
    nothing crashes, telemetry records each fault."""
    run_with_devices("""
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.dist import DistContext, use_dist
from repro.core.gemm import plan_mode_stats
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.sharding import batch_specs, expert_axis, param_specs, to_shardings
from repro.models.model import init_params
from repro.optim.adamw import OptConfig, init_opt_state
from repro.runtime import chaos
from repro.serve.engine import Request, ServeEngine
from repro.train.train_step import make_train_step

cfg = get_config("llama4-scout-17b-a16e-smoke")
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
mesh = make_mesh((2, 4), ("data", "model"))
ctx = DistContext(mesh=mesh, dp_axes=("data",), model_axis="model",
                  moe_ep_axis=expert_axis(mesh, True, "dp"))
plan = chaos.FaultPlan([chaos.Fault("ep_ring", at=0),
                        chaos.Fault("ep_gather", at=0),
                        chaos.Fault("transient_decode", at=1),
                        chaos.Fault("nan_logits", at=2, slot=0)], seed=0)
with chaos.chaos(plan), use_dist(ctx), mesh:
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    ps = to_shardings(param_specs(params, mesh, moe_ep=True), mesh)
    os_ = to_shardings(param_specs(opt, mesh, zero_stage=3, moe_ep=True), mesh)
    ds = SyntheticLM(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.host_batch(0).items()}
    bs = to_shardings(batch_specs(cfg, batch, mesh), mesh)
    step = jax.jit(make_train_step(cfg, OptConfig()),
                   in_shardings=(ps, os_, bs), donate_argnums=(0, 1))
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    deg = plan_mode_stats().get("degraded", {})
    assert deg.get("ep:gather->single", 0) >= 1, deg

    eng = ServeEngine(cfg, params, batch_slots=2, max_len=48)
    rng = np.random.default_rng(0)
    reqs = eng.run([Request(rid=i,
                            prompt=rng.integers(2, cfg.vocab_size, 8).astype(np.int32),
                            max_new_tokens=4) for i in range(2)])
    assert all(len(r.out_tokens) == 4 for r in reqs)
    h = eng.health()
    assert h["faults"]["transient_retries"] == 1, h
    assert h["faults"]["nonfinite_quarantined"] == 1, h
    assert h["degraded_mode"]
print("OK")
""", n_devices=8, timeout=560)
