"""Fused-epilogue generator + zero-copy edge tiles: conformance suite.

Covers the PR-5 acceptance bar: the fused-epilogue matmul matches the
unfused reference to fp32-accumulation tolerance (fwd and VJP) on every
trans / dim-order / split-K / batched variant, including non-block-multiple
shapes with the padded path fully bypassed (edge="masked"), on both the
pallas_interpret and XLA engines; plus the planner/candidate-space and
telemetry extensions and the bk-clamp bugfix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.gemm import (Epilogue, clear_plan_cache, epilogue_stats,
                             grouped_swiglu, matmul, matmul_swiglu,
                             plan_gemm, plan_mode_stats)
from repro.core.gemm import autotune, tuner
from repro.kernels.ftimm import ops, ref

KEY = jax.random.PRNGKey(11)


def _mk(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(KEY, seed), shape, dtype)


def _operands(trans, m, k, n, seed=0, dtype=jnp.float32):
    shapes = {"nn": ((m, k), (k, n)), "tn": ((k, m), (k, n)),
              "nt": ((m, k), (n, k))}[trans]
    return _mk(shapes[0], seed), _mk(shapes[1], seed + 1, dtype)


def _ref(trans):
    return {"nn": ref.matmul_nn, "tn": ref.matmul_tn,
            "nt": ref.matmul_nt}[trans]


FULL_EPI = Epilogue(bias=True, activation="silu", residual=True, scale=0.5)


def _apply_ref(epi, z, bias=None, residual=None):
    return epi.apply(z, bias=bias, residual=residual)


# ---------------------------------------------------------------------------
# Zero-copy edge tiles: masked == padded == reference on unaligned shapes.
# ---------------------------------------------------------------------------

EDGE_SHAPES = [(33, 257, 65), (100, 60, 96), (8, 128, 8), (129, 130, 131)]


@pytest.mark.parametrize("m,k,n", EDGE_SHAPES)
@pytest.mark.parametrize("trans", ["nn", "tn", "nt"])
@pytest.mark.parametrize("dim_order", ["mn", "nm"])
def test_masked_edge_matches_reference(m, k, n, trans, dim_order):
    a, b = _operands(trans, m, k, n, seed=m + k)
    want = _ref(trans)(a, b)
    out = ops.gemm(a, b, trans=trans, dim_order=dim_order, edge="masked",
                   interpret=True)
    assert out.shape == want.shape
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)
    padded = ops.gemm(a, b, trans=trans, dim_order=dim_order, edge="padded",
                      interpret=True)
    np.testing.assert_allclose(out, padded, rtol=1e-6, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 80), k=st.integers(1, 150), n=st.integers(1, 80))
def test_masked_edge_property(m, k, n):
    """Random non-block-multiple shapes through the zero-copy path."""
    a, b = _operands("nn", m, k, n, seed=m * 131 + k * 7 + n)
    out = ops.gemm(a, b, edge="masked", interpret=True)
    np.testing.assert_allclose(out, ref.matmul_nn(a, b),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("nsplit", [2, 4])
def test_masked_splitk_unaligned(nsplit):
    """Split-K with K not a multiple of nsplit*bk: out-of-range K blocks
    mask to zero contributions."""
    a, b = _operands("nn", 16, 1000, 96, seed=3)
    out = ops.gemm(a, b, nsplit=nsplit, edge="masked", interpret=True)
    np.testing.assert_allclose(out, ref.matmul_nn(a, b),
                               rtol=2e-4, atol=2e-4)


def test_bk_clamped_to_problem_extent():
    """Regression (satellite bugfix): a K=64 problem under a bk=512 plan
    must clamp bk instead of padding K 8x — and a split-K plan whose clamped
    bk covers all of K degenerates to one split."""
    a, b = _operands("nn", 128, 64, 32, seed=5)
    want = ref.matmul_nn(a, b)
    for edge in ("masked", "padded"):
        out = ops.gemm(a, b, bk=512, edge=edge, interpret=True)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)
    out = ops.gemm(a, b, bk=512, nsplit=4, interpret=True)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)
    # batched wrapper clamps the same way
    a3, b3 = _mk((3, 64, 64), 6), _mk((3, 64, 32), 7)
    out = ops.batched_gemm(a3, b3, bk=512, interpret=True)
    np.testing.assert_allclose(out, jnp.einsum("gmk,gkn->gmn", a3, b3),
                               rtol=2e-4, atol=2e-4)


def test_batched_masked_edge_matches_reference():
    a3, b3 = _mk((3, 33, 100), 8), _mk((3, 100, 65), 9)
    want = jnp.einsum("gmk,gkn->gmn", a3, b3)
    for edge in ("masked", "padded"):
        out = ops.batched_gemm(a3, b3, edge=edge, interpret=True)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)
    # shared-operand (grouped) case
    a2 = _mk((33, 100), 10)
    want = jnp.einsum("mk,gkn->gmn", a2, b3)
    out = ops.batched_gemm(a2, b3, edge="masked", interpret=True)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Fused epilogue: fwd + VJP vs the unfused reference, both engines.
# ---------------------------------------------------------------------------

EPI_CASES = [
    Epilogue(bias=True),
    Epilogue(activation="silu"),
    Epilogue(activation="gelu"),
    Epilogue(residual=True),
    Epilogue(scale=0.25),
    FULL_EPI,
]


@pytest.mark.parametrize("epi", EPI_CASES, ids=lambda e: repr(e))
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_epilogue_fwd_matches_reference(epi, backend):
    m, k, n = 33, 70, 65          # unaligned: masked path exercised
    a, b = _operands("nn", m, k, n, seed=20)
    bias = _mk((n,), 21) if epi.bias else None
    res = _mk((m, n), 22) if epi.residual else None
    out = matmul(a, b, epilogue=epi, bias=bias, residual=res,
                 backend=backend)
    want = _apply_ref(epi, ref.matmul_nn(a, b, jnp.float32), bias, res)
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("trans", ["nn", "tn", "nt"])
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_epilogue_vjp_matches_autodiff(trans, backend):
    """Gradients of the fused path (remat + planned backward GEMMs) match
    plain autodiff of the reference composition — incl. bias/residual
    cotangents — on an unaligned shape."""
    m, k, n = 24, 50, 40
    a, b = _operands(trans, m, k, n, seed=30)
    bias, res = _mk((n,), 31), _mk((m, n), 32)
    epi = FULL_EPI

    def fused(a, b, bias, res):
        y = matmul(a, b, trans=trans, epilogue=epi, bias=bias, residual=res,
                   backend=backend)
        return jnp.sum(jnp.tanh(y))

    def reference(a, b, bias, res):
        y = _apply_ref(epi, _ref(trans)(a, b, jnp.float32), bias, res)
        return jnp.sum(jnp.tanh(y))

    g1 = jax.grad(fused, argnums=(0, 1, 2, 3))(a, b, bias, res)
    g2 = jax.grad(reference, argnums=(0, 1, 2, 3))(a, b, bias, res)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=3e-4, atol=3e-4)


@settings(max_examples=6, deadline=None)
@given(m=st.integers(2, 48), k=st.integers(2, 64), n=st.integers(2, 48))
def test_epilogue_property_fwd_and_grad(m, k, n):
    """Random unaligned shapes: fused silu epilogue fwd + dA grad vs
    reference, pallas_interpret engine (the padded path fully bypassed)."""
    a, b = _operands("nn", m, k, n, seed=m * 7 + k * 3 + n)
    epi = Epilogue(activation="silu")

    def fused(a):
        return jnp.sum(matmul(a, b, epilogue=epi,
                              backend="pallas_interpret") ** 2)

    def reference(a):
        return jnp.sum(jax.nn.silu(ref.matmul_nn(a, b, jnp.float32)) ** 2)

    np.testing.assert_allclose(
        matmul(a, b, epilogue=epi, backend="pallas_interpret"),
        jax.nn.silu(ref.matmul_nn(a, b, jnp.float32)),
        rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(jax.grad(fused)(a), jax.grad(reference)(a),
                               rtol=2e-3, atol=2e-3)


def test_epilogue_splitk_plan_path():
    """A split-K plan (nsplit > 1) applies the epilogue after the partials
    reduction — same math as the fused flush."""
    a, b = _operands("nn", 16, 1000, 96, seed=40)
    bias = _mk((96,), 41)
    epi = Epilogue(bias=True, activation="gelu")
    out = ops.gemm(a, b, nsplit=4, epilogue=epi, bias=bias, interpret=True)
    want = _apply_ref(epi, ref.matmul_nn(a, b, jnp.float32), bias)
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)


def test_epilogue_operand_mismatch_raises():
    a, b = _operands("nn", 16, 32, 32, seed=42)
    with pytest.raises(ValueError):
        matmul(a, b, epilogue=Epilogue(bias=True))           # bias missing
    with pytest.raises(ValueError):
        matmul(a, b, residual=_mk((16, 32), 43))             # spec missing
    with pytest.raises(ValueError):
        Epilogue(activation="relu")                          # unknown act


# ---------------------------------------------------------------------------
# Fused SwiGLU pairs (dense + grouped).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_matmul_swiglu_fwd_and_vjp(backend):
    x, wg, wu = _mk((33, 100), 50), _mk((100, 65), 51), _mk((100, 65), 52)

    def sw_ref(x, wg, wu):
        return jax.nn.silu(x @ wg) * (x @ wu)

    out = matmul_swiglu(x, wg, wu, backend=backend)
    np.testing.assert_allclose(out, sw_ref(x, wg, wu), rtol=3e-4, atol=3e-4)
    g1 = jax.grad(lambda *p: jnp.sum(jnp.tanh(matmul_swiglu(
        *p, backend=backend))), argnums=(0, 1, 2))(x, wg, wu)
    g2 = jax.grad(lambda *p: jnp.sum(jnp.tanh(sw_ref(*p))),
                  argnums=(0, 1, 2))(x, wg, wu)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_grouped_swiglu_fwd_and_vjp(backend):
    x = _mk((3, 33, 100), 60)
    wg, wu = _mk((3, 100, 65), 61), _mk((3, 100, 65), 62)

    def sw_ref(x, wg, wu):
        return (jax.nn.silu(jnp.einsum("gmk,gkn->gmn", x, wg))
                * jnp.einsum("gmk,gkn->gmn", x, wu))

    out = grouped_swiglu(x, wg, wu, backend=backend)
    np.testing.assert_allclose(out, sw_ref(x, wg, wu), rtol=3e-4, atol=3e-4)
    g1 = jax.grad(lambda *p: jnp.sum(jnp.tanh(grouped_swiglu(
        *p, backend=backend))), argnums=(0, 1, 2))(x, wg, wu)
    g2 = jax.grad(lambda *p: jnp.sum(jnp.tanh(sw_ref(*p))),
                  argnums=(0, 1, 2))(x, wg, wu)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Planner: candidate space, cached round-trip, telemetry.
# ---------------------------------------------------------------------------

def test_candidate_space_edges_and_fusion():
    # Unaligned shape WITH an epilogue: all four (edge, fuse) corners exist.
    cands = tuner.gemm_candidates(100, 60, 96, epi_ops=2)
    assert {(c.edge, c.fuse) for c in cands} == {
        ("masked", True), ("masked", False),
        ("padded", True), ("padded", False)}
    # Aligned shape, no epilogue: nothing to fork on.
    aligned = tuner.gemm_candidates(256, 256, 256)
    assert {(c.edge, c.fuse) for c in aligned} == {("masked", True)}
    # The analytic winner never pays for pad copies or separate passes.
    best = tuner.argmin_plan(cands)
    assert best.edge == "masked" and best.fuse


def test_epilogue_pricing_monotone():
    from repro.core.gemm import estimate
    kw = dict(m=1000, k=60, n=96, bm=128, bn=128, bk=128)
    base = estimate(**kw)
    padded = estimate(**kw, edge="padded")
    unfused = estimate(**kw, epi_ops=2, epi_fused=False)
    fused = estimate(**kw, epi_ops=2, epi_fused=True)
    assert padded.hbm_bytes > base.hbm_bytes
    assert unfused.hbm_bytes > fused.hbm_bytes == base.hbm_bytes


def test_measured_plan_round_trips_edge_and_fuse():
    """autotune persists edge/fuse; the cached plan serves them back."""
    clear_plan_cache()
    try:
        res = autotune.autotune_gemm(
            200, 60, 96, top_k=3, repeats=1, engine="xla",
            max_elements=1 << 14, epilogue=Epilogue(activation="silu"))
        served = plan_gemm(200, 60, 96)
        assert served.mode == "cached"
        assert served.edge == res.plan.edge
        assert served.fuse == res.plan.fuse
    finally:
        clear_plan_cache()


def test_fusion_telemetry():
    clear_plan_cache()
    try:
        a, b = _operands("nn", 32, 64, 32, seed=70)
        matmul(a, b, backend="xla")                      # identity: no count
        assert epilogue_stats() == {}
        matmul(a, b, epilogue=Epilogue(activation="silu"), backend="xla")
        stats = epilogue_stats()
        assert stats["dense"]["fused"] == 1
        assert "epilogue" in plan_mode_stats()
        x = _mk((2, 16, 32), 71)
        w = _mk((2, 32, 32), 72)
        grouped_swiglu(x, w, w, backend="xla")
        assert epilogue_stats()["batched"]["fused"] == 1
        clear_plan_cache()
        assert epilogue_stats() == {}
    finally:
        clear_plan_cache()


def test_decompose_reproduces_apply():
    """The unfused path's per-op decomposition composes back to exactly the
    fused ``apply`` (what both the CPU benchmark and an unfused measured
    plan execute)."""
    z = _mk((17, 23), 80, jnp.float32)
    bias, res = _mk((23,), 81), _mk((17, 23), 82)
    want = FULL_EPI.apply(z, bias=bias, residual=res)
    out = z
    for op in FULL_EPI.decompose():
        out = op.apply(out, bias=bias, residual=res)
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)
    assert len(FULL_EPI.decompose()) == FULL_EPI.num_ops == 4
    assert Epilogue().decompose() == () and Epilogue().num_ops == 0


# ---------------------------------------------------------------------------
# Distributed: epilogue through dist_matmul on both strategies.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["m_parallel", "k_parallel"])
def test_dist_matmul_epilogue(strategy):
    from jax.sharding import Mesh
    from repro.core.gemm import dist_matmul

    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
    m, k, n = 33, 70, 65
    a, b = _operands("nn", m, k, n, seed=90)
    bias, res = _mk((n,), 91), _mk((m, n), 92)
    epi = FULL_EPI
    out = dist_matmul(a, b, mesh=mesh, axis="model", strategy=strategy,
                      epilogue=epi, bias=bias, residual=res, backend="xla")
    want = _apply_ref(epi, ref.matmul_nn(a, b, jnp.float32), bias, res)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Model layers: the fused tails match the unfused composition.
# ---------------------------------------------------------------------------

def test_layers_dense_fused_residual():
    from repro.models.layers import dense
    x = _mk((2, 9, 48), 100)
    w = _mk((48, 48), 101)
    h = _mk((2, 9, 48), 102)
    out = dense(x, w, jnp.float32, residual=h)
    want = ref.matmul_nn(x.reshape(18, 48), w,
                         jnp.float32).reshape(2, 9, 48) + h
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)


def test_layers_swiglu_fused_matches_unfused():
    from repro.models.layers import swiglu
    x = _mk((2, 9, 48), 110)
    wg, wu = _mk((48, 64), 111), _mk((48, 64), 112)
    wd = _mk((64, 48), 113)
    h = _mk((2, 9, 48), 114)
    out = swiglu(x, wg, wu, wd, jnp.float32, residual=h)
    xf = x.reshape(18, 48)
    want = (jax.nn.silu(xf @ wg) * (xf @ wu)) @ wd
    want = want.reshape(2, 9, 48) + h
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)
