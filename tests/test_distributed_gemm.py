"""Cross-chip ftIMM strategies (paper Alg. 4/5) and the expert-parallel
ragged executors on a fake 8-device mesh (subprocess: multi-host simulated
via --xla_force_host_platform_device_count)."""
import pytest
from helpers import run_with_devices


@pytest.mark.slow
def test_dist_matmul_strategies():
    run_with_devices("""
import jax
import jax.numpy as jnp
import numpy as np
from repro.core.compat import make_mesh
from repro.core.gemm import dist_matmul, choose_strategy
mesh = make_mesh((8,), ("model",))
key = jax.random.PRNGKey(0)

# T1: tall-and-skinny -> M-parallel, uneven M exercises the pad path
a = jax.random.normal(key, (1003, 64)); b = jax.random.normal(jax.random.fold_in(key,1), (64, 32))
assert choose_strategy(1003, 64, 32, 8) == "m_parallel"
np.testing.assert_allclose(dist_matmul(a, b, mesh=mesh), a @ b, rtol=1e-4, atol=1e-4)

# T2: skinny-and-tall -> K-parallel with psum reduction
a = jax.random.normal(key, (32, 8192)); b = jax.random.normal(jax.random.fold_in(key,2), (8192, 32))
assert choose_strategy(32, 8192, 32, 8) == "k_parallel"
np.testing.assert_allclose(dist_matmul(a, b, mesh=mesh), a @ b, rtol=1e-3, atol=1e-3)

# forced strategies both correct on a regular shape
a = jax.random.normal(key, (256, 256)); b = jax.random.normal(jax.random.fold_in(key,3), (256, 64))
for s in ("m_parallel", "k_parallel"):
    np.testing.assert_allclose(dist_matmul(a, b, mesh=mesh, strategy=s), a @ b, rtol=1e-3, atol=1e-3)
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_dist_matmul_shape_mismatch_raises():
    run_with_devices("""
import jax
import pytest
from repro.core.compat import make_mesh
from repro.core.gemm import dist_matmul
mesh = make_mesh((8,), ("model",))
a = jax.numpy.zeros((16, 32)); b = jax.numpy.zeros((48, 8))
try:
    dist_matmul(a, b, mesh=mesh)
except ValueError as e:
    assert "(16, 32)" in str(e) and "(48, 8)" in str(e), e
else:
    raise AssertionError("mismatched K must raise ValueError")
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_ep_ragged_matmul_parity_fwd_and_vjp():
    """EP-sharded ragged GEMM vs the single-device oracle on the property
    suite's degenerate distributions: empty groups, one giant group,
    singletons, unaligned totals — forward and VJP.  The token exchange is
    exact (bitwise row round-trip); the per-shard ragged_dot engine
    schedules its contraction per group count, so values agree to ~ulp of
    the output scale (asserted at 1e-5 x max|oracle|)."""
    run_with_devices("""
import numpy as np
import jax
import jax.numpy as jnp
from repro.core.compat import make_mesh
from repro.core.gemm import ep_ragged_matmul, ep_ragged_swiglu, \
    ragged_matmul, ragged_swiglu

mesh = make_mesh((8,), ("expert",))
key = jax.random.PRNGKey(7)
D, F = 16, 24

def close(a, b, tol=1e-5):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    s = max(1.0, float(np.abs(b).max()))
    np.testing.assert_allclose(a, b, rtol=tol, atol=tol * s)

DISTS = [
    [5, 0, 17, 3, 2, 2, 1, 9],       # interior empties, unaligned total
    [0, 0, 40, 0, 0, 0, 0, 1],       # leading empties + one giant group
    [1] * 8,                         # all singletons
    [0, 33, 0, 0, 8, 16, 24, 32],    # trailing/leading empties + aligned
    [3, 1, 4, 1, 5, 9, 2, 6] * 2,    # 16 groups: 2 per shard
]
for seed, sizes in enumerate(DISTS):
    t = sum(sizes)
    offs = jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)
    k1, k2, k3 = jax.random.split(jax.random.fold_in(key, seed), 3)
    x = jax.random.normal(k1, (t, D), jnp.float32)
    wg = jax.random.normal(k2, (len(sizes), D, F), jnp.float32)
    wu = jax.random.normal(k3, (len(sizes), D, F), jnp.float32)

    close(ep_ragged_matmul(x, wg, offs, mesh=mesh, axis="expert"),
          ragged_matmul(x, wg, offs))
    close(ep_ragged_swiglu(x, wg, wu, offs, mesh=mesh, axis="expert"),
          ragged_swiglu(x, wg, wu, offs))

    ge = jax.grad(lambda x, w: jnp.sum(ep_ragged_matmul(
        x, w, offs, mesh=mesh, axis="expert") ** 2), argnums=(0, 1))(x, wg)
    g1 = jax.grad(lambda x, w: jnp.sum(
        ragged_matmul(x, w, offs) ** 2), argnums=(0, 1))(x, wg)
    close(ge[0], g1[0]); close(ge[1], g1[1])

    gse = jax.grad(lambda x, a, b: jnp.sum(ep_ragged_swiglu(
        x, a, b, offs, mesh=mesh, axis="expert") ** 2),
        argnums=(0, 1, 2))(x, wg, wu)
    gs1 = jax.grad(lambda x, a, b: jnp.sum(
        ragged_swiglu(x, a, b, offs) ** 2), argnums=(0, 1, 2))(x, wg, wu)
    for a, b in zip(gse, gs1):
        close(a, b)
print("OK")
""", n_devices=8)
