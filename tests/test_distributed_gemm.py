"""Cross-chip ftIMM strategies (paper Alg. 4/5) on a fake 8-device mesh."""
import pytest
from helpers import run_with_devices


@pytest.mark.slow
def test_dist_matmul_strategies():
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.compat import make_mesh
from repro.core.gemm import dist_matmul, choose_strategy
mesh = make_mesh((8,), ("model",))
key = jax.random.PRNGKey(0)

# T1: tall-and-skinny -> M-parallel, uneven M exercises the pad path
a = jax.random.normal(key, (1003, 64)); b = jax.random.normal(jax.random.fold_in(key,1), (64, 32))
assert choose_strategy(1003, 64, 32, 8) == "m_parallel"
np.testing.assert_allclose(dist_matmul(a, b, mesh=mesh), a @ b, rtol=1e-4, atol=1e-4)

# T2: skinny-and-tall -> K-parallel with psum reduction
a = jax.random.normal(key, (32, 8192)); b = jax.random.normal(jax.random.fold_in(key,2), (8192, 32))
assert choose_strategy(32, 8192, 32, 8) == "k_parallel"
np.testing.assert_allclose(dist_matmul(a, b, mesh=mesh), a @ b, rtol=1e-3, atol=1e-3)

# forced strategies both correct on a regular shape
a = jax.random.normal(key, (256, 256)); b = jax.random.normal(jax.random.fold_in(key,3), (256, 64))
for s in ("m_parallel", "k_parallel"):
    np.testing.assert_allclose(dist_matmul(a, b, mesh=mesh, strategy=s), a @ b, rtol=1e-3, atol=1e-3)
print("OK")
""", n_devices=8)
