"""Analytic perf model vs fully-unrolled compiled cost_analysis.

Train-step FLOPs must agree well (matmul-dominated); decode/prefill have a
documented wider band (XLA counts elementwise/padding work the analytic
model treats coarsely — see perf_model docstring).  Sizes are mid-scale to
keep compiles < 1 min on one CPU core.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import compat
from repro.configs.base import ShapeConfig
from repro.models import model as M
from repro.optim.adamw import OptConfig, init_opt_state
from repro.roofline.perf_model import step_perf
from repro.train.train_step import make_train_step


def _medium(name):
    cfg0 = get_config(name + "-smoke")
    return dataclasses.replace(
        cfg0, d_model=512, num_heads=8 if cfg0.num_heads else 0,
        num_kv_heads=4 if cfg0.num_kv_heads else 0,
        head_dim=64 if cfg0.num_heads else 0,
        d_ff=2048 if cfg0.d_ff else 0, vocab_size=32768, scan_unroll=True,
        remat="none", num_layers=2, attn_every=0, ssm_chunk=64,
        encoder_layers=2 if cfg0.encoder_layers else 0,
        encoder_seq=128 if cfg0.encoder_seq else 0,
        num_patches=32 if cfg0.num_patches else 0)


def _train_flops(cfg, shape):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params = jax.eval_shape(lambda k: M.init_params(cfg, k), key)
    opt = jax.eval_shape(init_opt_state, params)
    sds = jax.ShapeDtypeStruct
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s), jnp.int32),
             "labels": sds((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_patches:
        batch["patch_embeds"] = sds((b, cfg.num_patches, cfg.d_model),
                                    jnp.bfloat16)
    c = jax.jit(make_train_step(cfg, OptConfig())).lower(
        params, opt, batch).compile()
    return compat.cost_analysis(c)["flops"]


@pytest.mark.parametrize("arch", ["qwen3-8b", "mixtral-8x7b"])
def test_train_flops_validates(arch):
    cfg = _medium(arch)
    shape = ShapeConfig("probe", seq_len=512, global_batch=2, kind="train")
    analytic = step_perf(cfg, shape).flops
    hlo = _train_flops(cfg, shape)
    assert 0.75 < analytic / hlo < 1.15, (analytic, hlo)


def test_breakdown_covers_everything():
    cfg = get_config("qwen3-8b")
    shape = ShapeConfig("t", 4096, 256, "train")
    p = step_perf(cfg, shape)
    assert abs(sum(v[0] for v in p.breakdown.values()) - p.flops) < 1e-3 * p.flops
    # MoE active-flops accounting: top-1 llama4 far below dense-16x
    m = get_config("llama4-scout-17b-a16e")
    pm = step_perf(m, shape)
    dense_equiv = 6 * m.param_count() * shape.tokens
    assert pm.flops < 0.5 * dense_equiv


def test_decode_memory_dominated_by_weights_and_cache():
    cfg = get_config("qwen3-8b")
    shape = ShapeConfig("d", 32768, 128, "decode")
    p = step_perf(cfg, shape)
    # weights read once + per-layer KV cache reads (attn_score bucket);
    # the kv_cache_write bucket is the one-token update (tiny)
    wk = p.breakdown["weights"][1] + p.breakdown["attn_score"][1]
    assert wk > 0.8 * p.bytes_hbm
    assert p.breakdown["kv_cache_write"][1] < 0.01 * p.bytes_hbm
