"""Expert-parallel ragged GEMM executors vs the single-device oracle.

In-process multi-device: runs on however many host devices the process
exposes (the CI quick leg forces 8 with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on a single device
everything here skips and the subprocess tests in test_distributed_gemm.py
cover the path instead).

Tolerances: the token EXCHANGE itself round-trips rows bitwise (checked via
identity panels), but the per-shard GEMM engine (``jax.lax.ragged_dot``)
schedules its contraction differently for different group counts, so
EP-vs-oracle values agree to a few ulp of the output scale, not bit-for-bit
— asserted at 1e-5 x max|oracle|.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

NDEV = jax.device_count()
pytestmark = pytest.mark.skipif(
    NDEV < 2, reason="needs a multi-device runtime (CI quick leg forces 8)")

from repro.core.compat import make_mesh                       # noqa: E402
from repro.core.dist import DistContext, use_dist             # noqa: E402
from repro.core.gemm import (batched_matmul, dist_batched_matmul,  # noqa: E402
                             ep_ragged_matmul, ep_ragged_moe,
                             ep_ragged_swiglu, ragged_matmul, ragged_swiglu)
from repro.models.moe import init_moe_params, moe_mlp         # noqa: E402

KEY = jax.random.PRNGKey(3)

# Degenerate-distribution zoo per the ragged conformance suite: empty
# groups, one giant group, singletons, unaligned totals.
SIZES = [5, 0, 17, 3, 11, 1, 0, 8, 2, 2, 9, 0, 4, 6, 1, 3]


def _mesh():
    return make_mesh((NDEV,), ("expert",))


def _offsets(sizes):
    return jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)


def _groups(n_dev):
    """A group count divisible by the device count, >= 2 groups/shard."""
    return 2 * n_dev


def _mk(d, f, dtype=jnp.float32, seed=0):
    g = _groups(NDEV)
    sizes = (SIZES * ((g + len(SIZES) - 1) // len(SIZES)))[:g]
    t = sum(sizes)
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, seed), 3)
    x = jax.random.normal(k1, (t, d), dtype)
    wg = jax.random.normal(k2, (g, d, f), dtype)
    wu = jax.random.normal(k3, (g, d, f), dtype)
    return x, wg, wu, _offsets(sizes), sizes


def _close(got, want, tol=1e-5):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    scale = max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * scale)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_ep_ragged_matmul_matches_oracle(dtype, tol):
    x, w, _, offs, _ = _mk(24, 40, dtype)
    got = ep_ragged_matmul(x, w, offs, mesh=_mesh(), axis="expert")
    _close(got, ragged_matmul(x, w, offs), tol)


def test_ep_exchange_roundtrips_rows_bitwise():
    """With identity panels the GEMM is exact, so any discrepancy would be
    the exchange's fault: gather -> window -> inverse exchange must restore
    every row bit-for-bit."""
    d = 32
    x, _, _, offs, _ = _mk(d, d)
    eye = jnp.broadcast_to(jnp.eye(d, dtype=jnp.float32),
                           (_groups(NDEV), d, d))
    got = ep_ragged_matmul(x, eye, offs, mesh=_mesh(), axis="expert")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_ep_ragged_matmul_vjp_matches_oracle():
    x, w, _, offs, _ = _mk(24, 40)
    mesh = _mesh()

    def loss_ep(x, w):
        return jnp.sum(
            ep_ragged_matmul(x, w, offs, mesh=mesh, axis="expert") ** 2)

    def loss_1d(x, w):
        return jnp.sum(ragged_matmul(x, w, offs) ** 2)

    ge = jax.grad(loss_ep, argnums=(0, 1))(x, w)
    g1 = jax.grad(loss_1d, argnums=(0, 1))(x, w)
    _close(ge[0], g1[0])
    _close(ge[1], g1[1])


def test_ep_ragged_swiglu_fwd_and_vjp_match_oracle():
    x, wg, wu, offs, _ = _mk(24, 40)
    mesh = _mesh()
    _close(ep_ragged_swiglu(x, wg, wu, offs, mesh=mesh, axis="expert"),
           ragged_swiglu(x, wg, wu, offs))

    def loss(f):
        return lambda x, a, b: jnp.sum(f(x, a, b) ** 2)

    ge = jax.grad(loss(lambda x, a, b: ep_ragged_swiglu(
        x, a, b, offs, mesh=mesh, axis="expert")), argnums=(0, 1, 2))(
            x, wg, wu)
    g1 = jax.grad(loss(lambda x, a, b: ragged_swiglu(x, a, b, offs)),
                  argnums=(0, 1, 2))(x, wg, wu)
    for a, b in zip(ge, g1):
        _close(a, b)


def test_ep_ragged_moe_fused_fwd_and_vjp_match_oracle():
    """The fused EP MoE pipeline (one d_model-wide exchange each way) vs the
    single-device swiglu + down composition, forward and backward."""
    x, wg, wu, offs, _ = _mk(24, 40)
    wd = jax.random.normal(jax.random.fold_in(KEY, 9),
                           (_groups(NDEV), 40, 24))
    mesh = _mesh()

    def ep(x, wg, wu, wd):
        return ep_ragged_moe(x, wg, wu, wd, offs, mesh=mesh, axis="expert")

    def oracle(x, wg, wu, wd):
        return ragged_matmul(ragged_swiglu(x, wg, wu, offs), wd, offs)

    _close(ep(x, wg, wu, wd), oracle(x, wg, wu, wd))
    ge = jax.grad(lambda *a: jnp.sum(ep(*a) ** 2),
                  argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    g1 = jax.grad(lambda *a: jnp.sum(oracle(*a) ** 2),
                  argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    for a, b in zip(ge, g1):
        _close(a, b)


def test_ep_ragged_pallas_interpret_backend():
    """The per-shard engine can be the Pallas ragged kernel too (interpret
    mode off-TPU) — exercises shard_map_unchecked around pallas_call."""
    x, w, _, offs, _ = _mk(24, 40)
    got = ep_ragged_matmul(x, w, offs, mesh=_mesh(), axis="expert",
                           backend="pallas_interpret")
    _close(got, ragged_matmul(x, w, offs))


def test_ep_ragged_under_jit_with_row_padding():
    """T not divisible by the axis: the public wrapper pads/unpads, under
    jit."""
    x, w, _, offs, sizes = _mk(16, 24)
    drop = 1 if sizes[-1] > 0 else 0
    sizes2 = list(sizes)
    sizes2[-1] -= drop
    x2, offs2 = x[:sum(sizes2)], _offsets(sizes2)
    mesh = _mesh()
    got = jax.jit(lambda x, w, o: ep_ragged_matmul(
        x, w, o, mesh=mesh, axis="expert"))(x2, w, offs2)
    _close(got, ragged_matmul(x2, w, offs2))


def test_ep_ragged_rejects_indivisible_experts():
    x, w, _, offs, _ = _mk(16, 24)
    with pytest.raises(ValueError):
        ep_ragged_matmul(x, w[:_groups(NDEV) - 1], offs[:-1], mesh=_mesh(),
                         axis="expert")


def test_dist_batched_matmul_matches_local():
    """The batched-family executor: expert dim sharded, shared operands
    replicated, uneven batch counts padded."""
    mesh = _mesh()
    a = jax.random.normal(KEY, (NDEV, 16, 24))
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (NDEV, 24, 40))
    _close(dist_batched_matmul(a, b, mesh=mesh, axis="expert"),
           batched_matmul(a, b))
    # uneven g + shared 2-D weight
    a5 = jax.random.normal(KEY, (NDEV - 1, 16, 24))
    w2 = jax.random.normal(jax.random.fold_in(KEY, 2), (24, 40))
    _close(dist_batched_matmul(a5, w2, mesh=mesh, axis="expert"),
           batched_matmul(a5, w2))


def test_expert_axis_divisibility_rule():
    """The EP-eligibility decision lives in ONE place: expert_axis returns
    None when the expert count doesn't divide the axis, so the pricing side
    (dryrun's ep_shards) and the executing side (moe._ep_axis) can never
    disagree."""
    from repro.launch.sharding import expert_axis
    mesh = make_mesh((NDEV,), ("data",))
    assert expert_axis(mesh, True, "dp", 2 * NDEV) == "data"
    assert expert_axis(mesh, True, "dp", NDEV + 1) is None
    assert expert_axis(mesh, True, "dp") == "data"      # E unknown: allowed
    assert expert_axis(mesh, False, "dp", 2 * NDEV) is None
    assert expert_axis(mesh, True, "nope", 2 * NDEV) is None


def test_moe_ep_routing_matches_single_device():
    """moe_mlp's ragged dispatch must route through the EP executors when
    the DistContext exposes an expert axis — and agree with the
    single-device ragged path, forward and backward."""
    d, f, e = 32, 64, _groups(NDEV)
    mesh = make_mesh((NDEV,), ("data",))
    ctx = DistContext(mesh=mesh, dp_axes=("data",), model_axis="data",
                      moe_ep_axis="data")
    params = init_moe_params(jax.random.PRNGKey(0), d, f, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, d)) * 0.5

    def loss(p, x, ep):
        with (use_dist(ctx) if ep else use_dist(None)):
            y, aux = moe_mlp(x, p, num_experts=e, top_k=2,
                             compute_dtype=jnp.float32, dispatch="ragged")
        return jnp.sum(y ** 2) + 0.01 * aux

    assert float(loss(params, x, True)) == pytest.approx(
        float(loss(params, x, False)), rel=1e-6)
    g_ep = jax.grad(loss)(params, x, True)
    g_1d = jax.grad(loss)(params, x, False)
    for k in g_1d:
        _close(g_ep[k], g_1d[k])
