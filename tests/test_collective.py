"""Overlapped collective GEMM (PR 7): schedule axis, ragged exchange, ring.

Two halves:

  * single-device units — always run: the ``schedule`` axis through
    ``Placement``/``Plan.t_total``/plan-store records/static contracts, the
    bottleneck-shard ``estimate_ep`` pricing, the ICI calibration constant,
    and the planner's schedule preference (including the ``serial``
    timeshared-host evaluation the executors use on CPU meshes).
  * in-process multi-device — skip below 2 devices (the CI quick leg
    forces 8 via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``):
    bitwise exchange round-trips under skewed/empty/single-group
    distributions for BOTH schedules, ring-vs-gather numerical equality
    (the overlap property test), fwd+VJP parity vs the single-device
    oracle under the forced ring schedule, the all-rows-on-one-expert
    empty-shard regression, and the ring k_parallel ``dist_matmul``.
"""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import contracts
from repro.core.compat import make_mesh
from repro.core.gemm import (Calibration, Placement, dist_matmul,
                             ep_ragged_matmul, ep_ragged_moe, matmul,
                             plan_ragged_gemm, preferred_ep_schedule,
                             ragged_matmul)
from repro.core.gemm import collective, plan_store
from repro.core.gemm.cmr import TPU_V5E, estimate_ep
from repro.core.gemm.tuner import clear_planner_caches

NDEV = jax.device_count()
KEY = jax.random.PRNGKey(11)

multidev = pytest.mark.skipif(
    NDEV < 2, reason="needs a multi-device runtime (CI quick leg forces 8)")


# ---------------------------------------------------------------------------
# Single-device units
# ---------------------------------------------------------------------------

def test_estimate_ep_prices_bottleneck_shard():
    """With all rows on one expert the bandwidth-bound time is set by the
    max shard's bytes, not the mean: imbalance = max/mean = num_shards."""
    even = estimate_ep(1024, 64, 8)
    skew = estimate_ep(1024, 64, 8, max_shard_rows=1024)
    assert even.imbalance == 1.0
    assert skew.imbalance == pytest.approx(8.0)
    assert skew.ici_bytes == even.ici_bytes          # same global bytes
    assert skew.t_exchange == pytest.approx(8 * even.t_exchange)
    # __add__ sums bytes/time and keeps the worst imbalance
    both = even + skew
    assert both.imbalance == pytest.approx(8.0)
    assert both.t_exchange == pytest.approx(even.t_exchange
                                            + skew.t_exchange)


def test_plan_t_total_schedule_composition():
    """gather composes local+collective as SUM, ring as MAX."""
    plan = plan_ragged_gemm(8, 512, 64, 64)
    local = plan.est.t_total

    def mk(s):
        return replace(plan, placement=Placement(
            "expert_parallel", 8, t_collective=5 * local, schedule=s))

    assert mk("gather").t_total == pytest.approx(local + 5 * local)
    assert mk("ring").t_total == pytest.approx(5 * local)


def test_placement_schedule_contract():
    pl = Placement("expert_parallel", 4, schedule="ring")
    assert contracts.check_placement("ragged", (8, 512, 64, 64), pl) == []
    bad = Placement("m_parallel", 4, schedule="ring")
    codes = [v.code for v in
             contracts.check_placement("ragged", (8, 512, 64, 64), bad)]
    assert "ring_undefined" in codes
    unknown = Placement("k_parallel", 4, schedule="spiral")
    codes = [v.code for v in
             contracts.check_placement("dense", (512, 512, 512), unknown)]
    assert codes == ["bad_schedule"]


def test_record_schedule_contract_and_roundtrip():
    key = plan_store.shape_key("dense", (512, 1024, 512), 4, 4, num_shards=4)
    rec = {"bm": 128, "bn": 128, "bk": 128, "strategy": "k_parallel",
           "schedule": "ring"}
    assert contracts.errors(contracts.check_record(key, rec)) == []
    rec_bad = dict(rec, schedule="spiral")
    assert [v.code for v in contracts.check_record(key, rec_bad)] \
        == ["bad_schedule"]
    rec_illegal = dict(rec, strategy="m_parallel")
    assert "ring_undefined" in [v.code for v in
                                contracts.check_record(key, rec_illegal)]
    # the store keeps the schedule field through put()
    st = plan_store.PlanStore()
    st.put(key, rec)
    assert st.entries[key]["schedule"] == "ring"


def test_calibration_ici_frac_roundtrip_and_spec_scaling():
    cal = Calibration(flops_frac=0.5, bw_frac=0.25, ici_frac=0.125)
    assert Calibration.from_json(cal.to_json()) == cal
    # files written before the ici_frac field default it to 1.0
    legacy = {k: v for k, v in cal.to_json().items() if k != "ici_frac"}
    assert Calibration.from_json(legacy).ici_frac == 1.0
    spec = TPU_V5E.calibrated(cal.flops_frac, cal.bw_frac, cal.ici_frac)
    assert spec.ici_bw_per_link == pytest.approx(
        TPU_V5E.ici_bw_per_link * 0.125)


def test_preferred_ep_schedule_serial_evaluation():
    """num_shards<=1 is always gather; the timeshared-host evaluation
    (serial=nc) flips the MoE bench shape to ring, because the gather
    schedule's worst-case full-window compute serializes over the shards
    while ring computes only owned rows."""
    clear_planner_caches()
    assert preferred_ep_schedule(8, 1024, 128, 256, num_shards=1) == "gather"
    assert preferred_ep_schedule(8, 1024, 128, 256, 4, 4, num_shards=8,
                                 serial=8) == "ring"
    assert preferred_ep_schedule(8, 1024, 128, 256, 4, 4, num_shards=8) \
        in ("gather", "ring")      # per-chip answer is shape-dependent


def test_ragged_placement_offers_both_schedules():
    from repro.core.gemm.tuner import ragged_placement_options
    opts = ragged_placement_options(8, 1024, 128, 256, 8)
    scheds = {(o.placement.strategy, o.placement.schedule) for o in opts}
    assert ("expert_parallel", "ring") in scheds
    assert ("expert_parallel", "gather") in scheds


def test_exchange_method_env_override(monkeypatch):
    """REPRO_RAGGED_A2A=dense forces the dense fallback without a probe."""
    monkeypatch.setenv(collective.ENV_A2A, "dense")
    collective._method_cached.cache_clear()
    mesh = make_mesh((NDEV,), ("x",))
    assert collective.exchange_method(mesh, ("x",)) == "dense"
    collective._method_cached.cache_clear()


# ---------------------------------------------------------------------------
# Multi-device: exchange round-trips, schedules, regression
# ---------------------------------------------------------------------------

def _offsets(sizes):
    return jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)


def _distributions(g):
    """Skewed / empty-shard / single-group / balanced group-size zoos."""
    skew = [0] * g
    skew[0] = 37          # most rows on shard 0's first expert
    for i in range(1, g):
        skew[i] = i % 3
    one = [0] * g
    one[g // 2] = 29      # every row on ONE middle expert
    bal = [3] * g
    return {"skewed": skew, "one_expert": one, "balanced": bal}


def _close(got, want, tol=1e-5):
    got, want = np.asarray(got, np.float32), np.asarray(want, np.float32)
    scale = max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * scale)


@multidev
@pytest.mark.parametrize("schedule", ["gather", "ring"])
def test_exchange_roundtrips_bitwise_under_degenerate_distributions(schedule):
    """Identity panels make the GEMM exact, so the output equals the input
    iff every row survived dispatch+combine bit-for-bit — under skew,
    all-rows-on-one-expert (most shards own ZERO rows) and balance."""
    d, g = 16, 2 * NDEV
    mesh = make_mesh((NDEV,), ("expert",))
    eye = jnp.broadcast_to(jnp.eye(d, dtype=jnp.float32), (g, d, d))
    for name, sizes in _distributions(g).items():
        t = sum(sizes)
        x = jax.random.normal(jax.random.fold_in(KEY, t), (t, d))
        got = ep_ragged_matmul(x, eye, _offsets(sizes), mesh=mesh,
                               axis="expert", schedule=schedule)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(x),
                                      err_msg=f"{schedule}/{name}")


@multidev
def test_ring_matches_gather_schedule():
    """The overlap property test: both schedules are the SAME math over
    different communication patterns, so outputs and gradients agree to
    numerical tolerance on every distribution."""
    d, f, g = 16, 24, 2 * NDEV
    mesh = make_mesh((NDEV,), ("expert",))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (g, d, f))
    for name, sizes in _distributions(g).items():
        t = sum(sizes)
        x = jax.random.normal(jax.random.fold_in(KEY, 100 + t), (t, d))
        offs = _offsets(sizes)

        def loss(x, w, schedule):
            return jnp.sum(ep_ragged_matmul(
                x, w, offs, mesh=mesh, axis="expert",
                schedule=schedule) ** 2)

        _close(ep_ragged_matmul(x, w, offs, mesh=mesh, axis="expert",
                                schedule="ring"),
               ep_ragged_matmul(x, w, offs, mesh=mesh, axis="expert",
                                schedule="gather"))
        gr = jax.grad(loss, argnums=(0, 1))(x, w, "ring")
        gg = jax.grad(loss, argnums=(0, 1))(x, w, "gather")
        _close(gr[0], gg[0], 1e-4)
        _close(gr[1], gg[1], 1e-4)


@multidev
@pytest.mark.parametrize("schedule", ["gather", "ring"])
def test_ep_forward_and_vjp_match_oracle(schedule):
    d, f, g = 16, 24, 2 * NDEV
    mesh = make_mesh((NDEV,), ("expert",))
    sizes = _distributions(g)["skewed"]
    t = sum(sizes)
    offs = _offsets(sizes)
    x = jax.random.normal(KEY, (t, d))
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (g, d, f))
    got = ep_ragged_matmul(x, w, offs, mesh=mesh, axis="expert",
                           schedule=schedule)
    _close(got, ragged_matmul(x, w, offs))
    ge = jax.grad(lambda x, w: jnp.sum(ep_ragged_matmul(
        x, w, offs, mesh=mesh, axis="expert", schedule=schedule) ** 2),
        argnums=(0, 1))(x, w)
    g1 = jax.grad(lambda x, w: jnp.sum(ragged_matmul(x, w, offs) ** 2),
                  argnums=(0, 1))(x, w)
    _close(ge[0], g1[0], 1e-4)
    _close(ge[1], g1[1], 1e-4)


@multidev
@pytest.mark.parametrize("schedule", ["gather", "ring"])
def test_empty_shard_regression_all_rows_one_expert(schedule):
    """Adversarial distribution from the issue: EVERY row routed to one
    expert, so all but one shard own zero rows.  Forward + backward of the
    fused MoE pipeline must match the oracle (the empty shards short-circuit
    their window GEMMs instead of launching degenerate ones)."""
    d, f, g = 16, 24, 2 * NDEV
    mesh = make_mesh((NDEV,), ("expert",))
    sizes = _distributions(g)["one_expert"]
    offs = _offsets(sizes)
    t = sum(sizes)
    x = jax.random.normal(KEY, (t, d)) * 0.5
    wg = jax.random.normal(jax.random.fold_in(KEY, 3), (g, d, f))
    wu = jax.random.normal(jax.random.fold_in(KEY, 4), (g, d, f))
    wd = jax.random.normal(jax.random.fold_in(KEY, 5), (g, f, d))

    def ep(x, wg, wu, wd):
        return ep_ragged_moe(x, wg, wu, wd, offs, mesh=mesh, axis="expert",
                             schedule=schedule)

    def oracle(x, wg, wu, wd):
        from repro.core.gemm import ragged_swiglu
        return ragged_matmul(ragged_swiglu(x, wg, wu, offs), wd, offs)

    _close(ep(x, wg, wu, wd), oracle(x, wg, wu, wd))
    ge = jax.grad(lambda *a: jnp.sum(ep(*a) ** 2),
                  argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    g1 = jax.grad(lambda *a: jnp.sum(oracle(*a) ** 2),
                  argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    for a, b in zip(ge, g1):
        _close(a, b, 1e-4)


@multidev
@pytest.mark.parametrize("schedule", ["gather", "ring"])
def test_dist_matmul_k_parallel_schedules(schedule):
    """k_parallel under both schedules vs the local GEMM — N deliberately
    NOT divisible by the device count so the ring pads its output chunks."""
    m, k, n = 32, 16 * NDEV, 8 * NDEV + 4
    mesh = make_mesh((NDEV,), ("model",))
    a = jax.random.normal(KEY, (m, k))
    b = jax.random.normal(jax.random.fold_in(KEY, 6), (k, n))
    got = dist_matmul(a, b, mesh=mesh, axis="model", strategy="k_parallel",
                      schedule=schedule)
    assert got.shape == (m, n)
    _close(got, matmul(a, b))


@multidev
def test_dist_matmul_rejects_ring_m_parallel():
    mesh = make_mesh((NDEV,), ("model",))
    a = jax.random.normal(KEY, (16, 16))
    b = jax.random.normal(KEY, (16, 16))
    with pytest.raises(ValueError):
        dist_matmul(a, b, mesh=mesh, axis="model", strategy="m_parallel",
                    schedule="ring")
