"""MoE dispatch (capacity and ragged) vs a dense per-expert oracle, plus
capacity-vs-ragged parity in the undropped regime."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import capacity, init_moe_params, moe_mlp

KEY = jax.random.PRNGKey(11)
D, F, E = 32, 64, 4


def oracle(x, params, top_k):
    """Every token through its top-k experts, no capacity limit."""
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, top_k)
    if top_k > 1:
        w = w / w.sum(-1, keepdims=True)
    out = jnp.zeros_like(x)
    for t in range(x.shape[0]):
        acc = jnp.zeros((D,))
        for j in range(top_k):
            e = int(idx[t, j])
            h = jax.nn.silu(x[t] @ params["w_gate"][e]) * (
                x[t] @ params["w_up"][e])
            acc = acc + w[t, j] * (h @ params["w_down"][e])
        out = out.at[t].set(acc)
    return out


def test_moe_matches_oracle_when_capacity_ample():
    params = init_moe_params(KEY, D, F, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (16, D)) * 0.5
    for top_k in (1, 2):
        got, aux = moe_mlp(x, params, num_experts=E, top_k=top_k,
                           capacity_factor=8.0,     # ample: nothing dropped
                           compute_dtype=jnp.float32)
        want = oracle(x, params, top_k)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
        assert np.isfinite(float(aux))


def test_moe_drops_overflow_tokens():
    """With capacity 0-ish, output must be (near) zero, not garbage."""
    params = init_moe_params(KEY, D, F, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (64, D))
    got, _ = moe_mlp(x, params, num_experts=E, top_k=1,
                     capacity_factor=0.001, compute_dtype=jnp.float32)
    # capacity clamps at 8 rows/expert -> at most 32 of 64 tokens routed
    n_nonzero = int(jnp.sum(jnp.any(got != 0, axis=-1)))
    assert n_nonzero <= 32


def test_capacity_rounding():
    assert capacity(1024, 8, 2, 1.25) % 8 == 0
    assert capacity(4, 8, 1, 1.0) == 8      # min clamp (decode batches)


def test_capacity_dtype_sublane():
    """bf16 register tiles are (16, 128): capacity must pad to 16, not the
    fp32 sublane of 8 (the bug class PR 1 fixed in ftimm/ops.py)."""
    assert capacity(1024, 8, 2, 1.25, dtype=jnp.bfloat16) % 16 == 0
    assert capacity(100, 8, 1, 1.25, dtype=jnp.bfloat16) % 16 == 0
    assert capacity(4, 8, 1, 1.0, dtype=jnp.bfloat16) == 16  # min clamp
    assert capacity(100, 8, 1, 1.25, dtype=jnp.float32) % 8 == 0


def test_moe_ragged_matches_oracle_and_drops_nothing():
    """The ragged path has no capacity: it must equal the unlimited dense
    oracle exactly (every token through its experts), for any batch."""
    params = init_moe_params(KEY, D, F, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (64, D)) * 0.5
    for top_k in (1, 2):
        got, aux = moe_mlp(x, params, num_experts=E, top_k=top_k,
                           compute_dtype=jnp.float32, dispatch="ragged")
        want = oracle(x, params, top_k)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
        assert np.isfinite(float(aux))


def test_moe_capacity_vs_ragged_parity():
    """With capacity_factor high enough that nothing is dropped, the two
    dispatch modes must agree to per-dtype tolerance — and the aux loss
    (dispatch-independent) must match."""
    params = init_moe_params(KEY, D, F, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (48, D)) * 0.5
    for dtype, tol in ((jnp.float32, 2e-3), (jnp.bfloat16, 4e-2)):
        for top_k in (1, 2):
            y_cap, aux_cap = moe_mlp(x, params, num_experts=E, top_k=top_k,
                                     capacity_factor=8.0,  # undropped regime
                                     compute_dtype=dtype)
            y_rag, aux_rag = moe_mlp(x, params, num_experts=E, top_k=top_k,
                                     compute_dtype=dtype, dispatch="ragged")
            np.testing.assert_allclose(np.asarray(y_rag, np.float32),
                                       np.asarray(y_cap, np.float32),
                                       rtol=tol, atol=tol)
            np.testing.assert_allclose(float(aux_rag), float(aux_cap),
                                       rtol=1e-6)


def test_moe_ragged_grads_finite():
    params = init_moe_params(KEY, D, F, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (32, D))

    def loss(p, x):
        y, aux = moe_mlp(x, p, num_experts=E, top_k=2,
                         compute_dtype=jnp.float32, dispatch="ragged")
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params, x)
    for leaf in jax.tree.leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_moe_grads_finite():
    params = init_moe_params(KEY, D, F, E)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (32, D))

    def loss(p, x):
        y, aux = moe_mlp(x, p, num_experts=E, top_k=2,
                         capacity_factor=1.25, compute_dtype=jnp.float32)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params, x)
    for leaf in jax.tree.leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf)))
