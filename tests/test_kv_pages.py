"""Paged KV cache: allocator safety properties + paged-vs-dense parity.

The allocator properties are the exhaustion-safety foundation: under any
interleaving of alloc / free / preempt, no physical page is ever owned by
two live requests (aliasing would cross-contaminate KV), nothing leaks,
and draining every owner returns the pool to fully-free.
"""
import jax
import numpy as np
import pytest

from _prop import given, settings, st
from repro.serve.kv_pages import (PageAllocator, PagedKV, PagesExhausted,
                                  pages_for)


def test_pages_for():
    assert pages_for(1, 16) == 1
    assert pages_for(16, 16) == 1
    assert pages_for(17, 16) == 2
    assert pages_for(0, 16) == 0


def test_alloc_all_or_nothing():
    a = PageAllocator(4)
    a.alloc(3, "r0")
    with pytest.raises(PagesExhausted) as ei:
        a.alloc(2, "r1")
    assert ei.value.needed == 2 and ei.value.available == 1
    # the failed alloc consumed nothing
    assert a.available == 1
    assert a.owned("r1") == []
    a.check()


def test_lifo_replay_determinism():
    """Two identical op sequences hand out identical physical pages —
    what makes chaos preemption tests bit-reproducible."""
    def script():
        a = PageAllocator(8)
        trace = [a.alloc(3, 0), a.alloc(2, 1)]
        a.free_owner(0)
        trace.append(a.alloc(4, 2))
        return trace
    assert script() == script()


def test_null_page_never_allocated():
    a = PageAllocator(16, first=1)
    pages = a.alloc(16, "all")
    assert 0 not in pages
    assert sorted(pages) == list(range(1, 17))


@settings(max_examples=40)
@given(ops=st.lists(st.integers(min_value=0, max_value=999),
                    min_size=1, max_size=60))
def test_allocator_never_aliases_and_drains(ops):
    """Property: random alloc/free/preempt interleavings keep every page
    either free or owned by exactly ONE live owner (``check`` audits both
    directions + leaks), and a full drain returns free == total."""
    a = PageAllocator(12)
    for v in ops:
        owner = v % 5
        if v % 3 == 0:
            a.free_owner(owner)            # preemption / completion
        else:
            try:
                a.alloc(v % 4, owner)
            except PagesExhausted:
                pass                       # all-or-nothing; still consistent
        a.check()
        # no page appears under two owners
        seen = {}
        for o in range(5):
            for p in a.owned(o):
                assert p not in seen, (p, o, seen[p])
                seen[p] = o
    for o in range(5):
        a.free_owner(o)
    a.check()
    assert a.available == a.total


def test_paged_decode_matches_dense_cache():
    """End-to-end parity: bucketed prefill + page-insert + paged fused
    decode reproduces the dense slot-cache engine token-for-token."""
    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("qwen3-1.7b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    reqs = lambda: [Request(rid=i, prompt=rng_prompts[i], max_new_tokens=m)
                    for i, m in enumerate([5, 7])]
    rng_prompts = [rng.integers(2, cfg.vocab_size, s).astype(np.int32)
                   for s in (6, 11)]

    dense = ServeEngine(cfg, params, batch_slots=2, max_len=32,
                        paged=False).run(reqs())
    paged = ServeEngine(cfg, params, batch_slots=2, max_len=32,
                        paged=True, page_size=8).run(reqs())
    for d, p in zip(dense, paged):
        assert d.out_tokens == p.out_tokens, (d.rid, d.out_tokens,
                                              p.out_tokens)


def test_paged_kv_insert_roundtrip():
    """insert() lands rows at the mapped physical positions; the gathered
    logical view reproduces them in order."""
    from repro.configs import get_config
    import jax.numpy as jnp
    cfg = get_config("qwen3-1.7b-smoke")
    alloc = PageAllocator(4)
    kv = PagedKV.build(cfg, slots=2, max_len=16, num_pages=5, page_size=4,
                       dtype=jnp.float32)
    depth = 6
    rows = np.random.default_rng(0).normal(size=(
        cfg.num_layers, depth, cfg.num_kv_heads, cfg.head_dim_)).astype(
            np.float32)
    pages = alloc.alloc(pages_for(depth, 4), "r")
    kv.insert(0, pages, jnp.asarray(rows), jnp.asarray(rows))
    pool = np.asarray(kv.k)                  # (L, P, page, KVH, D)
    flat = pool.reshape(cfg.num_layers, -1, cfg.num_kv_heads,
                        cfg.head_dim_)
    logical = flat[:, [p * 4 + i for p in pages for i in range(4)]][:, :depth]
    np.testing.assert_array_equal(logical, rows)
    # null page 0 untouched
    assert np.all(pool[:, 0] == 0)
