"""Mamba2 SSD: the chunked scan must equal the naive per-timestep recurrence
(the state-space duality), and decode must continue prefill exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (HEADDIM, init_ssm_params,
                              ssd_decode_step, ssd_forward, ssm_dims)

KEY = jax.random.PRNGKey(5)
D_MODEL, NSTATE = 64, 16


def naive_ssd(x, params, ssm_state):
    """Per-timestep recurrence oracle (no chunking)."""
    from repro.models.ssm import _causal_conv, _split_proj, CONV_WIDTH
    from repro.models.layers import dense, rms_norm
    bsz, s, d_model = x.shape
    di, hh, n = ssm_dims(d_model, ssm_state)
    p = HEADDIM
    cdt = jnp.float32
    zxbcdt = dense(x, params["in_proj"], cdt)
    z, xs, b, c, dt = _split_proj(zxbcdt, di, n, hh)
    xbc = _causal_conv(jnp.concatenate([xs, b, c], -1),
                       params["conv_w"].astype(cdt),
                       params["conv_b"].astype(cdt))
    xs = xbc[..., :di].reshape(bsz, s, hh, p)
    b = xbc[..., di:di + n]
    c = xbc[..., di + n:]
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    h = jnp.zeros((bsz, hh, p, n))
    ys = []
    for t in range(s):
        decay = jnp.exp(dt[:, t] * a)                       # (B,H)
        xdt = xs[:, t] * dt[:, t][..., None]                # (B,H,P)
        h = decay[:, :, None, None] * h + jnp.einsum(
            "bhp,bn->bhpn", xdt, b[:, t])
        ys.append(jnp.einsum("bhpn,bn->bhp", h, c[:, t]))
    y = jnp.stack(ys, 1) + xs * params["D_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"])
    return dense(y, params["out_proj"], cdt), h


@pytest.mark.parametrize("s,chunk", [(32, 8), (40, 16), (16, 16)])
def test_chunked_ssd_equals_recurrence(s, chunk):
    params = init_ssm_params(KEY, D_MODEL, NSTATE)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, s, D_MODEL)) * 0.5
    y, h = ssd_forward(x, params, ssm_state=NSTATE, chunk=chunk,
                       compute_dtype=jnp.float32)
    y_ref, h_ref = naive_ssd(x, params, NSTATE)
    np.testing.assert_allclose(y, y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(h, h_ref, rtol=2e-3, atol=2e-3)


def test_decode_continues_prefill():
    """prefill(x[:, :t]) then decode(x[:, t]) == forward(x[:, :t+1])[-1]."""
    params = init_ssm_params(KEY, D_MODEL, NSTATE)
    s = 24
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (1, s + 1, D_MODEL)) * 0.5

    y_full, _ = ssd_forward(x, params, ssm_state=NSTATE, chunk=8,
                            compute_dtype=jnp.float32)

    # prefill first s tokens -> state; then one decode step
    y_pre, h = ssd_forward(x[:, :s], params, ssm_state=NSTATE, chunk=8,
                           compute_dtype=jnp.float32)
    # reconstruct conv state from the last W-1 raw conv inputs
    from repro.models.ssm import _split_proj, CONV_WIDTH
    from repro.models.layers import dense
    di, hh, n = ssm_dims(D_MODEL, NSTATE)
    zxbcdt = dense(x[:, :s], params["in_proj"], jnp.float32)
    _, xs_raw, b_raw, c_raw, _ = _split_proj(zxbcdt, di, n, hh)
    conv_in = jnp.concatenate([xs_raw, b_raw, c_raw], -1)
    state = {"h": h, "conv": conv_in[:, s - (CONV_WIDTH - 1):s]}
    y_dec, _ = ssd_decode_step(x[:, s:s + 1], params, state,
                               ssm_state=NSTATE, compute_dtype=jnp.float32)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, s],
                               rtol=5e-3, atol=5e-3)


def test_initial_state_threading():
    """ssd_forward(x2, initial_state=state(x1)) == tail of ssd_forward(x1x2).

    The causal conv is set to an identity tap so the split point carries no
    conv history (state threading isolated; the production prefill->decode
    conv-tail path is covered by test_decode_continues_prefill)."""
    params = init_ssm_params(KEY, D_MODEL, NSTATE)
    cw = jnp.zeros_like(params["conv_w"]).at[-1].set(1.0)
    params = dict(params, conv_w=cw, conv_b=jnp.zeros_like(params["conv_b"]))
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 32, D_MODEL)) * 0.5
    y_full, h_full = ssd_forward(x, params, ssm_state=NSTATE, chunk=8,
                                 compute_dtype=jnp.float32)
    _, h1 = ssd_forward(x[:, :16], params, ssm_state=NSTATE, chunk=8,
                        compute_dtype=jnp.float32)
    # NOTE: conv state crosses the split too; use a conv-safe split point by
    # feeding overlapping context and comparing the strictly interior part.
    y2, h2 = ssd_forward(x[:, 16:], params, ssm_state=NSTATE, chunk=8,
                         compute_dtype=jnp.float32, initial_state=h1)
    np.testing.assert_allclose(h2, h_full, rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(y2, y_full[:, 16:], rtol=5e-3, atol=5e-3)
