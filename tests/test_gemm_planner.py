"""Shape classifier, CMR model and dynamic-adjusting tuner invariants —
the paper's §III-A taxonomy and §IV-C behaviour."""
from _prop import given, settings, st

from repro.core.gemm import (GemmClass, TPU_V5E, classify, estimate,
                             plan_distributed, plan_gemm, tgemm_plan,
                             upper_bound_fraction)


def test_classifier_taxonomy():
    assert classify(10**6, 64, 32) is GemmClass.T1_TALL_SMALL
    assert classify(32, 10**6, 32) is GemmClass.T2_SKINNY_TALL
    assert classify(20480, 20480, 32) is GemmClass.T3_REGULAR_TALL
    assert classify(4096, 4096, 4096) is GemmClass.REGULAR
    # paper N <= 96 examples
    assert classify(2**22, 32, 32) is GemmClass.T1_TALL_SMALL
    assert classify(20480, 20480, 96) is GemmClass.T3_REGULAR_TALL


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 2**22), k=st.integers(1, 2**22),
       n=st.integers(1, 4096))
def test_plan_respects_vmem_budget(m, k, n):
    plan = plan_gemm(m, k, n)
    assert plan.est.vmem_bytes <= TPU_V5E.vmem_budget
    # blocks hardware-aligned
    assert plan.bn % TPU_V5E.lane == 0
    assert plan.bm % TPU_V5E.sublane_fp32 == 0 or plan.bm >= m


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 2**18), k=st.integers(1, 2**18),
       n=st.integers(1, 512))
def test_analytic_plan_prefers_zero_copy(m, k, n):
    """The CMR model never picks the padded edge policy or an unfused
    epilogue over masked/fused — pad copies and separate output passes only
    ADD traffic (only a measurement can overrule that)."""
    from repro.core.gemm.tuner import argmin_plan, gemm_candidates
    plan = argmin_plan(gemm_candidates(m, k, n, epi_ops=2))
    assert plan.edge == "masked" and plan.fuse
    # padded candidates exist exactly when some dim is unaligned
    cands = gemm_candidates(m, k, n)
    has_padded = any(c.edge == "padded" for c in cands)
    all_aligned = all(m % c.bm == 0 and n % c.bn == 0 and k % c.bk == 0
                      for c in cands)
    assert has_padded == (not all_aligned)


@settings(max_examples=30, deadline=None)
@given(m=st.integers(8, 2**20), k=st.integers(8, 2**20),
       n=st.integers(1, 128))
def test_adaptive_beats_or_ties_tgemm(m, k, n):
    """Dynamic adjusting must never be worse than the fixed TGEMM blocking
    under the same cost model (the paper's Fig. 4/5 relationship)."""
    ours = plan_gemm(m, k, n)
    fixed = tgemm_plan(m, k, n)
    assert ours.est.t_total <= fixed.est.t_total * 1.001


def test_plan_deterministic_and_cached():
    a = plan_gemm(4096, 512, 64)
    b = plan_gemm(4096, 512, 64)
    assert a is b   # lru cache


def test_upper_bound_fraction_monotone_in_n():
    """Paper §IV-A3: small N caps utilization (66.7% at n<=32 on FT-m7032;
    lane-fraction bound on TPU)."""
    fracs = [upper_bound_fraction(4096, n, 4096) for n in (16, 32, 64, 128)]
    assert fracs == sorted(fracs)
    assert fracs[-1] > 0.9
    assert fracs[0] <= 0.2   # 16/128 lanes


def test_distributed_strategy_crossover():
    """Paper §IV-C: K-parallel iff M, N small and K large.  Since the ring
    collective matmul landed, the overlapped schedule may extend K-parallel
    onto boundary shapes (the psum hides behind compute) — the paper's rule
    binds the UNOVERLAPPED schedule, so a boundary win must carry
    schedule == "ring"."""
    assert plan_distributed(2**20, 64, 32, 8).strategy == "m_parallel"
    assert plan_distributed(32, 2**20, 32, 8).strategy == "k_parallel"
    d = plan_distributed(20480, 20480, 32, 8)
    assert d.strategy == "m_parallel" or \
        d.local.placement.schedule == "ring"
    # more cores -> K-parallel stays necessary for T2
    assert plan_distributed(32, 2**20, 32, 256).strategy == "k_parallel"


def test_kparallel_reduction_cost_counted():
    d = plan_distributed(32, 2**20, 32, 8)
    assert d.strategy == "k_parallel"
    assert d.t_collective > 0


def test_t1_plan_keeps_b_resident():
    """T1 (M >> K ~ N): expect full-K blocks (gk == 1) so the small B panel
    stays VMEM-resident — the paper's 'B in GSM' reuse."""
    p = plan_gemm(2**20, 128, 32)
    assert p.bk >= 128  # covers all of K
    e = estimate(2**20, 128, 32, bm=p.bm, bn=p.bn, bk=p.bk,
                 dim_order=p.dim_order)
    # traffic ~ one pass over A + one (lane-padded) pass over C + tiny B:
    # B must NOT be re-streamed per M block row.
    a_once = 2**20 * 128 * 4
    c_once = 2**20 * p.bn * 4
    assert e.hbm_bytes < 1.1 * (a_once + c_once)


def test_estimate_memory_bound_for_irregular():
    """The paper's scalability analysis: irregular GEMMs are bandwidth-bound."""
    p = plan_gemm(2**20, 64, 32)
    assert p.est.bound == "memory"
    p = plan_gemm(8192, 8192, 8192)
    assert p.est.bound == "compute"
