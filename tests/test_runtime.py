"""Fault tolerance: heartbeats, stragglers, elastic planning, supervisor."""
import pytest

from repro.runtime.fault_tolerance import (HeartbeatMonitor, HostFailure,
                                           TrainSupervisor, plan_elastic_mesh)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_dead_host_detection():
    clk = FakeClock()
    mon = HeartbeatMonitor(["h0", "h1"], dead_after=10, clock=clk)
    clk.t = 5
    mon.beat("h0", 1)
    clk.t = 12
    assert mon.dead_hosts() == ["h1"]


def test_straggler_detection():
    clk = FakeClock()
    mon = HeartbeatMonitor(["h0", "h1", "h2"], straggler_factor=2.0,
                           clock=clk)
    for step in range(1, 6):
        clk.t = step * 1.0
        mon.beat("h0", step)
        mon.beat("h1", step)
    for step in range(1, 6):
        mon.hosts["h2"].step_times.append(5.0)   # 5x median
        mon.hosts["h2"].last_step = step
    assert mon.stragglers() == ["h2"]


def test_straggler_median_even_host_count():
    """Even host counts take the true median (mean of the middle pair) —
    the old upper-median let one slow host drag the threshold up and hide
    a genuine straggler behind its own slowness."""
    clk = FakeClock()
    mon = HeartbeatMonitor(["h0", "h1", "h2", "h3"], straggler_factor=2.0,
                           clock=clk)
    for h, t in [("h0", 1.0), ("h1", 1.0), ("h2", 3.0), ("h3", 5.0)]:
        mon.hosts[h].step_times.append(t)
        mon.hosts[h].last_step = 1
    # median of {1,1,3,5} is 2.0 -> threshold 4.0: h3 flagged, h2 not.
    # The upper median (3.0 -> threshold 6.0) flagged nobody.
    assert mon.stragglers() == ["h3"]


def test_elastic_plan_preserves_tp():
    p = plan_elastic_mesh(240, model_parallel=16, global_batch=256)
    assert p.model == 16
    assert p.data <= 15 and 256 % p.data == 0
    assert p.chips == p.data * 16 <= 240


def test_elastic_plan_batch_divisibility():
    p = plan_elastic_mesh(7 * 16, model_parallel=16, global_batch=256)
    assert 256 % p.data == 0      # dp=7 rejected -> 4
    assert p.data == 4


def test_elastic_plan_too_few_chips():
    with pytest.raises(ValueError):
        plan_elastic_mesh(8, model_parallel=16, global_batch=64)


def test_supervisor_retry_shrink(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    import jax.numpy as jnp
    ck = Checkpointer(tmp_path)
    attempts = []

    def run_fn(start_step, mesh_shape):
        attempts.append((start_step, mesh_shape))
        if len(attempts) == 1:
            ck.save(10, {"w": jnp.zeros(3)}, blocking=True)
            raise HostFailure(lost_chips=64)
        return 100

    sup = TrainSupervisor(checkpointer=ck, model_parallel=16,
                          global_batch=256, total_chips=256)
    assert sup.run(run_fn) == 100
    assert attempts[0] == (0, (16, 16))
    # after losing 64 chips: 192 survive -> dp=12 (256%12!=0 -> 8) => (8,16)
    assert attempts[1][1] == (8, 16)
    assert attempts[1][0] == 11   # resumes AFTER the checkpoint


def test_supervisor_history_records_failures(tmp_path):
    """The supervisor's post-mortem trail: every attempt AND every failure
    lands in ``history`` (the old loop only logged attempts, so a recovered
    run was indistinguishable from a clean one)."""
    from repro.checkpoint.checkpointer import Checkpointer
    sup = TrainSupervisor(checkpointer=Checkpointer(tmp_path),
                          model_parallel=16, global_batch=256,
                          total_chips=256)
    calls = []

    def run_fn(start_step, mesh_shape):
        calls.append(start_step)
        if len(calls) == 1:
            raise HostFailure(lost_chips=64, msg="rack power loss")
        return 7

    assert sup.run(run_fn) == 7
    kinds = [("failure" if "failure" in h else "attempt")
             for h in sup.history]
    assert kinds == ["attempt", "failure", "attempt"]
    fail = sup.history[1]
    assert fail["failure"] == "HostFailure" and fail["lost_chips"] == 64
    assert sup.history[2]["mesh"] == (8, 16)
