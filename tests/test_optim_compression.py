"""Error-feedback int8 gradient compression: exactness of the integer psum,
error-feedback convergence, and wire dtype (s8 on the all-reduce)."""
import pytest
from helpers import run_with_devices


@pytest.mark.slow
def test_compressed_allreduce_accuracy_and_wire_dtype():
    run_with_devices("""
import functools
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.compat import make_mesh, shard_map
from repro.optim.compression import compress_allreduce, init_error_state

mesh = make_mesh((8,), ("dp",))
N = 8

@functools.partial(shard_map, mesh=mesh, in_specs=(P("dp"), P("dp")),
                   out_specs=(P("dp"), P("dp")))
def step(g, err):
    mean, new_err = compress_allreduce(g[0], err[0], "dp", N)
    return mean[None], new_err[None]

key = jax.random.PRNGKey(0)
g = jax.random.normal(key, (N, 64, 32)) * 0.01
err = jnp.zeros((N, 64, 32))
true_mean = jnp.mean(g, axis=0)

# single step: quantized mean close to true mean
mean, err1 = jax.jit(step)(g, err)
m0 = np.asarray(mean)[0]
rel = np.abs(m0 - np.asarray(true_mean)).max() / np.abs(np.asarray(true_mean)).max()
assert rel < 0.2, rel

# error feedback: accumulated mean over T steps converges to T * true mean
acc = np.zeros((64, 32)); e = err
for t in range(20):
    mean, e = jax.jit(step)(g, e)
    acc += np.asarray(mean)[0]
err_rel = np.abs(acc / 20 - np.asarray(true_mean)).max() / np.abs(np.asarray(true_mean)).max()
assert err_rel < 0.03, err_rel

# the wire carries s8: check the compiled HLO
hlo = jax.jit(step).lower(g, err).compile().as_text()
assert any("s8[" in ln and "all-reduce" in ln for ln in hlo.splitlines()), "no s8 all-reduce"
print("OK")
""", n_devices=8)
