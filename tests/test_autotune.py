"""Measured auto-tuning: persistent plan cache round-trips, graceful
degradation on bad cache files, measured plans never shape-invalid,
interpret-mode timing-harness smoke, calibration tightening, and the
one-entry-point cache reset (planners + dispatch + mesh executors)."""
import importlib
import json

import pytest

from repro.core.gemm import (autotune, dispatch, distributed, plan_store,
                             tuner)
from repro.core.gemm.cmr import TPU_V5E, estimate


@pytest.fixture(autouse=True)
def _clean_stores(monkeypatch):
    monkeypatch.delenv(plan_store.ENV_VAR, raising=False)
    tuner.clear_plan_cache()
    yield
    tuner.clear_plan_cache()


def _tune_small(**kw):
    kw.setdefault("top_k", 2)
    kw.setdefault("repeats", 1)
    kw.setdefault("engine", "xla")
    kw.setdefault("max_elements", 1 << 16)
    return autotune.autotune_gemm(20000, 999, 31, **kw)


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------

def test_measured_then_cached_roundtrip(tmp_path):
    r = _tune_small()
    assert r.plan.mode == "measured"
    assert r.t_measured <= r.t_analytic          # analytic is candidate 0
    served = tuner.plan_gemm(20000, 999, 31)
    assert served.mode == "cached"
    assert (served.bm, served.bn, served.bk) == \
        (r.plan.bm, r.plan.bn, r.plan.bk)

    path = tmp_path / "plans.json"
    autotune.save_plan_cache(str(path))
    autotune.clear_plan_store()
    assert tuner.plan_gemm(20000, 999, 31).mode == "analytic"
    assert autotune.load_plan_cache(str(path)) == 1
    assert tuner.plan_gemm(20000, 999, 31).mode == "cached"


def test_roundtrip_survives_fresh_process(tmp_path, monkeypatch):
    """Write -> simulate a fresh process (importlib.reload of the store
    module, which drops the in-memory view and re-arms the env auto-load)
    -> the planner hits the persisted winner."""
    path = tmp_path / "plans.json"
    _tune_small()
    autotune.save_plan_cache(str(path))

    monkeypatch.setenv(plan_store.ENV_VAR, str(path))
    importlib.reload(plan_store)
    tuner.clear_planner_caches()
    try:
        served = tuner.plan_gemm(20000, 999, 31)
        assert served.mode == "cached"
    finally:
        monkeypatch.delenv(plan_store.ENV_VAR)
        importlib.reload(plan_store)


def test_corrupt_cache_files_ignored(tmp_path):
    cases = {
        "missing.json": None,
        "garbage.json": "{ not json !",
        "not_dict.json": json.dumps([1, 2, 3]),
        "bad_schema.json": json.dumps({"schema": 999, "device_kind":
                                       plan_store.device_kind(),
                                       "entries": {}}),
        "bad_entries.json": json.dumps({"schema": 1, "device_kind":
                                        plan_store.device_kind(),
                                        "entries": "nope"}),
    }
    for name, blob in cases.items():
        p = tmp_path / name
        if blob is not None:
            p.write_text(blob)
        assert autotune.load_plan_cache(str(p)) == 0, name
    # And the planners still work afterwards.
    assert tuner.plan_gemm(256, 256, 32).mode == "analytic"


def test_mismatched_device_kind_ignored(tmp_path):
    r = _tune_small()
    path = tmp_path / "plans.json"
    autotune.save_plan_cache(str(path))
    blob = json.loads(path.read_text())
    blob["device_kind"] = "tpu_v9_imaginary"
    path.write_text(json.dumps(blob))
    autotune.clear_plan_store()
    assert autotune.load_plan_cache(str(path)) == 0
    assert tuner.plan_gemm(*r.dims).mode == "analytic"


def test_cache_can_suggest_but_never_force_invalid_plans():
    """A poisoned record (VMEM-busting blocks / misaligned lanes) must be
    rejected at lookup: the planner falls back to analytic."""
    m, k, n = 4096, 4096, 128
    key = plan_store.shape_key("dense", (m, k, n), 4, 4)
    st = plan_store.get_store()
    st.put(key, {"bm": 8192, "bn": 8192, "bk": 8192, "dim_order": "mn"})
    tuner.clear_planner_caches()
    p = tuner.plan_gemm(m, k, n)
    assert p.mode == "analytic"
    assert p.est.vmem_bytes <= TPU_V5E.vmem_budget

    st.put(key, {"bm": 128, "bn": 100, "bk": 128, "dim_order": "mn"})
    tuner.clear_planner_caches()
    assert tuner.plan_gemm(m, k, n).mode == "analytic"   # bn % lane != 0


def test_measured_plan_is_analytic_valid():
    """The measured winner always comes from the shared candidate
    enumeration — i.e. a tiling the analytic model accepts as
    shape-valid."""
    for m, k, n in [(20000, 999, 31), (63, 4097, 130), (8, 8, 8)]:
        r = autotune.autotune_gemm(m, k, n, top_k=3, repeats=1,
                                   engine="xla", max_elements=1 << 16,
                                   store=False)
        sigs = {(c.bm, c.bn, c.bk, c.dim_order)
                for c in tuner.gemm_candidates(m, k, n)}
        assert (r.plan.bm, r.plan.bn, r.plan.bk, r.plan.dim_order) in sigs
        assert r.plan.est.vmem_bytes <= TPU_V5E.vmem_budget


def test_placed_measured_roundtrip():
    r = autotune.autotune_gemm(1 << 14, 64, 32, num_shards=4, top_k=2,
                               repeats=1, engine="xla",
                               max_elements=1 << 14)
    assert r.plan.mode == "measured"
    assert r.plan.placement is not None
    served = tuner.plan_gemm(1 << 14, 64, 32, num_shards=4)
    assert served.mode == "cached"
    assert served.placement.strategy == r.plan.placement.strategy


def test_batched_and_ragged_roundtrip():
    rb = autotune.autotune_batched_gemm(4, 256, 64, 128, top_k=2, repeats=1,
                                        engine="xla", max_elements=1 << 16)
    rr = autotune.autotune_ragged_gemm(4, 1024, 64, 128, top_k=2, repeats=1,
                                       engine="xla", max_elements=1 << 16)
    assert rb.plan.mode == rr.plan.mode == "measured"
    assert tuner.plan_batched_gemm(4, 256, 64, 128).mode == "cached"
    assert tuner.plan_ragged_gemm(4, 1024, 64, 128).mode == "cached"
    # Different variant keys don't collide.
    assert tuner.plan_ragged_gemm(4, 1024, 64, 128, ragged="k").mode == \
        "analytic"


# ---------------------------------------------------------------------------
# Timing harness (interpret mode: plan-dependent timing without a TPU)
# ---------------------------------------------------------------------------

def test_timing_harness_interpret_smoke():
    r = autotune.autotune_gemm(96, 64, 32, top_k=2, repeats=1,
                               engine="pallas_interpret",
                               max_elements=1 << 14, store=False)
    assert r.engine == "pallas_interpret"
    assert 0.0 < r.t_measured <= r.t_analytic
    assert len(r.timed) <= 2 and all(t > 0 for *_sig, t in r.timed)


def test_timing_harness_interpret_ragged_smoke():
    r = autotune.autotune_ragged_gemm(2, 128, 32, 32, top_k=2, repeats=1,
                                      engine="pallas_interpret",
                                      max_elements=1 << 14, store=False)
    assert 0.0 < r.t_measured <= r.t_analytic


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        autotune.autotune_gemm(64, 64, 64, engine="cuda")


def test_unsupported_operand_width_rejected():
    """An unknown width would silently time the wrong operand bytes and
    poison both the stored winner and the calibration sample.  (in_bytes=1
    is the int8 compute path since the dtype axis landed — supported and
    covered in tests/test_quant.py.)"""
    with pytest.raises(ValueError, match="unsupported operand width"):
        autotune.autotune_gemm(64, 64, 64, in_bytes=8, engine="xla",
                               store=False)


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

def _synthetic_samples(factor: float, shapes):
    out = []
    for m, k, n in shapes:
        p = tuner.argmin_plan(tuner.gemm_candidates(m, k, n))
        out.append((p.est, p.est.t_total * factor))
    return out


def test_calibration_tightens_prediction_on_heldout():
    shapes = [(20000, 999, 31), (4096, 4096, 128), (63, 4097, 130),
              (1 << 16, 64, 32), (32, 1 << 16, 32), (8192, 8192, 96)]
    fit = _synthetic_samples(700.0, shapes[::2])
    hold = _synthetic_samples(700.0, shapes[1::2])
    cal = autotune.fit_calibration(fit)
    before = autotune.prediction_error(hold)
    after = autotune.prediction_error(hold, cal.flops_frac, cal.bw_frac)
    assert after < before
    assert after < 1.5      # constant-factor world: nearly exact recovery
    assert abs(autotune.geomean_ratio(hold, cal.flops_frac, cal.bw_frac)
               - 1.0) < 0.5


def test_calibration_flows_into_default_planning(tmp_path):
    r = _tune_small()
    cal = autotune.calibrate([r])
    spec = tuner.effective_spec(TPU_V5E)
    assert spec is not TPU_V5E and spec.name.endswith("+cal")
    assert spec.hbm_bw == pytest.approx(TPU_V5E.hbm_bw * cal.bw_frac)
    # Persisted with the plans, reloaded with them.
    path = tmp_path / "plans.json"
    autotune.save_plan_cache(str(path))
    autotune.clear_plan_store()
    assert tuner.effective_spec(TPU_V5E) is TPU_V5E
    autotune.load_plan_cache(str(path))
    assert tuner.effective_spec(TPU_V5E).name.endswith("+cal")
    # Custom specs are never silently rewritten.
    custom = TPU_V5E.calibrated(1.0, 1.0)
    assert tuner.effective_spec(custom) is custom


def test_recalibration_composes_instead_of_collapsing():
    """est_measured must be expressed in the RAW base spec even while a
    calibration is installed — otherwise re-tuning under an active
    calibration feeds already-corrected predictions back into the fit and
    a re-calibration collapses to ~1.0, destroying the correction."""
    r1 = _tune_small()
    autotune.calibrate([r1])
    r2 = _tune_small()      # tuned WITH the calibration installed
    assert r2.est_measured.t_total == pytest.approx(
        r1.est_measured.t_total, rel=1e-6)


def test_reset_store_does_not_rearm_env_autoload(tmp_path, monkeypatch):
    """clear_plan_store means EMPTY until an explicit load — the env
    auto-load must not silently refill the clean slate."""
    path = tmp_path / "plans.json"
    _tune_small()
    autotune.save_plan_cache(str(path))
    monkeypatch.setenv(plan_store.ENV_VAR, str(path))
    importlib.reload(plan_store)        # fresh process: auto-load armed
    tuner.clear_planner_caches()
    try:
        assert tuner.plan_gemm(20000, 999, 31).mode == "cached"
        autotune.clear_plan_store()
        assert len(plan_store.get_store()) == 0
        assert tuner.plan_gemm(20000, 999, 31).mode == "analytic"
    finally:
        monkeypatch.delenv(plan_store.ENV_VAR)
        importlib.reload(plan_store)


def test_calibrated_estimates_scale():
    e0 = estimate(4096, 4096, 128, bm=256, bn=128, bk=512)
    spec = TPU_V5E.calibrated(0.5, 0.25)
    e1 = estimate(4096, 4096, 128, bm=256, bn=128, bk=512, spec=spec)
    assert e1.t_compute == pytest.approx(e0.t_compute / 0.5)
    assert e1.t_memory == pytest.approx(e0.t_memory / 0.25)


# ---------------------------------------------------------------------------
# Mode telemetry + the single-entry-point reset (satellite bugfix)
# ---------------------------------------------------------------------------

def test_plan_mode_stats_counts_dispatch():
    import jax.numpy as jnp
    from repro.core.gemm import matmul, plan_mode_stats
    _tune_small()       # (20000, 999, 31) now cached
    a = jnp.ones((20000, 999), jnp.float32)
    b = jnp.ones((999, 31), jnp.float32)
    matmul(a, b, backend="xla")
    stats = plan_mode_stats()
    assert stats.get("dense", {}).get("cached", 0) >= 1


def test_clear_plan_cache_clears_every_layer():
    import jax.numpy as jnp
    from repro.core.gemm import matmul, ragged_matmul

    _tune_small()
    a = jnp.ones((64, 32), jnp.float32)
    matmul(a, jnp.ones((32, 16), jnp.float32), backend="pallas_interpret")
    x = jnp.ones((32, 16), jnp.float32)
    w = jnp.ones((2, 16, 8), jnp.float32)
    ragged_matmul(x, w, jnp.asarray([0, 16, 32]), backend="xla")

    assert dispatch._pallas_fn.cache_info().currsize > 0
    assert dispatch._ragged_fn.cache_info().currsize > 0
    assert tuner.plan_gemm.cache_info().currsize > 0
    assert len(plan_store.get_store()) > 0
    assert tuner.PLAN_MODE_COUNTS

    tuner.clear_plan_cache()
    assert dispatch._pallas_fn.cache_info().currsize == 0
    assert dispatch._ragged_fn.cache_info().currsize == 0
    assert distributed._ep_ragged_fn.cache_info().currsize == 0
    assert distributed._ep_ragged_swiglu_fn.cache_info().currsize == 0
    assert distributed._ep_ragged_moe_fn.cache_info().currsize == 0
    for f in (tuner.plan_gemm, tuner.plan_batched_gemm,
              tuner.plan_ragged_gemm, tuner.plan_distributed,
              tuner.plan_moe_dispatch):
        assert f.cache_info().currsize == 0
    assert len(plan_store.get_store()) == 0
    assert not tuner.PLAN_MODE_COUNTS


def test_clear_plan_cache_clears_mesh_executors():
    """The satellite bug: stale mesh executors used to survive a cache
    reset.  Populate one EP executor on a 1-device mesh and check the
    single entry point drops it."""
    import jax
    import jax.numpy as jnp
    from repro.core.compat import make_mesh
    from repro.core.gemm import ep_ragged_matmul

    if len(jax.devices()) < 1:      # pragma: no cover
        pytest.skip("no devices")
    mesh = make_mesh((1,), ("data",))
    x = jnp.ones((32, 16), jnp.float32)
    w = jnp.ones((2, 16, 8), jnp.float32)
    out = ep_ragged_matmul(x, w, jnp.asarray([0, 16, 32]), mesh=mesh,
                           axis="data", backend="xla")
    assert out.shape == (32, 8)
    assert distributed._ep_ragged_fn.cache_info().currsize == 1
    tuner.clear_plan_cache()
    assert distributed._ep_ragged_fn.cache_info().currsize == 0


# ---------------------------------------------------------------------------
# Shared candidate generator (satellite simplification)
# ---------------------------------------------------------------------------

def test_shortlist_leads_with_analytic_argmin():
    cands = tuner.gemm_candidates(20000, 999, 31)
    sl = tuner.shortlist(cands, 4)
    best = tuner.argmin_plan(cands)
    assert (sl[0].bm, sl[0].bn, sl[0].bk, sl[0].dim_order) == \
        (best.bm, best.bn, best.bk, best.dim_order)
    assert len(sl) <= 4
    sigs = [(c.bm, c.bn, c.bk, c.nsplit, c.dim_order) for c in sl]
    assert len(sigs) == len(set(sigs))      # deduped


def test_planners_agree_with_shared_enumeration():
    for m, k, n in [(2**20, 64, 32), (32, 2**20, 32), (20480, 20480, 32),
                    (4096, 4096, 4096)]:
        p = tuner.plan_gemm(m, k, n)
        best = tuner.argmin_plan(tuner.gemm_candidates(m, k, n))
        assert (p.bm, p.bn, p.bk, p.dim_order) == \
            (best.bm, best.bn, best.bk, best.dim_order)
