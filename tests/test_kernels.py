"""ftIMM Pallas kernels vs the pure-jnp oracle: shape/dtype/transpose/split-K
sweeps in interpret mode, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels.ftimm import gemm, ref
from repro.kernels.ftimm.kernel import ftimm_gemm

KEY = jax.random.PRNGKey(7)


def _mk(trans, m, k, n, dtype):
    ka, kb = jax.random.split(jax.random.fold_in(KEY, m * 31 + k * 7 + n))
    shapes = {"nn": ((m, k), (k, n)), "tn": ((k, m), (k, n)),
              "nt": ((m, k), (n, k))}[trans]
    a = jax.random.normal(ka, shapes[0], dtype)
    b = jax.random.normal(kb, shapes[1], dtype)
    return a, b


def _check(trans, a, b, out, dtype):
    want = {"nn": ref.matmul_nn, "tn": ref.matmul_tn,
            "nt": ref.matmul_nt}[trans](a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# The paper's three irregular types + regular + edge shapes.
SHAPES = [
    (1024, 32, 32),      # T1 tall-and-skinny x small
    (32, 2048, 32),      # T2 skinny-and-tall x tall-and-skinny
    (512, 512, 32),      # T3 regular x tall-and-skinny
    (256, 256, 256),     # regular
    (100, 60, 96),       # unaligned everything, paper's N=96
    (8, 128, 8),         # tiny
    (33, 257, 65),       # primes-ish
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("trans", ["nn", "tn", "nt"])
def test_gemm_vs_oracle_fp32(m, k, n, trans):
    a, b = _mk(trans, m, k, n, jnp.float32)
    out = gemm(a, b, trans=trans, interpret=True)
    _check(trans, a, b, out, jnp.float32)


@pytest.mark.parametrize("m,k,n", SHAPES[:4])
def test_gemm_vs_oracle_bf16(m, k, n):
    a, b = _mk("nn", m, k, n, jnp.bfloat16)
    out = gemm(a, b, trans="nn", interpret=True)
    _check("nn", a, b, out, jnp.bfloat16)


@pytest.mark.parametrize("m,k,n,nsplit", [
    (32, 2048, 32, 4),    # the paper's K-parallel case (T2)
    (32, 2048, 32, 8),
    (64, 1000, 96, 2),    # unaligned K
    (16, 512, 128, 4),
])
@pytest.mark.parametrize("trans", ["nn", "tn"])
def test_splitk_vs_oracle(m, k, n, nsplit, trans):
    a, b = _mk(trans, m, k, n, jnp.float32)
    out = gemm(a, b, trans=trans, nsplit=nsplit, interpret=True)
    _check(trans, a, b, out, jnp.float32)


def test_splitk_equals_monolithic():
    a, b = _mk("nn", 64, 1024, 64, jnp.float32)
    out1 = gemm(a, b, interpret=True)
    out2 = gemm(a, b, nsplit=4, interpret=True)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dim_order", ["mn", "nm"])
def test_dim_order_equivalence(dim_order):
    a, b = _mk("nn", 96, 256, 160, jnp.float32)
    out = gemm(a, b, dim_order=dim_order, interpret=True)
    _check("nn", a, b, out, jnp.float32)


def test_block_shape_sweep():
    """Paper §IV-A: arbitrary micro-kernel sizes under hardware constraints."""
    a, b = _mk("nn", 256, 384, 256, jnp.float32)
    want = ref.matmul_nn(a, b)
    for bm in (8, 32, 128, 256):
        for bn in (128, 256):
            for bk in (128, 384):
                out = ftimm_gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
                np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4,
                                           err_msg=f"{bm},{bn},{bk}")


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 96), k=st.integers(1, 160), n=st.integers(1, 96))
def test_gemm_property_random_shapes(m, k, n):
    a, b = _mk("nn", m, k, n, jnp.float32)
    out = gemm(a, b, interpret=True)
    _check("nn", a, b, out, jnp.float32)


@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 96), k=st.integers(1, 160), n=st.integers(1, 96))
def test_gemm_property_zero_copy_edges(m, k, n):
    """Non-block-multiple shapes through the in-kernel edge-tile masking
    (edge="masked": no pad, no slice) across all trans layouts and both dim
    orders, against the padded path and the oracle."""
    for trans in ("nn", "tn", "nt"):
        a, b = _mk(trans, m, k, n, jnp.float32)
        for dim_order in ("mn", "nm"):
            out = gemm(a, b, trans=trans, dim_order=dim_order,
                       edge="masked", interpret=True)
            _check(trans, a, b, out, jnp.float32)
    padded = gemm(a, b, trans="nt", edge="padded", interpret=True)
    np.testing.assert_allclose(out, padded, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 48), k=st.integers(2, 64), n=st.integers(2, 48))
def test_gemm_linearity(m, k, n):
    """gemm(a, b1 + b2) == gemm(a, b1) + gemm(a, b2) (fp32 exact-ish)."""
    a, b1 = _mk("nn", m, k, n, jnp.float32)
    _, b2 = _mk("nn", m + 1, k, n, jnp.float32)
    b2 = b2[:k] if b2.shape[0] != k else b2
    lhs = gemm(a, b1 + b2, interpret=True)
    rhs = gemm(a, b1, interpret=True) + gemm(a, b2, interpret=True)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


def test_custom_vjp_grads_match_xla():
    from repro.core.gemm import matmul
    a, b = _mk("nn", 48, 96, 40, jnp.float32)

    def loss(fn):
        return lambda a, b: jnp.sum(fn(a, b) ** 2)

    g_pl = jax.grad(loss(lambda a, b: matmul(
        a, b, backend="pallas_interpret")), argnums=(0, 1))(a, b)
    g_x = jax.grad(loss(lambda a, b: matmul(
        a, b, backend="xla")), argnums=(0, 1))(a, b)
    for u, v in zip(g_pl, g_x):
        np.testing.assert_allclose(u, v, rtol=3e-4, atol=3e-4)
