"""Checkpointer: async atomic save/restore, GC, and elastic re-mesh."""
import pytest
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer

from helpers import run_with_devices


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "layers": {"ln": jnp.ones((16,))}},
        "opt": {"m": {"w": jnp.zeros((8, 16)),
                      "layers": {"ln": jnp.zeros((16,))}},
                "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    st = _state()
    ck.save(3, st, blocking=True)
    step, got = ck.restore(st)
    assert step == 3
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    st = _state()
    for i in (1, 2, 3, 4):
        ck.save(i, st)
    ck.wait()
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_atomicity_marker(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _state(), blocking=True)
    # remove DONE: checkpoint must become invisible
    (tmp_path / "step_00000005" / "DONE").unlink()
    assert ck.latest_step() is None


@pytest.mark.slow
def test_elastic_restore_new_mesh(tmp_path):
    """Save under an (8,)-device sharding, restore under (4,) — the node
    failure path (and the mesh growth path by symmetry)."""
    run_with_devices(f"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpointer import Checkpointer

ck = Checkpointer(r"{tmp_path}")
from repro.core.compat import make_mesh
mesh8 = make_mesh((8,), ("data",))
w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                   NamedSharding(mesh8, P("data", None)))
ck.save(1, {{"w": w}}, blocking=True)

# restore on a 4-device sub-mesh (simulated survivor set)
mesh4 = make_mesh((4,), ("data",), devices=jax.devices()[:4])
sh = {{"w": NamedSharding(mesh4, P("data", None))}}
step, got = ck.restore({{"w": w}}, shardings=sh)
assert step == 1
np.testing.assert_array_equal(np.asarray(got["w"]),
                              np.arange(64, dtype=np.float32).reshape(8, 8))
assert got["w"].sharding.mesh.shape["data"] == 4
print("OK")
""", n_devices=8)
