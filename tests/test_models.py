"""Per-architecture smoke tests (reduced same-family configs): one forward/
train step on CPU asserting output shapes + no NaNs, and prefill+decode
consistency against teacher-forced full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import model as M

KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def _batch(cfg):
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 2, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, S), 2, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.num_patches:
        batch["patch_embeds"] = jax.random.normal(
            KEY, (B, cfg.num_patches, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    cfg = get_config(arch + "-smoke")
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        M.loss_fn, has_aux=True)(params, cfg, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.all(np.isfinite(np.asarray(g, np.float32)))
                          for g in leaves)
    logits, _ = M.forward_train(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch):
    """Greedy decode after prefill must reproduce the teacher-forced logits
    of the full forward at the same position (cache correctness)."""
    import dataclasses
    cfg = get_config(arch + "-smoke")
    if cfg.num_experts:
        # ample capacity: token-dropping depends on the batch composition,
        # which legitimately differs between prefill(S) and forward(S+1)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    toks = batch["tokens"]

    # teacher-forced reference: logits at position S-1 given toks[:, :S]
    full_logits, _ = M.forward_train(params, cfg, batch)
    ref = full_logits[:, S - 1]

    # prefill of toks[:, :S] — last-position logits must match
    cache = M.make_cache(cfg, B, S + 8)
    pre_batch = dict(batch)
    pre_batch["tokens"] = toks
    got, cache = M.prefill(params, cfg, pre_batch, cache)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)

    # one decode step with the argmax token: compare against a fresh
    # teacher-forced forward over S+1 tokens
    nxt = jnp.argmax(got, -1)[:, None].astype(jnp.int32)
    pos = jnp.int32(S + (cfg.num_patches or 0))
    dec_logits, _ = M.decode_step(params, cfg, nxt, cache, pos)

    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([toks, nxt], axis=1)
    batch2.pop("labels", None)
    full2, _ = M.forward_train(params, cfg, batch2)
    ref2 = full2[:, S]
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(ref2, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_vocab_padding_masked():
    cfg = get_config("whisper-base-smoke")
    assert cfg.vocab_padded % cfg.vocab_pad_multiple == 0
    params = M.init_params(cfg, KEY)
    logits, _ = M.forward_train(params, cfg, _batch(cfg))
    pad = np.asarray(logits, np.float32)[..., cfg.vocab_size:]
    if pad.size:
        assert np.all(pad <= -1e29)


def test_window_pattern_cycles():
    cfg = get_config("gemma3-4b")
    w = cfg.windows()
    assert len(w) == cfg.num_layers
    assert w[:6] == (1024, 1024, 1024, 1024, 1024, 0)
    assert w[6] == 1024


def test_param_count_sane():
    """Full configs should land near their nominal sizes."""
    approx = {
        "qwen3-8b": (7e9, 10e9),
        "qwen3-1.7b": (1.5e9, 2.5e9),
        "mamba2-370m": (0.25e9, 0.55e9),
        "mixtral-8x7b": (40e9, 50e9),
        "llava-next-34b": (30e9, 40e9),
    }
    for name, (lo, hi) in approx.items():
        n = get_config(name).param_count()
        assert lo < n < hi, (name, n)
    # MoE active < total
    cfg = get_config("mixtral-8x7b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()
