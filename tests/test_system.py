"""End-to-end behaviour tests for the whole system: train -> checkpoint ->
elastic resume -> serve, on a reduced config; plus a multi-device
integration pass of train_step on a (2,4) mesh; plus a mini multi-pod
dry-run proving lower().compile() with the production code path."""
import pytest
from helpers import run_with_devices


@pytest.mark.slow
def test_train_checkpoint_resume_serve(tmp_path):
    run_with_devices(f"""
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.optim.adamw import OptConfig
from repro.train.trainer import Trainer
from repro.serve.engine import Request, ServeEngine

cfg = get_config("qwen3-1.7b-smoke")
shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
oc = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)

t1 = Trainer(cfg, shape, oc, ckpt_dir=r"{tmp_path}", ckpt_every=5)
p1, o1 = t1.run(8)

# resume from the checkpoint and keep training — deterministic data means
# fresh-run(12) == resume-run(12)
t2 = Trainer(cfg, shape, oc, ckpt_dir=r"{tmp_path}", ckpt_every=5)
p2, o2 = t2.run(12)
t3 = Trainer(cfg, shape, oc)
p3, o3 = t3.run(12)
for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-2, atol=2e-2)

# serve with the trained weights
eng = ServeEngine(cfg, p2, batch_slots=2, max_len=48)
rng = np.random.default_rng(0)
reqs = eng.run([Request(rid=i, prompt=rng.integers(2, cfg.vocab_size, 8).astype(np.int32),
                        max_new_tokens=4) for i in range(2)])
assert all(len(r.out_tokens) == 4 for r in reqs)
print("OK")
""", n_devices=1, timeout=560)


@pytest.mark.slow
def test_sharded_train_step_runs():
    run_with_devices("""
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.dist import DistContext, use_dist
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.sharding import batch_specs, dp_axes, param_specs, to_shardings
from repro.models.model import init_params
from repro.optim.adamw import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

cfg = get_config("mixtral-8x7b-smoke")   # exercises MoE path
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
mesh = make_mesh((2, 4), ("data", "model"))
ctx = DistContext(mesh=mesh, dp_axes=("data",), model_axis="model")
with use_dist(ctx), mesh:
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    ps = to_shardings(param_specs(params, mesh), mesh)
    os_ = to_shardings(param_specs(opt, mesh), mesh)
    ds = SyntheticLM(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.host_batch(0).items()}
    bs = to_shardings(batch_specs(cfg, batch, mesh), mesh)
    step = jax.jit(make_train_step(cfg, OptConfig()),
                   in_shardings=(ps, os_, bs), donate_argnums=(0, 1))
    params, opt, metrics = step(params, opt, batch)
    loss1 = float(metrics["loss"])
    batch = {k: jnp.asarray(v) for k, v in ds.host_batch(1).items()}
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(loss1) and np.isfinite(float(metrics["loss"]))
print("OK")
""", n_devices=8)


@pytest.mark.slow
def test_sharded_train_step_moe_ep_runs():
    """The expert-parallel production path end-to-end: ragged (capacity-free)
    MoE dispatch routed through the ep_ragged_* shard_map executors INSIDE a
    GSPMD-jitted train step on a (data, model) mesh, with the expert weights
    EP-sharded by param_specs(moe_ep=True) — forward + backward + optimizer."""
    run_with_devices("""
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.dist import DistContext, use_dist
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_mesh
from repro.launch.sharding import batch_specs, expert_axis, param_specs, to_shardings
from repro.models.model import init_params
from repro.optim.adamw import OptConfig, init_opt_state
from repro.train.train_step import make_train_step

cfg = get_config("llama4-scout-17b-a16e-smoke")  # moe_dispatch="ragged"
assert cfg.moe_dispatch == "ragged"
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
mesh = make_mesh((2, 4), ("data", "model"))
ep_ax = expert_axis(mesh, True, "dp")
assert ep_ax == "data"
ctx = DistContext(mesh=mesh, dp_axes=("data",), model_axis="model",
                  moe_ep_axis=ep_ax)
with use_dist(ctx), mesh:
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    ps = to_shardings(param_specs(params, mesh, moe_ep=True), mesh)
    os_ = to_shardings(param_specs(opt, mesh, zero_stage=3, moe_ep=True), mesh)
    ds = SyntheticLM(cfg, shape)
    batch = {k: jnp.asarray(v) for k, v in ds.host_batch(0).items()}
    bs = to_shardings(batch_specs(cfg, batch, mesh), mesh)
    step = jax.jit(make_train_step(cfg, OptConfig()),
                   in_shardings=(ps, os_, bs), donate_argnums=(0, 1))
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
print("OK")
""", n_devices=8, timeout=560)


@pytest.mark.slow
def test_mini_multipod_dryrun():
    """The production dry-run path on a scaled-down (2, 2, 4) pod mesh."""
    run_with_devices("""
import jax
import jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.dist import DistContext, use_dist
from repro.launch.mesh import make_mesh
from repro.launch.sharding import batch_specs, param_specs, to_shardings
from repro.launch.dryrun import input_specs, abstract_state
from repro.optim.adamw import OptConfig
from repro.train.train_step import make_train_step
from repro.core import compat
from repro.roofline.analysis import collective_bytes

cfg = get_config("qwen3-1.7b")
shape = ShapeConfig("mini", seq_len=256, global_batch=16, kind="train")
mesh = make_mesh((2, 2, 4), ("pod", "data", "model"))
dist = DistContext(mesh=mesh, dp_axes=("pod", "data"), model_axis="model")
with use_dist(dist), mesh:
    batch = input_specs(cfg, shape)
    params, opt = abstract_state(cfg, shape, True)
    c = jax.jit(make_train_step(cfg, OptConfig()),
                in_shardings=(to_shardings(param_specs(params, mesh), mesh),
                              to_shardings(param_specs(opt, mesh), mesh),
                              to_shardings(batch_specs(cfg, batch, mesh), mesh)),
                donate_argnums=(0, 1)).lower(params, opt, batch).compile()
mem = c.memory_analysis()
assert compat.cost_analysis(c)["flops"] > 0
coll = collective_bytes(c.as_text())
assert coll["all-reduce"] > 0   # pod-axis gradient reduction present
print("OK", mem.temp_size_in_bytes)
""", n_devices=16, timeout=560)
