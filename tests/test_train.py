"""Training loop: loss decreases, grad accumulation is equivalent, optimizer
math matches a reference implementation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim.adamw import OptConfig, apply_updates, init_opt_state, schedule
from repro.train.train_step import make_train_step

KEY = jax.random.PRNGKey(0)


def test_loss_decreases():
    cfg = get_config("qwen3-1.7b-smoke")
    shape = ShapeConfig("t", seq_len=64, global_batch=4, kind="train")
    ds = SyntheticLM(cfg, shape, seed=0)
    params = M.init_params(cfg, KEY)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=2,
                                                  total_steps=30)))
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in ds.host_batch(i).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]


def test_grad_accumulation_equivalence():
    cfg = get_config("mamba2-370m-smoke")
    shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
    ds = SyntheticLM(cfg, shape, seed=1)
    batch = {k: jnp.asarray(v) for k, v in ds.host_batch(0).items()}

    params = M.init_params(cfg, KEY)
    opt = init_opt_state(params)
    oc = OptConfig(lr=1e-3)
    p1, _, m1 = jax.jit(make_train_step(cfg, oc, accum_steps=1))(
        params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, oc, accum_steps=2))(
        params, init_opt_state(params), batch)
    # same data, same update (microbatch mean == full-batch mean here since
    # loss is token-mean over equal-sized microbatches)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-3)


def test_adamw_reference_math():
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    cfg = OptConfig(lr=0.01, beta1=0.9, beta2=0.999, eps=1e-8,
                    weight_decay=0.0, warmup_steps=0, total_steps=10,
                    min_lr_ratio=1.0, clip_norm=1e9)
    st = init_opt_state(p)
    new_p, st, stats = apply_updates(p, g, st, cfg)
    # hand-rolled adam step 1
    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.001 * np.array([0.1, 0.2, -0.3]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.999)
    want = np.array([1.0, -2.0, 3.0]) - 0.01 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)


def test_schedule_warmup_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_ratio=0.1)
    assert float(schedule(jnp.int32(5), cfg)) == 0.5
    assert abs(float(schedule(jnp.int32(10), cfg)) - 1.0) < 1e-6
    end = float(schedule(jnp.int32(110), cfg))
    assert abs(end - 0.1) < 1e-3


def test_grad_clip():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    cfg = OptConfig(clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    st = init_opt_state(p)
    _, _, stats = apply_updates(p, g, st, cfg)
    assert float(stats["grad_norm"]) == 200.0
