"""Data pipeline: determinism and prefetcher correctness."""
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import Prefetcher, SyntheticLM


def _ds(seed=0):
    cfg = get_config("qwen3-1.7b-smoke")
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    return SyntheticLM(cfg, shape, seed=seed)


def test_deterministic_per_step():
    a = _ds().host_batch(5)
    b = _ds().host_batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = _ds().host_batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_seed_changes_stream():
    a = _ds(seed=0).host_batch(0)
    b = _ds(seed=1).host_batch(0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_labels_are_shifted_tokens():
    b = _ds().host_batch(0)
    # labels = next-token continuation of the same sampled stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_in_vocab():
    cfg = get_config("qwen3-1.7b-smoke")
    b = _ds().host_batch(3)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < cfg.vocab_size


def test_prefetcher_order_and_replay():
    ds = _ds()
    pf = Prefetcher(ds, depth=2, start_step=10)
    s0, b0 = pf.next()
    s1, b1 = pf.next()
    pf.close()
    assert (s0, s1) == (10, 11)
    np.testing.assert_array_equal(b0["tokens"],
                                  np.asarray(ds.host_batch(10)["tokens"]))
