"""MoE dispatch benchmark: capacity vs ragged vs EP-ragged.

Legs of the same (T, D, F, E, top_k) MoE MLP:

  * ``capacity`` — Switch-style static capacity (pad + drop),
  * ``ragged``   — capacity-free sort-by-expert dispatch (PR 2),
  * ``ep_ragged`` — the ragged dispatch expert-sharded over an 8-way axis
    under the planner-chosen schedule (ring overlap since PR 7): measured
    in a SUBPROCESS with 8 fake host devices, because the bench process
    pins its platform device count at jax init,
  * ``ep_ragged_gather`` — the same EP layer with the unoverlapped
    gather-exchange schedule forced (``REPRO_EP_SCHEDULE=gather``), the
    pre-PR-7 behavior kept as the regression reference.

``us_per_call`` is the runnable XLA-CPU wall time (jitted; the 8 fake
devices timeshare one CPU, so EP numbers show schedule overhead, not ICI
speedup — the speedup lives in the modeled column).  The ring schedule
still wins WALL time here because its per-shard compute touches only the
owned token window instead of the worst-case full T.  ``derived`` carries
the planner's view: dispatch rows, the chosen placement strategy+schedule
and the modeled t_total ratio vs the single-device plan at TPU-v5e
constants.

Also writes ``results/BENCH_moe_ep.json`` — the first point of the repo's
perf trajectory; later PRs append comparable runs next to it.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.core.gemm import (plan_moe_dispatch, plan_ragged_gemm,
                             preferred_ep_schedule)
from repro.models.moe import init_moe_params, moe_mlp

from .common import record, time_fn

T, D, F, E, TOP_K = 512, 128, 256, 8, 2
N_SHARDS = 8

_EP_SNIPPET = """
import time
import jax
import jax.numpy as jnp
from repro.core.compat import make_mesh
from repro.core.dist import DistContext, use_dist
from repro.models.moe import init_moe_params, moe_mlp

T, D, F, E, TOP_K = {t}, {d}, {f}, {e}, {top_k}
mesh = make_mesh(({n},), ("data",))
ctx = DistContext(mesh=mesh, dp_axes=("data",), model_axis="data",
                  moe_ep_axis="data")
params = init_moe_params(jax.random.PRNGKey(0), D, F, E)
x = jax.random.normal(jax.random.PRNGKey(1), (T, D))

def step(p, x):
    with use_dist(ctx):
        y, aux = moe_mlp(x, p, num_experts=E, top_k=TOP_K,
                         compute_dtype=jnp.float32, dispatch="ragged")
    return y

f = jax.jit(step)
jax.block_until_ready(f(params, x))
t0 = time.perf_counter()
for _ in range(3):
    jax.block_until_ready(f(params, x))
print("US", (time.perf_counter() - t0) / 3 * 1e6)
"""


def _time_ep_subprocess(schedule: str | None = None) -> float:
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_SHARDS}"
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    if schedule is not None:
        env["REPRO_EP_SCHEDULE"] = schedule
    else:
        env.pop("REPRO_EP_SCHEDULE", None)
    code = _EP_SNIPPET.format(t=T, d=D, f=F, e=E, top_k=TOP_K, n=N_SHARDS)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return float(out.stdout.strip().split("US")[-1])


def run() -> None:
    params = init_moe_params(jax.random.PRNGKey(0), D, F, E)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D))

    rows = []

    def leg(name: str, us: float, derived: str):
        record(f"moe_ep_{name}", us, derived)
        rows.append({"name": name, "us_per_call": round(us, 2),
                     "derived": derived})

    for dispatch in ("capacity", "ragged"):
        f = jax.jit(lambda p, x, d=dispatch: moe_mlp(
            x, p, num_experts=E, top_k=TOP_K, compute_dtype=jnp.float32,
            dispatch=d)[0])
        us = time_fn(f, params, x)
        mp = plan_moe_dispatch(T, E, TOP_K, D, F, dispatch=dispatch)
        leg(dispatch, us, f"rows={mp.rows};strategy={mp.strategy}")

    # EP legs: measured in the 8-device subprocess; modeled off the SAME
    # planner the executors consult.  ``ep_ragged`` runs the planner-chosen
    # schedule (ring); ``ep_ragged_gather`` forces the unoverlapped
    # exchange as the pre-ring reference.
    p1 = plan_ragged_gemm(E, T * TOP_K, D, F, 4, 4)
    p8 = plan_ragged_gemm(E, T * TOP_K, D, F, 4, 4, num_shards=N_SHARDS)
    mp8 = plan_moe_dispatch(T, E, TOP_K, D, F, dispatch="ragged",
                            elt_bytes=4, num_shards=N_SHARDS)
    # The schedule the EP executors resolve in the subprocess: the planner
    # preference evaluated with serial=nc (the fake devices timeshare one
    # CPU core, so per-shard local compute serializes).
    schedule = preferred_ep_schedule(E, T * TOP_K, D, F, 4, 4,
                                     num_shards=N_SHARDS, serial=N_SHARDS)
    for name, forced in (("ep_ragged", None), ("ep_ragged_gather", "gather")):
        try:
            us_ep = _time_ep_subprocess(forced)
            err = ""
        except (RuntimeError, subprocess.TimeoutExpired, ValueError) as e:
            us_ep, err = 0.0, f";error={type(e).__name__}"
        leg(name, us_ep,
            f"rows={mp8.rows};strategy={p8.placement.strategy};"
            f"schedule={forced or schedule};"
            f"modeled_t1_over_t8={p1.t_total / p8.t_total:.2f};"
            f"a2a_bytes={mp8.placement.ici_bytes:.0f}" + err)

    out = pathlib.Path(__file__).resolve().parents[1] / "results"
    out.mkdir(exist_ok=True)
    payload = {
        "bench": "moe_ep",
        "created": time.strftime("%Y-%m-%d"),
        "config": {"tokens": T, "d_model": D, "d_ff": F, "experts": E,
                   "top_k": TOP_K, "ep_shards": N_SHARDS,
                   "backend": jax.default_backend()},
        "rows": rows,
    }
    with open(out / "BENCH_moe_ep.json", "w") as fp:
        json.dump(payload, fp, indent=1)
