"""Paper Fig. 7 — efficiency: ftIMM on the accelerator vs a traditional BLAS
on the host CPU (paper: GPDSP cluster vs OpenBLAS on the 16-core ARMv8 of
FT-m7032; ftIMM up to 3.1x higher EFFICIENCY = achieved/peak).

TPU analogue: modeled ftIMM efficiency on v5e vs a fixed-blocking BLAS model
on a host CPU spec (FT-2000+-like: 281.6 GFlops fp32, 42.6 GB/s).  The
figure's quantity is the ratio of efficiencies, which cancels absolute
hardware scale and isolates the blocking/strategy quality — the thing the
paper is actually demonstrating."""
from __future__ import annotations

from repro.core.gemm import plan_gemm, tgemm_plan
from repro.core.gemm.cmr import TPU_V5E, TpuSpec

CPU_SPEC = TpuSpec(name="ft2000plus_cpu", peak_flops_bf16=281.6e9,
                   peak_flops_fp32=281.6e9, hbm_bw=42.6e9,
                   vmem_budget=32 * 1024 * 1024,   # L2-ish blocking budget
                   lane=4, sublane_fp32=4, mxu=4)

from .common import record

CASES = [
    ("t1", 2**20, 32, 32),
    ("t2", 32, 2**20, 32),
    ("t3", 20480, 20480, 32),
    ("t3_n96", 20480, 20480, 96),
]


def _efficiency(plan, spec) -> float:
    return plan.est.flops_useful / max(
        plan.est.t_total * spec.peak_flops_fp32, 1e-30)


def run() -> None:
    for name, m, k, n in CASES:
        ours = plan_gemm(m, k, n, spec=TPU_V5E)
        eff_tpu = _efficiency(ours, TPU_V5E)
        # CPU BLAS model: fixed regular blocking on the CPU spec
        cpu_plan = tgemm_plan(m, k, n, spec=CPU_SPEC)
        eff_cpu = _efficiency(cpu_plan, CPU_SPEC)
        record(f"fig7_cpu_compare_{name}", 0.0,
               f"eff_ftimm_tpu={eff_tpu:.3f};eff_blas_cpu={eff_cpu:.3f};"
               f"efficiency_ratio={eff_tpu / max(eff_cpu, 1e-9):.2f}")
