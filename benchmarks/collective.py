"""Overlapped-vs-gather collective GEMM benchmark (PR 7).

Runs in a SUBPROCESS with 8 fake host devices (the bench process pins its
platform device count at jax init) and times the PLACED executors
end-to-end — collectives executed, not modeled:

  * dense ``dist_matmul``: m_parallel vs k_parallel/gather (compute then
    psum) vs k_parallel/ring (the overlapped collective matmul),
  * ragged EP ``ep_ragged_matmul``: the single-device reference vs
    expert-parallel under the gather and ring schedules,
  * ``calibrate_ici`` — the fitted effective-ICI-bandwidth fraction (on
    fake host devices this absorbs the software-collective overhead; on a
    real ICI mesh it would sit near 1.0),
  * the crossover-agreement check: does the measured EP winner match the
    schedule ``preferred_ep_schedule`` predicts from the CMR model?  This
    is the gate that the planner's default decision and the hardware agree.

Writes ``results/BENCH_collective.json`` next to the other trajectory
files.  Wall times are XLA-CPU with 8 timesharing fake devices, so sharded
legs cannot beat the single-device leg on wall clock; what the ring legs
demonstrate is per-shard work proportional to OWNED rows instead of the
worst-case full window — which is exactly the term that made the pre-PR-7
EP layer 4.8x slower than one device.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

from .common import record

N_SHARDS = 8
# Ragged shape = the moe_ep bench's dispatch GEMM (T*top_k, D -> F).
G, TOTAL, K, N = 8, 1024, 128, 256
# Dense shape: short M, deep K — the K-parallel regime (paper Alg. 5).
DM, DK, DN = 64, 2048, 256

_SNIPPET = """
import json
import jax
from repro.core.compat import make_mesh
from repro.core.gemm import autotune
from repro.core.gemm.tuner import preferred_ep_schedule

G, TOTAL, K, N = {g}, {total}, {k}, {n}
DM, DK, DN = {dm}, {dk}, {dn}
mesh = make_mesh(({nc},), ("data",))

# Planner predictions FIRST, under the default (uncalibrated) constants
# a fresh process consults.  "predicted" is what the EP executors actually
# resolve here: on the CPU backend the fake devices timeshare one core,
# so the preference is evaluated with the local term serialized over the
# shards (serial=nc) — the same call _resolve_ep_schedule makes.
# "predicted_tpu" is the per-chip (serial=1) preference at TPU constants.
serial = {nc} if jax.default_backend() == "cpu" else 1
predicted = preferred_ep_schedule(G, TOTAL, K, N, 4, 4, num_shards={nc},
                                  serial=serial)
predicted_tpu = preferred_ep_schedule(G, TOTAL, K, N, 4, 4, num_shards={nc})

ragged = autotune.time_placed_ragged_e2e(G, TOTAL, K, N, mesh=mesh,
                                         axis="data", backend="xla")
dense = autotune.time_placed_dense_e2e(DM, DK, DN, mesh=mesh, axis="data",
                                       backend="xla")

# Fit the effective-ICI-bandwidth fraction from timed mesh exchanges and
# report the planner's post-calibration prediction alongside.
cal = autotune.calibrate_ici(mesh, "data")
predicted_cal = preferred_ep_schedule(G, TOTAL, K, N, 4, 4, num_shards={nc},
                                      serial=serial)

print("JSON" + json.dumps({{
    "ragged": ragged, "dense": dense,
    "ici_frac": cal.ici_frac,
    "predicted_schedule": predicted,
    "predicted_schedule_tpu": predicted_tpu,
    "predicted_schedule_calibrated": predicted_cal,
}}))
"""


def _run_subprocess() -> dict:
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_SHARDS}"
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("REPRO_EP_SCHEDULE", None)
    code = _SNIPPET.format(g=G, total=TOTAL, k=K, n=N, dm=DM, dk=DK, dn=DN,
                           nc=N_SHARDS)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().split("JSON")[-1])


def run() -> None:
    rows = []

    def leg(name: str, us: float, derived: str):
        record(f"collective_{name}", us, derived)
        rows.append({"name": name, "us_per_call": round(us, 2),
                     "derived": derived})

    try:
        data = _run_subprocess()
    except (RuntimeError, subprocess.TimeoutExpired, ValueError) as e:
        record("collective_error", 0.0, f"error={type(e).__name__}")
        return

    for fam, fam_rows in (("ragged", data["ragged"]),
                          ("dense", data["dense"])):
        for r in fam_rows:
            t_model = r["t_model"]
            model_us = (f"{t_model * 1e6:.1f}"
                        if t_model == t_model else "nan")
            leg(f"{fam}_{r['strategy']}_{r['schedule']}",
                r["t_measured"] * 1e6,
                f"modeled_us={model_us}")

    ep = [r for r in data["ragged"] if r["strategy"] == "expert_parallel"]
    measured_winner = min(ep, key=lambda r: r["t_measured"])["schedule"]
    predicted = data["predicted_schedule"]
    leg("ep_crossover", 0.0,
        f"measured_winner={measured_winner};predicted={predicted};"
        f"agree={measured_winner == predicted};"
        f"predicted_tpu={data['predicted_schedule_tpu']};"
        f"predicted_calibrated={data['predicted_schedule_calibrated']}")
    leg("ici_calibration", 0.0, f"ici_frac={data['ici_frac']:.3e}")

    out = pathlib.Path(__file__).resolve().parents[1] / "results"
    out.mkdir(exist_ok=True)
    payload = {
        "bench": "collective",
        "created": time.strftime("%Y-%m-%d"),
        "config": {"shards": N_SHARDS,
                   "ragged": {"g": G, "total": TOTAL, "k": K, "n": N},
                   "dense": {"m": DM, "k": DK, "n": DN}},
        "rows": rows,
        "ici_frac": data["ici_frac"],
        "predicted_schedule": predicted,
        "predicted_schedule_tpu": data["predicted_schedule_tpu"],
        "measured_winner": measured_winner,
        "crossover_agree": measured_winner == predicted,
        "note": ("8 fake host devices timeshare one CPU: sharded wall "
                 "times bound overhead, not ICI speedup, so the planner "
                 "prediction here is the serial=nc (timeshared-local) "
                 "evaluation the executors use on the CPU backend — the "
                 "ring schedule wins because its per-shard compute covers "
                 "only the owned token window instead of the worst-case "
                 "full T.  predicted_schedule_tpu is the per-chip TPU-v5e "
                 "preference, where this small shape's serialized ring "
                 "rotation bytes favor the gather exchange instead.  "
                 "ici_frac absorbs the software-collective cost and would "
                 "sit near 1.0 on a real ICI mesh; note it is a BANDWIDTH "
                 "fraction fitted on one fused exchange, so it overcharges "
                 "the ring's many small latency-dominated ppermute hops — "
                 "which is why predicted_schedule_calibrated can fall back "
                 "to gather here while measurement (and the uncalibrated "
                 "serialized-local prediction) pick ring."),
    }
    with open(out / "BENCH_collective.json", "w") as fp:
        json.dump(payload, fp, indent=1)
