"""Closed-loop serving benchmark: open-loop arrivals at 0.5x / 1x / 2x of
measured capacity through the overload-safe engine.

Measures what the serving tentpole promises: under 2x sustained overload
the engine SHEDS load (typed ``Overloaded`` rejections at the door plus
estimate-gated queue shedding) instead of hanging or OOMing, every admitted
request either completes or times out at its deadline, and the admitted
p99 stays bounded (within 2x of the 1x p99 — admission control keeps the
queue from eating the latency budget).

Protocol per leg (seeded, deterministic arrival schedule):
  1. capacity: a saturated closed run measures tokens/s; the per-request
     completion rate prices the arrival process;
  2. each leg draws exponential inter-arrivals at ``mult x capacity`` and
     injects them between engine steps (open-loop: arrivals don't wait for
     completions — the 2x leg genuinely overloads);
  3. per-request terminal states + latencies recorded; the ``burst_arrival``
     chaos site injects arrival bursts when armed (the chaos smoke leg).

Writes ``results/BENCH_serve.json`` (append-a-run schema shared with the
other gated suites) or ``BENCH_serve_smoke.json`` with ``--smoke`` (small
counts, CI artifact — never the committed baseline).  ``run.py --gate``
ratchets the fresh 1x admitted p99 at 1.30x of the committed baseline and
requires the three flags to hold.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from repro.configs import get_config                          # noqa: E402
from repro.models.model import init_params                    # noqa: E402
from repro.runtime import chaos as _chaos                     # noqa: E402
from repro.serve.engine import (Overloaded, Request,          # noqa: E402
                                ServeEngine)
from .common import record                                    # noqa: E402

_RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"
ARCH = "qwen3-1.7b-smoke"
PROMPT_LEN = 12
MAX_NEW = 16
SLOTS = 4
MAX_LEN = 64
LEGS = (0.5, 1.0, 2.0)


def _make_requests(rng, n, start_rid, deadline_s):
    return [Request(rid=start_rid + i,
                    prompt=rng.integers(2, 512, PROMPT_LEN).astype(np.int32),
                    max_new_tokens=MAX_NEW, deadline_s=deadline_s)
            for i in range(n)]


def _measure_capacity(eng, rng) -> float:
    """Tokens/s of a saturated closed run (every slot busy, no deadlines);
    also calibrates the engine's cost model.  Returns requests/s.  A warm
    pass first: compile time must not deflate the capacity estimate (an
    underpriced capacity makes the 2x leg no overload at all)."""
    eng.run(_make_requests(rng, SLOTS, 0, None))            # compile/warm
    reqs = _make_requests(rng, SLOTS * 4, 100, None)
    t0 = time.monotonic()
    eng.run(reqs)
    wall = time.monotonic() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    return (toks / wall) / MAX_NEW


def _drive_leg(eng, reqs, arrivals) -> dict:
    """Open-loop: submit each request at its scheduled arrival offset while
    stepping the engine; returns terminal-state counts + latency stats."""
    base_faults = dict(eng.faults)
    rejected, finish = [], {}
    t0 = time.monotonic()
    i = 0
    while True:
        now = time.monotonic() - t0
        while i < len(reqs) and arrivals[i] <= now:
            f = _chaos.should_fire("burst_arrival")
            burst = 1 + (f.burst if f is not None else 0)
            for _ in range(burst):
                if i >= len(reqs):
                    break
                try:
                    eng.submit(reqs[i])
                except Overloaded:
                    reqs[i].done = True
                    rejected.append(reqs[i])
                i += 1
        busy = eng.step() > 0
        for r in reqs:
            if r.done and r.rid not in finish:
                finish[r.rid] = time.monotonic()
        if i >= len(reqs) and not eng.queue \
                and not any(a is not None for a in eng.active):
            break
        if not busy and i < len(reqs):
            time.sleep(max(0.0, min(arrivals[i] - (time.monotonic() - t0),
                                    0.002)))
    wall = time.monotonic() - t0

    completed = [r for r in reqs if r.done and not r.timed_out and not r.shed
                 and r not in rejected]
    lat = sorted(finish[r.rid] - r.submitted_at for r in completed)
    toks = sum(len(r.out_tokens) for r in completed)
    deltas = {k: eng.faults[k] - base_faults[k] for k in eng.faults}
    n = len(reqs)
    terminal = all(r.done for r in reqs)
    return {
        "offered": n,
        "rejected": len(rejected),
        "shed": deltas["shed"],
        "timed_out": sum(1 for r in reqs if r.timed_out and not r.shed),
        "completed": len(completed),
        "preemptions": deltas["preemptions"],
        "tokens_per_s": toks / wall if wall > 0 else 0.0,
        "p50_s": lat[len(lat) // 2] if lat else None,
        "p99_s": lat[min(len(lat) - 1, int(len(lat) * 0.99))] if lat
                 else None,
        "shed_rate": (len(rejected) + deltas["shed"]) / n if n else 0.0,
        "all_terminal": terminal,
        "wall_s": wall,
    }


def run(smoke: bool = False, seed: int = 0) -> dict:
    cfg = get_config(ARCH)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=SLOTS, max_len=MAX_LEN)
    rng = np.random.default_rng(seed)
    cap_rps = _measure_capacity(eng, rng)

    # Deadline: a fixed multiple of the calibrated service estimate — tight
    # enough that a saturated queue becomes infeasible (shedding engages),
    # loose enough that the 0.5x leg never sheds.
    step = eng.cost.step_s() or 1e-3
    pre = eng.cost.prefill_s(eng.buckets[0]) or 1e-3
    service_s = pre + MAX_NEW * step
    deadline_s = max(0.2, 4.0 * service_s)

    n_leg = 8 if smoke else 48
    legs = {}
    rid = 1000
    for mult in LEGS:
        rate = cap_rps * mult
        # The overload leg runs proportionally longer: sustained 2x
        # pressure needs time to build the backlog admission control is
        # there to bound.
        n = int(n_leg * max(1.0, mult))
        gaps = rng.exponential(1.0 / rate, size=n)
        arrivals = np.cumsum(gaps)
        reqs = _make_requests(rng, n, rid, deadline_s)
        rid += n
        leg = _drive_leg(eng, reqs, arrivals)
        leg["offered_rps"] = rate
        legs[f"{mult}x"] = leg
        record(f"serve_{mult}x",
               (leg["p99_s"] or 0.0) * 1e6,
               f"{leg['tokens_per_s']:.0f}tok/s "
               f"shed={leg['shed_rate']:.2f} "
               f"done={leg['completed']}/{leg['offered']}")

    p99_1x = legs["1.0x"]["p99_s"]
    p99_2x = legs["2.0x"]["p99_s"]
    run_rec = {
        "arch": ARCH,
        "smoke": smoke,
        "capacity_rps": cap_rps,
        "deadline_s": deadline_s,
        "slots": SLOTS,
        "prompt_len": PROMPT_LEN,
        "max_new": MAX_NEW,
        "legs": legs,
        "admitted_p99_1x_s": p99_1x,
        # Acceptance flags the gate enforces.  The tail bound: admitted
        # p99 at 2x within 2x of the 1x p99 — OR within the deadline,
        # which is the lever admission control actually enforces (on a
        # fast machine the unloaded 1x p99 can sit below deadline/2, and
        # admitted 2x work legitimately runs up to the deadline).
        "overload_sheds": legs["2.0x"]["shed_rate"] > 0,
        "all_terminal": all(leg["all_terminal"] for leg in legs.values()),
        "p99_within_2x": (p99_1x is not None and p99_2x is not None
                          and p99_2x <= max(2.0 * p99_1x, deadline_s)),
        "health": eng.health(),
    }
    out = _RESULTS / ("BENCH_serve_smoke.json" if smoke
                      else "BENCH_serve.json")
    _RESULTS.mkdir(exist_ok=True)
    try:
        blob = json.loads(out.read_text())
        assert isinstance(blob.get("runs"), list)
    except (OSError, ValueError, AssertionError):
        blob = {"runs": []}
    blob["runs"].append(run_rec)
    out.write_text(json.dumps(blob, indent=1, default=str) + "\n")
    print(f"serve: wrote {out}", file=sys.stderr)
    return run_rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small counts; writes BENCH_serve_smoke.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rec = run(smoke=args.smoke, seed=args.seed)
    if not rec["all_terminal"]:
        raise SystemExit("serve benchmark: non-terminal requests (hang)")
    print("serve benchmark:",
          "sheds-under-overload" if rec["overload_sheds"] else "no-shed",
          f"p99_1x={rec['admitted_p99_1x_s']}")


if __name__ == "__main__":
    main()
