"""Offline measured auto-tuning sweep + the ``irregular`` replay leg.

Sweep (the paper's evaluation loop, closed):

    PYTHONPATH=src python -m benchmarks.autotune \
        [--engine xla|pallas|pallas_interpret] [--top-k 4] [--repeats 3] \
        [--cache results/plan_cache.json] [--out results/BENCH_irregular.json]

For every T1/T2/T3 shape of the paper's irregular families plus
model-derived GEMM shapes from ``configs.registry`` (decode qkv / MLP /
LM-head projections), the CMR model shortlists candidate tilings, the
timing harness measures them, winners land in the persistent plan cache,
and a calibration is fitted on the tune split and *evaluated on the
held-out split* — the JSON records, per shape, the analytic-plan time, the
measured-plan time and the predicted-vs-measured ratio, and per run whether
measured mode ever lost to analytic (it cannot, on the same harness run).

``--smoke``: tiny shapes on the interpret-mode kernels (plan-dependent
timing without a TPU), a 2-deep shortlist, one repeat — the CI leg; writes
to separate ``*_smoke`` files so the committed baseline stays put.

Replay (``benchmarks/run.py --only irregular``): re-times the T1/T2/T3
sweep from the *committed* plan cache — no search, just cached-vs-analytic
— and appends a run record to ``results/BENCH_irregular.json``, growing the
perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))

import jax  # noqa: E402

from repro.core.gemm import autotune, plan_store, tuner  # noqa: E402
from repro.core.gemm.shapes import PAPER_IRREGULAR_SHAPES, classify  # noqa: E402
from repro.configs import get_config  # noqa: E402

RESULTS = _ROOT / "results"
DEFAULT_OUT = RESULTS / "BENCH_irregular.json"
DEFAULT_CACHE = RESULTS / "plan_cache.json"

# The paper's 21 T1/T2/T3 shapes — canonical list lives in
# ``repro.core.gemm.shapes`` (shared with the static verification sweep).
T_SHAPES: list[tuple[str, int, int, int]] = list(PAPER_IRREGULAR_SHAPES)

SMOKE_SHAPES: list[tuple[str, int, int, int]] = [
    ("t1_smoke", 1024, 32, 32),
    ("t2_smoke", 32, 2048, 32),
    ("t3_smoke", 512, 512, 32),
]

# Model-derived dense GEMMs (decode-batch tokens against the projection
# panels) — the irregular shapes production serving actually issues.
MODEL_ARCHS = ("qwen3-8b", "mixtral-8x7b", "llama4-scout-17b-a16e",
               "gemma3-4b")
DECODE_TOKENS = 128


def model_shapes() -> list[tuple[str, int, int, int]]:
    shapes = []
    for arch in MODEL_ARCHS:
        cfg = get_config(arch)
        n_q = cfg.num_heads * cfg.head_dim_
        n_kv = cfg.num_kv_heads * cfg.head_dim_
        shapes.append((f"{arch}_qkv", DECODE_TOKENS, cfg.d_model,
                       n_q + 2 * n_kv))
        shapes.append((f"{arch}_mlp", DECODE_TOKENS, cfg.d_model, cfg.d_ff))
    return shapes


def _split(i: int) -> str:
    """Deterministic tune/holdout split: every third shape is held out of
    the calibration fit so the JSON can demonstrate generalization."""
    return "holdout" if i % 3 == 2 else "tune"


def sweep(engine: str, top_k: int, repeats: int, max_elements: int,
          smoke: bool, out_path: pathlib.Path,
          cache_path: pathlib.Path) -> dict:
    shapes = SMOKE_SHAPES if smoke else T_SHAPES + model_shapes()
    t_names = {s[0] for s in (SMOKE_SHAPES if smoke else T_SHAPES)}
    autotune.clear_plan_store()     # sweep from a clean slate
    rows, results = [], []
    for i, (name, m, k, n) in enumerate(shapes):
        cls = classify(m, k, n).value
        if name in t_names and not smoke:
            assert cls != "regular", (name, m, k, n)
        r = autotune.autotune_gemm(m, k, n, top_k=top_k, repeats=repeats,
                                   engine=engine, max_elements=max_elements)
        rows.append({
            "name": name, "family": "dense", "class": cls, "set": _split(i),
            "m": m, "k": k, "n": n,
            "measured_dims": list(r.measured_dims),
            "analytic_plan": {"bm": r.analytic_plan.bm,
                              "bn": r.analytic_plan.bn,
                              "bk": r.analytic_plan.bk,
                              "dim_order": r.analytic_plan.dim_order},
            "measured_plan": {"bm": r.plan.bm, "bn": r.plan.bn,
                              "bk": r.plan.bk,
                              "dim_order": r.plan.dim_order},
            "t_analytic_us": round(r.t_analytic * 1e6, 3),
            "t_measured_us": round(r.t_measured * 1e6, 3),
            "t_model_us": round(r.est_measured.t_total * 1e6, 6),
            "ratio_pred_over_meas": round(r.ratio_pred_over_meas, 6),
        })
        results.append(r)
        print(f"{name}: analytic={r.t_analytic*1e6:.1f}us "
              f"measured={r.t_measured*1e6:.1f}us "
              f"plan=({r.plan.bm},{r.plan.bn},{r.plan.bk},"
              f"{r.plan.dim_order}) ratio={r.ratio_pred_over_meas:.3g}")
    if smoke:
        # Exercise the batched + ragged searches too (kernel-path coverage).
        rb = autotune.autotune_batched_gemm(
            4, 256, 64, 128, top_k=2, repeats=repeats, engine=engine,
            max_elements=max_elements)
        rr = autotune.autotune_ragged_gemm(
            4, 1024, 64, 128, top_k=2, repeats=repeats, engine=engine,
            max_elements=max_elements)
        print(f"batched smoke: measured={rb.t_measured*1e6:.1f}us; "
              f"ragged smoke: measured={rr.t_measured*1e6:.1f}us")

    hold = [(r.est_measured, r.t_measured)
            for i, r in enumerate(results) if _split(i) == "holdout"]
    if not hold:                    # smoke runs are tiny; degrade gracefully
        hold = [(r.est_measured, r.t_measured) for r in results]
    cal = autotune.calibrate(
        [r for i, r in enumerate(results) if _split(i) == "tune"])
    cal_block = {
        **cal.to_json(),
        "holdout_err_before": round(autotune.prediction_error(hold), 6),
        "holdout_err_after": round(autotune.prediction_error(
            hold, cal.flops_frac, cal.bw_frac), 6),
        "holdout_ratio_before": round(autotune.geomean_ratio(hold), 8),
        "holdout_ratio_after": round(autotune.geomean_ratio(
            hold, cal.flops_frac, cal.bw_frac), 6),
    }
    st = plan_store.get_store()
    autotune.save_plan_cache(str(cache_path))

    never_slower = all(r["t_measured_us"] <= r["t_analytic_us"]
                       for r in rows)
    payload = _load_or_new(out_path)
    payload.update({
        "config": {"engine": engine, "top_k": top_k, "repeats": repeats,
                   "max_elements": max_elements,
                   "device_kind": plan_store.device_kind(),
                   "backend": jax.default_backend(),
                   "jax": jax.__version__},
        "calibration": cal_block,
        "shapes": rows,
    })
    payload.setdefault("runs", []).append({
        "date": time.strftime("%Y-%m-%d"),
        "source": "sweep", "engine": engine,
        "device_kind": plan_store.device_kind(),
        "n_shapes": len(rows),
        "measured_never_slower": never_slower,
        "plan_cache_entries": len(st),
    })
    out_path.parent.mkdir(exist_ok=True)
    with open(out_path, "w") as fp:
        json.dump(payload, fp, indent=1)
    print(f"calibration: flops_frac={cal.flops_frac:.3g} "
          f"bw_frac={cal.bw_frac:.3g} "
          f"holdout err {cal_block['holdout_err_before']:.3g} -> "
          f"{cal_block['holdout_err_after']:.3g}")
    print(f"wrote {out_path} ({len(rows)} shapes) and {cache_path} "
          f"({len(st)} plans); measured_never_slower={never_slower}")
    return payload


def _load_or_new(out_path: pathlib.Path) -> dict:
    if out_path.exists():
        try:
            with open(out_path) as fp:
                payload = json.load(fp)
            if isinstance(payload, dict) and payload.get("bench") == \
                    "irregular_autotune":
                return payload
        except (OSError, ValueError):
            pass
    return {"bench": "irregular_autotune", "schema": 1,
            "created": time.strftime("%Y-%m-%d")}


# ---------------------------------------------------------------------------
# Replay leg: benchmarks/run.py --only irregular
# ---------------------------------------------------------------------------

def run() -> None:
    """Replay the T1/T2/T3 sweep from the committed plan cache: time the
    analytic argmin against the cached measured winner for every shape
    (no search) and append a run record to the baseline JSON."""
    from .common import record

    n_loaded = autotune.load_plan_cache(str(DEFAULT_CACHE))
    engine = autotune.default_engine()
    speedups, n_cached = [], 0
    for name, m, k, n in T_SHAPES:
        analytic = tuner.argmin_plan(tuner.gemm_candidates(m, k, n))
        served = tuner.plan_gemm(m, k, n)       # cached when the store hits
        n_cached += served.mode == "cached"
        ts = autotune.time_dense_plans(m, k, n, [analytic, served],
                                       engine=engine, repeats=2)
        speedups.append(ts[0] / max(ts[1], 1e-12))
        record(f"irregular_{name}", ts[1] * 1e6,
               f"mode={served.mode};analytic_us={ts[0]*1e6:.1f};"
               f"plan=({served.bm},{served.bn},{served.bk},"
               f"{served.dim_order})")

    payload = _load_or_new(DEFAULT_OUT)
    geo = 1.0
    if speedups:
        import math
        geo = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
    payload.setdefault("runs", []).append({
        "date": time.strftime("%Y-%m-%d"),
        "source": "replay", "engine": engine,
        "device_kind": plan_store.device_kind(),
        "n_shapes": len(T_SHAPES),
        "cache_entries_loaded": n_loaded,
        "cache_hits": n_cached,
        "geomean_analytic_over_cached": round(geo, 4),
    })
    DEFAULT_OUT.parent.mkdir(exist_ok=True)
    with open(DEFAULT_OUT, "w") as fp:
        json.dump(payload, fp, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, interpret engine, 2-deep shortlist")
    ap.add_argument("--engine", default=None,
                    choices=["xla", "pallas", "pallas_interpret"])
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--max-elements", type=int, default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--cache", default=None)
    args = ap.parse_args()

    if args.smoke:
        engine = args.engine or "pallas_interpret"
        top_k = args.top_k or 2
        repeats = args.repeats or 1
        max_elements = args.max_elements or (1 << 17)
        out = pathlib.Path(args.out or RESULTS / "BENCH_irregular_smoke.json")
        cache = pathlib.Path(args.cache
                             or RESULTS / "plan_cache_smoke.json")
    else:
        engine = args.engine or autotune.default_engine()
        top_k = args.top_k or autotune.DEFAULT_TOP_K
        repeats = args.repeats or autotune.DEFAULT_REPEATS
        max_elements = args.max_elements or autotune.DEFAULT_MAX_ELEMENTS
        out = pathlib.Path(args.out or DEFAULT_OUT)
        cache = pathlib.Path(args.cache or DEFAULT_CACHE)
    sweep(engine, top_k, repeats, max_elements, args.smoke, out, cache)


if __name__ == "__main__":
    main()
