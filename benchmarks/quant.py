"""Low-precision decode GEMM benchmark leg (the dtype axis, ISSUE 8).

    PYTHONPATH=src python -m benchmarks.quant [--smoke] [--engine ...]
    PYTHONPATH=src python -m benchmarks.run --only quant

Times the weight-only int8 path against the bf16 baseline on the paper's
weight-streaming irregular classes — T2 (K >> M ~ N: the skinny-tall
decode GEMMs whose weight panel is streamed against a handful of token
rows) and T3 (M ~ K >> N) for contrast — through the real dispatch layer
(``matmul``), three candidates per shape:

  * **bf16**       — ``matmul(x, w_bf16)``: the full-width baseline.
  * **w8 fused**   — ``matmul(x, w_q, epilogue=scale_vec, scale=s)`` with a
    PRE-quantized int8 panel (``core.quant.quantize_weights``): the weight
    bytes halve, and the per-channel dequant rides the accumulator flush.
  * **w8 unfused** — explicit full-panel dequant materialized per call,
    then the bf16 GEMM: the separate-pass spelling the fusion saves.

The decode claim this leg demonstrates (and the committed baseline
records): on the T2 shapes the fused w8 GEMM is never slower than bf16 —
the halved weight stream pays even though this engine upconverts both
operand widths into the same fp32 dot — and fusing the dequant into the
flush is never slower than the separate pass.  T3 rows (weight panels tiny
next to the M x K activations) are recorded honestly as parity context.
Candidates within 2% land as ties: a ms-scale CPU GEMM cannot resolve
differences that small, and pretending otherwise would flap the flags.

Writes ``results/BENCH_quant.json`` (``*_smoke`` under ``--smoke``, the CI
leg); a run record keeps the trajectory across replays.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import quant  # noqa: E402
from repro.core.gemm import autotune, matmul, plan_store  # noqa: E402
from repro.core.gemm.shapes import PAPER_IRREGULAR_SHAPES, classify  # noqa: E402
from repro.kernels.ftimm.epilogue import Epilogue  # noqa: E402

RESULTS = _ROOT / "results"
DEFAULT_OUT = RESULTS / "BENCH_quant.json"

# The decode family: every T2/T3 paper shape (scaled to the element budget).
SHAPES = [s for s in PAPER_IRREGULAR_SHAPES
          if s[0].startswith(("t2_", "t3_"))]
SMOKE_SHAPES = [("t2_32_8k", 32, 8192, 32), ("t3_512_64", 512, 512, 64)]

BUDGET_S = 4.0      # per-shape interleaved-sampling wall-clock budget
TIE_FRAC = 0.02     # candidates within 2% are a timing tie

_SCALE_VEC = Epilogue(scale_vec=True)


def _min_interleaved(thunks, budget: float = BUDGET_S) -> list[float]:
    """Per-thunk min over an interleaved sampling loop (same statistic and
    rationale as benchmarks/epilogue.py: deterministic work difference ->
    min; alternation spreads load drift over all candidates equally)."""
    for t in thunks:
        jax.block_until_ready(t())      # compile
        jax.block_until_ready(t())      # warm
    t0 = time.perf_counter()
    for t in thunks:
        jax.block_until_ready(t())
    per_round = max(time.perf_counter() - t0, 1e-6)
    rounds = int(max(min(budget / per_round, 200), 8))
    best = [float("inf")] * len(thunks)
    for _ in range(rounds):
        for i, t in enumerate(thunks):
            s = time.perf_counter()
            jax.block_until_ready(t())
            best[i] = min(best[i], time.perf_counter() - s)
    return best


def _shape_times(m: int, k: int, n: int,
                 max_elements: int) -> tuple[tuple[int, int, int],
                                             float, float, float]:
    mm, kk, nn = autotune._scale_dense(m, k, n, max_elements)
    x = autotune._rand((mm, kk), jnp.bfloat16)
    w32 = autotune._rand((kk, nn), jnp.float32, seed=1)
    wb = w32.astype(jnp.bfloat16)
    wq, s = quant.quantize_weights(w32, quant.QuantConfig(mode="w8"))

    f_bf16 = jax.jit(lambda x_, w_: matmul(x_, w_, out_dtype=jnp.bfloat16))
    f_fused = jax.jit(lambda x_, q_, s_: matmul(
        x_, q_, epilogue=_SCALE_VEC, scale=s_, out_dtype=jnp.bfloat16))

    def _unfused(x_, q_, s_):
        wd = quant.dequantize(q_, s_, dtype=jnp.bfloat16)
        return matmul(x_, wd, out_dtype=jnp.bfloat16)

    f_unfused = jax.jit(_unfused)
    t_b, t_f, t_u = _min_interleaved([
        lambda: f_bf16(x, wb),
        lambda: f_fused(x, wq, s),
        lambda: f_unfused(x, wq, s),
    ])
    # Tie rule: differences inside the noise floor collapse to the shared
    # min instead of minting a fake winner.
    floor = TIE_FRAC * min(t_b, t_f, t_u)
    if abs(t_f - t_u) < floor:
        t_f = t_u = min(t_f, t_u)
    if abs(t_f - t_b) < floor:
        t_f = min(t_f, t_b)
        t_b = t_f
    return (mm, kk, nn), t_b, t_f, t_u


def sweep(engine: str, max_elements: int, smoke: bool,
          out_path: pathlib.Path) -> dict:
    shapes = SMOKE_SHAPES if smoke else SHAPES
    rows = []
    for name, m, k, n in shapes:
        (mm, kk, nn), t_b, t_f, t_u = _shape_times(m, k, n, max_elements)
        rows.append({
            "name": name, "class": classify(mm, kk, nn).value,
            "m": mm, "k": kk, "n": nn,
            "weight_mib_bf16": round(kk * nn * 2 / 2**20, 3),
            "t_bf16_us": round(t_b * 1e6, 3),
            "t_w8_fused_us": round(t_f * 1e6, 3),
            "t_w8_unfused_us": round(t_u * 1e6, 3),
            "w8_speedup": round(t_b / max(t_f, 1e-12), 4),
            "fused_speedup": round(t_u / max(t_f, 1e-12), 4),
        })
        print(f"{name} ({mm}x{kk}x{nn}): bf16={t_b*1e6:.0f}us "
              f"w8_fused={t_f*1e6:.0f}us w8_unfused={t_u*1e6:.0f}us "
              f"(x{rows[-1]['w8_speedup']:.3f} vs bf16)")

    t2 = [r for r in rows if r["name"].startswith("t2_")]
    decode_ok = bool(t2) and all(
        r["t_w8_fused_us"] <= r["t_bf16_us"] for r in t2)
    fused_ok = all(r["t_w8_fused_us"] <= r["t_w8_unfused_us"] for r in rows)
    payload = _load_or_new(out_path)
    payload.update({
        "config": {"engine": engine, "max_elements": max_elements,
                   "budget_s": BUDGET_S, "tie_frac": TIE_FRAC,
                   "device_kind": plan_store.device_kind(),
                   "backend": jax.default_backend(),
                   "jax": jax.__version__},
        "shapes": rows,
    })
    payload.setdefault("runs", []).append({
        "date": time.strftime("%Y-%m-%d"),
        "engine": engine, "n_shapes": len(rows),
        "device_kind": plan_store.device_kind(),
        "w8_beats_bf16_decode": decode_ok,
        "fused_never_slower": fused_ok,
        "geomean_w8_speedup_t2": _geomean([r["w8_speedup"] for r in t2]),
        "geomean_fused_speedup": _geomean(
            [r["fused_speedup"] for r in rows]),
    })
    out_path.parent.mkdir(exist_ok=True)
    with open(out_path, "w") as fp:
        json.dump(payload, fp, indent=1)
    print(f"wrote {out_path} ({len(rows)} shapes); "
          f"w8_beats_bf16_decode={decode_ok} fused_never_slower={fused_ok}")
    return payload


def _geomean(xs) -> float:
    import math
    if not xs:
        return 1.0
    return round(math.exp(sum(math.log(max(x, 1e-12)) for x in xs)
                          / len(xs)), 4)


def _load_or_new(out_path: pathlib.Path) -> dict:
    if out_path.exists():
        try:
            with open(out_path) as fp:
                payload = json.load(fp)
            if isinstance(payload, dict) and payload.get("bench") == "quant":
                return payload
        except (OSError, ValueError):
            pass
    return {"bench": "quant", "schema": 1,
            "created": time.strftime("%Y-%m-%d")}


def run() -> None:
    """The ``benchmarks/run.py --only quant`` leg: record each shape in the
    common CSV."""
    from .common import record

    payload = sweep(autotune.default_engine(), max_elements=1 << 22,
                    smoke=False, out_path=DEFAULT_OUT)
    for r in payload["shapes"]:
        record(f"quant_{r['name']}", r["t_w8_fused_us"],
               f"w8_x{r['w8_speedup']};fused_x{r['fused_speedup']}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, *_smoke output — the CI leg")
    ap.add_argument("--engine", default=None,
                    choices=["xla", "pallas", "pallas_interpret"])
    ap.add_argument("--max-elements", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    engine = args.engine or autotune.default_engine()
    max_elements = args.max_elements or (1 << 16 if args.smoke else 1 << 22)
    out = pathlib.Path(args.out) if args.out else (
        RESULTS / "BENCH_quant_smoke.json" if args.smoke else DEFAULT_OUT)
    sweep(engine, max_elements, args.smoke, out)


if __name__ == "__main__":
    main()
