"""Paper Fig. 3 — micro-kernel performance sweep.

Paper setup: auto-generated micro-kernels, K in {512, 32}, N in {96, 64, 32},
sweeping M; y-axis = fraction of single-core peak.  Paper's upper bounds on
FT-m7032: ~100 % for 32 < N <= 96 (broadcast fills 3 FMACs), 66.7 % for
N <= 32.  TPU analogue: the MXU lane bound (N/128) caps small-N kernels; the
K and M stream terms shave the rest.

``us_per_call``: measured interpret-mode Pallas kernel wall time at the
given (M, K, N) — validates the kernel executes; interpret speed is NOT a
TPU metric.  ``derived``: modeled utilization fraction (ours) alongside the
paper's broadcast-bound for the same N.
"""
from __future__ import annotations

import functools

from repro.core.gemm import plan_gemm, upper_bound_fraction
from repro.core.gemm.cmr import TPU_V5E
from repro.kernels.ftimm import gemm

from .common import rand, record, time_fn


def paper_bound(n: int) -> float:
    return 1.0 if n > 32 else 0.667


def run() -> None:
    for k in (512, 32):
        for n in (96, 64, 32):
            for m in (6, 12, 24, 48, 96):
                plan = plan_gemm(m, k, n)
                eff = plan.est.flops_useful / max(
                    plan.est.t_total * TPU_V5E.peak_flops_fp32, 1e-30)
                bound = upper_bound_fraction(m, n, k)
                fn = functools.partial(
                    gemm, interpret=True, **plan.kernel_kwargs())
                us = time_fn(fn, rand((m, k)), rand((k, n), seed=1),
                             warmup=1, iters=2)
                record(
                    f"fig3_microkernel_M{m}_K{k}_N{n}", us,
                    f"modeled_eff={eff:.3f};tpu_bound={bound:.3f};"
                    f"paper_bound={paper_bound(n):.3f}")
