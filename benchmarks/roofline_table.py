"""Aggregate results/dryrun/*.json into the §Roofline table (markdown + CSV)
and emit one CSV row per cell for benchmarks.run."""
from __future__ import annotations

import json
import pathlib

from .common import record

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(mesh: str = "pod16x16", variant: str = "baseline") -> list[dict]:
    cells = []
    for p in sorted(RESULTS.glob(f"*__{mesh}__{variant}.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def markdown_table(mesh: str = "pod16x16", variant: str = "baseline") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | bound | "
            "useful frac | roofline frac | mem/dev GiB |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in load_cells(mesh, variant):
        if c["status"] == "skipped":
            arch, shape = c["cell"].split("__")[:2]
            rows.append(f"| {arch} | {shape} | — | — | — | skipped | — | — | — |")
            continue
        if c["status"] != "ok":
            continue
        r = c["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.2e} | "
            f"{r['t_memory']:.2e} | {r['t_collective']:.2e} | "
            f"**{r['dominant']}** | {r['useful_fraction']:.2f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{c['memory']['peak_memory'] / 2**30:.2f} |")
    return "\n".join(rows)


def run() -> None:
    for c in load_cells():
        if c["status"] != "ok":
            continue
        r = c["roofline"]
        record(f"roofline_{r['arch']}_{r['shape']}",
               r["t_bound"] * 1e6,
               f"dominant={r['dominant']};"
               f"roofline_frac={r['roofline_fraction']:.3f};"
               f"useful_frac={r['useful_fraction']:.2f}")


if __name__ == "__main__":
    print(markdown_table())
