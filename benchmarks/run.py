"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (and writes results/benchmarks.csv).

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig5] [--gate]

``--gate`` turns the run into a perf regression check.  The committed
result files are read BEFORE the suites execute, then the rerun must hold
every ratchet — exit code 1 otherwise:

  * ``moe_ep``: the fresh ``ep_ragged`` wall time stays within a noise
    margin (1.30x) of the committed ``BENCH_moe_ep.json`` baseline — the
    tripwire for the EP slowdown class of bug.
  * ``irregular``: the fresh ``geomean_analytic_over_cached`` stays within
    1.05x of the committed ratio — cached (measured) plans must keep at
    least matching the analytic argmin, so a planner/store regression that
    silently degrades replayed winners fails the build.
  * ``epilogue``: the fresh run keeps ``fused_never_slower`` and
    ``masked_never_slower`` true and its ``geomean_masked_speedup`` within
    1.05x of the committed one — the zero-copy edge and fusion wins are
    load-bearing paper claims, not one-off measurements.
  * ``quant``: the fresh run keeps ``w8_beats_bf16_decode`` and
    ``fused_never_slower`` true — the weight-only int8 decode win.
  * ``serve``: the fresh run keeps ``overload_sheds``, ``all_terminal``
    and ``p99_within_2x`` true, and the admitted 1x p99 stays within the
    1.30x wall-clock margin of the committed baseline — overload safety
    and tail latency are contract, not best-effort.

Geomeans over whole shape sweeps are far less noisy than single wall
times, hence the tighter 1.05x margin on the ratio ratchets.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from . import (autotune, collective, common, cpu_compare,  # noqa: E402
               epilogue, microkernel, moe_ep, multi_core, quant,
               roofline_table, scalability, serve, single_core)

SUITES = {
    "fig3": microkernel.run,
    "fig4": single_core.run,
    "fig5": multi_core.run,
    "fig6": scalability.run,
    "fig7": cpu_compare.run,
    "roofline": roofline_table.run,
    "moe_ep": moe_ep.run,
    # Replays the T1/T2/T3 sweep from the committed plan cache (no search)
    # and appends a run record to results/BENCH_irregular.json.
    "irregular": autotune.run,
    # Fused-vs-unfused epilogue + masked-vs-padded edge sweep
    # (results/BENCH_epilogue.json).
    "epilogue": epilogue.run,
    # Overlapped ring vs gather collective schedules, end-to-end on 8 fake
    # devices + ICI calibration + EP crossover agreement
    # (results/BENCH_collective.json).
    "collective": collective.run,
    # Weight-only int8 decode GEMMs vs the bf16 baseline, fused vs unfused
    # dequant, on the T2/T3 paper shapes (results/BENCH_quant.json).
    "quant": quant.run,
    # Open-loop overload sweep through the serving engine at 0.5x/1x/2x of
    # measured capacity (results/BENCH_serve.json).
    "serve": serve.run,
}

GATE_MARGIN = 1.30      # wall-clock noise allowance for the EP gate
RATCHET_MARGIN = 1.05   # sweep-geomean allowance (averages: low noise)
GATED = ["moe_ep", "irregular", "epilogue", "quant", "serve"]
_RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"


def _ep_ragged_us(path: pathlib.Path) -> float | None:
    """The ``ep_ragged`` wall time recorded in a BENCH_moe_ep.json file,
    or None when the file / leg is missing or errored (us == 0)."""
    try:
        with open(path) as fp:
            blob = json.load(fp)
        for row in blob.get("rows", []):
            if row.get("name") == "ep_ragged" and row.get("us_per_call"):
                return float(row["us_per_call"])
    except (OSError, ValueError, TypeError):
        pass
    return None


def _last_run(path: pathlib.Path) -> dict:
    """The newest run record of a sweep-style result file (irregular /
    epilogue / quant all append one per replay), or {} when missing."""
    try:
        with open(path) as fp:
            blob = json.load(fp)
        runs = blob.get("runs") or []
        return runs[-1] if isinstance(runs[-1], dict) else {}
    except (OSError, ValueError, TypeError, IndexError):
        return {}


def _gate_failures(baselines: dict) -> list[str]:
    """Evaluate every ratchet against the freshly rewritten result files;
    returns the failure messages (empty == gate holds)."""
    fails: list[str] = []

    fresh_ep = _ep_ragged_us(_RESULTS / "BENCH_moe_ep.json")
    if fresh_ep is None:
        fails.append("moe_ep: ep_ragged leg missing or errored")
    elif baselines["ep"] is not None and \
            fresh_ep > baselines["ep"] * GATE_MARGIN:
        fails.append(f"moe_ep: ep_ragged regressed {fresh_ep:.0f}us > "
                     f"{GATE_MARGIN}x baseline {baselines['ep']:.0f}us")

    irr = _last_run(_RESULTS / "BENCH_irregular.json")
    ratio = irr.get("geomean_analytic_over_cached")
    base = baselines["irregular"]
    if ratio is None:
        fails.append("irregular: no run record")
    elif base is not None and ratio < base / RATCHET_MARGIN:
        fails.append(f"irregular: geomean_analytic_over_cached {ratio:.4f}"
                     f" < baseline {base:.4f} / {RATCHET_MARGIN}")

    epi = _last_run(_RESULTS / "BENCH_epilogue.json")
    for flag in ("fused_never_slower", "masked_never_slower"):
        if not epi.get(flag):
            fails.append(f"epilogue: {flag} is false")
    masked = epi.get("geomean_masked_speedup")
    base = baselines["epilogue"]
    if masked is not None and base is not None and \
            masked < base / RATCHET_MARGIN:
        fails.append(f"epilogue: geomean_masked_speedup {masked:.4f} < "
                     f"baseline {base:.4f} / {RATCHET_MARGIN}")

    qrun = _last_run(_RESULTS / "BENCH_quant.json")
    for flag in ("w8_beats_bf16_decode", "fused_never_slower"):
        if not qrun.get(flag):
            fails.append(f"quant: {flag} is false")

    srun = _last_run(_RESULTS / "BENCH_serve.json")
    for flag in ("overload_sheds", "all_terminal", "p99_within_2x"):
        if not srun.get(flag):
            fails.append(f"serve: {flag} is false")
    p99 = srun.get("admitted_p99_1x_s")
    base = baselines["serve"]
    if p99 is None:
        fails.append("serve: no admitted_p99_1x_s in run record")
    elif base is not None and p99 > base * GATE_MARGIN:
        fails.append(f"serve: admitted p99 at 1x regressed {p99:.3f}s > "
                     f"{GATE_MARGIN}x baseline {base:.3f}s")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names " + str(list(SUITES)))
    ap.add_argument("--gate", action="store_true",
                    help="rerun the gated legs " + str(GATED) + " and fail "
                         "(exit 1) on any ratchet regression vs the "
                         "committed result files")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    if args.gate:
        names += [g for g in GATED if g not in names]
        # Fail up front with a clear message when a committed baseline file
        # is absent — the helpers below return None/{} for unreadable files
        # (a deliberate grace for partially-populated result dirs), which
        # would otherwise run the whole gate and pass vacuously.
        _BASE_FILES = {"moe_ep": "BENCH_moe_ep.json",
                       "irregular": "BENCH_irregular.json",
                       "epilogue": "BENCH_epilogue.json",
                       "quant": "BENCH_quant.json",
                       "serve": "BENCH_serve.json"}
        missing = [f for f in _BASE_FILES.values()
                   if not (_RESULTS / f).exists()]
        if missing:
            raise SystemExit(
                "gate: missing committed baseline file(s): "
                + ", ".join(str(_RESULTS / f) for f in missing)
                + " — run the gated suites once without --gate and commit "
                  "the result files to establish baselines")
        baselines = {
            "ep": _ep_ragged_us(_RESULTS / "BENCH_moe_ep.json"),
            "irregular": _last_run(_RESULTS / "BENCH_irregular.json")
            .get("geomean_analytic_over_cached"),
            "epilogue": _last_run(_RESULTS / "BENCH_epilogue.json")
            .get("geomean_masked_speedup"),
            "serve": _last_run(_RESULTS / "BENCH_serve.json")
            .get("admitted_p99_1x_s"),
        }
    print("name,us_per_call,derived")
    for name in names:
        SUITES[name]()
    _RESULTS.mkdir(exist_ok=True)
    common.dump_csv(str(_RESULTS / "benchmarks.csv"))
    if args.gate:
        fails = _gate_failures(baselines)
        for msg in fails:
            print(f"gate: {msg}", file=sys.stderr)
        if fails:
            raise SystemExit(1)
        print(f"gate: all ratchets hold ({', '.join(GATED)})")


if __name__ == "__main__":
    main()
