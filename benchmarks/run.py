"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (and writes results/benchmarks.csv).

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig5]
"""
from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from . import (autotune, common, cpu_compare, epilogue,  # noqa: E402
               microkernel, moe_ep, multi_core, roofline_table, scalability,
               single_core)

SUITES = {
    "fig3": microkernel.run,
    "fig4": single_core.run,
    "fig5": multi_core.run,
    "fig6": scalability.run,
    "fig7": cpu_compare.run,
    "roofline": roofline_table.run,
    "moe_ep": moe_ep.run,
    # Replays the T1/T2/T3 sweep from the committed plan cache (no search)
    # and appends a run record to results/BENCH_irregular.json.
    "irregular": autotune.run,
    # Fused-vs-unfused epilogue + masked-vs-padded edge sweep
    # (results/BENCH_epilogue.json).
    "epilogue": epilogue.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names " + str(list(SUITES)))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    for name in names:
        SUITES[name]()
    out = pathlib.Path(__file__).resolve().parents[1] / "results"
    out.mkdir(exist_ok=True)
    common.dump_csv(str(out / "benchmarks.csv"))


if __name__ == "__main__":
    main()
