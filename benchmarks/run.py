"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (and writes results/benchmarks.csv).

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig5] [--gate]

``--gate`` turns the run into a perf regression check: the committed
``results/BENCH_moe_ep.json`` is read BEFORE the suites execute, and after
the rerun the fresh ``ep_ragged`` wall time must stay within a noise
margin (1.30x) of that baseline — exit code 1 otherwise.  This is the CI
tripwire for the EP slowdown class of bug: the committed file holds the
last accepted number, so a schedule or exchange regression that re-inflates
the EP leg fails the build instead of silently landing.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from . import (autotune, collective, common, cpu_compare,  # noqa: E402
               epilogue, microkernel, moe_ep, multi_core, roofline_table,
               scalability, single_core)

SUITES = {
    "fig3": microkernel.run,
    "fig4": single_core.run,
    "fig5": multi_core.run,
    "fig6": scalability.run,
    "fig7": cpu_compare.run,
    "roofline": roofline_table.run,
    "moe_ep": moe_ep.run,
    # Replays the T1/T2/T3 sweep from the committed plan cache (no search)
    # and appends a run record to results/BENCH_irregular.json.
    "irregular": autotune.run,
    # Fused-vs-unfused epilogue + masked-vs-padded edge sweep
    # (results/BENCH_epilogue.json).
    "epilogue": epilogue.run,
    # Overlapped ring vs gather collective schedules, end-to-end on 8 fake
    # devices + ICI calibration + EP crossover agreement
    # (results/BENCH_collective.json).
    "collective": collective.run,
}

GATE_MARGIN = 1.30      # wall-clock noise allowance for the EP gate
_RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"


def _ep_ragged_us(path: pathlib.Path) -> float | None:
    """The ``ep_ragged`` wall time recorded in a BENCH_moe_ep.json file,
    or None when the file / leg is missing or errored (us == 0)."""
    try:
        with open(path) as fp:
            blob = json.load(fp)
        for row in blob.get("rows", []):
            if row.get("name") == "ep_ragged" and row.get("us_per_call"):
                return float(row["us_per_call"])
    except (OSError, ValueError, TypeError):
        pass
    return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names " + str(list(SUITES)))
    ap.add_argument("--gate", action="store_true",
                    help="fail (exit 1) if the rerun ep_ragged leg "
                         f"regresses beyond {GATE_MARGIN}x the committed "
                         "BENCH_moe_ep.json baseline")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    if args.gate and "moe_ep" not in names:
        names.append("moe_ep")
    baseline = _ep_ragged_us(_RESULTS / "BENCH_moe_ep.json") \
        if args.gate else None
    print("name,us_per_call,derived")
    for name in names:
        SUITES[name]()
    _RESULTS.mkdir(exist_ok=True)
    common.dump_csv(str(_RESULTS / "benchmarks.csv"))
    if args.gate:
        fresh = _ep_ragged_us(_RESULTS / "BENCH_moe_ep.json")
        if fresh is None:
            print("gate: ep_ragged leg missing or errored", file=sys.stderr)
            raise SystemExit(1)
        if baseline is not None and fresh > baseline * GATE_MARGIN:
            print(f"gate: ep_ragged regressed {fresh:.0f}us > "
                  f"{GATE_MARGIN}x baseline {baseline:.0f}us",
                  file=sys.stderr)
            raise SystemExit(1)
        ref = f"{baseline:.0f}us" if baseline is not None else "none"
        print(f"gate: ep_ragged {fresh:.0f}us within {GATE_MARGIN}x of "
              f"baseline {ref}")


if __name__ == "__main__":
    main()
