"""Paper Fig. 4 — single-core irregular GEMM: ftIMM vs TGEMM.

Paper: ftIMM beats the fixed-blocking TGEMM on all three irregular types (up
to 2.0x at M=N=K=20480x32x20480 single-core ... figure peaks ~2x); the win
comes from shape-adapted blocks (no implicit N-padding, bigger K blocks).

``us_per_call``: measured XLA-CPU GEMM wall time (the runnable path).
``derived``: modeled TPU time ratio TGEMM/ftIMM (the figure's speedup) and
both modeled times.
"""
from __future__ import annotations

from repro.core.gemm import matmul, plan_gemm, tgemm_plan

from .common import rand, record, time_fn

CASES = [
    # (name, M, K, N)  — paper's three types
    ("t1_tall_small", 2**20, 32, 32),
    ("t1_tall_small_k64", 2**20, 64, 64),
    ("t2_skinny_tall", 32, 2**20, 32),
    ("t2_skinny_tall_n64", 64, 2**20, 64),
    ("t3_regular_tall", 20480, 20480, 32),
    ("t3_regular_tall_n96", 20480, 20480, 96),
    ("regular_control", 4096, 4096, 4096),
]


def run() -> None:
    for name, m, k, n in CASES:
        ours = plan_gemm(m, k, n)
        fixed = tgemm_plan(m, k, n)
        speedup = fixed.est.t_total / ours.est.t_total
        # measured: run the XLA path at a memory-safe scale factor
        scale = max(1, (m * k + k * n) // (2**24))
        mm, kk = max(m // scale, 8), k
        us = time_fn(lambda a, b: matmul(a, b, backend="xla"),
                     rand((mm, kk)), rand((kk, n), seed=1))
        record(f"fig4_single_core_{name}", us,
               f"modeled_speedup_vs_tgemm={speedup:.2f};"
               f"ftimm_t={ours.est.t_total:.3e}s;"
               f"tgemm_t={fixed.est.t_total:.3e}s;"
               f"class={ours.gemm_class.value}")
