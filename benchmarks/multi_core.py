"""Paper Fig. 5 — multi-core irregular GEMMs with strategy selection.

Paper: on 8 DSP cores, ftIMM (adaptive strategy + blocks) vs TGEMM
(N-dimension parallelization only) — up to 4.2x (T1), 5.8x (T2), 7.2x (T3),
and ~67 % of the cluster roofline on bandwidth-bound cases.

TPU analogue: 8 "cores" = 8 chips on one ICI ring.  TGEMM-baseline = fixed
blocks + N-parallel only (N <= 96 cannot occupy 8 chips: modeled as
ceil(N/128)=1 chip active).  ftIMM = CMR-chosen M-/K-parallel.

``us_per_call``: measured XLA wall time of the 8-way shard_map dist_matmul
at reduced scale (runnable path, 8 fake devices only when available — falls
back to single-device measure).  ``derived``: modeled speedup + roofline %.
"""
from __future__ import annotations

from repro.core.gemm import plan_distributed, tgemm_plan, matmul
from repro.core.gemm.cmr import TPU_V5E

from .common import rand, record, time_fn

N_CORES = 8

CASES = [
    ("t1_M2^16", 2**16, 32, 32),
    ("t1_M2^20", 2**20, 32, 32),
    ("t1_M2^22", 2**22, 32, 32),
    ("t2_K2^16", 32, 2**16, 32),
    ("t2_K2^20", 32, 2**20, 32),
    ("t3_20480", 20480, 20480, 32),
    ("t3_16384", 16384, 16384, 64),
]


def _tgemm_multicore_time(m: int, k: int, n: int) -> float:
    """TGEMM parallelizes only over N (paper Alg. 1 line 5): with N <= 96
    only one lane-tile of work exists -> 1 active chip."""
    active = max(1, -(-n // 128))
    active = min(active, N_CORES)
    fixed = tgemm_plan(m, k, n)
    return fixed.est.t_total / active


def run() -> None:
    for name, m, k, n in CASES:
        dist = plan_distributed(m, k, n, N_CORES)
        t_ft = dist.t_total
        t_tg = _tgemm_multicore_time(m, k, n)
        # roofline: bandwidth bound for the aggregate shape
        flops = 2.0 * m * k * n
        bytes_min = 4.0 * (m * k + k * n + m * n)
        t_roof = max(flops / (N_CORES * TPU_V5E.peak_flops_fp32),
                     bytes_min / (N_CORES * TPU_V5E.hbm_bw))
        roof_frac = t_roof / t_ft
        scale = max(1, (m * k + k * n) // (2**24))
        us = time_fn(lambda a, b: matmul(a, b, backend="xla"),
                     rand((max(m // scale, 8), min(k, 2**16))),
                     rand((min(k, 2**16), n), seed=1))
        record(f"fig5_multicore_{name}", us,
               f"modeled_speedup_vs_tgemm={t_tg / t_ft:.2f};"
               f"strategy={dist.strategy};roofline_frac={roof_frac:.3f}")
