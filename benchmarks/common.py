"""Shared benchmark utilities: wall-clock timing of the runnable (XLA-CPU)
path + modeled TPU metrics from the CMR/roofline machinery.

This container has no TPU, so each benchmark reports BOTH:
  * ``us_per_call`` — measured wall time of the executable CPU path (jitted
    XLA GEMM / interpret-mode kernel at reduced size where noted), and
  * ``derived``     — the modeled TPU-v5e quantity the paper's figure
    plots (efficiency %, speedup x, GFlops), from the same planner models
    the dry-run validates.
"""
from __future__ import annotations

import csv
import time

import jax
import jax.numpy as jnp

ROWS: list[tuple] = []


def record(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, round(us_per_call, 2), derived))
    print(f"{name},{round(us_per_call, 2)},{derived}")


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def rand(shape, dtype=jnp.float32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


def dump_csv(path: str) -> None:
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["name", "us_per_call", "derived"])
        w.writerows(ROWS)
