"""Paper Fig. 6 — scalability of ftIMM 1 -> 8 cores on the three 20480-sized
irregular GEMMs.  Paper finding: sub-linear scaling (memory-bound), and the
K-parallel case (T2/T3 with N=32) scales worst because reduction overhead
grows with cores.

``derived``: modeled speedup at each core count (the figure's y-axis)."""
from __future__ import annotations

from repro.core.gemm import plan_distributed, plan_gemm

from .common import record

CASES = [
    ("t1_20480x32x32", 20480 * 32, 32, 32),      # tall-skinny x small
    ("t2_32x20480_ish", 32, 20480 * 32, 32),     # skinny-tall
    ("t3_20480x20480x32", 20480, 20480, 32),
]


def run() -> None:
    for name, m, k, n in CASES:
        t1 = plan_gemm(m, k, n).est.t_total
        for cores in (1, 2, 4, 8):
            if cores == 1:
                speed, strat = 1.0, "single"
            else:
                d = plan_distributed(m, k, n, cores)
                speed, strat = t1 / d.t_total, d.strategy
            record(f"fig6_scalability_{name}_c{cores}", 0.0,
                   f"modeled_speedup={speed:.2f};strategy={strat}")
