"""Fused-epilogue / zero-copy-edge benchmark leg.

    PYTHONPATH=src python -m benchmarks.epilogue [--smoke] [--engine ...]
    PYTHONPATH=src python -m benchmarks.run --only epilogue

Two sweeps over the paper's T1/T2/T3 irregular shapes plus the registry
models' MLP projections, on the measured-autotuning harness's scaled
problems (jit + block_until_ready, median of repeats):

  * **fused vs unfused** — the model-layer elementwise tail (silu +
    residual add, the MLP gate / down-proj epilogue) as ONE pass over the
    output vs one separate compiled pass PER op.  The GEMM itself is shared
    (identical computation for both candidates), so it is timed once and
    the tail variants are timed on its stored output — the per-shape
    difference then isolates the pass-count mechanism instead of drowning
    in multi-ms GEMM jitter.  On the TPU kernels the fused tail costs ZERO
    extra passes (it rides the accumulator flush); the one-pass fused
    timing here is the CPU upper bound of that.
  * **masked vs padded** — the zero-copy in-kernel edge-tile policy vs the
    legacy pad -> kernel -> slice wrapper on the same blocking, timed
    end-to-end through ``autotune.time_dense_plans`` (the pad copies and
    the enlarged padded GEMM are the difference being measured).

Writes ``results/BENCH_epilogue.json`` (``*_smoke`` under ``--smoke``, the
CI leg) recording per shape both times and the speedups; a run record keeps
the trajectory across replays.  The committed baseline demonstrates
fused <= unfused and masked <= padded per shape on the same run.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
from dataclasses import replace

_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))

import jax  # noqa: E402

from repro.core.gemm import autotune, plan_store, tuner  # noqa: E402
from repro.core.gemm.shapes import classify  # noqa: E402
from repro.kernels.ftimm.epilogue import Epilogue  # noqa: E402

from .autotune import SMOKE_SHAPES, T_SHAPES, model_shapes  # noqa: E402

RESULTS = _ROOT / "results"
DEFAULT_OUT = RESULTS / "BENCH_epilogue.json"

# The model layers' tail: the MLP down projection's residual add plus the
# activation — two elementwise passes when unfused.
EPI = Epilogue(activation="silu", residual=True)


def _mlp_shapes():
    return [s for s in model_shapes() if s[0].endswith("_mlp")]


BUDGET_S = 3.0      # per-comparison interleaved-sampling wall-clock budget


def _min_interleaved(thunks, repeats: int) -> list[float]:
    """Per-thunk min over an interleaved sampling loop.

    The candidates being compared differ by a *deterministic* amount of
    work, so min is the right statistic under background load, and
    alternating them in one loop makes load drift hit both distributions
    equally instead of biasing whichever ran during a spike.  The sample
    count adapts to the thunks' cost under a fixed wall-clock budget."""
    import time

    warm = []
    for t in thunks:
        t0 = time.perf_counter()
        jax.block_until_ready(t())          # compile + warm
        jax.block_until_ready(t())
        warm.append(time.perf_counter() - t0)
    per_round = max(sum(warm) / 2.0, 1e-6)
    n = int(min(max(repeats * 20, 40), max(BUDGET_S / per_round, 8)))
    best = [float("inf")] * len(thunks)
    for _ in range(n):
        for i, t in enumerate(thunks):
            t0 = time.perf_counter()
            jax.block_until_ready(t())
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def _fusion_times(m: int, k: int, n: int, repeats: int,
                  max_elements: int) -> tuple[float, float, float]:
    """(t_gemm, t_tail_fused, t_tail_unfused) on the scaled problem.

    The GEMM is identical for both fusion candidates, so it is timed once;
    the tail variants (one combined pass vs one pass per op) are timed on
    its stored output.  Totals compose as t_gemm + tail."""
    import jax.numpy as jnp

    mm, kk, nn = autotune._scale_dense(m, k, n, max_elements)
    a = autotune._rand((mm, kk), jnp.float32)
    b = autotune._rand((kk, nn), jnp.float32, seed=1)
    gemm_fn = autotune._jit_dense_ref("float32")
    y = jax.block_until_ready(gemm_fn(a, b))
    bias, res = autotune._epi_operands(EPI, mm, nn, a.dtype)
    (t_gemm,) = _min_interleaved([lambda: gemm_fn(a, b)], repeats)

    def tail_run(passes):
        def run():
            out = y
            for p in passes:
                out = p(out, bias, res)
            return out
        return run

    one = autotune._tail_passes(EPI, jnp.float32, True)
    per = autotune._tail_passes(EPI, jnp.float32, False)
    t_tail_f, t_tail_u = _min_interleaved(
        [tail_run(one), tail_run(per)], repeats)
    # Tiny-output shapes (T2: M, N ~ 32..128) have ~10us tails; when the two
    # candidates land within timer resolution of each other they are
    # indistinguishable and recorded as a tie (the shared min) rather than
    # pretending sub-microsecond precision.
    if abs(t_tail_f - t_tail_u) < 2e-6:
        t_tail_f = t_tail_u = min(t_tail_f, t_tail_u)
    return t_gemm, t_tail_f, t_tail_u


def _edge_times(m: int, k: int, n: int, base, repeats: int,
                max_elements: int, engine: str) -> tuple[float, float]:
    """(t_masked, t_padded) on the scaled problem via the autotune harness's
    runners, interleaved.  When the (clamped) blocking already divides the
    scaled shape the two candidates are physically identical — no pad, no
    slice, no in-kernel mask emitted — and one measurement serves both (the
    pallas runner signatures still differ, carrying ``edge``, so identity is
    decided from the alignment itself)."""
    import jax.numpy as jnp

    from repro.kernels.ftimm.ops import _clamp_blocks

    mm, kk, nn = autotune._scale_dense(m, k, n, max_elements)
    a = autotune._rand((mm, kk), jnp.float32)
    b = autotune._rand((kk, nn), jnp.float32, seed=1)
    _, thunk_m = autotune._dense_runner(
        engine, a, b, replace(base, edge="masked"), jnp.float32)
    _, thunk_p = autotune._dense_runner(
        engine, a, b, replace(base, edge="padded"), jnp.float32)
    bm, bn, bk, _ = _clamp_blocks(mm, kk, nn, base.bm, base.bn, base.bk,
                                  1, jnp.float32)
    if mm % bm == 0 and nn % bn == 0 and kk % bk == 0:
        (t,) = _min_interleaved([thunk_m], repeats)
        return t, t
    return tuple(_min_interleaved([thunk_m, thunk_p], repeats))


def sweep(engine: str, repeats: int, max_elements: int, smoke: bool,
          out_path: pathlib.Path) -> dict:
    shapes = SMOKE_SHAPES if smoke else T_SHAPES + _mlp_shapes()
    rows = []
    for name, m, k, n in shapes:
        base = tuner.argmin_plan(tuner.gemm_candidates(m, k, n))
        t_g, t_tf, t_tu = _fusion_times(m, k, n, repeats, max_elements)
        t_f, t_u = t_g + t_tf, t_g + t_tu
        t_m, t_p = _edge_times(m, k, n, base, repeats, max_elements, engine)
        rows.append({
            "name": name, "class": classify(m, k, n).value,
            "m": m, "k": k, "n": n,
            "plan": {"bm": base.bm, "bn": base.bn, "bk": base.bk,
                     "dim_order": base.dim_order},
            "t_gemm_us": round(t_g * 1e6, 3),
            "t_tail_fused_us": round(t_tf * 1e6, 3),
            "t_tail_unfused_us": round(t_tu * 1e6, 3),
            "t_fused_us": round(t_f * 1e6, 3),
            "t_unfused_us": round(t_u * 1e6, 3),
            "fused_speedup": round(t_u / max(t_f, 1e-12), 4),
            "t_masked_us": round(t_m * 1e6, 3),
            "t_padded_us": round(t_p * 1e6, 3),
            "masked_speedup": round(t_p / max(t_m, 1e-12), 4),
        })
        print(f"{name}: fused={t_f*1e6:.1f}us unfused={t_u*1e6:.1f}us "
              f"(x{rows[-1]['fused_speedup']:.2f}); "
              f"masked={t_m*1e6:.1f}us padded={t_p*1e6:.1f}us "
              f"(x{rows[-1]['masked_speedup']:.2f})")

    fused_ok = all(r["t_fused_us"] <= r["t_unfused_us"] for r in rows)
    masked_ok = all(r["t_masked_us"] <= r["t_padded_us"] for r in rows)
    payload = _load_or_new(out_path)
    payload.update({
        "config": {"engine": engine, "repeats": repeats,
                   "max_elements": max_elements,
                   "epilogue": {"activation": EPI.activation,
                                "residual": EPI.residual},
                   "device_kind": plan_store.device_kind(),
                   "backend": jax.default_backend(),
                   "jax": jax.__version__},
        "shapes": rows,
    })
    payload.setdefault("runs", []).append({
        "date": time.strftime("%Y-%m-%d"),
        "engine": engine, "n_shapes": len(rows),
        "device_kind": plan_store.device_kind(),
        "fused_never_slower": fused_ok,
        "masked_never_slower": masked_ok,
        "geomean_fused_speedup": _geomean([r["fused_speedup"] for r in rows]),
        "geomean_masked_speedup": _geomean(
            [r["masked_speedup"] for r in rows]),
    })
    out_path.parent.mkdir(exist_ok=True)
    with open(out_path, "w") as fp:
        json.dump(payload, fp, indent=1)
    print(f"wrote {out_path} ({len(rows)} shapes); "
          f"fused_never_slower={fused_ok} masked_never_slower={masked_ok}")
    return payload


def _geomean(xs) -> float:
    import math
    if not xs:
        return 1.0
    return round(math.exp(sum(math.log(max(x, 1e-12)) for x in xs)
                          / len(xs)), 4)


def _load_or_new(out_path: pathlib.Path) -> dict:
    if out_path.exists():
        try:
            with open(out_path) as fp:
                payload = json.load(fp)
            if isinstance(payload, dict) and payload.get("bench") == \
                    "epilogue":
                return payload
        except (OSError, ValueError):
            pass
    return {"bench": "epilogue", "schema": 1,
            "created": time.strftime("%Y-%m-%d")}


def run() -> None:
    """The ``benchmarks/run.py --only epilogue`` leg: re-run the sweep with
    the defaults and record each shape in the common CSV."""
    from .common import record

    payload = sweep(autotune.default_engine(), repeats=3,
                    max_elements=1 << 20, smoke=False, out_path=DEFAULT_OUT)
    for r in payload["shapes"]:
        record(f"epilogue_{r['name']}", r["t_fused_us"],
               f"fused_x{r['fused_speedup']};masked_x{r['masked_speedup']}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 repeat, *_smoke output — the CI leg")
    ap.add_argument("--engine", default=None,
                    choices=["xla", "pallas", "pallas_interpret"])
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--max-elements", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke:
        engine = args.engine or autotune.default_engine()
        repeats = args.repeats or 1
        max_elements = args.max_elements or (1 << 16)
        out = pathlib.Path(args.out or RESULTS / "BENCH_epilogue_smoke.json")
    else:
        engine = args.engine or autotune.default_engine()
        repeats = args.repeats or 5
        max_elements = args.max_elements or (1 << 20)
        out = pathlib.Path(args.out or DEFAULT_OUT)
    sweep(engine, repeats, max_elements, args.smoke, out)


if __name__ == "__main__":
    main()
