"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b-smoke \
        --steps 20 [--seq 128 --batch 8] [--mesh 2x4] [--ckpt /tmp/ck] \
        [--elastic [--model-parallel 1]]

On real hardware the same entry runs under ``jax.distributed.initialize``
(multi-host); in this container a ``--mesh AxB`` spawns that many host
devices (set before jax import via XLA_FLAGS).

``--elastic`` runs under ``runtime.elastic.ElasticRunner`` instead of a
bare ``Trainer``: a ``HostFailure`` mid-run (real, or injected with
``REPRO_CHAOS="shard_loss@N:chips=K"``) shrinks the mesh to the
survivors, re-plans the placed GEMMs, restores the latest checkpoint and
resumes with deterministic data replay.  Requires ``--ckpt``."""
from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 = (data, model)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--elastic", action="store_true",
                    help="recover from HostFailure by re-meshing onto the "
                         "survivors (checkpoint-restart; needs --ckpt)")
    ap.add_argument("--model-parallel", type=int, default=None,
                    help="TP degree preserved across elastic re-meshes "
                         "(default: the model axis of --mesh, else 1)")
    args = ap.parse_args()

    if args.mesh:
        n = 1
        for part in args.mesh.split("x"):
            n *= int(part)
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={n}")

    import jax

    from ..configs import get_config
    from ..configs.base import ShapeConfig
    from ..optim.adamw import OptConfig
    from ..train.trainer import Trainer
    from .mesh import make_mesh
    from .sharding import (batch_specs, param_specs, to_shardings)
    from .dryrun import abstract_state, input_specs

    cfg = get_config(args.arch)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")

    if args.elastic:
        from ..runtime.elastic import ElasticRunner
        dims = tuple(int(x) for x in args.mesh.split("x")) if args.mesh \
            else (len(jax.devices()),)
        tp = args.model_parallel or (dims[1] if len(dims) == 2 else 1)
        opt_cfg = OptConfig(lr=args.lr,
                            warmup_steps=min(100, args.steps // 10 + 1),
                            total_steps=args.steps)
        runner = ElasticRunner(cfg, shape, opt_cfg, ckpt_dir=args.ckpt,
                               model_parallel=tp, seed=args.seed)
        runner.run(args.steps)
        for h in runner.history:
            print("elastic:", h)
        print("training done")
        return

    mesh = None
    shardings = {}
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(dims)] if len(dims) == 2 else ("data",)
        mesh = make_mesh(dims, axes)
        params_s, opt_s = abstract_state(cfg, shape, with_opt=True)
        batch_s = input_specs(cfg, shape)
        with mesh:
            shardings = {
                "params": to_shardings(param_specs(params_s, mesh), mesh),
                "opt": to_shardings(param_specs(opt_s, mesh), mesh),
                "batch": to_shardings(batch_specs(cfg, batch_s, mesh), mesh),
            }
            shardings["batch_leaves"] = shardings["batch"]

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                        total_steps=args.steps)
    trainer = Trainer(cfg, shape, opt_cfg, mesh=mesh, shardings=shardings,
                      seed=args.seed, ckpt_dir=args.ckpt)
    trainer.run(args.steps)
    print("training done")


if __name__ == "__main__":
    main()
