"""Sharding rules: param / batch / cache PartitionSpecs for the production mesh.

2-D strategy (DESIGN.md §6):
  * TP over ``model`` on heads/d_ff/vocab/expert-ffn dims,
  * ZeRO-3/FSDP over the data axes (``data`` or ``(pod, data)``) on the
    opposite dim — params are all-gathered at use, gradients reduce-scattered
    (XLA GSPMD inserts the collectives; they land in the roofline's
    collective term).
  * A dim is sharded only if divisible by the axis size, else replicated
    (e.g. whisper's tiny dims on a 16-way axis).

Cache sharding implements the paper's K-parallel layout: attention-cache
sequence dims are sharded over ``model`` so decode runs as flash-decode
(attention.flash_decode); SSM state shards its head dim.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

_REPLICATED = {
    "ln1", "ln2", "ln_cross", "ln", "norm", "final_norm", "enc_norm",
    "A_log", "D_skip", "dt_bias", "conv_b", "q_norm", "k_norm", "step",
}
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "patch_proj",
        "frame_proj"}            # (in=dp, out=model)
_ROW = {"wo", "w_down", "out_proj"}   # (in=model, out=dp)
_STACKED = {"layers", "encoder"}


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(math.prod(mesh.shape[a] for a in axes))


def expert_axis(mesh: Mesh, moe_ep: bool, moe_ep_axis: str = "dp",
                num_experts: int | None = None):
    """The concrete mesh axis (or axis tuple) that owns the MoE expert dim
    under expert parallelism, or None when EP is off / the axis is trivial /
    the expert count doesn't divide it.

    ``moe_ep_axis`` uses the same vocabulary as ``param_specs``: "dp" (the
    data axes) or a literal mesh axis name.  The result is what
    ``DistContext.moe_ep_axis`` carries so the ragged MoE dispatch can bind
    its ``ep_ragged_*`` executors to the same axis the weights are sharded
    on.  Pass ``num_experts`` so the divisibility rule the executors apply
    is decided HERE, once — a caller that prices EP (dryrun's ``ep_shards``)
    and the model code that executes it then can never disagree."""
    if not moe_ep:
        return None
    if moe_ep_axis == "dp":
        axes = dp_axes(mesh)
    elif moe_ep_axis in mesh.axis_names:
        axes = (moe_ep_axis,)
    else:
        return None
    n = axis_size(mesh, axes) if axes else 1
    if n <= 1 or (num_experts is not None and num_experts % n):
        return None
    return axes if len(axes) > 1 else axes[0]


def _maybe(dim: int, axes, mesh: Mesh):
    """Shard ``dim`` over ``axes`` only when divisible."""
    if axes is None:
        return None
    n = axis_size(mesh, axes)
    return axes if (n > 1 and dim % n == 0) else None


def _leaf_spec(path_names: list[str], shape, mesh: Mesh) -> P:
    name = path_names[-1]
    stacked = int(any(p in _STACKED for p in path_names[:-1]))
    dims = shape[stacked:]
    dp = dp_axes(mesh)
    dp = dp if dp else None

    def spec(*parts):
        return P(*([None] * stacked), *parts)

    if name in _REPLICATED or len(dims) == 0:
        return spec(*([None] * len(dims)))
    if name == "embed":
        return P(_maybe(dims[0], "model", mesh), _maybe(dims[1], dp, mesh))
    if name == "router":
        return spec(_maybe(dims[0], dp, mesh), None)
    if name == "conv_w":
        return spec(None, _maybe(dims[1], "model", mesh))
    if name in _COL:
        if len(dims) == 3:     # moe experts (E, D, F)
            return spec(None, _maybe(dims[1], dp, mesh),
                        _maybe(dims[2], "model", mesh))
        return spec(_maybe(dims[0], dp, mesh), _maybe(dims[1], "model", mesh))
    if name in _ROW:
        if len(dims) == 3:     # moe experts (E, F, D)
            return spec(None, _maybe(dims[1], "model", mesh),
                        _maybe(dims[2], dp, mesh))
        return spec(_maybe(dims[0], "model", mesh), _maybe(dims[1], dp, mesh))
    # default: replicate
    return spec(*([None] * len(dims)))


def param_specs(params_shape, mesh: Mesh, *, zero_stage: int = 3,
                moe_ep: bool = False, moe_ep_axis: str = "dp"):
    """PartitionSpec tree matching a param (or optimizer-state) pytree.

    zero_stage: 3 -> weights 2-D sharded (TP x FSDP, all-gather at use);
                0/1 -> weights TP-sharded only, replicated over the data
                axes (ZeRO-1 shards just the optimizer state: pass
                zero_stage=3 for the opt tree and 0 for params).
    moe_ep: shard MoE expert weights on the EXPERT dim over the data axes
            (expert parallelism — tokens move via all-to-all instead of
            expert weights via all-gather).
    """
    def walk(path, leaf):
        names = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
        names = [str(n) for n in names]
        spec = _leaf_spec(names, leaf.shape, mesh)
        name = names[-1]
        stacked = int(any(p in _STACKED for p in names[:-1]))
        dims = leaf.shape[stacked:]
        dp = dp_axes(mesh) or None
        if moe_ep and name in (_COL | _ROW) and len(dims) == 3:
            # Expert parallelism: experts over ``moe_ep_axis``; the other
            # weight dim ZeRO-sharded over dp when EP rides the model axis.
            e_ax = dp if moe_ep_axis == "dp" else "model"
            other = dp if moe_ep_axis != "dp" else None
            parts = [None] * stacked + [_maybe(dims[0], e_ax, mesh),
                                        None, None]
            if name in _COL:   # (E, D, F)
                parts[stacked + 1] = _maybe(dims[1], other, mesh)
                parts[stacked + 2] = (_maybe(dims[2], "model", mesh)
                                      if moe_ep_axis == "dp" else None)
            else:              # (E, F, D)
                parts[stacked + 1] = (_maybe(dims[1], "model", mesh)
                                      if moe_ep_axis == "dp" else None)
                parts[stacked + 2] = _maybe(dims[2], other, mesh)
            return P(*parts)
        if zero_stage < 3:
            # strip dp axes from weight specs (keep TP)
            cleaned = tuple(None if p is not None and p != "model" else p
                            for p in spec)
            return P(*cleaned)
        return spec
    return jax.tree_util.tree_map_with_path(walk, params_shape)


def batch_specs(cfg: ModelConfig, batch_shape, mesh: Mesh):
    dp = dp_axes(mesh) or None

    def per_leaf(path, leaf):
        b = leaf.shape[0]
        lead = _maybe(b, dp, mesh)
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(per_leaf, batch_shape)


def cache_specs(cfg: ModelConfig, cache_shape, mesh: Mesh):
    """Decode/prefill cache: B over dp, sequence over model (K-parallel),
    SSM head dim over model."""
    dp = dp_axes(mesh) or None

    def walk(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        s = leaf.shape
        if name in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v"):
            # (L|G, B, S, KVH, hd): seq over model
            return P(None, _maybe(s[1], dp, mesh),
                     _maybe(s[2], "model", mesh), None, None)
        if name == "h":        # (L, B, H, P, N)
            return P(None, _maybe(s[1], dp, mesh),
                     _maybe(s[2], "model", mesh), None, None)
        if name == "ssm_h":
            return P(None, _maybe(s[1], dp, mesh),
                     _maybe(s[2], "model", mesh), None, None)
        if name in ("conv", "ssm_conv"):   # (L, B, W-1, C)
            return P(None, _maybe(s[1], dp, mesh), None,
                     _maybe(s[3], "model", mesh))
        return P(*([None] * len(s)))

    return jax.tree_util.tree_map_with_path(walk, cache_shape)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
