"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh with abstract inputs (ShapeDtypeStruct — zero allocation),
then record memory/cost analysis + collective schedule for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k [--multi-pod] [--variant baseline] [--all]

Results land in results/dryrun/<arch>__<shape>__<mesh>__<variant>.json and
are consumed by benchmarks/roofline_table.py and EXPERIMENTS.md.
"""
# The very first statements — before ANY other import, jax locks the device
# count on first init: 512 placeholder CPU devices for the production mesh.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import SHAPES, applicable, get_config, list_archs
from ..core import compat
from ..configs.base import ModelConfig, ShapeConfig
from ..core.dist import DistContext, use_dist
from ..models.model import init_params, make_cache
from ..optim.adamw import OptConfig, init_opt_state
from ..roofline.analysis import (build_roofline, collective_bytes,
                                 model_flops_estimate)
from ..roofline.perf_model import step_perf
from ..train.train_step import (make_prefill_step, make_serve_step,
                                make_train_step)
from .mesh import make_production_mesh
from .sharding import (axis_size, batch_specs, cache_specs, dp_axes,
                       expert_axis, param_specs, to_shardings)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Hillclimb variants: sharding-layout / model knobs applied per run.
#   zero_stage: 3 = params+opt 2-D sharded (baseline); 1 = params TP-only +
#               opt still dp-sharded (ZeRO-1); 0 also for serve layouts.
VARIANTS: dict[str, dict] = {
    "baseline": {},
    "zero1": {"zero_stage": 1},
    "ep_moe": {"moe_ep": True},
    "zero1_ep": {"zero_stage": 1, "moe_ep": True},
    "zero1_ep_buf": {"zero_stage": 1, "moe_ep": True, "moe_buf_shard": True},
    "serve_tp": {"zero_stage": 0},
    "ssm_shard": {"ssm_head_shard": True},
    "zero1_ssm": {"zero_stage": 1, "ssm_head_shard": True},
    "rms_bf16": {"rms_bf16": True},
    "zero1_rms": {"zero_stage": 1, "rms_bf16": True},
    "moe_buf": {"moe_buf_shard": True},
    "sp_v2": {"rms_bf16": True, "sp_inputs": True},
    "sp_v2_zero1": {"rms_bf16": True, "sp_inputs": True, "zero_stage": 1},
    "best_moe": {"rms_bf16": True, "sp_inputs": True, "moe_ep": True,
                 "moe_buf_shard": True},
    "serve_tp_best": {"zero_stage": 0, "rms_bf16": True},
    # mesh re-balance: same 256 chips, trade TP degree for DP (activation
    # collectives scale with per-device batch; grad reduction with 1/TP)
    "mesh32x8": {"mesh": (32, 8)},
    "mesh64x4": {"mesh": (64, 4)},
    "mesh32x8_zero1": {"mesh": (32, 8), "zero_stage": 1},
    "mesh64x4_zero1": {"mesh": (64, 4), "zero_stage": 1},
    "mesh32x8_ep": {"mesh": (32, 8), "moe_ep": True},
    "mesh64x4_dots": {"mesh": (64, 4), "cfg": {"remat": "dots"}},
    "serve_bf16": {"zero_stage": 0, "cfg": {"param_dtype": "bfloat16"}},
    "mesh64x4_ep": {"mesh": (64, 4), "moe_ep": True},
    "l4_ep_model": {"mesh": (32, 8), "moe_ep": True, "moe_ep_axis": "model"},
    "l4_ep_model_bf16p": {"mesh": (32, 8), "moe_ep": True,
                          "moe_ep_axis": "model",
                          "cfg": {"param_dtype": "bfloat16"}},
}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract (ShapeDtypeStruct) stand-ins for every model input."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {
            "tokens": sds((b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32),
            "loss_mask": sds((b, s), jnp.float32),
        }
    elif shape.kind == "prefill":
        batch = {"tokens": sds((b, s), jnp.int32)}
    else:  # decode: one new token against a cache of seq_len
        batch = {"tokens": sds((b, 1), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_patches:
        batch["patch_embeds"] = sds((b, cfg.num_patches, cfg.d_model),
                                    jnp.bfloat16)
    return batch


def abstract_state(cfg: ModelConfig, shape: ShapeConfig, with_opt: bool):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params = jax.eval_shape(lambda k: init_params(cfg, k), key)
    opt = jax.eval_shape(init_opt_state, params) if with_opt else None
    return params, opt


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             variant: str = "baseline", save: bool = True,
             opt_overrides: dict | None = None) -> dict:
    knob_cfg = VARIANTS.get(variant, {}).get("cfg")
    if knob_cfg:
        opt_overrides = dict(opt_overrides or {}, **knob_cfg)
    cfg = get_config(arch)
    if opt_overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **opt_overrides)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cellname = f"{arch}__{shape_name}__{mesh_name}__{variant}"
    if not ok:
        result = {"cell": cellname, "status": "skipped", "reason": reason}
        if save:
            _save(cellname, result)
        return result

    knobs = dict(VARIANTS[variant])
    zero_stage = knobs.pop("zero_stage", 3)
    moe_ep = knobs.pop("moe_ep", False)
    moe_ep_axis = knobs.pop("moe_ep_axis", "dp")
    mesh_shape = knobs.pop("mesh", None)
    knobs.pop("cfg", None)
    if mesh_shape is not None:
        from .mesh import make_mesh
        mesh = make_mesh(tuple(mesh_shape), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    dist = DistContext(mesh=mesh, dp_axes=dp_axes(mesh), model_axis="model",
                       moe_ep_axis=expert_axis(mesh, moe_ep, moe_ep_axis,
                                               cfg.num_experts or None),
                       **knobs)
    t0 = time.time()
    with use_dist(dist), mesh:
        batch = input_specs(cfg, shape)
        b_shard = to_shardings(batch_specs(cfg, batch, mesh), mesh)
        if shape.kind == "train":
            params, opt = abstract_state(cfg, shape, with_opt=True)
            p_shard = to_shardings(param_specs(
                params, mesh, zero_stage=zero_stage, moe_ep=moe_ep,
                moe_ep_axis=moe_ep_axis), mesh)
            # ZeRO-1: optimizer state stays dp-sharded even when params are
            # replicated over dp
            o_shard = to_shardings(param_specs(
                opt, mesh, zero_stage=3, moe_ep=moe_ep,
                moe_ep_axis=moe_ep_axis), mesh)
            step = make_train_step(cfg, OptConfig())
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params, opt, batch)
        elif shape.kind == "prefill":
            params, _ = abstract_state(cfg, shape, with_opt=False)
            p_shard = to_shardings(param_specs(
                params, mesh, zero_stage=zero_stage, moe_ep=moe_ep,
                moe_ep_axis=moe_ep_axis), mesh)
            cache = jax.eval_shape(
                lambda: make_cache(cfg, shape.global_batch, shape.seq_len))
            c_shard = to_shardings(cache_specs(cfg, cache, mesh), mesh)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, b_shard, c_shard),
                             donate_argnums=(2,))
            lowered = jitted.lower(params, batch, cache)
        else:  # decode
            params, _ = abstract_state(cfg, shape, with_opt=False)
            p_shard = to_shardings(param_specs(
                params, mesh, zero_stage=zero_stage, moe_ep=moe_ep,
                moe_ep_axis=moe_ep_axis), mesh)
            cache = jax.eval_shape(
                lambda: make_cache(cfg, shape.global_batch, shape.seq_len))
            c_shard = to_shardings(cache_specs(cfg, cache, mesh), mesh)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            step = make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, b_shard["tokens"], None),
                donate_argnums=(1,))
            lowered = jitted.lower(params, cache, batch["tokens"], pos)

        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compat.cost_analysis(compiled)
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)

    mem_stats = {
        "argument_size": getattr(mem, "argument_size_in_bytes", 0),
        "output_size": getattr(mem, "output_size_in_bytes", 0),
        "temp_size": getattr(mem, "temp_size_in_bytes", 0),
        "peak_memory": (getattr(mem, "argument_size_in_bytes", 0)
                        + getattr(mem, "temp_size_in_bytes", 0)),
    }
    # Price EP off the SAME axis the DistContext routed execution through
    # (axis_size(None) == 1 -> replicated-expert pricing).
    ep_shards = axis_size(mesh, dist.moe_ep_axis) if cfg.num_experts else 1
    perf = step_perf(cfg, shape, ep_shards=ep_shards)
    roof = build_roofline(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        analytic_flops=perf.flops, analytic_bytes=perf.bytes_hbm,
        cost=cost, coll=coll,
        model_flops=model_flops_estimate(cfg, shape, shape.kind),
        memory_stats=mem_stats)
    result = {
        "cell": cellname, "status": "ok", "variant": variant,
        "compile_s": round(t_compile, 1),
        "memory": mem_stats,
        "perf_breakdown": {k: [round(x, 1) for x in v]
                           for k, v in perf.breakdown.items()},
        "roofline": roof.to_dict(),
    }
    if save:
        _save(cellname, result)
    return result


def _save(cellname: str, result: dict) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    with open(RESULTS / f"{cellname}.json", "w") as f:
        json.dump(result, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell for the given mesh")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    n_ok = n_skip = n_fail = 0
    for arch, shape in cells:
        cellname = f"{arch}__{shape}__{mesh_name}__{args.variant}"
        if args.skip_existing and (RESULTS / f"{cellname}.json").exists():
            prior = json.loads((RESULTS / f"{cellname}.json").read_text())
            if prior.get("status") in ("ok", "skipped"):
                print(f"[skip-existing] {cellname}")
                continue
        try:
            r = run_cell(arch, shape, multi_pod=args.multi_pod,
                         variant=args.variant)
            if r["status"] == "ok":
                n_ok += 1
                roof = r["roofline"]
                print(f"[ok {r['compile_s']}s] {cellname} "
                      f"dominant={roof['dominant']} "
                      f"t_bound={roof['t_bound']:.3e}s "
                      f"mem/dev={r['memory']['peak_memory']/2**30:.2f}GiB")
            else:
                n_skip += 1
                print(f"[skipped] {cellname}: {r['reason']}")
        except Exception as e:  # noqa: BLE001 — record failures per cell
            n_fail += 1
            _save(cellname, {"cell": cellname, "status": "failed",
                             "error": repr(e),
                             "trace": traceback.format_exc()[-4000:]})
            print(f"[FAIL] {cellname}: {e!r}")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")


if __name__ == "__main__":
    main()
