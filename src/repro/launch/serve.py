"""Serving launcher: batched requests through the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b-smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_config
from ..models.model import init_params
from ..serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=args.prompt_len + args.max_new + 8)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    engine.run(reqs)
    for r in reqs:
        print(f"req {r.rid}: {r.out_tokens}")
    print("serving done")


if __name__ == "__main__":
    main()
