"""Serving launcher: batched requests through the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b-smoke \
        --requests 8 --max-new 16 [--plan-cache results/plan_cache.json]

Warmup loads the persistent measured-plan cache (``--plan-cache``, or
``$REPRO_PLAN_CACHE``, or ``results/plan_cache.json`` when present) BEFORE
the engine compiles anything, so every GEMM the serving graphs trace plans
from measured winners (``mode == "cached"``) instead of the raw CMR model.
"""
from __future__ import annotations

import argparse
import os
import pathlib

import jax
import numpy as np

from ..configs import get_config
from ..core.gemm import autotune, epilogue_stats, plan_mode_stats
from ..models.model import init_params
from ..serve.engine import Request, ServeEngine

_DEFAULT_CACHE = pathlib.Path(__file__).resolve().parents[3] \
    / "results" / "plan_cache.json"


def load_plan_cache(path: str | None) -> int:
    """Serve-warmup plan-cache load: explicit path > env > repo default.
    Returns adopted entries (0 when nothing loadable — serving proceeds on
    analytic plans, it never fails on a missing/corrupt cache)."""
    path = path or os.environ.get(autotune.plan_store.ENV_VAR) \
        or (str(_DEFAULT_CACHE) if _DEFAULT_CACHE.exists() else None)
    if not path:
        return 0
    n = autotune.load_plan_cache(path)
    store = autotune.plan_store.get_store()
    cal = store.calibration
    print(f"plan cache: {n} measured plans from {path}"
          + (f" (calibration flops_frac={cal.flops_frac:.3g} "
               f"bw_frac={cal.bw_frac:.3g})" if cal else ""))
    if store.quarantined:
        # Static verifier rejected these cached records at load; the shapes
        # re-plan analytically instead of silently serving a bad tiling.
        codes = sorted({c for v in store.quarantined.values() for c in v})
        print(f"plan cache: {len(store.quarantined)} records quarantined "
              f"by the static verifier ({', '.join(codes)})")
    return n


def fusion_coverage() -> str:
    """Human-readable epilogue-fusion census of the traced serving graphs:
    how many epilogue-carrying GEMMs ran their elementwise tail fused into
    the kernel/jit vs as separate output passes, per plan family."""
    stats = epilogue_stats()
    if not stats:
        return "(no epilogue-carrying GEMMs traced)"
    fused = sum(v.get("fused", 0) for v in stats.values())
    total = fused + sum(v.get("separate", 0) for v in stats.values())
    per_family = ", ".join(
        f"{fam}: {v.get('fused', 0)}/{v.get('fused', 0) + v.get('separate', 0)}"
        for fam, v in sorted(stats.items()))
    return f"{fused}/{total} fused ({per_family})"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan-cache", default=None,
                    help="persistent measured-plan cache to load at warmup")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size (paged families)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="KV pool size in pages (default: slots x max pages)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline; admission control prices "
                         "against it once calibrated")
    args = ap.parse_args()

    load_plan_cache(args.plan_cache)
    cfg = get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_slots=args.slots,
                         max_len=args.prompt_len + args.max_new + 8,
                         page_size=args.page_size, num_pages=args.num_pages)
    if engine.paged:
        # Constructing the engine priced every bucket through plan_gemm —
        # the plan cache is now warm for exactly the serving signatures.
        cost = engine.cost.snapshot()
        print(f"warmup: buckets={cost['buckets']} "
              f"warmed {cost['warmed_signatures']} GEMM signatures "
              f"(plan-store lookups={cost['store_lookups']} "
              f"hits={cost['store_hits']}), "
              f"KV pool {engine.alloc.total} pages x {engine.page_size} rows")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature,
                    deadline_s=args.deadline_s)
            for i in range(args.requests)]
    engine.run(reqs)
    for r in reqs:
        tag = " SHED" if r.shed else (" TIMEOUT" if r.timed_out else "")
        print(f"req {r.rid}{tag}: {r.out_tokens}")
    # plan_mode_stats carries "epilogue"/"degraded" summary entries too; the
    # census and the health snapshot print those dedicated lines instead.
    modes = {fam: v for fam, v in plan_mode_stats().items()
             if fam not in ("epilogue", "degraded")}
    print("plan modes:", modes or "(no planned GEMMs traced)")
    print("epilogue fusion:", fusion_coverage())
    health = engine.health()
    print("health:", "DEGRADED" if health["degraded_mode"] else "ok",
          f"faults={health['faults']}",
          f"degraded_servings={health['degraded_servings'] or '{}'}")
    print("serving done")


if __name__ == "__main__":
    main()
