"""Production mesh construction (a FUNCTION so importing never touches jax
device state).  Single pod: 16x16 = 256 chips (data, model).  Multi-pod:
2x16x16 = 512 chips (pod, data, model); the pod axis joins data-parallel
gradient reduction (hierarchical: reduce in-pod over ICI, then across pods)."""
from __future__ import annotations

from ..core.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return _compat_make_mesh(shape, axes)
