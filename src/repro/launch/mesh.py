"""Production mesh construction (a FUNCTION so importing never touches jax
device state).  Single pod: 16x16 = 256 chips (data, model).  Multi-pod:
2x16x16 = 512 chips (pod, data, model); the pod axis joins data-parallel
gradient reduction (hierarchical: reduce in-pod over ICI, then across pods)."""
from __future__ import annotations

from ..core.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return _compat_make_mesh(shape, axes)


def mesh_from_plan(plan, *, devices=None):
    """The shrunken (data, model) mesh an ``ElasticPlan`` prescribes.

    ``devices`` defaults to the local device list; the mesh takes the first
    ``plan.chips`` of them — the survivors after elastic exclusion (lost
    and dropped chips come off the tail).  Raises when fewer devices exist
    than the plan needs, so a stale plan can't silently oversubscribe."""
    import jax
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < plan.chips:
        raise ValueError(
            f"elastic plan needs {plan.chips} chips but only "
            f"{len(devs)} devices are visible")
    return _compat_make_mesh(plan.mesh_shape, ("data", "model"),
                             devices=devs[:plan.chips])
