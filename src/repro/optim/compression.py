"""Error-feedback int8 gradient compression for the data-parallel all-reduce.

Beyond-paper distributed-optimization feature (DESIGN.md §6): gradients are
quantized to int8 *before* the DP psum so the all-reduce moves 1/4 of the
fp32 bytes over ICI/DCI, with per-tensor scale agreement via one scalar
psum(max) and residual error carried to the next step (error feedback keeps
the scheme convergent — EF-SGD/EF21 literature).

Overflow safety: each device clips its quantized values to +-(127 // n) so
the integer all-reduce over n devices cannot wrap.  Used inside shard_map
(see train.train_step.make_compressed_grad_sync).

The symmetric scale fit / clip-round / error-feedback arithmetic lives in
``core.quant`` — ONE rounding rule shared with the low-precision GEMM
kernels' quantization, so the ICI compressor and the kernel quant paths can
never drift apart.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.quant import (INT8_LEVELS, dequantize, error_residual, quantize,
                          scale_from_absmax)


def compress_allreduce(g: jax.Array, err: jax.Array, axis,
                       num_devices: int) -> tuple[jax.Array, jax.Array]:
    """All-reduce-mean one gradient tensor in int8 with error feedback.
    Must be called inside shard_map over ``axis``.
    Returns (mean_gradient fp32, new_error fp32)."""
    gf = g.astype(jnp.float32) + err
    # Shared scale: global max|g| via a scalar fp32 psum (cheap).
    local_max = jnp.max(jnp.abs(gf))
    global_max = jax.lax.pmax(local_max, axis)
    level = max(INT8_LEVELS // max(num_devices, 1), 1)
    scale = scale_from_absmax(global_max, level)
    q = quantize(gf, scale, level)
    new_err = error_residual(gf, q, scale)
    q_sum = jax.lax.psum(q, axis)                   # s8 on the wire
    mean = dequantize(q_sum, scale) / num_devices
    return mean, new_err


def init_error_state(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
