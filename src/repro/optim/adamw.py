"""AdamW with warmup+cosine schedule and global-norm clipping, pure JAX.

Optimizer state mirrors the param tree (so the same PartitionSpecs apply —
ZeRO-style sharding of m/v comes for free from the param sharding rules).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


def schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)  # noqa: E731
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    lr = schedule(step, cfg)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state["v"], grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        wd = cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * (delta + wd)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, stats
