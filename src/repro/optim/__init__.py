from .adamw import OptConfig, apply_updates, global_norm, init_opt_state, schedule
from .compression import compress_allreduce, init_error_state

__all__ = ["OptConfig", "apply_updates", "global_norm", "init_opt_state",
           "schedule", "compress_allreduce", "init_error_state"]
