"""Static verification sweep: the repo's zero-device-time correctness ratchet.

    PYTHONPATH=src python -m repro.analysis.sweep \
        [--out results/ANALYSIS_static.json] [--cache results/plan_cache.json]
        [--arch NAME ...] [--quick]

Sweeps the FULL candidate space — every tiling ``tuner.gemm_candidates`` /
``batched_candidates`` / ``ragged_candidates`` would offer the measured
auto-tuner — for the paper's 21 T1/T2/T3 shapes plus GEMM shapes derived
from every registry config (dense projections, MoE ragged/capacity
families), and checks each candidate against the static kernel contracts
(``repro.analysis.contracts``).  Also proves, once per run:

  * the kernel bodies mask the contraction remainder on every operand
    (AST inspection — the 0*NaN hazard);
  * the ragged visit metadata satisfies the sorted-visit contract on a set
    of adversarial group distributions (balanced / skewed / empty groups /
    boundary-sharing), per winning row tile;
  * the symbolic store-coverage proof for each winner's real index maps,
    across all three trans variants;
  * every committed plan-cache record parses and passes ``check_record``
    (what plan-store load would otherwise quarantine at serve time);
  * pruning round-trip: enabling the generators' contract pre-check changes
    no argmin plan (``verify=True`` vs ``verify=False``).

Exit code 1 on any error-severity violation; warnings (e.g. the CMR
formula's un-priced swiglu VMEM extras) are reported but never fatal.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Iterable, Sequence

from ..configs.registry import get_config, list_archs
from ..core.gemm import tuner
from ..core.gemm.cmr import TPU_V5E, ceil_to
from ..core.gemm.shapes import PAPER_IRREGULAR_SHAPES
from . import contracts

DECODE_TOKENS = 128     # decode-step rows for registry-derived shapes
# Dtype-axis rows: (in_bytes, out_bytes, b_bytes).  b_bytes=None is the
# homogeneous legacy pair (fp32, bf16); (1, 4, None) is the full-int8
# compute path; b_bytes=1 are the weight-only mixed rows (bf16/fp32
# activations streaming an int8 panel) the quantized dispatch plans with.
_WIDTHS = ((4, 4, None), (2, 2, None), (1, 4, None), (2, 2, 1), (4, 4, 1))
_EPI_OPS = (0, 2)               # identity and bias+activation epilogues


def _dense_jobs(shapes: Sequence[tuple[str, int, int, int]]
                ) -> list[tuple[str, str, tuple[int, ...], str]]:
    return [(name, "dense", (m, k, n), "m") for name, m, k, n in shapes]


def registry_jobs(archs: Iterable[str] | None = None
                  ) -> list[tuple[str, str, tuple[int, ...], str]]:
    """GEMM shapes every registry config actually dispatches at decode:
    dense qkv / attention-out / MLP / LM-head projections, plus the MoE
    ragged (forward and ragged-K dW) and capacity-mode batched families."""
    jobs: list[tuple[str, str, tuple[int, ...], str]] = []
    t = DECODE_TOKENS
    for arch in (archs if archs is not None else list_archs()):
        cfg = get_config(arch)
        d = cfg.d_model
        if cfg.num_heads:
            n_q = cfg.num_heads * cfg.head_dim_
            n_kv = cfg.num_kv_heads * cfg.head_dim_
            jobs.append((f"{arch}:qkv", "dense", (t, d, n_q + 2 * n_kv), "m"))
            jobs.append((f"{arch}:attn_out", "dense", (t, n_q, d), "m"))
        if cfg.d_ff:    # SSM-only archs have no MLP pair to dispatch
            jobs.append((f"{arch}:mlp_up", "dense", (t, d, cfg.d_ff), "m"))
            jobs.append((f"{arch}:mlp_down", "dense", (t, cfg.d_ff, d), "m"))
        jobs.append((f"{arch}:lm_head", "dense", (t, d, cfg.vocab_padded),
                     "m"))
        if cfg.num_experts:
            e, tk = cfg.num_experts, max(cfg.top_k, 1)
            jobs.append((f"{arch}:moe_fwd", "ragged", (e, t * tk, d,
                                                       cfg.d_ff), "m"))
            jobs.append((f"{arch}:moe_dw", "ragged", (e, t * tk, d,
                                                      cfg.d_ff), "k"))
            cap = ceil_to(max(int(t * tk * cfg.capacity_factor) // e, 1), 8)
            jobs.append((f"{arch}:moe_cap", "batched", (e, cap, d, cfg.d_ff),
                         "m"))
    return jobs


def _candidates(family: str, dims: tuple[int, ...], ib: int, ob: int,
                epi_ops: int, ragged: str, verify: bool,
                bb: int | None = None) -> list[Any]:
    if family == "dense":
        m, k, n = dims
        return tuner.gemm_candidates(m, k, n, ib, ob, TPU_V5E, epi_ops,
                                     verify=verify, b_bytes=bb)
    if family == "batched":
        g, m, k, n = dims
        return tuner.batched_candidates(g, m, k, n, ib, ob, "none", TPU_V5E,
                                        epi_ops, verify=verify)
    g, total, k, n = dims
    return tuner.ragged_candidates(g, total, k, n, ib, ob, ragged, TPU_V5E,
                                   verify=verify, b_bytes=bb)


def _argmin(cands: Sequence[Any]) -> Any:
    return min(cands, key=lambda p: p.est.t_total)


# Adversarial group distributions for the ragged sorted-visit proof:
# balanced, heavily skewed, leading/inner empty groups, tile-boundary
# sharing, single group, all-empty-but-one.
_RAGGED_DISTS = (
    lambda g, total: [total * i // g for i in range(g + 1)],
    lambda g, total: [0] + [total] * g,
    lambda g, total: [0, 0] + [total * i // max(g - 1, 1)
                               for i in range(1, g)],
    lambda g, total: [min(7 * i, total) for i in range(g)] + [total],
)


def run_sweep(shapes: Sequence[tuple[str, int, int, int]] | None = None,
              archs: Iterable[str] | None = None,
              cache_path: str | None = "results/plan_cache.json",
              coverage: bool = True) -> dict:
    """Run the full static sweep; returns the findings report (pure data,
    JSON-serializable).  ``report["violations"]`` is the fatal list."""
    shapes = PAPER_IRREGULAR_SHAPES if shapes is None else shapes
    jobs = _dense_jobs(shapes) + registry_jobs(archs)
    violations: list[dict] = []
    warnings: list[dict] = []
    n_checked = 0
    n_jobs = 0
    roundtrip_mismatch: list[str] = []
    coverage_seen: set[tuple] = set()

    def record(name: str, ctx: str, found: Iterable[contracts.Violation]
               ) -> None:
        for v in found:
            row = {"job": name, "context": ctx, "code": v.code,
                   "severity": v.severity, "message": v.message}
            (violations if v.severity == "error" else warnings).append(row)

    for name, family, dims, ragged in jobs:
        n_jobs += 1
        for ib, ob, bb in _WIDTHS:
            if family == "batched" and bb is not None:
                continue    # mixed-width panels: dense/ragged families only
            for epi_ops in (_EPI_OPS if family != "ragged" else (0,)):
                cands = _candidates(family, dims, ib, ob, epi_ops, ragged,
                                    verify=True, bb=bb)
                bbs = "" if bb is None else f" bb{bb}"
                if not cands:
                    record(name, f"ib{ib}{bbs} epi{epi_ops}",
                           [contracts.Violation(
                               "empty_candidates",
                               "generator returned no candidates")])
                    continue
                for plan in cands:
                    n_checked += 1
                    record(name, f"ib{ib}{bbs} epi{epi_ops} bm{plan.bm} "
                                 f"bn{plan.bn} bk{plan.bk} {plan.dim_order} "
                                 f"{plan.edge}",
                           contracts.check_plan(family, dims, plan,
                                                in_bytes=ib, out_bytes=ob,
                                                ragged=ragged, b_bytes=bb))
                # Symbolic store-coverage proof on the winner, all trans
                # variants, deduped by grid geometry across jobs.
                win = _argmin(cands)
                if coverage and family in ("dense", "batched"):
                    for trans in ("nn", "tn", "nt"):
                        c = contracts.variant_contract(family, dims, win,
                                                       trans=trans)
                        sig = (c.name, c.grid, c.out_extent, trans)
                        if sig in coverage_seen:
                            continue
                        coverage_seen.add(sig)
                        record(name, f"coverage {trans}",
                               contracts.verify_contract(c))
                # Pruning round-trip: the contract pre-check must not change
                # the chosen plan (it only removes plans that cannot run).
                unverified = _candidates(family, dims, ib, ob, epi_ops,
                                         ragged, verify=False, bb=bb)
                if unverified and _argmin(unverified) != win:
                    roundtrip_mismatch.append(
                        f"{name} ib{ib}{bbs} epi{epi_ops}")
        if family == "ragged":
            g, total = dims[0], dims[1]
            win = _argmin(_candidates(family, dims, 4, 4, 0, ragged, True))
            tile = win.bm if ragged == "m" else win.bk
            for i, dist in enumerate(_RAGGED_DISTS):
                off = dist(g, total)
                record(name, f"visits dist{i} tile{tile}",
                       contracts.check_ragged_visit_plan(off, tile))

    # Kernel-body mask soundness (once; AST inspection).
    record("kernels", "mask-soundness", contracts.check_contraction_masking())

    if roundtrip_mismatch:
        for ctx in roundtrip_mismatch:
            violations.append({"job": ctx, "context": "prune-roundtrip",
                               "code": "prune_changed_plan",
                               "severity": "error",
                               "message": "contract pre-check changed the "
                                          "argmin plan"})

    # Committed plan-cache records (what load would quarantine).
    cache_report: dict[str, Any] = {"path": cache_path, "entries": 0,
                                    "quarantine_candidates": 0}
    if cache_path:
        try:
            with open(cache_path) as fp:
                blob = json.load(fp)
            entries = blob.get("entries", {}) if isinstance(blob, dict) \
                else {}
        except (OSError, ValueError):
            entries = {}
        cache_report["entries"] = len(entries)
        for key, rec in entries.items():
            found = contracts.errors(contracts.check_record(key, rec))
            if found:
                cache_report["quarantine_candidates"] += 1
                record(key, "plan-cache", found)

    return {
        "jobs": n_jobs,
        "candidates_checked": n_checked,
        "coverage_contracts": len(coverage_seen),
        "plan_cache": cache_report,
        "violations": violations,
        "warnings": warnings,
    }


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="static kernel-contract sweep (no device time)")
    ap.add_argument("--out", default="results/ANALYSIS_static.json",
                    help="findings report path ('' to skip writing)")
    ap.add_argument("--cache", default="results/plan_cache.json",
                    help="committed plan cache to validate ('' to skip)")
    ap.add_argument("--arch", action="append", default=None,
                    help="registry config(s) to sweep (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweep (first 6 paper shapes, 2 archs)")
    args = ap.parse_args(argv)

    shapes = PAPER_IRREGULAR_SHAPES
    archs = args.arch
    if args.quick:
        shapes = PAPER_IRREGULAR_SHAPES[:6]
        archs = archs or list_archs()[:2]
    report = run_sweep(shapes=shapes, archs=archs,
                       cache_path=args.cache or None)

    if args.out:
        with open(args.out, "w") as fp:
            json.dump(report, fp, indent=1, sort_keys=True)
    print(f"static sweep: {report['jobs']} shape jobs, "
          f"{report['candidates_checked']} candidate plans, "
          f"{report['coverage_contracts']} store contracts verified, "
          f"{report['plan_cache']['entries']} cached records checked")
    for row in report["warnings"][:10]:
        print(f"  warning {row['code']}: {row['job']} ({row['context']})")
    if len(report["warnings"]) > 10:
        print(f"  ... {len(report['warnings']) - 10} more warnings "
              "(see the JSON report)")
    if report["violations"]:
        for row in report["violations"][:20]:
            print(f"  VIOLATION {row['code']}: {row['job']} "
                  f"({row['context']}): {row['message']}")
        print(f"static sweep: FAIL ({len(report['violations'])} violations)")
        return 1
    print("static sweep: PASS (zero violations)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
