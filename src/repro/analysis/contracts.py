"""Static kernel-contract verifier: prove plans safe before any kernel runs.

Every generated ftIMM variant and every cached ``Plan`` is checked against
machine-checkable contracts WITHOUT executing a kernel:

  1. VMEM/footprint budget — the per-grid-step working set (double-buffered
     A/B blocks, fp32 accumulator, double-buffered output block, plus
     bias/residual/swiglu extra inputs and split-K fp32 partials) computed
     from block shapes and dtypes, rejected when it exceeds the device spec.
  2. Grid coverage & write-race analysis — the kernel's real output
     ``BlockSpec`` index map is evaluated symbolically over a sampled cdiv
     grid to prove every output block is stored by exactly one parallel grid
     point and that stores are invariant to the reduction dimension.  The
     ragged kernels' masked boundary-tile read-modify-write is the one
     *ordered* exception: it is sound only under the sorted visit list, which
     ``check_ragged_visits`` re-proves from the concrete metadata.
  3. Edge-mask soundness — masked-edge kernels must mask the contraction
     remainder on BOTH operands (the 0*NaN hazard), established by AST
     inspection of the kernel bodies; padded-edge plans must have their pad
     copies priced by the CMR estimate they carry.
  4. Plan invariants — block sizes clamped to problem extents (the PR 5
     bk-clamp bug class), sublane/lane alignment per dtype, split-K with a
     fused nonlinear epilogue is illegal, placement divisibility (EP expert
     counts, k_parallel K-shards).

Layering: this module imports NOTHING from ``repro`` at module level (stdlib
only) so ``core.gemm.tuner`` and ``core.gemm.plan_store`` can import it
without creating a cycle; the device spec, the CMR estimator, the kernel
index maps and the ragged metadata generator are pulled in lazily inside the
checks that need them.
"""
from __future__ import annotations

import ast
import inspect
import itertools
import textwrap
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Sequence

FAMILIES = ("dense", "batched", "ragged")
STRATEGIES = ("m_parallel", "k_parallel", "expert_parallel")
SCHEDULES = ("gather", "ring")
# Ring (overlapped) schedules exist only where a chunk rotation is defined:
# the dense k_parallel collective matmul and the ragged EP token pipeline.
_RING_LEGAL = {("dense", "k_parallel"), ("ragged", "expert_parallel")}
_EDGES = ("masked", "padded")
_ORDERS = ("mn", "nm")


def _ceil_to(x: int, b: int) -> int:
    return -(-x // b) * b


def _cdiv(x: int, b: int) -> int:
    return -(-x // b)


def _spec(spec: Any) -> Any:
    """Resolve the device spec (duck-typed: needs ``.vmem_budget``, ``.lane``
    and ``.sublane(dtype_bytes)``); defaults to the CMR TPU v5e model."""
    if spec is not None:
        return spec
    from ..core.gemm.cmr import TPU_V5E
    return TPU_V5E


@dataclass(frozen=True)
class Violation:
    """One broken contract.  ``severity == "error"`` means the plan must not
    run; warnings are report-only (surfaced by the sweep, never fatal)."""
    code: str
    message: str
    severity: str = "error"

    def __str__(self) -> str:
        return f"[{self.severity}] {self.code}: {self.message}"


class ContractError(AssertionError):
    """Raised by ``assert_plan`` (the ``REPRO_VERIFY=1`` dispatch mode)."""

    def __init__(self, violations: Sequence[Violation],
                 context: str = "") -> None:
        self.violations = tuple(violations)
        head = f"kernel contract violated for {context}: " if context else \
            "kernel contract violated: "
        super().__init__(head + "; ".join(str(v) for v in self.violations))


def errors(violations: Iterable[Violation]) -> list[Violation]:
    """Only the fatal subset."""
    return [v for v in violations if v.severity == "error"]


def block_aligned(dims: Sequence[int], blocks: Sequence[int]) -> bool:
    """True when every extent is an exact multiple of its block — the edge is
    degenerate and pad/slice copies are pure waste (zero-copy legal)."""
    return all(d % b == 0 for d, b in zip(dims, blocks))


# ---------------------------------------------------------------------------
# Contract 4: plan invariants (alignment, clamping, schedule legality)
# ---------------------------------------------------------------------------

def _check_dim(out: list[Violation], name: str, blk: int, extent: int,
               unit: int, unit_name: str) -> None:
    if blk % unit:
        out.append(Violation(
            "misaligned_block",
            f"{name}={blk} is not a multiple of the {unit_name} ({unit})"))
    if blk > _ceil_to(max(extent, 1), unit):
        out.append(Violation(
            "unclamped_block",
            f"{name}={blk} exceeds the problem extent {extent} rounded to "
            f"{_ceil_to(max(extent, 1), unit)} — the grid would pad "
            f"{name}-fold (the PR 5 bk-clamp bug class)"))


def check_blocks(family: str, dims: Sequence[int], *, bm: int, bn: int,
                 bk: int, nsplit: int = 1, dim_order: str = "mn",
                 edge: str = "masked", in_bytes: int = 4, out_bytes: int = 4,
                 ragged: str = "m", spec: Any = None) -> list[Violation]:
    """Pure-geometry plan invariants: positivity, alignment per dtype,
    clamping to problem extents, split-K factor sanity.  Cheap enough to run
    on every candidate the tuner generates, before CMR pricing."""
    sp = _spec(spec)
    v: list[Violation] = []
    if min(bm, bn, bk) <= 0 or nsplit <= 0:
        v.append(Violation("nonpositive_block",
                           f"bm={bm} bn={bn} bk={bk} nsplit={nsplit} must "
                           "all be positive"))
        return v
    if edge not in _EDGES:
        v.append(Violation("bad_edge", f"edge={edge!r} not in {_EDGES}"))
    sub = sp.sublane(in_bytes)
    lane = sp.lane
    if family == "dense":
        if len(dims) != 3:
            return v + [Violation("bad_dims", f"dense wants (m, k, n), got "
                                              f"{tuple(dims)}")]
        m, k, n = dims
        if dim_order not in _ORDERS:
            v.append(Violation("bad_dim_order",
                               f"dim_order={dim_order!r} not in {_ORDERS}"))
        _check_dim(v, "bm", bm, m, sub, "sublane")
        _check_dim(v, "bn", bn, n, lane, "lane")
        _check_dim(v, "bk", bk, k, lane, "lane")
        if nsplit > 1 and nsplit > _cdiv(_ceil_to(max(k, 1), lane), bk):
            v.append(Violation(
                "unclamped_nsplit",
                f"nsplit={nsplit} exceeds the {_cdiv(_ceil_to(max(k, 1), lane), bk)} "
                f"K-blocks available at bk={bk} — some splits would be empty"))
    elif family == "batched":
        if len(dims) != 4:
            return v + [Violation("bad_dims", f"batched wants (g, m, k, n), "
                                              f"got {tuple(dims)}")]
        g, m, k, n = dims
        if g <= 0:
            v.append(Violation("nonpositive_block", f"batch g={g} must be "
                                                    "positive"))
        if dim_order not in _ORDERS:
            v.append(Violation("bad_dim_order",
                               f"dim_order={dim_order!r} not in {_ORDERS}"))
        _check_dim(v, "bm", bm, m, sub, "sublane")
        _check_dim(v, "bn", bn, n, lane, "lane")
        _check_dim(v, "bk", bk, k, lane, "lane")
        if nsplit != 1:
            v.append(Violation("splitk_unsupported",
                               "batched kernels have no split-K schedule"))
    elif family == "ragged":
        if len(dims) != 4:
            return v + [Violation("bad_dims", f"ragged wants (g, total, k, n),"
                                              f" got {tuple(dims)}")]
        g, total, k, n = dims
        if g <= 0:
            v.append(Violation("nonpositive_block", f"group count g={g} must "
                                                    "be positive"))
        if dim_order != "mn":
            v.append(Violation("bad_dim_order",
                               "ragged kernels walk a fixed visit order; only "
                               f"dim_order='mn' is defined (got {dim_order!r})"))
        if nsplit != 1:
            v.append(Violation("splitk_unsupported",
                               "ragged kernels have no split-K schedule"))
        if ragged == "m":
            # bm tiles the ragged token axis; bk/bn tile dense K/N.
            _check_dim(v, "bm", bm, total, sub, "sublane")
            _check_dim(v, "bk", bk, k, lane, "lane")
            _check_dim(v, "bn", bn, n, lane, "lane")
        elif ragged == "k":
            # dW layout: bk tiles the ragged token (contraction) axis, bm
            # tiles the D rows of the (g, D, F) output.
            _check_dim(v, "bk", bk, total, sub, "sublane")
            _check_dim(v, "bm", bm, k, sub, "sublane")
            _check_dim(v, "bn", bn, n, lane, "lane")
        else:
            v.append(Violation("bad_ragged_axis",
                               f"ragged axis {ragged!r} not in ('m', 'k')"))
    else:
        v.append(Violation("bad_family", f"family {family!r} not in "
                                         f"{FAMILIES}"))
    return v


def vmem_footprint(family: str, *, bm: int, bn: int, bk: int,
                   in_bytes: int = 4, out_bytes: int = 4, nsplit: int = 1,
                   ragged: str = "m", epilogue: Any = None,
                   swiglu: bool = False, b_bytes: int | None = None) -> int:
    """Per-grid-step VMEM working set in bytes: double-buffered A/B input
    blocks, the fp32 accumulator scratch, and the double-buffered output
    block (fp32 when split-K writes partials).  ``epilogue``/``swiglu`` add
    the extra kernel inputs the base CMR formula does not price: a bias row,
    a residual block, the scale vector, the second weight panel + second
    accumulator.  ``b_bytes`` is the B-operand element width when it differs
    from A's (the mixed-dtype weight-only paths: int8/int4 weights against
    bf16/fp32 activations)."""
    bb = in_bytes if b_bytes is None else b_bytes
    if family == "ragged" and ragged == "k":
        a_blk, b_blk = bk * bm, bk * bn   # x^T panel and dy panel
    else:
        a_blk, b_blk = bm * bk, bk * bn
    out_elt = 4 if nsplit > 1 else out_bytes
    total = (2 * a_blk * in_bytes + 2 * b_blk * bb
             + bm * bn * 4 + 2 * bm * bn * out_elt)
    if swiglu:
        # Second weight panel (double-buffered) + second fp32 accumulator.
        total += 2 * b_blk * bb + bm * bn * 4
    if epilogue is not None:
        if getattr(epilogue, "bias", False):
            total += 2 * bn * out_bytes
        if getattr(epilogue, "residual", False):
            total += 2 * bm * bn * out_bytes
        if getattr(epilogue, "scale_vec", False):
            total += 2 * bn * 4         # fp32 dequant vector row
    return total


def check_schedule(*, nsplit: int = 1, fuse: bool = True, epilogue: Any = None,
                   swiglu: bool = False) -> list[Violation]:
    """Split-K ∧ nonlinear-epilogue legality.  A nonlinear tail (activation /
    swiglu gate) fused into the per-split flush would apply the nonlinearity
    to PARTIAL sums — act(a+b) != act(a)+act(b) — so a split-K plan may only
    claim ``fuse`` for tails applied after the cross-split reduction."""
    v: list[Violation] = []
    if nsplit <= 1:
        return v
    nonlinear = swiglu or (
        epilogue is not None
        and getattr(epilogue, "activation", "none") != "none")
    if fuse and nonlinear:
        v.append(Violation(
            "splitk_nonlinear_epilogue",
            f"nsplit={nsplit} with a fused nonlinear epilogue would apply "
            "the activation to partial sums"))
    # NOTE: a scale_vec epilogue (the quantized paths' dequant) is LINEAR —
    # it commutes with the cross-split sum, so split-K legally applies it
    # post-reduction and no violation is raised for it here.
    if swiglu:
        v.append(Violation("splitk_unsupported",
                           "no split-K swiglu kernel exists"))
    return v


def check_epilogue_vectors(family: str, dims: Sequence[int], epilogue: Any,
                           *, bias_shape: Sequence[int] | None = None,
                           scale_shape: Sequence[int] | None = None
                           ) -> list[Violation]:
    """Scale-vector / per-expert-bias operand legality for one planned call.

    The flush-time vector operands must be (N,)-wide — broadcast over rows —
    or, for the grouped/ragged families, (G, N) per-expert panels indexed by
    the visit list's group id.  A wrong N silently broadcasts or raises deep
    inside pallas; checking it here turns it into a named contract."""
    v: list[Violation] = []
    if epilogue is None:
        return v
    n = int(dims[-1])
    g = int(dims[0]) if family in ("batched", "ragged") else None

    def _check(name: str, flag: bool, shape) -> None:
        if not flag or shape is None:
            return
        shp = tuple(int(s) for s in shape)
        ok = shp == (n,) or (g is not None and shp == (g, n))
        if not ok:
            want = f"({n},)" if g is None else f"({n},) or ({g}, {n})"
            v.append(Violation(
                f"bad_{name}_shape",
                f"{family} epilogue {name} operand has shape {shp}; "
                f"expected {want}"))

    _check("scale", getattr(epilogue, "scale_vec", False), scale_shape)
    _check("bias", getattr(epilogue, "bias", False), bias_shape)
    return v


def check_placement(family: str, dims: Sequence[int], placement: Any,
                    spec: Any = None) -> list[Violation]:
    """Placement divisibility: EP needs the expert/group count divisible by
    the shard count (mirrors ``launch.sharding.expert_axis``); k_parallel
    must leave every shard at least one 128-wide K panel; the ring
    (overlapped) schedule only exists where a chunk rotation is defined."""
    sp = _spec(spec)
    v: list[Violation] = []
    strategy = getattr(placement, "strategy", None)
    nshards = int(getattr(placement, "num_shards", 1))
    if strategy not in STRATEGIES:
        return [Violation("bad_strategy",
                          f"placement strategy {strategy!r} not in "
                          f"{STRATEGIES}")]
    if nshards < 1:
        return [Violation("bad_shards", f"num_shards={nshards} must be >= 1")]
    schedule = getattr(placement, "schedule", "gather")
    if schedule not in SCHEDULES:
        return [Violation("bad_schedule",
                          f"placement schedule {schedule!r} not in "
                          f"{SCHEDULES}")]
    if schedule == "ring" and (family, strategy) not in _RING_LEGAL:
        v.append(Violation(
            "ring_undefined",
            f"ring schedule is undefined for ({family}, {strategy}); legal "
            f"pairs: {sorted(_RING_LEGAL)}"))
    if strategy == "expert_parallel":
        if family not in ("batched", "ragged"):
            v.append(Violation("strategy_family",
                               f"expert_parallel is undefined for {family}"))
        else:
            g = int(dims[0])
            if g % nshards:
                v.append(Violation(
                    "ep_indivisible",
                    f"{g} experts over {nshards} shards leaves ragged expert "
                    "placement; launch.sharding.expert_axis refuses this"))
    elif strategy == "k_parallel":
        if family != "dense":
            v.append(Violation("strategy_family",
                               f"k_parallel is undefined for {family}"))
        else:
            k = int(dims[1])
            if nshards > _cdiv(max(k, 1), sp.lane):
                v.append(Violation(
                    "kparallel_overshard",
                    f"{nshards} K-shards over K={k} leaves shards without a "
                    f"full {sp.lane}-wide panel", severity="warning"))
    return v


# ---------------------------------------------------------------------------
# Contract 2: grid coverage & write-race analysis
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KernelContract:
    """What a generated variant promises about its output stores.

    ``out_index_map`` is the kernel's REAL output BlockSpec index map (taken
    from ``kernels.ftimm.kernel``, not re-derived), evaluated over sampled
    grid points.  ``ordered_rmw`` marks the ragged masked read-modify-write,
    which is exempt from the exactly-once rule but must instead satisfy the
    sorted-visit-list contract (``check_ragged_visits``)."""
    name: str
    grid: tuple[int, ...]
    out_extent: tuple[int, ...]
    out_index_map: Callable[..., tuple[int, ...]]
    store_dims: tuple[int, ...]
    reduction_dims: tuple[int, ...]
    needs_k_mask: bool
    ordered_rmw: bool = False


def _samples(extent: int, cap: int) -> list[int]:
    """Boundary-biased sample of a grid dimension: the first ``cap`` points
    plus the last one (edge tiles live there)."""
    return sorted(set(range(min(extent, cap))) | {extent - 1})


def variant_contract(family: str, dims: Sequence[int], plan: Any, *,
                     trans: str = "nn", swiglu: bool = False
                     ) -> KernelContract:
    """Build the store contract for a generated dense/batched variant from
    the kernel module's actual BlockSpecs."""
    from ..kernels.ftimm import kernel as _kernel
    bm, bn, bk = int(plan.bm), int(plan.bn), int(plan.bk)
    nsplit = int(getattr(plan, "nsplit", 1))
    order = getattr(plan, "dim_order", "mn")
    if family == "dense":
        m, k, n = dims
        gm, gn, gk = _cdiv(m, bm), _cdiv(n, bn), _cdiv(k, bk)
        if nsplit > 1:
            # Split-K grid (nsplit, gm, gn, gk_per_split); the partials
            # output is (nsplit, gm, gn) blocks, indexed (s, i, j).
            gks = _cdiv(gk, nsplit)
            return KernelContract(
                name="ftimm_gemm_splitk",
                grid=(nsplit, gm, gn, gks),
                out_extent=(nsplit, gm, gn),
                out_index_map=lambda s, i, j, kb: (s, i, j),
                store_dims=(0, 1, 2), reduction_dims=(3,),
                needs_k_mask=bool(k % bk) or bool(gk % nsplit))
        c_spec = _kernel._specs(trans, bm, bn, bk, order)[2]
        grid = (gm, gn, gk) if order == "mn" else (gn, gm, gk)
        return KernelContract(
            name="ftimm_gemm_swiglu" if swiglu else "ftimm_gemm",
            grid=grid, out_extent=(gm, gn),
            out_index_map=c_spec.index_map,
            store_dims=(0, 1), reduction_dims=(2,),
            needs_k_mask=bool(k % bk))
    if family == "batched":
        g, m, k, n = dims
        gm, gn, gk = _cdiv(m, bm), _cdiv(n, bn), _cdiv(k, bk)
        c_spec = _kernel._batched_specs(trans, bm, bn, bk, order,
                                        a_batched=True, b_batched=True)[2]
        grid = (g, gm, gn, gk) if order == "mn" else (g, gn, gm, gk)
        return KernelContract(
            name="ftimm_gemm_grouped_swiglu" if swiglu else "ftimm_gemm_batched",
            grid=grid, out_extent=(g, gm, gn),
            out_index_map=c_spec.index_map,
            store_dims=(0, 1, 2), reduction_dims=(3,),
            needs_k_mask=bool(k % bk))
    raise ValueError(f"no static store contract for family {family!r} "
                     "(ragged is the ordered exception: check_ragged_visits)")


def verify_contract(contract: KernelContract, cap: int = 3
                    ) -> list[Violation]:
    """Symbolically evaluate the output index map over a boundary-biased
    sample of the grid: stores must be invariant to the reduction dims, land
    in range, collide on no two parallel grid points, and cover every sampled
    output block."""
    v: list[Violation] = []
    seen_codes: set[str] = set()

    def flag(code: str, msg: str) -> None:
        if code not in seen_codes:
            seen_codes.add(code)
            v.append(Violation(code, f"{contract.name}: {msg}"))

    store_samples = [_samples(contract.grid[d], cap)
                     for d in contract.store_dims]
    red_samples = [sorted({0, contract.grid[d] - 1})
                   for d in contract.reduction_dims]
    produced: dict[tuple[int, ...], tuple[int, ...]] = {}
    for pt in itertools.product(*store_samples):
        outs = set()
        for red in itertools.product(*red_samples):
            coords = [0] * len(contract.grid)
            for d, val in zip(contract.store_dims, pt):
                coords[d] = val
            for d, val in zip(contract.reduction_dims, red):
                coords[d] = val
            outs.add(tuple(int(x) for x in contract.out_index_map(*coords)))
        if len(outs) > 1:
            flag("store_moves_with_reduction",
                 f"store target varies over the reduction dim at grid point "
                 f"{pt}: {sorted(outs)}")
            continue
        idx = next(iter(outs))
        if len(idx) != len(contract.out_extent) or any(
                not 0 <= x < e for x, e in zip(idx, contract.out_extent)):
            flag("out_of_range_store",
                 f"grid point {pt} stores block {idx}, outside extent "
                 f"{contract.out_extent}")
            continue
        if idx in produced and not contract.ordered_rmw:
            flag("write_race",
                 f"grid points {produced[idx]} and {pt} both store output "
                 f"block {idx} — last-writer-wins is schedule-dependent")
        produced[idx] = pt
    expected = set(itertools.product(
        *(_samples(e, cap) for e in contract.out_extent)))
    missing = expected - set(produced)
    if missing:
        flag("coverage_gap",
             f"{len(missing)} sampled output blocks are never stored, e.g. "
             f"{sorted(missing)[:4]}")
    return v


# ---------------------------------------------------------------------------
# Contract 3: edge-mask soundness (AST inspection, no execution)
# ---------------------------------------------------------------------------

def masked_operand_count(fn: Callable[..., Any]) -> int:
    """How many distinct operands a kernel body routes through
    ``_mask_contract`` — the masked-edge kernels must mask the contraction
    remainder on EVERY operand of the dot (zeroing one side still multiplies
    the other side's garbage: 0 * NaN == NaN).  Counted from the AST."""
    try:
        src = textwrap.dedent(inspect.getsource(fn))
    except (OSError, TypeError):
        return -1
    masked: set[str] = set()
    for node in ast.walk(ast.parse(src)):
        if isinstance(node, ast.Call):
            f = node.func
            name = f.id if isinstance(f, ast.Name) else getattr(f, "attr", "")
            if name == "_mask_contract" and node.args:
                arg = node.args[0]
                masked.add(arg.id if isinstance(arg, ast.Name)
                           else ast.dump(arg))
    return len(masked)


def _calls(fn: Callable[..., Any], callee: str) -> bool:
    try:
        src = textwrap.dedent(inspect.getsource(fn))
    except (OSError, TypeError):
        return False
    for node in ast.walk(ast.parse(src)):
        if isinstance(node, ast.Call):
            f = node.func
            name = f.id if isinstance(f, ast.Name) else getattr(f, "attr", "")
            if name == callee:
                return True
    return False


def check_contraction_masking(accum_body: Callable[..., Any] | None = None,
                              swiglu_body: Callable[..., Any] | None = None,
                              dw_kernel: Callable[..., Any] | None = None
                              ) -> list[Violation]:
    """Prove (by AST) that every masked-edge kernel body masks all operands
    of its contraction: 2 for the dense/batched accumulate body (A and B),
    3 for the swiglu body (x, w_gate, w_up), and the ragged dW kernel must
    mask invalid token rows on its input side (``_ragged_row_mask``)."""
    if accum_body is None or swiglu_body is None or dw_kernel is None:
        from ..kernels.ftimm import kernel as _kernel
        accum_body = accum_body or _kernel._accum_body
        swiglu_body = swiglu_body or _kernel._swiglu_body
        dw_kernel = dw_kernel or _kernel._ragged_dw_kernel
    v: list[Violation] = []
    n = masked_operand_count(accum_body)
    if 0 <= n < 2:
        v.append(Violation(
            "missing_k_mask",
            f"dense accumulate body masks only {n} operand(s) of the "
            "contraction remainder; both A and B must be masked (0*NaN)"))
    n = masked_operand_count(swiglu_body)
    if 0 <= n < 3:
        v.append(Violation(
            "missing_k_mask",
            f"swiglu body masks only {n} operand(s); x, w_gate and w_up must "
            "all be masked"))
    if not _calls(dw_kernel, "_ragged_row_mask"):
        v.append(Violation(
            "missing_input_mask",
            "ragged dW kernel does not mask invalid token rows "
            "(_ragged_row_mask) — padded tokens would leak into dW"))
    return v


def _pad_priced(family: str, dims: Sequence[int], plan: Any, *,
                in_bytes: int, out_bytes: int, spec: Any,
                b_bytes: int | None = None) -> list[Violation]:
    """Padded-edge plans must carry a CMR estimate whose HBM traffic includes
    the pad round-trip copies (``cmr._pad_copy_bytes``)."""
    est = getattr(plan, "est", None)
    if est is None or getattr(est, "hbm_bytes", None) is None:
        return []
    bm, bn, bk = int(plan.bm), int(plan.bn), int(plan.bk)
    from ..core.gemm import cmr
    if family == "dense":
        m, k, n = dims
        if block_aligned((m, k, n), (bm, bk, bn)):
            return []
        floor = cmr.estimate(m, k, n, bm=bm, bn=bn, bk=bk,
                             nsplit=int(getattr(plan, "nsplit", 1)),
                             dim_order=getattr(plan, "dim_order", "mn"),
                             in_bytes=in_bytes, out_bytes=out_bytes,
                             spec=_spec(spec), edge="padded",
                             b_bytes=b_bytes).hbm_bytes
    elif family == "batched":
        g, m, k, n = dims
        if block_aligned((m, k, n), (bm, bk, bn)):
            return []
        floor = cmr.estimate_batched(g, m, k, n, bm=bm, bn=bn, bk=bk,
                                     dim_order=getattr(plan, "dim_order",
                                                       "mn"),
                                     in_bytes=in_bytes, out_bytes=out_bytes,
                                     spec=_spec(spec), edge="padded"
                                     ).hbm_bytes
    else:
        return []
    if est.hbm_bytes < floor - 0.5:
        return [Violation(
            "pad_copies_unpriced",
            f"padded-edge plan prices {est.hbm_bytes:.3g} HBM bytes but the "
            f"pad round-trip floor is {floor:.3g} — the tuner would compare "
            "it against masked plans with an unfair cost")]
    return []


# ---------------------------------------------------------------------------
# The umbrella check
# ---------------------------------------------------------------------------

def check_plan(family: str, dims: Sequence[int], plan: Any, *,
               in_bytes: int = 4, out_bytes: int = 4, spec: Any = None,
               epilogue: Any = None, swiglu: bool = False, ragged: str = "m",
               trans: str = "nn", coverage: bool = False,
               b_bytes: int | None = None) -> list[Violation]:
    """Check one plan (a ``tuner.GemmPlan``/``BatchedPlan``/``RaggedPlan`` or
    anything duck-typed like one) against every static contract.  With
    ``coverage=True`` the dense/batched store contract is also symbolically
    verified from the kernel's real index maps.  ``b_bytes`` declares a
    mixed-dtype B operand (the weight-only quantized paths) so the VMEM
    working set prices the narrow weight panel honestly."""
    sp = _spec(spec)
    bm = getattr(plan, "bm", None)
    v: list[Violation] = []
    if bm is not None:
        nsplit = int(getattr(plan, "nsplit", 1))
        v += check_blocks(family, dims, bm=int(plan.bm), bn=int(plan.bn),
                          bk=int(plan.bk), nsplit=nsplit,
                          dim_order=getattr(plan, "dim_order", "mn"),
                          edge=getattr(plan, "edge", "masked"),
                          in_bytes=in_bytes, out_bytes=out_bytes,
                          ragged=ragged, spec=sp)
        base = vmem_footprint(family, bm=int(plan.bm), bn=int(plan.bn),
                              bk=int(plan.bk), in_bytes=in_bytes,
                              out_bytes=out_bytes, nsplit=nsplit,
                              ragged=ragged, b_bytes=b_bytes)
        if base > sp.vmem_budget:
            v.append(Violation(
                "vmem_budget",
                f"per-step working set {base} B exceeds the "
                f"{sp.vmem_budget} B VMEM budget"))
        else:
            full = vmem_footprint(family, bm=int(plan.bm), bn=int(plan.bn),
                                  bk=int(plan.bk), in_bytes=in_bytes,
                                  out_bytes=out_bytes, nsplit=nsplit,
                                  ragged=ragged, epilogue=epilogue,
                                  swiglu=swiglu, b_bytes=b_bytes)
            if full > sp.vmem_budget:
                # The tuner admits candidates on the base formula (matching
                # cmr.estimate); extra epilogue/swiglu inputs pushing past
                # the budget is a pricing gap, reported but not fatal.
                v.append(Violation(
                    "vmem_budget_extras",
                    f"working set {full} B incl. epilogue/swiglu inputs "
                    f"exceeds the {sp.vmem_budget} B budget (base {base} B "
                    "fits — the CMR formula under-prices the extras)",
                    severity="warning"))
        v += check_schedule(nsplit=nsplit, fuse=getattr(plan, "fuse", True),
                            epilogue=epilogue, swiglu=swiglu)
        if getattr(plan, "edge", "masked") == "padded":
            v += _pad_priced(family, dims, plan, in_bytes=in_bytes,
                             out_bytes=out_bytes, spec=sp, b_bytes=b_bytes)
    placement = getattr(plan, "placement", None)
    if placement is not None and int(getattr(placement, "num_shards", 1)) > 1:
        v += check_placement(family, dims, placement, spec=sp)
    if (coverage and bm is not None and family in ("dense", "batched")
            and not errors(v)):
        v += verify_contract(variant_contract(family, dims, plan, trans=trans,
                                              swiglu=swiglu))
    return v


def assert_plan(family: str, dims: Sequence[int], plan: Any,
                **kwargs: Any) -> None:
    """Raise ``ContractError`` when any error-severity contract is violated —
    the ``REPRO_VERIFY=1`` dispatch hook."""
    bad = errors(check_plan(family, dims, plan, **kwargs))
    if bad:
        raise ContractError(bad, context=f"{family}{tuple(dims)}")


# ---------------------------------------------------------------------------
# The ragged ordered exception: sorted visit lists
# ---------------------------------------------------------------------------

def check_ragged_visits(offsets: Sequence[int], m_tiles: int, bm: int,
                        gids: Sequence[int], tids: Sequence[int],
                        valid: Sequence[int]) -> list[Violation]:
    """The ragged kernels' masked boundary-tile read-modify-write is sound
    ONLY when the visit list walks tiles in sorted order (the ``first`` flag
    in ``_ragged_store`` keys off the PREVIOUS entry) and groups in sorted
    order (the dW kernel flushes on group change).  Prove it concretely."""
    v: list[Violation] = []
    off = [int(x) for x in offsets]
    if not off or off[0] != 0 or any(b < a for a, b in zip(off, off[1:])):
        return [Violation("bad_offsets",
                          f"group offsets must be a non-decreasing prefix sum "
                          f"starting at 0, got {off[:8]}...")]
    ngroups = len(off) - 1
    vals = [int(x) for x in valid]
    if any(b > a for a, b in zip(vals, vals[1:])):
        v.append(Violation("ragged_valid_not_prefix",
                           "valid flags are not a 1s-prefix; the kernel "
                           "early-outs on the first invalid visit"))
    entries = [(int(g), int(t))
               for g, t, ok in zip(gids, tids, vals) if ok]
    tt = [t for _, t in entries]
    if tt != sorted(tt):
        v.append(Violation(
            "unsorted_visits",
            "visit tile ids are not non-decreasing — the masked boundary-tile "
            "read-modify-write requires same-tile visits adjacent and "
            "ascending (the ordered exception to exactly-once stores)"))
    gg = [g for g, _ in entries]
    if gg != sorted(gg):
        v.append(Violation(
            "unsorted_groups",
            "visit group ids are not non-decreasing — the dW accumulate/flush "
            "keys off group boundaries"))
    if len(set(entries)) != len(entries):
        v.append(Violation("duplicate_visit",
                           "a (group, tile) pair is visited twice — its rows "
                           "would be accumulated twice"))
    expected: set[tuple[int, int]] = set()
    for g in range(ngroups):
        s, e = off[g], off[g + 1]
        for t in range(s // bm, _cdiv(e, bm) if e > s else s // bm):
            expected.add((g, t))
    actual = set(entries)
    missing = expected - actual
    if missing:
        v.append(Violation(
            "ragged_row_uncovered",
            f"{len(missing)} (group, tile) row panels are never visited, "
            f"e.g. {sorted(missing)[:4]} — those output rows are dropped"))
    nonempty_extra = {(g, t) for g, t in actual - expected
                      if off[g + 1] > off[g]}
    if nonempty_extra:
        v.append(Violation(
            "ragged_extra_visit",
            f"visits outside the groups' row ranges: "
            f"{sorted(nonempty_extra)[:4]}"))
    present = set(gg)
    missing_groups = [g for g in range(ngroups)
                      if off[g + 1] == off[g] and g not in present]
    if missing_groups:
        v.append(Violation(
            "ragged_missing_empty_group",
            f"empty groups {missing_groups[:8]} get no forced visit — the dW "
            "kernel would never flush their zero panel", severity="warning"))
    out_of_range = [t for t in tt if not 0 <= t < max(m_tiles, 1)]
    if out_of_range:
        v.append(Violation("out_of_range_store",
                           f"visit tile ids {out_of_range[:4]} outside the "
                           f"{m_tiles} row tiles"))
    return v


def check_ragged_visit_plan(offsets: Sequence[int], bm: int
                            ) -> list[Violation]:
    """Build the ragged visit metadata exactly as the ops wrappers do (via
    ``ops._ragged_metadata`` — concrete evaluation, no kernel launch) and
    check the sorted-visit contract on it."""
    from ..kernels.ftimm import ops as _ops
    import numpy as np
    off = np.asarray(list(offsets), dtype=np.int32)
    total = int(off[-1]) if len(off) else 0
    m_tiles = _ceil_to(max(total, 1), bm) // bm
    gids, tids, valid = _ops._ragged_metadata(off, m_tiles, bm)
    return check_ragged_visits(
        [int(x) for x in off], m_tiles, bm,
        np.asarray(gids).tolist(), np.asarray(tids).tolist(),
        np.asarray(valid).tolist())


# ---------------------------------------------------------------------------
# Cached-record validation (plan_store load-time quarantine)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RecordKey:
    """A parsed ``plan_store.shape_key``."""
    family: str
    dims: tuple[int, ...]
    in_bytes: int
    out_bytes: int
    num_shards: int = 1
    extra: str = ""


def parse_key(key: str) -> RecordKey | None:
    """Parse ``family|MxKxN|ib4|ob4[|extra][|shardsN]`` (the plan_store key
    grammar); ``None`` when malformed."""
    parts = key.split("|")
    if len(parts) < 4:
        return None
    family = parts[0]
    try:
        dims = tuple(int(x) for x in parts[1].split("x"))
        if not (parts[2].startswith("ib") and parts[3].startswith("ob")):
            return None
        in_bytes, out_bytes = int(parts[2][2:]), int(parts[3][2:])
    except ValueError:
        return None
    num_shards, extra = 1, ""
    for p in parts[4:]:
        if p.startswith("shards"):
            try:
                num_shards = int(p[6:])
            except ValueError:
                return None
        else:
            extra = p
    return RecordKey(family, dims, in_bytes, out_bytes, num_shards, extra)


_EXPECTED_NDIMS = {"dense": 3, "batched": 4, "ragged": 4}


def check_record(key: str, rec: Any, spec: Any = None) -> list[Violation]:
    """Validate one cached plan-store record against the static contracts —
    the load-time quarantine gate.  Unknown families pass (forward compat);
    malformed keys/records and contract violations are errors."""
    sp = _spec(spec)
    pk = parse_key(key)
    if pk is None:
        return [Violation("bad_key", f"unparseable plan-store key {key!r}")]
    if pk.family not in FAMILIES:
        return []
    if len(pk.dims) != _EXPECTED_NDIMS[pk.family]:
        return [Violation("bad_key",
                          f"{pk.family} key wants {_EXPECTED_NDIMS[pk.family]}"
                          f" dims, got {pk.dims}")]
    if not isinstance(rec, dict):
        return [Violation("bad_record", "record is not a mapping")]
    try:
        bm, bn, bk = int(rec["bm"]), int(rec["bn"]), int(rec["bk"])
        nsplit = int(rec.get("nsplit", 1))
        dim_order = str(rec.get("dim_order", "mn"))
        edge = str(rec.get("edge", "masked"))
    except (KeyError, TypeError, ValueError):
        return [Violation("bad_record",
                          f"record for {key!r} is missing/mistyping block "
                          "fields")]
    # Parse the extra: "+"-joined variant markers — the ragged axis and the
    # mixed-dtype B width ("bb1" = int8/fp8 weights against wider
    # activations, the dtype axis of the plan key).
    ragged_axis, b_bytes = "m", None
    for part in pk.extra.split("+"):
        if part.startswith("ragged:"):
            ragged_axis = part[len("ragged:"):]
        elif part.startswith("bb"):
            try:
                b_bytes = int(part[2:])
            except ValueError:
                return [Violation("bad_key",
                                  f"unparseable mixed-dtype marker "
                                  f"{part!r} in {key!r}")]
    if b_bytes is not None and nsplit > 1:
        # Conservative quarantine: the measured store never times split-K
        # mixed-dtype variants (the tuner does not generate them), so a
        # cached record claiming one is corrupt or foreign.
        return [Violation(
            "splitk_mixed_dtype",
            f"cached mixed-dtype record (bb{b_bytes}) claims nsplit={nsplit};"
            " no measured split-K mixed-width variant exists")]
    if pk.num_shards > 1:
        strategy = rec.get("strategy")
        if strategy not in STRATEGIES:
            return [Violation("bad_strategy",
                              f"sharded record strategy {strategy!r} not in "
                              f"{STRATEGIES}")]
        schedule = rec.get("schedule", "gather")
        if schedule not in SCHEDULES:
            return [Violation("bad_schedule",
                              f"sharded record schedule {schedule!r} not in "
                              f"{SCHEDULES}")]
        v: list[Violation] = []
        if schedule == "ring" and (pk.family, strategy) not in _RING_LEGAL:
            v.append(Violation(
                "ring_undefined",
                f"ring schedule cached for ({pk.family}, {strategy})"))
        if (strategy == "expert_parallel" and pk.family in ("batched",
                                                            "ragged")
                and pk.dims[0] % pk.num_shards):
            v.append(Violation(
                "ep_indivisible",
                f"{pk.dims[0]} experts cached over {pk.num_shards} shards"))
        if min(bm, bn, bk) <= 0 or nsplit <= 0:
            v.append(Violation("nonpositive_block",
                               f"bm={bm} bn={bn} bk={bk} nsplit={nsplit}"))
        return v
    v = check_blocks(pk.family, pk.dims, bm=bm, bn=bn, bk=bk, nsplit=nsplit,
                     dim_order=dim_order, edge=edge, in_bytes=pk.in_bytes,
                     out_bytes=pk.out_bytes, ragged=ragged_axis, spec=sp)
    if not errors(v):
        footprint = vmem_footprint(pk.family, bm=bm, bn=bn, bk=bk,
                                   in_bytes=pk.in_bytes,
                                   out_bytes=pk.out_bytes, nsplit=nsplit,
                                   ragged=ragged_axis, b_bytes=b_bytes)
        if footprint > sp.vmem_budget:
            v.append(Violation(
                "vmem_budget",
                f"cached record's working set {footprint} B exceeds the "
                f"{sp.vmem_budget} B VMEM budget"))
    return v
