"""Static analysis of generated ftIMM kernel variants and GEMM plans.

``contracts`` proves plans safe without executing any kernel; ``sweep`` is
the CLI ratchet (``python -m repro.analysis.sweep``) that checks the full
candidate space for the paper's irregular shapes plus every registry config.
"""
from .contracts import (
    ContractError,
    KernelContract,
    RecordKey,
    Violation,
    assert_plan,
    block_aligned,
    check_blocks,
    check_contraction_masking,
    check_placement,
    check_plan,
    check_ragged_visit_plan,
    check_ragged_visits,
    check_record,
    check_schedule,
    errors,
    masked_operand_count,
    parse_key,
    variant_contract,
    verify_contract,
    vmem_footprint,
)

__all__ = [
    "ContractError",
    "KernelContract",
    "RecordKey",
    "Violation",
    "assert_plan",
    "block_aligned",
    "check_blocks",
    "check_contraction_masking",
    "check_placement",
    "check_plan",
    "check_ragged_visit_plan",
    "check_ragged_visits",
    "check_record",
    "check_schedule",
    "errors",
    "masked_operand_count",
    "parse_key",
    "variant_contract",
    "verify_contract",
    "vmem_footprint",
]
