"""Fault tolerance & straggler mitigation for multi-pod training.

Design for 1000+ nodes (DESIGN.md §6); mechanisms implemented & unit-tested
here, exercised against simulated hosts in tests/test_runtime.py:

* HeartbeatMonitor — every host records a heartbeat per step; hosts silent
  past ``dead_after`` are failed, hosts slower than ``straggler_factor`` x
  median step time are flagged (mitigation at this scale is exclusion +
  elastic restart, since SPMD steps are barrier-synchronous).
* ElasticPlan — given the surviving host/chip count, choose the largest
  (data, model) mesh <= survivors that preserves TP degree (params reshard
  cleanly) and keeps global batch divisible; the trainer then restores the
  latest checkpoint onto the new mesh (Checkpointer.restore re-shards) and
  replays the data stream deterministically from (seed, step).
* TrainSupervisor — retry-with-shrink loop: run -> on failure, compute the
  elastic plan, restore, continue.  The deterministic data pipeline makes
  the recovery exactly-once w.r.t. optimizer steps.
"""
from __future__ import annotations

import dataclasses
import statistics
import time


@dataclasses.dataclass
class HostState:
    last_beat: float
    last_step: int = -1
    step_times: list = dataclasses.field(default_factory=list)


class HeartbeatMonitor:
    def __init__(self, hosts: list[str], *, dead_after: float = 60.0,
                 straggler_factor: float = 2.0, clock=time.monotonic):
        self.clock = clock
        self.dead_after = dead_after
        self.straggler_factor = straggler_factor
        now = clock()
        self.hosts = {h: HostState(last_beat=now) for h in hosts}

    def beat(self, host: str, step: int) -> None:
        st = self.hosts[host]
        now = self.clock()
        if st.last_step >= 0 and step > st.last_step:
            st.step_times.append((now - st.last_beat) / (step - st.last_step))
            st.step_times = st.step_times[-32:]
        st.last_beat = now
        st.last_step = step

    def dead_hosts(self) -> list[str]:
        now = self.clock()
        return [h for h, st in self.hosts.items()
                if now - st.last_beat > self.dead_after]

    def stragglers(self) -> list[str]:
        times = {h: (sum(st.step_times) / len(st.step_times))
                 for h, st in self.hosts.items() if st.step_times}
        if len(times) < 2:
            return []
        med = statistics.median(times.values())
        return [h for h, t in times.items()
                if t > self.straggler_factor * med]

    def remove(self, host: str) -> None:
        self.hosts.pop(host, None)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    chips: int
    dropped_chips: int

    @property
    def mesh_shape(self) -> tuple[int, int]:
        return (self.data, self.model)


def plan_elastic_mesh(surviving_chips: int, *, model_parallel: int,
                      global_batch: int) -> ElasticPlan:
    """Largest (data, model) grid that fits the survivors, keeping the TP
    degree fixed (so param shards stay valid) and dp | global_batch."""
    if surviving_chips < model_parallel:
        raise ValueError(
            f"fewer chips ({surviving_chips}) than TP degree "
            f"({model_parallel}); cannot re-mesh")
    dp = surviving_chips // model_parallel
    while dp > 1 and global_batch % dp != 0:
        dp -= 1
    chips = dp * model_parallel
    return ElasticPlan(data=dp, model=model_parallel, chips=chips,
                       dropped_chips=surviving_chips - chips)


class TrainSupervisor:
    """Checkpoint-restart driver: run the step loop, and on a failure event
    re-mesh + restore + resume.  ``run_fn(start_step, mesh_shape)`` should
    raise ``HostFailure`` (or any exception) to signal a lost host."""

    def __init__(self, *, checkpointer, model_parallel: int,
                 global_batch: int, total_chips: int, max_retries: int = 3):
        self.ckpt = checkpointer
        self.tp = model_parallel
        self.gb = global_batch
        self.chips = total_chips
        self.max_retries = max_retries
        self.history: list[dict] = []

    def run(self, run_fn) -> int:
        chips = self.chips
        for attempt in range(self.max_retries + 1):
            plan = plan_elastic_mesh(chips, model_parallel=self.tp,
                                     global_batch=self.gb)
            start = (self.ckpt.latest_step() or -1) + 1
            self.history.append({"attempt": attempt, "chips": plan.chips,
                                 "mesh": plan.mesh_shape, "start": start})
            try:
                return run_fn(start, plan.mesh_shape)
            except HostFailure as e:
                self.history.append({"attempt": attempt,
                                     "failure": type(e).__name__,
                                     "lost_chips": e.lost_chips})
                chips = plan.chips - e.lost_chips
        raise RuntimeError("exhausted retries")


class HostFailure(Exception):
    def __init__(self, lost_chips: int, msg: str = ""):
        super().__init__(msg or f"lost {lost_chips} chips")
        self.lost_chips = lost_chips
