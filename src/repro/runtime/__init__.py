from .fault_tolerance import (ElasticPlan, HeartbeatMonitor, HostFailure,
                              TrainSupervisor, plan_elastic_mesh)

__all__ = ["ElasticPlan", "HeartbeatMonitor", "HostFailure",
           "TrainSupervisor", "plan_elastic_mesh"]
