from . import chaos
from .chaos import Fault, FaultPlan
from .fault_tolerance import (ElasticPlan, HeartbeatMonitor, HostFailure,
                              TrainSupervisor, plan_elastic_mesh)

__all__ = ["ElasticPlan", "Fault", "FaultPlan", "HeartbeatMonitor",
           "HostFailure", "TrainSupervisor", "chaos", "plan_elastic_mesh"]
