"""Elastic re-planned training: the glue between fault tolerance and the
real execution stack.

``fault_tolerance.TrainSupervisor`` is the jax-free retry-with-shrink state
machine; this module wires the same loop to the production pieces so a
``HostFailure`` (real, or injected via ``runtime.chaos``'s ``shard_loss``
site in the trainer's step loop) actually recovers:

  1. **re-mesh** — ``plan_elastic_mesh`` keeps the TP degree and shrinks
     data-parallel to the survivors; ``launch.mesh.mesh_from_plan`` builds
     the smaller (data, model) mesh on the surviving devices.
  2. **invalidate** — every plan-serving cache that closed over the old
     mesh is dropped (``invalidate_plans``): the five planner LRUs, the
     dispatch-level custom-VJP closures, and the bounded mesh-keyed EP
     executor caches.  The persistent plan store is NOT reset — its keys
     carry the ``|shards{n}`` suffix, so plans measured at the old shard
     count are unreachable at the new one by construction, and plans for
     the new count stay warm.  Telemetry counters survive so
     ``plan_mode_stats()`` shows the re-plan happening.
  3. **restore** — the next ``Trainer`` restores the latest checkpoint
     onto the new mesh (``Checkpointer.restore`` re-shards to the new
     shardings) and replays the deterministic data stream from the
     checkpointed step — recovery is exactly-once w.r.t. optimizer steps.

Import note: ``runtime.fault_tolerance``/``runtime.chaos`` stay jax-free;
this module imports the jax-side stack and is therefore NOT re-exported
from ``repro.runtime`` — import it as ``repro.runtime.elastic``.
"""
from __future__ import annotations

from .fault_tolerance import HostFailure, plan_elastic_mesh


def invalidate_plans() -> None:
    """Drop every cache that may have closed over the old mesh/shard count:
    planner LRUs, dispatch custom-VJP closures, EP executor closures.
    Keeps the persistent plan store (shard-count-suffixed keys) and the
    telemetry counters (the re-plan should be observable)."""
    from ..core.gemm.dispatch import clear_dispatch_caches
    from ..core.gemm.distributed import clear_executor_caches
    from ..core.gemm.tuner import clear_planner_caches
    clear_planner_caches()
    clear_dispatch_caches()
    clear_executor_caches()


class ElasticRunner:
    """Checkpoint-restart training on a shrinking mesh.

    Runs ``Trainer`` attempts until ``num_steps`` completes: each attempt
    plans the largest TP-preserving mesh for the surviving chips, rebuilds
    shardings for it, invalidates the stale executor caches, and resumes
    from the latest checkpoint with deterministic data replay.  A
    ``HostFailure`` out of the step loop (e.g. the ``shard_loss`` chaos
    site) shrinks the survivor count and retries; anything else
    propagates.  ``history`` records every attempt and failure;
    ``metrics_log`` accumulates the per-attempt step metrics in order."""

    def __init__(self, cfg, shape, opt_cfg=None, *, ckpt_dir,
                 model_parallel: int = 1, total_chips: int | None = None,
                 max_retries: int = 3, seed: int = 0, ckpt_every: int = 50,
                 log_every: int = 10, monitor=None):
        if not ckpt_dir:
            raise ValueError("elastic training requires a checkpoint dir "
                             "(recovery restores from it)")
        self.cfg = cfg
        self.shape = shape
        self.opt_cfg = opt_cfg
        self.ckpt_dir = ckpt_dir
        self.tp = model_parallel
        self.total_chips = total_chips
        self.max_retries = max_retries
        self.seed = seed
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.monitor = monitor
        self.history: list[dict] = []
        self.metrics_log: list[dict] = []

    def _shardings(self, mesh) -> dict:
        from ..launch.dryrun import abstract_state, input_specs
        from ..launch.sharding import batch_specs, param_specs, to_shardings
        params_s, opt_s = abstract_state(self.cfg, self.shape, with_opt=True)
        batch_s = input_specs(self.cfg, self.shape)
        sh = {
            "params": to_shardings(param_specs(params_s, mesh), mesh),
            "opt": to_shardings(param_specs(opt_s, mesh), mesh),
            "batch": to_shardings(batch_specs(self.cfg, batch_s, mesh),
                                  mesh),
        }
        sh["batch_leaves"] = sh["batch"]
        return sh

    def run(self, num_steps: int):
        import jax

        from ..launch.mesh import mesh_from_plan
        from ..train.trainer import Trainer

        chips = self.total_chips or len(jax.devices())
        for attempt in range(self.max_retries + 1):
            plan = plan_elastic_mesh(chips, model_parallel=self.tp,
                                     global_batch=self.shape.global_batch)
            mesh = mesh_from_plan(plan)
            invalidate_plans()
            trainer = Trainer(self.cfg, self.shape, self.opt_cfg,
                              mesh=mesh, shardings=self._shardings(mesh),
                              seed=self.seed, ckpt_dir=self.ckpt_dir,
                              ckpt_every=self.ckpt_every,
                              monitor=self.monitor,
                              log_every=self.log_every)
            start = (trainer.ckpt.latest_step() or -1) + 1
            self.history.append({"attempt": attempt, "chips": plan.chips,
                                 "mesh": plan.mesh_shape, "start": start})
            try:
                result = trainer.run(num_steps)
                self.metrics_log.extend(trainer.metrics_log)
                return result
            except HostFailure as e:
                self.metrics_log.extend(trainer.metrics_log)
                self.history.append({"attempt": attempt,
                                     "failure": type(e).__name__,
                                     "lost_chips": e.lost_chips})
                chips = plan.chips - e.lost_chips
        raise RuntimeError("exhausted elastic retries")
