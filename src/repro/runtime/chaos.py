"""Deterministic fault injection for chaos-testing the degradation paths.

Production serving meets shard loss, straggler collectives, corrupt plan
caches, kernel-launch failures and non-finite numerics as a matter of
routine.  This module makes every one of those failure modes a *seeded,
replayable event* so the graceful-degradation machinery (the dispatch
fallback ladder, the elastic re-planning supervisor, the serve engine's
containment guards) can be exercised in CI instead of discovered in an
incident.

A ``FaultPlan`` is a list of ``Fault`` specs, each naming an injection
*site* and which occurrences of that site should fail.  Sites are armed by
probe calls the production code already makes (``fire``/``should_fire``) —
when no plan is active the probe is one attribute read, so the hot paths
pay nothing.

Sites wired in this repo:

    kernel          any planned ftIMM kernel launch (dispatch ladder)
    kernel_fused    only the fused-epilogue kernel (fused -> unfused rung)
    ep_ring         the EP ring-schedule executor (ring -> gather rung)
    ep_gather       the EP gather exchange (gather -> single-device rung)
    shard_loss      a training step boundary (raises ``HostFailure``;
                    payload ``chips`` = lost chip count)
    nan_logits      serve decode output (poisons one slot's logits row;
                    payload ``slot``)
    transient_decode  serve decode call (raises ``TransientFault`` — the
                    retry/backoff path)
    slow_step       a sleep at the armed site (straggler simulation;
                    payload ``delay_s``)
    plan_save_crash plan-store ``save`` between temp-write and rename
                    (the crash-mid-save atomicity test)
    page_exhaustion serve KV page allocation (forces the allocator to
                    report exhaustion -> the preempt/re-prefill path even
                    when free pages remain)
    bucket_miss     serve prefill bucket lookup (forces a miss -> the
                    legacy exact-length prefill fallback rung)
    burst_arrival   the serve benchmark's arrival process (payload
                    ``burst`` = extra arrivals injected at once)

Activation: ``chaos(plan)`` context manager, or the ``REPRO_CHAOS`` env
var (``site@occurrence[xcount][:key=value,...]`` specs joined by ``;``,
e.g. ``REPRO_CHAOS="kernel@0;shard_loss@3:chips=4"``) for subprocess /
CI legs.  Injection happens at probe time (usually jax trace time), so a
given program replays identically under the same plan — the point.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time

ENV_VAR = "REPRO_CHAOS"

# Every probe site wired in the repo (the docstring above documents each).
# ``parse_env`` validates against this set so a typo'd CI spec fails loudly
# at startup instead of silently arming nothing.  Programmatic ``Fault``
# construction is NOT gated on it (tests invent sites freely).
KNOWN_SITES = frozenset({
    "kernel", "kernel_fused", "ep_ring", "ep_gather", "shard_loss",
    "nan_logits", "transient_decode", "slow_step", "plan_save_crash",
    "page_exhaustion", "bucket_miss", "burst_arrival",
})


class ChaosError(RuntimeError):
    """Base class for injected faults (tells handlers the failure is
    synthetic; real exceptions take the same degradation paths)."""


class KernelLaunchFailure(ChaosError):
    """Injected at the ``kernel``/``kernel_fused`` sites."""


class CollectiveFailure(ChaosError):
    """Injected at the ``ep_ring``/``ep_gather`` sites."""


class TransientFault(ChaosError):
    """A retryable fault (serve decode): succeeds on retry by construction
    because occurrences are count-based."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """Fail occurrences ``[at, at + count)`` of ``site``."""
    site: str
    at: int = 0
    count: int = 1
    chips: int = 1          # shard_loss payload: lost chip count
    slot: int = 0           # nan_logits payload: which serve slot
    delay_s: float = 0.0    # slow_step payload
    burst: int = 1          # burst_arrival payload: extra arrivals at once


class FaultPlan:
    """Seeded, replayable schedule of injected faults.

    ``seed`` keys nothing random inside the plan itself (occurrence
    selection is explicit) but is carried so helpers like ``corrupt_json``
    derive their deterministic corruption from the plan, and so two runs
    labelled with the same seed are bit-identical chaos."""

    def __init__(self, faults: list[Fault] | tuple = (), *, seed: int = 0):
        self.faults = list(faults)
        self.seed = seed
        self.counters: dict[str, int] = {}   # site -> occurrences armed
        self.fired: dict[str, int] = {}      # site -> faults injected

    def should_fire(self, site: str) -> Fault | None:
        """Arm one occurrence of ``site``; the matching Fault when this
        occurrence is scheduled to fail, else None."""
        n = self.counters.get(site, 0)
        self.counters[site] = n + 1
        for f in self.faults:
            if f.site == site and f.at <= n < f.at + f.count:
                self.fired[site] = self.fired.get(site, 0) + 1
                return f
        return None

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FaultPlan(seed={self.seed}, faults={self.faults})"


_PAYLOAD_KEYS = frozenset({"chips", "slot", "delay_s", "burst"})


def _bad_segment(segment: str, why: str) -> ValueError:
    return ValueError(
        f"malformed {ENV_VAR} segment {segment!r}: {why} "
        f"(expected site@occurrence[xcount][:key=value,...])")


def parse_env(spec: str) -> FaultPlan:
    """``site@occurrence[xcount][:k=v,...]`` specs joined by ``;``.
    A bare ``seed=N`` entry sets the plan seed.

    Malformed specs raise ``ValueError`` naming the offending segment — a
    typo'd CI chaos leg must fail at startup, not silently arm nothing:
    unknown site names, non-integer occurrences/counts, unknown payload
    keys, and empty segments (a trailing/doubled ``;``) are all rejected.
    """
    faults: list[Fault] = []
    seed = 0
    if not spec.strip():
        return FaultPlan(faults, seed=seed)
    segments = [p.strip() for p in spec.split(";")]
    for i, raw in enumerate(segments):
        if not raw:
            if i == len(segments) - 1:
                raise _bad_segment(spec, "trailing ';' leaves an empty "
                                         "segment")
            raise _bad_segment(spec, f"empty segment at position {i}")
        if raw.startswith("seed="):
            try:
                seed = int(raw[5:])
            except ValueError:
                raise _bad_segment(raw, "seed must be an integer") from None
            continue
        part = raw
        payload: dict = {}
        if ":" in part:
            part, kv = part.split(":", 1)
            for item in filter(None, kv.split(",")):
                if "=" not in item:
                    raise _bad_segment(raw, f"payload {item!r} is not "
                                            "key=value")
                k, v = item.split("=", 1)
                if k not in _PAYLOAD_KEYS:
                    raise _bad_segment(
                        raw, f"unknown payload key {k!r} "
                             f"(known: {', '.join(sorted(_PAYLOAD_KEYS))})")
                try:
                    payload[k] = float(v) if k == "delay_s" else int(v)
                except ValueError:
                    raise _bad_segment(raw, f"payload {k}={v!r} is not "
                                            "numeric") from None
        at, count = 0, 1
        if "@" in part:
            part, occ = part.split("@", 1)
            occ_raw, cnt = occ, None
            if "x" in occ:
                occ, cnt = occ.split("x", 1)
            try:
                at = int(occ)
            except ValueError:
                raise _bad_segment(raw, f"occurrence {occ_raw!r} is not an "
                                        "integer") from None
            if cnt is not None:
                try:
                    count = int(cnt)
                except ValueError:
                    raise _bad_segment(raw, f"count {cnt!r} is not an "
                                            "integer") from None
        if part not in KNOWN_SITES:
            raise _bad_segment(raw, f"unknown site {part!r} "
                                    f"(known: {', '.join(sorted(KNOWN_SITES))})")
        faults.append(Fault(site=part, at=at, count=count, **payload))
    return FaultPlan(faults, seed=seed)


# Process-global active plan: None (the fast path) until the env var or the
# context manager installs one.
_ACTIVE: FaultPlan | None = None
_env_checked = False


def active() -> FaultPlan | None:
    """The installed plan, arming ``REPRO_CHAOS`` on first use."""
    global _ACTIVE, _env_checked
    if not _env_checked:
        _env_checked = True
        spec = os.environ.get(ENV_VAR)
        if spec:
            _ACTIVE = parse_env(spec)
    return _ACTIVE


@contextlib.contextmanager
def chaos(plan: FaultPlan | None):
    """Install ``plan`` as the active fault schedule for the block."""
    global _ACTIVE, _env_checked
    old, old_checked = _ACTIVE, _env_checked
    _ACTIVE, _env_checked = plan, True
    try:
        yield plan
    finally:
        _ACTIVE, _env_checked = old, old_checked


def should_fire(site: str) -> Fault | None:
    """Probe one occurrence of ``site`` (no-op without an active plan)."""
    plan = active()
    return plan.should_fire(site) if plan is not None else None


def fire(site: str) -> None:
    """Probe ``site`` and raise its fault class when armed."""
    f = should_fire(site)
    if f is None:
        return
    if site in ("kernel", "kernel_fused"):
        raise KernelLaunchFailure(f"injected {site} failure")
    if site in ("ep_ring", "ep_gather"):
        raise CollectiveFailure(f"injected {site} failure")
    if site == "transient_decode":
        raise TransientFault("injected transient decode fault")
    if site == "shard_loss":
        # Local import: runtime.fault_tolerance is sibling, jax-free.
        from .fault_tolerance import HostFailure
        raise HostFailure(f.chips, "injected shard loss")
    if site == "plan_save_crash":
        raise ChaosError("injected crash between temp write and rename")
    raise ChaosError(f"injected {site} fault")


def maybe_delay(site: str = "slow_step") -> float:
    """Sleep the armed fault's ``delay_s`` (straggler simulation); returns
    the delay actually injected (0.0 when the site didn't fire)."""
    f = should_fire(site)
    if f is None or f.delay_s <= 0:
        return 0.0
    time.sleep(f.delay_s)
    return f.delay_s


def poison_logits(logits, site: str = "nan_logits"):
    """NaN-poison one slot's row of a host-side logits array when the site
    fires (simulates a kernel emitting non-finite values).  Returns the
    (possibly copied) array — callers feed it to their non-finite guard."""
    f = should_fire(site)
    if f is None:
        return logits
    import numpy as np
    out = np.array(logits, copy=True)
    out[min(f.slot, out.shape[0] - 1)] = np.nan
    return out


def corrupt_json(path: str, *, seed: int | None = None,
                 mode: str = "truncate") -> None:
    """Deterministically corrupt a JSON file in place — the
    corrupted/truncated plan-cache-record fault.  ``truncate`` cuts the
    file mid-record at a seed-derived offset; ``scramble`` flips bytes at
    seed-derived positions (valid-length, invalid-content)."""
    plan = active()
    if seed is None:
        seed = plan.seed if plan is not None else 0
    with open(path, "rb") as fp:
        raw = bytearray(fp.read())
    if len(raw) < 4:
        raw = bytearray(b"{" * 4)
    if mode == "truncate":
        cut = 1 + (seed * 2654435761 % max(len(raw) - 2, 1))
        raw = raw[:cut]
    elif mode == "scramble":
        for i in range(8):
            pos = (seed * 2654435761 + i * 40503) % len(raw)
            raw[pos] = (raw[pos] + 13) % 256
    else:
        raise ValueError(f"unknown corruption mode: {mode!r}")
    with open(path, "wb") as fp:
        fp.write(bytes(raw))
