"""Paged KV cache: a page-pool + free-list allocator so serve slot count
and sequence length stop being compile-time constants.

The dense slot cache allocates ``slots x max_len`` KV rows up front — every
slot pays for the longest request the engine might ever see.  Paging (the
vLLM idea, fitted to this repo's layer-scanned cache layout) breaks the
cache into fixed ``page_size``-row pages in one physical pool:

  * each request owns just enough pages for its current depth, acquired
    from a host-side free list as decode crosses page boundaries;
  * the decode step receives a ``(slots, max_pages)`` page table; attention
    gathers each slot's logical view out of the pool and scatters the new
    token's K/V at its physical row (``models.attention``, paged branch);
  * physical page 0 is RESERVED as the null target: unallocated page-table
    entries point at it, inactive slots write their garbage row into it,
    and the per-row position masks keep it out of every softmax.

Exhaustion safety is the engine's contract, built on two pieces here: the
allocator *reports* exhaustion precisely (``PagesExhausted`` carries the
shortfall, nothing is half-allocated), and ownership is tracked per request
so preemption can free exactly one victim's pages.  The allocator is
host-side and deterministic (LIFO free list) — a replayed run allocates the
identical physical pages, which is what makes the ``page_exhaustion`` chaos
tests bit-reproducible.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig


class PagesExhausted(RuntimeError):
    """Raised by ``PageAllocator.alloc`` when the pool cannot satisfy the
    request.  Carries the shortfall so the engine can decide how many
    victims to preempt.  The failed alloc has NO side effects."""

    def __init__(self, needed: int, available: int):
        super().__init__(
            f"KV page pool exhausted: need {needed} pages, {available} free")
        self.needed = needed
        self.available = available


class PageAllocator:
    """Deterministic free-list allocator over physical page ids
    ``[first, first + total)``.

    Ownership is tracked per ``owner`` (the engine uses request ids): a page
    is either free or owned by exactly one live owner, and ``free_owner``
    returns every page an owner held — the preemption primitive.  The free
    list is LIFO so replayed runs hand out identical physical pages.
    """

    def __init__(self, total: int, *, first: int = 1):
        if total < 1:
            raise ValueError(f"page pool needs >= 1 page, got {total}")
        self.total = total
        self.first = first
        # LIFO: lowest ids come back out first (reversed push order).
        self._free: list[int] = list(range(first + total - 1, first - 1, -1))
        self._owned: dict[object, list[int]] = {}

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def live_owners(self) -> int:
        return len(self._owned)

    def owned(self, owner) -> list[int]:
        return list(self._owned.get(owner, ()))

    def alloc(self, n: int, owner) -> list[int]:
        """Acquire ``n`` pages for ``owner``; all-or-nothing."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise PagesExhausted(n, len(self._free))
        pages = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(owner, []).extend(pages)
        return pages

    def free_owner(self, owner) -> list[int]:
        """Release every page ``owner`` holds (no-op for unknown owners);
        returns the released pages (the engine zeroes them on quarantine)."""
        pages = self._owned.pop(owner, [])
        self._free.extend(pages)
        return pages

    def check(self) -> None:
        """Invariant audit: no page is double-owned or both free and owned,
        and every page is accounted for.  Cheap (set arithmetic over ints);
        the property tests call it after every step."""
        owned = [p for pages in self._owned.values() for p in pages]
        owned_set = set(owned)
        if len(owned) != len(owned_set):
            raise AssertionError(f"page owned twice: {sorted(owned)}")
        free_set = set(self._free)
        if len(self._free) != len(free_set):
            raise AssertionError("free list holds duplicates")
        if owned_set & free_set:
            raise AssertionError(
                f"pages both free and owned: {sorted(owned_set & free_set)}")
        universe = set(range(self.first, self.first + self.total))
        if owned_set | free_set != universe:
            raise AssertionError(
                f"pages leaked: {sorted(universe - owned_set - free_set)}")


def pages_for(depth: int, page_size: int) -> int:
    """Pages needed to hold ``depth`` KV rows."""
    return -(-depth // page_size)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(pool: jax.Array, rows: jax.Array,
                  phys: jax.Array) -> jax.Array:
    """Write ``rows`` (L, S, KVH, D) into the flattened-row view of
    ``pool`` (L, P, page, KVH, D) at physical row indices ``phys`` (S,)."""
    l, p, page, kvh, d = pool.shape
    flat = pool.reshape(l, p * page, kvh, d)
    flat = flat.at[:, phys].set(rows.astype(flat.dtype))
    return flat.reshape(l, p, page, kvh, d)


@functools.partial(jax.jit, donate_argnums=(0,))
def _zero_pages(pool: jax.Array, pages: jax.Array) -> jax.Array:
    return pool.at[:, pages].set(0.0)


@dataclasses.dataclass
class PagedKV:
    """Device page pools + the host-side page table for one engine.

    ``k``/``v``: (L, num_pages, page_size, KVH, D) — same leaf structure as
    the dense cache (layer-stacked axis 0) so ``stack_cached`` scans it
    unchanged; only the per-layer shape differs.  ``table``: host
    (slots, max_pages) int32, logical page -> physical page, 0 = the
    reserved null page.
    """
    k: jax.Array
    v: jax.Array
    table: np.ndarray
    page_size: int

    @classmethod
    def build(cls, cfg: ModelConfig, *, slots: int, max_len: int,
              num_pages: int, page_size: int, dtype=None) -> "PagedKV":
        dtype = dtype or jnp.dtype(cfg.compute_dtype)
        shape = (cfg.num_layers, num_pages, page_size,
                 cfg.num_kv_heads, cfg.head_dim_)
        max_pages = pages_for(max_len, page_size)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   table=np.zeros((slots, max_pages), np.int32),
                   page_size=page_size)

    @property
    def num_pages(self) -> int:
        return self.k.shape[1]

    def cache(self) -> dict:
        """The cache dict the layer scan consumes (paged leaves)."""
        return {"k": self.k, "v": self.v}

    def update(self, new_cache: dict) -> None:
        self.k, self.v = new_cache["k"], new_cache["v"]

    def map_slot(self, slot: int, pages: list[int]) -> None:
        """Point ``slot``'s logical pages at ``pages`` (in logical order)."""
        self.table[slot, :] = 0
        self.table[slot, :len(pages)] = pages

    def extend_slot(self, slot: int, pages: list[int],
                    start_logical: int) -> None:
        self.table[slot, start_logical:start_logical + len(pages)] = pages

    def clear_slot(self, slot: int) -> None:
        self.table[slot, :] = 0

    def insert(self, slot: int, pages: list[int], k_rows: jax.Array,
               v_rows: jax.Array) -> None:
        """Prefill-insert: scatter ``k_rows``/``v_rows`` (L, S, KVH, D) —
        one request's freshly prefilled KV — into the pool and map the
        slot's table.  ``S <= len(pages) * page_size``; rows land at the
        pages' physical rows in logical order."""
        s = k_rows.shape[1]
        if s > len(pages) * self.page_size:
            raise ValueError(f"{s} rows > {len(pages)} pages "
                             f"x {self.page_size}")
        logical = np.arange(s)
        phys = (np.asarray(pages, np.int64)[logical // self.page_size]
                * self.page_size + logical % self.page_size)
        phys_j = jnp.asarray(phys, jnp.int32)
        self.k = _scatter_rows(self.k, k_rows, phys_j)
        self.v = _scatter_rows(self.v, v_rows, phys_j)
        self.map_slot(slot, pages)

    def zero_pages(self, pages: list[int]) -> None:
        """Zero page contents — required when quarantining possibly
        non-finite KV so a later occupant of the same physical pages can
        never contract against NaN rows (0 * finite is safe, 0 * NaN is
        not)."""
        if pages:
            idx = jnp.asarray(pages, jnp.int32)
            self.k = _zero_pages(self.k, idx)
            self.v = _zero_pages(self.v, idx)
