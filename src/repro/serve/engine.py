"""Batched serving engine: slot-based continuous batching over jitted
prefill / decode steps.

The engine owns a fixed pool of B cache slots.  Requests are admitted into
free slots (prefill writes that slot's cache region), and a single fused
``decode_step`` advances every active slot one token per tick — finished
slots are freed and refilled, so decode batches stay full (the serving-side
analogue of keeping all DSP cores busy).  Sampling is greedy or temperature.

Decode attention runs as flash-decode (paper K-parallel) whenever a
DistContext is active — see models.attention.flash_decode.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import decode_step, make_cache, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.cache = make_cache(cfg, batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)       # filled length/slot
        self.active: list[Request | None] = [None] * batch_slots
        self.queue: list[Request] = []
        self._decode = jax.jit(functools.partial(decode_step, cfg=cfg))
        self._prefill_cache: dict[int, object] = {}

    # -------------------------- request plumbing ------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _prefill_one(self, slot: int, req: Request) -> None:
        s = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encoder_seq, self.cfg.d_model), jnp.float32)
        if self.cfg.num_patches:
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.num_patches, self.cfg.d_model), jnp.float32)
        fn = self._prefill_cache.get(s)
        if fn is None:
            fn = jax.jit(functools.partial(prefill, cfg=self.cfg))
            self._prefill_cache[s] = fn
        one_cache = make_cache(self.cfg, 1, self.max_len)
        logits, one_cache = fn(self.params, batch=batch, cache=one_cache)
        # copy slot cache in
        self.cache = jax.tree.map(
            lambda big, small: jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), slot, axis=self._batch_axis(big)),
            self.cache, one_cache)
        tok = self._sample(logits, req)
        req.out_tokens.append(int(tok[0]))
        self.pos[slot] = s + (self.cfg.num_patches or 0)
        self.active[slot] = req

    def _batch_axis(self, leaf) -> int:
        # cache leaves: (L|G, B, ...) stacked — batch axis is 1
        return 1

    def _sample(self, logits, req: Request):
        if req.temperature <= 0:
            return np.asarray(jnp.argmax(logits, -1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / req.temperature, axis=-1))

    # ------------------------------ stepping -----------------------------

    def _admit(self) -> None:
        for slot in range(self.b):
            if self.active[slot] is None and self.queue:
                self._prefill_one(slot, self.queue.pop(0))

    def step(self) -> int:
        """One decode tick across all active slots; returns #active."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        last = np.zeros((self.b, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is not None and r.out_tokens:
                last[i, 0] = r.out_tokens[-1]
        # single fused decode over all slots (pos varies per slot: use max —
        # per-slot masks come from each slot's own valid length)
        pos = jnp.int32(int(self.pos.max()))
        logits, self.cache = self._decode(
            self.params, tokens=jnp.asarray(last), cache=self.cache, pos=pos)
        n_active = 0
        for i, r in enumerate(self.active):
            if r is None:
                continue
            tok = self._sample(logits[i:i + 1], r)
            r.out_tokens.append(int(tok[0]))
            self.pos[i] += 1
            if (len(r.out_tokens) >= r.max_new_tokens
                    or self.pos[i] >= self.max_len - 1):
                r.done = True
                self.active[i] = None
            else:
                n_active += 1
        return n_active

    def run(self, requests: list[Request]) -> list[Request]:
        for r in requests:
            self.submit(r)
        while self.queue or any(r is not None for r in self.active):
            self.step()
        return requests
