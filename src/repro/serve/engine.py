"""Overload-safe batched serving engine: bucketed batch prefill, paged KV,
CMR-priced admission control over jitted prefill / decode steps.

The engine owns B decode slots.  For attention-cache families (dense / moe /
vlm) the KV lives in a PAGED pool (``serve.kv_pages``): each request owns
just the pages its depth needs, acquired from a free-list allocator as
decode crosses page boundaries, and a (B, max_pages) page table routes the
fused ``decode_step`` — slot count and sequence length stop being
compile-time constants of the cache.  Prompts are admitted through
LENGTH-BUCKETED batch prefill (``serve.buckets`` / ``prefill_bucket``): a
small geometric ladder of capacities, one compiled prefill per bucket,
right-padding exact by causality.  Recurrent families (ssm / hybrid /
encdec) keep the legacy dense slot cache + exact-length prefill — pad
tokens would contaminate recurrent state.

A single fused ``decode_step`` advances every active slot one token per
tick with PER-SLOT positions, so slots at different depths write and mask
at their own rows.  Sampling is greedy or temperature.  Detokenization
runs on a worker thread consuming a token queue — the decode hot loop
never blocks on string assembly.

Overload safety (chaos-tested; see ``runtime.chaos``):

  * ``submit`` prices each deadline-carrying request against the
    CMR-derived, measurement-calibrated cost model (``serve.buckets``) and
    raises typed ``Overloaded`` when the projected completion cannot meet
    the deadline — rejection at the door, not a hang at the deadline;
  * deadline-infeasible QUEUED work is shed oldest-first as estimates
    move, and expired requests (queued or active) free their resources;
  * KV page exhaustion preempts the lowest-priority active request
    (pages freed, request re-queued for re-prefill of prompt + generated
    tokens — greedy decode makes recovery bit-identical) instead of
    OOMing or wedging; admission never preempts, it waits
    (``page_exhaustion`` site forces this path);
  * a prompt the bucket ladder cannot hold falls back to the legacy
    exact-length jitted prefill (LRU-bounded) and page-inserts
    (``bucket_miss`` site forces the rung);
  * transient decode faults retry with exponential backoff
    (``transient_decode`` site); non-finite logits quarantine the slot —
    pages freed AND ZEROED (a later occupant's ``p @ V`` would contract
    0 * NaN = NaN against poisoned rows) and the request re-prefills
    (``nan_logits`` site).

Decode attention runs as flash-decode (paper K-parallel) whenever a
DistContext is active — see models.attention.flash_decode.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import queue as _queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import (decode_step, make_cache, prefill,
                            prefill_bucket)
from ..runtime import chaos as _chaos
from .buckets import CostModel, bucket_for, make_buckets
from .kv_pages import PageAllocator, PagedKV, PagesExhausted, pages_for

PAGED_FAMILIES = ("dense", "moe", "vlm")


class Overloaded(RuntimeError):
    """Typed admission rejection: the engine cannot meet this request's
    deadline at current load (or the request cannot fit the KV pool at
    all).  Raised by ``submit`` BEFORE the request consumes anything —
    the caller sheds or re-routes instead of waiting for a timeout."""

    def __init__(self, reason: str, *, projected_s: float | None = None,
                 deadline_s: float | None = None):
        msg = reason
        if projected_s is not None and deadline_s is not None:
            msg += (f" (projected {projected_s:.3f}s"
                    f" > deadline {deadline_s:.3f}s)")
        super().__init__(msg)
        self.reason = reason
        self.projected_s = projected_s
        self.deadline_s = deadline_s


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    deadline_s: float | None = None   # wall-clock budget from submit()
    priority: int = 0             # higher survives page pressure longer
    out_tokens: list = dataclasses.field(default_factory=list)
    text: str = ""                # filled by the detokenize worker
    done: bool = False
    timed_out: bool = False
    shed: bool = False            # dropped by load shedding / admission
    submitted_at: float = 0.0


class _Detokenizer:
    """Worker thread turning emitted token ids into ``Request.text`` off
    the decode hot loop.  The decode tick enqueues (request, token) and
    moves on; ``drain()`` joins the queue at end-of-run."""

    def __init__(self, fn):
        self.fn = fn
        self.q: _queue.Queue = _queue.Queue()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self.q.get()
            if item is None:
                self.q.task_done()
                return
            req, tok = item
            try:
                req.text += self.fn(tok)
            finally:
                self.q.task_done()

    def put(self, req: Request, tok: int) -> None:
        self.q.put((req, tok))

    def drain(self) -> None:
        self.q.join()

    def close(self) -> None:
        self.q.put(None)
        self._thread.join(timeout=5)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 512, seed: int = 0,
                 prefill_cache_size: int = 8, decode_retries: int = 2,
                 retry_backoff_s: float = 0.02,
                 paged: bool | None = None, page_size: int = 16,
                 num_pages: int | None = None,
                 buckets: tuple[int, ...] | None = None,
                 detokenize=None):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.extra = cfg.num_patches or 0
        self.pos = np.zeros(batch_slots, np.int32)       # filled length/slot
        self.active: list[Request | None] = [None] * batch_slots
        self.queue: list[Request] = []
        self._decode = jax.jit(functools.partial(decode_step, cfg=cfg))
        self._prefill_cache: collections.OrderedDict[int, object] = \
            collections.OrderedDict()
        self.prefill_cache_size = prefill_cache_size
        self.decode_retries = decode_retries
        self.retry_backoff_s = retry_backoff_s
        self._detok = _Detokenizer(detokenize) if detokenize else None
        self.faults = {"transient_retries": 0, "deadline_expired": 0,
                       "nonfinite_quarantined": 0, "prefill_evictions": 0,
                       "admission_rejected": 0, "shed": 0,
                       "preemptions": 0, "bucket_misses": 0}

        self.paged = (cfg.family in PAGED_FAMILIES if paged is None
                      else paged)
        if self.paged and cfg.family not in PAGED_FAMILIES:
            raise ValueError(f"paged KV unsupported for {cfg.family}")
        if self.paged:
            depth_cap = max_len + self.extra
            self.page_size = page_size
            self.num_pages = (num_pages if num_pages is not None
                              else batch_slots * pages_for(depth_cap,
                                                           page_size))
            self.alloc = PageAllocator(self.num_pages, first=1)
            # Pool holds the reserved null page 0 in front of the
            # allocatable ids [1, num_pages].
            self.kv = PagedKV.build(cfg, slots=batch_slots,
                                    max_len=depth_cap,
                                    num_pages=self.num_pages + 1,
                                    page_size=page_size)
            self.cache = None
            self.buckets = (tuple(buckets) if buckets
                            else make_buckets(max_len))
            # Constructing the cost model prices every bucket via
            # plan_gemm — which warms the plan cache for exactly the
            # signatures serving will hit.
            self.cost: CostModel | None = CostModel(cfg, self.buckets,
                                                    batch_slots)
            self._bucket_prefill = jax.jit(
                functools.partial(prefill_bucket, cfg=cfg))
            # First call per compiled shape includes trace+compile wall —
            # feeding it to the cost EWMAs would wildly overprice steady
            # state (and with it every admission deadline decision).
            self._timed_buckets: set[int] = set()
            self._timed_step = False
        else:
            self.cache = make_cache(cfg, batch_slots, max_len)
            self.buckets = ()
            self.cost = None
            self.alloc = None
            self.kv = None

    # -------------------------- request plumbing ------------------------

    def _req_tokens(self, req: Request) -> np.ndarray:
        """What a (re-)prefill must run: prompt + everything generated
        so far (preemption / quarantine recovery re-enters here)."""
        if req.out_tokens:
            return np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(req.out_tokens, np.int32)])
        return np.asarray(req.prompt, np.int32)

    def submit(self, req: Request) -> None:
        """Admit ``req`` to the queue, or raise typed ``Overloaded``.

        Rejection happens only when the request carries a deadline AND the
        cost model has measured wall times to price against (an unpriced
        guess never rejects) — or when the request could never fit the KV
        pool at all."""
        req.submitted_at = time.monotonic()
        if self.paged:
            # Depth is also capped by max_len (decode stops there), so a
            # huge max_new_tokens is not by itself inadmissible.
            worst = pages_for(
                min(len(req.prompt) + req.max_new_tokens, self.max_len)
                + self.extra, self.page_size)
            if worst > self.alloc.total:
                self.faults["admission_rejected"] += 1
                raise Overloaded(
                    f"request needs {worst} KV pages, pool holds "
                    f"{self.alloc.total}")
        if req.deadline_s is not None:
            est = self._projected_completion_s(req)
            if est is not None and est > req.deadline_s:
                self.faults["admission_rejected"] += 1
                raise Overloaded("projected completion misses deadline",
                                 projected_s=est,
                                 deadline_s=req.deadline_s)
        self.queue.append(req)

    def _projected_completion_s(self, req: Request) -> float | None:
        """Estimated seconds until ``req`` would finish if admitted now:
        amortized prefill share + fused-decode share of the backlog ahead
        of it, plus its own service.  None while uncalibrated."""
        if self.cost is None or not self.cost.calibrated():
            return None
        step = self.cost.step_s()
        ahead = sum(max(r.max_new_tokens - len(r.out_tokens), 0)
                    for r in self.active if r is not None)
        ahead += sum(max(r.max_new_tokens - len(r.out_tokens), 0)
                     for r in self.queue)
        pre_backlog = 0.0
        for r in self.queue:
            pre = self.cost.prefill_s(
                bucket_for(len(self._req_tokens(r)), self.buckets))
            pre_backlog += (pre or 0.0) / self.b
        own_pre = self.cost.prefill_s(
            bucket_for(len(self._req_tokens(req)), self.buckets)) or 0.0
        return (pre_backlog + (ahead / self.b) * step + own_pre
                + req.max_new_tokens * step)

    def _prefill_fn(self, s: int):
        """One jitted prefill per exact prompt length, LRU-bounded: the
        legacy rung (recurrent families, bucket misses) must not grow a
        compiled-function cache without bound."""
        fn = self._prefill_cache.get(s)
        if fn is not None:
            self._prefill_cache.move_to_end(s)
            return fn
        fn = jax.jit(functools.partial(prefill, cfg=self.cfg))
        self._prefill_cache[s] = fn
        while len(self._prefill_cache) > self.prefill_cache_size:
            self._prefill_cache.popitem(last=False)
            self.faults["prefill_evictions"] += 1
        return fn

    def _frontend_batch(self, toks: np.ndarray) -> dict:
        batch = {"tokens": jnp.asarray(toks)}
        bsz = toks.shape[0]
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (bsz, self.cfg.encoder_seq, self.cfg.d_model), jnp.float32)
        if self.cfg.num_patches:
            batch["patch_embeds"] = jnp.zeros(
                (bsz, self.cfg.num_patches, self.cfg.d_model), jnp.float32)
        return batch

    def _prefill_one(self, slot: int, req: Request,
                     tokens: np.ndarray | None = None) -> None:
        """Legacy dense-slot prefill (non-paged engines): run ``tokens``
        (default: the prompt) into ``slot``'s cache region and sample one
        continuation token."""
        toks = np.asarray(req.prompt if tokens is None else tokens, np.int32)
        s = len(toks)
        fn = self._prefill_fn(s)
        one_cache = make_cache(self.cfg, 1, self.max_len)
        logits, one_cache = fn(self.params,
                               batch=self._frontend_batch(toks[None, :]),
                               cache=one_cache)
        # copy slot cache in
        self.cache = jax.tree.map(
            lambda big, small: jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), slot, axis=1),
            self.cache, one_cache)
        self._emit(req, self._sample(logits, req))
        self.pos[slot] = s + self.extra
        self.active[slot] = req

    def _sample(self, logits, req: Request) -> int:
        if req.temperature <= 0:
            return int(np.asarray(jnp.argmax(logits, -1))[0])
        self.key, sub = jax.random.split(self.key)
        return int(np.asarray(jax.random.categorical(
            sub, logits / req.temperature, axis=-1))[0])

    def _emit(self, req: Request, tok: int) -> None:
        req.out_tokens.append(tok)
        if self._detok is not None:
            self._detok.put(req, tok)

    # ------------------------------ paging -------------------------------

    def _alloc_pages(self, req: Request, n: int, *,
                     active_slot: int | None = None) -> list[int] | None:
        """Acquire ``n`` pages for ``req``, or None if it must wait.

        Admission-time calls (``active_slot`` is None) NEVER preempt —
        an incoming request waits rather than thrashing live decode.
        Decode-growth calls preempt the lowest-priority active victim
        (ties: youngest submitted) with ``priority <= req.priority``;
        when the best victim is ``req`` itself, it yields its own slot.
        The ``page_exhaustion`` chaos site forces the exhaustion branch
        even with free pages."""
        forced = _chaos.should_fire("page_exhaustion") is not None
        while True:
            if forced:
                forced = False
            else:
                try:
                    return self.alloc.alloc(n, id(req))
                except PagesExhausted:
                    pass
            if active_slot is None:
                return None
            victim_slot = self._pick_victim(req)
            if victim_slot is None:
                return None
            self._preempt_slot(victim_slot)
            if victim_slot == active_slot:
                return None           # req preempted itself (yielded)

    def _pick_victim(self, req: Request) -> int | None:
        """Slot of the lowest-priority active request ``req`` may evict
        (priority <= req.priority; ties resolved against the youngest).
        ``req``'s own slot is eligible last — returning it means 'yield'."""
        best = None
        for i, r in enumerate(self.active):
            if r is None or r.priority > req.priority:
                continue
            rank = (r.priority, -r.submitted_at, 1 if r is req else 0)
            if best is None or rank < best[0]:
                best = (rank, i)
        return None if best is None else best[1]

    def _preempt_slot(self, slot: int) -> None:
        """Free a victim's pages and send it back to the queue head for
        re-prefill (prompt + generated-so-far) — pages hold finite values,
        so no zeroing is needed (stale rows are position-masked and weight
        exactly 0 in the next occupant's softmax)."""
        r = self.active[slot]
        self.alloc.free_owner(id(r))
        self.kv.clear_slot(slot)
        self.pos[slot] = 0
        self.active[slot] = None
        self.queue.insert(0, r)
        self.faults["preemptions"] += 1

    def _release_slot(self, slot: int, req: Request) -> None:
        if self.paged:
            self.alloc.free_owner(id(req))
            self.kv.clear_slot(slot)
        self.active[slot] = None
        self.pos[slot] = 0

    def _ensure_pages(self) -> None:
        """Grow each active slot's page span to cover the row this tick's
        decode will write; exhaustion preempts (see ``_alloc_pages``)."""
        for i in range(self.b):
            r = self.active[i]
            if r is None:
                continue
            need = pages_for(int(self.pos[i]) + 1, self.page_size)
            have = len(self.alloc.owned(id(r)))
            if need <= have:
                continue
            pages = self._alloc_pages(r, need - have, active_slot=i)
            if pages is None:
                if self.active[i] is r:     # couldn't grow, didn't yield:
                    self._preempt_slot(i)   # requeue rather than wedge
                continue
            self.kv.extend_slot(i, pages, have)

    # --------------------------- admission -------------------------------

    def _admit(self) -> None:
        if not self.paged:
            for slot in range(self.b):
                if self.active[slot] is None and self.queue:
                    req = self.queue.pop(0)
                    self._prefill_one(slot, req,
                                      tokens=self._req_tokens(req))
            return
        while self.queue:
            free = [i for i in range(self.b) if self.active[i] is None]
            if not free:
                return
            head_toks = self._req_tokens(self.queue[0])
            bkt = bucket_for(len(head_toks), self.buckets)
            if _chaos.should_fire("bucket_miss") is not None:
                bkt = None
            if bkt is None:
                self.faults["bucket_misses"] += 1
                req = self.queue.pop(0)
                if not self._admit_exact(free[0], req, head_toks):
                    return
                continue
            batch: list[tuple[Request, np.ndarray]] = []
            while self.queue and len(batch) < len(free):
                toks = self._req_tokens(self.queue[0])
                if bucket_for(len(toks), self.buckets) != bkt:
                    break
                batch.append((self.queue.pop(0), toks))
            if not self._admit_bucket(free, batch, bkt):
                return

    def _admit_exact(self, slot: int, req: Request,
                     toks: np.ndarray) -> bool:
        """Bucket-miss rung: legacy exact-length jitted prefill, then
        page-insert.  False = pool pressure, stop admitting this tick."""
        depth = len(toks) + self.extra
        pages = self._alloc_pages(req, pages_for(depth + 1, self.page_size))
        if pages is None:
            self.queue.insert(0, req)
            return False
        fn = self._prefill_fn(len(toks))
        one_cache = make_cache(self.cfg, 1, len(toks))
        t0 = time.monotonic()
        logits, one_cache = fn(self.params,
                               batch=self._frontend_batch(toks[None, :]),
                               cache=one_cache)
        tok = self._sample(logits, req)
        key = ("exact", len(toks))
        if self.cost is not None and key in self._timed_buckets:
            self.cost.observe_prefill(self.buckets[-1],
                                      time.monotonic() - t0)
        self._timed_buckets.add(key)
        self.kv.insert(slot, pages, one_cache["k"][:, 0, :depth],
                       one_cache["v"][:, 0, :depth])
        self._emit(req, tok)
        self.pos[slot] = depth
        self.active[slot] = req
        return True

    def _admit_bucket(self, free: list[int],
                      batch: list[tuple[Request, np.ndarray]],
                      bkt: int) -> bool:
        """One bucketed batch prefill: every admitted request's padded
        prompt runs through ONE compiled stack pass, each row's KV rows
        page-insert into its slot.  Page allocation happens FIRST (cheap,
        host-side) so an exhausted pool skips the compute; blocked
        requests go back to the queue head.  False = stop admitting."""
        rows: list[tuple[int, Request, np.ndarray, list[int]]] = []
        blocked = False
        for (req, toks) in batch:
            depth = len(toks) + self.extra
            pages = self._alloc_pages(
                req, pages_for(depth + 1, self.page_size))
            if pages is None:
                self.queue.insert(0, req)
                blocked = True
                break
            rows.append((free[len(rows)], req, toks, pages))
        if not rows:
            return not blocked
        toks_pad = np.zeros((self.b, bkt), np.int32)
        lens = np.ones(self.b, np.int32)    # pad rows: 1 token-0 row
        for j, (_, _, toks, _) in enumerate(rows):
            toks_pad[j, :len(toks)] = toks
            lens[j] = len(toks)
        cache = make_cache(self.cfg, self.b, bkt)
        t0 = time.monotonic()
        logits, cache = self._bucket_prefill(
            self.params, batch=self._frontend_batch(toks_pad),
            cache=cache, lens=jnp.asarray(lens))
        logits = np.asarray(logits)          # sync: the wall we observe
        if self.cost is not None and bkt in self._timed_buckets:
            self.cost.observe_prefill(bkt, time.monotonic() - t0)
        self._timed_buckets.add(bkt)
        for j, (slot, req, toks, pages) in enumerate(rows):
            depth = len(toks) + self.extra
            self.kv.insert(slot, pages, cache["k"][:, j, :depth],
                           cache["v"][:, j, :depth])
            self._emit(req, self._sample(jnp.asarray(logits[j:j + 1]),
                                         req))
            self.pos[slot] = depth
            self.active[slot] = req
        return not blocked

    # --------------------------- containment -----------------------------

    def _evict_slot(self, slot: int) -> None:
        """Quarantine a slot whose occupant produced non-finite values.
        Paged: free AND ZERO its pages — the next occupant's ``p @ V``
        contracts every cache row (masked rows at weight 0), and
        0 * NaN = NaN.  Legacy: zero the slot's dense cache region."""
        if self.paged:
            r = self.active[slot]
            pages = self.alloc.free_owner(id(r))
            self.kv.zero_pages(pages)
            self.kv.clear_slot(slot)
        else:
            self.cache = jax.tree.map(
                lambda leaf: leaf.at[:, slot].set(
                    jnp.zeros_like(leaf[:, slot])), self.cache)

    def _requarantine_prefill(self, slot: int, req: Request) -> None:
        """Re-prefill prompt + generated-so-far after quarantine, through
        whichever rung fits (bucket / exact-length)."""
        toks = self._req_tokens(req)
        if not self.paged:
            self._prefill_one(slot, req, tokens=toks)
            return
        self.active[slot] = None
        self.pos[slot] = 0
        bkt = bucket_for(len(toks), self.buckets)
        if bkt is None:
            self._admit_exact(slot, req, toks)
        else:
            self._admit_bucket([slot], [(req, toks)], bkt)

    def _expire_deadlines(self) -> None:
        now = time.monotonic()
        for slot, r in enumerate(self.active):
            if (r is not None and r.deadline_s is not None
                    and now - r.submitted_at > r.deadline_s):
                r.done = True
                r.timed_out = True
                self.faults["deadline_expired"] += 1
                self._release_slot(slot, r)
        kept = []
        for r in self.queue:
            if (r.deadline_s is not None
                    and now - r.submitted_at > r.deadline_s):
                r.done = True
                r.timed_out = True
                self.faults["deadline_expired"] += 1
            else:
                kept.append(r)
        self.queue = kept
        self._shed_infeasible(now)

    def _shed_infeasible(self, now: float) -> None:
        """Load shedding: drop queued requests whose deadline the current
        estimates say cannot be met, OLDEST first (they block everything
        behind them and are the most doomed).  Estimate-gated: nothing is
        shed until the cost model has measured wall times."""
        if self.cost is None or not self.cost.calibrated():
            return
        step = self.cost.step_s()
        ahead = sum(max(r.max_new_tokens - len(r.out_tokens), 0)
                    for r in self.active if r is not None)
        kept = []
        for r in self.queue:
            rem = max(r.max_new_tokens - len(r.out_tokens), 0)
            if r.deadline_s is None:
                kept.append(r)
                ahead += rem
                continue
            pre = self.cost.prefill_s(
                bucket_for(len(self._req_tokens(r)), self.buckets)) or 0.0
            est = ((now - r.submitted_at) + pre
                   + (ahead / self.b) * step + rem * step)
            if est > r.deadline_s:
                r.done = True
                r.timed_out = True
                r.shed = True
                self.faults["shed"] += 1
            else:
                kept.append(r)
                ahead += rem
        self.queue = kept

    def _decode_with_retry(self, last: np.ndarray, pos: jnp.ndarray):
        """Run one fused decode, retrying transient faults with exponential
        backoff (bounded; the last attempt propagates)."""
        for attempt in range(self.decode_retries + 1):
            try:
                _chaos.fire("transient_decode")
                if self.paged:
                    return self._decode(
                        self.params, tokens=jnp.asarray(last),
                        cache=self.kv.cache(), pos=pos,
                        page_table=jnp.asarray(self.kv.table))
                return self._decode(self.params, tokens=jnp.asarray(last),
                                    cache=self.cache, pos=pos)
            except _chaos.TransientFault:
                self.faults["transient_retries"] += 1
                if attempt == self.decode_retries:
                    raise
                time.sleep(self.retry_backoff_s * (2 ** attempt))

    def health(self) -> dict:
        """Operational snapshot: slot occupancy, fault counters, page-pool
        pressure, admission pricing, and the dispatch ladder's
        degraded-servings telemetry."""
        from ..core.gemm import plan_mode_stats
        degraded = plan_mode_stats().get("degraded", {})
        out = {
            "active_slots": sum(r is not None for r in self.active),
            "queue_depth": len(self.queue),
            "slot_pos": [int(p) for p in self.pos],
            "prefill_cache_size": len(self._prefill_cache),
            "faults": dict(self.faults),
            "degraded_servings": dict(degraded),
            "degraded_mode": bool(degraded)
                             or any(self.faults.values()),
        }
        if self.paged:
            out["pages"] = {"total": self.alloc.total,
                            "free": self.alloc.available,
                            "page_size": self.page_size,
                            "live_owners": self.alloc.live_owners}
            out["buckets"] = list(self.buckets)
            out["cost"] = self.cost.snapshot()
        if self._detok is not None:
            out["detok_backlog"] = self._detok.q.qsize()
        return out

    # ------------------------------ stepping -----------------------------

    def step(self) -> int:
        """One decode tick across all active slots; returns #active."""
        self._expire_deadlines()
        self._admit()
        if self.paged:
            self._ensure_pages()
        if not any(r is not None for r in self.active):
            return 0
        last = np.zeros((self.b, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is not None and r.out_tokens:
                last[i, 0] = r.out_tokens[-1]
        # Single fused decode over all slots with PER-SLOT positions: each
        # row writes its own cache row and masks under its own horizon, so
        # mixed-depth slots (and freed-slot reuse) can't cross-contaminate.
        t0 = time.monotonic()
        logits, new_cache = self._decode_with_retry(
            last, jnp.asarray(self.pos))
        logits = _chaos.poison_logits(np.asarray(logits))
        if self.cost is not None and self._timed_step:
            self.cost.observe_step(time.monotonic() - t0)
        self._timed_step = True
        if self.paged:
            self.kv.update(new_cache)
        else:
            self.cache = new_cache
        finite = np.isfinite(logits).all(axis=-1)
        n_active = 0
        for i, r in enumerate(self.active):
            if r is None:
                continue
            if not finite[i]:
                # Quarantine: drop the slot's (possibly poisoned) cache and
                # re-prefill prompt + tokens generated so far — the request
                # continues instead of emitting garbage.
                self.faults["nonfinite_quarantined"] += 1
                self._evict_slot(i)
                self._requarantine_prefill(i, r)
                r = self.active[i]
                if r is None:       # re-prefill blocked on page pressure
                    continue
            else:
                self._emit(r, self._sample(jnp.asarray(logits[i:i + 1]), r))
                self.pos[i] += 1
            if (len(r.out_tokens) >= r.max_new_tokens
                    or self.pos[i] >= self.max_len - 1 + self.extra):
                r.done = True
                self._release_slot(i, r)
            else:
                n_active += 1
        return n_active

    def drain_detok(self) -> None:
        """Block until every emitted token has been detokenized."""
        if self._detok is not None:
            self._detok.drain()

    def close(self) -> None:
        if self._detok is not None:
            self._detok.close()
            self._detok = None

    def run(self, requests: list[Request]) -> list[Request]:
        for r in requests:
            self.submit(r)
        while self.queue or any(r is not None for r in self.active):
            self.step()
        self.drain_detok()
        return requests
