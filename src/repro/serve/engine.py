"""Batched serving engine: slot-based continuous batching over jitted
prefill / decode steps.

The engine owns a fixed pool of B cache slots.  Requests are admitted into
free slots (prefill writes that slot's cache region), and a single fused
``decode_step`` advances every active slot one token per tick — finished
slots are freed and refilled, so decode batches stay full (the serving-side
analogue of keeping all DSP cores busy).  Sampling is greedy or temperature.
The decode runs with PER-SLOT positions (a (B,) vector into ``decode_step``)
so slots at different depths write and mask at their own rows — a freed
slot's next occupant never sees the previous occupant's cache rows.

Failure containment (chaos-tested; see ``runtime.chaos``):

  * transient decode faults retry with exponential backoff
    (``transient_decode`` site), counted in ``health()``;
  * per-request deadlines (``Request.deadline_s``) expire the request and
    free its slot instead of wedging the batch;
  * a non-finite-logits guard quarantines the offending slot — its cache
    region is evicted and the request re-prefills (prompt + tokens so far)
    instead of emitting garbage (``nan_logits`` site);
  * the per-length jitted-prefill cache is a small LRU, with evictions
    counted in the health snapshot.

Decode attention runs as flash-decode (paper K-parallel) whenever a
DistContext is active — see models.attention.flash_decode.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import decode_step, make_cache, prefill
from ..runtime import chaos as _chaos


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    deadline_s: float | None = None   # wall-clock budget from submit()
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    timed_out: bool = False
    submitted_at: float = 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 512, seed: int = 0,
                 prefill_cache_size: int = 8, decode_retries: int = 2,
                 retry_backoff_s: float = 0.02):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.cache = make_cache(cfg, batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)       # filled length/slot
        self.active: list[Request | None] = [None] * batch_slots
        self.queue: list[Request] = []
        self._decode = jax.jit(functools.partial(decode_step, cfg=cfg))
        self._prefill_cache: collections.OrderedDict[int, object] = \
            collections.OrderedDict()
        self.prefill_cache_size = prefill_cache_size
        self.decode_retries = decode_retries
        self.retry_backoff_s = retry_backoff_s
        self.faults = {"transient_retries": 0, "deadline_expired": 0,
                       "nonfinite_quarantined": 0, "prefill_evictions": 0}

    # -------------------------- request plumbing ------------------------

    def submit(self, req: Request) -> None:
        req.submitted_at = time.monotonic()
        self.queue.append(req)

    def _prefill_fn(self, s: int):
        """One jitted prefill per prompt length, LRU-bounded: serving
        arbitrary traffic must not grow a compiled-function cache without
        bound (each entry holds a full executable)."""
        fn = self._prefill_cache.get(s)
        if fn is not None:
            self._prefill_cache.move_to_end(s)
            return fn
        fn = jax.jit(functools.partial(prefill, cfg=self.cfg))
        self._prefill_cache[s] = fn
        while len(self._prefill_cache) > self.prefill_cache_size:
            self._prefill_cache.popitem(last=False)
            self.faults["prefill_evictions"] += 1
        return fn

    def _prefill_one(self, slot: int, req: Request,
                     tokens: np.ndarray | None = None) -> None:
        """Prefill ``tokens`` (default: the prompt) into ``slot`` and sample
        one continuation token.  The quarantine path re-enters with
        prompt + generated-so-far after evicting the slot."""
        toks = np.asarray(req.prompt if tokens is None else tokens, np.int32)
        s = len(toks)
        batch = {"tokens": jnp.asarray(toks)[None, :]}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encoder_seq, self.cfg.d_model), jnp.float32)
        if self.cfg.num_patches:
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.num_patches, self.cfg.d_model), jnp.float32)
        fn = self._prefill_fn(s)
        one_cache = make_cache(self.cfg, 1, self.max_len)
        logits, one_cache = fn(self.params, batch=batch, cache=one_cache)
        # copy slot cache in
        self.cache = jax.tree.map(
            lambda big, small: jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), slot, axis=self._batch_axis(big)),
            self.cache, one_cache)
        tok = self._sample(logits, req)
        req.out_tokens.append(int(tok[0]))
        self.pos[slot] = s + (self.cfg.num_patches or 0)
        self.active[slot] = req

    def _batch_axis(self, leaf) -> int:
        # cache leaves: (L|G, B, ...) stacked — batch axis is 1
        return 1

    def _sample(self, logits, req: Request):
        if req.temperature <= 0:
            return np.asarray(jnp.argmax(logits, -1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / req.temperature, axis=-1))

    # --------------------------- containment -----------------------------

    def _free(self, slot: int) -> None:
        self.active[slot] = None
        self.pos[slot] = 0

    def _evict_slot(self, slot: int) -> None:
        """Zero the slot's cache region — the quarantined occupant's state
        (possibly non-finite) must not survive into the re-prefill."""
        self.cache = jax.tree.map(
            lambda leaf: leaf.at[:, slot].set(
                jnp.zeros_like(leaf[:, slot])), self.cache)

    def _expire_deadlines(self) -> None:
        now = time.monotonic()
        for slot, r in enumerate(self.active):
            if (r is not None and r.deadline_s is not None
                    and now - r.submitted_at > r.deadline_s):
                r.done = True
                r.timed_out = True
                self.faults["deadline_expired"] += 1
                self._free(slot)
        kept = []
        for r in self.queue:
            if (r.deadline_s is not None
                    and now - r.submitted_at > r.deadline_s):
                r.done = True
                r.timed_out = True
                self.faults["deadline_expired"] += 1
            else:
                kept.append(r)
        self.queue = kept

    def _decode_with_retry(self, last: np.ndarray, pos: jnp.ndarray):
        """Run one fused decode, retrying transient faults with exponential
        backoff (bounded; the last attempt propagates)."""
        for attempt in range(self.decode_retries + 1):
            try:
                _chaos.fire("transient_decode")
                return self._decode(self.params, tokens=jnp.asarray(last),
                                    cache=self.cache, pos=pos)
            except _chaos.TransientFault:
                self.faults["transient_retries"] += 1
                if attempt == self.decode_retries:
                    raise
                time.sleep(self.retry_backoff_s * (2 ** attempt))

    def health(self) -> dict:
        """Operational snapshot: slot occupancy, fault counters, and the
        dispatch ladder's degraded-servings telemetry."""
        from ..core.gemm import plan_mode_stats
        degraded = plan_mode_stats().get("degraded", {})
        return {
            "active_slots": sum(r is not None for r in self.active),
            "queue_depth": len(self.queue),
            "slot_pos": [int(p) for p in self.pos],
            "prefill_cache_size": len(self._prefill_cache),
            "faults": dict(self.faults),
            "degraded_servings": dict(degraded),
            "degraded_mode": bool(degraded)
                             or any(self.faults.values()),
        }

    # ------------------------------ stepping -----------------------------

    def _admit(self) -> None:
        for slot in range(self.b):
            if self.active[slot] is None and self.queue:
                self._prefill_one(slot, self.queue.pop(0))

    def step(self) -> int:
        """One decode tick across all active slots; returns #active."""
        self._expire_deadlines()
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        last = np.zeros((self.b, 1), np.int32)
        for i, r in enumerate(self.active):
            if r is not None and r.out_tokens:
                last[i, 0] = r.out_tokens[-1]
        # Single fused decode over all slots with PER-SLOT positions: each
        # row writes its own cache row and masks under its own horizon, so
        # mixed-depth slots (and freed-slot reuse) can't cross-contaminate.
        logits, self.cache = self._decode_with_retry(
            last, jnp.asarray(self.pos))
        logits = _chaos.poison_logits(np.asarray(logits))
        finite = np.isfinite(logits).all(axis=-1)
        n_active = 0
        for i, r in enumerate(self.active):
            if r is None:
                continue
            if not finite[i]:
                # Quarantine: drop the slot's (possibly poisoned) cache and
                # re-prefill prompt + tokens generated so far — the request
                # continues instead of emitting garbage.
                self.faults["nonfinite_quarantined"] += 1
                self._evict_slot(i)
                toks = np.concatenate(
                    [np.asarray(r.prompt, np.int32),
                     np.asarray(r.out_tokens, np.int32)])
                self._prefill_one(i, r, tokens=toks)
            else:
                tok = self._sample(jnp.asarray(logits[i:i + 1]), r)
                r.out_tokens.append(int(tok[0]))
                self.pos[i] += 1
            if (len(r.out_tokens) >= r.max_new_tokens
                    or self.pos[i] >= self.max_len - 1):
                r.done = True
                self._free(i)
            else:
                n_active += 1
        return n_active

    def run(self, requests: list[Request]) -> list[Request]:
        for r in requests:
            self.submit(r)
        while self.queue or any(r is not None for r in self.active):
            self.step()
        return requests
