from .engine import Overloaded, Request, ServeEngine
from .kv_pages import PageAllocator, PagedKV, PagesExhausted, pages_for
from .buckets import CostModel, bucket_for, make_buckets
from ..models.attention import flash_decode

__all__ = ["Overloaded", "Request", "ServeEngine",
           "PageAllocator", "PagedKV", "PagesExhausted", "pages_for",
           "CostModel", "bucket_for", "make_buckets", "flash_decode"]
