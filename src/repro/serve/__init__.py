from .engine import Request, ServeEngine
from ..models.attention import flash_decode

__all__ = ["Request", "ServeEngine", "flash_decode"]
