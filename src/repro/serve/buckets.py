"""Prompt-length buckets + CMR-priced serve cost model.

Serving arbitrary prompt lengths with one jitted prefill per exact length
compiles without bound and stalls the engine on every novel length.  The
bucket set fixes that: a SMALL geometric ladder of prompt capacities, each
compiled exactly once (right-padding is exact for causal attention — see
``models.model.prefill_bucket``), and every admission maps to the smallest
bucket that fits.  Lengths beyond the ladder fall through to the legacy
exact-length prefill rung (LRU-bounded), so a miss degrades, never fails.

Pricing rides the repo's CMR planner: each bucket's prefill and the fused
decode tick decompose into the GEMM signatures the stack actually runs
(qkv / attn-out / ffn / unembed per layer), and ``plan_gemm`` prices each
signature — which *also* warms the plan cache for exactly the signatures
serving will hit, so the first real request never pays a planning stall.
The CMR numbers are model-relative (a DSP/TPU roofline, not this host), so
``CostModel`` calibrates them against measured wall times the same way
``autotune.calibrate`` closes the loop for kernels: observed buckets use
their wall EWMA directly, never-observed buckets scale their model price
by the measured/modeled ratio of the buckets that HAVE run.  Admission
control (``engine.ServeEngine.submit``) prices deadlines against these
estimates.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.gemm import plan_gemm, plan_store

__all__ = ["make_buckets", "bucket_for", "gemm_signatures", "CostModel"]

_EWMA_ALPHA = 0.3


def make_buckets(max_prompt: int, *, smallest: int = 32,
                 growth: int = 2) -> tuple[int, ...]:
    """Geometric bucket ladder ``smallest, smallest*growth, ... >= max_prompt``.

    Small by construction (log_growth(max/smallest) entries) — the point is
    a bounded compile set, not a tight fit; padding waste per request is at
    most (growth-1)/growth of the bucket.
    """
    if max_prompt < 1:
        raise ValueError(f"max_prompt={max_prompt}")
    buckets = [min(smallest, max_prompt)]
    while buckets[-1] < max_prompt:
        buckets.append(min(buckets[-1] * growth, max_prompt))
    return tuple(buckets)


def bucket_for(length: int, buckets: tuple[int, ...]) -> int | None:
    """Smallest bucket holding ``length`` tokens; None = miss (legacy rung)."""
    for b in buckets:
        if length <= b:
            return b
    return None


def gemm_signatures(cfg: ModelConfig, m: int) -> list[tuple[int, int, int]]:
    """Per-LAYER (m, k, n) GEMM signatures of one stack pass over ``m``
    token rows — the shapes the CMR planner prices and the plan store keys
    on.  One entry per projection; callers multiply by ``cfg.num_layers``."""
    d, h, kvh, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                     cfg.head_dim_)
    return [
        (m, d, (h + 2 * kvh) * hd),     # fused qkv projection
        (m, h * hd, d),                 # attention output projection
        (m, d, 2 * cfg.d_ff),           # ffn gate+up
        (m, cfg.d_ff, d),               # ffn down
    ]


def _stack_price_s(cfg: ModelConfig, m: int, logit_rows: int) -> float:
    """Modeled seconds for one stack pass over ``m`` rows plus the unembed
    over ``logit_rows`` rows, via ``plan_gemm`` (consults the plan store
    first, analytic CMR otherwise) — pricing IS warming."""
    width = jnp.dtype(cfg.compute_dtype).itemsize
    t = 0.0
    for (mm, k, n) in gemm_signatures(cfg, m):
        t += plan_gemm(mm, k, n, width, width).t_total * cfg.num_layers
    t += plan_gemm(logit_rows, cfg.d_model, cfg.vocab_size, width,
                   width).t_total
    return t


@dataclasses.dataclass
class CostModel:
    """CMR-relative, measurement-calibrated serve pricing.

    Constructing it warms the plan cache for every bucket's prefill
    signatures and the fused decode signature (``warmed`` /
    ``store_lookups`` / ``store_hits`` record what that touched — the serve
    launch banner surfaces them).  ``observe_*`` feed measured wall times;
    ``prefill_s`` / ``step_s`` return calibrated estimates, or None while
    nothing has been measured yet (admission control admits unconditionally
    until the model is calibrated — never reject on an unpriced guess)."""
    cfg: ModelConfig
    buckets: tuple[int, ...]
    slots: int
    model_prefill: dict = dataclasses.field(default_factory=dict)
    model_step: float = 0.0
    obs_prefill: dict = dataclasses.field(default_factory=dict)
    obs_step: float | None = None
    warmed: int = 0
    store_lookups: int = 0
    store_hits: int = 0

    def __post_init__(self):
        store = plan_store.get_store()
        lk, ht = store.lookups, store.hits
        for b in self.buckets:
            # A bucket prefill runs the whole batch's rows through the
            # stack in one pass; logits are one row per request.
            self.model_prefill[b] = _stack_price_s(
                self.cfg, self.slots * b, self.slots)
            self.warmed += len(gemm_signatures(self.cfg, self.slots * b)) + 1
        self.model_step = _stack_price_s(self.cfg, self.slots, self.slots)
        self.warmed += len(gemm_signatures(self.cfg, self.slots)) + 1
        self.store_lookups = store.lookups - lk
        self.store_hits = store.hits - ht

    # -- measurement feedback --------------------------------------------

    def observe_prefill(self, bucket: int, wall_s: float) -> None:
        prev = self.obs_prefill.get(bucket)
        self.obs_prefill[bucket] = (wall_s if prev is None else
                                    prev + _EWMA_ALPHA * (wall_s - prev))

    def observe_step(self, wall_s: float) -> None:
        self.obs_step = (wall_s if self.obs_step is None else
                         self.obs_step + _EWMA_ALPHA
                         * (wall_s - self.obs_step))

    # -- calibrated estimates --------------------------------------------

    def _scale(self) -> float | None:
        """Measured/modeled ratio averaged over observed buckets — how the
        CMR's relative prices transfer to never-measured buckets."""
        ratios = [wall / self.model_prefill[b]
                  for b, wall in self.obs_prefill.items()
                  if self.model_prefill.get(b, 0.0) > 0.0]
        if not ratios:
            return None
        return sum(ratios) / len(ratios)

    def prefill_s(self, bucket: int | None) -> float | None:
        """Estimated wall seconds for one batch prefill at ``bucket``
        (None bucket = legacy rung: priced as the largest bucket)."""
        if bucket is None:
            bucket = self.buckets[-1]
        wall = self.obs_prefill.get(bucket)
        if wall is not None:
            return wall
        scale = self._scale()
        if scale is None:
            return None
        model = self.model_prefill.get(bucket)
        if model is None:
            model = _stack_price_s(self.cfg, self.slots * bucket, self.slots)
            self.model_prefill[bucket] = model
        return model * scale

    def step_s(self) -> float | None:
        return self.obs_step

    def calibrated(self) -> bool:
        return self.obs_step is not None and bool(self.obs_prefill)

    def snapshot(self) -> dict:
        return {
            "buckets": list(self.buckets),
            "warmed_signatures": self.warmed,
            "store_lookups": self.store_lookups,
            "store_hits": self.store_hits,
            "model_prefill_s": {str(b): self.model_prefill[b]
                                for b in self.buckets},
            "model_step_s": self.model_step,
            "observed_buckets": sorted(self.obs_prefill),
            "step_ewma_s": self.obs_step,
        }
