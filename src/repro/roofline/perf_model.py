"""Analytic FLOP / HBM-byte accounting per (arch x shape x step-kind).

Why analytic: XLA's ``cost_analysis`` on the compiled module is per-device
and counts each while-loop body ONCE (scan-over-layers => ~L x undercount),
and exposes no per-op breakdown to correct it.  This module reproduces the
dot-FLOP accounting of every operation in ``repro.models`` (the code is
ours, so the bookkeeping is exact for matmuls), and is VALIDATED against
``cost_analysis`` of fully-unrolled reduced configs in
``tests/test_perf_model.py`` — agreement within a few % on every family.

Bytes are a documented engineering approximation (sum of operand/result
streams of the major ops at the HBM level), exact for the decode cells
(weights + KV cache reads dominate) and conservative for train/prefill.

All numbers are GLOBAL (whole fleet); divide by chip count for per-device.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..configs.base import ModelConfig, ShapeConfig
from ..core.gemm import plan_moe_dispatch
from ..models.ssm import CONV_WIDTH, HEADDIM, ssm_dims


@dataclass
class Perf:
    flops: float = 0.0               # matmul(+attention) flops, forward
    bytes_hbm: float = 0.0           # HBM traffic (global)
    bytes_ici: float = 0.0           # cross-chip traffic (global) — NOT HBM:
    # priced at ICI bandwidth, never seen by XLA's per-device cost_analysis
    breakdown: dict = field(default_factory=dict)   # name -> [flops, hbm, ici]

    def add(self, name: str, flops: float = 0.0, byts: float = 0.0,
            ici: float = 0.0):
        self.flops += flops
        self.bytes_hbm += byts
        self.bytes_ici += ici
        d = self.breakdown.setdefault(name, [0.0, 0.0, 0.0])
        d[0] += flops
        d[1] += byts
        d[2] += ici


def _keff(s_q: int, kv_len: int, window: int, causal: bool,
          decode: bool) -> float:
    """Mean effective KV length per query under the window encoding."""
    if decode:
        full = kv_len
        if window > 0:
            return min(window, full)
        if window < 0:
            return min(-window, full)   # current chunk tail
        return full
    if not causal:
        return kv_len
    if window > 0:
        return min(window, (s_q + 1) / 2)
    if window < 0:
        return min(-window / 2, (s_q + 1) / 2)
    return (s_q + 1) / 2


def _attn(perf: Perf, cfg: ModelConfig, n_layers_by_window: dict[int, int],
          b: int, s_q: int, kv_len: int, *, causal=True, decode=False,
          cross=False, cdt=2):
    d, hd = cfg.d_model, cfg.head_dim_
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    t = b * s_q
    for window, n_l in n_layers_by_window.items():
        keff = _keff(s_q, kv_len, window, causal, decode)
        if not cross:
            proj_f = 2 * t * d * (nq * hd) + 2 * 2 * t * d * (nkv * hd)
        else:
            proj_f = 2 * t * d * (nq * hd)   # cross K/V projected separately
        proj_f += 2 * t * (nq * hd) * d      # output proj
        score_f = 2 * b * nq * hd * s_q * keff * 2   # qk^T and p@v
        byts = (proj_f / (2 * d) * cdt * 2           # act streams in/out
                + 2 * b * keff * nkv * hd * cdt * n_l * 0)  # kv read counted below
        kv_bytes = 2 * b * min(keff * 2, kv_len) * nkv * hd * cdt
        perf.add("attn_proj", proj_f * n_l, byts * n_l)
        perf.add("attn_score", score_f * n_l, kv_bytes * n_l)


def _mlp(perf: Perf, cfg: ModelConfig, n_l: int, t: int, cdt=2,
         ep_shards: int = 1):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.num_experts:
        perf.add("router", 2 * t * d * cfg.num_experts * n_l,
                 t * d * cdt * n_l)
        # Dispatch-mode x placement pricing comes from the SAME planner
        # object the GEMM stack tunes with (core.gemm.plan_moe_dispatch),
        # not a local special-case: ``rows`` is the exact dispatch-buffer
        # row count — E x capacity incl. min-clamp and sublane rounding for
        # "capacity" (the padding overhead is the paper's TGEMM-waste
        # phenomenon: tiny decode batches pay E x C_min slots regardless of
        # tokens), T x top_k for "ragged" (every routed copy and nothing
        # else; boundary-tile padding is sub-percent at these sizes) — and
        # the expert-parallel placement's a2a legs land in their own bucket.
        mp = plan_moe_dispatch(
            t, cfg.num_experts, cfg.top_k, d, f,
            dispatch=cfg.moe_dispatch,
            capacity_factor=cfg.capacity_factor,
            elt_bytes=cdt, num_shards=ep_shards)
        cap_tokens = mp.rows
        perf.add("moe_mlp", 6 * cap_tokens * d * f * n_l,
                 (2 * cap_tokens * d * cdt + 3 * d * f * cdt
                  * cfg.num_experts) * n_l)
        if mp.placement is not None:
            # EP: tokens cross ICI (dispatch + return); flops unchanged,
            # and the bytes are ICI — kept out of the HBM stream totals.
            perf.add("moe_a2a", ici=mp.placement.ici_bytes * n_l)
    else:
        perf.add("mlp", 6 * t * d * f * n_l,
                 (2 * t * d * cdt + 3 * d * f * cdt) * n_l)


def _ssm(perf: Perf, cfg: ModelConfig, n_l: int, b: int, s: int,
         decode: bool, cdt=2):
    d = cfg.d_model
    di, hh, n = ssm_dims(d, cfg.ssm_state)
    p = HEADDIM
    t = b * s
    proj_out = 2 * di + 2 * n + hh
    perf.add("ssm_proj", (2 * t * d * proj_out + 2 * t * di * d) * n_l,
             (2 * t * d * cdt + (d * proj_out + di * d) * 4) * n_l)
    perf.add("ssm_conv", 2 * t * CONV_WIDTH * (di + 2 * n) * n_l,
             t * (di + 2 * n) * cdt * n_l)
    if decode:
        # h' = decay h + x (x) b ; y = C.h : ~4 flops per state element
        perf.add("ssm_state", 4 * t * hh * p * n * n_l,
                 2 * t * hh * p * n * 4 * n_l)   # state read+write f32
    else:
        q = cfg.ssm_chunk
        intra = 2 * t * q * n + 2 * t * q * hh * p   # cb + y_intra
        inter = 3 * 2 * t * hh * p * n               # y_inter/state upd/decay
        perf.add("ssm_ssd", (intra + inter) * n_l,
                 (t * hh * p * cdt * 3) * n_l)


def forward_perf(cfg: ModelConfig, b: int, s: int, kind: str,
                 ep_shards: int = 1) -> Perf:
    """kind: train | prefill | decode (decode: s = cache len, one new tok).

    ``ep_shards`` > 1 prices the MoE layers expert-parallel (the a2a token
    exchange appears as the ``moe_a2a`` bucket) — pass the expert-axis size
    of the launch layout; 1 keeps replicated-expert semantics."""
    perf = Perf()
    decode = kind == "decode"
    t = b * (1 if decode else s)
    s_q = 1 if decode else s
    kv_len = s
    cdt = 2

    wins: dict[int, int] = {}
    for w in cfg.windows():
        wins[w] = wins.get(w, 0) + 1

    fam = cfg.family
    if fam in ("dense", "vlm", "moe", "encdec"):
        if fam == "vlm" and not decode:
            s_q = s + cfg.num_patches
            t = b * s_q
            kv_len = s_q
        _attn(perf, cfg, wins, b, s_q, kv_len, decode=decode, cdt=cdt)
        _mlp(perf, cfg, cfg.num_layers, t, cdt, ep_shards)
        if fam == "encdec":
            se = cfg.encoder_seq
            te = b * se
            if not decode:
                # encoder runs at train/prefill only (cross-KV then cached)
                _attn(perf, cfg, {0: cfg.encoder_layers}, b, se, se,
                      causal=False, cdt=cdt)
                _mlp(perf, cfg, cfg.encoder_layers, te, cdt)
                perf.add("frame_proj", 2 * te * cfg.d_model ** 2)
                perf.add("cross_kv", 2 * te * cfg.d_model
                         * (2 * cfg.num_kv_heads * cfg.head_dim_)
                         * cfg.num_layers)
            _attn(perf, cfg, {0: cfg.num_layers}, b, s_q, se,
                  causal=False, decode=decode, cross=True, cdt=cdt)
    elif fam == "ssm":
        _ssm(perf, cfg, cfg.num_layers, b, 1 if decode else s, decode, cdt)
    elif fam == "hybrid":
        _ssm(perf, cfg, cfg.num_layers, b, 1 if decode else s, decode, cdt)
        g = cfg.num_layers // cfg.attn_every
        _attn(perf, cfg, {0: g}, b, s_q, kv_len, decode=decode, cdt=cdt)
        _mlp(perf, cfg, g, t, cdt, ep_shards)
    if cfg.num_patches and not decode:
        perf.add("patch_proj", 2 * b * cfg.num_patches * cfg.d_model ** 2)

    # coarse elementwise terms (norms/residuals/rope/softmax) — small at
    # production scale, keeps validation tight at reduced scale
    n_l = cfg.num_layers
    perf.add("elementwise", 25.0 * t * cfg.d_model * n_l)
    if cfg.num_heads:
        for window, nw in wins.items():
            keff = _keff(s_q, kv_len, window, True, decode)
            perf.add("elementwise",
                     6.0 * b * cfg.num_heads * s_q * keff * nw)
    if fam in ("ssm", "hybrid"):
        perf.add("elementwise",
                 4.0 * b * (1 if decode else s) * cfg.ssm_chunk
                 * (2 * cfg.d_model // 64) * n_l)

    # unembed: all positions for train, last position otherwise
    t_logits = t if kind == "train" else b
    perf.add("unembed", 2 * t_logits * cfg.d_model * cfg.vocab_padded,
             t_logits * cfg.vocab_padded * 4)
    perf.add("embed", 0.0, t * cfg.d_model * cdt)
    return perf


def step_perf(cfg: ModelConfig, shape: ShapeConfig,
              ep_shards: int = 1) -> Perf:
    """Whole-step perf: training includes backward + remat recompute +
    optimizer; decode/prefill are forward-only.  ``ep_shards`` as in
    ``forward_perf``."""
    kind = shape.kind
    fwd = forward_perf(cfg, shape.global_batch, shape.seq_len, kind,
                       ep_shards)
    if kind != "train":
        # weights are read once per step regardless of batch
        n_params = cfg.param_count()
        pbytes = 2 if cfg.param_dtype == "bfloat16" else 4
        fwd.add("weights", 0.0, n_params * pbytes)
        if kind == "decode":
            # cache READS are already counted per-layer in attn_score /
            # ssm_state; this bucket is the one-token cache WRITE only
            fwd.add("kv_cache_write", 0.0,
                    _cache_bytes(cfg, shape) / max(shape.seq_len, 1))
        return fwd
    mult = {"none": 3.0, "dots": 3.4, "full": 4.0}[cfg.remat]
    inner_ckpt = {"attn_score", "ssm_ssd"}   # jax.checkpoint'd inner scans
    out = Perf()
    for k, (f, by, ici) in fwd.breakdown.items():
        m = mult + 1.0 if k in inner_ckpt else mult
        # ICI scales like the HBM streams: the backward runs its own
        # exchange legs (dY in, dX back) and remat re-runs the forward's.
        out.add(k, f * m, by * (m - 1.0), ici * (m - 1.0))
    n_params = cfg.param_count()
    # params read fwd+bwd, grads written+read, adam m/v read+write, p write
    out.add("weights_opt", 10.0 * n_params, 12.0 * n_params * 4)
    # layer-scan residual checkpoints: save + 2 reads, bf16
    t = shape.tokens
    out.add("residual_ckpt", 0.0, 3.0 * cfg.num_layers * t * cfg.d_model * 2)
    return out


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    b, s = shape.global_batch, shape.seq_len
    kvh, hd = cfg.num_kv_heads, cfg.head_dim_
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        c = 2 * cfg.num_layers * b * s * kvh * hd * 2
        if cfg.family == "encdec":
            c += 2 * cfg.num_layers * b * cfg.encoder_seq * kvh * hd * 2
        return c
    di, hh, n = ssm_dims(cfg.d_model, cfg.ssm_state)
    ssm = cfg.num_layers * b * (hh * HEADDIM * n * 4
                                + (CONV_WIDTH - 1) * (di + 2 * n) * 2)
    if cfg.family == "hybrid":
        g = cfg.num_layers // cfg.attn_every
        ssm += 2 * g * b * s * kvh * hd * 2
    return ssm
