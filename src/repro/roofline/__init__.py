from .analysis import Roofline, build_roofline, collective_bytes
from .perf_model import forward_perf, step_perf

__all__ = ["Roofline", "build_roofline", "collective_bytes",
           "forward_perf", "step_perf"]
