"""Collective dissection: loop-aware per-op listing for the perf loop.

    PYTHONPATH=src python -m repro.roofline.dissect --arch qwen3-8b \
        --shape train_4k [--variant baseline] [--top 20]

Prints each collective with its wire bytes x trip count and the HLO
metadata op_name (which maps back to the JAX source op), so hypotheses in
EXPERIMENTS.md §Perf cite actual offenders instead of guesses.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import re

from .analysis import (_CALL_RE, _CONST_RE, _LINE_RE, _WHILE_RE,
                       _split_computations, _tensor_bytes)


def dissect(hlo_text: str, top: int = 25) -> list[tuple]:
    comps, entry = _split_computations(hlo_text)
    trip = {}
    for name, lines in comps.items():
        consts = [int(c) for ln in lines for c in _CONST_RE.findall(ln)]
        if consts:
            trip[name] = max(consts)

    rows = []

    def walk(name, mult, seen):
        if name not in comps or name in seen:
            return
        seen = seen | {name}
        for line in comps[name]:
            m = _LINE_RE.search(line)
            if m and (m.group("op") + "-done") not in line:
                byts = _tensor_bytes(m.group("ret"))
                meta = re.search(r'op_name="([^"]+)"', line)
                rows.append((byts * mult, m.group("op"), byts, mult,
                             (meta.group(1) if meta else "?")[:110]))
            w = _WHILE_RE.search(line)
            if w:
                walk(w.group(2), mult * trip.get(w.group(1), 1), seen)
                continue
            c = _CALL_RE.search(line)
            if c:
                walk(c.group(1), mult, seen)

    walk(entry, 1.0, frozenset())
    return sorted(rows, reverse=True)[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    from ..launch import dryrun as D
    from ..configs import SHAPES, get_config
    from ..core.dist import DistContext, use_dist
    from ..launch.mesh import make_production_mesh
    from ..launch.sharding import (batch_specs, cache_specs, dp_axes,
                                   param_specs, to_shardings)
    from ..optim.adamw import OptConfig
    from ..train.train_step import (make_prefill_step, make_serve_step,
                                    make_train_step)
    import jax
    import jax.numpy as jnp

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    knobs = dict(D.VARIANTS[args.variant])
    zero_stage = knobs.pop("zero_stage", 3)
    moe_ep = knobs.pop("moe_ep", False)
    moe_ep_axis = knobs.pop("moe_ep_axis", "dp")
    mesh = make_production_mesh()
    from ..launch.sharding import expert_axis
    dist = DistContext(mesh=mesh, dp_axes=dp_axes(mesh), model_axis="model",
                       moe_ep_axis=expert_axis(mesh, moe_ep, moe_ep_axis,
                                               cfg.num_experts or None),
                       **knobs)
    with use_dist(dist), mesh:
        batch = D.input_specs(cfg, shape)
        b_shard = to_shardings(batch_specs(cfg, batch, mesh), mesh)
        if shape.kind == "train":
            params, opt = D.abstract_state(cfg, shape, True)
            jitted = jax.jit(
                make_train_step(cfg, OptConfig()),
                in_shardings=(
                    to_shardings(param_specs(params, mesh,
                                             zero_stage=zero_stage,
                                             moe_ep=moe_ep), mesh),
                    to_shardings(param_specs(opt, mesh, zero_stage=3,
                                             moe_ep=moe_ep), mesh),
                    b_shard),
                donate_argnums=(0, 1))
            hlo = jitted.lower(params, opt, batch).compile().as_text()
        else:
            from ..models.model import make_cache
            params, _ = D.abstract_state(cfg, shape, False)
            cache = jax.eval_shape(
                lambda: make_cache(cfg, shape.global_batch, shape.seq_len))
            p_sh = to_shardings(param_specs(params, mesh,
                                            zero_stage=zero_stage,
                                            moe_ep=moe_ep), mesh)
            c_sh = to_shardings(cache_specs(cfg, cache, mesh), mesh)
            if shape.kind == "prefill":
                jitted = jax.jit(make_prefill_step(cfg),
                                 in_shardings=(p_sh, b_shard, c_sh),
                                 donate_argnums=(2,))
                hlo = jitted.lower(params, batch, cache).compile().as_text()
            else:
                jitted = jax.jit(
                    make_serve_step(cfg),
                    in_shardings=(p_sh, c_sh, b_shard["tokens"], None),
                    donate_argnums=(1,))
                hlo = jitted.lower(params, cache, batch["tokens"],
                                   jax.ShapeDtypeStruct((), jnp.int32)
                                   ).compile().as_text()

    total = 0.0
    for tot, op, byts, mult, meta in dissect(hlo, args.top):
        total += tot
        print(f"{tot/2**30:9.3f} GiB  {op:19s} x{mult:5.0f} "
              f"({byts/2**20:9.2f} MiB each)  {meta}")
    print(f"TOTAL(top {args.top}): {total/2**30:.2f} GiB")


if __name__ == "__main__":
    main()
