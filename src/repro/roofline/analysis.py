"""Three-term roofline from the compiled dry-run artifact.

    compute    = HLO_FLOPs  / (chips x peak FLOP/s)
    memory     = HLO_bytes  / (chips x HBM bw)
    collective = coll_bytes / (chips x links x link bw)

``cost_analysis`` supplies FLOPs/bytes.  Collective bytes are NOT in
cost_analysis: we parse the post-optimization HLO text and sum tensor sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Convention (documented in EXPERIMENTS.md): per-op wire
bytes = result-tensor bytes, x2 for all-reduce (reduce + broadcast phases of
a ring).  HLO totals are whole-program (all chips); cost_analysis FLOPs are
already whole-program, so both are divided by chip count.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, asdict

from ..core.gemm.cmr import TPU_V5E, TpuSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# e.g. "%ag = bf16[2,1024,512]{2,1,0} all-gather(...)" possibly with a
# tuple result "( f32[..], f32[..] )".
_LINE_RE = re.compile(
    r"=\s*(?P<ret>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*{")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"[su]32\[\]\s+constant\((\d+)\)")
_CALL_RE = re.compile(
    r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w\.\-]+)")


def _split_computations(hlo_text: str) -> tuple[dict[str, list[str]], str]:
    comps: dict[str, list[str]] = {}
    cur = None
    entry = ""
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                entry = cur
        elif cur is not None:
            comps[cur].append(line)
    return comps, entry


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Loop-aware collective byte totals per op type.

    Scan-over-layers puts per-layer collectives inside HLO while bodies,
    which appear ONCE in the text; we recover true totals by multiplying a
    body's collectives by its loop trip count (read from the s32 constant in
    the loop's condition computation), recursively for nested scans.
    """
    comps, entry = _split_computations(hlo_text)

    trip: dict[str, int] = {}        # condition comp -> trip count
    for name, lines in comps.items():
        consts = [int(c) for ln in lines for c in _CONST_RE.findall(ln)]
        if consts:
            trip[name] = max(consts)

    def comp_totals(name: str, mult: float, out, counts, seen):
        if name not in comps or name in seen:
            return
        seen = seen | {name}
        for line in comps[name]:
            m = _LINE_RE.search(line)
            if m and (m.group("op") + "-done") not in line:
                out[m.group("op")] += _tensor_bytes(m.group("ret")) * mult
                counts[m.group("op")] += mult
            w = _WHILE_RE.search(line)
            if w:
                cond, body = w.group(1), w.group(2)
                comp_totals(body, mult * trip.get(cond, 1), out, counts, seen)
                continue
            c = _CALL_RE.search(line)
            if c:
                comp_totals(c.group(1), mult, out, counts, seen)

    out = {op: 0.0 for op in _COLL_OPS}
    counts = {op: 0.0 for op in _COLL_OPS}
    comp_totals(entry or max(comps, key=lambda k: len(comps[k]), default=""),
                1.0, out, counts, frozenset())
    out_all = dict(out)
    out_all.update({f"n_{k}": counts[k] for k in counts})
    return out_all


@dataclass
class Roofline:
    """Per-device three-term roofline for one (arch x shape x mesh) cell.

    * flops/bytes: analytic perf model (repro.roofline.perf_model — validated
      against fully-unrolled compiled probes), global / chips.
    * collective wire bytes: loop-aware parse of the compiled per-device HLO
      (scan bodies multiplied by trip counts); convention: result-tensor
      bytes per op, x2 for all-reduce (ring reduce + broadcast phases).
    * raw_cost: XLA cost_analysis as-is (per-device, loop bodies counted
      once) for reference.
    """
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device_hbm: float
    coll_bytes_wire: float
    coll_by_type: dict = field(default_factory=dict)
    raw_cost: dict = field(default_factory=dict)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    model_flops: float = 0.0            # 6*N_active*D (train) / 2*N*D (inf)
    peak_memory_per_device: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Ideal step time with perfect overlap = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / compiled-equivalent FLOPs (catches remat/padding)."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL FLOPs / (chips * peak * t_bound): fraction of fleet bf16
        peak spent on useful model math at the modeled bound."""
        if not self.t_bound:
            return 0.0
        spec = TPU_V5E
        return self.model_flops / (self.chips * spec.peak_flops_bf16
                                   * self.t_bound)

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(dominant=self.dominant, t_bound=self.t_bound,
                 useful_fraction=self.useful_fraction,
                 roofline_fraction=self.roofline_fraction)
        return d


def build_roofline(
    *, arch: str, shape: str, mesh_name: str, chips: int,
    analytic_flops: float, analytic_bytes: float,
    cost: dict, coll: dict, model_flops: float,
    memory_stats: dict | None = None,
    spec: TpuSpec = TPU_V5E,
) -> Roofline:
    wire = (2.0 * coll.get("all-reduce", 0.0)
            + coll.get("all-gather", 0.0)
            + coll.get("reduce-scatter", 0.0)
            + coll.get("all-to-all", 0.0)
            + coll.get("collective-permute", 0.0))
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=analytic_flops / chips,
        bytes_per_device_hbm=analytic_bytes / chips,
        coll_bytes_wire=wire, coll_by_type=coll,
        raw_cost={k: cost.get(k) for k in
                  ("flops", "bytes accessed", "transcendentals")
                  if k in cost},
        model_flops=model_flops,
    )
    r.t_compute = r.flops_per_device / spec.peak_flops_bf16
    r.t_memory = r.bytes_per_device_hbm / spec.hbm_bw
    r.t_collective = wire / (spec.ici_links * spec.ici_bw_per_link)
    if memory_stats:
        r.peak_memory_per_device = memory_stats.get("peak_memory", 0.0)
    return r


def model_flops_estimate(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = active params, D = tokens);
    2*N*D for inference forward."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * shape.tokens
    if kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch   # decode: one token per sequence
