"""Deterministic synthetic token pipeline, shard-aware, with background
prefetch.

Production posture without shipping a corpus: batches are generated
deterministically from (seed, step) — any host can regenerate any shard of
any step independently, which is what makes checkpoint-restart and elastic
re-sharding trivial (restoring at step k on a different mesh replays the
exact global batch k).  Generation is zipfian over the vocab with a
document-boundary structure so losses are non-degenerate.

``make_global_batch`` builds a jax.Array from per-shard callbacks
(``jax.make_array_from_callback``), so each host only materializes its
addressable shards — the multi-host path and the single-host path are the
same code.
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed

    def _tokens(self, step: int, row0: int, nrows: int) -> np.ndarray:
        """Rows [row0, row0+nrows) of the global batch at ``step``."""
        s = self.shape.seq_len
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row0]))
        # zipfian unigram stream with doc boundaries every ~512 tokens
        v = self.cfg.vocab_size
        ranks = rng.zipf(1.3, size=(nrows, s + 1)).astype(np.int64)
        toks = np.minimum(ranks, v - 1).astype(np.int32)
        doc_len = rng.integers(128, 1024)
        toks[:, ::doc_len] = 1   # BOS-ish
        return toks

    def host_batch(self, step: int) -> dict[str, np.ndarray]:
        """Whole global batch on this host (single-host convenience)."""
        b, s = self.shape.global_batch, self.shape.seq_len
        toks = self._tokens(step, 0, b)
        return self._pack(toks)

    def _pack(self, toks: np.ndarray) -> dict[str, np.ndarray]:
        cfg, s = self.cfg, self.shape.seq_len
        batch = {
            "tokens": toks[:, :s],
            "labels": toks[:, 1:s + 1],
            "loss_mask": np.ones((toks.shape[0], s), np.float32),
        }
        b = toks.shape[0]
        if cfg.family == "encdec":
            rng = np.random.default_rng(abs(hash((self.seed, int(toks[0, 0])))) % 2**32)
            batch["frames"] = rng.standard_normal(
                (b, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.num_patches:
            rng = np.random.default_rng(abs(hash((self.seed, 7, int(toks[0, 0])))) % 2**32)
            batch["patch_embeds"] = rng.standard_normal(
                (b, cfg.num_patches, cfg.d_model)).astype(np.float32) * 0.02
        return batch

    def make_global_batch(self, step: int, shardings: dict) -> dict:
        """Build sharded jax.Arrays; each shard generated independently."""
        host = self.host_batch(step)

        def arr(name):
            data = host[name]
            sh = shardings.get(name) if isinstance(shardings, dict) else None
            if sh is None:
                return jax.numpy.asarray(data)
            return jax.make_array_from_callback(
                data.shape, sh, lambda idx: data[idx])

        return {k: arr(k) for k in host}


class Prefetcher:
    """Background thread generating the next N batches."""

    def __init__(self, dataset: SyntheticLM, shardings=None, depth: int = 2,
                 start_step: int = 0):
        self.dataset = dataset
        self.shardings = shardings or {}
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        while not self._stop.is_set():
            batch = self.dataset.make_global_batch(self.step, self.shardings)
            self.q.put((self.step, batch))
            self.step += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
