"""repro: ftIMM (irregular-shaped GEMM on software-managed-memory cores)
as a production JAX/Pallas training + serving framework for TPU pods."""
__version__ = "0.1.0"
