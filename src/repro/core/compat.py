"""JAX version compatibility layer.

Policy: the repo targets the *pinned* container JAX (0.4.x line) while
staying forward-compatible with newer releases.  Every API that moved or was
renamed between 0.4.x and 0.5+/0.6+ is wrapped HERE, once, and the rest of
the codebase imports from ``repro.core.compat`` — never version-checks
inline.  Wrapped surfaces:

  * ``shard_map``        — ``jax.shard_map`` (new) vs
                           ``jax.experimental.shard_map.shard_map`` (0.4.x).
  * ``shard_map_unchecked`` — shard_map with replication checking off under
                           either kwarg name (``check_rep`` -> ``check_vma``);
                           required around pallas_call bodies on 0.4.x.
  * ``make_mesh``        — ``jax.make_mesh`` grew an ``axis_types`` kwarg and
                           ``jax.sharding.AxisType`` only exists on newer
                           releases; we always want plain Auto axes.
  * ``normalize_cost_analysis`` — ``Compiled.cost_analysis()`` returns a
                           list-of-dict on 0.4.x and a flat dict on newer
                           versions.
  * ``pallas_compiler_params`` — ``pltpu.CompilerParams`` is the new name of
                           ``pltpu.TPUCompilerParams``.
  * ``prefetch_scalar_grid_spec`` — ``pltpu.PrefetchScalarGridSpec`` (scalar-
                           prefetch grids for data-dependent index maps, e.g.
                           the ragged grouped GEMM metadata).
  * ``ragged_all_to_all``  — ``jax.lax.ragged_all_to_all`` exists only on
                           newer releases (and not on every backend); exposed
                           as ``None`` when absent so the collective exchange
                           layer can probe for it and fall back to the dense
                           realization.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax

# --- shard_map -------------------------------------------------------------

if hasattr(jax, "shard_map"):                     # jax >= 0.5
    shard_map = jax.shard_map
else:                                             # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


def _shard_map_uncheck_kwargs() -> dict:
    """The kwarg that disables shard_map's replication checking, under its
    current name: ``check_rep`` (0.4.x/0.5) became ``check_vma`` later."""
    import inspect
    params = inspect.signature(shard_map).parameters
    for name in ("check_rep", "check_vma"):
        if name in params:
            return {name: False}
    return {}


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off.

    Needed whenever the mapped body contains a ``pallas_call`` (the ftIMM
    kernels): 0.4.x has no replication rule for it and raises
    NotImplementedError under the default ``check_rep=True``."""
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **_shard_map_uncheck_kwargs())


# --- mesh construction -----------------------------------------------------

def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None) -> jax.sharding.Mesh:
    """Portable ``jax.make_mesh`` with Auto axis types on every version."""
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


# --- ragged all-to-all -----------------------------------------------------

# The true ragged collective (newer jax; backend support varies).  ``None``
# on the pinned 0.4.x line.  Consumers must treat availability of the symbol
# as necessary but NOT sufficient: ``core.gemm.collective`` runs a concrete
# round-trip probe on the actual mesh before trusting it, and falls back to
# the dense all_gather/psum_scatter realization otherwise.
ragged_all_to_all = getattr(jax.lax, "ragged_all_to_all", None)


# --- compiled cost analysis ------------------------------------------------

def normalize_cost_analysis(cost) -> dict:
    """``Compiled.cost_analysis()`` -> one flat dict on every version.

    jax 0.4.x returns ``[{...}]`` (one dict per program); newer versions
    return the dict directly.  Missing/empty analyses normalize to ``{}``.
    """
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        merged: dict = {}
        for entry in cost:
            if entry:
                merged.update(entry)
        return merged
    return dict(cost)


def cost_analysis(compiled) -> dict:
    """Run + normalize ``compiled.cost_analysis()``."""
    return normalize_cost_analysis(compiled.cost_analysis())


# --- pallas compiler params ------------------------------------------------

def pallas_compiler_params(**kwargs):
    """Build TPU Pallas compiler params under either class name."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def prefetch_scalar_grid_spec(*, num_scalar_prefetch: int, grid, in_specs,
                              out_specs, scratch_shapes=()):
    """Scalar-prefetch grid spec (index maps may read int32 operands).

    ``pltpu.PrefetchScalarGridSpec`` has kept its name across the 0.4.x ->
    current line; wrapped here anyway so any future rename/move lands in one
    place (repo compat policy — see module docstring)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch, grid=grid,
        in_specs=in_specs, out_specs=out_specs,
        scratch_shapes=list(scratch_shapes))
