"""Computation-to-memory-ratio (CMR) model — paper §IV-C Eqs. 1-4, adapted.

The paper derives block sizes by maximizing the CMR of each on-chip memory
level under capacity limits (GSM 6 MB / SM 64 KB / AM 768 KB, DMA'd).  On
TPU the two-level hierarchy is HBM -> VMEM with the Pallas grid pipeline as
the DMA engine, so the adapted model estimates, per candidate tiling:

  * HBM traffic (bytes) given the revisiting/reuse pattern of the grid,
  * padded compute (the cost TGEMM pays for its fixed micro-kernel),
  * a per-shape *upper-bound utilization fraction* — the TPU analogue of the
    paper's broadcast-bandwidth bound (100% for 64 < n_a <= 96, 66.7% for
    n_a <= 32): on TPU the MXU is a 128x128 systolic array, so lanes beyond
    N are dead unless repacked, and streams shorter than ~128 rows pay the
    pipeline-fill latency.

The original paper formulas are kept verbatim (``paper_f1..f4``) so the
benchmarks can reproduce the paper's block-size reasoning next to ours.
"""
from __future__ import annotations

from dataclasses import dataclass


def ceil_to(x: int, b: int) -> int:
    return -(-x // b) * b


def cdiv(x: int, b: int) -> int:
    return -(-x // b)


@dataclass(frozen=True)
class TpuSpec:
    """TPU v5e per-chip constants (targets; container runs CPU)."""
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12
    peak_flops_fp32: float = 98.5e12        # MXU fp32 ~ half bf16 rate
    peak_flops_int8: float = 394e12         # int8 OPS ~ 2x bf16 rate
    hbm_bw: float = 819e9                   # bytes/s
    vmem_budget: int = 16 * 1024 * 1024     # usable VMEM per core (conservative)
    lane: int = 128                          # vreg lanes / MXU width
    sublane_fp32: int = 8
    sublane_bf16: int = 16
    sublane_int8: int = 32
    mxu: int = 128                           # systolic array edge
    ici_bw_per_link: float = 50e9           # bytes/s per ICI link
    ici_links: int = 4                      # usable links/chip on a 2D torus
    num_chips: int = 1

    def peak_flops(self, dtype_bytes: int) -> float:
        """Peak MXU rate for the *compute* element width.  1-byte operands
        (int8 / fp8) run at the narrow-dtype peak — NOT the bf16 peak the
        pre-quant model fell through to, which overpriced int8 compute 2x."""
        if dtype_bytes >= 4:
            return self.peak_flops_fp32
        if dtype_bytes == 1:
            return self.peak_flops_int8
        return self.peak_flops_bf16

    def sublane(self, dtype_bytes: int) -> int:
        """Register-tile second-to-minor extent: (8,128) fp32, (16,128)
        bf16/fp16, (32,128) int8/fp8 — matches ``kernels.ftimm.sublane``."""
        if dtype_bytes >= 4:
            return self.sublane_fp32
        if dtype_bytes == 1:
            return self.sublane_int8
        return self.sublane_bf16

    def calibrated(self, flops_frac: float, bw_frac: float,
                   ici_frac: float = 1.0,
                   int8_frac: float | None = None) -> "TpuSpec":
        """The measured-effective view of this device: peak FLOP/s scaled by
        the achievable fraction, HBM bandwidth by the effective fraction
        (both fitted by ``autotune.calibrate`` from measured-vs-predicted
        ratios), ICI per-link bandwidth by the effective-ICI fraction
        fitted by ``autotune.calibrate_ici`` from timed mesh exchanges, and
        the int8 peak by its own fitted fraction when the calibration run
        carried narrow-dtype samples (``None`` falls back to the shared
        flops fraction).  Capacities and tile geometry stay nominal — only
        the roofline rates are what measurement corrects."""
        from dataclasses import replace
        return replace(
            self,
            name=f"{self.name}+cal",
            peak_flops_bf16=self.peak_flops_bf16 * flops_frac,
            peak_flops_fp32=self.peak_flops_fp32 * flops_frac,
            peak_flops_int8=self.peak_flops_int8
            * (flops_frac if int8_frac is None else int8_frac),
            hbm_bw=self.hbm_bw * bw_frac,
            ici_bw_per_link=self.ici_bw_per_link * ici_frac,
        )


TPU_V5E = TpuSpec()


def upper_bound_fraction(m: int, n: int, k: int, spec: TpuSpec = TPU_V5E) -> float:
    """Per-shape upper bound on MXU utilization (paper §IV-A3 analogue).

    Paper: broadcast bandwidth caps small-n_a kernels at 66.7%.  TPU: the
    lane dimension (N) of the MXU below 128 leaves columns dead, a
    contraction below 128 leaves rows dead, and short M streams pay the
    ~MXU-depth pipeline fill.
    """
    lane_frac = min(n, spec.lane) / spec.lane if n < spec.lane else 1.0
    k_frac = min(k, spec.mxu) / spec.mxu if k < spec.mxu else 1.0
    stream_frac = m / (m + spec.mxu)  # pipeline fill amortization
    return lane_frac * k_frac * min(1.0, stream_frac * 2.0)


@dataclass(frozen=True)
class PlanEstimate:
    """Roofline-style estimate for one candidate tiling."""
    flops_useful: float
    flops_padded: float
    hbm_bytes: float
    t_compute: float
    t_memory: float
    vmem_bytes: int
    mxu_fraction: float

    @property
    def t_total(self) -> float:
        # ping-pong / Pallas pipeline: compute overlaps DMA, take the max.
        return max(self.t_compute, self.t_memory)

    @property
    def bound(self) -> str:
        return "compute" if self.t_compute >= self.t_memory else "memory"


def _pad_copy_bytes(orig: int, padded: int, elt_bytes: int) -> float:
    """HBM traffic of one materialized pad (or slice) copy: the original is
    read once and the padded buffer written once (slicing is the mirror
    image).  Zero when already aligned — the copy is elided."""
    if padded == orig:
        return 0.0
    return float(orig + padded) * elt_bytes


def _epilogue_bytes(m: int, n: int, out_bytes: int, epi_ops: int,
                    epi_fused: bool) -> float:
    """Post-GEMM elementwise tail traffic.  Fused into the accumulator flush
    it is free (the output write already happens; bias/residual reads are
    counted small enough to ignore at this altitude); run as ``epi_ops``
    separate XLA passes each one re-reads and re-writes C through HBM."""
    if epi_fused or epi_ops <= 0:
        return 0.0
    return float(epi_ops) * 2.0 * m * n * out_bytes


def estimate(
    m: int, k: int, n: int,
    *,
    bm: int, bn: int, bk: int,
    nsplit: int = 1,
    dim_order: str = "mn",
    in_bytes: int = 4,
    out_bytes: int = 4,
    b_bytes: int | None = None,
    edge: str = "masked",
    epi_ops: int = 0,
    epi_fused: bool = True,
    spec: TpuSpec = TPU_V5E,
) -> PlanEstimate:
    """Model one tiling of C(M,N) += A(M,K) B(K,N) on one TPU core.

    Grid is (outer, inner, K) with K innermost and the fp32 accumulator
    revisited in VMEM (M-parallel), or split-K with ``nsplit`` partials
    reduced through HBM (K-parallel).

    Traffic follows Pallas pipeline semantics: a block is re-fetched whenever
    its index map changes between consecutive grid steps.  When gk == 1 the
    operand indexed only by the *outer* grid dim stays resident across the
    whole inner sweep — the TPU analogue of the paper's "B panel cached in
    GSM" (Alg. 4): e.g. T1 (M >> K ~ N <= 128) with bk=K, bn=ceil(N,128),
    dim_order="nm" streams A exactly once and loads B exactly once.

    ``edge="padded"`` prices the legacy pad -> kernel -> slice wrapper: each
    unaligned operand pays a materialized pad copy and the output a slice
    copy; ``"masked"`` (in-kernel edge tiles) pays nothing extra.  ``epi_ops``
    is the post-GEMM elementwise tail length: fused (``epi_fused``) it rides
    the accumulator flush for free, unfused each op re-reads + re-writes C.

    ``b_bytes`` prices a mixed-width B operand (weight-only quant: bf16
    activations x int8 weights) — B-side traffic, pad copies, and VMEM run
    at the narrow width while the MXU rate is set by the *wider* operand
    (the narrow one upcasts at load).  ``None`` means B matches A.
    """
    bb = in_bytes if b_bytes is None else b_bytes
    mp, np_, kp = ceil_to(m, bm), ceil_to(n, bn), ceil_to(k, bk * nsplit)
    gm, gn, gk = mp // bm, np_ // bn, kp // (bk * nsplit)

    flops_useful = 2.0 * m * n * k
    flops_padded = 2.0 * mp * np_ * kp

    # HBM traffic under index-map-constancy reuse.
    if gk == 1 and nsplit == 1:
        if dim_order == "mn":   # i outer: A resident across the j sweep
            traffic_a = mp * kp * in_bytes
            traffic_b = kp * np_ * gm * bb
        else:                   # j outer: B resident across the i sweep
            traffic_a = mp * kp * gn * in_bytes
            traffic_b = kp * np_ * bb
    else:
        traffic_a = mp * kp * gn * in_bytes
        traffic_b = kp * np_ * gm * bb
    traffic_c = mp * np_ * out_bytes
    if nsplit > 1:
        # Partials written + re-read for the reduction (paper: through GSM;
        # here through HBM within a chip / ICI across chips).
        traffic_c += 2.0 * nsplit * mp * np_ * 4 + mp * np_ * 4
    hbm_bytes = traffic_a + traffic_b + traffic_c
    if edge == "padded":
        # Pad copies in (A, B) and the slice copy out, each a full HBM
        # round-trip the masked path never makes.
        hbm_bytes += _pad_copy_bytes(m * k, mp * kp, in_bytes)
        hbm_bytes += _pad_copy_bytes(k * n, kp * np_, bb)
        hbm_bytes += _pad_copy_bytes(m * n, mp * np_, out_bytes)
    hbm_bytes += _epilogue_bytes(m, n, out_bytes, epi_ops, epi_fused)

    frac = upper_bound_fraction(mp, np_, kp, spec)
    peak = spec.peak_flops(max(in_bytes, bb)) * max(frac, 1e-3)
    t_compute = flops_padded / peak
    t_memory = hbm_bytes / spec.hbm_bw

    # VMEM: double-buffered input blocks + resident fp32 accumulator + out.
    vmem = (2 * (bm * bk * in_bytes + bk * bn * bb)
            + bm * bn * 4
            + 2 * bm * bn * out_bytes)
    return PlanEstimate(
        flops_useful=flops_useful,
        flops_padded=flops_padded,
        hbm_bytes=hbm_bytes,
        t_compute=t_compute,
        t_memory=t_memory,
        vmem_bytes=vmem,
        mxu_fraction=frac,
    )


def estimate_batched(
    g: int, m: int, k: int, n: int,
    *,
    bm: int, bn: int, bk: int,
    dim_order: str = "mn",
    shared_a: bool = False,
    shared_b: bool = False,
    in_bytes: int = 4,
    out_bytes: int = 4,
    b_bytes: int | None = None,
    edge: str = "masked",
    epi_ops: int = 0,
    epi_fused: bool = True,
    spec: TpuSpec = TPU_V5E,
) -> PlanEstimate:
    """Model one tiling of the batched GEMM C(g) += A(g) B(g), g in [0, G).

    Grid is (g, outer, inner, K) with the batch dim outermost.  Per-entry
    traffic follows the same index-map-constancy reuse rule as ``estimate``;
    batched operands then re-fetch for every batch entry (their index map
    carries ``g``), while a *shared* operand (2-D, no batch dim — the grouped
    case) is counted once when the pipeline can actually keep it resident:
    its index map must be globally constant, i.e. a single block in every
    grid dim it reads (gk == 1 and its own outer extent == 1).  Otherwise the
    shared panel re-streams per batch entry exactly like the paper's
    re-fetched operand in the non-cached loop order.  ``b_bytes`` prices a
    mixed-width B operand (see ``estimate``).
    """
    bb = in_bytes if b_bytes is None else b_bytes
    mp, np_, kp = ceil_to(m, bm), ceil_to(n, bn), ceil_to(k, bk)
    gm, gn, gk = mp // bm, np_ // bn, kp // bk

    flops_useful = 2.0 * g * m * n * k
    flops_padded = 2.0 * g * mp * np_ * kp

    # Per-batch-entry traffic under index-map-constancy reuse (cf. estimate).
    if gk == 1:
        if dim_order == "mn":   # i outer: A resident across the j sweep
            ta_entry = mp * kp * in_bytes
            tb_entry = kp * np_ * gm * bb
        else:                   # j outer: B resident across the i sweep
            ta_entry = mp * kp * gn * in_bytes
            tb_entry = kp * np_ * bb
    else:
        ta_entry = mp * kp * gn * in_bytes
        tb_entry = kp * np_ * gm * bb

    a_resident = shared_a and gm == 1 and gk == 1
    b_resident = shared_b and gn == 1 and gk == 1
    traffic_a = (mp * kp * in_bytes) if a_resident else ta_entry * g
    traffic_b = (kp * np_ * bb) if b_resident else tb_entry * g
    traffic_c = g * mp * np_ * out_bytes
    hbm_bytes = traffic_a + traffic_b + traffic_c
    if edge == "padded":
        # Per-group pad copies (a shared 2-D operand pads once) + the
        # per-group output slice copy.
        hbm_bytes += _pad_copy_bytes(m * k, mp * kp, in_bytes) \
            * (1 if shared_a else g)
        hbm_bytes += _pad_copy_bytes(k * n, kp * np_, bb) \
            * (1 if shared_b else g)
        hbm_bytes += _pad_copy_bytes(m * n, mp * np_, out_bytes) * g
    hbm_bytes += _epilogue_bytes(g * m, n, out_bytes, epi_ops, epi_fused)

    frac = upper_bound_fraction(mp, np_, kp, spec)
    peak = spec.peak_flops(max(in_bytes, bb)) * max(frac, 1e-3)
    t_compute = flops_padded / peak
    t_memory = hbm_bytes / spec.hbm_bw

    # VMEM footprint is per grid step — independent of G (batch blocks are 1
    # entry deep), identical to the 2-D kernel's.
    vmem = (2 * (bm * bk * in_bytes + bk * bn * bb)
            + bm * bn * 4
            + 2 * bm * bn * out_bytes)
    return PlanEstimate(
        flops_useful=flops_useful,
        flops_padded=flops_padded,
        hbm_bytes=hbm_bytes,
        t_compute=t_compute,
        t_memory=t_memory,
        vmem_bytes=vmem,
        mxu_fraction=frac,
    )


def estimate_ragged(
    g: int, total: int, k: int, n: int,
    *,
    bm: int, bn: int, bk: int,
    ragged: str = "m",
    in_bytes: int = 4,
    out_bytes: int = 4,
    b_bytes: int | None = None,
    spec: TpuSpec = TPU_V5E,
) -> PlanEstimate:
    """Model one tiling of the ragged grouped GEMM over G groups.

    ``ragged == "m"``: rows of a flat (total, k) operand are chunked per group
    against per-group (k, n) panels — the capacity-free MoE forward.  Priced
    off the *actual* size distribution, i.e. the total row count plus at most
    one shared boundary tile per group — NOT G x max(rows_g) as the static
    capacity path must assume.  ``ragged == "k"``: the ragged dimension is the
    contraction (the backward dW — the paper's T2 regime per group); ``k`` is
    then the per-group output rows (D) and ``n`` the output cols (F).

    Traffic follows the ragged kernels' grids.  Forward (N/bn, NT, K/bk): the
    row operand re-streams once per N-block sweep; when gk == 1 each group's
    panel is fetched once per (j, group) run — the per-group analogue of the
    paper's "B panel cached in GSM"; shared boundary tiles re-store their
    output block (the masked read-modify-write).  dW (D/bm, F/bn, NT): both
    row operands stream once per output-panel block, each group's panel is
    stored once.  ``b_bytes`` prices mixed-width per-group panels (int8
    experts under bf16 tokens — see ``estimate``).
    """
    bb = in_bytes if b_bytes is None else b_bytes
    if ragged == "m":
        tp = ceil_to(max(total, 1), bm)
        visits = tp // bm + max(g - 1, 0)      # boundary tiles, ≤ 1 per group
        np_, kp = ceil_to(n, bn), ceil_to(k, bk)
        gn, gk = np_ // bn, kp // bk
        flops_useful = 2.0 * total * n * k
        flops_padded = 2.0 * visits * bm * np_ * kp
        traffic_x = gn * visits * bm * kp * in_bytes
        if gk == 1:   # panel resident across one group's row tiles
            traffic_w = g * kp * np_ * bb
        else:
            traffic_w = visits * kp * np_ * bb
        # One store per visit per N block; shared-tile visits re-read the
        # block they merge into (read-modify-write).
        traffic_c = visits * bm * np_ * out_bytes \
            + (visits - tp // bm) * bm * np_ * out_bytes
        vmem = (2 * (bm * bk * in_bytes + bk * bn * bb)
                + bm * bn * 4 + 2 * bm * bn * out_bytes)
        frac = upper_bound_fraction(bm, np_, kp, spec)
    elif ragged == "k":
        tp = ceil_to(max(total, 1), bk)
        visits = tp // bk + max(g - 1, 0)
        mp, np_ = ceil_to(k, bm), ceil_to(n, bn)
        gm, gn = mp // bm, np_ // bn
        flops_useful = 2.0 * total * k * n
        flops_padded = 2.0 * visits * bk * mp * np_
        traffic_x = gn * visits * bk * mp * in_bytes
        traffic_w = gm * visits * bk * np_ * bb
        traffic_c = g * mp * np_ * out_bytes
        vmem = (2 * (bk * bm * in_bytes + bk * bn * bb)
                + bm * bn * 4 + 2 * bm * bn * out_bytes)
        frac = upper_bound_fraction(bk, np_, mp, spec)
    else:
        raise ValueError(ragged)

    hbm_bytes = traffic_x + traffic_w + traffic_c
    peak = spec.peak_flops(max(in_bytes, bb)) * max(frac, 1e-3)
    return PlanEstimate(
        flops_useful=flops_useful,
        flops_padded=flops_padded,
        hbm_bytes=hbm_bytes,
        t_compute=flops_padded / peak,
        t_memory=hbm_bytes / spec.hbm_bw,
        vmem_bytes=vmem,
        mxu_fraction=frac,
    )


@dataclass(frozen=True)
class EpEstimate:
    """Modeled cost of ONE expert-parallel all-to-all leg over ICI."""
    ici_bytes: float        # global bytes crossing ICI (all shards summed)
    t_exchange: float       # seconds, set by the BOTTLENECK shard
    imbalance: float = 1.0  # max-shard rows / mean-shard rows

    def __add__(self, other: "EpEstimate") -> "EpEstimate":
        return EpEstimate(self.ici_bytes + other.ici_bytes,
                          self.t_exchange + other.t_exchange,
                          max(self.imbalance, other.imbalance))


EP_ZERO = EpEstimate(0.0, 0.0)


def estimate_ep(
    rows: int, width: int, num_shards: int,
    *,
    elt_bytes: int = 4,
    spec: TpuSpec = TPU_V5E,
    max_shard_rows: int | None = None,
) -> EpEstimate:
    """Price one all-to-all leg of the EP token exchange.

    A (rows, width) token matrix is row-sharded over ``num_shards`` chips,
    and each chip must forward the ``(num_shards - 1) / num_shards``
    fraction of its rows that route to experts owned by other chips.  Each
    chip transmits its share across its ICI links; the exchange is
    bandwidth-bound, so t is a per-chip send time — and like the
    asymmetric-multicore result (slowest participant sets the clock), it is
    the time of the *max* shard, not the mean.  ``max_shard_rows`` is the
    largest per-shard row count when the caller knows the actual group
    distribution; left ``None`` the balanced-routing assumption applies
    (max == mean, imbalance == 1).  One EP GEMM pays TWO legs (dispatch +
    return); callers add the two ``EpEstimate``s.
    """
    if num_shards <= 1:
        return EP_ZERO
    frac = (num_shards - 1) / num_shards
    ici_bytes = float(rows) * width * elt_bytes * frac
    mean_rows = rows / num_shards
    imbalance = 1.0
    if max_shard_rows is not None and mean_rows > 0:
        imbalance = max(1.0, float(max_shard_rows) / mean_rows)
    bottleneck = (ici_bytes / num_shards) * imbalance
    return EpEstimate(ici_bytes,
                      bottleneck / (spec.ici_bw_per_link * spec.ici_links),
                      imbalance)


# ---------------------------------------------------------------------------
# Paper Eqs. 1-4 (verbatim), used by benchmarks/ to reproduce the paper's
# block-size reasoning for FT-m7032 next to the TPU-adapted model above.
# ---------------------------------------------------------------------------

def paper_f1(m_a: float, k_g: float, n_g: float, num_core: int) -> float:
    """Eq. 1 — M-parallel, B panel in GSM; A via SM, C via AM."""
    return (2.0 * m_a * k_g * n_g * num_core) / (
        num_core * m_a * (k_g + 2.0 * n_g) + k_g * n_g)


def paper_f2(m_a: float, k_a: float, n_a: float, num_core: int) -> float:
    """Eq. 2 — M-parallel, B/C blocks resident in AM; A streamed."""
    return (2.0 * m_a * k_a * n_a * num_core) / (
        num_core * m_a * (k_a + 2.0 * n_a) + k_a * n_a)


def paper_f3(m_g: float, k_a: float, n_g: float, num_core: int) -> float:
    """Eq. 3 — K-parallel, C panel in GSM."""
    return (2.0 * m_g * k_a * n_g * num_core) / (
        num_core * k_a * (m_g + n_g) + 2.0 * m_g * n_g)


def paper_f4(m_a: float, k_a: float, n_a: float, num_core: int) -> float:
    """Eq. 4 — K-parallel, AM level."""
    return (2.0 * m_a * k_a * n_a * num_core) / (
        num_core * k_a * (m_a + n_a) + 2.0 * m_a * n_a)
