"""Shape classification for irregular GEMMs (paper §III-A).

The paper defines three irregular types for C += A x B with at least one of
M, K sufficiently large and N <= 96 (<= 3 x 32-lane vregs on FT-m7032):

    T1: M >> K ~ N      tall-and-skinny x small
    T2: K >> M ~ N      skinny-and-tall x tall-and-skinny
    T3: M ~ K >> N      large regular x tall-and-skinny

TPU adaptation: the natural "skinny" unit is one 128-wide lane tile, so the
skinny threshold defaults to 128 instead of 96; the "much larger" ratio is
kept at the paper's implied order-of-magnitude gap (default 8x).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass


class GemmClass(enum.Enum):
    REGULAR = "regular"
    T1_TALL_SMALL = "t1_tall_small"        # M >> K ~ N
    T2_SKINNY_TALL = "t2_skinny_tall"      # K >> M ~ N
    T3_REGULAR_TALL = "t3_regular_tall"    # M ~ K >> N


@dataclass(frozen=True)
class ShapeThresholds:
    skinny: int = 128      # "N is small" boundary (one lane tile)
    ratio: float = 8.0     # "much larger than" factor


def classify(m: int, k: int, n: int,
             th: ShapeThresholds = ShapeThresholds()) -> GemmClass:
    """Classify a GEMM shape into the paper's taxonomy."""
    r = th.ratio
    n_small = n <= th.skinny
    if n_small and m >= r * max(k, n) and k <= th.skinny * 4:
        return GemmClass.T1_TALL_SMALL
    if n_small and k >= r * max(m, n) and m <= th.skinny * 4:
        return GemmClass.T2_SKINNY_TALL
    if n_small and m >= r * n and k >= r * n:
        return GemmClass.T3_REGULAR_TALL
    return GemmClass.REGULAR


def is_irregular(m: int, k: int, n: int,
                 th: ShapeThresholds = ShapeThresholds()) -> bool:
    return classify(m, k, n, th) is not GemmClass.REGULAR


# The paper's three irregular families (§III-A), TPU-adapted sizes — 21
# shapes, every one classified T1/T2/T3.  Single source of truth, shared by
# the measured sweep (``benchmarks.autotune``) and the static verification
# ratchet (``repro.analysis.sweep``).
PAPER_IRREGULAR_SHAPES: tuple[tuple[str, int, int, int], ...] = (
    # T1: M >> K ~ N (tall-and-skinny x small)
    ("t1_64k_32", 65536, 32, 32),
    ("t1_64k_64", 65536, 64, 64),
    ("t1_64k_128", 65536, 128, 128),
    ("t1_256k_32", 262144, 32, 32),
    ("t1_256k_64", 262144, 64, 64),
    ("t1_256k_128", 262144, 128, 128),
    ("t1_1m_32", 1048576, 32, 32),
    ("t1_1m_64", 1048576, 64, 64),
    ("t1_1m_128", 1048576, 128, 128),
    # T2: K >> M ~ N (skinny-and-tall x tall-and-skinny)
    ("t2_32_64k", 32, 65536, 32),
    ("t2_32_256k", 32, 262144, 64),
    ("t2_64_1m", 64, 1048576, 64),
    ("t2_128_512k", 128, 524288, 128),
    ("t2_32_1m", 32, 1048576, 32),
    ("t2_64_64k", 64, 65536, 128),
    # T3: M ~ K >> N (large regular x tall-and-skinny)
    ("t3_4k_32", 4096, 4096, 32),
    ("t3_8k_64", 8192, 8192, 64),
    ("t3_8k_96", 8192, 8192, 96),
    ("t3_16k_32", 16384, 16384, 32),
    ("t3_20k_32", 20480, 20480, 32),
    ("t3_20k_96", 20480, 20480, 96),
)
