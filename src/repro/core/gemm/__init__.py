from .shapes import GemmClass, ShapeThresholds, classify, is_irregular
from .cmr import (TPU_V5E, TpuSpec, PlanEstimate, estimate, estimate_batched,
                  estimate_ragged, upper_bound_fraction)
from .tuner import (GemmPlan, DistPlan, plan_gemm, plan_batched_gemm,
                    plan_distributed, plan_ragged_gemm, tgemm_plan,
                    clear_plan_cache)
from .dispatch import (batched_matmul, grouped_matmul, matmul, project,
                       ragged_matmul, ragged_swiglu)
from .distributed import dist_matmul, choose_strategy

__all__ = [
    "GemmClass", "ShapeThresholds", "classify", "is_irregular",
    "TPU_V5E", "TpuSpec", "PlanEstimate", "estimate", "estimate_batched",
    "estimate_ragged", "upper_bound_fraction",
    "GemmPlan", "DistPlan", "plan_gemm", "plan_batched_gemm",
    "plan_distributed", "plan_ragged_gemm", "tgemm_plan", "clear_plan_cache",
    "matmul", "batched_matmul", "grouped_matmul", "project",
    "ragged_matmul", "ragged_swiglu",
    "dist_matmul", "choose_strategy",
]
