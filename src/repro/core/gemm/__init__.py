"""ftIMM GEMM stack: classify -> plan (blocks x placement) -> execute.

Layering, bottom-up:

  * ``shapes``  — the paper's §III-A irregular-shape taxonomy (T1/T2/T3).
  * ``cmr``     — the §IV-C computation-to-memory-ratio cost model:
    ``estimate`` / ``estimate_batched`` / ``estimate_ragged`` price one
    candidate tiling per plan family, ``estimate_ep`` prices the
    expert-parallel all-to-all token exchange the same way the K-parallel
    psum is priced.
  * ``tuner``   — the unified **plan hierarchy**.  Every planner
    (``plan_gemm`` / ``plan_batched_gemm`` / ``plan_ragged_gemm``) returns a
    ``Plan``: the best single-core tiling plus an optional ``Placement``
    (mesh strategy ∈ {m_parallel, k_parallel, expert_parallel}, shard count,
    modeled ICI term) when asked to place the GEMM (``num_shards > 1``) —
    strategy x blocking is ONE joint auto-tuning decision, cached per shape
    signature.  ``plan_distributed`` is the dense compat view;
    ``plan_moe_dispatch`` prices a whole MoE layer's dispatch mode +
    placement for the roofline.
  * ``dispatch`` — single-device entry points (``matmul`` / ``project`` /
    ``batched_matmul`` / ``grouped_matmul`` / ``ragged_matmul`` /
    ``ragged_swiglu``): plan, run the Pallas ftIMM kernel (or the XLA
    engine off-TPU), custom VJPs whose backward GEMMs are planned too.
  * ``plan_store`` / ``autotune`` — the measured auto-tuning loop (paper
    pillar three): ``autotune_*`` time the CMR-shortlisted candidates on
    the device through the ops layer (bypassing the plan cache), persist
    winners in the on-disk store the planners consult first, and
    ``calibrate`` fits the effective ``TpuSpec`` constants so unmeasured
    shapes plan better too.  Every plan carries ``mode`` ∈ {analytic,
    measured, cached}; ``plan_mode_stats`` reports which loop served the
    executors.
  * ``collective`` / ``distributed`` — the mesh executors consuming
    placements: ``dist_matmul`` (Alg. 4/5 dense, with the overlapped ring
    collective matmul as a ``schedule="ring"`` variant of K-parallel),
    ``dist_batched_matmul`` (expert-dim sharded grouped GEMM) and
    ``ep_ragged_matmul`` / ``ep_ragged_swiglu`` / ``ep_ragged_moe``
    (expert-parallel capacity-free MoE: a true ragged all-to-all keyed by
    the ``group_offsets`` prefix sums — ``jax.lax.ragged_all_to_all`` when
    the runtime proves it correct, a dense-window exchange otherwise — or
    the ring schedule that rotates token blocks and overlaps transfer with
    compute; ``preferred_ep_schedule`` arbitrates via CMR and
    ``calibrate_ici`` fits the effective-ICI-bandwidth fraction the
    modeled wires are scaled by).
"""
from ...kernels.ftimm.epilogue import Epilogue
from ..quant import QuantConfig
from .shapes import GemmClass, ShapeThresholds, classify, is_irregular
from .cmr import (TPU_V5E, TpuSpec, EpEstimate, PlanEstimate, estimate,
                  estimate_batched, estimate_ep, estimate_ragged,
                  upper_bound_fraction)
from .tuner import (GemmPlan, DistPlan, MoeDispatchPlan, Placement, Plan,
                    plan_gemm, plan_batched_gemm, plan_distributed,
                    plan_moe_dispatch, plan_ragged_gemm, tgemm_plan,
                    clear_plan_cache, degraded_stats, effective_spec,
                    epilogue_stats, plan_mode_stats, preferred_ep_schedule)
from .dispatch import (batched_matmul, grouped_matmul, grouped_swiglu,
                       matmul, matmul_swiglu, project, project_swiglu,
                       ragged_matmul, ragged_swiglu)
from .distributed import (choose_strategy, dist_batched_matmul, dist_matmul,
                          ep_ragged_matmul, ep_ragged_moe, ep_ragged_swiglu)
from .autotune import (TuneResult, autotune_batched_gemm, autotune_gemm,
                       autotune_ragged_gemm, calibrate, calibrate_ici,
                       clear_plan_store, load_plan_cache, save_plan_cache,
                       time_placed_dense_e2e, time_placed_ragged_e2e)
from .plan_store import Calibration, PlanStore

__all__ = [
    "GemmClass", "ShapeThresholds", "classify", "is_irregular",
    "TPU_V5E", "TpuSpec", "EpEstimate", "PlanEstimate", "estimate",
    "estimate_batched", "estimate_ep", "estimate_ragged",
    "upper_bound_fraction",
    "GemmPlan", "DistPlan", "MoeDispatchPlan", "Placement", "Plan",
    "plan_gemm", "plan_batched_gemm", "plan_distributed",
    "plan_moe_dispatch", "plan_ragged_gemm", "tgemm_plan",
    "clear_plan_cache",
    "degraded_stats", "effective_spec", "epilogue_stats", "plan_mode_stats",
    "Epilogue", "QuantConfig",
    "matmul", "batched_matmul", "grouped_matmul", "grouped_swiglu",
    "matmul_swiglu", "project", "project_swiglu",
    "ragged_matmul", "ragged_swiglu",
    "dist_matmul", "dist_batched_matmul", "choose_strategy",
    "ep_ragged_matmul", "ep_ragged_moe", "ep_ragged_swiglu",
    "preferred_ep_schedule",
    "TuneResult", "autotune_gemm", "autotune_batched_gemm",
    "autotune_ragged_gemm", "calibrate", "calibrate_ici",
    "clear_plan_store", "load_plan_cache", "save_plan_cache",
    "time_placed_dense_e2e", "time_placed_ragged_e2e",
    "Calibration", "PlanStore",
]
