"""Mesh-scale ftIMM executors: one ``shard_map`` engine per plan family.

The tuner (``tuner.plan_*``) decides *placement jointly with blocking* — a
``Plan`` whose optional ``Placement`` names the cross-chip strategy, its
modeled ICI term, and (new) the overlap ``schedule``.  This module is the
execution side of that hierarchy:

  * **dense** — ``dist_matmul``: the paper's two multi-core strategies.
    Alg. 4 (m_parallel) shards A's M rows over the axis with B replicated
    (no steady-state collective); Alg. 5 (k_parallel) shards the contraction
    and reduces the fp32 partials over ICI — either as one ``psum`` after
    the local GEMM ("gather" schedule) or as the overlapped ring collective
    matmul ("ring" schedule): output columns are chunked over shard-steps
    and each hop's partial-sum transfer overlaps the next chunk's compute,
    the mesh-level analogue of the paper's core-level DMA pipelining.

  * **batched/grouped** — ``dist_batched_matmul``: the batch/expert dim
    shards over the axis (expert_parallel for the capacity-mode grouped MoE
    GEMMs), shared 2-D operands replicate, per-entry M/K/N stay local.

  * **ragged** — ``ep_ragged_matmul`` / ``ep_ragged_swiglu`` /
    ``ep_ragged_moe`` (the fused pipeline the MoE layer actually routes
    through — one d_model-wide exchange each way, the d_ff hidden never
    crosses the axis): expert-parallel capacity-free MoE.  Rows arrive
    sorted by group with ``group_offsets`` prefix sums, and experts are
    contiguously owned by shards, so shard s's tokens are the *contiguous
    window* [offsets[s*G_l], offsets[(s+1)*G_l]) of the global row array.
    Two schedules realize the exchange+GEMM (``core.gemm.collective``):

      - "gather": the token exchange runs first (the true ragged
        all-to-all when ``jax.lax.ragged_all_to_all`` is available and
        passes the mesh probe, otherwise the dense all_gather/psum_scatter
        realization), then ONE per-shard ragged GEMM over the worst-case
        window.  Empty shards skip the window slice + GEMM entirely
        (``lax.cond`` short-circuit); the collectives still run on every
        shard, as they must.
      - "ring": token blocks rotate around the axis via ``ppermute`` and
        each shard computes only the blocks intersecting its owned window —
        per-shard compute scales with the rows the shard actually owns
        instead of T, and the block transfers hide behind compute.

    The per-shard GEMM is the already-planned ragged kernel, and the custom
    VJP reuses the per-shard ragged dX ("nt") and dW (ragged-K T2) products
    with the inverse exchange — gradients for an expert's panel never leave
    the shard that owns it.  The backward's (cotangent, activation) pair
    crosses the axis as ONE fused exchange (concatenated columns), not two.

Strategy and schedule selection use the same CMR-with-collective-term
scoring as the paper's dynamic adjusting (``tuner.plan_gemm(...,
num_shards=n)`` / ``tuner.preferred_ep_schedule``); ``REPRO_EP_SCHEDULE``
forces the EP schedule for experiments.
"""
from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ...kernels.ftimm.epilogue import IDENTITY, Epilogue
from ...runtime import chaos as _chaos
from ..compat import shard_map_unchecked as shard_map
from . import collective
from .dispatch import (_backend, _check_epi, _degraded, _float0_zeros,
                       _run_planned_ragged, _run_planned_ragged_dw,
                       batched_matmul, matmul, ragged_matmul, ragged_swiglu)
from .tuner import note_plan_use, plan_distributed, preferred_ep_schedule

ENV_EP_SCHEDULE = "REPRO_EP_SCHEDULE"


def _axes(axis) -> tuple[str, ...]:
    return tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)


def _axis_size(mesh: Mesh, axis) -> int:
    return int(math.prod(mesh.shape[a] for a in _axes(axis)))


def _spec_entry(axis):
    ax = _axes(axis)
    return ax if len(ax) > 1 else ax[0]


def choose_strategy(m: int, k: int, n: int, num_cores: int,
                    in_bytes: int = 4) -> str:
    # The compat planner handles num_cores == 1 (a size-1 mesh axis) too.
    return plan_distributed(m, k, n, num_cores, in_bytes).strategy


def dist_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "model",
    strategy: str | None = None,
    schedule: str | None = None,
    out_dtype=None,
    backend: str | None = None,
    epilogue: Epilogue | None = None,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
) -> jax.Array:
    """C = A(M,K) @ B(K,N) parallelized over ``mesh[axis]``.

    Operands may be global arrays with any sharding; shard_map re-shards to
    the strategy's layout.  Output is M-sharded (m_parallel) or replicated
    (k_parallel) over ``axis``.

    ``schedule`` picks the k_parallel reduction realization: "gather" is
    compute-then-psum; "ring" is the overlapped collective matmul (chunked
    output columns rotating partial sums, transfer hidden behind compute).
    ``None`` defers to the plan (m_parallel is always "gather" — it has no
    steady-state collective to overlap).

    ``epilogue`` (with ``bias`` (N,) / ``residual`` (M, N)) fuses the
    elementwise tail per shard: under m_parallel the residual's rows shard
    with A and each shard flushes its own fused tile; under k_parallel the
    tail applies AFTER the full reduction of the fp32 partials (the
    activation is nonlinear — applying it per shard would be wrong), still
    inside the shard_map body, so no extra pass over a stored output either
    way.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(
            f"dist_matmul contraction mismatch: a has shape {a.shape} "
            f"(K = {k}) but b has shape {b.shape} (K = {k2})")
    epi = IDENTITY if epilogue is None else epilogue
    _check_epi(epi, bias, residual)
    nc = mesh.shape[axis]
    if strategy is None:
        plan = plan_distributed(m, k, n, nc, jnp.dtype(a.dtype).itemsize)
        note_plan_use("dist_dense", plan)
        strategy = plan.strategy
        if schedule is None:
            schedule = plan.placement.schedule
    schedule = schedule or "gather"
    if schedule not in collective.SCHEDULES:
        raise ValueError(f"unknown schedule: {schedule!r}")
    if schedule == "ring" and strategy != "k_parallel":
        raise ValueError(
            f"ring schedule is undefined for {strategy} (no steady-state "
            "collective to overlap)")
    out_dtype = jnp.dtype(out_dtype or a.dtype)

    bias2 = None if bias is None else bias.reshape(1, n)

    if strategy == "m_parallel":
        pad_m = (-m) % nc
        a_p = jnp.pad(a, ((0, pad_m), (0, 0))) if pad_m else a
        res_p = None
        if residual is not None:
            res_p = jnp.pad(residual, ((0, pad_m), (0, 0))) if pad_m \
                else residual

        in_specs = [P(axis, None), P(None, None)]
        operands = [a_p, b]
        if bias2 is not None:
            in_specs.append(P(None, None))
            operands.append(bias2)
        if res_p is not None:
            in_specs.append(P(axis, None))      # residual rows shard with A
            operands.append(res_p)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=P(axis, None),
        )
        def f(a_l, b_l, *extras_l):
            bias_l, res_l, scale_l = epi.unpack(extras_l)
            bias_l = None if bias_l is None else bias_l.reshape(-1)
            scale_l = None if scale_l is None else scale_l.reshape(-1)
            return matmul(a_l, b_l, out_dtype=out_dtype, backend=backend,
                          epilogue=epilogue, bias=bias_l, residual=res_l,
                          scale=scale_l)

        out = f(*operands)
        return out[:m] if pad_m else out

    if strategy == "k_parallel":
        pad_k = (-k) % nc
        # The ring schedule chunks the output columns over shard-steps.
        pad_n = (-n) % nc if schedule == "ring" else 0
        a_p = jnp.pad(a, ((0, 0), (0, pad_k))) if pad_k else a
        b_p = jnp.pad(b, ((0, pad_k), (0, pad_n))) if (pad_k or pad_n) else b
        bias_p = bias2
        if bias2 is not None and pad_n:
            bias_p = jnp.pad(bias2, ((0, 0), (0, pad_n)))
        res_p = residual
        if residual is not None and pad_n:
            res_p = jnp.pad(residual, ((0, 0), (0, pad_n)))

        in_specs = [P(None, axis), P(axis, None)]
        operands = [a_p, b_p]
        if bias_p is not None:
            in_specs.append(P(None, None))
            operands.append(bias_p)
        if res_p is not None:
            in_specs.append(P(None, None))
            operands.append(res_p)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=P(None, None),
        )
        def f(a_l, b_l, *extras_l):
            if schedule == "ring":
                full = collective.ring_kparallel(
                    a_l, b_l, axis, nc,
                    lambda al, bc: matmul(al, bc, out_dtype=jnp.float32,
                                          backend=backend))
            else:
                partial_c = matmul(a_l, b_l, out_dtype=jnp.float32,
                                   backend=backend)
                # Paper Alg. 5 line 12: reduce partial C among cores.
                full = jax.lax.psum(partial_c, axis)
            if epi.is_identity:
                return full
            bias_l, res_l, scale_l = epi.unpack(extras_l)
            bias_l = None if bias_l is None else bias_l.reshape(-1)
            scale_l = None if scale_l is None else scale_l.reshape(-1)
            return epi.apply(full, bias=bias_l, residual=res_l,
                             scale=scale_l)

        out = f(*operands).astype(out_dtype)
        return out[:, :n] if pad_n else out

    raise ValueError(f"unknown strategy: {strategy}")


def dist_batched_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: Mesh,
    axis="data",
    trans: str = "nn",
    out_dtype=None,
    backend: str | None = None,
) -> jax.Array:
    """Batched/grouped GEMM with the batch (expert) dim sharded over
    ``mesh[axis]`` — the expert_parallel placement of the capacity-mode MoE
    GEMMs (E, C, D) @ (E, D, F).  A 2-D (shared) operand replicates; the
    per-entry GEMM runs through the planned ``batched_matmul`` locally."""
    if a.ndim != 3 and b.ndim != 3:
        raise ValueError(f"need a batched operand: {a.shape} / {b.shape}")
    g = a.shape[0] if a.ndim == 3 else b.shape[0]
    nc = _axis_size(mesh, axis)
    pad_g = (-g) % nc
    ax = _spec_entry(axis)

    def pad3(x):
        if x.ndim != 3 or not pad_g:
            return x
        return jnp.pad(x, ((0, pad_g), (0, 0), (0, 0)))

    a_p, b_p = pad3(a), pad3(b)
    spec3 = P(ax, None, None)
    spec2 = P(None, None)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec3 if a.ndim == 3 else spec2,
                  spec3 if b.ndim == 3 else spec2),
        out_specs=spec3,
    )
    def f(a_l, b_l):
        return batched_matmul(a_l, b_l, trans=trans, out_dtype=out_dtype,
                              backend=backend)

    out = f(a_p, b_p)
    return out[:g] if pad_g else out


# ---------------------------------------------------------------------------
# Expert-parallel ragged (capacity-free) grouped GEMM
# ---------------------------------------------------------------------------

def _sidx(axis) -> jax.Array:
    """Linear shard index along (possibly multiple) mesh axes, major-first —
    matching the row-major layout of ``P((a, b), ...)``."""
    idx = jnp.int32(0)
    for a in _axes(axis):
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


_mask_rows = collective.mask_rows


def _resolve_ep_schedule(schedule: str | None, axes: tuple, nc: int,
                         g: int, total: int, k: int, n: int,
                         in_bytes: int, out_bytes: int) -> str:
    """Explicit kwarg > ``REPRO_EP_SCHEDULE`` > the planner's preference.
    The ring rotates ONE named axis (``ppermute``), so multi-axis EP and
    degenerate single-shard meshes fall back to the gather schedule.

    The planner preference is environment-aware: on the CPU backend the
    mesh is fake host devices timesharing one core, so the shards' local
    GEMMs serialize and the preference is evaluated with the local term
    scaled by ``nc`` (``serial=nc``) — on a real accelerator mesh each
    shard has its own chip and ``serial=1``."""
    if schedule is None:
        schedule = os.environ.get(ENV_EP_SCHEDULE) or None
    if schedule is None:
        serial = nc if jax.default_backend() == "cpu" else 1
        schedule = preferred_ep_schedule(g, total, k, n, in_bytes,
                                         out_bytes, nc, serial=serial)
    if schedule not in collective.SCHEDULES:
        raise ValueError(f"unknown EP schedule: {schedule!r}")
    if schedule == "ring" and (len(axes) > 1 or nc <= 1):
        schedule = "gather"
    return schedule


def _gather_exchange_fwd(x_l, offs, g_l, axis, ax, nc, method, compute,
                         out_width, out_dtype):
    """Gather-schedule forward: exchange COLLECTIVES run unconditionally on
    every shard; the window slice + GEMM are ``lax.cond``-skipped when the
    shard owns zero rows (the empty-shard short-circuit)."""
    tl = x_l.shape[0]
    t = nc * tl
    payload, loffs_abs, start, stop = collective.dispatch_payload(
        x_l, offs, g_l, axis, ax, nc, method, _sidx(axis))
    wlen = stop - start

    def run():
        win = collective.window_from_payload(payload, start, method)
        loffs = (loffs_abs - start).astype(jnp.int32)
        return _mask_rows(compute(win, loffs, wlen), wlen)

    y_win = jax.lax.cond(wlen > 0, run,
                         lambda: jnp.zeros((t, out_width), out_dtype))
    return collective.combine_rows(y_win, offs, g_l, axis, ax, nc, method,
                                   start, tl)


def _gather_exchange_bwd(ct_l, x_l, offs, g_l, axis, ax, nc, method,
                         compute, dw_zeros):
    """Gather-schedule backward with the FUSED exchange: the cotangent and
    activation cross the axis as one concatenated payload (one collective
    latency, not two), then split back in the shard's window.  ``compute``
    maps (ct_win, x_win, loffs, wlen) -> (dx_win, (dw, ...))."""
    tl = x_l.shape[0]
    t = nc * tl
    n_ct = ct_l.shape[1]
    cat_dt = jnp.promote_types(ct_l.dtype, x_l.dtype)
    cat = jnp.concatenate([ct_l.astype(cat_dt), x_l.astype(cat_dt)], axis=1)
    payload, loffs_abs, start, stop = collective.dispatch_payload(
        cat, offs, g_l, axis, ax, nc, method, _sidx(axis))
    wlen = stop - start

    def run():
        win = _mask_rows(collective.window_from_payload(payload, start,
                                                        method), wlen)
        ct_win = win[:, :n_ct].astype(ct_l.dtype)
        x_win = win[:, n_ct:].astype(x_l.dtype)
        loffs = (loffs_abs - start).astype(jnp.int32)
        dx_win, dw_c = compute(ct_win, x_win, loffs, wlen)
        return (_mask_rows(dx_win, wlen),) + tuple(dw_c)

    zero = ((jnp.zeros((t, x_l.shape[1]), x_l.dtype),)
            + tuple(jnp.zeros_like(z) for z in dw_zeros))
    out = jax.lax.cond(wlen > 0, run, lambda: zero)
    dx_l = collective.combine_rows(out[0], offs, g_l, axis, ax, nc, method,
                                   start, tl)
    return dx_l, out[1:]


@functools.lru_cache(maxsize=32)   # keyed on the Mesh: bound it
def _ep_ragged_fn(mesh: Mesh, axis: tuple, out_dtype_name: str, backend: str,
                  schedule: str = "gather", method: str = "dense"):
    """Custom-VJP'd expert-parallel ragged matmul for one (mesh, axis,
    dtype, backend, schedule, exchange-method) combo.  The VJP reuses the
    planned per-shard ragged products: dX is the "nt" product against the
    shard's own panels (then the inverse exchange), dW is the ragged-K T2
    product of the shard's token window — expert gradients never cross the
    axis."""
    out_dtype = jnp.dtype(out_dtype_name)
    ax = _spec_entry(axis)
    rows, experts, rep = P(ax, None), P(ax, None, None), P(None)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(rows, experts, rep), out_specs=rows)
    def fwd_local(x_l, w_l, offs):
        g_l, n = w_l.shape[0], w_l.shape[2]

        def compute(win, loffs, wlen):
            return ragged_matmul(win, w_l, loffs, out_dtype=out_dtype,
                                 backend=backend)

        if schedule == "ring":
            nc = _axis_size(mesh, axis)
            return collective.ring_forward(x_l, offs, g_l, axis[0], nc,
                                           compute, n, out_dtype)
        nc = _axis_size(mesh, axis)
        return _gather_exchange_fwd(x_l, offs, g_l, axis, ax, nc, method,
                                    compute, n, out_dtype)

    @jax.custom_vjp
    def f(x, w, offsets):
        return fwd_local(x, w, offsets)

    def fwd(x, w, offsets):
        return f(x, w, offsets), (x, w, offsets)

    def bwd(res, ct):
        x, w, offsets = res

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(rows, rows, experts, rep),
                           out_specs=(rows, experts))
        def bwd_local(ct_l, x_l, w_l, offs):
            g_l = w_l.shape[0]
            nc = _axis_size(mesh, axis)

            def compute(ct_win, x_win, loffs, wlen):
                ct_win = _mask_rows(ct_win, wlen)
                x_win = _mask_rows(x_win, wlen)
                dx_win = _run_planned_ragged(ct_win, w_l, loffs, "nt",
                                             x_l.dtype, backend)
                dw_c = _run_planned_ragged_dw(x_win, ct_win, loffs,
                                              w_l.dtype, backend)
                return dx_win, (dw_c,)

            if schedule == "ring":
                dx_l, dws = collective.ring_backward(
                    ct_l, x_l, offs, g_l, axis[0], nc, compute,
                    (jnp.zeros(w_l.shape, w_l.dtype),))
            else:
                dx_l, dws = _gather_exchange_bwd(
                    ct_l, x_l, offs, g_l, axis, ax, nc, method, compute,
                    (jnp.zeros(w_l.shape, w_l.dtype),))
            return dx_l, dws[0]

        dx, dw = bwd_local(ct, x, w, offsets)
        return dx, dw, _float0_zeros(offsets)

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=32)   # keyed on the Mesh: bound it
def _ep_ragged_swiglu_fn(mesh: Mesh, axis: tuple, out_dtype_name: str,
                         backend: str, schedule: str = "gather",
                         method: str = "dense"):
    """Expert-parallel fused ragged SwiGLU: one exchange in, the fused
    silu(gate)*up pair per shard, one exchange back.  Backward follows the
    single-device fused-epilogue recipe (rematerialize the two fp32
    pre-activations per shard, two "nt" dX products + two ragged-K dW
    products), all inside the shard's token window."""
    out_dtype = jnp.dtype(out_dtype_name)
    ax = _spec_entry(axis)
    rows, experts, rep = P(ax, None), P(ax, None, None), P(None)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(rows, experts, experts, rep),
                       out_specs=rows)
    def fwd_local(x_l, wg_l, wu_l, offs):
        g_l, n = wg_l.shape[0], wg_l.shape[2]
        nc = _axis_size(mesh, axis)

        def compute(win, loffs, wlen):
            return ragged_swiglu(win, wg_l, wu_l, loffs, out_dtype=out_dtype,
                                 backend=backend)

        if schedule == "ring":
            return collective.ring_forward(x_l, offs, g_l, axis[0], nc,
                                           compute, n, out_dtype)
        return _gather_exchange_fwd(x_l, offs, g_l, axis, ax, nc, method,
                                    compute, n, out_dtype)

    @jax.custom_vjp
    def f(x, wg, wu, offsets):
        return fwd_local(x, wg, wu, offsets)

    def fwd(x, wg, wu, offsets):
        return f(x, wg, wu, offsets), (x, wg, wu, offsets)

    def bwd(res, ct):
        x, wg, wu, offsets = res

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(rows, rows, experts, experts, rep),
                           out_specs=(rows, experts, experts))
        def bwd_local(ct_l, x_l, wg_l, wu_l, offs):
            g_l = wg_l.shape[0]
            nc = _axis_size(mesh, axis)

            def compute(ct_win, x_win, loffs, wlen):
                ct_win = _mask_rows(ct_win, wlen)
                x_win = _mask_rows(x_win, wlen)
                a = _run_planned_ragged(x_win, wg_l, loffs, "nn",
                                        jnp.float32, backend)
                b = _run_planned_ragged(x_win, wu_l, loffs, "nn",
                                        jnp.float32, backend)
                sg = jax.nn.sigmoid(a)
                ct32 = ct_win.astype(jnp.float32)
                da = (ct32 * b * sg
                      * (1.0 + a * (1.0 - sg))).astype(x_l.dtype)
                db = (ct32 * a * sg).astype(x_l.dtype)
                dx_win = (
                    _run_planned_ragged(da, wg_l, loffs, "nt", jnp.float32,
                                        backend)
                    + _run_planned_ragged(db, wu_l, loffs, "nt", jnp.float32,
                                          backend)).astype(x_l.dtype)
                dwg_c = _run_planned_ragged_dw(x_win, da, loffs, wg_l.dtype,
                                               backend)
                dwu_c = _run_planned_ragged_dw(x_win, db, loffs, wu_l.dtype,
                                               backend)
                return dx_win, (dwg_c, dwu_c)

            dw_zeros = (jnp.zeros(wg_l.shape, wg_l.dtype),
                        jnp.zeros(wu_l.shape, wu_l.dtype))
            if schedule == "ring":
                dx_l, dws = collective.ring_backward(
                    ct_l, x_l, offs, g_l, axis[0], nc, compute, dw_zeros)
            else:
                dx_l, dws = _gather_exchange_bwd(
                    ct_l, x_l, offs, g_l, axis, ax, nc, method, compute,
                    dw_zeros)
            return dx_l, dws[0], dws[1]

        dx, dwg, dwu = bwd_local(ct, x, wg, wu, offsets)
        return dx, dwg, dwu, _float0_zeros(offsets)

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=32)   # keyed on the Mesh: bound it
def _ep_ragged_moe_fn(mesh: Mesh, axis: tuple, out_dtype_name: str,
                      backend: str, schedule: str = "gather",
                      method: str = "dense"):
    """Fused expert-parallel ragged MoE MLP: ONE token exchange each way for
    the whole silu(x Wg)*(x Wu) Wd pipeline.  The (rows, d_ff) hidden is
    produced and consumed on the shard that owns the expert — running
    ``ep_ragged_swiglu`` then ``ep_ragged_matmul`` instead would exchange it
    back and immediately re-gather it into the exact same windows.
    Backward: ONE fused (cotangent, x) exchange in, all three dW products
    and both dX products per shard, one inverse exchange for dX."""
    out_dtype = jnp.dtype(out_dtype_name)
    ax = _spec_entry(axis)
    rows, experts, rep = P(ax, None), P(ax, None, None), P(None)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(rows, experts, experts, experts, rep),
                       out_specs=rows)
    def fwd_local(x_l, wg_l, wu_l, wd_l, offs):
        g_l, n = wg_l.shape[0], wd_l.shape[2]
        nc = _axis_size(mesh, axis)

        def compute(win, loffs, wlen):
            h_win = ragged_swiglu(win, wg_l, wu_l, loffs,
                                  out_dtype=out_dtype, backend=backend)
            return ragged_matmul(_mask_rows(h_win, wlen), wd_l, loffs,
                                 out_dtype=out_dtype, backend=backend)

        if schedule == "ring":
            return collective.ring_forward(x_l, offs, g_l, axis[0], nc,
                                           compute, n, out_dtype)
        return _gather_exchange_fwd(x_l, offs, g_l, axis, ax, nc, method,
                                    compute, n, out_dtype)

    @jax.custom_vjp
    def f(x, wg, wu, wd, offsets):
        return fwd_local(x, wg, wu, wd, offsets)

    def fwd(x, wg, wu, wd, offsets):
        return f(x, wg, wu, wd, offsets), (x, wg, wu, wd, offsets)

    def bwd(res, ct):
        x, wg, wu, wd, offsets = res

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(rows, rows, experts, experts, experts, rep),
            out_specs=(rows, experts, experts, experts))
        def bwd_local(ct_l, x_l, wg_l, wu_l, wd_l, offs):
            g_l = wg_l.shape[0]
            nc = _axis_size(mesh, axis)

            def compute(ct_win, x_win, loffs, wlen):
                ct_win = _mask_rows(ct_win, wlen)
                x_win = _mask_rows(x_win, wlen)
                # Rematerialize the fp32 pre-activations and the hidden.
                a = _run_planned_ragged(x_win, wg_l, loffs, "nn",
                                        jnp.float32, backend)
                b = _run_planned_ragged(x_win, wu_l, loffs, "nn",
                                        jnp.float32, backend)
                sg = jax.nn.sigmoid(a)
                h_win = _mask_rows((a * sg * b).astype(x_l.dtype), wlen)
                # Down projection: dH and dWd stay on the owning shard.
                dh = _mask_rows(
                    _run_planned_ragged(ct_win, wd_l, loffs, "nt",
                                        jnp.float32, backend), wlen)
                dwd_c = _run_planned_ragged_dw(h_win, ct_win, loffs,
                                               wd_l.dtype, backend)
                # SwiGLU epilogue backward, then the two dX products.
                da = (dh * b * sg * (1.0 + a * (1.0 - sg))).astype(x_l.dtype)
                db = (dh * a * sg).astype(x_l.dtype)
                dx_win = (
                    _run_planned_ragged(da, wg_l, loffs, "nt", jnp.float32,
                                        backend)
                    + _run_planned_ragged(db, wu_l, loffs, "nt", jnp.float32,
                                          backend)).astype(x_l.dtype)
                dwg_c = _run_planned_ragged_dw(x_win, da, loffs, wg_l.dtype,
                                               backend)
                dwu_c = _run_planned_ragged_dw(x_win, db, loffs, wu_l.dtype,
                                               backend)
                return dx_win, (dwg_c, dwu_c, dwd_c)

            dw_zeros = (jnp.zeros(wg_l.shape, wg_l.dtype),
                        jnp.zeros(wu_l.shape, wu_l.dtype),
                        jnp.zeros(wd_l.shape, wd_l.dtype))
            if schedule == "ring":
                dx_l, dws = collective.ring_backward(
                    ct_l, x_l, offs, g_l, axis[0], nc, compute, dw_zeros)
            else:
                dx_l, dws = _gather_exchange_bwd(
                    ct_l, x_l, offs, g_l, axis, ax, nc, method, compute,
                    dw_zeros)
            return dx_l, dws[0], dws[1], dws[2]

        dx, dwg, dwu, dwd = bwd_local(ct, x, wg, wu, wd, offsets)
        return dx, dwg, dwu, dwd, _float0_zeros(offsets)

    f.defvjp(fwd, bwd)
    return f


def clear_executor_caches() -> None:
    """Drop the bounded mesh-keyed executor caches.  Part of the single
    ``tuner.clear_plan_cache`` reset: these closures re-plan their ragged
    GEMMs at trace time, so an executor traced before a spec change /
    plan-cache load would keep serving the stale blocking forever (the bug:
    ``clear_plan_cache`` used to clear only the five planner LRUs)."""
    _ep_ragged_fn.cache_clear()
    _ep_ragged_swiglu_fn.cache_clear()
    _ep_ragged_moe_fn.cache_clear()


def _ep_prepare(x: jax.Array, w: jax.Array, mesh: Mesh, axis):
    if x.ndim != 2 or w.ndim != 3:
        raise ValueError((x.shape, w.shape))
    g = w.shape[0]
    nc = _axis_size(mesh, axis)
    if g % nc:
        raise ValueError(
            f"expert count {g} not divisible by mesh axis {axis} ({nc})")
    t = x.shape[0]
    pad_t = (-t) % nc
    x_p = jnp.pad(x, ((0, pad_t), (0, 0))) if pad_t else x
    return x_p, t, pad_t


def _ep_executor_args(x_p, w, out_dtype, mesh, axis, schedule):
    """Resolve the (schedule, exchange-method) pair for one EP call: the
    planner's preferred schedule for this shape unless forced, and the
    probed exchange realization for this mesh.  Both land in the executor's
    cache key so env/plan changes retrace instead of serving stale."""
    axes = _axes(axis)
    nc = _axis_size(mesh, axis)
    g, k, n = w.shape[0], w.shape[1], w.shape[2]
    schedule = _resolve_ep_schedule(
        schedule, axes, nc, g, x_p.shape[0], k, n,
        jnp.dtype(x_p.dtype).itemsize, out_dtype.itemsize)
    method = collective.exchange_method(mesh, axes)
    return axes, schedule, method


def _ep_ladder(run, schedule: str, single):
    """The EP fallback ladder: ring -> gather -> single-device.

    ``run(schedule)`` builds + calls the sharded executor; ``single()`` is
    the last rung — the plain planned ragged op on the GLOBAL arrays, which
    is numerically the same computation with the exchange gone (under jit
    GSPMD gathers sharded operands implicitly).  Each degradation is
    counted in ``plan_mode_stats()['degraded']`` and logged once.  The
    ``ep_ring``/``ep_gather`` chaos sites arm here, at trace time, so a
    jitted program replays its injected degradations deterministically."""
    if schedule == "ring":
        try:
            _chaos.fire("ep_ring")
            return run("ring")
        except Exception as e:
            _degraded("ep", "ring->gather", e)
            schedule = "gather"
    try:
        _chaos.fire("ep_gather")
        return run(schedule)
    except Exception as e:
        _degraded("ep", "gather->single", e)
        return single()


def ep_ragged_matmul(x: jax.Array, w: jax.Array, group_offsets: jax.Array, *,
                     mesh: Mesh, axis="data", out_dtype=None,
                     backend: str | None = None,
                     schedule: str | None = None) -> jax.Array:
    """Expert-parallel ragged grouped GEMM over ``mesh[axis]``.

    Same contract as ``ragged_matmul`` — ``x`` (T, D) rows sorted so each
    group's rows are contiguous, ``group_offsets`` (G+1,) prefix sums,
    ``w`` (G, D, F) per-group panels, G divisible by the axis size — but the
    expert dim is sharded: tokens travel to the shard owning their expert
    (the contiguous-window exchange keyed by the prefix sums), the planned
    per-shard ragged kernel runs on G/num_shards local panels, and the
    inverse exchange restores the global row order.  ``schedule`` picks
    "ring" (overlapped block rotation) vs "gather" (exchange-then-GEMM);
    ``None`` defers to ``REPRO_EP_SCHEDULE`` then the planner.  Returns
    (T, F)."""
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    backend = backend or _backend()
    x_p, t, pad_t = _ep_prepare(x, w, mesh, axis)
    axes, schedule, method = _ep_executor_args(x_p, w, out_dtype, mesh,
                                               axis, schedule)
    offs = group_offsets.astype(jnp.int32)

    def run(sched):
        fn = _ep_ragged_fn(mesh, axes, out_dtype.name, backend, sched,
                           method)
        out = fn(x_p, w, offs)
        return out[:t] if pad_t else out

    return _ep_ladder(run, schedule,
                      lambda: ragged_matmul(x, w, offs, out_dtype=out_dtype,
                                            backend=backend))


def ep_ragged_swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                     group_offsets: jax.Array, *, mesh: Mesh, axis="data",
                     out_dtype=None, backend: str | None = None,
                     schedule: str | None = None) -> jax.Array:
    """Expert-parallel fused ragged MoE front half: silu(x @ Wg_g) * (x @
    Wu_g) per group with the gate/up panels expert-sharded over
    ``mesh[axis]`` — ONE token exchange each way for the fused pair (same
    contract as ``ragged_swiglu``; ``schedule`` as in
    ``ep_ragged_matmul``)."""
    if w_gate.shape != w_up.shape:
        raise ValueError((w_gate.shape, w_up.shape))
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    backend = backend or _backend()
    x_p, t, pad_t = _ep_prepare(x, w_gate, mesh, axis)
    axes, schedule, method = _ep_executor_args(x_p, w_gate, out_dtype, mesh,
                                               axis, schedule)
    offs = group_offsets.astype(jnp.int32)

    def run(sched):
        fn = _ep_ragged_swiglu_fn(mesh, axes, out_dtype.name, backend,
                                  sched, method)
        out = fn(x_p, w_gate, w_up, offs)
        return out[:t] if pad_t else out

    return _ep_ladder(run, schedule,
                      lambda: ragged_swiglu(x, w_gate, w_up, offs,
                                            out_dtype=out_dtype,
                                            backend=backend))


def ep_ragged_moe(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                  w_down: jax.Array, group_offsets: jax.Array, *,
                  mesh: Mesh, axis="data", out_dtype=None,
                  backend: str | None = None,
                  schedule: str | None = None) -> jax.Array:
    """Whole expert-parallel ragged MoE MLP in one placement:
    (silu(x @ Wg_g) * (x @ Wu_g)) @ Wd_g per group, all three panel sets
    expert-sharded over ``mesh[axis]``.  Tokens cross the axis exactly once
    each way (d_model wide); the (rows, d_ff) hidden never does — composing
    ``ep_ragged_swiglu`` + ``ep_ragged_matmul`` would exchange it twice for
    nothing, since both key off the same ``group_offsets`` windows.
    ``x`` (T, D), ``w_gate``/``w_up`` (G, D, F), ``w_down`` (G, F, D);
    ``schedule`` as in ``ep_ragged_matmul``.  Returns (T, D)."""
    if w_gate.shape != w_up.shape:
        raise ValueError((w_gate.shape, w_up.shape))
    if w_down.ndim != 3 or w_down.shape[0] != w_gate.shape[0] \
            or w_down.shape[1] != w_gate.shape[2]:
        raise ValueError((w_gate.shape, w_down.shape))
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    backend = backend or _backend()
    x_p, t, pad_t = _ep_prepare(x, w_gate, mesh, axis)
    axes, schedule, method = _ep_executor_args(x_p, w_gate, out_dtype, mesh,
                                               axis, schedule)
    offs = group_offsets.astype(jnp.int32)

    def run(sched):
        fn = _ep_ragged_moe_fn(mesh, axes, out_dtype.name, backend, sched,
                               method)
        out = fn(x_p, w_gate, w_up, w_down, offs)
        return out[:t] if pad_t else out

    def single():
        h = ragged_swiglu(x, w_gate, w_up, offs, backend=backend)
        return ragged_matmul(h, w_down, offs, out_dtype=out_dtype,
                             backend=backend)

    return _ep_ladder(run, schedule, single)
