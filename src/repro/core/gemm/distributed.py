"""Cross-chip ftIMM: the paper's two multi-core strategies over a JAX mesh.

Paper Alg. 4 (M-parallel): DSP cores split the M loop; the shared B panel
sits in GSM.  Here: shard A's M rows over a mesh axis, replicate B, no
steady-state collective.

Paper Alg. 5 (K-parallel): cores split the K loop and reduce partial C
through GSM.  Here: shard the contraction dim over the axis and ``psum`` the
fp32 partials over ICI.  This is the strategy that wins when M and N are both
small but K is huge — exactly the shape of long-context decode attention
(see ``repro.serve.decode``: flash-decoding == ftIMM K-parallel).

Strategy selection uses the same CMR-with-collective-term scoring as the
paper's dynamic adjusting (``tuner.plan_distributed``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from .dispatch import matmul
from .tuner import plan_distributed


def choose_strategy(m: int, k: int, n: int, num_cores: int,
                    in_bytes: int = 4) -> str:
    return plan_distributed(m, k, n, num_cores, in_bytes).strategy


def dist_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "model",
    strategy: str | None = None,
    out_dtype=None,
    backend: str | None = None,
) -> jax.Array:
    """C = A(M,K) @ B(K,N) parallelized over ``mesh[axis]``.

    Operands may be global arrays with any sharding; shard_map re-shards to
    the strategy's layout.  Output is M-sharded (m_parallel) or replicated
    (k_parallel) over ``axis``.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    nc = mesh.shape[axis]
    if strategy is None:
        strategy = choose_strategy(m, k, n, nc, jnp.dtype(a.dtype).itemsize)
    out_dtype = jnp.dtype(out_dtype or a.dtype)

    if strategy == "m_parallel":
        pad_m = (-m) % nc
        a_p = jnp.pad(a, ((0, pad_m), (0, 0))) if pad_m else a

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis, None), P(None, None)),
            out_specs=P(axis, None),
        )
        def f(a_l, b_l):
            return matmul(a_l, b_l, out_dtype=out_dtype, backend=backend)

        out = f(a_p, b_p := b)
        return out[:m] if pad_m else out

    if strategy == "k_parallel":
        pad_k = (-k) % nc
        a_p = jnp.pad(a, ((0, 0), (0, pad_k))) if pad_k else a
        b_p = jnp.pad(b, ((0, pad_k), (0, 0))) if pad_k else b

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(None, axis), P(axis, None)),
            out_specs=P(None, None),
        )
        def f(a_l, b_l):
            partial_c = matmul(a_l, b_l, out_dtype=jnp.float32,
                               backend=backend)
            # Paper Alg. 5 line 12: reduce partial C among cores (GSM -> ICI).
            return jax.lax.psum(partial_c, axis)

        return f(a_p, b_p).astype(out_dtype)

    raise ValueError(f"unknown strategy: {strategy}")
