"""Persistent on-disk plan cache for the measured auto-tuner.

The paper's auto-tuning is a *closed loop*: the CMR model proposes, the
hardware disposes, and the winner is remembered so the search never reruns
for a shape the device has already answered.  This module is the memory —
a JSON file of measured-winner records keyed by

    (device kind, plan family, shape signature, dtype widths, placement
     request)

that the analytic planners (``tuner.plan_*``) consult *before* their
CMR argmin.  Records store only the decision (blocks, dim order, strategy)
plus provenance (measured/analytic times, timing engine); the analytic
estimate is recomputed at lookup so a cached plan always carries a fresh
``PlanEstimate`` and is re-validated against the VMEM budget — a cache can
suggest, it can never force a shape-invalid tiling.

Device-kind gating: a store file created on one device kind (say
``tpu_v5e``) is ignored wholesale on another (``cpu``) — measured times do
not transfer.  Corrupt or schema-mismatched files are ignored gracefully
(the loop falls back to pure analytic planning), never raised through the
planners.

The file also carries the **calibration** block fitted by
``autotune.calibrate``: the effective achievable-flops fraction and HBM
bandwidth fraction of the device, so *unmeasured* shapes plan against
corrected constants too.

Process-global store: ``get_store()``; auto-loads ``$REPRO_PLAN_CACHE`` on
first use.  This module stays jax-light (jax imported lazily only to read
the device kind) so ``tuner`` can import it without cycles.
"""
from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass, field

from ...runtime.chaos import fire as _chaos_fire

SCHEMA_VERSION = 1
ENV_VAR = "REPRO_PLAN_CACHE"

# Fields a record may carry.  Only "blocks" is mandatory; everything else is
# provenance or placement/edge/fusion detail.
_RECORD_KEYS = frozenset({
    "bm", "bn", "bk", "nsplit", "dim_order", "strategy", "schedule", "edge",
    "fuse", "t_measured_us", "t_analytic_us", "t_model_us", "engine", "mode",
})


def device_kind() -> str:
    """Canonical device kind of the timing device ("cpu", "tpu_v5e", ...)."""
    try:
        import jax
        kind = jax.devices()[0].device_kind
    except Exception:  # pragma: no cover - jax always importable in-repo
        return "unknown"
    return str(kind).strip().lower().replace(" ", "_")


def shape_key(family: str, dims: tuple, in_bytes: int, out_bytes: int,
              num_shards: int = 1, extra: str = "") -> str:
    """Canonical store key: family + shape signature + dtype widths +
    placement request.  ``dims`` is the family's positional shape tuple
    ((m,k,n) dense, (g,m,k,n) batched, (g,total,k,n) ragged); ``extra``
    carries family variants (shared operand, ragged axis)."""
    d = "x".join(str(int(x)) for x in dims)
    key = f"{family}|{d}|ib{int(in_bytes)}|ob{int(out_bytes)}"
    if extra:
        key += f"|{extra}"
    if num_shards > 1:
        key += f"|shards{int(num_shards)}"
    return key


@dataclass
class Calibration:
    """Fitted effective-hardware constants (fractions of the spec's peaks).

    ``flops_frac_int8`` is the separately-fitted achievable fraction of the
    narrow-dtype (int8) peak — the MXU's int8 path saturates differently
    from its float path, so one shared fraction would misprice whichever
    family was not measured.  ``None`` means "not fitted": the planners
    fall back to ``flops_frac`` for int8 shapes too."""
    flops_frac: float = 1.0     # achievable fraction of peak FLOP/s
    bw_frac: float = 1.0        # achievable fraction of peak HBM bandwidth
    ici_frac: float = 1.0       # achievable fraction of peak ICI bandwidth
    flops_frac_int8: float | None = None    # int8-peak fraction (optional)
    n_samples: int = 0
    engine: str = ""
    base_spec: str = ""

    def to_json(self) -> dict:
        d = {"flops_frac": self.flops_frac, "bw_frac": self.bw_frac,
             "ici_frac": self.ici_frac, "n_samples": self.n_samples,
             "engine": self.engine, "base_spec": self.base_spec}
        if self.flops_frac_int8 is not None:
            d["flops_frac_int8"] = self.flops_frac_int8
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Calibration":
        int8 = d.get("flops_frac_int8")
        return cls(flops_frac=float(d["flops_frac"]),
                   bw_frac=float(d["bw_frac"]),
                   ici_frac=float(d.get("ici_frac", 1.0)),
                   flops_frac_int8=None if int8 is None else float(int8),
                   n_samples=int(d.get("n_samples", 0)),
                   engine=str(d.get("engine", "")),
                   base_spec=str(d.get("base_spec", "")))


@dataclass
class PlanStore:
    """In-memory view of one persistent plan-cache file.

    ``quarantined`` maps record keys the static verifier rejected at load
    time to their violation codes — those shapes fall back to analytic
    planning, and the count is surfaced by ``tuner.plan_mode_stats`` and
    the serve warmup banner instead of being silently re-planned."""
    kind: str = ""                          # device kind the entries measure
    entries: dict = field(default_factory=dict)
    calibration: Calibration | None = None
    path: str | None = None                 # last load/save path
    quarantined: dict = field(default_factory=dict)
    lookups: int = 0                        # telemetry: lookup() calls
    hits: int = 0                           # telemetry: lookups that served

    def __len__(self) -> int:
        return len(self.entries)

    def lookup(self, key: str) -> dict | None:
        """Record for ``key`` if it was measured on the current device kind."""
        self.lookups += 1
        if not self.entries or self.kind != device_kind():
            return None
        rec = self.entries.get(key)
        if rec is not None:
            self.hits += 1
        return rec

    def put(self, key: str, record: dict) -> None:
        self.kind = self.kind or device_kind()
        self.entries[key] = {k: v for k, v in record.items()
                             if k in _RECORD_KEYS}

    def clear(self) -> None:
        self.entries.clear()
        self.quarantined.clear()
        self.calibration = None
        self.kind = ""
        self.lookups = 0
        self.hits = 0

    # -- persistence ------------------------------------------------------

    def load(self, path: str) -> int:
        """Merge entries from ``path``.  Returns the number of entries
        adopted; 0 (never an exception) for missing / corrupt / wrong-schema
        / wrong-device-kind files."""
        try:
            with open(path) as fp:
                blob = json.load(fp)
        except (OSError, ValueError):
            return 0
        if not isinstance(blob, dict) \
                or blob.get("schema") != SCHEMA_VERSION:
            return 0
        kind = blob.get("device_kind")
        if kind != device_kind():
            return 0        # measured elsewhere: times don't transfer
        entries = blob.get("entries")
        if not isinstance(entries, dict):
            return 0
        n = 0
        for key, rec in entries.items():
            if isinstance(rec, dict) and "bm" in rec:
                bad = _record_violations(key, rec)
                if bad:
                    # Contract-violating cached plans (the bk-clamp bug
                    # class, over-budget blocks, malformed keys) are
                    # quarantined, never served; the planners re-plan the
                    # shape analytically and telemetry counts the miss.
                    self.quarantined[key] = bad
                    continue
                self.put(key, rec)
                n += 1
        self.kind = kind
        cal = blob.get("calibration")
        if isinstance(cal, dict):
            try:
                self.calibration = Calibration.from_json(cal)
            except (KeyError, TypeError, ValueError):
                pass
        self.path = path
        return n

    def save(self, path: str | None = None) -> str:
        path = path or self.path
        if path is None:
            raise ValueError("no path: pass one or load() first")
        blob = {
            "schema": SCHEMA_VERSION,
            "device_kind": self.kind or device_kind(),
            "entries": self.entries,
        }
        if self.calibration is not None:
            blob["calibration"] = self.calibration.to_json()
        d = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(d, exist_ok=True)
        # Atomic replace so a crashed writer never leaves a torn file for
        # the graceful-degradation loader to (correctly, silently) reject.
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".plan_cache.")
        try:
            with os.fdopen(fd, "w") as fp:
                json.dump(blob, fp, indent=1, sort_keys=True)
                fp.flush()
                os.fsync(fp.fileno())
            _chaos_fire("plan_save_crash")
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.path = path
        return path


def _record_violations(key: str, rec: dict) -> list:
    """Error-severity static-contract violation codes for one cached record
    (the load-time quarantine gate).  Lazy import: the verifier package is
    a leaf, but keeping the store importable without it preserves the
    graceful-degradation promise of ``load``."""
    try:
        from ...analysis.contracts import check_record, errors
    except Exception:   # pragma: no cover - analysis ships with the repo
        return []
    return [v.code for v in errors(check_record(key, rec))]


_STORE = PlanStore()
_env_checked = False


def get_store() -> PlanStore:
    """The process-global store; loads ``$REPRO_PLAN_CACHE`` on first use."""
    global _env_checked
    if not _env_checked:
        _env_checked = True
        path = os.environ.get(ENV_VAR)
        if path:
            _STORE.load(path)
    return _STORE


def reset_store() -> None:
    """Drop all in-memory entries + calibration (the file is untouched).
    The ``$REPRO_PLAN_CACHE`` auto-load is NOT re-armed: a reset means an
    empty store until an explicit ``load`` — otherwise the very next
    ``get_store()`` would silently refill the "clean slate" from the env
    file (and a sweep started from reset would merge stale entries into
    whatever it saves)."""
    global _env_checked
    _env_checked = True
    _STORE.clear()
    _STORE.path = None
