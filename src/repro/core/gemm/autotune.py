"""Measured auto-tuning: on-device search over CMR-shortlisted plans.

ftIMM's third pillar is auto-tuning of block sizes and parallelization
strategies; until now the repo's "tuning" was purely analytic — every
``plan_*`` takes the argmin of the CMR model, which is never validated
against hardware.  This module closes the loop the way Catalán et al.
(arXiv:1506.08988) prescribe — measurement-driven configuration on top of a
*model-pruned* search space:

  1. **Shortlist** — the shared candidate generator (``tuner.*_candidates``)
     enumerates every feasible tiling, the CMR model ranks them, and the
     top-K (analytic argmin first) survive to the device.
  2. **Measure** — a common timing harness compiles and times each survivor
     (jit + ``block_until_ready``, median of R repeats) through the ops
     layer's block-parameterized wrappers DIRECTLY — never through the plan
     cache it is validating.  Oversized problems are scaled down (largest
     dims halved under an element budget) so the harness runs everywhere;
     an interpret-mode engine exists for hosts without a TPU.
  3. **Remember** — the winner lands in the persistent ``plan_store`` keyed
     by (device kind, family, shape signature, dtype widths, placement
     request); ``plan_gemm``/``plan_batched_gemm``/``plan_ragged_gemm``
     consult it before their analytic argmin and tag served plans
     ``mode == "cached"``.
  4. **Calibrate** — ``calibrate`` fits the effective ``TpuSpec`` constants
     (achievable-flops fraction, effective HBM bandwidth) from
     measured-vs-predicted ratios, and ``calibrate_ici`` fits the
     effective-ICI-bandwidth fraction from timed mesh collectives, so
     *unmeasured* shapes plan against corrected rooflines AND corrected
     wires too (``tuner.effective_spec``).

Timing engines (``engine=``):

  * ``"pallas"`` — the real ftIMM kernels (TPU).  Fully plan-dependent.
  * ``"pallas_interpret"`` — the same kernels in interpret mode: slow, but
    plan-dependent (grid geometry is executed) and runs on any host.
  * ``"xla"`` — the XLA reference GEMM on operands padded to the candidate's
    block multiples.  Fast everywhere; differentiates candidates only
    through their padding waste (the execution itself is untiled), so on
    CPU it mostly *validates* the analytic choice and feeds calibration.

Placed searches (``num_shards > 1``) are hybrid: the per-shard local GEMM of
each ``tuner.PlacementOption`` is measured, the ICI collective term stays
modeled (there is no mesh inside the harness, but ``calibrate_ici``
corrects the modeled wires from timed mesh exchanges), and the same
clear-win margins arbitrate — measured compute, calibrated-model wires.
Overlapped (``schedule == "ring"``) options compose local and collective
time as MAX, unoverlapped as SUM, in both the measured and analytic
scores.  ``time_placed_ragged_e2e``/``time_placed_dense_e2e`` go one step
further: they run the placed executors end-to-end on a real mesh —
collectives executed, not priced — for crossover-agreement checks.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

from ...kernels.ftimm import ops as _ops
from ...kernels.ftimm import ref as _ref
from ...kernels.ftimm.epilogue import Epilogue
from . import plan_store, tuner
from .cmr import (TPU_V5E, PlanEstimate, TpuSpec, ceil_to, estimate,
                  estimate_batched, estimate_ep, estimate_ragged)
from .plan_store import Calibration
from .tuner import GemmPlan

DEFAULT_TOP_K = 4
DEFAULT_REPEATS = 3
DEFAULT_MAX_ELEMENTS = 1 << 22      # per-sweep operand-element budget


def default_engine() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


_ENGINES = ("xla", "pallas", "pallas_interpret")


def _check_engine(engine: str) -> str:
    if engine not in _ENGINES:
        raise ValueError(f"unknown timing engine: {engine!r} "
                         f"(expected one of {_ENGINES})")
    return engine


def _dtype(nbytes: int):
    try:
        return {4: jnp.float32, 2: jnp.bfloat16, 1: jnp.int8}[int(nbytes)]
    except KeyError:
        raise ValueError(
            f"unsupported operand width for measured tuning: {nbytes} bytes "
            "(4 = float32, 2 = bfloat16, 1 = int8)") from None


def _rand(shape, dtype, seed: int = 0):
    if jnp.dtype(dtype) == jnp.int8:
        # Full-range int8 operands: timing is value-independent, but keep
        # the panels representative of real quantized weights anyway.
        return jax.random.randint(jax.random.PRNGKey(seed), shape,
                                  -127, 128, jnp.int32).astype(jnp.int8)
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32).astype(dtype)


def _widen(x: jax.Array) -> jax.Array:
    """XLA-engine operand fixup: the reference dots have no narrow-int path
    on the pinned jax, so itemsize-1 operands run upcast (outside the timed
    thunk; the engine differentiates candidates through padding only)."""
    return x.astype(jnp.float32) if jnp.dtype(x.dtype).itemsize == 1 else x


@dataclass(frozen=True)
class TuneResult:
    """Outcome of one measured search.

    ``plan`` is the winner for the ORIGINAL dims (analytic estimate
    attached, ``mode == "measured"``).  Times are wall-clock seconds of the
    *measured problem* — ``measured_dims``, the original shape scaled into
    the harness's element budget — so ``t_measured <= t_analytic`` holds on
    the same run by construction (the analytic argmin is always candidate
    zero of the shortlist).  ``est_measured`` is the analytic estimate of
    that measured problem under the winner's tiling: the (prediction,
    measurement) pair calibration consumes."""
    family: str
    dims: tuple
    measured_dims: tuple
    key: str
    plan: GemmPlan
    t_measured: float
    t_analytic: float
    analytic_plan: GemmPlan
    est_measured: PlanEstimate
    engine: str
    timed: tuple                    # ((bm, bn, bk, dim_order, seconds), ...)
    in_bytes: int = 4               # operand width (1 routes the int8 peak)
    b_bytes: int | None = None      # mixed-width B operand, None = same as A

    @property
    def ratio_pred_over_meas(self) -> float:
        return self.est_measured.t_total / max(self.t_measured, 1e-12)


# ---------------------------------------------------------------------------
# Shape scaling: keep the harness inside an element budget by halving the
# largest shrinkable dims (never N — irregularity lives in M/K/G).
# ---------------------------------------------------------------------------

_SCALE_FLOOR = 4096


def _scale2(a: int, b: int, budget_check) -> tuple[int, int]:
    """Halve the larger of two shrinkable dims until the budget holds or
    both hit the floor."""
    while not budget_check(a, b):
        if a >= b and a > _SCALE_FLOOR:
            a = max(a // 2, _SCALE_FLOOR)
        elif b > _SCALE_FLOOR:
            b = max(b // 2, _SCALE_FLOOR)
        elif a > _SCALE_FLOOR:
            a = max(a // 2, _SCALE_FLOOR)
        else:
            break
    return a, b


def _scale_dense(m: int, k: int, n: int, budget: int) -> tuple[int, int, int]:
    m, k = _scale2(m, k, lambda a, b: a * b + b * n + a * n <= budget)
    return m, k, n


def _scale_batched(g: int, m: int, k: int, n: int,
                   budget: int) -> tuple[int, int, int, int]:
    per = m * k + k * n + m * n
    while g * per > budget and g > 4:
        g = max(g // 2, 4)
    m, k = _scale2(m, k,
                   lambda a, b: g * (a * b + b * n + a * n) <= budget)
    return g, m, k, n


def _scale_ragged(g: int, total: int, k: int, n: int,
                  budget: int) -> tuple[int, int, int, int]:
    floor_t = max(_SCALE_FLOOR, 2 * g)
    while total * (k + n) + g * k * n > budget and total > floor_t:
        total = max(total // 2, floor_t)
    while total * (k + n) + g * k * n > budget and k > _SCALE_FLOOR:
        k = max(k // 2, _SCALE_FLOOR)
    return g, total, k, n


def _balanced_offsets(g: int, total: int) -> jnp.ndarray:
    import numpy as np
    return jnp.asarray(np.rint(np.linspace(0, total, g + 1)).astype(np.int32))


# ---------------------------------------------------------------------------
# Per-family timing runners.  Each returns (signature, thunk): candidates
# whose executed computation coincides share one measurement (no noise
# mining between physically identical runs).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jit_dense_ref(out_dtype_name: str):
    od = jnp.dtype(out_dtype_name)
    return jax.jit(lambda a, b: _ref.matmul_nn(a, b, od))


@functools.lru_cache(maxsize=None)
def _jit_batched_ref(out_dtype_name: str, a_ndim: int, b_ndim: int):
    od = jnp.dtype(out_dtype_name)
    al = "gmk" if a_ndim == 3 else "mk"
    bl = "gkn" if b_ndim == 3 else "kn"

    def f(a, b):
        out = jnp.einsum(f"{al},{bl}->gmn", a, b,
                         preferred_element_type=jnp.float32)
        return out.astype(od)

    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _jit_ragged_ref(out_dtype_name: str):
    od = jnp.dtype(out_dtype_name)
    rd = getattr(jax.lax, "ragged_dot", None)
    if rd is None:  # pragma: no cover - every supported jax ships ragged_dot
        return jax.jit(functools.partial(_ref.ragged_matmul_ref,
                                         out_dtype=od))

    def f(x, w, offsets):
        sizes = jnp.diff(offsets).astype(jnp.int32)
        return rd(x, w, sizes,
                  preferred_element_type=jnp.float32).astype(od)

    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _jit_ragged_dw_ref(out_dtype_name: str):
    od = jnp.dtype(out_dtype_name)
    return jax.jit(functools.partial(_ref.ragged_matmul_dw_ref, out_dtype=od))


def _clamp_blocks(plan: GemmPlan, bm_top: int, bn_top: int,
                  bk_top: int) -> tuple[int, int, int]:
    return (min(plan.bm, bm_top), min(plan.bn, bn_top), min(plan.bk, bk_top))


@functools.lru_cache(maxsize=None)
def _jit_epilogue(epi: Epilogue, out_dtype_name: str):
    """One compiled tail pass over a stored output."""
    od = jnp.dtype(out_dtype_name)
    return jax.jit(lambda y, bias, res: epi.apply(
        y.astype(jnp.float32), bias=bias, residual=res).astype(od))


def _tail_passes(epi: Epilogue, out_dtype, fused: bool):
    """The tail as compiled passes: the FUSED candidate runs it as one pass
    (its cost is an upper bound — on the TPU kernels it is zero, folded into
    the accumulator flush; an XLA:CPU emitter quirk makes a tail inlined
    into the dot jit run single-threaded, i.e. slower than a standalone
    pass, so inline fusion is deliberately not what this harness times),
    the UNFUSED candidate as one separate pass per op — the extra HBM
    round-trips ``cmr._epilogue_bytes`` prices."""
    specs = (epi,) if fused else epi.decompose()
    return [_jit_epilogue(s, jnp.dtype(out_dtype).name) for s in specs]


def _epi_operands(epi: Epilogue | None, m: int, n: int, dtype):
    if epi is None:
        return None, None
    # Flush vectors stay float even when the GEMM operands are quantized.
    vdt = dtype if jnp.dtype(dtype).itemsize > 1 else jnp.float32
    bias = _rand((n,), vdt, seed=2) if epi.bias else None
    res = _rand((m, n), vdt, seed=3) if epi.residual else None
    return bias, res


def _dense_runner(engine, a, b, plan, out_dtype, epi: Epilogue | None = None):
    m, k = a.shape
    n = b.shape[1]
    sub = _ops.sublane(a.dtype)
    bm, bn, bk = _clamp_blocks(plan, ceil_to(m, sub), ceil_to(n, 128),
                               ceil_to(k, 128))
    bias, res = _epi_operands(epi, m, n, a.dtype)
    fused = epi is not None and plan.fuse

    def with_tail(thunk, passes):
        """Chain tail passes over the GEMM result (sliced to the true shape
        first when the padded engine produced a padded output)."""
        if not passes:
            return thunk

        def run():
            y = thunk()[:m, :n]
            for p in passes:
                y = p(y, bias, res)
            return y

        return run

    if engine == "xla":
        if plan.edge == "padded":
            mp, kp, np_ = ceil_to(m, bm), ceil_to(k, bk), ceil_to(n, bn)
            a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
            b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
        else:
            mp, kp, np_ = m, k, n
            a_p, b_p = a, b
        a_p, b_p = _widen(a_p), _widen(b_p)
        fn = _jit_dense_ref(jnp.dtype(out_dtype).name)
        passes = [] if epi is None else _tail_passes(epi, out_dtype, fused)
        return (("xla", mp, kp, np_, epi, fused),
                with_tail(lambda: fn(a_p, b_p), passes))
    interp = engine == "pallas_interpret"
    sig = ("pl", bm, bn, bk, plan.dim_order, plan.edge, interp, epi, fused)
    kw = dict(bm=bm, bn=bn, bk=bk, dim_order=plan.dim_order,
              out_dtype=out_dtype, interpret=interp, edge=plan.edge)
    if fused:
        # True in-kernel fusion: the tail rides the accumulator flush.
        return sig, (lambda: _ops.gemm(a, b, epilogue=epi, bias=bias,
                                       residual=res, **kw))
    return sig, with_tail(lambda: _ops.gemm(a, b, **kw),
                          [] if epi is None
                          else _tail_passes(epi, out_dtype, False))


def _batched_runner(engine, a, b, plan, out_dtype):
    m, k = a.shape[-2:]
    n = b.shape[-1]
    sub = _ops.sublane(a.dtype)
    bm, bn, bk = _clamp_blocks(plan, ceil_to(m, sub), ceil_to(n, 128),
                               ceil_to(k, 128))
    if engine == "xla":
        if plan.edge == "padded":
            mp, kp, np_ = ceil_to(m, bm), ceil_to(k, bk), ceil_to(n, bn)

            def pad(x, last2):
                pads = [(0, 0)] * (x.ndim - 2) + \
                    [(0, t - s) for s, t in zip(x.shape[-2:], last2)]
                return jnp.pad(x, pads)

            a_p, b_p = pad(a, (mp, kp)), pad(b, (kp, np_))
        else:
            mp, kp, np_ = m, k, n
            a_p, b_p = a, b
        a_p, b_p = _widen(a_p), _widen(b_p)
        fn = _jit_batched_ref(jnp.dtype(out_dtype).name, a.ndim, b.ndim)
        return ("xla", mp, kp, np_), (lambda: fn(a_p, b_p))
    interp = engine == "pallas_interpret"
    sig = ("pl", bm, bn, bk, plan.dim_order, plan.edge, interp)
    return sig, (lambda: _ops.batched_gemm(
        a, b, bm=bm, bn=bn, bk=bk, dim_order=plan.dim_order,
        out_dtype=out_dtype, interpret=interp, edge=plan.edge))


def _ragged_runner(engine, x, w, offsets, plan, out_dtype, ragged):
    total, k = x.shape
    if ragged == "k":
        # dW: x (T, D), w is dy (T, F); the ragged dim is the contraction.
        if engine == "xla":
            fn = _jit_ragged_dw_ref(jnp.dtype(out_dtype).name)
            xw, ww = _widen(x), _widen(w)
            return ("xla", "dw"), (lambda: fn(xw, ww, offsets))
        interp = engine == "pallas_interpret"
        sig = ("pl", plan.bm, plan.bn, plan.bk, interp)
        return sig, (lambda: _ops.ragged_gemm_dw(
            x, w, offsets, bm=plan.bm, bn=plan.bn, bk=plan.bk,
            out_dtype=out_dtype, interpret=interp))
    n = w.shape[2]
    sub = _ops.sublane(x.dtype)
    bm, bn, bk = _clamp_blocks(plan, ceil_to(total, sub), ceil_to(n, 128),
                               ceil_to(k, 128))
    if engine == "xla":
        tp = ceil_to(total, bm)
        x_p = jnp.pad(x, ((0, tp - total), (0, 0)))
        offs = offsets.at[-1].set(tp)       # pad rows ride the last group
        x_p, w_p = _widen(x_p), _widen(w)
        fn = _jit_ragged_ref(jnp.dtype(out_dtype).name)
        return ("xla", tp), (lambda: fn(x_p, w_p, offs))
    interp = engine == "pallas_interpret"
    sig = ("pl", bm, bn, bk, interp)
    return sig, (lambda: _ops.ragged_gemm(
        x, w, offsets, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
        interpret=interp))


def _measure_shortlist(sl, make_runner, repeats):
    """Time each shortlisted candidate (memoized on the executed-computation
    signature) and return (times, winner_index).  Ties keep the earliest —
    i.e. the analytic argmin, which is always index 0."""
    memo: dict = {}
    times: list[float] = []
    for cand in sl:
        sig, thunk = make_runner(cand)
        if sig not in memo:
            memo[sig] = _ops.bench(thunk, repeats=repeats)
        times.append(memo[sig])
    widx = min(range(len(sl)), key=lambda i: (times[i], i))
    return times, widx


def _store_result(res: TuneResult, *, num_shards: int = 1,
                  strategy: str | None = None,
                  schedule: str | None = None) -> None:
    rec = {
        "bm": res.plan.bm, "bn": res.plan.bn, "bk": res.plan.bk,
        "nsplit": res.plan.nsplit, "dim_order": res.plan.dim_order,
        "edge": res.plan.edge, "fuse": res.plan.fuse,
        "t_measured_us": round(res.t_measured * 1e6, 3),
        "t_analytic_us": round(res.t_analytic * 1e6, 3),
        "t_model_us": round(res.est_measured.t_total * 1e6, 6),
        "engine": res.engine, "mode": "measured",
    }
    if strategy is not None:
        rec["strategy"] = strategy
        if schedule is not None:
            rec["schedule"] = schedule
    plan_store.get_store().put(res.key, rec)
    tuner.clear_planner_caches()    # next plan_* consults the new entry


def time_dense_plans(m: int, k: int, n: int, plans, *,
                     in_bytes: int = 4, out_bytes: int = 4,
                     engine: str | None = None,
                     repeats: int = DEFAULT_REPEATS,
                     max_elements: int = DEFAULT_MAX_ELEMENTS,
                     epilogue: Epilogue | None = None) -> list[float]:
    """Time an explicit list of dense plans on the harness (one shared
    scaled problem, physically-identical runs memoized) — the replay path:
    no search, no store, just seconds per plan.  ``epilogue`` times each
    plan WITH the elementwise tail, fused or separate per its ``fuse``."""
    engine = _check_engine(engine or default_engine())
    mm, kk, nn = _scale_dense(m, k, n, max_elements)
    in_dt, out_dt = _dtype(in_bytes), _dtype(out_bytes)
    a, b = _rand((mm, kk), in_dt), _rand((kk, nn), in_dt, seed=1)
    times, _ = _measure_shortlist(
        list(plans),
        lambda c: _dense_runner(engine, a, b, c, out_dt, epilogue),
        repeats)
    return times


# ---------------------------------------------------------------------------
# Family searches
# ---------------------------------------------------------------------------

def autotune_gemm(
    m: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    spec: TpuSpec = TPU_V5E,
    *,
    num_shards: int = 1,
    axis: str | None = None,
    top_k: int = DEFAULT_TOP_K,
    repeats: int = DEFAULT_REPEATS,
    engine: str | None = None,
    max_elements: int = DEFAULT_MAX_ELEMENTS,
    store: bool = True,
    epilogue: Epilogue | None = None,
    b_bytes: int | None = None,
) -> TuneResult:
    """Measured search for the dense GEMM: CMR shortlist -> time -> winner
    (``mode == "measured"``), persisted to the plan store unless
    ``store=False``.  ``num_shards > 1`` runs the hybrid placed search
    (measured local GEMM per strategy + modeled collective).

    ``epilogue`` widens the search to the fusion decision: candidates fork
    on running the elementwise tail in the accumulator flush (``fuse=True``)
    vs as separate compiled passes over the stored output, and every
    candidate is timed WITH its tail — so the persisted winner records
    whether fusion actually paid on this engine, not just in the model.

    ``b_bytes`` searches the MIXED-width dtype axis (weight-only quant:
    ``in_bytes``-wide A against a ``b_bytes``-wide B panel); the winner is
    stored under the ``+bb{n}`` key fragment so only mixed-width calls are
    served by it."""
    engine = _check_engine(engine or default_engine())
    epi_ops = epilogue.num_ops if epilogue is not None else 0
    # Shortlist under the calibrated view (better pruning), but express
    # est_measured in the RAW base spec: calibration fractions are absolute
    # w.r.t. that spec, so fitting against already-calibrated predictions
    # would collapse a re-calibration to ~1.0 and destroy the correction.
    base_spec = spec
    spec = tuner.effective_spec(spec)
    if num_shards > 1:
        opts = tuner.dense_placement_options(m, k, n, num_shards, in_bytes,
                                             out_bytes, spec, axis)
        return _tune_placed(
            "dense", (m, k, n), opts, in_bytes, out_bytes, spec,
            lambda dims: autotune_gemm(
                *dims, in_bytes, out_bytes, spec, top_k=top_k,
                repeats=repeats, engine=engine, max_elements=max_elements,
                store=False, epilogue=epilogue),
            num_shards=num_shards, engine=engine, store=store)

    cands = tuner.gemm_candidates(m, k, n, in_bytes, out_bytes, spec,
                                  epi_ops, b_bytes=b_bytes)
    sl = tuner.shortlist(cands, top_k)
    mm, kk, nn = _scale_dense(m, k, n, max_elements)
    in_dt, out_dt = _dtype(in_bytes), _dtype(out_bytes)
    b_dt = in_dt if b_bytes is None else _dtype(b_bytes)
    a, b = _rand((mm, kk), in_dt), _rand((kk, nn), b_dt, seed=1)
    times, widx = _measure_shortlist(
        sl, lambda c: _dense_runner(engine, a, b, c, out_dt, epilogue),
        repeats)
    winner = replace(sl[widx], mode="measured")
    est_meas = estimate(mm, kk, nn, bm=winner.bm, bn=winner.bn, bk=winner.bk,
                        dim_order=winner.dim_order, in_bytes=in_bytes,
                        out_bytes=out_bytes, edge=winner.edge,
                        epi_ops=epi_ops, epi_fused=winner.fuse,
                        spec=base_spec, b_bytes=b_bytes)
    res = TuneResult(
        family="dense", dims=(m, k, n), measured_dims=(mm, kk, nn),
        key=plan_store.shape_key("dense", (m, k, n), in_bytes, out_bytes,
                                 extra=tuner._dtype_extra(b_bytes)),
        plan=winner, t_measured=times[widx], t_analytic=times[0],
        analytic_plan=sl[0], est_measured=est_meas, engine=engine,
        timed=tuple((c.bm, c.bn, c.bk, c.dim_order, t)
                    for c, t in zip(sl, times)),
        in_bytes=in_bytes, b_bytes=b_bytes)
    if store:
        _store_result(res)
    return res


def autotune_batched_gemm(
    g: int, m: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    shared: str = "none",
    spec: TpuSpec = TPU_V5E,
    *,
    num_shards: int = 1,
    axis: str | None = None,
    top_k: int = DEFAULT_TOP_K,
    repeats: int = DEFAULT_REPEATS,
    engine: str | None = None,
    max_elements: int = DEFAULT_MAX_ELEMENTS,
    store: bool = True,
) -> TuneResult:
    """Measured search for the batched/grouped GEMM family (same contract
    as ``autotune_gemm``; ``shared`` marks the 2-D cross-batch operand)."""
    engine = _check_engine(engine or default_engine())
    base_spec = spec                # see autotune_gemm: calibration basis
    spec = tuner.effective_spec(spec)
    if num_shards > 1:
        opts = tuner.batched_placement_options(
            g, m, k, n, num_shards, in_bytes, out_bytes, shared, spec, axis)
        return _tune_placed(
            "batched", (g, m, k, n), opts, in_bytes, out_bytes, spec,
            lambda dims: autotune_batched_gemm(
                *dims, in_bytes, out_bytes, shared, spec, top_k=top_k,
                repeats=repeats, engine=engine, max_elements=max_elements,
                store=False),
            num_shards=num_shards, engine=engine, store=store,
            extra=f"shared:{shared}")

    cands = tuner.batched_candidates(g, m, k, n, in_bytes, out_bytes, shared,
                                     spec)
    sl = tuner.shortlist(cands, top_k)
    gg, mm, kk, nn = _scale_batched(g, m, k, n, max_elements)
    in_dt, out_dt = _dtype(in_bytes), _dtype(out_bytes)
    a = _rand((mm, kk) if shared == "a" else (gg, mm, kk), in_dt)
    b = _rand((kk, nn) if shared == "b" else (gg, kk, nn), in_dt, seed=1)
    times, widx = _measure_shortlist(
        sl, lambda c: _batched_runner(engine, a, b, c, out_dt), repeats)
    winner = replace(sl[widx], mode="measured")
    est_meas = estimate_batched(
        gg, mm, kk, nn, bm=winner.bm, bn=winner.bn, bk=winner.bk,
        dim_order=winner.dim_order, shared_a=shared == "a",
        shared_b=shared == "b", in_bytes=in_bytes, out_bytes=out_bytes,
        edge=winner.edge, spec=base_spec)
    res = TuneResult(
        family="batched", dims=(g, m, k, n), measured_dims=(gg, mm, kk, nn),
        key=plan_store.shape_key("batched", (g, m, k, n), in_bytes,
                                 out_bytes, extra=f"shared:{shared}"),
        plan=winner, t_measured=times[widx], t_analytic=times[0],
        analytic_plan=sl[0], est_measured=est_meas, engine=engine,
        timed=tuple((c.bm, c.bn, c.bk, c.dim_order, t)
                    for c, t in zip(sl, times)))
    if store:
        _store_result(res)
    return res


def autotune_ragged_gemm(
    g: int, total: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    ragged: str = "m",
    spec: TpuSpec = TPU_V5E,
    *,
    num_shards: int = 1,
    axis: str | None = None,
    top_k: int = DEFAULT_TOP_K,
    repeats: int = DEFAULT_REPEATS,
    engine: str | None = None,
    max_elements: int = DEFAULT_MAX_ELEMENTS,
    store: bool = True,
    b_bytes: int | None = None,
) -> TuneResult:
    """Measured search for the ragged grouped GEMM family.  The harness
    times a *balanced* distribution of the same signature (per-group counts
    are dynamic at run time; the plan is keyed by the aggregate anyway).
    ``b_bytes`` searches the mixed-width axis (quantized expert panels
    against wide activations), keyed ``ragged:m+bb{n}``."""
    engine = _check_engine(engine or default_engine())
    base_spec = spec                # see autotune_gemm: calibration basis
    spec = tuner.effective_spec(spec)
    if num_shards > 1:
        opts = tuner.ragged_placement_options(
            g, total, k, n, num_shards, in_bytes, out_bytes, ragged, spec,
            axis)
        return _tune_placed(
            "ragged", (g, total, k, n), opts, in_bytes, out_bytes, spec,
            lambda dims: autotune_ragged_gemm(
                *dims, in_bytes, out_bytes, ragged, spec, top_k=top_k,
                repeats=repeats, engine=engine, max_elements=max_elements,
                store=False),
            num_shards=num_shards, engine=engine, store=store,
            extra=f"ragged:{ragged}")

    cands = tuner.ragged_candidates(g, total, k, n, in_bytes, out_bytes,
                                    ragged, spec, b_bytes=b_bytes)
    sl = tuner.shortlist(cands, top_k)
    gg, tt, kk, nn = _scale_ragged(g, total, k, n, max_elements)
    in_dt, out_dt = _dtype(in_bytes), _dtype(out_bytes)
    b_dt = in_dt if b_bytes is None else _dtype(b_bytes)
    offsets = _balanced_offsets(gg, tt)
    if ragged == "k":
        x = _rand((tt, kk), in_dt)           # (T, D)
        w = _rand((tt, nn), in_dt, seed=1)   # dy: (T, F)
    else:
        x = _rand((tt, kk), in_dt)
        w = _rand((gg, kk, nn), b_dt, seed=1)
    times, widx = _measure_shortlist(
        sl, lambda c: _ragged_runner(engine, x, w, offsets, c, out_dt,
                                     ragged), repeats)
    winner = replace(sl[widx], mode="measured")
    est_meas = estimate_ragged(gg, tt, kk, nn, bm=winner.bm, bn=winner.bn,
                               bk=winner.bk, ragged=ragged,
                               in_bytes=in_bytes, out_bytes=out_bytes,
                               spec=base_spec, b_bytes=b_bytes)
    res = TuneResult(
        family="ragged", dims=(g, total, k, n),
        measured_dims=(gg, tt, kk, nn),
        key=plan_store.shape_key(
            "ragged", (g, total, k, n), in_bytes, out_bytes,
            extra=tuner._dtype_extra(b_bytes, f"ragged:{ragged}")),
        plan=winner, t_measured=times[widx], t_analytic=times[0],
        analytic_plan=sl[0], est_measured=est_meas, engine=engine,
        timed=tuple((c.bm, c.bn, c.bk, "mn", t)
                    for c, t in zip(sl, times)),
        in_bytes=in_bytes, b_bytes=b_bytes)
    if store:
        _store_result(res)
    return res


def _placed_total(t_local: float, placement) -> float:
    """Compose a measured local time with the modeled collective exactly the
    way ``Plan.t_total`` does: SUM for the gather schedule, MAX for the ring
    (the overlapped transfer hides behind compute)."""
    if placement.schedule == "ring":
        return max(t_local * placement.waste, placement.t_collective)
    return t_local * placement.waste + placement.t_collective


def _tune_placed(family, dims, options, in_bytes, out_bytes, spec,
                 tune_local, *, num_shards, engine, store,
                 extra: str = "") -> TuneResult:
    """Hybrid placed search: measured local GEMM per ``PlacementOption``,
    modeled collective/waste terms (schedule-composed), the same clear-win
    margins as the analytic placer."""
    scored = []
    for opt in options:
        res = tune_local(opt.local_dims)
        total = _placed_total(res.t_measured, opt.placement)
        scored.append((opt, res, total))
    best_i = 0
    for i, (opt, _res, total) in enumerate(scored[1:], start=1):
        if total * opt.margin < scored[best_i][2]:
            best_i = i
    opt, local, total = scored[best_i]
    winner = replace(local.plan, placement=opt.placement, mode="measured")
    # The analytic placed choice, scored with ITS analytic blocks' measured
    # time — the apples-to-apples baseline for this harness run.
    analytic_scored = [
        (o, _placed_total(r.t_analytic, o.placement))
        for o, r, _t in scored]
    a_i = 0
    for i, (o, t) in enumerate(analytic_scored[1:], start=1):
        if t * o.margin < analytic_scored[a_i][1]:
            a_i = i
    a_opt, a_local, _ = scored[a_i]
    res = TuneResult(
        family=family, dims=dims, measured_dims=local.measured_dims,
        key=plan_store.shape_key(family, dims, in_bytes, out_bytes,
                                 num_shards=num_shards, extra=extra),
        plan=winner, t_measured=total, t_analytic=analytic_scored[a_i][1],
        analytic_plan=replace(a_local.analytic_plan,
                              placement=a_opt.placement),
        est_measured=local.est_measured, engine=engine, timed=local.timed)
    if store:
        _store_result(res, num_shards=num_shards,
                      strategy=opt.placement.strategy,
                      schedule=opt.placement.schedule)
    return res


# ---------------------------------------------------------------------------
# Calibration: fit the effective TpuSpec constants from (prediction,
# measurement) pairs so unmeasured shapes plan better too.
# ---------------------------------------------------------------------------

def prediction_error(samples, flops_frac: float = 1.0,
                     bw_frac: float = 1.0) -> float:
    """Geomean multiplicative error of the roofline prediction
    ``max(t_compute / flops_frac, t_memory / bw_frac)`` against measurement
    — 1.0 is a perfect model, symmetric in over/under-prediction."""
    logs = []
    for est, t_meas in samples:
        tp = max(est.t_compute / flops_frac, est.t_memory / bw_frac)
        logs.append(abs(math.log(max(tp, 1e-12) / max(t_meas, 1e-12))))
    return math.exp(sum(logs) / len(logs)) if logs else 1.0


def geomean_ratio(samples, flops_frac: float = 1.0,
                  bw_frac: float = 1.0) -> float:
    """Signed geomean of predicted/measured (shows the bias direction)."""
    logs = []
    for est, t_meas in samples:
        tp = max(est.t_compute / flops_frac, est.t_memory / bw_frac)
        logs.append(math.log(max(tp, 1e-12) / max(t_meas, 1e-12)))
    return math.exp(sum(logs) / len(logs)) if logs else 1.0


def fit_calibration(samples, *, engine: str = "",
                    spec: TpuSpec = TPU_V5E) -> Calibration:
    """Grid-fit (achievable-flops fraction, effective-bandwidth fraction)
    minimizing the geomean prediction error over ``samples`` — a list of
    (PlanEstimate-of-measured-problem, measured-seconds) pairs, e.g.
    ``[(r.est_measured, r.t_measured) for r in results]``.

    Coordinate grid in log space (the roofline max() makes the objective
    piecewise-smooth but not convex; the grid is cheap and global), then one
    refinement round around the coarse winner."""
    if not samples:
        return Calibration(engine=engine, base_spec=spec.name)

    def sweep(centers, span, steps):
        best = None
        for ef in range(-steps, steps + 1):
            ff = centers[0] * (10 ** (ef * span / steps))
            for eb in range(-steps, steps + 1):
                bf = centers[1] * (10 ** (eb * span / steps))
                err = prediction_error(samples, ff, bf)
                if best is None or err < best[0]:
                    best = (err, ff, bf)
        return best

    _, ff, bf = sweep((1.0, 1.0), span=4.0, steps=16)       # 1e-4 .. 1e4
    _, ff, bf = sweep((ff, bf), span=0.25, steps=8)         # refine
    return Calibration(flops_frac=ff, bw_frac=bf, n_samples=len(samples),
                       engine=engine, base_spec=spec.name)


def calibrate(results, *, spec: TpuSpec = TPU_V5E,
              store: bool = True) -> Calibration:
    """Fit calibration from a batch of ``TuneResult``s and (by default)
    install it in the plan store, where ``tuner.effective_spec`` picks it up
    for every subsequent default-spec planning decision.  (``est_measured``
    is always expressed in the raw base spec, so refitting with a
    calibration already installed composes correctly instead of collapsing
    to ~1.0.)

    Narrow-dtype results (``in_bytes == 1`` — the full-int8 compute path,
    whose predictions price against ``TpuSpec.peak_flops_int8``) are fitted
    SEPARATELY into ``flops_frac_int8``: the int8 MXU path saturates
    differently from the float path, so one shared fraction would misprice
    whichever family wasn't measured.  Mixed weight-only results
    (``b_bytes`` set, wide activations) compute on the float path and stay
    in the main fit."""
    engines = {r.engine for r in results}
    wide = [r for r in results if getattr(r, "in_bytes", 4) != 1]
    narrow = [r for r in results if getattr(r, "in_bytes", 4) == 1]
    cal = fit_calibration([(r.est_measured, r.t_measured) for r in wide],
                          engine=",".join(sorted(engines)), spec=spec)
    if narrow:
        # Fit the int8 flops fraction against the MAIN fit's bandwidth
        # fraction (the wires don't change with the MXU path); fall back to
        # a narrow-only joint fit when no wide samples anchored bw_frac.
        nsam = [(r.est_measured, r.t_measured) for r in narrow]
        if wide:
            best = None
            for e in range(-64, 65):
                ff = 10.0 ** (e * 4.0 / 64)
                err = prediction_error(nsam, ff, cal.bw_frac)
                if best is None or err < best[0]:
                    best = (err, ff)
            int8_frac = best[1]
        else:
            ncal = fit_calibration(nsam, engine=cal.engine, spec=spec)
            cal = replace(cal, bw_frac=ncal.bw_frac)
            int8_frac = ncal.flops_frac
        cal = replace(cal, flops_frac_int8=int8_frac,
                      n_samples=len(results))
    if store:
        st = plan_store.get_store()
        old = st.calibration
        if old is not None:          # keep a fitted ICI fraction, if any
            cal = replace(cal, ici_frac=old.ici_frac)
        st.kind = st.kind or plan_store.device_kind()
        st.calibration = cal
        tuner.clear_planner_caches()
    return cal


# ---------------------------------------------------------------------------
# Mesh-measured extensions: end-to-end placed timing + ICI calibration.
# Until this landed the placed search timed only the LOCAL GEMM and kept the
# ICI term modeled; these helpers time placed plans on the actual mesh
# (collectives executed, not priced) and fit the effective-ICI-bandwidth
# constant the same way ``calibrate`` fits flops/HBM.
# ---------------------------------------------------------------------------

def calibrate_ici(mesh, axis="data", *,
                  widths=(128, 256),
                  rows: int = 4096,
                  repeats: int = DEFAULT_REPEATS,
                  spec: TpuSpec = TPU_V5E,
                  store: bool = True) -> Calibration:
    """Fit the effective-ICI-bandwidth fraction from timed mesh exchanges.

    Times the EP exchange round-trip (``all_gather`` in, ``psum_scatter``
    back — the two legs ``cmr.estimate_ep`` prices) on ``mesh[axis]`` and
    fits ``ici_frac`` so the modeled exchange matches measurement:
    ``t_effective = t_model / ici_frac``, geomean over samples.  On fake
    host devices this absorbs the whole software-collective overhead — the
    point is that the *same* constant then corrects every planned
    ``t_collective``, exactly like the HBM-bandwidth fraction corrects
    ``t_memory``.  Installed into the store's ``Calibration`` (preserving
    fitted flops/HBM fractions) unless ``store=False``."""
    from ..compat import shard_map_unchecked
    from jax.sharding import PartitionSpec as P

    nc = int(mesh.shape[axis])
    cal_base = plan_store.get_store().calibration or Calibration(
        engine="ici", base_spec=spec.name)
    if nc <= 1:
        return cal_base
    logs = []
    for width in widths:
        r = max(nc, rows - rows % nc)
        x = _rand((r, width), jnp.float32)

        def roundtrip(x_l):
            full = jax.lax.all_gather(x_l, axis, axis=0, tiled=True)
            return jax.lax.psum_scatter(full, axis, scatter_dimension=0,
                                        tiled=True)

        fn = jax.jit(shard_map_unchecked(
            roundtrip, mesh=mesh, in_specs=(P(axis, None),),
            out_specs=P(axis, None)))
        t_meas = _ops.bench(lambda: fn(x), repeats=repeats)
        ex = estimate_ep(r, width, nc, elt_bytes=4, spec=spec)
        t_model = 2.0 * ex.t_exchange            # both legs
        logs.append(math.log(max(t_model, 1e-12) / max(t_meas, 1e-12)))
    ici = math.exp(sum(logs) / len(logs)) if logs else 1.0
    cal = replace(cal_base, ici_frac=ici,
                  n_samples=cal_base.n_samples + len(logs))
    if store:
        st = plan_store.get_store()
        st.kind = st.kind or plan_store.device_kind()
        st.calibration = cal
        tuner.clear_planner_caches()
    return cal


def time_placed_ragged_e2e(g: int, total: int, k: int, n: int, *,
                           mesh, axis="data",
                           in_bytes: int = 4, out_bytes: int = 4,
                           repeats: int = DEFAULT_REPEATS,
                           backend: str = "xla") -> list[dict]:
    """Time the placed ragged options END-TO-END on the mesh — collectives
    executed, not modeled — one row per (strategy, schedule) candidate plus
    the single-device reference:

      * ``single`` — the unplaced ragged GEMM (the m_parallel proxy: on a
        timeshared host mesh every shard shares one core, so the sharded
        m_parallel wall time equals the single-device wall time).
      * ``expert_parallel``/``gather`` and ``expert_parallel``/``ring`` —
        the real EP executors under each schedule.

    Each row carries ``t_measured`` (seconds) and the planner's modeled
    ``t_model`` for the matching option (``Plan.t_total`` under the current
    calibration), so callers can check the measured winner against the
    modeled winner — the crossover-agreement gate."""
    from .dispatch import ragged_matmul as _ragged
    from .distributed import ep_ragged_matmul as _ep

    nc = int(mesh.shape[axis]) if not isinstance(axis, (tuple, list)) \
        else int(math.prod(mesh.shape[a] for a in axis))
    in_dt, out_dt = _dtype(in_bytes), _dtype(out_bytes)
    x = _rand((total, k), in_dt)
    w = _rand((g, k, n), in_dt, seed=1)
    offsets = _balanced_offsets(g, total)

    rows: list[dict] = []
    f1 = jax.jit(lambda x, w, o: _ragged(x, w, o, out_dtype=out_dt,
                                         backend=backend))
    rows.append({
        "strategy": "single", "schedule": "gather",
        "t_measured": _ops.bench(lambda: f1(x, w, offsets),
                                 repeats=repeats),
        "t_model": tuner.plan_ragged_gemm(g, total, k, n, in_bytes,
                                          out_bytes).t_total,
    })
    opts = {(o.placement.strategy, o.placement.schedule): o
            for o in tuner.ragged_placement_options(
                g, total, k, n, nc, in_bytes, out_bytes, "m",
                tuner.effective_spec(TPU_V5E))}
    for schedule in ("gather", "ring"):
        fe = jax.jit(functools.partial(
            _ep, mesh=mesh, axis=axis, out_dtype=out_dt, backend=backend,
            schedule=schedule))
        opt = opts.get(("expert_parallel", schedule))
        t_model = float("nan")
        if opt is not None:
            plan = replace(opt.plan_local(in_bytes, out_bytes,
                                          tuner.effective_spec(TPU_V5E)),
                           placement=opt.placement)
            t_model = plan.t_total
        rows.append({
            "strategy": "expert_parallel", "schedule": schedule,
            "t_measured": _ops.bench(lambda: fe(x, w, offsets),
                                     repeats=repeats),
            "t_model": t_model,
        })
    return rows


def time_placed_dense_e2e(m: int, k: int, n: int, *, mesh, axis="model",
                          in_bytes: int = 4, out_bytes: int = 4,
                          repeats: int = DEFAULT_REPEATS,
                          backend: str = "xla") -> list[dict]:
    """Time the dense placed strategies end-to-end on the mesh through
    ``dist_matmul``: m_parallel, k_parallel/gather (psum) and
    k_parallel/ring (overlapped collective matmul), with the planner's
    modeled ``t_total`` alongside each."""
    from .distributed import dist_matmul as _dist

    nc = int(mesh.shape[axis])
    in_dt, out_dt = _dtype(in_bytes), _dtype(out_bytes)
    a = _rand((m, k), in_dt)
    b = _rand((k, n), in_dt, seed=1)
    opts = {(o.placement.strategy, o.placement.schedule): o
            for o in tuner.dense_placement_options(
                m, k, n, nc, in_bytes, out_bytes,
                tuner.effective_spec(TPU_V5E))}
    rows: list[dict] = []
    for strategy, schedule in (("m_parallel", "gather"),
                               ("k_parallel", "gather"),
                               ("k_parallel", "ring")):
        fn = jax.jit(functools.partial(
            _dist, mesh=mesh, axis=axis, strategy=strategy,
            schedule=schedule, out_dtype=out_dt, backend=backend))
        opt = opts.get((strategy, schedule))
        t_model = float("nan")
        if opt is not None:
            plan = replace(opt.plan_local(in_bytes, out_bytes,
                                          tuner.effective_spec(TPU_V5E)),
                           placement=opt.placement)
            t_model = plan.t_total
        rows.append({
            "strategy": strategy, "schedule": schedule,
            "t_measured": _ops.bench(lambda: fn(a, b), repeats=repeats),
            "t_model": t_model,
        })
    return rows


# ---------------------------------------------------------------------------
# Persistence entry points (thin veneers over plan_store that also
# invalidate the planner LRUs, so loads take effect immediately).
# ---------------------------------------------------------------------------

def load_plan_cache(path: str) -> int:
    """Adopt a persistent plan-cache file (0 entries for missing / corrupt /
    other-device files — graceful, never raises) and invalidate the planner
    LRUs so the next ``plan_*`` serves ``mode == "cached"`` plans."""
    n = plan_store.get_store().load(path)
    tuner.clear_planner_caches()
    return n


def save_plan_cache(path: str | None = None) -> str:
    return plan_store.get_store().save(path)


def clear_plan_store() -> None:
    """Forget all in-memory measured plans + calibration (the on-disk file
    is untouched) and invalidate the planner LRUs."""
    plan_store.reset_store()
    tuner.clear_planner_caches()
