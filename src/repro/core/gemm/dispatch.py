"""Framework-wide GEMM entry point.

Every dense contraction in the model stack routes through ``matmul`` /
``project``: the shape is classified (paper §III-A), the CMR tuner picks the
strategy + blocks (paper §IV-C), and the call dispatches to

  * the specialized Pallas ftIMM kernel on TPU (or in interpret mode when
    explicitly requested, e.g. kernel tests), wrapped in a custom VJP whose
    backward GEMMs are themselves ftIMM-planned — dW = x.T @ dy is the
    paper's T2 shape and gets the K-oriented treatment automatically;
  * an XLA ``dot_general`` path on CPU (used by the multi-pod dry-run so
    ``cost_analysis`` reflects the true FLOPs/bytes) with identical
    fp32-accumulation semantics.

Backend selection: ``REPRO_GEMM_BACKEND`` env var ("pallas" | "xla" |
"pallas_interpret"), else pallas on TPU and xla elsewhere.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from ...kernels.ftimm import ops as _ops
from ...kernels.ftimm import ref as _ref
from .tuner import plan_batched_gemm, plan_gemm

_REF = {"nn": _ref.matmul_nn, "tn": _ref.matmul_tn, "nt": _ref.matmul_nt}


def _backend() -> str:
    env = os.environ.get("REPRO_GEMM_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _mkn(trans: str, a_shape, b_shape):
    if trans == "nn":
        (m, k), (_, n) = a_shape, b_shape
    elif trans == "tn":
        (k, m), (_, n) = a_shape, b_shape
    else:
        (m, k), (n, _) = a_shape, b_shape
    return m, k, n


def _run_planned(a: jax.Array, b: jax.Array, trans: str, out_dtype,
                 interpret: bool) -> jax.Array:
    m, k, n = _mkn(trans, a.shape, b.shape)
    in_bytes = jnp.dtype(a.dtype).itemsize
    out_bytes = jnp.dtype(out_dtype).itemsize
    plan = plan_gemm(m, k, n, in_bytes, out_bytes)
    return _ops.gemm(
        a, b, trans=trans, out_dtype=out_dtype, interpret=interpret,
        **plan.kernel_kwargs(),
    )


@functools.lru_cache(maxsize=None)
def _pallas_fn(trans: str, out_dtype_name: str, interpret: bool):
    """Build the custom-VJP'd Pallas matmul for one (trans, dtype) combo."""
    out_dtype = jnp.dtype(out_dtype_name)

    @jax.custom_vjp
    def f(a, b):
        return _run_planned(a, b, trans, out_dtype, interpret)

    def fwd(a, b):
        return f(a, b), (a, b)

    def bwd(res, g):
        a, b = res
        run = lambda x, y, t, dt: _run_planned(x, y, t, dt, interpret)  # noqa: E731
        if trans == "nn":          # y = a @ b
            da = run(g, b, "nt", a.dtype)
            db = run(a, g, "tn", b.dtype)   # T2: K = tokens >> M ~ N
        elif trans == "tn":        # y = a.T @ b, a: (K, M)
            da = run(b, g, "nt", a.dtype)   # (K,N)@(N,M) -> (K,M)
            db = run(a, g, "nn", b.dtype)   # (K,M)@(M,N) -> (K,N)
        else:                      # y = a @ b.T, b: (N, K)
            da = run(g, b, "nn", a.dtype)   # (M,N)@(N,K) -> (M,K)
            db = run(g, a, "tn", b.dtype)   # g.T @ a -> (N,K)
        return da, db

    f.defvjp(fwd, bwd)
    return f


def matmul(a: jax.Array, b: jax.Array, *, trans: str = "nn",
           out_dtype=None, backend: str | None = None) -> jax.Array:
    """2-D GEMM through the ftIMM planner. fp32 accumulation always."""
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    backend = backend or _backend()
    if backend == "xla":
        return _REF[trans](a, b, out_dtype)
    if backend == "pallas":
        return _pallas_fn(trans, out_dtype.name, False)(a, b)
    if backend == "pallas_interpret":
        return _pallas_fn(trans, out_dtype.name, True)(a, b)
    raise ValueError(f"unknown gemm backend: {backend}")


def _ref_batched(a: jax.Array, b: jax.Array, trans: str,
                 out_dtype) -> jax.Array:
    """XLA oracle for batched/grouped GEMM with fp32 accumulation.  Either
    operand may be 2-D (shared across the batch)."""
    al = "gmk" if a.ndim == 3 else "mk"
    bl = "gkn" if b.ndim == 3 else "kn"
    if trans == "tn":
        al = al.replace("mk", "km")
    elif trans == "nt":
        bl = bl.replace("kn", "nk")
    elif trans != "nn":
        raise ValueError(trans)
    out = jnp.einsum(f"{al},{bl}->gmn", a, b,
                     preferred_element_type=jnp.float32)
    return out.astype(out_dtype)


def _batched_mkns(trans: str, a: jax.Array, b: jax.Array):
    m, k, n = _mkn(trans, a.shape[-2:], b.shape[-2:])
    shared = "a" if a.ndim == 2 else ("b" if b.ndim == 2 else "none")
    g = b.shape[0] if shared == "a" else a.shape[0]
    return g, m, k, n, shared


def _run_planned_batched(a: jax.Array, b: jax.Array, trans: str, out_dtype,
                         backend: str) -> jax.Array:
    """Plan one batched/grouped GEMM and run it on the selected backend.

    The planner runs on EVERY backend (it is trace-time-only work and keeps
    the plan cache an accurate census of the workload's irregular shapes);
    only the execution engine differs: XLA dot_general on CPU, the batched
    Pallas kernel on TPU / in interpret mode."""
    g, m, k, n, shared = _batched_mkns(trans, a, b)
    in_bytes = jnp.dtype(a.dtype).itemsize
    out_bytes = jnp.dtype(out_dtype).itemsize
    plan = plan_batched_gemm(g, m, k, n, in_bytes, out_bytes, shared)
    if backend == "xla":
        return _ref_batched(a, b, trans, out_dtype)
    return _ops.batched_gemm(
        a, b, bm=plan.bm, bn=plan.bn, bk=plan.bk, dim_order=plan.dim_order,
        trans=trans, out_dtype=out_dtype,
        interpret=(backend == "pallas_interpret"),
    )


@functools.lru_cache(maxsize=None)
def _batched_fn(trans: str, out_dtype_name: str, backend: str):
    """Custom-VJP'd batched matmul for one (trans, dtype, backend) combo.

    Both backward GEMMs are themselves planned batched GEMMs: for the
    grouped MoE forward (E, C, D) @ (E, D, F), dW = x^T dy contracts the
    capacity dim — the paper's T2 shape per expert — and dx is the N<=128
    "nt" GEMM; routing them through ``_run_planned_batched`` is what makes
    the backward pass see the CMR tuner at all."""
    out_dtype = jnp.dtype(out_dtype_name)

    @jax.custom_vjp
    def f(a, b):
        return _run_planned_batched(a, b, trans, out_dtype, backend)

    def fwd(a, b):
        return f(a, b), (a, b)

    def bwd(res, g):
        a, b = res
        run = lambda x, y, t, dt: _run_planned_batched(  # noqa: E731
            x, y, t, dt, backend)
        if trans == "nn":          # y_g = a_g @ b_g
            da = run(g, b, "nt", a.dtype)
            if b.ndim == 2:
                # Shared weight: dW = sum_g x_g^T dy_g == ONE flat T2 GEMM
                # over all G*M rows — no (G, K, N) intermediate.
                return da, matmul(
                    a.reshape(-1, a.shape[-1]), g.reshape(-1, g.shape[-1]),
                    trans="tn", out_dtype=b.dtype, backend=backend)
            db = run(a, g, "tn", b.dtype)   # T2 per group: K = capacity
        elif trans == "tn":        # y_g = a_g.T @ b_g, a: (G, K, M)
            da = run(b, g, "nt", a.dtype)
            db = run(a, g, "nn", b.dtype)
        else:                      # y_g = a_g @ b_g.T, b: (G, N, K)
            da = run(g, b, "nn", a.dtype)
            db = run(g, a, "tn", b.dtype)
        if a.ndim == 2:            # shared a: gradients sum over the batch
            da = jnp.sum(da, axis=0).astype(a.dtype)
        if b.ndim == 2:
            db = jnp.sum(db, axis=0).astype(b.dtype)
        return da, db

    f.defvjp(fwd, bwd)
    return f


def batched_matmul(a: jax.Array, b: jax.Array, *, trans: str = "nn",
                   out_dtype=None, backend: str | None = None) -> jax.Array:
    """Batched GEMM (G, M, K) @ (G, K, N) -> (G, M, N) through the ftIMM
    planner; fp32 accumulation always.  Either operand may be 2-D (shared
    across the batch).  The attention BMMs flatten their (batch, kv-head)
    dims into G and route here instead of raw einsum."""
    assert a.ndim == 3 or b.ndim == 3, (a.shape, b.shape)
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    backend = backend or _backend()
    if backend not in ("xla", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown gemm backend: {backend}")
    return _batched_fn(trans, out_dtype.name, backend)(a, b)


def grouped_matmul(x: jax.Array, w: jax.Array, *, trans: str = "nn",
                   out_dtype=None, backend: str | None = None) -> jax.Array:
    """Grouped GEMM: per-group panels where one operand may be shared —
    the MoE expert projections (E, C, D) @ (E, D, F) -> (E, C, F).  Same
    engine as ``batched_matmul``; kept as a distinct entry point so call
    sites read as what they are (experts, not batches)."""
    return batched_matmul(x, w, trans=trans, out_dtype=out_dtype,
                          backend=backend)


def project(x: jax.Array, w: jax.Array, *, out_dtype=None,
            backend: str | None = None) -> jax.Array:
    """(..., D) @ (D, N) -> (..., N): flattens leading dims into the paper's
    M dimension (tokens — typically the tall axis of T1/T3)."""
    lead = x.shape[:-1]
    m = 1
    for s in lead:
        m *= s
    y = matmul(x.reshape(m, x.shape[-1]), w, out_dtype=out_dtype,
               backend=backend)
    return y.reshape(*lead, w.shape[-1])
