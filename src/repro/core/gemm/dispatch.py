"""Framework-wide GEMM entry point.

Every dense contraction in the model stack routes through ``matmul`` /
``project``: the shape is classified (paper §III-A), the CMR tuner picks the
strategy + blocks (paper §IV-C), and the call dispatches to

  * the specialized Pallas ftIMM kernel on TPU (or in interpret mode when
    explicitly requested, e.g. kernel tests), wrapped in a custom VJP whose
    backward GEMMs are themselves ftIMM-planned — dW = x.T @ dy is the
    paper's T2 shape and gets the K-oriented treatment automatically;
  * an XLA ``dot_general`` path on CPU (used by the multi-pod dry-run so
    ``cost_analysis`` reflects the true FLOPs/bytes) with identical
    fp32-accumulation semantics.

Backend selection: ``REPRO_GEMM_BACKEND`` env var ("pallas" | "xla" |
"pallas_interpret"), else pallas on TPU and xla elsewhere.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import warnings

import jax
import jax.numpy as jnp

from ...analysis import contracts as _contracts
from ...kernels.ftimm import ops as _ops
from ...kernels.ftimm import ref as _ref
from ...kernels.ftimm.epilogue import IDENTITY, Epilogue
from ...runtime import chaos as _chaos
from .. import quant as _quant
from .tuner import (note_degraded, note_epilogue, note_plan_use,
                    plan_batched_gemm, plan_gemm, plan_ragged_gemm)

_REF = {"nn": _ref.matmul_nn, "tn": _ref.matmul_tn, "nt": _ref.matmul_nt}


# ---------------------------------------------------------------------------
# Dispatch fallback ladder: when a kernel fails (a real launch/trace error
# or a chaos-injected one), the call degrades one rung instead of taking
# the request down — pallas -> the XLA oracle with identical fp32-
# accumulation semantics, fused epilogue -> the unfused two-pass spelling.
# Every degraded serving is counted in ``tuner.plan_mode_stats()`` and the
# first occurrence of each rung is logged once.
# ---------------------------------------------------------------------------

_WARNED_RUNGS: set = set()


def _degraded(family: str, rung: str, err: BaseException) -> None:
    """Count one fallback-ladder serving and log the rung's first use."""
    note_degraded(family, rung)
    key = (family, rung)
    if key not in _WARNED_RUNGS:
        _WARNED_RUNGS.add(key)
        warnings.warn(
            f"gemm dispatch degraded: {family} {rung} "
            f"({type(err).__name__}: {err})", RuntimeWarning, stacklevel=3)


def _wide(x: jax.Array) -> jax.Array:
    """Upcast narrow-int (quantized) operands for the XLA oracle rungs —
    values are identical by construction, only the engine changes."""
    return x.astype(jnp.float32) if jnp.dtype(x.dtype).itemsize == 1 else x


def _xla_dense(a: jax.Array, b: jax.Array, trans: str, out_dtype,
               epi: Epilogue = IDENTITY, bias=None, residual=None,
               scale=None) -> jax.Array:
    """The dense XLA oracle rung: fp32-accumulating dot + the epilogue tail
    applied in the same jit (numerically the unfused planned path)."""
    if epi.is_identity:
        return _REF[trans](_wide(a), _wide(b), out_dtype)
    z = _REF[trans](_wide(a), _wide(b), jnp.float32)
    return epi.apply(z, bias=bias, residual=residual,
                     scale=scale).astype(out_dtype)


def _check_epi(epi: Epilogue, bias, residual, scale=None) -> None:
    if epi.bias != (bias is not None):
        raise ValueError(
            f"epilogue.bias={epi.bias} but bias operand "
            f"{'missing' if bias is None else 'given'}")
    if epi.residual != (residual is not None):
        raise ValueError(
            f"epilogue.residual={epi.residual} but residual operand "
            f"{'missing' if residual is None else 'given'}")
    if epi.scale_vec != (scale is not None):
        raise ValueError(
            f"epilogue.scale_vec={epi.scale_vec} but scale operand "
            f"{'missing' if scale is None else 'given'}")


def _backend() -> str:
    env = os.environ.get("REPRO_GEMM_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _verify_enabled() -> bool:
    return os.environ.get("REPRO_VERIFY", "") not in ("", "0")


@functools.lru_cache(maxsize=4096)
def _verify_cached(family: str, dims: tuple, plan, in_bytes: int,
                   out_bytes: int, epi, swiglu: bool, ragged: str,
                   trans: str, b_bytes: int | None = None) -> bool:
    _contracts.assert_plan(family, dims, plan, in_bytes=in_bytes,
                           out_bytes=out_bytes, epilogue=epi, swiglu=swiglu,
                           ragged=ragged, trans=trans, b_bytes=b_bytes,
                           coverage=family in ("dense", "batched"))
    return True


def _verify(family: str, dims, plan, in_bytes: int, out_bytes: int, *,
            epi=None, swiglu: bool = False, ragged: str = "m",
            trans: str = "nn", b_bytes: int | None = None) -> None:
    """``REPRO_VERIFY=1`` mode: assert the static kernel contracts
    (``analysis.contracts.check_plan`` incl. the symbolic store-coverage
    proof) on every planned call, raising ``analysis.ContractError`` before
    any kernel is launched.  Trace-time only; results are memoized per
    (shape, plan) so steady-state dispatch cost is one env read."""
    if _verify_enabled():
        _verify_cached(family, tuple(int(d) for d in dims), plan,
                       int(in_bytes), int(out_bytes), epi, swiglu, ragged,
                       trans, None if b_bytes is None else int(b_bytes))


def _check_vectors(family: str, dims, epi: Epilogue, bias, scale) -> None:
    """Raise ``ContractError`` on a malformed flush-vector operand (wrong N,
    neither shared (N,) nor per-expert (G, N)) — always on, trace-time."""
    if bias is None and scale is None:
        return
    bad = _contracts.errors(_contracts.check_epilogue_vectors(
        family, dims, epi,
        bias_shape=None if bias is None else bias.shape,
        scale_shape=None if scale is None else scale.shape))
    if bad:
        raise _contracts.ContractError(bad,
                                       context=f"{family}{tuple(dims)}")


def _b_bytes(a: jax.Array, b: jax.Array) -> int | None:
    """The planners' dtype-axis key: B's element width when it differs from
    A's (the weight-only mixed paths), else None (homogeneous — legacy
    keys)."""
    bb = jnp.dtype(b.dtype).itemsize
    return None if bb == jnp.dtype(a.dtype).itemsize else bb


def _mkn(trans: str, a_shape, b_shape):
    if trans == "nn":
        (m, k), (_, n) = a_shape, b_shape
    elif trans == "tn":
        (k, m), (_, n) = a_shape, b_shape
    else:
        (m, k), (n, _) = a_shape, b_shape
    return m, k, n


def _run_planned(a: jax.Array, b: jax.Array, trans: str, out_dtype,
                 interpret: bool, epi: Epilogue = IDENTITY,
                 bias=None, residual=None, scale=None) -> jax.Array:
    m, k, n = _mkn(trans, a.shape, b.shape)
    in_bytes = jnp.dtype(a.dtype).itemsize
    out_bytes = jnp.dtype(out_dtype).itemsize
    bb = _b_bytes(a, b)
    plan = plan_gemm(m, k, n, in_bytes, out_bytes, epi_ops=epi.num_ops,
                     b_bytes=bb)
    _verify("dense", (m, k, n), plan, in_bytes, out_bytes, epi=epi,
            trans=trans, b_bytes=bb)
    note_plan_use("dense", plan)
    if epi.is_identity:
        try:
            _chaos.fire("kernel")
            return _ops.gemm(
                a, b, trans=trans, out_dtype=out_dtype, interpret=interpret,
                **plan.kernel_kwargs(),
            )
        except Exception as e:
            _degraded("dense", "pallas->xla", e)
            return _xla_dense(a, b, trans, out_dtype)
    note_epilogue("dense", plan.fuse)
    if plan.fuse:
        try:
            _chaos.fire("kernel_fused")
            return _ops.gemm(
                a, b, trans=trans, out_dtype=out_dtype, interpret=interpret,
                epilogue=epi, bias=bias, residual=residual, scale=scale,
                **plan.kernel_kwargs(),
            )
        except Exception as e:
            # Fused kernel failed: next rung is the unfused spelling below
            # (identity kernel + separate tail), NOT straight to XLA.
            _degraded("dense", "fused->unfused", e)
    # The plan declined fusion (a measured winner can) or the fused kernel
    # just failed: identity kernel + the tail as its own pass, exactly what
    # the tuner priced.
    try:
        _chaos.fire("kernel")
        z = _ops.gemm(a, b, trans=trans, out_dtype=jnp.float32,
                      interpret=interpret, **plan.kernel_kwargs())
    except Exception as e:
        _degraded("dense", "pallas->xla", e)
        return _xla_dense(a, b, trans, out_dtype, epi, bias, residual, scale)
    return epi.apply(z, bias=bias, residual=residual,
                     scale=scale).astype(out_dtype)


@functools.lru_cache(maxsize=None)
def _pallas_fn(trans: str, out_dtype_name: str, interpret: bool,
               epi: Epilogue = IDENTITY):
    """Build the custom-VJP'd Pallas matmul for one (trans, dtype, epilogue)
    combo.  ``extras`` is the tuple of present epilogue operands (bias,
    residual and/or scale vector, in that order) so the custom_vjp signature
    stays fixed per spec.  The backward rematerializes the pre-epilogue fp32
    GEMM (the same remat the ragged SwiGLU backward does), pulls the
    elementwise tail's cotangents out with ``jax.vjp`` (exact for every
    activation), and runs the two planned backward GEMMs on the
    pre-activation cotangent."""
    out_dtype = jnp.dtype(out_dtype_name)

    @jax.custom_vjp
    def f(a, b, extras):
        bias, residual, scale = epi.unpack(extras)
        return _run_planned(a, b, trans, out_dtype, interpret, epi,
                            bias, residual, scale)

    def fwd(a, b, extras):
        return f(a, b, extras), (a, b, extras)

    def bwd(res, g):
        a, b, extras = res
        run = lambda x, y, t, dt: _run_planned(x, y, t, dt, interpret)  # noqa: E731
        if epi.is_identity:
            dz, d_extras = g, ()
        else:
            z = run(a, b, trans, jnp.float32)       # remat pre-activation

            def epi_fn(z_, *extras_):
                bias_, residual_, scale_ = epi.unpack(extras_)
                return epi.apply(z_, bias=bias_, residual=residual_,
                                 scale=scale_)

            _, epi_vjp = jax.vjp(epi_fn, z, *extras)
            grads = epi_vjp(g.astype(jnp.float32))
            dz = grads[0].astype(a.dtype)
            d_extras = tuple(d.astype(x.dtype)
                             for d, x in zip(grads[1:], extras))
        if trans == "nn":          # y = a @ b
            da = run(dz, b, "nt", a.dtype)
            db = run(a, dz, "tn", b.dtype)  # T2: K = tokens >> M ~ N
        elif trans == "tn":        # y = a.T @ b, a: (K, M)
            da = run(b, dz, "nt", a.dtype)  # (K,N)@(N,M) -> (K,M)
            db = run(a, dz, "nn", b.dtype)  # (K,M)@(M,N) -> (K,N)
        else:                      # y = a @ b.T, b: (N, K)
            da = run(dz, b, "nn", a.dtype)  # (M,N)@(N,K) -> (M,K)
            db = run(dz, a, "tn", b.dtype)  # g.T @ a -> (N,K)
        return da, db, d_extras

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def _quant_fn(qcfg: "_quant.QuantConfig", trans: str, out_dtype_name: str,
              backend: str, epi: Epilogue = IDENTITY):
    """Custom-VJP'd quantized dense matmul for one (quant config, dtype,
    backend, epilogue) combo — the managed ``matmul(..., quant=)`` engine.

    Forward quantizes IN-TRACE (weights per channel, activations per tensor
    for the dynamic modes — under jit with frozen weights the weight
    quantization constant-folds) and runs the narrow-/mixed-dtype planned
    GEMM with the combined dequant vector fused at the accumulator flush
    (``scale_vec``), then the caller's epilogue tail.  Serving paths that
    want zero per-call quantization cost pre-quantize with
    ``core.quant.quantize_weights`` and call ``matmul`` with int8 weights +
    an ``epilogue.scale_vec`` spec directly.

    Backward is straight-through against the DEQUANTIZED weights: d_a is the
    planned "nt" product of the (per-channel-rescaled) cotangent against the
    int8 panel — algebraically dz @ dequant(W).T — and d_b is the
    full-precision T2 product, so quantization noise perturbs the forward
    values, never the gradient estimator."""
    out_dtype = jnp.dtype(out_dtype_name)
    interpret = backend == "pallas_interpret"
    qepi = dataclasses.replace(epi, scale_vec=True)

    def quantize_operands(a, b):
        """(a_run, w_q, w_scale, combined_flush_scale)."""
        w_q, w_scale = _quant.quantize_weights(b, qcfg)
        if qcfg.mode == "w4":
            # Round-trip the nibble packing: the kernel consumes int8, but
            # values must be exactly what the packed storage format holds.
            w_q = _quant.unpack_int4(_quant.pack_int4(w_q))
        if qcfg.weight_only:
            return a, w_q, w_scale, w_scale
        a_q, a_scale = _quant.quantize_activations(a, qcfg)
        return a_q, w_q, w_scale, w_scale * a_scale

    def gemm32(x, y, t):
        """Planned fp32-out product that tolerates narrow/mixed operands on
        every backend (the XLA engine upcasts explicitly)."""
        if backend == "xla":
            return _REF[t](x.astype(jnp.float32), y.astype(jnp.float32),
                           jnp.float32)
        return _run_planned(x, y, t, jnp.float32, interpret)

    @jax.custom_vjp
    def f(a, b, extras):
        bias, residual, _ = epi.unpack(extras)
        a_q, w_q, _w_scale, sv = quantize_operands(a, b)
        if backend == "xla":
            m, k, n = _mkn(trans, a.shape, b.shape)
            in_bytes = jnp.dtype(a_q.dtype).itemsize
            plan = plan_gemm(m, k, n, in_bytes, out_dtype.itemsize,
                             epi_ops=qepi.num_ops,
                             b_bytes=_b_bytes(a_q, w_q))
            note_plan_use("dense", plan)
            note_epilogue("dense", True)
            z = _REF[trans](a_q.astype(jnp.float32),
                            w_q.astype(jnp.float32), jnp.float32)
            return qepi.apply(z, bias=bias, residual=residual,
                              scale=sv).astype(out_dtype)
        return _run_planned(a_q, w_q, trans, out_dtype, interpret, qepi,
                            bias, residual, sv)

    def fwd(a, b, extras):
        return f(a, b, extras), (a, b, extras)

    def bwd(res, g):
        a, b, extras = res
        a_q, w_q, w_scale, sv = quantize_operands(a, b)
        if epi.is_identity:
            dz, d_extras = g.astype(jnp.float32), ()
        else:
            # Remat the pre-tail value the forward produced (dequantized
            # GEMM output) and pull the tail's cotangents out exactly.
            z = gemm32(a_q, w_q, trans) * sv.astype(jnp.float32)

            def epi_fn(z_, *extras_):
                bias_, residual_, _ = epi.unpack(extras_)
                return epi.apply(z_, bias=bias_, residual=residual_)

            _, epi_vjp = jax.vjp(epi_fn, z, *extras)
            grads = epi_vjp(g.astype(jnp.float32))
            dz = grads[0]
            d_extras = tuple(d.astype(x.dtype)
                             for d, x in zip(grads[1:], extras))
        # dz @ dequant(W).T == (dz * w_scale) @ W_q.T — the per-channel
        # scale folds into the cotangent's columns, so the backward GEMM
        # streams the narrow panel too.
        da = gemm32((dz * w_scale.astype(jnp.float32)).astype(a.dtype),
                    w_q, "nt").astype(a.dtype)
        db = gemm32(a, dz.astype(a.dtype), "tn").astype(b.dtype)
        return da, db, d_extras

    f.defvjp(fwd, bwd)
    return f


def matmul(a: jax.Array, b: jax.Array, *, trans: str = "nn",
           out_dtype=None, backend: str | None = None,
           epilogue: Epilogue | None = None,
           bias: jax.Array | None = None,
           residual: jax.Array | None = None,
           scale: jax.Array | None = None,
           quant: "_quant.QuantConfig | str | None" = None) -> jax.Array:
    """2-D GEMM through the ftIMM planner. fp32 accumulation always.

    ``epilogue`` fuses the elementwise tail (bias add / activation /
    residual add / scale, ``kernels.ftimm.Epilogue``) into the accumulator
    flush on the Pallas path — and into the same jit on the XLA fallback, so
    CPU/TPU stay comparable — instead of separate XLA passes over the stored
    output.  ``bias`` is (N,), ``residual`` (M, N); both differentiable.

    ``scale`` is the (N,)-wide fp32 dequant vector of a
    ``epilogue.scale_vec`` spec — the manual spelling for callers holding
    PRE-quantized operands (int8/fp8 ``a``/``b`` from
    ``core.quant.quantize_weights``): the raw (integer) accumulator is
    multiplied by it at the flush.  ``quant`` is the managed spelling: a
    ``core.quant.QuantConfig`` (or mode string — "w8" / "w4" / "int8" /
    "fp8_e4m3" / "fp8_e5m2") quantizing full-precision operands in-trace and
    wrapping the whole thing in a straight-through custom VJP (backward runs
    bf16/fp32 against the dequantized weights)."""
    epi = IDENTITY if epilogue is None else epilogue
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    backend = backend or _backend()
    if backend not in ("xla", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown gemm backend: {backend}")
    qcfg = _quant.resolve(quant)
    if not qcfg.is_noop:
        if trans != "nn":
            raise ValueError("quantized matmul is defined for trans='nn' "
                             f"only (got trans={trans!r})")
        if epi.scale_vec or scale is not None:
            raise ValueError(
                "quant= derives its own dequant scale; for manual control "
                "pass pre-quantized operands with epilogue.scale_vec "
                "instead")
        _check_epi(epi, bias, residual)
        _check_vectors("dense", _mkn(trans, a.shape, b.shape), epi, bias,
                       None)
        extras = tuple(x for x in (bias, residual) if x is not None)
        return _quant_fn(qcfg, trans, out_dtype.name, backend,
                         epi)(a, b, extras)
    _check_epi(epi, bias, residual, scale)
    _check_vectors("dense", _mkn(trans, a.shape, b.shape), epi, bias, scale)
    if backend == "xla":
        # Plan even though XLA ignores the blocks: keeps the plan cache an
        # accurate census of the workload's shapes (as the batched/ragged
        # paths already do) and the mode telemetry complete.
        m, k, n = _mkn(trans, a.shape, b.shape)
        in_bytes = jnp.dtype(a.dtype).itemsize
        bb = _b_bytes(a, b)
        plan = plan_gemm(m, k, n, in_bytes, out_dtype.itemsize,
                         epi_ops=epi.num_ops, b_bytes=bb)
        _verify("dense", (m, k, n), plan, in_bytes, out_dtype.itemsize,
                epi=epi, trans=trans, b_bytes=bb)
        note_plan_use("dense", plan)
        if epi.is_identity:
            return _REF[trans](a, b, out_dtype)
        note_epilogue("dense", True)    # one jit: XLA fuses the tail
        z = _REF[trans](a, b, jnp.float32)
        return epi.apply(z, bias=bias, residual=residual,
                         scale=scale).astype(out_dtype)
    extras = tuple(x for x in (bias, residual, scale) if x is not None)
    return _pallas_fn(trans, out_dtype.name,
                      backend == "pallas_interpret", epi)(a, b, extras)


def _ref_batched(a: jax.Array, b: jax.Array, trans: str,
                 out_dtype) -> jax.Array:
    """XLA oracle for batched/grouped GEMM with fp32 accumulation.  Either
    operand may be 2-D (shared across the batch)."""
    al = "gmk" if a.ndim == 3 else "mk"
    bl = "gkn" if b.ndim == 3 else "kn"
    if trans == "tn":
        al = al.replace("mk", "km")
    elif trans == "nt":
        bl = bl.replace("kn", "nk")
    elif trans != "nn":
        raise ValueError(trans)
    out = jnp.einsum(f"{al},{bl}->gmn", a, b,
                     preferred_element_type=jnp.float32)
    return out.astype(out_dtype)


def _batched_mkns(trans: str, a: jax.Array, b: jax.Array):
    m, k, n = _mkn(trans, a.shape[-2:], b.shape[-2:])
    shared = "a" if a.ndim == 2 else ("b" if b.ndim == 2 else "none")
    g = b.shape[0] if shared == "a" else a.shape[0]
    return g, m, k, n, shared


def _run_planned_batched(a: jax.Array, b: jax.Array, trans: str, out_dtype,
                         backend: str) -> jax.Array:
    """Plan one batched/grouped GEMM and run it on the selected backend.

    The planner runs on EVERY backend (it is trace-time-only work and keeps
    the plan cache an accurate census of the workload's irregular shapes);
    only the execution engine differs: XLA dot_general on CPU, the batched
    Pallas kernel on TPU / in interpret mode."""
    g, m, k, n, shared = _batched_mkns(trans, a, b)
    in_bytes = jnp.dtype(a.dtype).itemsize
    out_bytes = jnp.dtype(out_dtype).itemsize
    plan = plan_batched_gemm(g, m, k, n, in_bytes, out_bytes, shared)
    _verify("batched", (g, m, k, n), plan, in_bytes, out_bytes, trans=trans)
    note_plan_use("batched", plan)
    if backend == "xla":
        return _ref_batched(a, b, trans, out_dtype)
    try:
        _chaos.fire("kernel")
        return _ops.batched_gemm(
            a, b, bm=plan.bm, bn=plan.bn, bk=plan.bk,
            dim_order=plan.dim_order, trans=trans, out_dtype=out_dtype,
            edge=plan.edge, interpret=(backend == "pallas_interpret"),
        )
    except Exception as e:
        _degraded("batched", "pallas->xla", e)
        return _ref_batched(_wide(a), _wide(b), trans, out_dtype)


@functools.lru_cache(maxsize=None)
def _batched_fn(trans: str, out_dtype_name: str, backend: str):
    """Custom-VJP'd batched matmul for one (trans, dtype, backend) combo.

    Both backward GEMMs are themselves planned batched GEMMs: for the
    grouped MoE forward (E, C, D) @ (E, D, F), dW = x^T dy contracts the
    capacity dim — the paper's T2 shape per expert — and dx is the N<=128
    "nt" GEMM; routing them through ``_run_planned_batched`` is what makes
    the backward pass see the CMR tuner at all."""
    out_dtype = jnp.dtype(out_dtype_name)

    @jax.custom_vjp
    def f(a, b):
        return _run_planned_batched(a, b, trans, out_dtype, backend)

    def fwd(a, b):
        return f(a, b), (a, b)

    def bwd(res, g):
        a, b = res
        run = lambda x, y, t, dt: _run_planned_batched(  # noqa: E731
            x, y, t, dt, backend)
        if trans == "nn":          # y_g = a_g @ b_g
            da = run(g, b, "nt", a.dtype)
            if b.ndim == 2:
                # Shared weight: dW = sum_g x_g^T dy_g == ONE flat T2 GEMM
                # over all G*M rows — no (G, K, N) intermediate.
                return da, matmul(
                    a.reshape(-1, a.shape[-1]), g.reshape(-1, g.shape[-1]),
                    trans="tn", out_dtype=b.dtype, backend=backend)
            db = run(a, g, "tn", b.dtype)   # T2 per group: K = capacity
        elif trans == "tn":        # y_g = a_g.T @ b_g, a: (G, K, M)
            da = run(b, g, "nt", a.dtype)
            db = run(a, g, "nn", b.dtype)
        else:                      # y_g = a_g @ b_g.T, b: (G, N, K)
            da = run(g, b, "nn", a.dtype)
            db = run(g, a, "tn", b.dtype)
        if a.ndim == 2:            # shared a: gradients sum over the batch
            da = jnp.sum(da, axis=0).astype(a.dtype)
        if b.ndim == 2:
            db = jnp.sum(db, axis=0).astype(b.dtype)
        return da, db

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def _batched_bias_fn(out_dtype_name: str, backend: str):
    """Custom-VJP'd batched matmul ("nn" only) with the bias epilogue: bias
    is shared (N,) or per-group (G, N), added at each group's accumulator
    flush.  d_bias sums the cotangent over the fused dims (batch + rows for
    shared, rows for per-group)."""
    out_dtype = jnp.dtype(out_dtype_name)
    epi = Epilogue(bias=True)

    @jax.custom_vjp
    def f(a, b, bias):
        g, m, k, n, shared = _batched_mkns("nn", a, b)
        in_bytes = jnp.dtype(a.dtype).itemsize
        plan = plan_batched_gemm(g, m, k, n, in_bytes, out_dtype.itemsize,
                                 shared, epi_ops=epi.num_ops)
        _verify("batched", (g, m, k, n), plan, in_bytes, out_dtype.itemsize,
                epi=epi)
        note_plan_use("batched", plan)
        if backend == "xla":
            note_epilogue("batched", True)  # one jit: XLA fuses the tail
            z = _ref_batched(a, b, "nn", jnp.float32)
            bb = bias if bias.ndim == 1 else bias[:, None, :]
            return epi.apply(z, bias=bb).astype(out_dtype)
        note_epilogue("batched", plan.fuse)
        if plan.fuse:
            return _ops.batched_gemm(
                a, b, bm=plan.bm, bn=plan.bn, bk=plan.bk,
                dim_order=plan.dim_order, trans="nn", out_dtype=out_dtype,
                edge=plan.edge, interpret=(backend == "pallas_interpret"),
                epilogue=epi, bias=bias)
        z = _run_planned_batched(a, b, "nn", jnp.float32, backend)
        bb = bias if bias.ndim == 1 else bias[:, None, :]
        return epi.apply(z, bias=bb).astype(out_dtype)

    def fwd(a, b, bias):
        return f(a, b, bias), (a, b, bias)

    def bwd(res, g):
        a, b, bias = res
        run = lambda x, y, t, dt: _run_planned_batched(  # noqa: E731
            x, y, t, dt, backend)
        da = run(g, b, "nt", a.dtype)
        if a.ndim == 2:
            da = jnp.sum(da, axis=0).astype(a.dtype)
        if b.ndim == 2:
            # Shared weight: ONE flat T2 GEMM over all G*M rows.
            db = matmul(a.reshape(-1, a.shape[-1]), g.reshape(-1, g.shape[-1]),
                        trans="tn", out_dtype=b.dtype, backend=backend)
        else:
            db = run(a, g, "tn", b.dtype)
        g32 = g.astype(jnp.float32)
        dbias = (g32.sum(axis=(0, 1)) if bias.ndim == 1
                 else g32.sum(axis=1)).astype(bias.dtype)
        return da, db, dbias

    f.defvjp(fwd, bwd)
    return f


def batched_matmul(a: jax.Array, b: jax.Array, *, trans: str = "nn",
                   out_dtype=None, backend: str | None = None,
                   bias: jax.Array | None = None) -> jax.Array:
    """Batched GEMM (G, M, K) @ (G, K, N) -> (G, M, N) through the ftIMM
    planner; fp32 accumulation always.  Either operand may be 2-D (shared
    across the batch).  The attention BMMs flatten their (batch, kv-head)
    dims into G and route here instead of raw einsum.

    ``bias`` — (N,) shared or (G, N) per-group, added at the accumulator
    flush (trans="nn" only); fully differentiable."""
    assert a.ndim == 3 or b.ndim == 3, (a.shape, b.shape)
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    backend = backend or _backend()
    if backend not in ("xla", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown gemm backend: {backend}")
    if bias is not None:
        if trans != "nn":
            raise ValueError("batched bias epilogue is defined for "
                             f"trans='nn' only (got trans={trans!r})")
        g, m, k, n, _ = _batched_mkns(trans, a, b)
        _check_vectors("batched", (g, m, k, n), Epilogue(bias=True), bias,
                       None)
        return _batched_bias_fn(out_dtype.name, backend)(a, b, bias)
    return _batched_fn(trans, out_dtype.name, backend)(a, b)


def grouped_matmul(x: jax.Array, w: jax.Array, *, trans: str = "nn",
                   out_dtype=None, backend: str | None = None) -> jax.Array:
    """Grouped GEMM: per-group panels where one operand may be shared —
    the MoE expert projections (E, C, D) @ (E, D, F) -> (E, C, F).  Same
    engine as ``batched_matmul``; kept as a distinct entry point so call
    sites read as what they are (experts, not batches)."""
    return batched_matmul(x, w, trans=trans, out_dtype=out_dtype,
                          backend=backend)


# ---------------------------------------------------------------------------
# Fused dense / grouped SwiGLU pairs — one kernel launch for gate + up +
# silu(gate)*up, mirroring the ragged ragged_swiglu entry point.
# ---------------------------------------------------------------------------

def _swiglu_bwd_products(run, x, wg, wu, a, b, g):
    """Shared SwiGLU backward: given the rematerialized fp32 pre-activations
    ``a = x@Wg`` / ``b = x@Wu`` and the output cotangent ``g``, produce
    (dx, dwg, dwu) with every GEMM planned through ``run(x, y, trans,
    out_dtype)``."""
    sg = jax.nn.sigmoid(a)
    g32 = g.astype(jnp.float32)
    da = (g32 * b * sg * (1.0 + a * (1.0 - sg))).astype(x.dtype)
    db = (g32 * a * sg).astype(x.dtype)
    dx = (run(da, wg, "nt", jnp.float32)
          + run(db, wu, "nt", jnp.float32)).astype(x.dtype)
    dwg = run(x, da, "tn", wg.dtype)
    dwu = run(x, db, "tn", wu.dtype)
    return dx, dwg, dwu


def _make_swiglu_fn(out_dtype, backend: str, family: str, plan_fn, run_fn,
                    fused_kernel):
    """Shared custom-VJP scaffolding for the fused SwiGLU pairs.

    ``plan_fn(x, wg)`` plans + records telemetry, ``run_fn(p, q, trans,
    out_dtype)`` is the family's planned GEMM for the unfused forward and
    every backward product, ``fused_kernel(x, wg, wu, plan)`` the
    one-launch forward.  Backward rematerializes the two fp32
    pre-activations (the usual fused-epilogue remat — exactly like the
    ragged SwiGLU backward), then two planned "nt" dX products and two
    planned T2 dW products."""

    @jax.custom_vjp
    def f(x, wg, wu):
        plan = plan_fn(x, wg)
        fused = backend != "xla" and plan.fuse
        note_epilogue(family, backend == "xla" or plan.fuse)
        if fused:
            try:
                _chaos.fire("kernel_fused")
                return fused_kernel(x, wg, wu, plan)
            except Exception as e:
                # Ladder rung: the one-launch SwiGLU kernel failed — fall
                # back to the two planned GEMMs + elementwise tail (whose
                # own pallas->xla rung guards the panels' kernels).
                _degraded(family, "fused->unfused", e)
        a = run_fn(x, wg, "nn", jnp.float32)
        b = run_fn(x, wu, "nn", jnp.float32)
        return (jax.nn.silu(a) * b).astype(out_dtype)

    def fwd(x, wg, wu):
        return f(x, wg, wu), (x, wg, wu)

    def bwd(res, g):
        x, wg, wu = res
        a = run_fn(x, wg, "nn", jnp.float32)
        b = run_fn(x, wu, "nn", jnp.float32)
        return _swiglu_bwd_products(run_fn, x, wg, wu, a, b, g)

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def _swiglu_fn(out_dtype_name: str, backend: str):
    """Custom-VJP'd dense fused SwiGLU pair (one kernel launch forward)."""
    out_dtype = jnp.dtype(out_dtype_name)
    interp = backend == "pallas_interpret"
    if backend == "xla":
        run = lambda p, q, t, dt: _REF[t](p, q, dt)  # noqa: E731
    else:
        run = lambda p, q, t, dt: _run_planned(  # noqa: E731
            p, q, t, dt, interp)

    def plan_fn(x, wg):
        plan = plan_gemm(x.shape[0], x.shape[1], wg.shape[1],
                         jnp.dtype(x.dtype).itemsize, out_dtype.itemsize,
                         epi_ops=2)
        _verify("dense", (x.shape[0], x.shape[1], wg.shape[1]), plan,
                jnp.dtype(x.dtype).itemsize, out_dtype.itemsize, swiglu=True)
        note_plan_use("dense", plan)
        return plan

    def fused_kernel(x, wg, wu, plan):
        return _ops.gemm_swiglu(
            x, wg, wu, bm=plan.bm, bn=plan.bn, bk=plan.bk, edge=plan.edge,
            out_dtype=out_dtype, interpret=interp)

    return _make_swiglu_fn(out_dtype, backend, "dense", plan_fn, run,
                           fused_kernel)


def matmul_swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, *,
                  out_dtype=None, backend: str | None = None) -> jax.Array:
    """Dense fused MLP front half: silu(x @ Wg) * (x @ Wu) in ONE kernel
    launch — x streamed once against both panels, the SwiGLU nonlinearity
    applied at the fp32 accumulator flush.  ``x`` (M, K), panels (K, N)."""
    assert x.ndim == 2 and w_gate.ndim == 2, (x.shape, w_gate.shape)
    assert w_gate.shape == w_up.shape, (w_gate.shape, w_up.shape)
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    backend = backend or _backend()
    if backend not in ("xla", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown gemm backend: {backend}")
    return _swiglu_fn(out_dtype.name, backend)(x, w_gate, w_up)


@functools.lru_cache(maxsize=None)
def _grouped_swiglu_fn(out_dtype_name: str, backend: str):
    """Custom-VJP'd grouped fused SwiGLU pair — the capacity-mode MoE
    gate/up projections (E, C, D) @ (E, D, F) as one launch.  Backward uses
    the planned batched products (dX "nt", dW the per-group T2)."""
    out_dtype = jnp.dtype(out_dtype_name)
    run = lambda p, q, t, dt: _run_planned_batched(  # noqa: E731
        p, q, t, dt, backend)

    def plan_fn(x, wg):
        plan = plan_batched_gemm(wg.shape[0], x.shape[-2], x.shape[-1],
                                 wg.shape[2], jnp.dtype(x.dtype).itemsize,
                                 out_dtype.itemsize, "none", epi_ops=2)
        _verify("batched",
                (wg.shape[0], x.shape[-2], x.shape[-1], wg.shape[2]), plan,
                jnp.dtype(x.dtype).itemsize, out_dtype.itemsize, swiglu=True)
        note_plan_use("batched", plan)
        return plan

    def fused_kernel(x, wg, wu, plan):
        return _ops.batched_gemm_swiglu(
            x, wg, wu, bm=plan.bm, bn=plan.bn, bk=plan.bk, edge=plan.edge,
            out_dtype=out_dtype,
            interpret=(backend == "pallas_interpret"))

    return _make_swiglu_fn(out_dtype, backend, "batched", plan_fn, run,
                           fused_kernel)


def grouped_swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, *,
                   out_dtype=None, backend: str | None = None) -> jax.Array:
    """Grouped fused MoE front half: silu(x_g @ Wg_g) * (x_g @ Wu_g) per
    group in ONE launch — the capacity-mode analogue of ``ragged_swiglu``.
    ``x`` (G, M, K), panels (G, K, N); returns (G, M, N)."""
    assert x.ndim == 3 and w_gate.ndim == 3, (x.shape, w_gate.shape)
    assert w_gate.shape == w_up.shape, (w_gate.shape, w_up.shape)
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    backend = backend or _backend()
    if backend not in ("xla", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown gemm backend: {backend}")
    return _grouped_swiglu_fn(out_dtype.name, backend)(x, w_gate, w_up)


# ---------------------------------------------------------------------------
# Ragged (capacity-free) grouped GEMM
# ---------------------------------------------------------------------------

def _float0_zeros(x: jax.Array):
    """Cotangent for integer primals (the group_offsets operand)."""
    import numpy as np
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


def _xla_ragged(x: jax.Array, w: jax.Array, offsets: jax.Array,
                trans: str, out_dtype) -> jax.Array:
    """XLA engine for the ragged product: ``jax.lax.ragged_dot`` (one pass
    over the rows) where the runtime has it, else the masked per-group
    oracle (G full-width GEMMs — correct but O(G) costlier)."""
    rd = getattr(jax.lax, "ragged_dot", None)
    if rd is None:  # pragma: no cover - every supported jax ships ragged_dot
        return _ref.ragged_matmul_ref(x, w, offsets, trans=trans,
                                      out_dtype=out_dtype)
    wx = w if trans == "nn" else jnp.swapaxes(w, 1, 2)
    sizes = jnp.diff(offsets).astype(jnp.int32)
    return rd(x, wx, sizes,
              preferred_element_type=jnp.float32).astype(out_dtype)


def _row_groups(offsets: jax.Array, t: int) -> jax.Array:
    """Owning group id per flat row: rows are sorted by group, so row r
    belongs to the group whose offset window contains it."""
    return jnp.searchsorted(offsets[1:], jnp.arange(t, dtype=offsets.dtype),
                            side="right")


def _expand_rows(v: jax.Array, offsets: jax.Array, t: int) -> jax.Array:
    """Broadcast a per-expert (G, N) flush vector to (T, N) rows — the XLA
    engine's spelling of the kernels' visit-list-indexed vector blocks."""
    return jnp.take(v, _row_groups(offsets, t), axis=0)


def _run_planned_ragged(x: jax.Array, w: jax.Array, offsets: jax.Array,
                        trans: str, out_dtype, backend: str,
                        epi: Epilogue = IDENTITY, bias=None,
                        scale=None) -> jax.Array:
    """Plan one ragged grouped GEMM off its distribution signature and run it.

    As with the batched path, the planner runs on EVERY backend (trace-time
    work; keeps the plan cache an accurate census of the irregular shapes);
    only the execution engine differs.  ``bias``/``scale`` are per-expert
    (G, N) flush vectors (the per-expert bias epilogue and the quantized
    paths' dequant), selected per tile by the visit list's group id on the
    Pallas engine and row-expanded on the XLA engine."""
    g = w.shape[0]
    k, n = (w.shape[1], w.shape[2]) if trans == "nn" else \
        (w.shape[2], w.shape[1])
    in_bytes = jnp.dtype(x.dtype).itemsize
    out_bytes = jnp.dtype(out_dtype).itemsize
    bb = _b_bytes(x, w)
    plan = plan_ragged_gemm(g, x.shape[0], k, n, in_bytes, out_bytes,
                            b_bytes=bb)
    _verify("ragged", (g, x.shape[0], k, n), plan, in_bytes, out_bytes,
            trans=trans, epi=None if epi.is_identity else epi, b_bytes=bb)
    note_plan_use("ragged", plan)
    if not epi.is_identity:
        note_epilogue("ragged", True)
    if backend == "xla":
        return _xla_ragged_epi(x, w, offsets, trans, out_dtype, epi, bias,
                               scale)
    try:
        _chaos.fire("kernel")
        return _ops.ragged_gemm(
            x, w, offsets, bm=plan.bm, bn=plan.bn, bk=plan.bk, trans=trans,
            out_dtype=out_dtype, interpret=(backend == "pallas_interpret"),
            epilogue=None if epi.is_identity else epi, bias=bias,
            scale=scale)
    except Exception as e:
        _degraded("ragged", "pallas->xla", e)
        return _xla_ragged_epi(x, w, offsets, trans, out_dtype, epi, bias,
                               scale)


def _xla_ragged_epi(x: jax.Array, w: jax.Array, offsets: jax.Array,
                    trans: str, out_dtype, epi: Epilogue, bias,
                    scale) -> jax.Array:
    """The ragged XLA engine with the per-expert flush vectors row-expanded
    — both the CPU execution path and the ragged pallas->xla ladder rung."""
    if epi.is_identity:
        return _xla_ragged(x, w, offsets, trans, out_dtype)
    # ragged_dot has no narrow-int path on the pinned jax: upcast the
    # quantized operand(s); the values are identical by construction.
    z = _xla_ragged(_wide(x), _wide(w), offsets, trans, jnp.float32)
    t = x.shape[0]
    return epi.apply(
        z,
        bias=None if bias is None else _expand_rows(bias, offsets, t),
        scale=None if scale is None else _expand_rows(scale, offsets, t),
    ).astype(out_dtype)


def _run_planned_ragged_dw(x: jax.Array, dy: jax.Array, offsets: jax.Array,
                           out_dtype, backend: str) -> jax.Array:
    """The ragged T2 backward dW — planned with ragged="k" (the ragged
    dimension is the contraction; K = routed tokens >> D ~ F per group)."""
    g = offsets.shape[0] - 1
    in_bytes = jnp.dtype(x.dtype).itemsize
    out_bytes = jnp.dtype(out_dtype).itemsize
    plan = plan_ragged_gemm(g, x.shape[0], x.shape[1], dy.shape[1],
                            in_bytes, out_bytes, ragged="k")
    _verify("ragged", (g, x.shape[0], x.shape[1], dy.shape[1]), plan,
            in_bytes, out_bytes, ragged="k")
    note_plan_use("ragged", plan)
    if backend == "xla":
        # Per-group outputs have no ragged_dot analogue on the pinned jax
        # (ragged_dot_general is newer); the masked per-group contraction
        # is the XLA engine here.
        return _ref.ragged_matmul_dw_ref(x, dy, offsets, out_dtype=out_dtype)
    try:
        _chaos.fire("kernel")
        return _ops.ragged_gemm_dw(
            x, dy, offsets, bm=plan.bm, bn=plan.bn, bk=plan.bk,
            out_dtype=out_dtype, interpret=(backend == "pallas_interpret"))
    except Exception as e:
        _degraded("ragged", "pallas->xla", e)
        return _ref.ragged_matmul_dw_ref(_wide(x), _wide(dy), offsets,
                                         out_dtype=out_dtype)


@functools.lru_cache(maxsize=None)
def _ragged_fn(out_dtype_name: str, backend: str):
    """Custom-VJP'd ragged matmul for one (dtype, backend) combo.

    Both backward GEMMs are themselves planned ragged GEMMs: dX is the "nt"
    ragged product against the same per-group panels, dW is the ragged-K T2
    grouped GEMM (``_run_planned_ragged_dw``).  group_offsets is integer
    data — its cotangent is float0."""
    out_dtype = jnp.dtype(out_dtype_name)

    @jax.custom_vjp
    def f(x, w, offsets):
        return _run_planned_ragged(x, w, offsets, "nn", out_dtype, backend)

    def fwd(x, w, offsets):
        return f(x, w, offsets), (x, w, offsets)

    def bwd(res, g):
        x, w, offsets = res
        dx = _run_planned_ragged(g, w, offsets, "nt", x.dtype, backend)
        dw = _run_planned_ragged_dw(x, g, offsets, w.dtype, backend)
        return dx, dw, _float0_zeros(offsets)

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def _ragged_bias_fn(out_dtype_name: str, backend: str):
    """Custom-VJP'd ragged matmul with the per-expert bias epilogue: bias is
    (G, N), its row selected per tile by the visit list's group id and added
    at the accumulator flush (RMW-safe: the masked boundary store only lands
    the visiting group's rows).  d_bias is the per-group row-sum of the
    cotangent — a segment sum over each row's owning group."""
    out_dtype = jnp.dtype(out_dtype_name)
    epi = Epilogue(bias=True)

    @jax.custom_vjp
    def f(x, w, offsets, bias):
        return _run_planned_ragged(x, w, offsets, "nn", out_dtype, backend,
                                   epi=epi, bias=bias)

    def fwd(x, w, offsets, bias):
        return f(x, w, offsets, bias), (x, w, offsets, bias)

    def bwd(res, g):
        x, w, offsets, bias = res
        dx = _run_planned_ragged(g, w, offsets, "nt", x.dtype, backend)
        dw = _run_planned_ragged_dw(x, g, offsets, w.dtype, backend)
        gid = _row_groups(offsets, g.shape[0])
        dbias = jnp.zeros((bias.shape[0], g.shape[1]), jnp.float32) \
            .at[gid].add(g.astype(jnp.float32)).astype(bias.dtype)
        return dx, dw, _float0_zeros(offsets), dbias

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def _quant_ragged_fn(qcfg: "_quant.QuantConfig", out_dtype_name: str,
                     backend: str):
    """Custom-VJP'd QUANTIZED ragged matmul — int8/int4/fp8 expert panels
    with per-expert (G, N) dequant scales fused at the accumulator flush,
    so the zero-drop MoE dispatch can run int8 experts end to end.

    Forward quantizes the per-group panels per channel in-trace (frozen
    expert weights constant-fold under jit); backward is straight-through
    against the DEQUANTIZED panels: dx is the planned "nt" ragged product
    over ``dequantize(w_q)`` (bf16/fp32 backward), dw the full-precision
    ragged-K T2."""
    out_dtype = jnp.dtype(out_dtype_name)
    qepi = Epilogue(scale_vec=True)

    def quantize_w(w):
        w_q, w_scale = _quant.quantize_weights(w, qcfg)     # scale (G, N)
        if qcfg.mode == "w4":
            w_q = _quant.unpack_int4(_quant.pack_int4(w_q))
        return w_q, w_scale

    @jax.custom_vjp
    def f(x, w, offsets):
        w_q, w_scale = quantize_w(w)
        if qcfg.weight_only:
            x_run, sv = x, w_scale
        else:
            x_q, a_scale = _quant.quantize_activations(x, qcfg)
            x_run, sv = x_q, w_scale * a_scale
        return _run_planned_ragged(x_run, w_q, offsets, "nn", out_dtype,
                                   backend, epi=qepi, scale=sv)

    def fwd(x, w, offsets):
        return f(x, w, offsets), (x, w, offsets)

    def bwd(res, g):
        x, w, offsets = res
        w_q, w_scale = quantize_w(w)
        w_dq = _quant.dequantize(w_q, w_scale[:, None, :], dtype=x.dtype)
        dx = _run_planned_ragged(g, w_dq, offsets, "nt", x.dtype, backend)
        dw = _run_planned_ragged_dw(x, g, offsets, w.dtype, backend)
        return dx, dw, _float0_zeros(offsets)

    f.defvjp(fwd, bwd)
    return f


def ragged_matmul(x: jax.Array, w: jax.Array, group_offsets: jax.Array, *,
                  out_dtype=None, backend: str | None = None,
                  bias: jax.Array | None = None,
                  quant: "_quant.QuantConfig | str | None" = None
                  ) -> jax.Array:
    """Ragged grouped GEMM through the ftIMM planner; fp32 accumulation.

    ``x`` is (T, D) flat rows sorted so each group's rows are contiguous;
    ``group_offsets`` (G+1,) prefix sums with offsets[0] == 0 and
    offsets[G] == T (every row owned — capacity-free, nothing dropped);
    ``w`` is (G, D, F) per-group panels.  Returns (T, F).  The capacity-free
    MoE expert projections route here instead of the padded grouped path.

    ``bias`` (G, F) adds a per-expert bias at the accumulator flush (fully
    differentiable — d_bias segment-sums the cotangent per expert).
    ``quant`` quantizes the expert panels in-trace (per-expert per-channel
    scales) and runs the narrow-dtype kernel with the dequant fused at the
    flush; straight-through backward against the dequantized panels."""
    assert x.ndim == 2 and w.ndim == 3, (x.shape, w.shape)
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    backend = backend or _backend()
    if backend not in ("xla", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown gemm backend: {backend}")
    qcfg = _quant.resolve(quant)
    if not qcfg.is_noop:
        if bias is not None:
            raise ValueError("quantized ragged matmul does not take a bias "
                             "operand; apply it as a separate epilogue")
        return _quant_ragged_fn(qcfg, out_dtype.name,
                                backend)(x, w, group_offsets)
    if bias is not None:
        _check_vectors("ragged", (w.shape[0], x.shape[0], w.shape[1],
                                  w.shape[2]), Epilogue(bias=True), bias,
                       None)
        return _ragged_bias_fn(out_dtype.name,
                               backend)(x, w, group_offsets, bias)
    return _ragged_fn(out_dtype.name, backend)(x, w, group_offsets)


@functools.lru_cache(maxsize=None)
def _ragged_swiglu_fn(out_dtype_name: str, backend: str):
    """Custom-VJP'd fused ragged SwiGLU pair (one kernel launch forward).

    Backward rematerializes the two fp32 pre-activations with planned ragged
    GEMMs (the usual fused-epilogue remat), then runs two planned "nt" dX
    products and two planned ragged-K dW products."""
    out_dtype = jnp.dtype(out_dtype_name)

    def _plan(x, wg):
        in_bytes = jnp.dtype(x.dtype).itemsize
        plan = plan_ragged_gemm(wg.shape[0], x.shape[0], wg.shape[1],
                                wg.shape[2], in_bytes, out_dtype.itemsize)
        _verify("ragged", (wg.shape[0], x.shape[0], wg.shape[1],
                           wg.shape[2]), plan, in_bytes, out_dtype.itemsize,
                swiglu=True)
        note_plan_use("ragged", plan)
        return plan

    @jax.custom_vjp
    def f(x, wg, wu, offsets):
        plan = _plan(x, wg)
        if backend == "xla":
            a = _xla_ragged(x, wg, offsets, "nn", jnp.float32)
            b = _xla_ragged(x, wu, offsets, "nn", jnp.float32)
            return (jax.nn.silu(a) * b).astype(out_dtype)
        try:
            _chaos.fire("kernel_fused")
            return _ops.ragged_gemm_swiglu(
                x, wg, wu, offsets, bm=plan.bm, bn=plan.bn, bk=plan.bk,
                out_dtype=out_dtype,
                interpret=(backend == "pallas_interpret"))
        except Exception as e:
            _degraded("ragged", "fused->unfused", e)
        a = _run_planned_ragged(x, wg, offsets, "nn", jnp.float32, backend)
        b = _run_planned_ragged(x, wu, offsets, "nn", jnp.float32, backend)
        return (jax.nn.silu(a) * b).astype(out_dtype)

    def fwd(x, wg, wu, offsets):
        return f(x, wg, wu, offsets), (x, wg, wu, offsets)

    def bwd(res, g):
        x, wg, wu, offsets = res
        a = _run_planned_ragged(x, wg, offsets, "nn", jnp.float32, backend)
        b = _run_planned_ragged(x, wu, offsets, "nn", jnp.float32, backend)
        sg = jax.nn.sigmoid(a)
        g32 = g.astype(jnp.float32)
        da = (g32 * b * sg * (1.0 + a * (1.0 - sg))).astype(x.dtype)
        db = (g32 * a * sg).astype(x.dtype)
        dx = (_run_planned_ragged(da, wg, offsets, "nt", jnp.float32, backend)
              + _run_planned_ragged(db, wu, offsets, "nt", jnp.float32,
                                    backend)).astype(x.dtype)
        dwg = _run_planned_ragged_dw(x, da, offsets, wg.dtype, backend)
        dwu = _run_planned_ragged_dw(x, db, offsets, wu.dtype, backend)
        return dx, dwg, dwu, _float0_zeros(offsets)

    f.defvjp(fwd, bwd)
    return f


def ragged_swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                  group_offsets: jax.Array, *, out_dtype=None,
                  backend: str | None = None) -> jax.Array:
    """Fused ragged MoE MLP front half: silu(x @ Wg_g) * (x @ Wu_g) per group
    in ONE kernel launch (same contract as ``ragged_matmul``)."""
    assert x.ndim == 2 and w_gate.ndim == 3, (x.shape, w_gate.shape)
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    backend = backend or _backend()
    if backend not in ("xla", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown gemm backend: {backend}")
    return _ragged_swiglu_fn(out_dtype.name, backend)(
        x, w_gate, w_up, group_offsets)


def clear_dispatch_caches() -> None:
    """Drop the custom-VJP'd dispatch function caches so the next call
    re-traces against the current planner state (part of the single
    ``tuner.clear_plan_cache`` reset: the cached closures re-consult the
    planners at trace time, and stale jit entries keyed on old blocks are
    unreachable once the planners re-decide)."""
    _pallas_fn.cache_clear()
    _quant_fn.cache_clear()
    _batched_fn.cache_clear()
    _batched_bias_fn.cache_clear()
    _ragged_fn.cache_clear()
    _ragged_bias_fn.cache_clear()
    _quant_ragged_fn.cache_clear()
    _ragged_swiglu_fn.cache_clear()
    _swiglu_fn.cache_clear()
    _grouped_swiglu_fn.cache_clear()
    _verify_cached.cache_clear()
    # The warn-once set must reset with everything else: after a full
    # cache reset a recurring degradation should log again instead of
    # being silently swallowed by a stale dedup key.
    _WARNED_RUNGS.clear()


def project(x: jax.Array, w: jax.Array, *, out_dtype=None,
            backend: str | None = None,
            epilogue: Epilogue | None = None,
            bias: jax.Array | None = None,
            residual: jax.Array | None = None,
            quant: "_quant.QuantConfig | str | None" = None) -> jax.Array:
    """(..., D) @ (D, N) -> (..., N): flattens leading dims into the paper's
    M dimension (tokens — typically the tall axis of T1/T3).  ``epilogue``
    fuses the layer's elementwise tail into the projection; ``residual``
    (..., N) is flattened alongside x, ``bias`` is (N,).  ``quant`` routes
    through the managed quantized engine (see ``matmul``)."""
    lead = x.shape[:-1]
    m = 1
    for s in lead:
        m *= s
    res = None if residual is None else residual.reshape(m, w.shape[-1])
    y = matmul(x.reshape(m, x.shape[-1]), w, out_dtype=out_dtype,
               backend=backend, epilogue=epilogue, bias=bias, residual=res,
               quant=quant)
    return y.reshape(*lead, w.shape[-1])


def project_swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, *,
                   out_dtype=None, backend: str | None = None) -> jax.Array:
    """(..., D) fused SwiGLU front half: silu(x @ Wg) * (x @ Wu) with the
    leading dims flattened into M — ONE kernel launch for a dense MLP's
    gate/up pair."""
    lead = x.shape[:-1]
    m = 1
    for s in lead:
        m *= s
    y = matmul_swiglu(x.reshape(m, x.shape[-1]), w_gate, w_up,
                      out_dtype=out_dtype, backend=backend)
    return y.reshape(*lead, w_gate.shape[-1])
