"""Framework-wide GEMM entry point.

Every dense contraction in the model stack routes through ``matmul`` /
``project``: the shape is classified (paper §III-A), the CMR tuner picks the
strategy + blocks (paper §IV-C), and the call dispatches to

  * the specialized Pallas ftIMM kernel on TPU (or in interpret mode when
    explicitly requested, e.g. kernel tests), wrapped in a custom VJP whose
    backward GEMMs are themselves ftIMM-planned — dW = x.T @ dy is the
    paper's T2 shape and gets the K-oriented treatment automatically;
  * an XLA ``dot_general`` path on CPU (used by the multi-pod dry-run so
    ``cost_analysis`` reflects the true FLOPs/bytes) with identical
    fp32-accumulation semantics.

Backend selection: ``REPRO_GEMM_BACKEND`` env var ("pallas" | "xla" |
"pallas_interpret"), else pallas on TPU and xla elsewhere.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from ...kernels.ftimm import ops as _ops
from ...kernels.ftimm import ref as _ref
from .tuner import plan_gemm

_REF = {"nn": _ref.matmul_nn, "tn": _ref.matmul_tn, "nt": _ref.matmul_nt}


def _backend() -> str:
    env = os.environ.get("REPRO_GEMM_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _mkn(trans: str, a_shape, b_shape):
    if trans == "nn":
        (m, k), (_, n) = a_shape, b_shape
    elif trans == "tn":
        (k, m), (_, n) = a_shape, b_shape
    else:
        (m, k), (n, _) = a_shape, b_shape
    return m, k, n


def _run_planned(a: jax.Array, b: jax.Array, trans: str, out_dtype,
                 interpret: bool) -> jax.Array:
    m, k, n = _mkn(trans, a.shape, b.shape)
    in_bytes = jnp.dtype(a.dtype).itemsize
    out_bytes = jnp.dtype(out_dtype).itemsize
    plan = plan_gemm(m, k, n, in_bytes, out_bytes)
    return _ops.gemm(
        a, b, trans=trans, out_dtype=out_dtype, interpret=interpret,
        **plan.kernel_kwargs(),
    )


@functools.lru_cache(maxsize=None)
def _pallas_fn(trans: str, out_dtype_name: str, interpret: bool):
    """Build the custom-VJP'd Pallas matmul for one (trans, dtype) combo."""
    out_dtype = jnp.dtype(out_dtype_name)

    @jax.custom_vjp
    def f(a, b):
        return _run_planned(a, b, trans, out_dtype, interpret)

    def fwd(a, b):
        return f(a, b), (a, b)

    def bwd(res, g):
        a, b = res
        run = lambda x, y, t, dt: _run_planned(x, y, t, dt, interpret)  # noqa: E731
        if trans == "nn":          # y = a @ b
            da = run(g, b, "nt", a.dtype)
            db = run(a, g, "tn", b.dtype)   # T2: K = tokens >> M ~ N
        elif trans == "tn":        # y = a.T @ b, a: (K, M)
            da = run(b, g, "nt", a.dtype)   # (K,N)@(N,M) -> (K,M)
            db = run(a, g, "nn", b.dtype)   # (K,M)@(M,N) -> (K,N)
        else:                      # y = a @ b.T, b: (N, K)
            da = run(g, b, "nn", a.dtype)   # (M,N)@(N,K) -> (M,K)
            db = run(g, a, "tn", b.dtype)   # g.T @ a -> (N,K)
        return da, db

    f.defvjp(fwd, bwd)
    return f


def matmul(a: jax.Array, b: jax.Array, *, trans: str = "nn",
           out_dtype=None, backend: str | None = None) -> jax.Array:
    """2-D GEMM through the ftIMM planner. fp32 accumulation always."""
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    backend = backend or _backend()
    if backend == "xla":
        return _REF[trans](a, b, out_dtype)
    if backend == "pallas":
        return _pallas_fn(trans, out_dtype.name, False)(a, b)
    if backend == "pallas_interpret":
        return _pallas_fn(trans, out_dtype.name, True)(a, b)
    raise ValueError(f"unknown gemm backend: {backend}")


def project(x: jax.Array, w: jax.Array, *, out_dtype=None,
            backend: str | None = None) -> jax.Array:
    """(..., D) @ (D, N) -> (..., N): flattens leading dims into the paper's
    M dimension (tokens — typically the tall axis of T1/T3)."""
    lead = x.shape[:-1]
    m = 1
    for s in lead:
        m *= s
    y = matmul(x.reshape(m, x.shape[-1]), w, out_dtype=out_dtype,
               backend=backend)
    return y.reshape(*lead, w.shape[-1])
