"""Collective exchange layer for the mesh executors: the ragged all-to-all
and the overlapped (ring) collective-GEMM schedules.

The EP token exchange is keyed by the ``group_offsets`` prefix sums: rows
arrive sorted by group and experts are contiguously owned by shards, so
shard s owns the contiguous window [offsets[s*G_l], offsets[(s+1)*G_l)) of
the global row array.  This module realizes that exchange two ways and the
surrounding GEMM two ways:

**Exchange realizations** (``exchange_method``):

  * ``"primitive"`` — ``jax.lax.ragged_all_to_all`` (newer jax, backend
    support varies): each shard ships ONLY the bytes of the owned windows,
    send/recv offsets derived from the prefix sums.  Availability of the
    symbol is necessary but not sufficient — a concrete round-trip probe on
    the actual mesh must pass before it is trusted (``REPRO_RAGGED_A2A=auto``,
    the default; ``=primitive`` forces, ``=dense`` disables).
  * ``"dense"`` — the portable realization: one ``all_gather`` of the rows
    in, a scatter + ``psum_scatter`` back (windows are disjoint and cover
    [0, T), so the sum just merges them).  Works on every jax/backend the
    repo supports; moves more bytes but the same number of collectives.

**Schedules** (the ``Placement.schedule`` axis the tuner prices):

  * ``"gather"`` — unoverlapped: exchange, then ONE per-shard ragged GEMM
    over the worst-case T-row window (every row could route to this shard's
    experts), then the return leg.  Simple, but the static window means
    per-shard compute is O(T) regardless of how many rows the shard owns.
  * ``"ring"`` — the overlapped collective matmul (paper §IV's DMA pipeline
    lifted to mesh scale): token blocks rotate around the ring via
    ``ppermute`` while each shard computes only the blocks that intersect
    its owned window (``lax.cond``-skipped otherwise), double-buffered by
    XLA's async collective scheduling — chunk k+1's transfer overlaps chunk
    k's compute, and per-shard compute is proportional to the rows the
    shard actually owns (~2 blocks when balanced) instead of T.

``ring_kparallel`` is the dense analogue for ``dist_matmul``: the output
columns are chunked over shard-steps, partial sums rotate around the ring
and each hop overlaps the next chunk's local GEMM.

Ring schedules require a single mesh axis (``ppermute`` permutes one named
axis); multi-axis EP requests fall back to the gather schedule.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .. import compat

ENV_A2A = "REPRO_RAGGED_A2A"
SCHEDULES = ("gather", "ring")


def mask_rows(x: jax.Array, n_valid: jax.Array) -> jax.Array:
    """Zero rows at index >= n_valid (rows past the owned window)."""
    return jnp.where(jnp.arange(x.shape[0])[:, None] < n_valid, x,
                     jnp.zeros((), x.dtype))


def owned_bounds(offsets: jax.Array, g_l: int, sidx: jax.Array):
    """This shard's slice of the prefix sums: (local offsets, start, stop)."""
    lo = jax.lax.dynamic_slice_in_dim(offsets, sidx * g_l, g_l + 1)
    return lo, lo[0], lo[g_l]


# ---------------------------------------------------------------------------
# Exchange-method selection: probe the true ragged a2a on the actual mesh.
# ---------------------------------------------------------------------------

def _probe_offsets(nc: int, tl: int):
    """A deliberately adversarial distribution for the probe: one window
    spanning several blocks, one empty window, singleton windows, ending
    exactly at T so the round-trip must reproduce the input bitwise."""
    import numpy as np
    t = nc * tl
    off = [0, t - (nc - 1)]                 # window 0 spans most rows
    for j in range(2, nc + 1):
        off.append(t - nc + j)
    off[min(2, nc)] = off[1]                # make one window empty
    return np.asarray(off, dtype=np.int32)


@functools.lru_cache(maxsize=16)
def _primitive_probe_ok(mesh: Mesh, ax: str) -> bool:
    """Run a tiny dispatch+combine round-trip through the primitive on the
    real mesh and require it to reproduce the input exactly.  Any failure
    (missing backend lowering, semantics drift, compile error) means the
    dense realization is used instead — the probe is the contract."""
    if compat.ragged_all_to_all is None:
        return False
    nc = int(mesh.shape[ax])
    if nc <= 1:
        return False
    tl, d = 2, 4
    import numpy as np
    offs = _probe_offsets(nc, tl)
    x = np.arange(nc * tl * d, dtype=np.float32).reshape(nc * tl, d)

    def f(x_l, o):
        win, lo, start, stop = primitive_dispatch(x_l, o, 1, ax, nc)
        return primitive_combine(mask_rows(win, stop - start), o, 1, ax, nc,
                                 tl)

    try:
        g = jax.jit(compat.shard_map_unchecked(
            f, mesh=mesh, in_specs=(P(ax, None), P(None)),
            out_specs=P(ax, None)))
        y = jax.device_get(g(x, offs))
        return bool((y == x).all())
    except Exception:
        return False


def exchange_method(mesh: Mesh, axes: tuple) -> str:
    """"primitive" when the true ragged all-to-all exists AND passes the
    round-trip probe on this mesh; "dense" otherwise.  ``REPRO_RAGGED_A2A``
    overrides: "dense" disables the probe, "primitive" makes an unusable
    primitive a hard error instead of a silent fallback."""
    return _method_cached(mesh, axes, os.environ.get(ENV_A2A, "auto"))


@functools.lru_cache(maxsize=32)
def _method_cached(mesh: Mesh, axes: tuple, env: str) -> str:
    if env == "dense":
        return "dense"
    ok = len(axes) == 1 and _primitive_probe_ok(mesh, axes[0])
    if env == "primitive" and not ok:
        raise RuntimeError(
            "REPRO_RAGGED_A2A=primitive but jax.lax.ragged_all_to_all is "
            "unavailable or failed the round-trip probe on this mesh")
    return "primitive" if ok else "dense"


# ---------------------------------------------------------------------------
# The true ragged all-to-all: send/recv geometry from the prefix sums.
# ---------------------------------------------------------------------------

def _window_bounds_all(offsets: jax.Array, g_l: int, nc: int):
    """(nc+1,) global window bounds: shard j owns [wb[j], wb[j+1])."""
    return offsets[jnp.arange(nc + 1, dtype=jnp.int32) * g_l]


def primitive_dispatch(x_l: jax.Array, offsets: jax.Array, g_l: int,
                       ax: str, nc: int):
    """Dispatch leg via ``ragged_all_to_all``: ship each contiguous run of
    my rows to the shard whose window contains it.  Returns the (T, d)
    window buffer with owned rows at [0, wlen) — the same layout the dense
    realization's window slice produces — plus (local offsets, start, stop).
    """
    tl, _d = x_l.shape
    t = nc * tl
    s = jax.lax.axis_index(ax)
    r0 = s * tl
    wb = _window_bounds_all(offsets, g_l, nc).astype(jnp.int32)
    w_lo, w_hi = wb[:-1], wb[1:]
    # To dest j: my rows ∩ j's window, placed at (global row - w_lo[j]).
    in_off = jnp.clip(w_lo - r0, 0, tl).astype(jnp.int32)
    send = jnp.clip(jnp.minimum(w_hi, r0 + tl) - jnp.maximum(w_lo, r0),
                    0, tl).astype(jnp.int32)
    out_off = jnp.clip(r0 - w_lo, 0, t).astype(jnp.int32)
    # From source i: i's rows ∩ my window.
    blk = jnp.arange(nc, dtype=jnp.int32) * tl
    lo, start, stop = owned_bounds(offsets, g_l, s)
    recv = jnp.clip(jnp.minimum(stop, blk + tl) - jnp.maximum(start, blk),
                    0, tl).astype(jnp.int32)
    buf = jnp.zeros((t,) + x_l.shape[1:], x_l.dtype)
    win = compat.ragged_all_to_all(x_l, buf, in_off, send, out_off, recv,
                                   axis_name=ax)
    return win, lo, start, stop


def primitive_combine(win_out: jax.Array, offsets: jax.Array, g_l: int,
                      ax: str, nc: int, tl: int) -> jax.Array:
    """Return leg via ``ragged_all_to_all``: the inverse geometry — my
    window rows [0, wlen) ship back to the shards owning the corresponding
    global rows.  Unowned output rows (T padding past offsets[-1]) stay
    zero, matching the psum_scatter realization."""
    t = win_out.shape[0]
    s = jax.lax.axis_index(ax)
    r0 = s * tl
    wb = _window_bounds_all(offsets, g_l, nc).astype(jnp.int32)
    w_lo, w_hi = wb[:-1], wb[1:]
    o_lo, o_hi = wb[s], wb[s + 1]
    blk = jnp.arange(nc, dtype=jnp.int32) * tl
    in_off = jnp.clip(blk - o_lo, 0, t).astype(jnp.int32)
    send = jnp.clip(jnp.minimum(o_hi, blk + tl) - jnp.maximum(o_lo, blk),
                    0, tl).astype(jnp.int32)
    out_off = jnp.clip(o_lo - blk, 0, tl).astype(jnp.int32)
    recv = jnp.clip(jnp.minimum(w_hi, r0 + tl) - jnp.maximum(w_lo, r0),
                    0, tl).astype(jnp.int32)
    buf = jnp.zeros((tl,) + win_out.shape[1:], win_out.dtype)
    return compat.ragged_all_to_all(win_out, buf, in_off, send, out_off,
                                    recv, axis_name=ax)


# ---------------------------------------------------------------------------
# Unified dispatch/combine: collective part split from the pure window
# slice, so the executors can cond-skip the slice+GEMM on empty shards
# (collectives must run unconditionally on every shard).
# ---------------------------------------------------------------------------

def dispatch_payload(x_l: jax.Array, offsets: jax.Array, g_l: int,
                     axes: tuple, ax, nc: int, method: str, sidx):
    """Run the dispatch leg's COLLECTIVE and return
    ``(payload, loffs, start, stop)``.  ``window_from_payload`` turns the
    payload into the (T, d) owned-rows window — a pure slice that callers
    wrap in the empty-shard ``lax.cond``."""
    if method == "primitive":
        return primitive_dispatch(x_l, offsets, g_l, axes[0], nc)
    full = jax.lax.all_gather(x_l, ax, axis=0, tiled=True)
    lo, start, stop = owned_bounds(offsets, g_l, sidx)
    return full, lo, start, stop


def window_from_payload(payload: jax.Array, start: jax.Array,
                        method: str) -> jax.Array:
    """Pure part of the dispatch leg: position the owned rows at [0, wlen).
    The primitive already delivered them there; the dense payload is the
    full gathered row array, sliced at ``start`` (zero-padded to keep the
    slice in range — rows past wlen are masked by the caller)."""
    if method == "primitive":
        return payload
    padded = jnp.concatenate([payload, jnp.zeros_like(payload)], axis=0)
    return jax.lax.dynamic_slice_in_dim(padded, start, payload.shape[0],
                                        axis=0)


def combine_rows(win_out: jax.Array, offsets: jax.Array, g_l: int,
                 axes: tuple, ax, nc: int, method: str, start,
                 tl: int) -> jax.Array:
    """Inverse exchange: window rows (masked past wlen by the caller) back
    to the global row-sorted layout, (tl, d) per shard."""
    if method == "primitive":
        return primitive_combine(win_out, offsets, g_l, axes[0], nc, tl)
    t = win_out.shape[0]
    buf = jnp.zeros((2 * t,) + win_out.shape[1:], win_out.dtype)
    buf = jax.lax.dynamic_update_slice_in_dim(buf, win_out, start, axis=0)
    return jax.lax.psum_scatter(buf[:t], ax, scatter_dimension=0, tiled=True)


# ---------------------------------------------------------------------------
# Ring schedules: the overlapped collective GEMM.
# ---------------------------------------------------------------------------

def ring_forward(x_l: jax.Array, offsets: jax.Array, g_l: int, ax: str,
                 nc: int, compute, out_width: int, out_dtype) -> jax.Array:
    """Overlapped EP forward: token blocks rotate around the ring; at step p
    shard s holds block b = (s - p) mod nc and computes only when b
    intersects its owned window [o_lo, o_hi) — the ``lax.cond`` skip is what
    makes per-shard compute proportional to owned rows instead of T.  The
    output block accumulates contributions as it rides the ring and arrives
    home after nc hops.  ``compute(win, loffs, run_len) -> (tl, out_width)``
    is the per-block local ragged product."""
    tl = x_l.shape[0]
    s = jax.lax.axis_index(ax)
    lo, o_lo, o_hi = owned_bounds(offsets, g_l, s)
    perm = [(j, (j + 1) % nc) for j in range(nc)]
    x_blk = x_l
    y_blk = jnp.zeros((tl, out_width), out_dtype)
    for p in range(nc):
        b0 = ((s - p) % nc) * tl
        run_lo = jnp.clip(o_lo - b0, 0, tl)
        run_hi = jnp.clip(o_hi - b0, 0, tl)
        run_len = run_hi - run_lo

        def step(x_blk=x_blk, run_lo=run_lo, run_hi=run_hi,
                 run_len=run_len, b0=b0):
            pad = jnp.concatenate([x_blk, jnp.zeros_like(x_blk)], axis=0)
            win = jax.lax.dynamic_slice_in_dim(pad, run_lo, tl, axis=0)
            loffs = (jnp.clip(lo - b0, run_lo, run_hi)
                     - run_lo).astype(jnp.int32)
            y_win = mask_rows(compute(win, loffs, run_len), run_len)
            buf = jnp.zeros((2 * tl, out_width), out_dtype)
            buf = jax.lax.dynamic_update_slice_in_dim(buf, y_win, run_lo,
                                                      axis=0)
            return buf[:tl]

        y_blk = y_blk + jax.lax.cond(
            run_len > 0, step,
            lambda: jnp.zeros((tl, out_width), out_dtype))
        if p < nc - 1:
            x_blk = jax.lax.ppermute(x_blk, ax, perm)
        y_blk = jax.lax.ppermute(y_blk, ax, perm)
    return y_blk


def ring_backward(ct_l: jax.Array, x_l: jax.Array, offsets: jax.Array,
                  g_l: int, ax: str, nc: int, compute, dw_zeros: tuple):
    """Overlapped EP backward: (cotangent, activation) blocks rotate
    TOGETHER (one fused rotation pair per hop — the ring analogue of the
    fused concatenated gather); dX contributions accumulate onto a third
    rotating block, dW accumulates locally on the shard owning the panels.
    ``compute(ct_win, x_win, loffs, run_len) -> (dx_win, (dw, ...))``;
    returns ``(dx_l, (dw, ...))``."""
    tl = x_l.shape[0]
    s = jax.lax.axis_index(ax)
    lo, o_lo, o_hi = owned_bounds(offsets, g_l, s)
    perm = [(j, (j + 1) % nc) for j in range(nc)]
    ct_blk, x_blk = ct_l, x_l
    dx_blk = jnp.zeros_like(x_l)
    dws = tuple(dw_zeros)
    for p in range(nc):
        b0 = ((s - p) % nc) * tl
        run_lo = jnp.clip(o_lo - b0, 0, tl)
        run_hi = jnp.clip(o_hi - b0, 0, tl)
        run_len = run_hi - run_lo

        def step(ct_blk=ct_blk, x_blk=x_blk, run_lo=run_lo,
                 run_hi=run_hi, run_len=run_len, b0=b0):
            def shift(blk):
                pad = jnp.concatenate([blk, jnp.zeros_like(blk)], axis=0)
                return jax.lax.dynamic_slice_in_dim(pad, run_lo, tl, axis=0)

            loffs = (jnp.clip(lo - b0, run_lo, run_hi)
                     - run_lo).astype(jnp.int32)
            dx_win, dw_c = compute(shift(ct_blk), shift(x_blk), loffs,
                                   run_len)
            dx_win = mask_rows(dx_win, run_len)
            buf = jnp.zeros((2 * tl,) + dx_win.shape[1:], dx_win.dtype)
            buf = jax.lax.dynamic_update_slice_in_dim(buf, dx_win, run_lo,
                                                      axis=0)
            return (buf[:tl],) + tuple(dw_c)

        zero = (jnp.zeros_like(x_l),) + tuple(jnp.zeros_like(z)
                                              for z in dws)
        out = jax.lax.cond(run_len > 0, step, lambda zero=zero: zero)
        dx_blk = dx_blk + out[0]
        dws = tuple(d + c for d, c in zip(dws, out[1:]))
        if p < nc - 1:
            ct_blk = jax.lax.ppermute(ct_blk, ax, perm)
            x_blk = jax.lax.ppermute(x_blk, ax, perm)
        dx_blk = jax.lax.ppermute(dx_blk, ax, perm)
    return dx_blk, dws


def ring_kparallel(a_l: jax.Array, b_l: jax.Array, ax: str, nc: int,
                   partial_fn) -> jax.Array:
    """Overlapped K-parallel collective matmul: output columns chunked over
    shard-steps.  At step p shard s computes its K-shard's partial for
    column chunk (s - p - 1) mod nc, adds the partial sum arriving from the
    ring, and forwards — chunk transfers overlap the next chunk's local
    GEMM (the mesh-level analogue of the paper's core-level DMA pipeline).
    After nc steps shard s holds the fully reduced chunk s; one tiled
    all_gather reassembles the replicated (M, N) output.  ``b_l``'s N must
    be an nc multiple (callers pad).  ``partial_fn(a_l, b_chunk)`` is the
    fp32 local GEMM."""
    n = b_l.shape[1]
    cn = n // nc
    s = jax.lax.axis_index(ax)
    perm = [(j, (j + 1) % nc) for j in range(nc)]
    acc = jnp.zeros((a_l.shape[0], cn), jnp.float32)
    for p in range(nc):
        c = (s - p - 1) % nc
        b_c = jax.lax.dynamic_slice_in_dim(b_l, c * cn, cn, axis=1)
        acc = acc + partial_fn(a_l, b_c)
        if p < nc - 1:
            acc = jax.lax.ppermute(acc, ax, perm)
    return jax.lax.all_gather(acc, ax, axis=1, tiled=True)
