"""Dynamic adjusting (paper §IV-C): choose parallelization strategy, block
sizes, AND mesh placement per GEMM shape, at trace time, from the CMR model.

The paper fixes initial block sizes from CMR + capacity, then adjusts them to
the actual matrix shape at run time, and picks M-parallel vs K-parallel from
the shape (K-parallel iff M and N are both small and K is large, because only
splitting K can occupy all 8 DSP cores).  Here that decision is one level of
a unified *plan hierarchy*:

  * every planner (``plan_gemm`` / ``plan_batched_gemm`` /
    ``plan_ragged_gemm``) returns a ``Plan`` whose single-core tiling
    (bm, bn, bk, dim_order) comes from ONE shared candidate enumeration
    (``gemm_candidates`` / ``batched_candidates`` / ``ragged_candidates``)
    scored with ``cmr.estimate*`` under the VMEM budget;
  * when asked to place the GEMM on a mesh (``num_shards > 1``), the same
    plan additionally carries a ``Placement`` — the cross-chip strategy
    (m_parallel / k_parallel / expert_parallel), the modeled ICI collective
    term (psum for K-parallel, the token all-to-all for expert-parallel via
    ``cmr.estimate_ep``) and the load-imbalance waste — so strategy x
    blocking is ONE joint auto-tuning decision, mirroring Eqs. 1-4's
    num_core terms at mesh scale;
  * plans are LRU-cached per shape signature — the paper's "dynamic
    adjusting" happens once per (shape, dtype, placement request) and is
    free afterwards.

Closing the paper's auto-tuning loop (pillar three), the analytic argmin is
no longer the last word: each planner first consults the **persistent
measured-plan store** (``plan_store`` — filled by ``autotune``'s on-device
search over the CMR-shortlisted candidates) and every plan carries a
``mode``:

    "analytic"  — CMR argmin, never validated against hardware;
    "measured"  — returned directly by ``autotune.autotune_*`` after timing
                  the shortlist on the device;
    "cached"    — served from the persistent store (a previous measured
                  winner), re-estimated and re-validated at lookup.

``plan_distributed`` survives as the dense-only compat view (``DistPlan``);
``tgemm_plan`` reproduces the TGEMM strawman the paper compares against: one
fixed micro-kernel/block configuration regardless of shape, with implicit
padding of N (its waste shows up in ``est.flops_padded`` / traffic).
"""
from __future__ import annotations

import collections
import functools
from dataclasses import dataclass, replace

from . import plan_store
from ...analysis import contracts
from .cmr import (TPU_V5E, EpEstimate, PlanEstimate, TpuSpec, cdiv, ceil_to,
                  estimate, estimate_batched, estimate_ep, estimate_ragged)
from .shapes import GemmClass, classify


@dataclass(frozen=True)
class Placement:
    """Where a plan runs on the mesh.  ``None`` placement = single device.

    ``strategy`` is the paper's parallelization mode lifted to the mesh:
    "m_parallel" (Alg. 4: shard rows, replicate panels, no steady-state
    collective), "k_parallel" (Alg. 5: shard the contraction, psum the fp32
    partials) or "expert_parallel" (shard the group/expert dim, all-to-all
    the tokens to their owning shard and back).  ``t_collective`` is the
    modeled ICI term of that choice, ``ici_bytes`` the global bytes it moves,
    and ``waste`` the load-imbalance multiplier on the local estimate.

    ``schedule`` is the overlap axis the ring collective matmul added:
    "gather" runs the collective then the local GEMM back-to-back (t_total
    SUMS the two), "ring" rotates chunks around the mesh so each hop's
    transfer overlaps the next chunk's compute (t_total takes the MAX — the
    mesh-level analogue of the paper's DMA/compute pipelining).
    """
    strategy: str                   # m_parallel | k_parallel | expert_parallel
    num_shards: int = 1
    axis: str | None = None         # mesh axis name (advisory; executors bind)
    t_collective: float = 0.0       # modeled ICI cost (s) per call
    ici_bytes: float = 0.0          # global bytes over ICI per call
    waste: float = 1.0              # >= 1: shard-imbalance multiplier
    schedule: str = "gather"        # gather (unoverlapped) | ring (overlapped)


class Plan:
    """Base of the unified plan hierarchy: a local CMR estimate (``est``)
    plus an optional ``Placement``.  ``t_total`` composes them the same way
    for every family: local time x imbalance waste + ICI collective for the
    gather schedule, max(local, ICI) for the ring schedule (the transfer
    hides behind compute — whichever dominates sets the clock).
    ``mode`` records which tuning loop produced the plan (analytic CMR
    argmin / measured on device / served from the persistent cache)."""

    est: PlanEstimate | None
    placement: Placement | None
    mode: str

    @property
    def t_total(self) -> float:
        t = self.est.t_total if self.est is not None else 0.0
        p = self.placement
        if p is not None:
            if p.schedule == "ring":
                t = max(t * p.waste, p.t_collective)
            else:
                t = t * p.waste + p.t_collective
        return t

    @property
    def strategy(self) -> str:
        return self.placement.strategy if self.placement is not None \
            else "single"


@dataclass(frozen=True)
class GemmPlan(Plan):
    bm: int
    bn: int
    bk: int
    nsplit: int = 1                 # in-kernel split-K factor
    dim_order: str = "mn"
    gemm_class: GemmClass = GemmClass.REGULAR
    est: PlanEstimate | None = None
    placement: Placement | None = None
    mode: str = "analytic"          # analytic | measured | cached
    edge: str = "masked"            # masked (zero-copy) | padded (pad/slice)
    fuse: bool = True               # fuse the requested epilogue in-kernel

    def kernel_kwargs(self) -> dict:
        return dict(bm=self.bm, bn=self.bn, bk=self.bk,
                    nsplit=self.nsplit, dim_order=self.dim_order,
                    edge=self.edge)


@dataclass(frozen=True)
class DistPlan(Plan):
    """Compat view of a placed dense plan (the paper's two cross-chip
    strategies).  ``local`` is the per-shard ``GemmPlan``; strategy/cost
    accessors read through to its ``Placement``."""
    local: GemmPlan
    placement: Placement
    est: PlanEstimate | None = None
    mode: str = "analytic"

    @property
    def num_cores(self) -> int:
        return self.placement.num_shards

    @property
    def t_collective(self) -> float:
        return self.placement.t_collective


def _bm_candidates(m: int, sublane: int) -> list[int]:
    cands = [c for c in (128, 256, 512, 1024) if c <= ceil_to(m, sublane)]
    if m < 128:
        cands.append(ceil_to(m, sublane))
    return sorted(set(cands)) or [ceil_to(m, sublane)]


def _bn_candidates(n: int, lane: int) -> list[int]:
    top = ceil_to(n, lane)
    cands = [c for c in (128, 256, 512) if c <= top]
    if top <= 1024:
        cands.append(top)
    return sorted(set(cands)) or [top]


def _bk_candidates(k: int) -> list[int]:
    top = ceil_to(k, 128)
    cands = [c for c in (128, 256, 512, 1024, 2048) if c <= top]
    if top <= 4096:
        cands.append(top)   # full-K residency — enables gk == 1 reuse
    return sorted(set(cands)) or [top]


def effective_spec(spec: TpuSpec) -> TpuSpec:
    """Swap the stock default spec for its measured calibration, when the
    persistent store carries one (``autotune.calibrate`` fits the achievable
    flops fraction + effective HBM bandwidth from measured-vs-predicted
    ratios).  Explicitly-passed custom specs are honored untouched — the
    calibration corrects the *default* constants so shapes that were never
    measured still plan against reality."""
    if spec is not TPU_V5E:
        return spec
    cal = plan_store.get_store().calibration
    if cal is None:
        return spec
    return spec.calibrated(cal.flops_frac, cal.bw_frac,
                           getattr(cal, "ici_frac", 1.0),
                           int8_frac=getattr(cal, "flops_frac_int8", None))


# ---------------------------------------------------------------------------
# Shared candidate enumeration — ONE generator per plan family, used by both
# the analytic argmin below and autotune's measured shortlist.
# ---------------------------------------------------------------------------

def _edge_variants(m: int, k: int, n: int, bm: int, bn: int,
                   bk: int) -> tuple[str, ...]:
    """Edge policies worth enumerating for one blocking: ``padded`` only
    differs from ``masked`` (and only costs anything) when some dimension is
    not a block multiple."""
    if m % bm or n % bn or k % bk:
        return ("masked", "padded")
    return ("masked",)


def _fuse_variants(epi_ops: int) -> tuple[bool, ...]:
    return (True, False) if epi_ops > 0 else (True,)


def gemm_candidates(m: int, k: int, n: int, in_bytes: int = 4,
                    out_bytes: int = 4,
                    spec: TpuSpec = TPU_V5E,
                    epi_ops: int = 0, *, verify: bool = True,
                    b_bytes: int | None = None
                    ) -> list[GemmPlan]:
    """Every VMEM-feasible candidate tiling for the dense GEMM, scored by
    the CMR model.  The candidate space is (blocking x dim order x edge
    policy x epilogue fusion): ``edge`` only forks on non-block-multiple
    shapes (where the padded wrapper pays real copies) and ``fuse`` only
    when the caller carries an epilogue (``epi_ops > 0``).  ``verify`` runs
    the static contract pre-check (``analysis.contracts.check_blocks``) so
    geometrically infeasible tilings are pruned BEFORE CMR pricing or
    measured timing.  Never empty: when nothing fits the budget the
    degenerate minimum tile is returned (and priced) as the only
    candidate."""
    cls = classify(m, k, n)
    sublane = spec.sublane(in_bytes)
    cands: list[GemmPlan] = []
    for bm in _bm_candidates(m, sublane):
        for bn in _bn_candidates(n, spec.lane):
            for bk in _bk_candidates(k):
                for order in ("mn", "nm"):
                    if verify and contracts.errors(contracts.check_blocks(
                            "dense", (m, k, n), bm=bm, bn=bn, bk=bk,
                            dim_order=order, in_bytes=in_bytes,
                            out_bytes=out_bytes, spec=spec)):
                        continue
                    for edge in _edge_variants(m, k, n, bm, bn, bk):
                        for fuse in _fuse_variants(epi_ops):
                            e = estimate(m, k, n, bm=bm, bn=bn, bk=bk,
                                         dim_order=order, in_bytes=in_bytes,
                                         out_bytes=out_bytes, edge=edge,
                                         epi_ops=epi_ops, epi_fused=fuse,
                                         b_bytes=b_bytes, spec=spec)
                            if e.vmem_bytes > spec.vmem_budget:
                                continue
                            cands.append(GemmPlan(
                                bm=bm, bn=bn, bk=bk, dim_order=order,
                                gemm_class=cls, est=e, edge=edge,
                                fuse=fuse))
    if not cands:   # degenerate: nothing fit; shrink to minimum tiles
        bm, bn, bk = min(128, ceil_to(m, sublane)), 128, 128
        e = estimate(m, k, n, bm=bm, bn=bn, bk=bk, epi_ops=epi_ops,
                     in_bytes=in_bytes, out_bytes=out_bytes, b_bytes=b_bytes,
                     spec=spec)
        cands.append(GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls, est=e))
    return cands


def batched_candidates(g: int, m: int, k: int, n: int, in_bytes: int = 4,
                       out_bytes: int = 4, shared: str = "none",
                       spec: TpuSpec = TPU_V5E,
                       epi_ops: int = 0, *, verify: bool = True
                       ) -> list[GemmPlan]:
    """Candidate tilings for the batched/grouped GEMM (same enumeration as
    the dense family, including the edge-policy and epilogue-fusion forks
    and the same static contract pre-check; the batch-aware estimator
    decides whether a shared panel earns cross-batch residency)."""
    cls = classify(m, k, n)
    sublane = spec.sublane(in_bytes)
    shared_a, shared_b = shared == "a", shared == "b"
    cands: list[GemmPlan] = []
    for bm in _bm_candidates(m, sublane):
        for bn in _bn_candidates(n, spec.lane):
            for bk in _bk_candidates(k):
                for order in ("mn", "nm"):
                    if verify and contracts.errors(contracts.check_blocks(
                            "batched", (g, m, k, n), bm=bm, bn=bn, bk=bk,
                            dim_order=order, in_bytes=in_bytes,
                            out_bytes=out_bytes, spec=spec)):
                        continue
                    for edge in _edge_variants(m, k, n, bm, bn, bk):
                        for fuse in _fuse_variants(epi_ops):
                            e = estimate_batched(
                                g, m, k, n, bm=bm, bn=bn, bk=bk,
                                dim_order=order, shared_a=shared_a,
                                shared_b=shared_b, in_bytes=in_bytes,
                                out_bytes=out_bytes, edge=edge,
                                epi_ops=epi_ops, epi_fused=fuse, spec=spec)
                            if e.vmem_bytes > spec.vmem_budget:
                                continue
                            cands.append(GemmPlan(
                                bm=bm, bn=bn, bk=bk, dim_order=order,
                                gemm_class=cls, est=e, edge=edge,
                                fuse=fuse))
    if not cands:
        bm, bn, bk = min(128, ceil_to(m, sublane)), 128, 128
        e = estimate_batched(g, m, k, n, bm=bm, bn=bn, bk=bk,
                             shared_a=shared_a, shared_b=shared_b,
                             in_bytes=in_bytes, out_bytes=out_bytes,
                             epi_ops=epi_ops, spec=spec)
        cands.append(GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls, est=e))
    return cands


def _ragged_tile_candidates(total: int, g: int, sublane: int) -> list[int]:
    """Row-tile candidates for the ragged dimension.

    Unlike the dense case, a smaller tile can win: every group boundary
    wastes at most one tile of padded compute, so tiles near the *mean*
    group size keep the boundary waste proportional to the distribution —
    the whole point of pricing off actual sizes instead of the max."""
    top = ceil_to(max(total, 1), sublane)
    mean = max(total // max(g, 1), 1)
    cands = {c for c in (64, 128, 256, 512) if c <= top}
    cands.add(min(ceil_to(mean, sublane), 512, top))
    if total < 64:
        cands.add(top)
    return sorted(cands)


def ragged_candidates(g: int, total: int, k: int, n: int, in_bytes: int = 4,
                      out_bytes: int = 4, ragged: str = "m",
                      spec: TpuSpec = TPU_V5E, *, verify: bool = True,
                      b_bytes: int | None = None
                      ) -> list[GemmPlan]:
    """Candidate tilings for the ragged grouped GEMM: the ragged dimension's
    tile list comes from the *distribution* (mean group size), the dense
    dimensions from the shared dense lists.  No dim_order choice — the
    ragged kernels fix their grid walk.  Same static contract pre-check as
    the dense enumeration."""
    sublane = spec.sublane(in_bytes)
    mean = max(total // max(g, 1), 1)
    if ragged == "m":
        cls = classify(mean, k, n)
        bms = _ragged_tile_candidates(total, g, sublane)
        bns, bks = _bn_candidates(n, spec.lane), _bk_candidates(k)
    elif ragged == "k":
        cls = classify(k, mean, n)
        bms = _bm_candidates(k, sublane)
        bns, bks = _bn_candidates(n, spec.lane), \
            _ragged_tile_candidates(total, g, sublane)
    else:
        raise ValueError(ragged)
    cands: list[GemmPlan] = []
    for bm in bms:
        for bn in bns:
            for bk in bks:
                if verify and contracts.errors(contracts.check_blocks(
                        "ragged", (g, total, k, n), bm=bm, bn=bn, bk=bk,
                        ragged=ragged, in_bytes=in_bytes,
                        out_bytes=out_bytes, spec=spec)):
                    continue
                e = estimate_ragged(g, total, k, n, bm=bm, bn=bn, bk=bk,
                                    ragged=ragged, in_bytes=in_bytes,
                                    out_bytes=out_bytes, b_bytes=b_bytes,
                                    spec=spec)
                if e.vmem_bytes > spec.vmem_budget:
                    continue
                cands.append(GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls,
                                      est=e))
    if not cands:
        bm, bn, bk = min(128, ceil_to(max(total, 1), sublane)), 128, 128
        e = estimate_ragged(g, total, k, n, bm=bm, bn=bn, bk=bk,
                            ragged=ragged, in_bytes=in_bytes,
                            out_bytes=out_bytes, b_bytes=b_bytes, spec=spec)
        cands.append(GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls, est=e))
    return cands


def _better(a: GemmPlan, b: GemmPlan) -> bool:
    ta, tb = a.est.t_total, b.est.t_total
    if abs(ta - tb) > 0.02 * max(ta, tb):
        return ta < tb
    # Tie-break as the paper does: prefer larger bk (more accumulator reuse),
    # then smaller padding waste, then the zero-copy edge policy and the
    # fused epilogue (fewer HBM round-trips at equal modeled time).
    if a.bk != b.bk:
        return a.bk > b.bk
    if a.est.flops_padded != b.est.flops_padded:
        return a.est.flops_padded < b.est.flops_padded
    if a.edge != b.edge:
        return a.edge == "masked"
    return a.fuse and not b.fuse


def argmin_plan(cands: list[GemmPlan]) -> GemmPlan:
    """The analytic winner under the CMR model (with the paper's tie-break
    rules) over one candidate list."""
    best = cands[0]
    for cand in cands[1:]:
        if _better(cand, best):
            best = cand
    return best


def shortlist(cands: list[GemmPlan], top_k: int) -> list[GemmPlan]:
    """The model-pruned search space the measured auto-tuner times: the
    analytic argmin first (so measured mode can never lose to it on the same
    harness run), then the next-best candidates by modeled time."""
    best = argmin_plan(cands)
    ordered = [best] + sorted(
        (c for c in cands if c is not best),
        key=lambda c: (c.est.t_total, c.est.flops_padded))
    seen: set[tuple] = set()
    out: list[GemmPlan] = []
    for c in ordered:
        sig = (c.bm, c.bn, c.bk, c.nsplit, c.dim_order, c.edge, c.fuse)
        if sig in seen:
            continue
        seen.add(sig)
        out.append(c)
        if len(out) >= max(top_k, 1):
            break
    return out


# ---------------------------------------------------------------------------
# Persistent-store consultation: cached measured winners are re-estimated
# (fresh PlanEstimate at the requested spec) and re-validated — the cache
# can suggest a tiling, never force a shape-invalid one.
# ---------------------------------------------------------------------------

def _plan_from_record(rec: dict, estimator, cls: GemmClass,
                      spec: TpuSpec) -> GemmPlan | None:
    try:
        bm, bn, bk = int(rec["bm"]), int(rec["bn"]), int(rec["bk"])
        nsplit = int(rec.get("nsplit", 1))
        order = str(rec.get("dim_order", "mn"))
        edge = str(rec.get("edge", "masked"))
        fuse = bool(rec.get("fuse", True))
    except (KeyError, TypeError, ValueError):
        return None
    if bm <= 0 or bn <= 0 or bk <= 0 or nsplit <= 0 \
            or order not in ("mn", "nm") or bn % spec.lane \
            or edge not in ("masked", "padded"):
        return None
    e = estimator(bm, bn, bk, order, edge)
    if e is None or e.vmem_bytes > spec.vmem_budget:
        return None
    return GemmPlan(bm=bm, bn=bn, bk=bk, nsplit=nsplit, dim_order=order,
                    gemm_class=cls, est=e, mode="cached", edge=edge,
                    fuse=fuse)


def _dtype_extra(b_bytes: int | None, base: str = "") -> str:
    """The plan-store key fragment for a mixed-dtype B operand: ``"bb1"``
    joined onto any family variant with "+".  Homogeneous calls keep their
    legacy key (no fragment) so existing stores stay addressable."""
    if b_bytes is None:
        return base
    frag = f"bb{int(b_bytes)}"
    return f"{base}+{frag}" if base else frag


def _cached_dense(m, k, n, in_bytes, out_bytes, spec,
                  b_bytes=None) -> GemmPlan | None:
    rec = plan_store.get_store().lookup(
        plan_store.shape_key("dense", (m, k, n), in_bytes, out_bytes,
                             extra=_dtype_extra(b_bytes)))
    if rec is None:
        return None

    def est(bm, bn, bk, order, edge="masked"):
        return estimate(m, k, n, bm=bm, bn=bn, bk=bk, nsplit=1,
                        dim_order=order, in_bytes=in_bytes,
                        out_bytes=out_bytes, edge=edge, b_bytes=b_bytes,
                        spec=spec)

    return _plan_from_record(rec, est, classify(m, k, n), spec)


def _cached_batched(g, m, k, n, in_bytes, out_bytes, shared,
                    spec) -> GemmPlan | None:
    rec = plan_store.get_store().lookup(
        plan_store.shape_key("batched", (g, m, k, n), in_bytes, out_bytes,
                             extra=f"shared:{shared}"))
    if rec is None:
        return None

    def est(bm, bn, bk, order, edge="masked"):
        return estimate_batched(g, m, k, n, bm=bm, bn=bn, bk=bk,
                                dim_order=order, shared_a=shared == "a",
                                shared_b=shared == "b", in_bytes=in_bytes,
                                out_bytes=out_bytes, edge=edge, spec=spec)

    return _plan_from_record(rec, est, classify(m, k, n), spec)


def _cached_ragged(g, total, k, n, in_bytes, out_bytes, ragged,
                   spec, b_bytes=None) -> GemmPlan | None:
    rec = plan_store.get_store().lookup(
        plan_store.shape_key("ragged", (g, total, k, n), in_bytes, out_bytes,
                             extra=_dtype_extra(b_bytes,
                                                f"ragged:{ragged}")))
    if rec is None:
        return None
    mean = max(total // max(g, 1), 1)
    cls = classify(mean, k, n) if ragged == "m" else classify(k, mean, n)

    def est(bm, bn, bk, order, edge="masked"):
        if order != "mn":       # ragged kernels fix their grid walk
            return None
        return estimate_ragged(g, total, k, n, bm=bm, bn=bn, bk=bk,
                               ragged=ragged, in_bytes=in_bytes,
                               out_bytes=out_bytes, b_bytes=b_bytes,
                               spec=spec)

    return _plan_from_record(rec, est, cls, spec)


def _cached_placed(family: str, dims: tuple, in_bytes: int, out_bytes: int,
                   num_shards: int, options, spec: TpuSpec,
                   extra: str = ""):
    """Reconstruct a placed measured winner: find the stored strategy among
    the analytic placement options (which carry the modeled collective/waste
    terms) and re-validate the stored local tiling on that option's local
    shape."""
    rec = plan_store.get_store().lookup(
        plan_store.shape_key(family, dims, in_bytes, out_bytes,
                             num_shards=num_shards, extra=extra))
    if rec is None:
        return None
    for opt in options:
        if opt.placement.strategy != rec.get("strategy"):
            continue
        if opt.placement.schedule != rec.get("schedule", "gather"):
            continue
        local = opt.cached_local(rec, in_bytes, out_bytes, spec)
        if local is None:
            return None
        return replace(local, placement=opt.placement, mode="cached")
    return None


# ---------------------------------------------------------------------------
# Placement options — the cross-chip layouts each family chooses between,
# with their modeled ICI/waste terms.  Shared by the analytic placers, the
# cached-plan reconstruction above, and autotune's measured placement search.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PlacementOption:
    """One candidate cross-chip layout: the per-shard local problem
    (``local_dims`` in the family's positional order), the modeled
    ``Placement``, and the margin a challenger must beat the preferred
    (first, collective-free) option by — the paper's "clear modeled win"
    rule for accepting a reduction/exchange strategy."""
    family: str
    local_dims: tuple
    placement: Placement
    margin: float = 1.0
    extra: str = ""

    def plan_local(self, in_bytes: int, out_bytes: int,
                   spec: TpuSpec) -> GemmPlan:
        if self.family == "dense":
            return plan_gemm(*self.local_dims, in_bytes, out_bytes, spec)
        if self.family == "batched":
            return plan_batched_gemm(*self.local_dims, in_bytes, out_bytes,
                                     self.extra, spec)
        return plan_ragged_gemm(*self.local_dims, in_bytes, out_bytes,
                                self.extra, spec)

    def cached_local(self, rec: dict, in_bytes: int = 4, out_bytes: int = 4,
                     spec: TpuSpec = TPU_V5E) -> GemmPlan | None:
        """Re-validate a stored local tiling against this option's local
        shape (fresh estimate under ``spec``); None if shape-invalid."""
        if self.family == "dense":
            m, k, n = self.local_dims

            def est(bm, bn, bk, order, edge="masked"):
                return estimate(m, k, n, bm=bm, bn=bn, bk=bk,
                                dim_order=order, in_bytes=in_bytes,
                                out_bytes=out_bytes, edge=edge, spec=spec)

            return _plan_from_record(rec, est, classify(m, k, n), spec)
        if self.family == "batched":
            g, m, k, n = self.local_dims

            def est(bm, bn, bk, order, edge="masked"):
                return estimate_batched(
                    g, m, k, n, bm=bm, bn=bn, bk=bk, dim_order=order,
                    shared_a=self.extra == "a", shared_b=self.extra == "b",
                    in_bytes=in_bytes, out_bytes=out_bytes, edge=edge,
                    spec=spec)

            return _plan_from_record(rec, est, classify(m, k, n), spec)
        g, total, k, n = self.local_dims
        mean = max(total // max(g, 1), 1)
        cls = classify(mean, k, n) if self.extra == "m" \
            else classify(k, mean, n)

        def est(bm, bn, bk, order, edge="masked"):
            if order != "mn":
                return None
            return estimate_ragged(g, total, k, n, bm=bm, bn=bn, bk=bk,
                                   ragged=self.extra, in_bytes=in_bytes,
                                   out_bytes=out_bytes, spec=spec)

        return _plan_from_record(rec, est, cls, spec)


def dense_placement_options(m: int, k: int, n: int, nc: int,
                            in_bytes: int = 4, out_bytes: int = 4,
                            spec: TpuSpec = TPU_V5E,
                            axis: str | None = None) -> list[PlacementOption]:
    """M-parallel vs K-parallel across ``nc`` chips (paper Alg. 4 vs 5).

    M-parallel: shard M; B replicated; no steady-state collective but a load
    imbalance term when M doesn't fill the chips.  K-parallel: shard K;
    partial C's reduced — a ring all-reduce of the fp32 partials over ICI —
    so it must win by a clear modeled margin (paper §IV-C: K-parallel
    "brings additional overhead of reduction").  K-parallel is offered under
    both schedules: "gather" (compute then psum, times SUM) and "ring" (the
    overlapped collective matmul: output chunks rotate while the next
    chunk's partial is computed — same bytes on the wire, but hidden behind
    compute, so times compose as MAX)."""
    sublane = spec.sublane(in_bytes)
    m_local = ceil_to(max(cdiv(m, nc), 1), sublane)
    waste_m = (cdiv(m, nc) * nc) / max(m, 1)
    opts = [PlacementOption(
        "dense", (m_local, k, n),
        Placement("m_parallel", nc, axis=axis, waste=waste_m))]

    k_local = ceil_to(max(cdiv(k, nc), 1), 128)
    ring = 2.0 * (nc - 1) / nc
    t_red = ring * (m * n * 4) / (spec.ici_bw_per_link * spec.ici_links)
    for schedule in ("ring", "gather"):
        opts.append(PlacementOption(
            "dense", (m, k_local, n),
            Placement("k_parallel", nc, axis=axis, t_collective=t_red,
                      ici_bytes=ring * m * n * 4 * nc, schedule=schedule),
            margin=1.15))
    return opts


def batched_placement_options(g: int, m: int, k: int, n: int, nc: int,
                              in_bytes: int = 4, out_bytes: int = 4,
                              shared: str = "none", spec: TpuSpec = TPU_V5E,
                              axis: str | None = None) -> list[PlacementOption]:
    """Per-entry m_parallel (rows sharded, every shard streams all G panels)
    vs expert_parallel (the G dim sharded, tokens all-to-all'd to their
    owning shard and back, priced by ``estimate_ep``); EP must amortize its
    exchange before it displaces the collective-free layout."""
    sublane = spec.sublane(in_bytes)
    m_l = ceil_to(max(cdiv(m, nc), 1), sublane)
    waste_m = (cdiv(m, nc) * nc) / max(m, 1)
    opts = [PlacementOption(
        "batched", (g, m_l, k, n),
        Placement("m_parallel", nc, axis=axis, waste=waste_m),
        extra=shared)]

    g_l = max(cdiv(g, nc), 1)
    ex = estimate_ep(g * m, k, nc, elt_bytes=in_bytes, spec=spec) \
        + estimate_ep(g * m, n, nc, elt_bytes=out_bytes, spec=spec)
    waste_g = (g_l * nc) / max(g, 1)
    opts.append(PlacementOption(
        "batched", (g_l, m, k, n),
        Placement("expert_parallel", nc, axis=axis,
                  t_collective=ex.t_exchange, ici_bytes=ex.ici_bytes,
                  waste=waste_g),
        margin=1.1, extra=shared))
    return opts


def ragged_placement_options(g: int, total: int, k: int, n: int, nc: int,
                             in_bytes: int = 4, out_bytes: int = 4,
                             ragged: str = "m", spec: TpuSpec = TPU_V5E,
                             axis: str | None = None) -> list[PlacementOption]:
    """Token-parallel (rows sharded, weights replicated) vs expert-parallel
    (groups sharded + the two token-exchange legs), with EP offered under
    both schedules.  EP "ring" is the overlapped collective matmul: token
    blocks rotate around the mesh and each shard computes only the blocks
    intersecting its owned window, so per-shard compute is ~2 block-spans of
    owned rows (priced as ``min(total, 2 * t_l)`` local rows) and the
    rotation bytes hide behind it (MAX composition).  EP "gather" is the
    unoverlapped exchange + ONE local GEMM over the worst-case window —
    every row could route to this shard's experts, so its local estimate
    honestly prices the FULL ``total`` rows (the old mean-rows pricing
    predicted a 3.65x EP win where measurement showed a 4.8x loss).  The EP
    backward dW (``ragged == "k"``) contracts rows that already live on the
    owning shard after the forward exchange — expert-local, no collective,
    no alternative."""
    t_l = max(cdiv(total, nc), 1)
    g_l = max(cdiv(g, nc), 1)
    waste = (cdiv(total, nc) * nc) / max(total, 1)
    if ragged == "k":
        return [PlacementOption(
            "ragged", (g_l, t_l, k, n),
            Placement("expert_parallel", nc, axis=axis, waste=waste),
            extra="k")]
    opts = [PlacementOption(
        "ragged", (g, t_l, k, n),
        Placement("m_parallel", nc, axis=axis, waste=waste), extra="m")]
    # Ring: (nc-1) x-block hops + nc output-block hops per shard.
    per_shard = ((nc - 1) * t_l * k * in_bytes
                 + nc * t_l * n * out_bytes)
    t_ring = per_shard / (spec.ici_bw_per_link * spec.ici_links)
    opts.append(PlacementOption(
        "ragged", (g_l, min(total, 2 * t_l), k, n),
        Placement("expert_parallel", nc, axis=axis, t_collective=t_ring,
                  ici_bytes=float(per_shard) * nc, waste=waste,
                  schedule="ring"),
        margin=1.1, extra="m"))
    ex = estimate_ep(total, k, nc, elt_bytes=in_bytes, spec=spec) \
        + estimate_ep(total, n, nc, elt_bytes=out_bytes, spec=spec)
    opts.append(PlacementOption(
        "ragged", (g_l, total, k, n),
        Placement("expert_parallel", nc, axis=axis,
                  t_collective=ex.t_exchange, ici_bytes=ex.ici_bytes,
                  waste=waste),
        margin=1.1, extra="m"))
    return opts


def _select_placed(scored: list[tuple[PlacementOption, GemmPlan]]) -> GemmPlan:
    """Pick among placed candidates: the first (collective-free) option is
    preferred; a challenger must beat it by its margin (the paper's "clear
    modeled win" rule, shared with autotune's measured placement search)."""
    best = scored[0][1]
    for opt, cand in scored[1:]:
        if cand.t_total * opt.margin < best.t_total:
            best = cand
    return best


@functools.lru_cache(maxsize=4096)
def preferred_ep_schedule(
    g: int, total: int, k: int, n: int,
    in_bytes: int = 4, out_bytes: int = 4,
    num_shards: int = 1,
    spec: TpuSpec = TPU_V5E,
    serial: int = 1,
) -> str:
    """Which EP exchange schedule the model prefers for this ragged shape:
    "ring" (overlapped) or "gather" (unoverlapped).  This is the planner
    knob the EP executors consult when the caller doesn't force a schedule
    (``REPRO_EP_SCHEDULE`` / explicit kwarg override it).

    ``serial`` multiplies the LOCAL term of every option: on a real mesh
    it is 1 (each shard has its own chip), but on a timeshared host mesh
    (fake devices forced onto one CPU) the shards' local GEMMs serialize,
    so wall-clock prediction needs the per-chip local time scaled by the
    shard count.  The executors pass ``serial=nc`` on the CPU backend —
    which is exactly why the gather schedule's worst-case-full-window
    compute loses there (the measured 4.8x EP slowdown) while the ring's
    owned-rows-only compute wins."""
    if num_shards <= 1:
        return "gather"
    spec = effective_spec(spec)
    best_t, best_s = float("inf"), "gather"
    for o in ragged_placement_options(g, total, k, n, num_shards, in_bytes,
                                      out_bytes, "m", spec):
        if o.placement.strategy != "expert_parallel":
            continue
        local = o.plan_local(in_bytes, out_bytes, spec).est.t_total \
            * o.placement.waste * max(1, serial)
        if o.placement.schedule == "ring":
            t = max(local, o.placement.t_collective)
        else:
            t = local + o.placement.t_collective
        if t < best_t:
            best_t, best_s = t, o.placement.schedule
    return best_s


# ---------------------------------------------------------------------------
# Planners
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8192)
def plan_gemm(
    m: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    spec: TpuSpec = TPU_V5E,
    *,
    num_shards: int = 1,
    axis: str | None = None,
    epi_ops: int = 0,
    b_bytes: int | None = None,
) -> GemmPlan:
    """Pick the best tiling for C(M,N) += A(M,K) B(K,N) — and, when
    ``num_shards > 1``, the cross-chip strategy too: the returned plan is the
    per-shard tiling of the winning layout with its ``Placement`` attached
    (m_parallel vs k_parallel, scored with the psum ICI term).  Consults the
    persistent measured-plan store first (``mode == "cached"``); otherwise
    falls back to the analytic CMR argmin.

    ``epi_ops > 0`` declares a post-GEMM elementwise tail of that many ops
    (``Epilogue.num_ops``): the candidate space then forks on fusing it into
    the accumulator flush vs running it as separate passes, and the winner's
    ``fuse`` records the decision (alongside ``edge``, the masked-vs-padded
    remainder-tile policy).

    ``b_bytes`` is the dtype axis of the plan key: the B (weight) operand's
    element width when it differs from A's — the weight-only quantized GEMMs
    (int8/int4-unpacked weights against bf16/fp32 activations) — so traffic,
    VMEM and the achievable peak are priced per dtype combination and cached
    winners never leak across widths (the key carries a ``bb{n}`` extra)."""
    spec = effective_spec(spec)
    if num_shards > 1:
        opts = dense_placement_options(m, k, n, num_shards, in_bytes,
                                       out_bytes, spec, axis)
        cached = _cached_placed("dense", (m, k, n), in_bytes, out_bytes,
                                num_shards, opts, spec)
        if cached is not None:
            return cached
        scored = [(o, replace(o.plan_local(in_bytes, out_bytes, spec),
                              placement=o.placement)) for o in opts]
        return _select_placed(scored)
    cached = _cached_dense(m, k, n, in_bytes, out_bytes, spec, b_bytes)
    if cached is not None:
        return cached
    return argmin_plan(gemm_candidates(m, k, n, in_bytes, out_bytes, spec,
                                       epi_ops, b_bytes=b_bytes))


@functools.lru_cache(maxsize=8192)
def plan_distributed(
    m: int, k: int, n: int,
    num_cores: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    spec: TpuSpec = TPU_V5E,
) -> DistPlan:
    """Choose M-parallel vs K-parallel across ``num_cores`` chips (the
    dense-only compat entry point; ``plan_gemm(..., num_shards=n)`` is the
    unified spelling and returns the same placed plan).  Unlike plan_gemm —
    whose num_shards=1 means "unplaced" — a degenerate single-core request
    still gets an (m_parallel, 1 shard, no collective) placement here, so
    ``.strategy`` / ``.num_cores`` always read."""
    spec = effective_spec(spec)
    nc = max(num_cores, 1)
    opts = dense_placement_options(m, k, n, nc, in_bytes, out_bytes, spec,
                                   None)
    cached = _cached_placed("dense", (m, k, n), in_bytes, out_bytes, nc, opts,
                            spec)
    if cached is not None:
        return DistPlan(local=cached, placement=cached.placement,
                        est=cached.est, mode="cached")
    scored = [(o, replace(o.plan_local(in_bytes, out_bytes, spec),
                          placement=o.placement)) for o in opts]
    p = _select_placed(scored)
    return DistPlan(local=p, placement=p.placement, est=p.est, mode=p.mode)


@functools.lru_cache(maxsize=8192)
def plan_batched_gemm(
    g: int, m: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    shared: str = "none",            # "none" | "a" | "b"
    spec: TpuSpec = TPU_V5E,
    *,
    num_shards: int = 1,
    axis: str | None = None,
    epi_ops: int = 0,
) -> GemmPlan:
    """Pick the best tiling for the batched GEMM C(g) += A(g) B(g).

    ``shared`` marks a 2-D operand reused by every batch entry (the grouped
    case); the batch-aware CMR model then credits cross-batch residency when
    the tiling actually earns it (single resident block), mirroring the
    paper's loop-order-for-reuse analysis with the batch as the outermost
    loop.  The per-entry shape is classified with the 2-D taxonomy (each MoE
    expert GEMM is T3/T1 per shard regardless of E).

    ``num_shards > 1``: place the batched GEMM on the mesh — per-entry
    m_parallel (rows sharded, every shard streams all G panels) vs
    expert_parallel (the G dim sharded, tokens all-to-all'd to their owning
    shard and back, priced by ``estimate_ep``)."""
    spec = effective_spec(spec)
    if num_shards > 1:
        opts = batched_placement_options(g, m, k, n, num_shards, in_bytes,
                                         out_bytes, shared, spec, axis)
        cached = _cached_placed("batched", (g, m, k, n), in_bytes, out_bytes,
                                num_shards, opts, spec,
                                extra=f"shared:{shared}")
        if cached is not None:
            return cached
        scored = [(o, replace(o.plan_local(in_bytes, out_bytes, spec),
                              placement=o.placement)) for o in opts]
        return _select_placed(scored)
    cached = _cached_batched(g, m, k, n, in_bytes, out_bytes, shared, spec)
    if cached is not None:
        return cached
    return argmin_plan(batched_candidates(g, m, k, n, in_bytes, out_bytes,
                                          shared, spec, epi_ops))


@functools.lru_cache(maxsize=8192)
def plan_ragged_gemm(
    g: int, total: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    ragged: str = "m",
    spec: TpuSpec = TPU_V5E,
    *,
    num_shards: int = 1,
    axis: str | None = None,
    b_bytes: int | None = None,
) -> GemmPlan:
    """Pick the best tiling for a ragged grouped GEMM over G groups.

    The cache key (g, total, k, n, dtype widths, ragged, placement request)
    is the *distribution signature*: per-group counts are dynamic (traced)
    so the plan prices the aggregate — total ragged rows plus one boundary
    tile per group — and is re-used by every call whose signature matches
    (one tuning per MoE layer shape, free afterwards, exactly like the
    paper's dynamic adjusting).

    ``ragged == "m"``: forward — (total, k) rows against per-group (k, n)
    panels; ``bm`` tiles the ragged rows.  ``ragged == "k"``: backward dW —
    the ragged dimension contracts (T2 per group); ``bk`` tiles it, ``k`` is
    the output panel's row dim.  The per-group *mean* shape is classified
    with the 2-D taxonomy (a balanced MoE dispatch is T3/T1 per expert).

    ``num_shards > 1``: place the ragged GEMM on the mesh — token-parallel
    (rows sharded, weights replicated: no collective but every shard streams
    all G panels) vs expert-parallel (groups sharded: only G/num_shards
    panels per shard, paid for with the two all-to-all token-exchange legs
    priced by ``estimate_ep``).  EP wins exactly when the panel-traffic
    saving amortizes the exchange — few tokens against many/large expert
    panels, the MoE decode regime.
    """
    spec = effective_spec(spec)
    if num_shards > 1:
        opts = ragged_placement_options(g, total, k, n, num_shards, in_bytes,
                                        out_bytes, ragged, spec, axis)
        cached = _cached_placed("ragged", (g, total, k, n), in_bytes,
                                out_bytes, num_shards, opts, spec,
                                extra=f"ragged:{ragged}")
        if cached is not None:
            return cached
        scored = [(o, replace(o.plan_local(in_bytes, out_bytes, spec),
                              placement=o.placement)) for o in opts]
        return _select_placed(scored)
    cached = _cached_ragged(g, total, k, n, in_bytes, out_bytes, ragged, spec,
                            b_bytes)
    if cached is not None:
        return cached
    return argmin_plan(ragged_candidates(g, total, k, n, in_bytes, out_bytes,
                                         ragged, spec, b_bytes=b_bytes))


@dataclass(frozen=True)
class MoeDispatchPlan(Plan):
    """Dispatch-mode x placement pricing for one MoE layer shape.

    ``rows`` is the effective expert-GEMM row count the dispatch mode
    produces: E x capacity for "capacity" (every expert padded to the max,
    overflow dropped), T x top_k for "ragged" (every routed copy, nothing
    else).  The roofline prices the layer's GEMM flops/bytes off ``rows``
    and its EP exchange off ``placement`` — ONE source of truth instead of
    per-consumer special cases."""
    rows: int
    est: PlanEstimate | None = None
    placement: Placement | None = None
    mode: str = "analytic"


@functools.lru_cache(maxsize=8192)
def plan_moe_dispatch(
    t: int, e: int, top_k: int, d_model: int, d_ff: int,
    *,
    dispatch: str = "capacity",
    capacity_factor: float = 1.25,
    elt_bytes: int = 2,
    num_shards: int = 1,
    axis: str | None = None,
    spec: TpuSpec = TPU_V5E,
) -> MoeDispatchPlan:
    """Price one MoE layer's dispatch mode + expert placement.

    ``num_shards > 1`` attaches the expert-parallel ``Placement`` with the
    two all-to-all legs of the FUSED pipeline (``ep_ragged_moe``): tokens
    out and back in d_model width, priced by ``estimate_ep`` — the d_ff-wide
    hidden is produced and consumed on the shard owning the expert and
    never crosses the axis.  (``d_ff`` stays in the signature/cache key: it
    sizes the layer's GEMMs for the rows-based pricing consumers.)"""
    spec = effective_spec(spec)
    if dispatch == "ragged":
        rows = t * top_k
    elif dispatch == "capacity":
        s = spec.sublane(elt_bytes)
        c = int(t * top_k * capacity_factor / e)
        rows = e * max(s, ceil_to(c, s))
    else:
        raise ValueError(f"unknown moe dispatch: {dispatch}")
    placement = None
    if num_shards > 1:
        leg = estimate_ep(rows, d_model, num_shards,
                          elt_bytes=elt_bytes, spec=spec)
        ex: EpEstimate = leg + leg            # dispatch + return
        placement = Placement("expert_parallel", num_shards, axis=axis,
                              t_collective=ex.t_exchange,
                              ici_bytes=ex.ici_bytes)
    return MoeDispatchPlan(rows=rows, placement=placement)


def tgemm_plan(m: int, k: int, n: int,
               in_bytes: int = 4, out_bytes: int = 4,
               spec: TpuSpec = TPU_V5E) -> GemmPlan:
    """The TGEMM baseline (paper Alg. 1): ONE fixed blocking for all shapes —
    (m_g=512, k_g=512, n_a=96, m_s=6) on FT-m7032; the TPU analogue keeps a
    fixed regular-GEMM tile (256, 256, 256) and pads everything into it."""
    bm, bn, bk = 256, 256, 256
    e = estimate(m, k, n, bm=bm, bn=bn, bk=bk,
                 in_bytes=in_bytes, out_bytes=out_bytes, spec=spec)
    return GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=classify(m, k, n), est=e)


# ---------------------------------------------------------------------------
# Plan-mode telemetry: dispatch and the mesh executors report which tuning
# loop (analytic / measured / cached) served each planned GEMM they trace.
# ---------------------------------------------------------------------------

PLAN_MODE_COUNTS: collections.Counter = collections.Counter()
EPILOGUE_COUNTS: collections.Counter = collections.Counter()
DEGRADED_COUNTS: collections.Counter = collections.Counter()


def note_plan_use(family: str, plan: Plan) -> None:
    """Executors call this when a plan reaches an execution path (trace
    time).  Keyed (family, mode) so ``plan_mode_stats`` shows whether the
    workload is being served by measurements or by the unvalidated model."""
    PLAN_MODE_COUNTS[(family, getattr(plan, "mode", "analytic"))] += 1


def note_epilogue(family: str, fused: bool) -> None:
    """Executors call this when they serve a GEMM that CARRIES an epilogue
    (identity epilogues don't count): ``fused`` means the elementwise tail
    ran in the same kernel/jit as the GEMM (the accumulator-flush fusion or
    the single-jit XLA fallback), not as separate output passes."""
    EPILOGUE_COUNTS[(family, "fused" if fused else "separate")] += 1


def note_degraded(family: str, rung: str) -> None:
    """Executors call this when a fallback-ladder rung serves a GEMM the
    primary engine failed on (kernel launch failure, collective failure,
    contract-violating plan).  Keyed (family, rung) — e.g. ``("dense",
    "pallas->xla")`` or ``("ep", "ring->gather")`` — so ``plan_mode_stats``
    surfaces degraded servings next to the plan modes and serve ``health()``
    can report degraded mode."""
    DEGRADED_COUNTS[(family, rung)] += 1


def degraded_stats() -> dict[str, int]:
    """{"family:rung": count} census of fallback-ladder servings (empty ==
    every planned GEMM ran on its primary engine)."""
    return {f"{family}:{rung}": count
            for (family, rung), count in sorted(DEGRADED_COUNTS.items())}


def epilogue_stats() -> dict[str, dict[str, int]]:
    """{family: {"fused"|"separate": count}} census of epilogue servings."""
    out: dict[str, dict[str, int]] = {}
    for (family, kind), count in sorted(EPILOGUE_COUNTS.items()):
        out.setdefault(family, {})[kind] = count
    return out


def plan_mode_stats() -> dict[str, dict[str, int]]:
    """{family: {mode: count}} census of plans that reached executors.  When
    any epilogue-carrying GEMMs were served, an extra ``"epilogue"`` entry
    reports fused-vs-separate coverage (``epilogue_stats`` aggregated) so
    serve warmup can print fusion coverage alongside the plan modes.
    Cached records the static verifier quarantined at load time show up as
    a per-family ``"quarantined"`` count — those shapes silently fell back
    to analytic planning, which this makes visible."""
    out: dict[str, dict[str, int]] = {}
    for (family, mode), count in sorted(PLAN_MODE_COUNTS.items()):
        out.setdefault(family, {})[mode] = count
    for key in plan_store.get_store().quarantined:
        family = key.split("|", 1)[0]
        fam = out.setdefault(family, {})
        fam["quarantined"] = fam.get("quarantined", 0) + 1
    epi: dict[str, int] = {}
    for (_family, kind), count in EPILOGUE_COUNTS.items():
        epi[kind] = epi.get(kind, 0) + count
    if epi:
        out["epilogue"] = dict(sorted(epi.items()))
    if DEGRADED_COUNTS:
        # Degraded servings: how many GEMMs a fallback-ladder rung served
        # after the primary engine failed (chaos-injected or real).
        out["degraded"] = degraded_stats()
    return out


def clear_plan_cache() -> None:
    """Reset EVERY plan-serving layer from one entry point: the five planner
    LRUs, the in-memory persistent store view, the mode-telemetry counters,
    the dispatch-level custom-VJP caches, and the bounded mesh-executor
    caches in ``distributed`` — executors close over planner state when they
    trace, so leaving them alive across a spec/cache reset serves stale
    plans (the bug this replaces: only the five LRUs were cleared)."""
    plan_gemm.cache_clear()
    plan_batched_gemm.cache_clear()
    plan_ragged_gemm.cache_clear()
    plan_distributed.cache_clear()
    plan_moe_dispatch.cache_clear()
    preferred_ep_schedule.cache_clear()
    PLAN_MODE_COUNTS.clear()
    EPILOGUE_COUNTS.clear()
    DEGRADED_COUNTS.clear()
    plan_store.reset_store()
    # Executor layers import the tuner; import them lazily to avoid cycles.
    from . import dispatch, distributed
    dispatch.clear_dispatch_caches()
    distributed.clear_executor_caches()


def clear_planner_caches() -> None:
    """Invalidate only the five planner LRUs — the minimal reset after the
    persistent store gains entries/calibration (``autotune`` calls this so
    the next ``plan_*`` consults the updated store; executors stay warm
    because their traced plans are re-planned per shape signature)."""
    plan_gemm.cache_clear()
    plan_batched_gemm.cache_clear()
    plan_ragged_gemm.cache_clear()
    plan_distributed.cache_clear()
    plan_moe_dispatch.cache_clear()
    preferred_ep_schedule.cache_clear()
