"""Dynamic adjusting (paper §IV-C): choose parallelization strategy, block
sizes, AND mesh placement per GEMM shape, at trace time, from the CMR model.

The paper fixes initial block sizes from CMR + capacity, then adjusts them to
the actual matrix shape at run time, and picks M-parallel vs K-parallel from
the shape (K-parallel iff M and N are both small and K is large, because only
splitting K can occupy all 8 DSP cores).  Here that decision is one level of
a unified *plan hierarchy*:

  * every planner (``plan_gemm`` / ``plan_batched_gemm`` /
    ``plan_ragged_gemm``) returns a ``Plan`` whose single-core tiling
    (bm, bn, bk, dim_order) comes from enumerating aligned candidates and
    scoring with ``cmr.estimate*`` under the VMEM budget;
  * when asked to place the GEMM on a mesh (``num_shards > 1``), the same
    plan additionally carries a ``Placement`` — the cross-chip strategy
    (m_parallel / k_parallel / expert_parallel), the modeled ICI collective
    term (psum for K-parallel, the token all-to-all for expert-parallel via
    ``cmr.estimate_ep``) and the load-imbalance waste — so strategy x
    blocking is ONE joint auto-tuning decision, mirroring Eqs. 1-4's
    num_core terms at mesh scale;
  * plans are LRU-cached per shape signature — the paper's "dynamic
    adjusting" happens once per (shape, dtype, placement request) and is
    free afterwards.

``plan_distributed`` survives as the dense-only compat view (``DistPlan``);
``tgemm_plan`` reproduces the TGEMM strawman the paper compares against: one
fixed micro-kernel/block configuration regardless of shape, with implicit
padding of N (its waste shows up in ``est.flops_padded`` / traffic).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace

from .cmr import (TPU_V5E, EpEstimate, PlanEstimate, TpuSpec, cdiv, ceil_to,
                  estimate, estimate_batched, estimate_ep, estimate_ragged)
from .shapes import GemmClass, classify


@dataclass(frozen=True)
class Placement:
    """Where a plan runs on the mesh.  ``None`` placement = single device.

    ``strategy`` is the paper's parallelization mode lifted to the mesh:
    "m_parallel" (Alg. 4: shard rows, replicate panels, no steady-state
    collective), "k_parallel" (Alg. 5: shard the contraction, psum the fp32
    partials) or "expert_parallel" (shard the group/expert dim, all-to-all
    the tokens to their owning shard and back).  ``t_collective`` is the
    modeled ICI term of that choice, ``ici_bytes`` the global bytes it moves,
    and ``waste`` the load-imbalance multiplier on the local estimate.
    """
    strategy: str                   # m_parallel | k_parallel | expert_parallel
    num_shards: int = 1
    axis: str | None = None         # mesh axis name (advisory; executors bind)
    t_collective: float = 0.0       # modeled ICI cost (s) per call
    ici_bytes: float = 0.0          # global bytes over ICI per call
    waste: float = 1.0              # >= 1: shard-imbalance multiplier


class Plan:
    """Base of the unified plan hierarchy: a local CMR estimate (``est``)
    plus an optional ``Placement``.  ``t_total`` composes them the same way
    for every family: local time x imbalance waste + ICI collective."""

    est: PlanEstimate | None
    placement: Placement | None

    @property
    def t_total(self) -> float:
        t = self.est.t_total if self.est is not None else 0.0
        p = self.placement
        if p is not None:
            t = t * p.waste + p.t_collective
        return t

    @property
    def strategy(self) -> str:
        return self.placement.strategy if self.placement is not None \
            else "single"


@dataclass(frozen=True)
class GemmPlan(Plan):
    bm: int
    bn: int
    bk: int
    nsplit: int = 1                 # in-kernel split-K factor
    dim_order: str = "mn"
    gemm_class: GemmClass = GemmClass.REGULAR
    est: PlanEstimate | None = None
    placement: Placement | None = None

    def kernel_kwargs(self) -> dict:
        return dict(bm=self.bm, bn=self.bn, bk=self.bk,
                    nsplit=self.nsplit, dim_order=self.dim_order)


@dataclass(frozen=True)
class DistPlan(Plan):
    """Compat view of a placed dense plan (the paper's two cross-chip
    strategies).  ``local`` is the per-shard ``GemmPlan``; strategy/cost
    accessors read through to its ``Placement``."""
    local: GemmPlan
    placement: Placement
    est: PlanEstimate | None = None

    @property
    def num_cores(self) -> int:
        return self.placement.num_shards

    @property
    def t_collective(self) -> float:
        return self.placement.t_collective


def _bm_candidates(m: int, sublane: int) -> list[int]:
    cands = [c for c in (128, 256, 512, 1024) if c <= ceil_to(m, sublane)]
    if m < 128:
        cands.append(ceil_to(m, sublane))
    return sorted(set(cands)) or [ceil_to(m, sublane)]


def _bn_candidates(n: int, lane: int) -> list[int]:
    top = ceil_to(n, lane)
    cands = [c for c in (128, 256, 512) if c <= top]
    if top <= 1024:
        cands.append(top)
    return sorted(set(cands)) or [top]


def _bk_candidates(k: int) -> list[int]:
    top = ceil_to(k, 128)
    cands = [c for c in (128, 256, 512, 1024, 2048) if c <= top]
    if top <= 4096:
        cands.append(top)   # full-K residency — enables gk == 1 reuse
    return sorted(set(cands)) or [top]


@functools.lru_cache(maxsize=8192)
def plan_gemm(
    m: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    spec: TpuSpec = TPU_V5E,
    *,
    num_shards: int = 1,
    axis: str | None = None,
) -> GemmPlan:
    """Pick the best tiling for C(M,N) += A(M,K) B(K,N) — and, when
    ``num_shards > 1``, the cross-chip strategy too: the returned plan is the
    per-shard tiling of the winning layout with its ``Placement`` attached
    (m_parallel vs k_parallel, scored with the psum ICI term)."""
    if num_shards > 1:
        return _plan_dense_placed(m, k, n, num_shards, in_bytes, out_bytes,
                                  spec, axis)
    cls = classify(m, k, n)
    sublane = spec.sublane(in_bytes)
    best: GemmPlan | None = None
    for bm in _bm_candidates(m, sublane):
        for bn in _bn_candidates(n, spec.lane):
            for bk in _bk_candidates(k):
                for order in ("mn", "nm"):
                    e = estimate(m, k, n, bm=bm, bn=bn, bk=bk,
                                 dim_order=order, in_bytes=in_bytes,
                                 out_bytes=out_bytes, spec=spec)
                    if e.vmem_bytes > spec.vmem_budget:
                        continue
                    cand = GemmPlan(bm=bm, bn=bn, bk=bk, dim_order=order,
                                    gemm_class=cls, est=e)
                    if best is None or _better(cand, best):
                        best = cand
    if best is None:  # degenerate: nothing fit; shrink to minimum tiles
        bm, bn, bk = min(128, ceil_to(m, sublane)), 128, 128
        e = estimate(m, k, n, bm=bm, bn=bn, bk=bk,
                     in_bytes=in_bytes, out_bytes=out_bytes, spec=spec)
        best = GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls, est=e)
    return best


def _better(a: GemmPlan, b: GemmPlan) -> bool:
    ta, tb = a.est.t_total, b.est.t_total
    if abs(ta - tb) > 0.02 * max(ta, tb):
        return ta < tb
    # Tie-break as the paper does: prefer larger bk (more accumulator reuse),
    # then smaller padding waste.
    if a.bk != b.bk:
        return a.bk > b.bk
    return a.est.flops_padded < b.est.flops_padded


def _plan_dense_placed(
    m: int, k: int, n: int, nc: int,
    in_bytes: int, out_bytes: int, spec: TpuSpec, axis: str | None,
) -> GemmPlan:
    """M-parallel vs K-parallel across ``nc`` chips (paper Alg. 4 vs 5).

    M-parallel: shard M; B replicated; no steady-state collective but a load
    imbalance term when M doesn't fill the chips.  K-parallel: shard K;
    partial C's reduced — a ring all-reduce of the fp32 partials over ICI.
    """
    sublane = spec.sublane(in_bytes)

    m_local = max(cdiv(m, nc), 1)
    pm = plan_gemm(ceil_to(m_local, sublane), k, n, in_bytes, out_bytes, spec)
    waste_m = (cdiv(m, nc) * nc) / max(m, 1)
    pm = replace(pm, placement=Placement("m_parallel", nc, axis=axis,
                                         waste=waste_m))

    k_local = max(cdiv(k, nc), 1)
    pk = plan_gemm(m, ceil_to(k_local, 128), n, in_bytes, out_bytes, spec)
    ring = 2.0 * (nc - 1) / nc
    t_red = ring * (m * n * 4) / (spec.ici_bw_per_link * spec.ici_links)
    pk = replace(pk, placement=Placement(
        "k_parallel", nc, axis=axis, t_collective=t_red,
        ici_bytes=ring * m * n * 4 * nc))

    # Paper §IV-C: K-parallel "brings additional overhead of reduction" and
    # is reserved for shapes where M cannot occupy the cores — require a
    # clear modeled win before accepting the reduction strategy.
    if pm.t_total <= pk.t_total * 1.15:
        return pm
    return pk


@functools.lru_cache(maxsize=8192)
def plan_distributed(
    m: int, k: int, n: int,
    num_cores: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    spec: TpuSpec = TPU_V5E,
) -> DistPlan:
    """Choose M-parallel vs K-parallel across ``num_cores`` chips (the
    dense-only compat entry point; ``plan_gemm(..., num_shards=n)`` is the
    unified spelling and returns the same placed plan).  Unlike plan_gemm —
    whose num_shards=1 means "unplaced" — a degenerate single-core request
    still gets an (m_parallel, 1 shard, no collective) placement here, so
    ``.strategy`` / ``.num_cores`` always read."""
    p = _plan_dense_placed(m, k, n, max(num_cores, 1), in_bytes, out_bytes,
                           spec, None)
    return DistPlan(local=p, placement=p.placement, est=p.est)


@functools.lru_cache(maxsize=8192)
def plan_batched_gemm(
    g: int, m: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    shared: str = "none",            # "none" | "a" | "b"
    spec: TpuSpec = TPU_V5E,
    *,
    num_shards: int = 1,
    axis: str | None = None,
) -> GemmPlan:
    """Pick the best tiling for the batched GEMM C(g) += A(g) B(g).

    ``shared`` marks a 2-D operand reused by every batch entry (the grouped
    case); the batch-aware CMR model then credits cross-batch residency when
    the tiling actually earns it (single resident block), mirroring the
    paper's loop-order-for-reuse analysis with the batch as the outermost
    loop.  The per-entry shape is classified with the 2-D taxonomy (each MoE
    expert GEMM is T3/T1 per shard regardless of E).

    ``num_shards > 1``: place the batched GEMM on the mesh — per-entry
    m_parallel (rows sharded, every shard streams all G panels) vs
    expert_parallel (the G dim sharded, tokens all-to-all'd to their owning
    shard and back, priced by ``estimate_ep``)."""
    if num_shards > 1:
        return _plan_batched_placed(g, m, k, n, num_shards, in_bytes,
                                    out_bytes, shared, spec, axis)
    cls = classify(m, k, n)
    sublane = spec.sublane(in_bytes)
    shared_a, shared_b = shared == "a", shared == "b"
    best: GemmPlan | None = None
    for bm in _bm_candidates(m, sublane):
        for bn in _bn_candidates(n, spec.lane):
            for bk in _bk_candidates(k):
                for order in ("mn", "nm"):
                    e = estimate_batched(
                        g, m, k, n, bm=bm, bn=bn, bk=bk, dim_order=order,
                        shared_a=shared_a, shared_b=shared_b,
                        in_bytes=in_bytes, out_bytes=out_bytes, spec=spec)
                    if e.vmem_bytes > spec.vmem_budget:
                        continue
                    cand = GemmPlan(bm=bm, bn=bn, bk=bk, dim_order=order,
                                    gemm_class=cls, est=e)
                    if best is None or _better(cand, best):
                        best = cand
    if best is None:  # degenerate: nothing fit; shrink to minimum tiles
        bm, bn, bk = min(128, ceil_to(m, sublane)), 128, 128
        e = estimate_batched(g, m, k, n, bm=bm, bn=bn, bk=bk,
                             shared_a=shared_a, shared_b=shared_b,
                             in_bytes=in_bytes, out_bytes=out_bytes,
                             spec=spec)
        best = GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls, est=e)
    return best


def _plan_batched_placed(
    g: int, m: int, k: int, n: int, nc: int,
    in_bytes: int, out_bytes: int, shared: str, spec: TpuSpec,
    axis: str | None,
) -> GemmPlan:
    sublane = spec.sublane(in_bytes)
    m_l = ceil_to(max(cdiv(m, nc), 1), sublane)
    pm = plan_batched_gemm(g, m_l, k, n, in_bytes, out_bytes, shared, spec)
    waste_m = (cdiv(m, nc) * nc) / max(m, 1)
    pm = replace(pm, placement=Placement("m_parallel", nc, axis=axis,
                                         waste=waste_m))

    g_l = max(cdiv(g, nc), 1)
    pe = plan_batched_gemm(g_l, m, k, n, in_bytes, out_bytes, shared, spec)
    ex = estimate_ep(g * m, k, nc, elt_bytes=in_bytes, spec=spec) \
        + estimate_ep(g * m, n, nc, elt_bytes=out_bytes, spec=spec)
    waste_g = (g_l * nc) / max(g, 1)
    pe = replace(pe, placement=Placement(
        "expert_parallel", nc, axis=axis, t_collective=ex.t_exchange,
        ici_bytes=ex.ici_bytes, waste=waste_g))
    # EP must amortize its exchange before it displaces the collective-free
    # token-parallel layout (same "clear win" rule as K-parallel).
    if pe.t_total * 1.1 < pm.t_total:
        return pe
    return pm


def _ragged_tile_candidates(total: int, g: int, sublane: int) -> list[int]:
    """Row-tile candidates for the ragged dimension.

    Unlike the dense case, a smaller tile can win: every group boundary
    wastes at most one tile of padded compute, so tiles near the *mean*
    group size keep the boundary waste proportional to the distribution —
    the whole point of pricing off actual sizes instead of the max."""
    top = ceil_to(max(total, 1), sublane)
    mean = max(total // max(g, 1), 1)
    cands = {c for c in (64, 128, 256, 512) if c <= top}
    cands.add(min(ceil_to(mean, sublane), 512, top))
    if total < 64:
        cands.add(top)
    return sorted(cands)


@functools.lru_cache(maxsize=8192)
def plan_ragged_gemm(
    g: int, total: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    ragged: str = "m",
    spec: TpuSpec = TPU_V5E,
    *,
    num_shards: int = 1,
    axis: str | None = None,
) -> GemmPlan:
    """Pick the best tiling for a ragged grouped GEMM over G groups.

    The cache key (g, total, k, n, dtype widths, ragged, placement request)
    is the *distribution signature*: per-group counts are dynamic (traced)
    so the plan prices the aggregate — total ragged rows plus one boundary
    tile per group — and is re-used by every call whose signature matches
    (one tuning per MoE layer shape, free afterwards, exactly like the
    paper's dynamic adjusting).

    ``ragged == "m"``: forward — (total, k) rows against per-group (k, n)
    panels; ``bm`` tiles the ragged rows.  ``ragged == "k"``: backward dW —
    the ragged dimension contracts (T2 per group); ``bk`` tiles it, ``k`` is
    the output panel's row dim.  The per-group *mean* shape is classified
    with the 2-D taxonomy (a balanced MoE dispatch is T3/T1 per expert).

    ``num_shards > 1``: place the ragged GEMM on the mesh — token-parallel
    (rows sharded, weights replicated: no collective but every shard streams
    all G panels) vs expert-parallel (groups sharded: only G/num_shards
    panels per shard, paid for with the two all-to-all token-exchange legs
    priced by ``estimate_ep``).  EP wins exactly when the panel-traffic
    saving amortizes the exchange — few tokens against many/large expert
    panels, the MoE decode regime.
    """
    if num_shards > 1:
        return _plan_ragged_placed(g, total, k, n, num_shards, in_bytes,
                                   out_bytes, ragged, spec, axis)
    sublane = spec.sublane(in_bytes)
    mean = max(total // max(g, 1), 1)
    if ragged == "m":
        cls = classify(mean, k, n)
        bms = _ragged_tile_candidates(total, g, sublane)
        bns, bks = _bn_candidates(n, spec.lane), _bk_candidates(k)
    elif ragged == "k":
        cls = classify(k, mean, n)
        bms = _bm_candidates(k, sublane)
        bns, bks = _bn_candidates(n, spec.lane), \
            _ragged_tile_candidates(total, g, sublane)
    else:
        raise ValueError(ragged)
    best: GemmPlan | None = None
    for bm in bms:
        for bn in bns:
            for bk in bks:
                e = estimate_ragged(g, total, k, n, bm=bm, bn=bn, bk=bk,
                                    ragged=ragged, in_bytes=in_bytes,
                                    out_bytes=out_bytes, spec=spec)
                if e.vmem_bytes > spec.vmem_budget:
                    continue
                cand = GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls, est=e)
                if best is None or _better(cand, best):
                    best = cand
    if best is None:  # degenerate: nothing fit; shrink to minimum tiles
        bm, bn, bk = min(128, ceil_to(max(total, 1), sublane)), 128, 128
        e = estimate_ragged(g, total, k, n, bm=bm, bn=bn, bk=bk,
                            ragged=ragged, in_bytes=in_bytes,
                            out_bytes=out_bytes, spec=spec)
        best = GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls, est=e)
    return best


def _plan_ragged_placed(
    g: int, total: int, k: int, n: int, nc: int,
    in_bytes: int, out_bytes: int, ragged: str, spec: TpuSpec,
    axis: str | None,
) -> GemmPlan:
    t_l = max(cdiv(total, nc), 1)
    g_l = max(cdiv(g, nc), 1)
    waste = (cdiv(total, nc) * nc) / max(total, 1)
    if ragged == "k":
        # The EP backward dW contracts rows that already live on the owning
        # shard after the forward exchange: expert-local, no collective.
        pe = plan_ragged_gemm(g_l, t_l, k, n, in_bytes, out_bytes, ragged,
                              spec)
        return replace(pe, placement=Placement("expert_parallel", nc,
                                               axis=axis, waste=waste))
    # Token-parallel: rows sharded, every shard streams all G panels.
    pm = plan_ragged_gemm(g, t_l, k, n, in_bytes, out_bytes, ragged, spec)
    pm = replace(pm, placement=Placement("m_parallel", nc, axis=axis,
                                         waste=waste))
    # Expert-parallel: G/nc panels per shard + the two exchange legs.
    pe = plan_ragged_gemm(g_l, t_l, k, n, in_bytes, out_bytes, ragged, spec)
    ex = estimate_ep(total, k, nc, elt_bytes=in_bytes, spec=spec) \
        + estimate_ep(total, n, nc, elt_bytes=out_bytes, spec=spec)
    pe = replace(pe, placement=Placement(
        "expert_parallel", nc, axis=axis, t_collective=ex.t_exchange,
        ici_bytes=ex.ici_bytes, waste=waste))
    # EP must amortize the exchange before it displaces the collective-free
    # layout (paper §IV-C's "clear modeled win" rule for K-parallel, reused).
    if pe.t_total * 1.1 < pm.t_total:
        return pe
    return pm


@dataclass(frozen=True)
class MoeDispatchPlan(Plan):
    """Dispatch-mode x placement pricing for one MoE layer shape.

    ``rows`` is the effective expert-GEMM row count the dispatch mode
    produces: E x capacity for "capacity" (every expert padded to the max,
    overflow dropped), T x top_k for "ragged" (every routed copy, nothing
    else).  The roofline prices the layer's GEMM flops/bytes off ``rows``
    and its EP exchange off ``placement`` — ONE source of truth instead of
    per-consumer special cases."""
    rows: int
    est: PlanEstimate | None = None
    placement: Placement | None = None


@functools.lru_cache(maxsize=8192)
def plan_moe_dispatch(
    t: int, e: int, top_k: int, d_model: int, d_ff: int,
    *,
    dispatch: str = "capacity",
    capacity_factor: float = 1.25,
    elt_bytes: int = 2,
    num_shards: int = 1,
    axis: str | None = None,
    spec: TpuSpec = TPU_V5E,
) -> MoeDispatchPlan:
    """Price one MoE layer's dispatch mode + expert placement.

    ``num_shards > 1`` attaches the expert-parallel ``Placement`` with the
    two all-to-all legs of the FUSED pipeline (``ep_ragged_moe``): tokens
    out and back in d_model width, priced by ``estimate_ep`` — the d_ff-wide
    hidden is produced and consumed on the shard owning the expert and
    never crosses the axis.  (``d_ff`` stays in the signature/cache key: it
    sizes the layer's GEMMs for the rows-based pricing consumers.)"""
    if dispatch == "ragged":
        rows = t * top_k
    elif dispatch == "capacity":
        s = spec.sublane(elt_bytes)
        c = int(t * top_k * capacity_factor / e)
        rows = e * max(s, ceil_to(c, s))
    else:
        raise ValueError(f"unknown moe dispatch: {dispatch}")
    placement = None
    if num_shards > 1:
        leg = estimate_ep(rows, d_model, num_shards,
                          elt_bytes=elt_bytes, spec=spec)
        ex: EpEstimate = leg + leg            # dispatch + return
        placement = Placement("expert_parallel", num_shards, axis=axis,
                              t_collective=ex.t_exchange,
                              ici_bytes=ex.ici_bytes)
    return MoeDispatchPlan(rows=rows, placement=placement)


def tgemm_plan(m: int, k: int, n: int,
               in_bytes: int = 4, out_bytes: int = 4,
               spec: TpuSpec = TPU_V5E) -> GemmPlan:
    """The TGEMM baseline (paper Alg. 1): ONE fixed blocking for all shapes —
    (m_g=512, k_g=512, n_a=96, m_s=6) on FT-m7032; the TPU analogue keeps a
    fixed regular-GEMM tile (256, 256, 256) and pads everything into it."""
    bm, bn, bk = 256, 256, 256
    e = estimate(m, k, n, bm=bm, bn=bn, bk=bk,
                 in_bytes=in_bytes, out_bytes=out_bytes, spec=spec)
    return GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=classify(m, k, n), est=e)


def clear_plan_cache() -> None:
    plan_gemm.cache_clear()
    plan_batched_gemm.cache_clear()
    plan_ragged_gemm.cache_clear()
    plan_distributed.cache_clear()
    plan_moe_dispatch.cache_clear()
