"""Dynamic adjusting (paper §IV-C): choose parallelization strategy and block
sizes per GEMM shape, at trace time, from the CMR model.

The paper fixes initial block sizes from CMR + capacity, then adjusts them to
the actual matrix shape at run time, and picks M-parallel vs K-parallel from
the shape (K-parallel iff M and N are both small and K is large, because only
splitting K can occupy all 8 DSP cores).  Here:

  * single-core blocks (bm, bn, bk, dim_order) come from enumerating aligned
    candidates and scoring with ``cmr.estimate`` under the VMEM budget,
  * the cross-chip strategy (M-shard vs K-shard+psum) is scored with an added
    ICI collective term (``plan_distributed``), mirroring Eqs. 1-4's
    num_core terms,
  * plans are LRU-cached per shape — the paper's "dynamic adjusting" happens
    once per (M, K, N, dtype) and is free afterwards.

``tgemm_plan`` reproduces the TGEMM strawman the paper compares against: one
fixed micro-kernel/block configuration regardless of shape, with implicit
padding of N (its waste shows up in ``est.flops_padded`` / traffic).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace

from .cmr import (TPU_V5E, PlanEstimate, TpuSpec, cdiv, ceil_to, estimate,
                  estimate_batched, estimate_ragged)
from .shapes import GemmClass, classify


@dataclass(frozen=True)
class GemmPlan:
    bm: int
    bn: int
    bk: int
    nsplit: int = 1                 # in-kernel split-K factor
    dim_order: str = "mn"
    gemm_class: GemmClass = GemmClass.REGULAR
    est: PlanEstimate | None = None

    def kernel_kwargs(self) -> dict:
        return dict(bm=self.bm, bn=self.bn, bk=self.bk,
                    nsplit=self.nsplit, dim_order=self.dim_order)


@dataclass(frozen=True)
class DistPlan:
    """Cross-chip strategy for one GEMM (paper's two parallelization modes)."""
    strategy: str                   # "m_parallel" | "k_parallel"
    num_cores: int
    local: GemmPlan                 # per-chip plan for the local shard shape
    t_collective: float             # modeled ICI reduction cost (s)
    t_total: float


def _bm_candidates(m: int, sublane: int) -> list[int]:
    cands = [c for c in (128, 256, 512, 1024) if c <= ceil_to(m, sublane)]
    if m < 128:
        cands.append(ceil_to(m, sublane))
    return sorted(set(cands)) or [ceil_to(m, sublane)]


def _bn_candidates(n: int, lane: int) -> list[int]:
    top = ceil_to(n, lane)
    cands = [c for c in (128, 256, 512) if c <= top]
    if top <= 1024:
        cands.append(top)
    return sorted(set(cands)) or [top]


def _bk_candidates(k: int) -> list[int]:
    top = ceil_to(k, 128)
    cands = [c for c in (128, 256, 512, 1024, 2048) if c <= top]
    if top <= 4096:
        cands.append(top)   # full-K residency — enables gk == 1 reuse
    return sorted(set(cands)) or [top]


@functools.lru_cache(maxsize=8192)
def plan_gemm(
    m: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    spec: TpuSpec = TPU_V5E,
) -> GemmPlan:
    """Pick the best single-core tiling for C(M,N) += A(M,K) B(K,N)."""
    cls = classify(m, k, n)
    sublane = spec.sublane(in_bytes)
    best: GemmPlan | None = None
    for bm in _bm_candidates(m, sublane):
        for bn in _bn_candidates(n, spec.lane):
            for bk in _bk_candidates(k):
                for order in ("mn", "nm"):
                    e = estimate(m, k, n, bm=bm, bn=bn, bk=bk,
                                 dim_order=order, in_bytes=in_bytes,
                                 out_bytes=out_bytes, spec=spec)
                    if e.vmem_bytes > spec.vmem_budget:
                        continue
                    cand = GemmPlan(bm=bm, bn=bn, bk=bk, dim_order=order,
                                    gemm_class=cls, est=e)
                    if best is None or _better(cand, best):
                        best = cand
    if best is None:  # degenerate: nothing fit; shrink to minimum tiles
        bm, bn, bk = min(128, ceil_to(m, sublane)), 128, 128
        e = estimate(m, k, n, bm=bm, bn=bn, bk=bk,
                     in_bytes=in_bytes, out_bytes=out_bytes, spec=spec)
        best = GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls, est=e)
    return best


def _better(a: GemmPlan, b: GemmPlan) -> bool:
    ta, tb = a.est.t_total, b.est.t_total
    if abs(ta - tb) > 0.02 * max(ta, tb):
        return ta < tb
    # Tie-break as the paper does: prefer larger bk (more accumulator reuse),
    # then smaller padding waste.
    if a.bk != b.bk:
        return a.bk > b.bk
    return a.est.flops_padded < b.est.flops_padded


@functools.lru_cache(maxsize=8192)
def plan_distributed(
    m: int, k: int, n: int,
    num_cores: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    spec: TpuSpec = TPU_V5E,
) -> DistPlan:
    """Choose M-parallel vs K-parallel across ``num_cores`` chips.

    M-parallel (paper Alg. 4): shard M; B replicated; no steady-state
    collective.  K-parallel (paper Alg. 5): shard K; partial C's reduced —
    modeled as a ring all-reduce of the fp32 partials over ICI.
    """
    sublane = spec.sublane(in_bytes)

    m_local = max(cdiv(m, num_cores), 1)
    pm = plan_gemm(ceil_to(m_local, sublane), k, n, in_bytes, out_bytes, spec)
    # Load imbalance when m doesn't fill the cores evenly / at all.
    waste_m = (cdiv(m, num_cores) * num_cores) / max(m, 1)
    t_m = pm.est.t_total * waste_m

    k_local = max(cdiv(k, num_cores), 1)
    pk = plan_gemm(m, ceil_to(k_local, 128), n, in_bytes, out_bytes, spec)
    ring = 2.0 * (num_cores - 1) / num_cores
    t_red = ring * (m * n * 4) / (spec.ici_bw_per_link * spec.ici_links)
    t_k = pk.est.t_total + t_red

    # Paper §IV-C: K-parallel "brings additional overhead of reduction" and
    # is reserved for shapes where M cannot occupy the cores — require a
    # clear modeled win before accepting the reduction strategy.
    if t_m <= t_k * 1.15:
        return DistPlan("m_parallel", num_cores, pm, 0.0, t_m)
    return DistPlan("k_parallel", num_cores, pk, t_red, t_k)


@functools.lru_cache(maxsize=8192)
def plan_batched_gemm(
    g: int, m: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    shared: str = "none",            # "none" | "a" | "b"
    spec: TpuSpec = TPU_V5E,
) -> GemmPlan:
    """Pick the best tiling for the batched GEMM C(g) += A(g) B(g).

    ``shared`` marks a 2-D operand reused by every batch entry (the grouped
    case); the batch-aware CMR model then credits cross-batch residency when
    the tiling actually earns it (single resident block), mirroring the
    paper's loop-order-for-reuse analysis with the batch as the outermost
    loop.  The per-entry shape is classified with the 2-D taxonomy (each MoE
    expert GEMM is T3/T1 per shard regardless of E)."""
    cls = classify(m, k, n)
    sublane = spec.sublane(in_bytes)
    shared_a, shared_b = shared == "a", shared == "b"
    best: GemmPlan | None = None
    for bm in _bm_candidates(m, sublane):
        for bn in _bn_candidates(n, spec.lane):
            for bk in _bk_candidates(k):
                for order in ("mn", "nm"):
                    e = estimate_batched(
                        g, m, k, n, bm=bm, bn=bn, bk=bk, dim_order=order,
                        shared_a=shared_a, shared_b=shared_b,
                        in_bytes=in_bytes, out_bytes=out_bytes, spec=spec)
                    if e.vmem_bytes > spec.vmem_budget:
                        continue
                    cand = GemmPlan(bm=bm, bn=bn, bk=bk, dim_order=order,
                                    gemm_class=cls, est=e)
                    if best is None or _better(cand, best):
                        best = cand
    if best is None:  # degenerate: nothing fit; shrink to minimum tiles
        bm, bn, bk = min(128, ceil_to(m, sublane)), 128, 128
        e = estimate_batched(g, m, k, n, bm=bm, bn=bn, bk=bk,
                             shared_a=shared_a, shared_b=shared_b,
                             in_bytes=in_bytes, out_bytes=out_bytes,
                             spec=spec)
        best = GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls, est=e)
    return best


def _ragged_tile_candidates(total: int, g: int, sublane: int) -> list[int]:
    """Row-tile candidates for the ragged dimension.

    Unlike the dense case, a smaller tile can win: every group boundary
    wastes at most one tile of padded compute, so tiles near the *mean*
    group size keep the boundary waste proportional to the distribution —
    the whole point of pricing off actual sizes instead of the max."""
    top = ceil_to(max(total, 1), sublane)
    mean = max(total // max(g, 1), 1)
    cands = {c for c in (64, 128, 256, 512) if c <= top}
    cands.add(min(ceil_to(mean, sublane), 512, top))
    if total < 64:
        cands.add(top)
    return sorted(cands)


@functools.lru_cache(maxsize=8192)
def plan_ragged_gemm(
    g: int, total: int, k: int, n: int,
    in_bytes: int = 4,
    out_bytes: int = 4,
    ragged: str = "m",
    spec: TpuSpec = TPU_V5E,
) -> GemmPlan:
    """Pick the best tiling for a ragged grouped GEMM over G groups.

    The cache key (g, total, k, n, dtype widths, ragged) is the *distribution
    signature*: per-group counts are dynamic (traced) so the plan prices the
    aggregate — total ragged rows plus one boundary tile per group — and is
    re-used by every call whose signature matches (one tuning per MoE layer
    shape, free afterwards, exactly like the paper's dynamic adjusting).

    ``ragged == "m"``: forward — (total, k) rows against per-group (k, n)
    panels; ``bm`` tiles the ragged rows.  ``ragged == "k"``: backward dW —
    the ragged dimension contracts (T2 per group); ``bk`` tiles it, ``k`` is
    the output panel's row dim.  The per-group *mean* shape is classified
    with the 2-D taxonomy (a balanced MoE dispatch is T3/T1 per expert).
    """
    sublane = spec.sublane(in_bytes)
    mean = max(total // max(g, 1), 1)
    if ragged == "m":
        cls = classify(mean, k, n)
        bms = _ragged_tile_candidates(total, g, sublane)
        bns, bks = _bn_candidates(n, spec.lane), _bk_candidates(k)
    elif ragged == "k":
        cls = classify(k, mean, n)
        bms = _bm_candidates(k, sublane)
        bns, bks = _bn_candidates(n, spec.lane), \
            _ragged_tile_candidates(total, g, sublane)
    else:
        raise ValueError(ragged)
    best: GemmPlan | None = None
    for bm in bms:
        for bn in bns:
            for bk in bks:
                e = estimate_ragged(g, total, k, n, bm=bm, bn=bn, bk=bk,
                                    ragged=ragged, in_bytes=in_bytes,
                                    out_bytes=out_bytes, spec=spec)
                if e.vmem_bytes > spec.vmem_budget:
                    continue
                cand = GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls, est=e)
                if best is None or _better(cand, best):
                    best = cand
    if best is None:  # degenerate: nothing fit; shrink to minimum tiles
        bm, bn, bk = min(128, ceil_to(max(total, 1), sublane)), 128, 128
        e = estimate_ragged(g, total, k, n, bm=bm, bn=bn, bk=bk,
                            ragged=ragged, in_bytes=in_bytes,
                            out_bytes=out_bytes, spec=spec)
        best = GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=cls, est=e)
    return best


def tgemm_plan(m: int, k: int, n: int,
               in_bytes: int = 4, out_bytes: int = 4,
               spec: TpuSpec = TPU_V5E) -> GemmPlan:
    """The TGEMM baseline (paper Alg. 1): ONE fixed blocking for all shapes —
    (m_g=512, k_g=512, n_a=96, m_s=6) on FT-m7032; the TPU analogue keeps a
    fixed regular-GEMM tile (256, 256, 256) and pads everything into it."""
    bm, bn, bk = 256, 256, 256
    e = estimate(m, k, n, bm=bm, bn=bn, bk=bk,
                 in_bytes=in_bytes, out_bytes=out_bytes, spec=spec)
    return GemmPlan(bm=bm, bn=bn, bk=bk, gemm_class=classify(m, k, n), est=e)


def clear_plan_cache() -> None:
    plan_gemm.cache_clear()
    plan_batched_gemm.cache_clear()
    plan_ragged_gemm.cache_clear()
    plan_distributed.cache_clear()
