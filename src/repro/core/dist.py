"""Trace-time distribution context.

Model code consults ``current_dist()`` to decide whether to use explicit
shard_map paths (e.g. sequence-parallel flash-decode attention — the paper's
K-parallel strategy across chips).  Set by launchers / dryrun via
``use_dist``; None means single-device semantics (smoke tests, examples).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass

from jax.sharding import Mesh


@dataclass(frozen=True)
class DistContext:
    mesh: Mesh
    dp_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    sp_decode: bool = True          # K-parallel (flash-decode) for decode attn
    moe_buf_shard: bool = False     # shard MoE dispatch buffers over dp
    # Expert parallelism: the concrete mesh axis (or axis tuple) that owns
    # the MoE expert dim — set from launch.sharding.expert_axis when the
    # layout shards experts.  Ragged (capacity-free) dispatch then routes
    # its grouped GEMMs through core.gemm.ep_ragged_* (all-to-all token
    # exchange) instead of replicating every expert panel on every chip.
    moe_ep_axis: str | tuple[str, ...] | None = None
    ssm_head_shard: bool = False    # shard SSD head dim over model
    rms_bf16: bool = False          # fusion-friendly rms_norm (no f32 stream)
    sp_inputs: bool = False         # pin AG points: gather residual at ln1/ln2

    @property
    def dp_size(self) -> int:
        return int(__import__("math").prod(
            self.mesh.shape[a] for a in self.dp_axes))

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])


_CURRENT: DistContext | None = None


def current_dist() -> DistContext | None:
    return _CURRENT


def shard_act(x, *dims: str | None):
    """Constrain an activation's sharding under the current DistContext.

    dims: per-dimension logical axis — "dp" (data axes), "model", or None.
    No-op outside a distribution context (smoke tests / single device).
    GSPMD left alone tends to replicate gather outputs (token embeddings)
    and then the whole residual stream; pinning (B, S, D) -> (dp, None/model
    -seq, None) at block boundaries keeps activations distributed — the same
    role the paper's explicit per-core DMA ownership plays.
    """
    ctx = _CURRENT
    if ctx is None:
        return x
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    parts = []
    for d, size in zip(dims, x.shape):
        if d == "dp":
            n = ctx.dp_size
            parts.append(ctx.dp_axes if (n > 1 and size % n == 0) else None)
        elif d == "model":
            n = ctx.model_size
            parts.append(ctx.model_axis if (n > 1 and size % n == 0) else None)
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*parts)))


@contextlib.contextmanager
def use_dist(ctx: DistContext | None):
    global _CURRENT
    old = _CURRENT
    _CURRENT = ctx
    try:
        yield
    finally:
        _CURRENT = old
