"""Shared quantization helpers: ONE rounding rule for every consumer.

The symmetric scale fit, clip-round quantizer and error-feedback residual
were born in ``optim/compression.py`` (error-feedback int8 over ICI); the
low-precision GEMM family reuses exactly the same arithmetic for kernel
quantization — per-tensor activation scales, per-channel (and per-expert)
weight scales, int4 nibble packing for weight storage, and fp8 casts — so
the ICI compressor and the kernels can never disagree on a rounding rule.

Conventions:

  * Scales are always fp32 and always *symmetric* (no zero point): the
    quantized value decodes as ``q * scale``.
  * Per-channel weight scales are fit over the contraction axis and kept as
    an (N,)-wide vector (or (G, N) per expert) — the shape the kernels'
    scale-vector epilogue operand expects.  Per-tensor scales are broadcast
    to the same vector shape so every consumer handles ONE operand layout.
  * The analytic error bound (``dot_error_bound``) is what the conformance
    tests assert: round-to-nearest puts per-element error at ``scale / 2``
    (int) or ``eps * |x|`` (fp8), and a K-long dot accumulates at most K of
    the cross terms.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

INT8_LEVELS = 127
INT4_LEVELS = 7

# Finite-max and round-off epsilon per fp8 format: e4m3 has a 3-bit
# mantissa (max 448), e5m2 a 2-bit mantissa (max 57344).
FP8_FORMATS: dict[str, tuple[Any, float, float]] = {
    "e4m3": (jnp.float8_e4m3fn, 448.0, 2.0 ** -3),
    "e5m2": (jnp.float8_e5m2, 57344.0, 2.0 ** -2),
}

MODES = ("none", "w8", "w4", "int8", "fp8_e4m3", "fp8_e5m2")


@dataclass(frozen=True)
class QuantConfig:
    """Per-layer quantization policy (hashable: keys jit static args and the
    dispatch function caches, like ``Epilogue``).

    ``mode``:
      * ``"none"``     — full-precision GEMM (the config is a no-op).
      * ``"w8"``       — weight-only int8: weights quantized per channel,
        activations stay bf16/fp32, dequant (the scale vector) fuses into
        the accumulator flush.  The memory-bound decode case — weight bytes
        halve vs bf16.
      * ``"w4"``       — weight-only int4: same math at 7 levels, weights
        *stored* nibble-packed (two per int8 byte — a quarter of the bf16
        bytes at rest / on the wire), unpacked to int8 ahead of the kernel.
      * ``"int8"``     — dynamic full int8: per-tensor activation scale x
        per-channel weight scale, int8 x int8 -> int32 accumulate, one
        combined (N,) scale at the flush.
      * ``"fp8_e4m3"`` / ``"fp8_e5m2"`` — both operands cast to fp8 with
        per-tensor scales, accumulated in fp32.
    """
    mode: str = "none"
    per_channel: bool = True

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"unknown quant mode: {self.mode!r} "
                             f"(expected one of {MODES})")

    @property
    def is_noop(self) -> bool:
        return self.mode == "none"

    @property
    def weight_only(self) -> bool:
        return self.mode in ("w8", "w4")

    @property
    def weight_bytes(self) -> int:
        """Kernel-visible weight element width (int4 unpacks to int8 before
        the kernel, so the *compute* width is 1; storage is 0.5)."""
        return 2 if self.mode == "none" else 1

    @property
    def levels(self) -> int:
        return INT4_LEVELS if self.mode == "w4" else INT8_LEVELS


def resolve(quant: "QuantConfig | str | None") -> QuantConfig:
    """Accept a ``QuantConfig``, a mode string, or None (-> no-op)."""
    if quant is None:
        return QuantConfig()
    if isinstance(quant, str):
        return QuantConfig(mode=quant)
    return quant


# ---------------------------------------------------------------------------
# The one rounding rule (shared with optim/compression.py)
# ---------------------------------------------------------------------------

def scale_from_absmax(absmax: jax.Array, levels: int = INT8_LEVELS,
                      eps: float = 1e-30) -> jax.Array:
    """Symmetric scale covering ``[-absmax, absmax]`` in ``levels`` steps."""
    return jnp.maximum(absmax.astype(jnp.float32), eps) / levels


def symmetric_scale(x: jax.Array, levels: int = INT8_LEVELS,
                    axis: Any = None) -> jax.Array:
    """Fit the symmetric scale from ``max |x|`` — per tensor (``axis=None``,
    scalar) or reduced over ``axis`` (per channel / per expert)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis)
    return scale_from_absmax(amax, levels)


def quantize(x: jax.Array, scale: jax.Array, levels: int = INT8_LEVELS,
             dtype: Any = jnp.int8) -> jax.Array:
    """Clip-round symmetric quantization: ``clip(round(x / scale))``."""
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -levels, levels)
    return q.astype(dtype)


def dequantize(q: jax.Array, scale: jax.Array,
               dtype: Any = jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def error_residual(x: jax.Array, q: jax.Array,
                   scale: jax.Array) -> jax.Array:
    """The error-feedback residual: what quantization dropped this step,
    carried into the next step's input (EF-SGD/EF21)."""
    return x.astype(jnp.float32) - dequantize(q, scale)


# ---------------------------------------------------------------------------
# int4 nibble packing (weight storage / wire format)
# ---------------------------------------------------------------------------

def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int8 values in [-7, 7] two-per-byte along the last axis (which
    must be even): element 2i in the low nibble, 2i+1 in the high."""
    if q.shape[-1] % 2:
        raise ValueError(f"last axis must be even to pack, got {q.shape}")
    lo = q[..., 0::2].astype(jnp.int8) & 0x0F
    hi = (q[..., 1::2].astype(jnp.int8) & 0x0F) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of ``pack_int4``: sign-extend both nibbles back to int8."""
    p = packed.astype(jnp.int8)
    lo = (p << 4) >> 4              # arithmetic shifts sign-extend
    hi = p >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# ---------------------------------------------------------------------------
# fp8 casts
# ---------------------------------------------------------------------------

def quantize_fp8(x: jax.Array, fmt: str = "e4m3") -> tuple[jax.Array,
                                                           jax.Array]:
    """Cast to fp8 with a per-tensor scale filling the format's range.
    Returns (q, scale) with ``q * scale`` the decoded value."""
    dt, fmax, _ = FP8_FORMATS[fmt]
    scale = scale_from_absmax(jnp.max(jnp.abs(x.astype(jnp.float32))),
                              levels=1) / fmax
    return (x.astype(jnp.float32) / scale).astype(dt), scale


# ---------------------------------------------------------------------------
# Weight quantization for the GEMM family
# ---------------------------------------------------------------------------

def quantize_weights(w: jax.Array, cfg: QuantConfig) -> tuple[jax.Array,
                                                              jax.Array]:
    """Quantize a (K, N) weight panel — or (G, K, N) per-expert panels — for
    the ``cfg.mode`` kernel path.  Returns ``(q, scale)`` where ``scale`` is
    ALWAYS an (N,)-wide fp32 vector (or (G, N)): per-channel scales are fit
    over the contraction axis, per-tensor scales are broadcast, so the
    kernels see one operand layout either way."""
    n = w.shape[-1]
    if cfg.mode in ("fp8_e4m3", "fp8_e5m2"):
        q, s = quantize_fp8(w, cfg.mode[4:])
        return q, jnp.broadcast_to(s, (*w.shape[:-2], n))
    if cfg.mode not in ("w8", "w4", "int8"):
        raise ValueError(f"no weight quantization for mode {cfg.mode!r}")
    if cfg.per_channel:
        # Scale per output column, fit over the contraction axis; the panel
        # divides by it with the contraction axis re-inserted for broadcast.
        scale = symmetric_scale(w, cfg.levels, axis=w.ndim - 2)
        step = scale if w.ndim == 2 else scale[..., None, :]
    else:
        step = symmetric_scale(w, cfg.levels)       # one scalar step
        scale = jnp.broadcast_to(step, (*w.shape[:-2], n))
    q = quantize(w, step, cfg.levels)
    return q, scale


def quantize_activations(x: jax.Array,
                         cfg: QuantConfig) -> tuple[jax.Array, jax.Array]:
    """Dynamic per-tensor activation quantization for ``mode="int8"`` /
    fp8 modes.  Returns (q, scalar scale)."""
    if cfg.mode in ("fp8_e4m3", "fp8_e5m2"):
        return quantize_fp8(x, cfg.mode[4:])
    scale = symmetric_scale(x, INT8_LEVELS)
    return quantize(x, scale, INT8_LEVELS), scale


# ---------------------------------------------------------------------------
# Analytic conformance bound
# ---------------------------------------------------------------------------

def dot_error_bound(k: int, amax_a: float, amax_b: float,
                    step_a: float = 0.0, step_b: float = 0.0) -> float:
    """Worst-case |quantized - exact| for one element of a K-long dot.

    Round-to-nearest symmetric quantization moves each element by at most
    half a step; each product then errs by at most
    ``|a| db + (|b| + db) da`` with ``da = step_a / 2``, ``db = step_b / 2``,
    and K products accumulate.  Weight-only passes ``step_a = 0`` (exact
    activations); fp8 callers pass ``step = 2 * eps * amax`` (relative
    round-off as an absolute step at the format's top magnitude).
    """
    da, db = step_a / 2.0, step_b / 2.0
    return k * (amax_a * db + (amax_b + db) * da)


def fp8_step(amax: float, fmt: str) -> float:
    """The absolute quantization step fp8 round-off implies at magnitude
    ``amax``: ``2 * eps * amax`` (so ``dot_error_bound`` can treat fp8 like
    an integer grid with this step)."""
    _, _, eps = FP8_FORMATS[fmt]
    return 2.0 * eps * amax
