# The paper's primary contribution: ftIMM — irregular-shaped GEMM with
# auto-specialized kernels, two parallelization strategies, and CMR-driven
# dynamic adjusting — lives in core.gemm.
from . import gemm

__all__ = ["gemm"]
