"""Architecture config schema covering all assigned families.

One frozen dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM
stacks; family-specific fields are zero/empty when unused.  Attention
patterns are encoded per layer as ints (see models.attention): >0 sliding
window, 0 global, <0 chunked local of size |w| — cycled over layers.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


def ceil_to(x: int, b: int) -> int:
    return -(-x // b) * b


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attn-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    window_pattern: tuple[int, ...] = (0,)
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "capacity"   # "capacity" (drop+pad) | "ragged" (keep all)
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_chunk: int = 256
    attn_every: int = 0              # hybrid: shared attn after every N ssm layers
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings (stub frontend)
    # --- VLM (llava) ---
    num_patches: int = 0             # precomputed patch embeddings (stub frontend)
    # --- numerics / memory ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    quant: str = "none"              # core.quant mode for MLP/expert panels
                                     # ("w8"/"w4"/"int8"/...); ragged MoE +
                                     # dense MLP down projections
    vocab_pad_multiple: int = 16
    remat: str = "full"              # none | full | dots
    scan_unroll: bool = False        # unroll all scans (FLOPs probes only)
    # long-context applicability (DESIGN.md §Arch-applicability)
    supports_long_context: bool = False
    source: str = ""

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def vocab_padded(self) -> int:
        return ceil_to(self.vocab_size, self.vocab_pad_multiple)

    def windows(self) -> tuple[int, ...]:
        pat = self.window_pattern or (0,)
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    def param_count(self) -> int:
        """Approximate parameter count (for 6ND roofline accounting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_padded
        hd = self.head_dim_
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        attn = d * (n_q + 2 * n_kv) + n_q * d
        mlp = 3 * d * f
        per_layer = 0
        if self.family in ("dense", "vlm", "encdec"):
            per_layer = attn + mlp
        elif self.family == "moe":
            per_layer = attn + self.num_experts * mlp + d * self.num_experts
        elif self.family in ("ssm", "hybrid"):
            d_inner = 2 * d
            nheads = d_inner // 64
            proj = d * (2 * d_inner + 2 * self.ssm_state + nheads)
            per_layer = proj + d_inner * d
        total = self.num_layers * per_layer + v * d
        if self.family == "hybrid" and self.attn_every:
            total += attn + mlp   # one shared attention+mlp block
        if self.family == "encdec":
            total += self.encoder_layers * (attn + mlp)   # encoder stack
            total += self.num_layers * (attn)             # cross-attention
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if self.family != "moe" or not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        hd = self.head_dim_
        attn = d * (self.num_heads + 2 * self.num_kv_heads) * hd \
            + self.num_heads * hd * d
        mlp = 3 * d * f
        per_layer = attn + self.top_k * mlp + d * self.num_experts
        return int(self.num_layers * per_layer + self.vocab_padded * d)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=2 if cfg.num_kv_heads else 0,
        head_dim=32 if cfg.num_heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4),
        top_k=min(cfg.top_k, 2),
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_chunk=32,
        attn_every=2 if cfg.attn_every else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 16),
        num_patches=min(cfg.num_patches, 8),
        window_pattern=tuple(min(w, 16) if w > 0 else max(w, -16)
                             for w in cfg.window_pattern),
        remat="none",
    )
