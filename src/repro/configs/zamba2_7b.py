"""zamba2-7b [hybrid]: Mamba2 blocks + ONE shared attention+MLP block applied
every 6 SSM layers (single param set, faithful to Zamba2's shared-block
design). [arXiv:2411.15242; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_chunk=128, attn_every=6,
    supports_long_context=True,    # SSM + periodic attention
    source="arXiv:2411.15242",
)
