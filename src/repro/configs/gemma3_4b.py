"""gemma3-4b [dense]: 5:1 local(1024):global attention, qk_norm, 128k ctx.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144, qk_norm=True, rope_theta=1e6,
    window_pattern=(1024, 1024, 1024, 1024, 1024, 0),
    supports_long_context=True,    # 5:1 sliding-window:global
    source="hf:google/gemma-3-1b-pt",
)
