from .base import ModelConfig, ShapeConfig, smoke_config
from .registry import ARCHS, get_config, list_archs
from .shapes import SHAPES, applicable

__all__ = ["ModelConfig", "ShapeConfig", "smoke_config", "ARCHS",
           "get_config", "list_archs", "SHAPES", "applicable"]
