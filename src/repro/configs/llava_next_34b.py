"""llava-next-34b [vlm]: dense LM backbone; anyres tiling / vision tower
STUBBED (input_specs provides precomputed patch embeddings, 576 = one
336px ViT-L/14 tile). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    num_patches=576,
    supports_long_context=False,   # pure full attention
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
