"""whisper-base [audio]: enc-dec, conv frontend STUBBED (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    encoder_layers=6, encoder_seq=1500,
    supports_long_context=False,   # enc-dec, full attention, 448-token decoder
    source="arXiv:2212.04356",
)
