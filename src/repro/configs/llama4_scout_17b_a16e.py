"""llama4-scout-17b-a16e [moe]: 16 experts top-1, 3:1 chunked-local:global
(iRoPE-style). Early-fusion modality frontend OUT of scope (stub).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    num_experts=16, top_k=1,
    moe_dispatch="ragged",         # capacity-free: 16-way top-1 routing is
                                   # exactly the unbalanced regime where
                                   # static capacity drops or over-pads
    window_pattern=(-8192, -8192, -8192, 0),   # chunked local x3, global x1
    supports_long_context=True,    # chunked attention is sub-quadratic
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
