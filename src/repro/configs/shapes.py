"""The four assigned input-shape suites (LM shapes are seq_len x global_batch).

decode_* / long_* lower ``serve_step`` (one new token against a KV cache of
seq_len), not ``train_step``.  long_500k requires sub-quadratic attention and
runs only for archs with ``supports_long_context`` (see DESIGN.md
§Arch-applicability for the skip list).
"""
from __future__ import annotations

from .base import ShapeConfig

SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256,
                            kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32,
                               kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128,
                              kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1,
                             kind="decode"),
}


def applicable(cfg, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 500k decode KV is "
                       "quadratic-prefill territory; skipped per assignment")
    return True, ""
