"""--arch registry: one config module per assigned architecture."""
from __future__ import annotations

from .base import ModelConfig, smoke_config
from .whisper_base import CONFIG as _whisper
from .zamba2_7b import CONFIG as _zamba2
from .qwen3_1p7b import CONFIG as _qwen17
from .minitron_4b import CONFIG as _minitron
from .qwen3_8b import CONFIG as _qwen8
from .gemma3_4b import CONFIG as _gemma3
from .llama4_scout_17b_a16e import CONFIG as _llama4
from .mixtral_8x7b import CONFIG as _mixtral
from .mamba2_370m import CONFIG as _mamba2
from .llava_next_34b import CONFIG as _llava

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        _whisper, _zamba2, _qwen17, _minitron, _qwen8,
        _gemma3, _llama4, _mixtral, _mamba2, _llava,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return smoke_config(ARCHS[name[:-len("-smoke")]])
    return ARCHS[name]


def list_archs() -> list[str]:
    return sorted(ARCHS)
