"""--arch registry: one config module per assigned architecture."""
from __future__ import annotations

from .base import ModelConfig, smoke_config
from .whisper_base import CONFIG as _whisper
from .zamba2_7b import CONFIG as _zamba2
from .qwen3_1p7b import CONFIG as _qwen17
from .minitron_4b import CONFIG as _minitron
from .qwen3_8b import CONFIG as _qwen8
from .gemma3_4b import CONFIG as _gemma3
from .llama4_scout_17b_a16e import CONFIG as _llama4
from .mixtral_8x7b import CONFIG as _mixtral
from .mamba2_370m import CONFIG as _mamba2
from .llava_next_34b import CONFIG as _llava

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        _whisper, _zamba2, _qwen17, _minitron, _qwen8,
        _gemma3, _llama4, _mixtral, _mamba2, _llava,
    ]
}


_QUANT_SUFFIXES = ("w8", "w4", "int8")


def get_config(name: str) -> ModelConfig:
    """Resolve an arch name, with composable variant suffixes:

    ``<arch>-smoke`` shrinks the config for CI; ``<arch>-w8`` / ``-w4`` /
    ``-int8`` turn on weight(-and-activation) quantization of the MLP /
    expert panels (``ModelConfig.quant`` -> the GEMM layer's ``quant=``),
    e.g. ``llama4_scout_17b_a16e-w8-smoke`` for the zero-drop int8-expert
    smoke run."""
    from dataclasses import replace
    quant = "none"
    smoke = False
    while True:
        if name.endswith("-smoke") and not smoke:
            name, smoke = name[:-len("-smoke")], True
            continue
        tail = name.rsplit("-", 1)[-1]
        if tail in _QUANT_SUFFIXES and quant == "none":
            name, quant = name[:-len(tail) - 1], tail
            continue
        break
    cfg = ARCHS[name]
    if smoke:
        cfg = smoke_config(cfg)
    if quant != "none":
        cfg = replace(cfg, quant=quant)
    return cfg


def list_archs() -> list[str]:
    return sorted(ARCHS)
