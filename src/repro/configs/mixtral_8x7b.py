"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention 4096.
[arXiv:2401.04088; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    num_experts=8, top_k=2,
    window_pattern=(4096,),
    supports_long_context=True,    # SWA is sub-quadratic
    source="arXiv:2401.04088",
)
