"""Train / serve step factories used by the trainer, the dry-run and tests."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import decode_step, loss_fn, prefill
from ..optim.adamw import OptConfig, apply_updates


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    accum_steps: int = 1, aux_weight: float = 0.01):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``accum_steps > 1`` splits the batch into microbatches scanned
    sequentially (gradient accumulation) — the standard way to overlap the
    DP gradient reduction of microbatch i with the backward of i+1.
    """
    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, aux_weight=aux_weight), has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, cfg, batch)
        metrics["total_loss"] = loss
        return grads, metrics

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            grads, metrics = single(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                grads, metrics = single(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return acc, metrics

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics_all = jax.lax.scan(body, zero, micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)
        params, opt_state, stats = apply_updates(params, grads, opt_state,
                                                 opt_cfg)
        metrics.update(stats)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        logits, cache = prefill(params, cfg, batch, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One-token greedy decode step (the dry-run's ``serve_step``)."""
    def serve_step(params, cache, tokens, pos):
        logits, cache = decode_step(params, cfg, tokens, cache, pos)
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32), cache
    return serve_step
