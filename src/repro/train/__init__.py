from .train_step import make_prefill_step, make_serve_step, make_train_step
from .trainer import Trainer

__all__ = ["make_prefill_step", "make_serve_step", "make_train_step", "Trainer"]
