"""Training loop: jitted step + prefetching data + async checkpoints +
heartbeat/straggler hooks.  Works identically on 1 device (examples/tests)
and on a production mesh (launch/train.py passes mesh + shardings)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from ..checkpoint.checkpointer import Checkpointer
from ..configs.base import ModelConfig, ShapeConfig
from ..core.dist import DistContext, use_dist
from ..data.pipeline import Prefetcher, SyntheticLM
from ..models.model import init_params
from ..optim.adamw import OptConfig, init_opt_state
from ..runtime import chaos as _chaos
from .train_step import make_train_step


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 opt_cfg: OptConfig | None = None, *,
                 mesh=None, shardings=None, seed: int = 0,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 monitor=None, log_every: int = 10):
        self.cfg = cfg
        self.shape = shape
        self.opt_cfg = opt_cfg or OptConfig()
        self.mesh = mesh
        self.shardings = shardings or {}
        self.seed = seed
        self.ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.monitor = monitor
        self.log_every = log_every
        self.metrics_log: list[dict] = []

        self.dataset = SyntheticLM(cfg, shape, seed=seed)
        self._step_fn = None

    def _build(self):
        step = make_train_step(self.cfg, self.opt_cfg)
        kw = {}
        if self.shardings:
            kw = dict(in_shardings=(self.shardings.get("params"),
                                    self.shardings.get("opt"),
                                    self.shardings.get("batch")))
        self._step_fn = jax.jit(step, donate_argnums=(0, 1), **kw)

    def init_state(self):
        key = jax.random.PRNGKey(self.seed)
        params = init_params(self.cfg, key)
        opt = init_opt_state(params)
        return params, opt

    def restore_or_init(self):
        params, opt = self.init_state()
        start = 0
        if self.ckpt and self.ckpt.latest_step() is not None:
            start, state = self.ckpt.restore(
                {"params": params, "opt": opt},
                shardings=({"params": self.shardings.get("params"),
                            "opt": self.shardings.get("opt")}
                           if self.shardings else None))
            params, opt = state["params"], state["opt"]
            start += 1
        return start, params, opt

    def run(self, num_steps: int, host: str = "host0"):
        ctx = None
        if self.mesh is not None:
            from ..launch.sharding import dp_axes
            ctx = DistContext(mesh=self.mesh, dp_axes=dp_axes(self.mesh),
                              model_axis="model")
        with use_dist(ctx):
            if self._step_fn is None:
                self._build()
            start, params, opt = self.restore_or_init()
            prefetch = Prefetcher(self.dataset,
                                  self.shardings.get("batch_leaves"),
                                  start_step=start)
            t0 = time.time()
            try:
                for _ in range(start, num_steps):
                    # Chaos sites: a step boundary is where production
                    # notices shard loss / stragglers, so the injected
                    # HostFailure propagates to the elastic supervisor.
                    _chaos.fire("shard_loss")
                    _chaos.maybe_delay("slow_step")
                    step_i, batch = prefetch.next()
                    params, opt, metrics = self._step_fn(params, opt, batch)
                    if self.monitor is not None:
                        self.monitor.beat(host, step_i)
                    if step_i % self.log_every == 0 or step_i == num_steps - 1:
                        m = {k: float(v) for k, v in metrics.items()}
                        m["step"] = step_i
                        m["wall_s"] = round(time.time() - t0, 2)
                        self.metrics_log.append(m)
                        print(f"step {step_i:5d} loss={m['loss']:.4f} "
                              f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e}")
                    if (self.ckpt and step_i > 0
                            and step_i % self.ckpt_every == 0):
                        self.ckpt.save(step_i, {"params": params, "opt": opt})
            finally:
                prefetch.close()
            # Final save only on clean completion: saving in the finally
            # block labelled a mid-run failure's state as step num_steps-1,
            # which made an elastic restart resume PAST the steps it never
            # ran (the checkpoint must never claim steps that didn't
            # happen).
            if self.ckpt:
                self.ckpt.save(num_steps - 1,
                               {"params": params, "opt": opt},
                               blocking=True)
            return params, opt
