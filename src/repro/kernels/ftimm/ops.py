"""jit'd public wrappers around the ftIMM Pallas kernels.

Handles what the paper calls the "implicit padding" problem.  Two edge
policies exist (``edge=``):

  * ``"masked"`` (default) — zero-copy: unpadded operands go straight to the
    kernels, whose cdiv grids + in-kernel iota masks handle the remainder
    tiles; the output comes back unsliced.  No extra HBM round-trip.
  * ``"padded"`` — the legacy pad -> kernel -> slice path (two extra HBM
    round-trips per GEMM on non-block-multiple shapes).  Kept as the
    comparison point the tuner/benchmarks price and measure against.

The *tuner* (``repro.core.gemm``) chooses blocks that minimize alignment
waste — the very thing the paper's auto-generated micro-kernels achieve over
TGEMM's fixed (m_s=6, n_a=96) kernel — and, since the epilogue generator,
also whether the post-GEMM elementwise tail (bias/activation/residual/scale,
``kernel.Epilogue``) fuses into the accumulator flush.

On non-TPU backends the kernels run in interpret mode (Python emulation of
the kernel body) — correct but slow; the framework's model code therefore
routes through ``repro.core.gemm.dispatch`` which picks the XLA path on CPU
and the Pallas path on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...analysis.contracts import block_aligned
from . import kernel as _k


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, shape) -> jax.Array:
    pads = [(0, t - s) for s, t in zip(x.shape, shape)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _ceil_to(x: int, b: int) -> int:
    return (x + b - 1) // b * b


def sublane(dtype) -> int:
    """Second-to-minor register tile extent per dtype: (8,128) fp32,
    (16,128) bf16/fp16, (32,128) int8/fp8."""
    return {4: 8, 2: 16, 1: 32}.get(jnp.dtype(dtype).itemsize, 8)


def bench(fn, *args, warmup: int = 1, repeats: int = 3) -> float:
    """Median wall-clock seconds of ``fn(*args)`` — the measured
    auto-tuner's timing primitive.

    Deliberately lives in the ops layer: candidate tilings are timed by
    calling these block-parameterized wrappers DIRECTLY (explicit
    bm/bn/bk), bypassing the planners and their caches entirely, so a
    measurement can never be served by the plan cache it is trying to
    validate.  The first call compiles (jit warms per static-block
    signature); repeats are individually synced with ``block_until_ready``
    and the median taken to shrug off scheduler noise."""
    import time

    jax.block_until_ready(fn(*args))            # compile + first warmup
    for _ in range(max(warmup - 1, 0)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _clamp_blocks(m: int, k: int, n: int, bm: int, bn: int, bk: int,
                  nsplit: int, dtype) -> tuple[int, int, int, int]:
    """Clamp a plan's blocks to the (rounded) problem extent.

    ``bk`` is clamped exactly like ``bm``/``bn`` — a K=64 problem under a
    bk=512 plan used to pad K 8x (the plan cache can legitimately suggest
    such blocks for a different shape of the same signature family).  A
    clamped ``bk`` may leave ``nsplit`` covering fewer K blocks than splits;
    the split count shrinks with it (degenerating to 1 = the M-parallel
    kernel)."""
    bm_ = min(bm, _ceil_to(m, sublane(dtype)))
    bn_ = min(bn, _ceil_to(n, 128))
    bk_ = min(bk, _ceil_to(k, 128))
    if nsplit > 1:
        nsplit = max(1, min(nsplit, -(-_ceil_to(k, 128) // bk_)))
    return bm_, bn_, bk_, nsplit


@functools.partial(
    jax.jit,
    static_argnames=(
        "bm", "bn", "bk", "nsplit", "trans", "dim_order", "out_dtype",
        "interpret", "epilogue", "edge",
    ),
)
def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    nsplit: int = 1,
    trans: str = "nn",
    dim_order: str = "mn",
    out_dtype=None,
    interpret: bool | None = None,
    epilogue: "_k.Epilogue | None" = None,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
    edge: str = "masked",
) -> jax.Array:
    """General entry: dispatches to the M-parallel or split-K kernel
    (``nsplit > 1`` selects K-parallel) with the epilogue fused at the flush.
    ``edge="masked"`` passes operands through unpadded (in-kernel edge
    tiles); ``edge="padded"`` pads to block multiples and slices back.
    ``scale`` is the (N,) dequant vector when ``epilogue.scale_vec``."""
    if interpret is None:
        interpret = _auto_interpret()
    if edge not in ("masked", "padded"):
        raise ValueError(f"unknown edge policy: {edge!r}")
    epilogue = _k.IDENTITY if epilogue is None else epilogue
    out_dtype = out_dtype or a.dtype
    m, k, n = _k._mkn(trans, a.shape, b.shape)
    bm_, bn_, bk_, nsplit = _clamp_blocks(m, k, n, bm, bn, bk, nsplit,
                                          a.dtype)

    if edge == "padded":
        mp, np_ = _ceil_to(m, bm_), _ceil_to(n, bn_)
        kp = _ceil_to(k, bk_ * nsplit) if nsplit > 1 else _ceil_to(k, bk_)
        kp = max(kp, bk_ * nsplit)
        if trans == "nn":
            a_p, b_p = _pad_to(a, (mp, kp)), _pad_to(b, (kp, np_))
        elif trans == "tn":
            a_p, b_p = _pad_to(a, (kp, mp)), _pad_to(b, (kp, np_))
        elif trans == "nt":
            a_p, b_p = _pad_to(a, (mp, kp)), _pad_to(b, (np_, kp))
        else:
            raise ValueError(trans)
        bias_p = None if bias is None else _pad_to(bias, (np_,))
        res_p = None if residual is None else _pad_to(residual, (mp, np_))
        scale_p = None if scale is None else _pad_to(scale, (np_,))
    else:
        if trans not in ("nn", "tn", "nt"):
            raise ValueError(trans)
        a_p, b_p, bias_p, res_p, scale_p = a, b, bias, residual, scale

    if nsplit > 1:
        out = _k.ftimm_gemm_splitk(
            a_p, b_p, bm=bm_, bn=bn_, bk=bk_, nsplit=nsplit, trans=trans,
            out_dtype=out_dtype, interpret=interpret, epilogue=epilogue,
            bias=bias_p, residual=res_p, scale=scale_p,
        )
    else:
        out = _k.ftimm_gemm(
            a_p, b_p, bm=bm_, bn=bn_, bk=bk_, trans=trans,
            dim_order=dim_order, out_dtype=out_dtype, interpret=interpret,
            epilogue=epilogue, bias=bias_p, residual=res_p, scale=scale_p,
        )
    return out if edge == "masked" else out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "bm", "bn", "bk", "trans", "dim_order", "out_dtype", "interpret",
        "epilogue", "edge",
    ),
)
def batched_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    trans: str = "nn",
    dim_order: str = "mn",
    out_dtype=None,
    interpret: bool | None = None,
    epilogue: "_k.Epilogue | None" = None,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
    edge: str = "masked",
) -> jax.Array:
    """Batched/grouped entry.  Either operand may be 2-D (shared across the
    batch — the grouped-GEMM case); the batch dim itself is never padded (it
    maps 1:1 onto the leading grid dim).  ``edge="masked"`` (default) runs
    the kernel on unpadded per-group panels; ``edge="padded"`` is the legacy
    pad/slice path.  ``bias`` and the dequant ``scale`` vector are (N,)
    shared across the batch or (G, N) per group; ``residual`` (G, M, N)."""
    if interpret is None:
        interpret = _auto_interpret()
    if edge not in ("masked", "padded"):
        raise ValueError(f"unknown edge policy: {edge!r}")
    epilogue = _k.IDENTITY if epilogue is None else epilogue
    out_dtype = out_dtype or a.dtype
    m, k, n = _k._mkn(trans, a.shape[-2:], b.shape[-2:])
    bm_, bn_, bk_, _ = _clamp_blocks(m, k, n, bm, bn, bk, 1, a.dtype)

    if edge == "padded":
        mp, np_, kp = _ceil_to(m, bm_), _ceil_to(n, bn_), _ceil_to(k, bk_)

        def pad_panels(x, last2):
            return _pad_to(x, x.shape[:-2] + last2)

        def pad_vec(v):
            return None if v is None else _pad_to(v, v.shape[:-1] + (np_,))

        if trans == "nn":
            a_p, b_p = pad_panels(a, (mp, kp)), pad_panels(b, (kp, np_))
        elif trans == "tn":
            a_p, b_p = pad_panels(a, (kp, mp)), pad_panels(b, (kp, np_))
        elif trans == "nt":
            a_p, b_p = pad_panels(a, (mp, kp)), pad_panels(b, (np_, kp))
        else:
            raise ValueError(trans)
        bias_p = pad_vec(bias)
        res_p = None if residual is None else \
            _pad_to(residual, (residual.shape[0], mp, np_))
        scale_p = pad_vec(scale)
    else:
        if trans not in ("nn", "tn", "nt"):
            raise ValueError(trans)
        a_p, b_p, bias_p, res_p, scale_p = a, b, bias, residual, scale

    out = _k.ftimm_gemm_grouped(
        a_p, b_p, bm=bm_, bn=bn_, bk=bk_, trans=trans,
        dim_order=dim_order, out_dtype=out_dtype, interpret=interpret,
        epilogue=epilogue, bias=bias_p, residual=res_p, scale=scale_p,
    )
    return out if edge == "masked" else out[:, :m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret", "edge"),
)
def gemm_swiglu(
    x: jax.Array,                 # (M, K)
    w_gate: jax.Array,            # (K, N)
    w_up: jax.Array,              # (K, N)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool | None = None,
    edge: str = "masked",
) -> jax.Array:
    """Dense fused SwiGLU pair: silu(x @ Wg) * (x @ Wu) in one launch — the
    dense MLP's gate/up projections without the separate silu/mul passes."""
    if interpret is None:
        interpret = _auto_interpret()
    if edge not in ("masked", "padded"):
        raise ValueError(f"unknown edge policy: {edge!r}")
    out_dtype = out_dtype or x.dtype
    m, k = x.shape
    n = w_gate.shape[1]
    bm_, bn_, bk_, _ = _clamp_blocks(m, k, n, bm, bn, bk, 1, x.dtype)
    if edge == "padded":
        mp, kp, np_ = _ceil_to(m, bm_), _ceil_to(k, bk_), _ceil_to(n, bn_)
        out = _k.ftimm_gemm_swiglu(
            _pad_to(x, (mp, kp)), _pad_to(w_gate, (kp, np_)),
            _pad_to(w_up, (kp, np_)), bm=bm_, bn=bn_, bk=bk_,
            out_dtype=out_dtype, interpret=interpret)
        return out[:m, :n]
    return _k.ftimm_gemm_swiglu(x, w_gate, w_up, bm=bm_, bn=bn_, bk=bk_,
                                out_dtype=out_dtype, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret", "edge"),
)
def batched_gemm_swiglu(
    x: jax.Array,                 # (G, M, K) | (M, K) shared
    w_gate: jax.Array,            # (G, K, N)
    w_up: jax.Array,              # (G, K, N)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool | None = None,
    edge: str = "masked",
) -> jax.Array:
    """Grouped fused SwiGLU pair — the capacity-mode MoE gate/up projections
    (E, C, D) @ (E, D, F) as ONE launch with the silu(gate)*up epilogue."""
    if interpret is None:
        interpret = _auto_interpret()
    if edge not in ("masked", "padded"):
        raise ValueError(f"unknown edge policy: {edge!r}")
    out_dtype = out_dtype or x.dtype
    m, k = x.shape[-2:]
    g, _, n = w_gate.shape
    bm_, bn_, bk_, _ = _clamp_blocks(m, k, n, bm, bn, bk, 1, x.dtype)
    if edge == "padded":
        mp, kp, np_ = _ceil_to(m, bm_), _ceil_to(k, bk_), _ceil_to(n, bn_)
        x_p = _pad_to(x, x.shape[:-2] + (mp, kp))
        out = _k.ftimm_gemm_grouped_swiglu(
            x_p, _pad_to(w_gate, (g, kp, np_)), _pad_to(w_up, (g, kp, np_)),
            bm=bm_, bn=bn_, bk=bk_, out_dtype=out_dtype, interpret=interpret)
        return out[:, :m, :n]
    return _k.ftimm_gemm_grouped_swiglu(
        x, w_gate, w_up, bm=bm_, bn=bn_, bk=bk_, out_dtype=out_dtype,
        interpret=interpret)


# ---------------------------------------------------------------------------
# Ragged (capacity-free) grouped GEMM
# ---------------------------------------------------------------------------

def _ragged_metadata(group_offsets: jax.Array, m_tiles: int, bm: int):
    """Sorted (row-tile, group) visit list for the ragged kernels.

    ``group_offsets`` is traced (dynamic per-group row counts), so the list is
    built with jnp ops and fed to the kernel as scalar-prefetch operands.  The
    static length is ``m_tiles + G``: every row tile is visited at least once,
    each group boundary inside a tile adds one shared visit, and every *empty*
    group is forced one no-op visit (so the dW kernel flushes a zero panel for
    it).  Entries past the true count carry ``valid == 0`` and repeat the last
    tile / group id — idempotent no-ops for both the masked-store forward and
    the accumulate-then-flush dW walk.
    """
    num_groups = group_offsets.shape[0] - 1
    nt = m_tiles + num_groups
    off = group_offsets.astype(jnp.int32)
    starts = off[:-1] // bm
    ends = (off[1:] + bm - 1) // bm
    sizes = jnp.maximum(ends - starts, 1)        # empty group -> 1 no-op visit
    cum = jnp.cumsum(sizes)
    gids = jnp.repeat(jnp.arange(num_groups, dtype=jnp.int32), sizes,
                      total_repeat_length=nt)
    pos = jnp.arange(nt, dtype=jnp.int32) - (cum - sizes)[gids]
    tids = starts[gids] + pos
    valid = (jnp.arange(nt) < cum[-1]).astype(jnp.int32)
    gids = jnp.where(valid > 0, gids, num_groups - 1).astype(jnp.int32)
    tids = jnp.clip(jnp.where(valid > 0, tids, m_tiles - 1),
                    0, m_tiles - 1).astype(jnp.int32)
    return gids, tids, valid


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "trans", "out_dtype", "interpret",
                     "epilogue"),
)
def ragged_gemm(
    x: jax.Array,                 # (T, K) flat rows, groups contiguous
    w: jax.Array,                 # (G, K, N) "nn" | (G, N, K) "nt"
    group_offsets: jax.Array,     # (G+1,) prefix sums; offsets[G] == T
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    trans: str = "nn",
    out_dtype=None,
    interpret: bool | None = None,
    epilogue: "_k.Epilogue | None" = None,
    bias: jax.Array | None = None,
    scale: jax.Array | None = None,
) -> jax.Array:
    """Capacity-free grouped GEMM: y[o_g:o_{g+1}] = x[o_g:o_{g+1}] @ W_g.

    Contract: ``group_offsets`` is a non-decreasing int prefix-sum array with
    ``offsets[0] == 0`` and ``offsets[G] == x.shape[0]`` — every row belongs
    to exactly one group (the capacity path's token-dropping has no analogue
    here).  Pads rows/cols to block multiples, builds the visit list, runs the
    scalar-prefetch kernel, un-pads.  ``bias`` / dequant ``scale`` are
    per-expert (G, N) vectors applied at the flush (``epilogue`` flags)."""
    if interpret is None:
        interpret = _auto_interpret()
    epilogue = _k.IDENTITY if epilogue is None else epilogue
    out_dtype = out_dtype or x.dtype
    t_rows, k = x.shape
    if trans == "nn":
        g, kw, n = w.shape
    elif trans == "nt":
        g, n, kw = w.shape
    else:
        raise ValueError(trans)
    assert kw == k, (x.shape, w.shape, trans)
    assert group_offsets.shape == (g + 1,), (group_offsets.shape, w.shape)
    if t_rows == 0:
        return jnp.zeros((0, n), out_dtype)

    bm_ = min(bm, _ceil_to(t_rows, sublane(x.dtype)))
    bn_ = min(bn, _ceil_to(n, 128))
    bk_ = min(bk, _ceil_to(k, 128))
    # The verifier's alignment check decides the edge path: block-aligned
    # shapes skip the pad AND the output slice entirely (zero-copy).
    if block_aligned((t_rows, k, n), (bm_, bk_, bn_)):
        tp, x_p, w_p, bias_p, scale_p = t_rows, x, w, bias, scale
    else:
        tp, kp, np_ = _ceil_to(t_rows, bm_), _ceil_to(k, bk_), \
            _ceil_to(n, bn_)
        x_p = _pad_to(x, (tp, kp))
        w_p = _pad_to(w, (g, kp, np_) if trans == "nn" else (g, np_, kp))
        bias_p = None if bias is None else _pad_to(bias, (g, np_))
        scale_p = None if scale is None else _pad_to(scale, (g, np_))
    gids, tids, valid = _ragged_metadata(group_offsets, tp // bm_, bm_)
    out = _k.ftimm_gemm_ragged(
        x_p, w_p, gids, tids, valid, group_offsets.astype(jnp.int32),
        bm=bm_, bn=bn_, bk=bk_, trans=trans, out_dtype=out_dtype,
        interpret=interpret, epilogue=epilogue, bias=bias_p, scale=scale_p)
    return out if out.shape == (t_rows, n) else out[:t_rows, :n]


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"),
)
def ragged_gemm_swiglu(
    x: jax.Array,                 # (T, K)
    w_gate: jax.Array,            # (G, K, N)
    w_up: jax.Array,              # (G, K, N)
    group_offsets: jax.Array,     # (G+1,)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused-epilogue ragged pair: silu(x @ Wg_g) * (x @ Wu_g) per group, one
    kernel launch (same contract as ``ragged_gemm``)."""
    if interpret is None:
        interpret = _auto_interpret()
    out_dtype = out_dtype or x.dtype
    t_rows, k = x.shape
    g, kw, n = w_gate.shape
    assert kw == k and w_up.shape == w_gate.shape, (
        x.shape, w_gate.shape, w_up.shape)
    assert group_offsets.shape == (g + 1,), (group_offsets.shape, w_gate.shape)
    if t_rows == 0:
        return jnp.zeros((0, n), out_dtype)

    bm_ = min(bm, _ceil_to(t_rows, sublane(x.dtype)))
    bn_ = min(bn, _ceil_to(n, 128))
    bk_ = min(bk, _ceil_to(k, 128))
    # Same verifier-driven zero-copy edge path as ragged_gemm.  NOTE: the
    # swiglu kernel has no in-kernel K mask, so the K-aligned requirement
    # from block_aligned is what makes skipping the pad sound.
    if block_aligned((t_rows, k, n), (bm_, bk_, bn_)):
        tp, x_p, wg_p, wu_p = t_rows, x, w_gate, w_up
    else:
        tp, kp, np_ = _ceil_to(t_rows, bm_), _ceil_to(k, bk_), \
            _ceil_to(n, bn_)
        x_p = _pad_to(x, (tp, kp))
        wg_p = _pad_to(w_gate, (g, kp, np_))
        wu_p = _pad_to(w_up, (g, kp, np_))
    gids, tids, valid = _ragged_metadata(group_offsets, tp // bm_, bm_)
    out = _k.ftimm_gemm_ragged_swiglu(
        x_p, wg_p, wu_p, gids, tids, valid, group_offsets.astype(jnp.int32),
        bm=bm_, bn=bn_, bk=bk_, out_dtype=out_dtype, interpret=interpret)
    return out if out.shape == (t_rows, n) else out[:t_rows, :n]


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"),
)
def ragged_gemm_dw(
    x: jax.Array,                 # (T, D)
    dy: jax.Array,                # (T, F)
    group_offsets: jax.Array,     # (G+1,)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """Ragged T2 grouped GEMM: dW[g] = x[rows_g].T @ dy[rows_g] -> (G, D, F).

    ``bk`` tiles the ragged (token) dimension — the contraction; ``bm``/``bn``
    tile the per-group (D, F) output panel.  Same offsets contract as
    ``ragged_gemm``; empty groups yield zero panels."""
    if interpret is None:
        interpret = _auto_interpret()
    out_dtype = out_dtype or x.dtype
    t_rows, d = x.shape
    t2, f = dy.shape
    g = group_offsets.shape[0] - 1
    assert t2 == t_rows, (x.shape, dy.shape)
    if t_rows == 0:
        return jnp.zeros((g, d, f), out_dtype)

    bk_ = min(bk, _ceil_to(t_rows, sublane(x.dtype)))   # ragged row tiles
    bm_ = min(bm, _ceil_to(d, sublane(x.dtype)))
    bn_ = min(bn, _ceil_to(f, 128))
    # Verifier-driven zero-copy edge path (ragged axis = contraction here).
    if block_aligned((t_rows, d, f), (bk_, bm_, bn_)):
        tp, x_p, dy_p = t_rows, x, dy
    else:
        tp, dp, fp = _ceil_to(t_rows, bk_), _ceil_to(d, bm_), \
            _ceil_to(f, bn_)
        x_p = _pad_to(x, (tp, dp))
        dy_p = _pad_to(dy, (tp, fp))
    gids, tids, valid = _ragged_metadata(group_offsets, tp // bk_, bk_)
    out = _k.ftimm_gemm_ragged_dw(
        x_p, dy_p, gids, tids, valid, group_offsets.astype(jnp.int32),
        bm=bm_, bn=bn_, bk=bk_, out_dtype=out_dtype, interpret=interpret)
    return out if out.shape == (g, d, f) else out[:, :d, :f]
