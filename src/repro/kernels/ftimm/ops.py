"""jit'd public wrappers around the ftIMM Pallas kernels.

Handles what the paper calls the "implicit padding" problem explicitly: the
wrapper pads operands up to the chosen block multiples, runs the specialized
kernel, and slices the result.  The *tuner* (``repro.core.gemm``) is
responsible for choosing blocks that minimize this padding waste — the very
thing the paper's auto-generated micro-kernels achieve over TGEMM's fixed
(m_s=6, n_a=96) kernel.

On non-TPU backends the kernels run in interpret mode (Python emulation of
the kernel body) — correct but slow; the framework's model code therefore
routes through ``repro.core.gemm.dispatch`` which picks the XLA path on CPU
and the Pallas path on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _k


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, shape) -> jax.Array:
    pads = [(0, t - s) for s, t in zip(x.shape, shape)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _ceil_to(x: int, b: int) -> int:
    return (x + b - 1) // b * b


def _sublane(dtype) -> int:
    """Second-to-minor register tile extent per dtype: (8,128) fp32,
    (16,128) bf16/fp16, (32,128) int8/fp8."""
    return {4: 8, 2: 16, 1: 32}.get(jnp.dtype(dtype).itemsize, 8)


@functools.partial(
    jax.jit,
    static_argnames=(
        "bm", "bn", "bk", "nsplit", "trans", "dim_order", "out_dtype", "interpret",
    ),
)
def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    nsplit: int = 1,
    trans: str = "nn",
    dim_order: str = "mn",
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """General entry: pads, dispatches to the M-parallel or split-K kernel,
    un-pads.  ``nsplit > 1`` selects the K-parallel strategy."""
    if interpret is None:
        interpret = _auto_interpret()
    out_dtype = out_dtype or a.dtype
    m, k, n = _k._mkn(trans, a.shape, b.shape)

    bm_ = min(bm, _ceil_to(m, _sublane(a.dtype)))
    bn_, bk_ = min(bn, _ceil_to(n, 128)), bk
    mp, np_, = _ceil_to(m, bm_), _ceil_to(n, bn_)
    kp = _ceil_to(k, bk_ * nsplit) if nsplit > 1 else _ceil_to(k, bk_)
    kp = max(kp, bk_ * nsplit)

    if trans == "nn":
        a_p, b_p = _pad_to(a, (mp, kp)), _pad_to(b, (kp, np_))
    elif trans == "tn":
        a_p, b_p = _pad_to(a, (kp, mp)), _pad_to(b, (kp, np_))
    elif trans == "nt":
        a_p, b_p = _pad_to(a, (mp, kp)), _pad_to(b, (np_, kp))
    else:
        raise ValueError(trans)

    if nsplit > 1:
        out = _k.ftimm_gemm_splitk(
            a_p, b_p, bm=bm_, bn=bn_, bk=bk_, nsplit=nsplit, trans=trans,
            out_dtype=out_dtype, interpret=interpret,
        )
    else:
        out = _k.ftimm_gemm(
            a_p, b_p, bm=bm_, bn=bn_, bk=bk_, trans=trans,
            dim_order=dim_order, out_dtype=out_dtype, interpret=interpret,
        )
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "bm", "bn", "bk", "trans", "dim_order", "out_dtype", "interpret",
    ),
)
def batched_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    trans: str = "nn",
    dim_order: str = "mn",
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched/grouped entry: pads per-group panels to block multiples, runs
    the batched kernel, un-pads.  Either operand may be 2-D (shared across
    the batch — the grouped-GEMM case); the batch dim itself is never padded
    (it maps 1:1 onto the leading grid dim)."""
    if interpret is None:
        interpret = _auto_interpret()
    out_dtype = out_dtype or a.dtype
    m, k, n = _k._mkn(trans, a.shape[-2:], b.shape[-2:])

    bm_ = min(bm, _ceil_to(m, _sublane(a.dtype)))
    bn_, bk_ = min(bn, _ceil_to(n, 128)), bk
    mp, np_, kp = _ceil_to(m, bm_), _ceil_to(n, bn_), _ceil_to(k, bk_)

    def pad_panels(x, last2):
        return _pad_to(x, x.shape[:-2] + last2)

    if trans == "nn":
        a_p, b_p = pad_panels(a, (mp, kp)), pad_panels(b, (kp, np_))
    elif trans == "tn":
        a_p, b_p = pad_panels(a, (kp, mp)), pad_panels(b, (kp, np_))
    elif trans == "nt":
        a_p, b_p = pad_panels(a, (mp, kp)), pad_panels(b, (np_, kp))
    else:
        raise ValueError(trans)

    out = _k.ftimm_gemm_grouped(
        a_p, b_p, bm=bm_, bn=bn_, bk=bk_, trans=trans,
        dim_order=dim_order, out_dtype=out_dtype, interpret=interpret,
    )
    return out[:, :m, :n]
