"""Pure-jnp oracles for the ftIMM GEMM kernels.

These are the ground truth every Pallas kernel in ``kernel.py`` is validated
against (interpret mode on CPU, Mosaic on TPU). They mirror the paper's
C += A x B semantics for the three irregular shapes plus the transposed
variants the training backward pass needs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_nn(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B with A:(M,K), B:(K,N) -> (M,N); fp32 accumulation."""
    out_dtype = out_dtype or a.dtype
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(out_dtype)


def matmul_tn(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A.T @ B with A:(K,M), B:(K,N) -> (M,N); the paper's T2 layout.

    This is the shape of dW = x.T @ dy in training (K = tokens >> M ~ N).
    """
    out_dtype = out_dtype or a.dtype
    return jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(out_dtype)


def matmul_nt(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B.T with A:(M,K), B:(N,K) -> (M,N)."""
    out_dtype = out_dtype or a.dtype
    return jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(out_dtype)


def ragged_matmul_ref(x: jax.Array, w: jax.Array, group_offsets: jax.Array,
                      trans: str = "nn", out_dtype=None) -> jax.Array:
    """Dense oracle for the ragged grouped GEMM: one masked full-width GEMM
    per group, fp32 accumulation.  ``group_offsets`` may be traced; the group
    count is static.  Rows outside every group (offsets[G] < T) yield zeros —
    matching the kernel's first-visit zero-fill of unowned rows."""
    out_dtype = out_dtype or x.dtype
    num_groups = w.shape[0]
    rows = jnp.arange(x.shape[0])[:, None]
    n = w.shape[2] if trans == "nn" else w.shape[1]
    acc = jnp.zeros((x.shape[0], n), jnp.float32)
    for g in range(num_groups):
        mask = (rows >= group_offsets[g]) & (rows < group_offsets[g + 1])
        xg = jnp.where(mask, x, jnp.zeros_like(x))
        dims = ((1,), (0,)) if trans == "nn" else ((1,), (1,))
        acc = acc + jax.lax.dot_general(
            xg, w[g], (dims, ((), ())), preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


def ragged_matmul_dw_ref(x: jax.Array, dy: jax.Array,
                         group_offsets: jax.Array,
                         out_dtype=None) -> jax.Array:
    """Dense oracle for the ragged T2 backward: per-group x^T @ dy with rows
    outside the group masked to zero -> (G, D, F)."""
    out_dtype = out_dtype or x.dtype
    num_groups = group_offsets.shape[0] - 1
    rows = jnp.arange(x.shape[0])[:, None]
    panels = []
    for g in range(num_groups):
        mask = (rows >= group_offsets[g]) & (rows < group_offsets[g + 1])
        xg = jnp.where(mask, x, jnp.zeros_like(x))
        panels.append(jax.lax.dot_general(
            xg, dy, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))
    return jnp.stack(panels).astype(out_dtype)


def ragged_swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                      group_offsets: jax.Array, out_dtype=None) -> jax.Array:
    """Oracle for the fused ragged SwiGLU pair: silu(x@Wg_g) * (x@Wu_g)."""
    out_dtype = out_dtype or x.dtype
    a = ragged_matmul_ref(x, w_gate, group_offsets, out_dtype=jnp.float32)
    b = ragged_matmul_ref(x, w_up, group_offsets, out_dtype=jnp.float32)
    return (jax.nn.silu(a) * b).astype(out_dtype)


def matmul_splitk(a: jax.Array, b: jax.Array, nsplit: int, out_dtype=None) -> jax.Array:
    """Reference for the K-parallel strategy: partial products over K chunks
    reduced at the end (the paper's Alg. 5 GSM reduction)."""
    out_dtype = out_dtype or a.dtype
    m, k = a.shape
    _, n = b.shape
    assert k % nsplit == 0, (k, nsplit)
    ks = k // nsplit
    partials = jnp.stack(
        [
            jax.lax.dot_general(
                a[:, s * ks:(s + 1) * ks],
                b[s * ks:(s + 1) * ks, :],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            for s in range(nsplit)
        ]
    )
    return jnp.sum(partials, axis=0).astype(out_dtype)
