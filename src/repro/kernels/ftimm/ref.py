"""Pure-jnp oracles for the ftIMM GEMM kernels.

These are the ground truth every Pallas kernel in ``kernel.py`` is validated
against (interpret mode on CPU, Mosaic on TPU). They mirror the paper's
C += A x B semantics for the three irregular shapes plus the transposed
variants the training backward pass needs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_nn(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B with A:(M,K), B:(K,N) -> (M,N); fp32 accumulation."""
    out_dtype = out_dtype or a.dtype
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(out_dtype)


def matmul_tn(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A.T @ B with A:(K,M), B:(K,N) -> (M,N); the paper's T2 layout.

    This is the shape of dW = x.T @ dy in training (K = tokens >> M ~ N).
    """
    out_dtype = out_dtype or a.dtype
    return jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(out_dtype)


def matmul_nt(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """C = A @ B.T with A:(M,K), B:(N,K) -> (M,N)."""
    out_dtype = out_dtype or a.dtype
    return jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(out_dtype)


def matmul_splitk(a: jax.Array, b: jax.Array, nsplit: int, out_dtype=None) -> jax.Array:
    """Reference for the K-parallel strategy: partial products over K chunks
    reduced at the end (the paper's Alg. 5 GSM reduction)."""
    out_dtype = out_dtype or a.dtype
    m, k = a.shape
    _, n = b.shape
    assert k % nsplit == 0, (k, nsplit)
    ks = k // nsplit
    partials = jnp.stack(
        [
            jax.lax.dot_general(
                a[:, s * ks:(s + 1) * ks],
                b[s * ks:(s + 1) * ks, :],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            for s in range(nsplit)
        ]
    )
    return jnp.sum(partials, axis=0).astype(out_dtype)
