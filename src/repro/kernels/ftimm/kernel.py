"""ftIMM Pallas TPU kernels: shape-specialized tiled GEMM.

Paper mapping (Yin et al., 2022):

* The paper auto-generates assembly micro-kernels per (m_s, k_a, n_a) so that
  small-N GEMMs neither waste AM space nor compute padded lanes.  Here the
  "generator" is a parametric ``pl.pallas_call`` factory: block shapes
  (bm, bn, bk), the grid order, and the split-K factor are free parameters
  chosen by the CMR tuner (``repro.core.gemm``), and Mosaic plays the role of
  the assembler.  The DMA ping-pong double buffering of the paper is the
  Pallas grid pipeline (automatic double-buffering of input blocks between
  sequential grid steps).

* M-parallel strategy (paper Alg. 4)  -> ``ftimm_gemm``: grid over
  (M/bm, N/bn) "parallel" dims with the K loop innermost ("arbitrary"), the
  fp32 accumulator resident in VMEM scratch across K steps (the role GSM/AM
  reuse plays in the paper).

* K-parallel strategy (paper Alg. 5)  -> ``ftimm_gemm_splitk``: the grid
  splits K into ``nsplit`` independent partial products; partials land in an
  fp32 buffer that is reduced afterwards (the paper reduces through GSM; on
  TPU the reduction is an XLA add — and across chips it is a psum over ICI,
  see ``repro.core.gemm.distributed``).

All kernels accumulate in fp32 regardless of input dtype.  Block shapes must
be multiples of the TPU register tiling — (8,128) fp32 / (16,128) bf16 — a
constraint the tuner enforces.  Operand shapes need NOT divide into the
blocks: remainder tiles are handled in-kernel (the grid is ``cdiv``-sized and
the contraction remainder is masked with iota compares), so the ops wrappers
can pass unpadded operands straight through — zero-copy in, unsliced out.
Out-of-range rows/cols of edge blocks read as garbage (Mosaic) / NaN
(interpret) but only ever land in output elements the store drops; only the
contraction dimension's garbage could poison valid outputs, hence only it is
masked (both operands — 0 * NaN is NaN, so masking one side is not enough).

Epilogues: every kernel family takes an ``Epilogue`` spec applied to the fp32
accumulator at the flush (scale -> bias add -> activation -> residual add ->
output cast), so dense model layers stop running silu/bias/residual as
separate XLA passes over the output.  The fused ``silu(x@Wg) * (x@Wu)`` pair
exists as a dense (``ftimm_gemm_swiglu``) and grouped
(``ftimm_gemm_grouped_swiglu``) two-accumulator variant mirroring the ragged
one.  The split-K kernel applies the epilogue after its partials reduction
(the activation is nonlinear; flushing it per split would be wrong).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import pallas_compiler_params, prefetch_scalar_grid_spec
from .epilogue import IDENTITY, Epilogue

DimOrder = Literal["mn", "nm"]


def _k_limit(k_total: int, bk: int, kb_idx):
    """Valid contraction extent of K block ``kb_idx`` — ``bk`` for interior
    blocks, the remainder for the edge block, 0 for fully out-of-range blocks
    (split-K grids can produce those)."""
    return jnp.clip(k_total - kb_idx * bk, 0, bk)


def _mask_contract(blk, k_lim, dim: int):
    """Zero a block's out-of-range contraction rows/cols (iota compare)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, blk.shape, dim)
    return jnp.where(iota < k_lim, blk, jnp.zeros_like(blk))


def _unpack_epi(rest, epi: Epilogue):
    """Split a kernel's trailing refs into (bias, residual, scale, c,
    *scratch) — same bias -> residual -> scale order as ``Epilogue.unpack``.
    """
    i = 0
    bias_ref = rest[i] if epi.bias else None
    i += int(epi.bias)
    res_ref = rest[i] if epi.residual else None
    i += int(epi.residual)
    scale_ref = rest[i] if epi.scale_vec else None
    i += int(epi.scale_vec)
    return bias_ref, res_ref, scale_ref, rest[i], rest[i + 1:]


def _acc_dtype(a_dtype, b_dtype):
    """Accumulator dtype under the dtype axis: int x int accumulates in
    int32 (the int8 MXU contract); every float combination (incl. fp8 and
    the mixed weight-only case) accumulates in fp32."""
    if (jnp.issubdtype(jnp.dtype(a_dtype), jnp.integer)
            and jnp.issubdtype(jnp.dtype(b_dtype), jnp.integer)):
        return jnp.int32
    return jnp.float32


def _dot_operands(a_blk, b_blk):
    """Prepare the operand pair for the MXU dot under the dtype axis.

    int x int passes through (int32 accumulate).  Mixed float x int — the
    weight-only-quant path — upcasts the integer operand to the float
    operand's dtype AT LOAD (the in-kernel dequant step; the scale applies
    at the flush).  fp8 operands upcast to fp32 before the dot so the same
    kernel body runs under interpret mode / CPU lowering."""
    a_int = jnp.issubdtype(a_blk.dtype, jnp.integer)
    b_int = jnp.issubdtype(b_blk.dtype, jnp.integer)
    if a_int and b_int:
        return a_blk, b_blk
    if a_int:
        return a_blk.astype(b_blk.dtype), b_blk
    if b_int:
        return a_blk, b_blk.astype(a_blk.dtype)
    if a_blk.dtype.itemsize == 1 or b_blk.dtype.itemsize == 1:
        return a_blk.astype(jnp.float32), b_blk.astype(jnp.float32)
    return a_blk, b_blk


def _accum_body(a_blk, b_blk, c_ref, acc_ref, *, k, nk, dims, k_lim=None,
                epi: Epilogue = IDENTITY, bias_ref=None, res_ref=None,
                scale_ref=None):
    """Shared accumulate-and-flush body across all kernel variants."""

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if k_lim is not None:
        a_blk = _mask_contract(a_blk, k_lim, dims[0][0])
        b_blk = _mask_contract(b_blk, k_lim, dims[1][0])
    a_blk, b_blk = _dot_operands(a_blk, b_blk)
    acc_ref[...] += jax.lax.dot_general(
        a_blk, b_blk, (dims, ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == nk - 1)
    def _flush():
        acc = acc_ref[...]
        if not epi.is_identity:
            acc = epi.apply(
                acc.astype(jnp.float32),
                bias=None if bias_ref is None else bias_ref[...],
                residual=None if res_ref is None else res_ref[...],
                scale=None if scale_ref is None else scale_ref[...])
        c_ref[...] = acc.astype(c_ref.dtype)


def _dense_kernel(a_ref, b_ref, *rest, nk, dims, bk, k_total, mask_k,
                  epi: Epilogue):
    bias_ref, res_ref, scale_ref, c_ref, (acc_ref,) = _unpack_epi(rest, epi)
    k = pl.program_id(2)
    k_lim = _k_limit(k_total, bk, k) if mask_k else None
    _accum_body(a_ref[...], b_ref[...], c_ref, acc_ref, k=k, nk=nk,
                dims=dims, k_lim=k_lim, epi=epi, bias_ref=bias_ref,
                res_ref=res_ref, scale_ref=scale_ref)


def _specs(trans: str, bm: int, bn: int, bk: int, order: DimOrder):
    """BlockSpecs for each operand layout under a given grid order.

    Grid is (outer, inner, k) with k innermost so the fp32 accumulator block
    is revisited across K steps (paper: C_a stays in AM during the k_g loop).
    ``order`` decides whether the M or the N dimension is the outer parallel
    loop — the paper's loop-order-for-reuse discussion: the operand indexed
    by the *inner* dim is re-fetched per outer step, the other is reused.
    """
    if order == "mn":
        i_of = lambda i, j, k: i   # noqa: E731
        j_of = lambda i, j, k: j   # noqa: E731
    else:
        i_of = lambda i, j, k: j   # noqa: E731
        j_of = lambda i, j, k: i   # noqa: E731
    if trans == "nn":
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i_of(i, j, k), k))
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j_of(i, j, k)))
    elif trans == "tn":
        a_spec = pl.BlockSpec((bk, bm), lambda i, j, k: (k, i_of(i, j, k)))
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j_of(i, j, k)))
    elif trans == "nt":
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i_of(i, j, k), k))
        b_spec = pl.BlockSpec((bn, bk), lambda i, j, k: (j_of(i, j, k), k))
    else:  # pragma: no cover
        raise ValueError(trans)
    c_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i_of(i, j, k), j_of(i, j, k)))
    bias_spec = pl.BlockSpec((1, bn), lambda i, j, k: (0, j_of(i, j, k)))
    return a_spec, b_spec, c_spec, bias_spec


def _mkn(trans: str, a_shape, b_shape):
    if trans == "nn":
        (m, k), (_, n) = a_shape, b_shape
    elif trans == "tn":
        (k, m), (_, n) = a_shape, b_shape
    else:  # nt
        (m, k), (n, _) = a_shape, b_shape
    return m, k, n


_DIMS = {"nn": ((1,), (0,)), "tn": ((0,), (0,)), "nt": ((1,), (1,))}


def ftimm_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    trans: str = "nn",
    dim_order: DimOrder = "mn",
    out_dtype=None,
    interpret: bool = False,
    epilogue: Epilogue = IDENTITY,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
) -> jax.Array:
    """M-parallel ftIMM GEMM.  Shapes need not be block multiples: the grid
    is cdiv-sized and remainder K tiles are masked in-kernel (zero-copy edge
    tiles); out-of-range output elements are dropped by the store.

    trans: "nn" A(M,K)@B(K,N); "tn" A(K,M).T@B(K,N); "nt" A(M,K)@B(N,K).T.
    ``epilogue`` is applied to the fp32 accumulator at the flush; ``bias``
    (N,), ``residual`` (M, N) and the dequant ``scale`` vector (N,) ride
    along as extra inputs when the spec asks for them.  Integer x integer
    operands accumulate in int32 (the int8 path); mixed float x int
    operands dequantize at load (weight-only quant).
    """
    m, k, n = _mkn(trans, a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    gm, gn, gk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk)
    grid = (gm, gn, gk) if dim_order == "mn" else (gn, gm, gk)
    a_spec, b_spec, c_spec, bias_spec = _specs(trans, bm, bn, bk, dim_order)
    in_specs, inputs = [a_spec, b_spec], [a, b]
    if epilogue.bias:
        in_specs.append(bias_spec)
        inputs.append(bias.reshape(1, n))
    if epilogue.residual:
        in_specs.append(c_spec)
        inputs.append(residual)
    if epilogue.scale_vec:
        in_specs.append(bias_spec)
        inputs.append(scale.reshape(1, n).astype(jnp.float32))
    return pl.pallas_call(
        functools.partial(_dense_kernel, nk=gk, dims=_DIMS[trans], bk=bk,
                          k_total=k, mask_k=bool(k % bk), epi=epilogue),
        grid=grid,
        in_specs=in_specs,
        out_specs=c_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), _acc_dtype(a.dtype, b.dtype))],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*inputs)


def _batched_kernel(a_ref, b_ref, *rest, nk, dims, a_batched, b_batched,
                    bk, k_total, mask_k, epi: Epilogue):
    bias_ref, res_ref, scale_ref, c_ref, (acc_ref,) = _unpack_epi(rest, epi)
    a_blk = a_ref[0] if a_batched else a_ref[...]
    b_blk = b_ref[0] if b_batched else b_ref[...]
    k = pl.program_id(3)
    k_lim = _k_limit(k_total, bk, k) if mask_k else None
    _accum_body(a_blk, b_blk, c_ref.at[0], acc_ref, k=k, nk=nk, dims=dims,
                k_lim=k_lim, epi=epi, bias_ref=bias_ref,
                res_ref=None if res_ref is None else res_ref.at[0],
                scale_ref=scale_ref)


def _batched_specs(trans: str, bm: int, bn: int, bk: int, order: DimOrder,
                   a_batched: bool, b_batched: bool):
    """BlockSpecs for the (g, outer, inner, k) grid.

    Batched operands carry a leading size-1 block indexed by the batch grid
    dim; a *shared* (2-D) operand's index map simply omits ``g`` — the Pallas
    pipeline then keeps its block resident across consecutive batch entries
    whenever the rest of the index map is constant (the grouped-GEMM analogue
    of the paper's "B panel cached in GSM" reuse, now across the batch)."""
    if order == "mn":
        i_of = lambda g, i, j, k: i   # noqa: E731
        j_of = lambda g, i, j, k: j   # noqa: E731
    else:
        i_of = lambda g, i, j, k: j   # noqa: E731
        j_of = lambda g, i, j, k: i   # noqa: E731

    def spec(batched: bool, shape2, idx2):
        if batched:
            return pl.BlockSpec(
                (1,) + shape2, lambda g, i, j, k: (g,) + idx2(g, i, j, k))
        return pl.BlockSpec(shape2, lambda g, i, j, k: idx2(g, i, j, k))

    if trans == "nn":
        a_spec = spec(a_batched, (bm, bk),
                      lambda g, i, j, k: (i_of(g, i, j, k), k))
        b_spec = spec(b_batched, (bk, bn),
                      lambda g, i, j, k: (k, j_of(g, i, j, k)))
    elif trans == "tn":
        a_spec = spec(a_batched, (bk, bm),
                      lambda g, i, j, k: (k, i_of(g, i, j, k)))
        b_spec = spec(b_batched, (bk, bn),
                      lambda g, i, j, k: (k, j_of(g, i, j, k)))
    elif trans == "nt":
        a_spec = spec(a_batched, (bm, bk),
                      lambda g, i, j, k: (i_of(g, i, j, k), k))
        b_spec = spec(b_batched, (bn, bk),
                      lambda g, i, j, k: (j_of(g, i, j, k), k))
    else:  # pragma: no cover
        raise ValueError(trans)
    c_spec = pl.BlockSpec(
        (1, bm, bn),
        lambda g, i, j, k: (g, i_of(g, i, j, k), j_of(g, i, j, k)))
    bias_spec = pl.BlockSpec((1, bn), lambda g, i, j, k: (0, j_of(g, i, j, k)))
    # Per-group variant: the (N,)-wide vector is indexed by the batch grid
    # dim — one bias/scale row per group (the per-expert epilogue).
    gbias_spec = pl.BlockSpec(
        (1, bn), lambda g, i, j, k: (g, j_of(g, i, j, k)))
    return a_spec, b_spec, c_spec, bias_spec, gbias_spec


def ftimm_gemm_grouped(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    trans: str = "nn",
    dim_order: DimOrder = "mn",
    out_dtype=None,
    interpret: bool = False,
    epilogue: Epilogue = IDENTITY,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
) -> jax.Array:
    """Grouped ftIMM GEMM: per-group operands with optional sharing.

    Either operand may be 3-D ``(G, ., .)`` (one panel per group — the MoE
    expert-weight case ``(E, C, D) @ (E, D, F)``) or 2-D (one panel shared by
    every group, e.g. a common activation against per-group weights or vice
    versa).  At least one operand must be 3-D.  Per-group shapes need not be
    block multiples (remainder K tiles masked in-kernel); returns
    ``(G, M, N)``.  ``epilogue`` flushes fused: ``bias`` is (N,) shared
    across the batch or (G, N) per group (the per-expert epilogue), and the
    same for the dequant ``scale`` vector; ``residual`` is (G, M, N).
    """
    a_batched, b_batched = a.ndim == 3, b.ndim == 3
    assert a_batched or b_batched, (a.shape, b.shape)
    if a_batched and b_batched:
        assert a.shape[0] == b.shape[0], (a.shape, b.shape)
    gsize = a.shape[0] if a_batched else b.shape[0]
    m, k, n = _mkn(trans, a.shape[-2:], b.shape[-2:])
    out_dtype = out_dtype or a.dtype
    gm, gn, gk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk)
    grid = ((gsize, gm, gn, gk) if dim_order == "mn"
            else (gsize, gn, gm, gk))
    a_spec, b_spec, c_spec, bias_spec, gbias_spec = _batched_specs(
        trans, bm, bn, bk, dim_order, a_batched, b_batched)

    def vec_arg(v):
        """(N,) shared vs (G, N) per-group (N,)-wide epilogue operand."""
        if v.ndim == 2:
            assert v.shape == (gsize, n), (v.shape, gsize, n)
            return gbias_spec, v
        return bias_spec, v.reshape(1, n)

    in_specs, inputs = [a_spec, b_spec], [a, b]
    if epilogue.bias:
        spec, arg = vec_arg(bias)
        in_specs.append(spec)
        inputs.append(arg)
    if epilogue.residual:
        in_specs.append(c_spec)
        inputs.append(residual)
    if epilogue.scale_vec:
        spec, arg = vec_arg(scale.astype(jnp.float32))
        in_specs.append(spec)
        inputs.append(arg)
    return pl.pallas_call(
        functools.partial(_batched_kernel, nk=gk, dims=_DIMS[trans],
                          a_batched=a_batched, b_batched=b_batched, bk=bk,
                          k_total=k, mask_k=bool(k % bk), epi=epilogue),
        grid=grid,
        in_specs=in_specs,
        out_specs=c_spec,
        out_shape=jax.ShapeDtypeStruct((gsize, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), _acc_dtype(a.dtype, b.dtype))],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(*inputs)


def ftimm_gemm_batched(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    trans: str = "nn",
    dim_order: DimOrder = "mn",
    out_dtype=None,
    interpret: bool = False,
    epilogue: Epilogue = IDENTITY,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
) -> jax.Array:
    """Batched ftIMM GEMM: leading batch grid dim over independent per-entry
    GEMMs, ``(G, M, K) @ (G, K, N) -> (G, M, N)`` (trans variants as in
    ``ftimm_gemm``).  The accumulator is revisited across the innermost
    K steps exactly as in the 2-D kernel; each batch entry owns its own
    output block so the batch dim is fully parallel."""
    assert a.ndim == 3 and b.ndim == 3, (a.shape, b.shape)
    return ftimm_gemm_grouped(
        a, b, bm=bm, bn=bn, bk=bk, trans=trans, dim_order=dim_order,
        out_dtype=out_dtype, interpret=interpret, epilogue=epilogue,
        bias=bias, residual=residual, scale=scale)


# ---------------------------------------------------------------------------
# Ragged (capacity-free) grouped GEMM — megablocks-style.
#
# Rows of a flat (T, K) operand are partitioned into G contiguous groups by a
# ``group_offsets`` prefix-sum array (dynamic values — the per-expert token
# counts of a capacity-free MoE dispatch).  The kernel walks a sorted list of
# (row-tile, group) visits; the visit list is *data-dependent*, so its
# ``group_ids`` / ``tile_ids`` arrays arrive via scalar prefetch and drive the
# BlockSpec index maps (which expert's weight panel to DMA for each step) —
# the ragged analogue of the paper's per-shape micro-kernel selection, decided
# per row-tile instead of per call.
#
# A row tile shared by several groups is visited once per group; each visit
# computes the full tile product against its own group's panel and stores only
# its own rows (masked read-modify-write).  Visits of the same tile are
# adjacent in the sorted list, so the output block stays VMEM-resident between
# them and the first visit zero-fills rows owned by no group (row padding).
# The static visit-list length is T/bm + G (every boundary adds at most one
# shared tile; empty groups get one forced no-op visit so each group id
# appears — see ops._ragged_metadata); padded tail entries have ``valid == 0``
# and mask to no-ops.
# ---------------------------------------------------------------------------


def _ragged_row_mask(offs_ref, g, tile, valid, shape, bm):
    """Rows of this (bm, .) tile owned by group ``g`` — empty when invalid."""
    rows = tile * bm + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    return (rows >= offs_ref[g]) & (rows < offs_ref[g + 1]) & (valid > 0)


def _ragged_store(gids_ref, tids_ref, valid_ref, offs_ref, o_ref, acc,
                  *, t, bm):
    """Masked read-modify-write of one output row tile.

    First visit of a tile zero-fills the rows outside the mask; later visits
    (same tile, next group — adjacent grid steps, block resident) preserve
    them.  Reading ``o_ref`` on a first visit would be garbage, but the
    ``where`` never selects it then."""
    g, tile = gids_ref[t], tids_ref[t]
    mask = _ragged_row_mask(offs_ref, g, tile, valid_ref[t], acc.shape, bm)
    first = (t == 0) | (tile != tids_ref[jnp.maximum(t - 1, 0)])
    prev = jnp.where(first, 0.0, o_ref[...].astype(jnp.float32))
    o_ref[...] = jnp.where(mask, acc, prev).astype(o_ref.dtype)


def _ragged_kernel(gids_ref, tids_ref, valid_ref, offs_ref,
                   x_ref, w_ref, *rest, nk, bm, dims, epi: Epilogue):
    i = 0
    bias_ref = rest[i] if epi.bias else None
    i += int(epi.bias)
    scale_ref = rest[i] if epi.scale_vec else None
    i += int(epi.scale_vec)
    o_ref, acc_ref = rest[i], rest[i + 1]
    t, k = pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x_blk, w_blk = _dot_operands(x_ref[...], w_ref[0])
    acc_ref[...] += jax.lax.dot_general(
        x_blk, w_blk, (dims, ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(k == nk - 1)
    def _flush():
        # The per-expert bias/scale blocks arrive pre-indexed by this
        # visit's group id; applying them to the WHOLE tile accumulator is
        # sound because the masked store below only lands this group's rows
        # — foreign rows (computed against the wrong panel anyway) drop.
        acc = acc_ref[...]
        if not epi.is_identity:
            acc = epi.apply(
                acc.astype(jnp.float32),
                bias=None if bias_ref is None else bias_ref[0],
                scale=None if scale_ref is None else scale_ref[0])
        _ragged_store(gids_ref, tids_ref, valid_ref, offs_ref, o_ref,
                      acc, t=t, bm=bm)


def ftimm_gemm_ragged(
    x: jax.Array,                 # (Tp, Kp) flat rows, padded
    w: jax.Array,                 # (G, Kp, Np) "nn" | (G, Np, Kp) "nt"
    group_ids: jax.Array,         # (NT,) int32 — visit list (scalar prefetch)
    tile_ids: jax.Array,          # (NT,) int32
    valid: jax.Array,             # (NT,) int32 0/1
    group_offsets: jax.Array,     # (G+1,) int32 prefix sums, offsets[G] == T
    *,
    bm: int,
    bn: int,
    bk: int,
    trans: str = "nn",
    out_dtype=None,
    interpret: bool = False,
    epilogue: Epilogue = IDENTITY,
    bias: jax.Array | None = None,
    scale: jax.Array | None = None,
) -> jax.Array:
    """Ragged grouped GEMM: per-group row chunks against per-group panels.

    Grid is (N/bn, NT, K/bk): N outermost so consecutive visits of a shared
    row tile keep the same output block resident (the masked-store protocol
    above); K innermost revisits the accumulator as in ``ftimm_gemm``.
    ``trans`` transposes the per-group panel: "nn" contracts panel rows,
    "nt" panel columns (the dX backward of the "nn" forward).

    ``epilogue`` supports the per-expert operands: ``bias`` (G, N) and the
    dequant ``scale`` vector (G, N) are indexed by the visit list's group id
    and applied at the flush (residual is not supported here — the RMW
    store would double-add it on shared tiles).  Mixed float x int operands
    dequantize at load (int8 expert panels under bf16 tokens).
    """
    tp, kp = x.shape
    out_dtype = out_dtype or x.dtype
    assert not epilogue.residual, "ragged kernel has no residual operand"
    if trans == "nn":
        _, kp_w, np_ = w.shape
        dims = ((1,), (0,))
        w_spec = pl.BlockSpec(
            (1, bk, bn), lambda j, t, k, g_r, t_r, v_r, o_r: (g_r[t], k, j))
    elif trans == "nt":
        _, np_, kp_w = w.shape
        dims = ((1,), (1,))
        w_spec = pl.BlockSpec(
            (1, bn, bk), lambda j, t, k, g_r, t_r, v_r, o_r: (g_r[t], j, k))
    else:
        raise ValueError(trans)
    assert kp_w == kp and tp % bm == 0 and kp % bk == 0 and np_ % bn == 0, (
        x.shape, w.shape, bm, bn, bk)
    nt = group_ids.shape[0]
    gk = kp // bk
    num_groups = group_offsets.shape[0] - 1
    x_spec = pl.BlockSpec(
        (bm, bk), lambda j, t, k, g_r, t_r, v_r, o_r: (t_r[t], k))
    o_spec = pl.BlockSpec(
        (bm, bn), lambda j, t, k, g_r, t_r, v_r, o_r: (t_r[t], j))
    # Per-expert (N,)-wide epilogue operand: one row per group, indexed by
    # the visit's group id exactly like the weight panel.
    vec_spec = pl.BlockSpec(
        (1, 1, bn), lambda j, t, k, g_r, t_r, v_r, o_r: (g_r[t], 0, j))
    in_specs, inputs = [x_spec, w_spec], [x, w]
    if epilogue.bias:
        assert bias.shape == (num_groups, np_), (bias.shape, w.shape)
        in_specs.append(vec_spec)
        inputs.append(bias.reshape(num_groups, 1, np_))
    if epilogue.scale_vec:
        assert scale.shape == (num_groups, np_), (scale.shape, w.shape)
        in_specs.append(vec_spec)
        inputs.append(scale.reshape(num_groups, 1, np_).astype(jnp.float32))
    return pl.pallas_call(
        functools.partial(_ragged_kernel, nk=gk, bm=bm, dims=dims,
                          epi=epilogue),
        grid_spec=prefetch_scalar_grid_spec(
            num_scalar_prefetch=4,
            grid=(np_ // bn, nt, gk),
            in_specs=in_specs,
            out_specs=o_spec,
            scratch_shapes=[pltpu.VMEM((bm, bn),
                                       _acc_dtype(x.dtype, w.dtype))],
        ),
        out_shape=jax.ShapeDtypeStruct((tp, np_), out_dtype),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(group_ids, tile_ids, valid, group_offsets, *inputs)


def _ragged_swiglu_kernel(gids_ref, tids_ref, valid_ref, offs_ref,
                          x_ref, wg_ref, wu_ref, o_ref,
                          accg_ref, accu_ref, *, nk, bm):
    t, k = pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x_blk = x_ref[...]
    accg_ref[...] += jax.lax.dot_general(
        x_blk, wg_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    accu_ref[...] += jax.lax.dot_general(
        x_blk, wu_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        gate = accg_ref[...]
        act = gate * jax.nn.sigmoid(gate) * accu_ref[...]
        _ragged_store(gids_ref, tids_ref, valid_ref, offs_ref, o_ref,
                      act, t=t, bm=bm)


def ftimm_gemm_ragged_swiglu(
    x: jax.Array,                 # (Tp, Kp)
    w_gate: jax.Array,            # (G, Kp, Np)
    w_up: jax.Array,              # (G, Kp, Np)
    group_ids: jax.Array,
    tile_ids: jax.Array,
    valid: jax.Array,
    group_offsets: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Ragged grouped GEMM pair with fused silu(x@Wg) * (x@Wu) epilogue.

    One kernel launch for the MoE gate/up projections: both panels stream
    against the same x tile (one fetch of x per step instead of two), two
    fp32 accumulators ride the K loop, and the SwiGLU nonlinearity is applied
    in VMEM at the flush — the epilogue fusion the grouped subsystem's
    ROADMAP entry called for."""
    tp, kp = x.shape
    out_dtype = out_dtype or x.dtype
    _, kp_w, np_ = w_gate.shape
    assert w_up.shape == w_gate.shape and kp_w == kp, (w_gate.shape, w_up.shape)
    assert tp % bm == 0 and kp % bk == 0 and np_ % bn == 0, (
        x.shape, w_gate.shape, bm, bn, bk)
    nt = group_ids.shape[0]
    gk = kp // bk
    x_spec = pl.BlockSpec(
        (bm, bk), lambda j, t, k, g_r, t_r, v_r, o_r: (t_r[t], k))
    w_spec = pl.BlockSpec(
        (1, bk, bn), lambda j, t, k, g_r, t_r, v_r, o_r: (g_r[t], k, j))
    o_spec = pl.BlockSpec(
        (bm, bn), lambda j, t, k, g_r, t_r, v_r, o_r: (t_r[t], j))
    return pl.pallas_call(
        functools.partial(_ragged_swiglu_kernel, nk=gk, bm=bm),
        grid_spec=prefetch_scalar_grid_spec(
            num_scalar_prefetch=4,
            grid=(np_ // bn, nt, gk),
            in_specs=[x_spec, w_spec, w_spec],
            out_specs=o_spec,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                            pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((tp, np_), out_dtype),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(group_ids, tile_ids, valid, group_offsets, x, w_gate, w_up)


def _ragged_dw_kernel(gids_ref, tids_ref, valid_ref, offs_ref,
                      x_ref, dy_ref, o_ref, acc_ref, *, nt, bm):
    t = pl.program_id(2)
    g = gids_ref[t]
    first = (t == 0) | (g != gids_ref[jnp.maximum(t - 1, 0)])
    last = (t == nt - 1) | (g != gids_ref[jnp.minimum(t + 1, nt - 1)])

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x_blk = x_ref[...]
    mask = _ragged_row_mask(offs_ref, g, tids_ref[t], valid_ref[t],
                            x_blk.shape, bm)
    x_blk = jnp.where(mask, x_blk, jnp.zeros_like(x_blk))
    acc_ref[...] += jax.lax.dot_general(
        x_blk, dy_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(last)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def ftimm_gemm_ragged_dw(
    x: jax.Array,                 # (Tp, Dp) padded rows
    dy: jax.Array,                # (Tp, Fp)
    group_ids: jax.Array,
    tile_ids: jax.Array,
    valid: jax.Array,
    group_offsets: jax.Array,
    *,
    bm: int,                      # D-dim block (output rows)
    bn: int,                      # F-dim block (output cols)
    bk: int,                      # ragged row-tile size (contraction)
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Ragged T2 grouped GEMM: dW[g] = x[rows_g].T @ dy[rows_g] -> (G, D, F).

    The ragged dimension is now the *contraction* (the paper's T2 regime,
    K = tokens >> M ~ N, per group).  Grid is (D/bm, F/bn, NT) with the visit
    list innermost: visits of one group are contiguous, so the fp32
    accumulator integrates that group's row tiles and flushes once per group;
    boundary tiles mask foreign rows on the *input* side (zeroed before the
    dot) since the contraction admits no output-side masking.  Metadata
    forces one visit per empty group, whose flush stores the zero panel."""
    tp, dp = x.shape
    tp2, fp = dy.shape
    out_dtype = out_dtype or x.dtype
    assert tp2 == tp and tp % bk == 0 and dp % bm == 0 and fp % bn == 0, (
        x.shape, dy.shape, bm, bn, bk)
    num_groups = group_offsets.shape[0] - 1
    nt = group_ids.shape[0]
    x_spec = pl.BlockSpec(
        (bk, bm), lambda i, j, t, g_r, t_r, v_r, o_r: (t_r[t], i))
    dy_spec = pl.BlockSpec(
        (bk, bn), lambda i, j, t, g_r, t_r, v_r, o_r: (t_r[t], j))
    o_spec = pl.BlockSpec(
        (1, bm, bn), lambda i, j, t, g_r, t_r, v_r, o_r: (g_r[t], i, j))
    return pl.pallas_call(
        functools.partial(_ragged_dw_kernel, nt=nt, bm=bk),
        grid_spec=prefetch_scalar_grid_spec(
            num_scalar_prefetch=4,
            grid=(dp // bm, fp // bn, nt),
            in_specs=[x_spec, dy_spec],
            out_specs=o_spec,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((num_groups, dp, fp), out_dtype),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(group_ids, tile_ids, valid, group_offsets, x, dy)


def _splitk_kernel(a_ref, b_ref, c_ref, acc_ref, *, nk, dims, gk, bk,
                   k_total, mask_k):
    s, k = pl.program_id(0), pl.program_id(3)
    k_lim = _k_limit(k_total, bk, s * gk + k) if mask_k else None
    _accum_body(a_ref[...], b_ref[...], c_ref.at[0], acc_ref,
                k=k, nk=nk, dims=dims, k_lim=k_lim)


def ftimm_gemm_splitk(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    nsplit: int,
    trans: str = "nn",
    out_dtype=None,
    interpret: bool = False,
    epilogue: Epilogue = IDENTITY,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
) -> jax.Array:
    """K-parallel ftIMM GEMM (paper Alg. 5).

    Returns the REDUCED (M, N) result; the partials buffer (nsplit, M, N)
    — fp32, or int32 on the int x int path — is produced by the kernel and
    summed outside it, the TPU analogue of the paper's reduction of
    per-core partial C through GSM.  K need not divide into nsplit *
    bk-multiples: each split owns ``cdiv(cdiv(K, bk), nsplit)`` K blocks
    and out-of-range blocks mask to zero contributions.  The epilogue
    applies AFTER the reduction (its activation is nonlinear, so per-split
    flushing would be wrong; the LINEAR dequant ``scale`` vector commutes
    with the sum, so applying it post-reduction is exact) — still one fused
    elementwise pass over the partial sum, not per-op XLA passes over a
    stored output.
    """
    m, k, n = _mkn(trans, a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    nkb = pl.cdiv(k, bk)                 # total K blocks over the real K
    gk = pl.cdiv(nkb, nsplit)            # K blocks per split
    mask_k = bool(k % bk) or bool(nkb % nsplit)
    gm, gn = pl.cdiv(m, bm), pl.cdiv(n, bn)
    dims = _DIMS[trans]

    # Index maps: split s owns K blocks [s*gk, (s+1)*gk).
    if trans == "nn":
        a_spec = pl.BlockSpec((bm, bk), lambda s, i, j, k: (i, s * gk + k))
        b_spec = pl.BlockSpec((bk, bn), lambda s, i, j, k: (s * gk + k, j))
    elif trans == "tn":
        a_spec = pl.BlockSpec((bk, bm), lambda s, i, j, k: (s * gk + k, i))
        b_spec = pl.BlockSpec((bk, bn), lambda s, i, j, k: (s * gk + k, j))
    else:  # nt
        a_spec = pl.BlockSpec((bm, bk), lambda s, i, j, k: (i, s * gk + k))
        b_spec = pl.BlockSpec((bn, bk), lambda s, i, j, k: (j, s * gk + k))
    c_spec = pl.BlockSpec((1, bm, bn), lambda s, i, j, k: (s, i, j))

    acc_dtype = _acc_dtype(a.dtype, b.dtype)
    partials = pl.pallas_call(
        functools.partial(_splitk_kernel, nk=gk, dims=dims, gk=gk, bk=bk,
                          k_total=k, mask_k=mask_k),
        grid=(nsplit, gm, gn, gk),
        in_specs=[a_spec, b_spec],
        out_specs=c_spec,
        out_shape=jax.ShapeDtypeStruct((nsplit, m, n), acc_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
    out = jnp.sum(partials, axis=0)
    if not epilogue.is_identity:
        out = epilogue.apply(out.astype(jnp.float32), bias=bias,
                             residual=residual, scale=scale)
    return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# Fused silu(x@Wg) * (x@Wu) pair — the dense/grouped two-output epilogue
# variant mirroring the ragged ftimm_gemm_ragged_swiglu: both panels stream
# against the same x tile (one fetch of x per step instead of two), two fp32
# accumulators ride the K loop, and the SwiGLU nonlinearity is applied in
# VMEM at the flush.  One kernel launch for a dense MLP's gate/up pair.
# ---------------------------------------------------------------------------


def _swiglu_body(x_blk, wg_blk, wu_blk, o_ref, accg_ref, accu_ref, *,
                 k, nk, k_lim):
    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    if k_lim is not None:
        x_blk = _mask_contract(x_blk, k_lim, 1)
        wg_blk = _mask_contract(wg_blk, k_lim, 0)
        wu_blk = _mask_contract(wu_blk, k_lim, 0)
    accg_ref[...] += jax.lax.dot_general(
        x_blk, wg_blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    accu_ref[...] += jax.lax.dot_general(
        x_blk, wu_blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        gate = accg_ref[...]
        act = gate * jax.nn.sigmoid(gate) * accu_ref[...]
        o_ref[...] = act.astype(o_ref.dtype)


def _swiglu_kernel(x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref, *,
                   nk, bk, k_total, mask_k):
    k = pl.program_id(2)
    k_lim = _k_limit(k_total, bk, k) if mask_k else None
    _swiglu_body(x_ref[...], wg_ref[...], wu_ref[...], o_ref,
                 accg_ref, accu_ref, k=k, nk=nk, k_lim=k_lim)


def ftimm_gemm_swiglu(
    x: jax.Array,                 # (M, K)
    w_gate: jax.Array,            # (K, N)
    w_up: jax.Array,              # (K, N)
    *,
    bm: int,
    bn: int,
    bk: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Dense fused SwiGLU pair: silu(x @ Wg) * (x @ Wu) in ONE kernel launch
    (shapes need not be block multiples — remainder K tiles mask in-kernel).
    """
    m, k = x.shape
    kw, n = w_gate.shape
    assert kw == k and w_up.shape == w_gate.shape, (
        x.shape, w_gate.shape, w_up.shape)
    out_dtype = out_dtype or x.dtype
    gm, gn, gk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk)
    x_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    return pl.pallas_call(
        functools.partial(_swiglu_kernel, nk=gk, bk=bk, k_total=k,
                          mask_k=bool(k % bk)),
        grid=(gm, gn, gk),
        in_specs=[x_spec, w_spec, w_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w_gate, w_up)


def _grouped_swiglu_kernel(x_ref, wg_ref, wu_ref, o_ref, accg_ref, accu_ref,
                           *, nk, bk, k_total, mask_k, x_batched):
    k = pl.program_id(3)
    k_lim = _k_limit(k_total, bk, k) if mask_k else None
    x_blk = x_ref[0] if x_batched else x_ref[...]
    _swiglu_body(x_blk, wg_ref[0], wu_ref[0], o_ref.at[0],
                 accg_ref, accu_ref, k=k, nk=nk, k_lim=k_lim)


def ftimm_gemm_grouped_swiglu(
    x: jax.Array,                 # (G, M, K) per-group rows | (M, K) shared
    w_gate: jax.Array,            # (G, K, N)
    w_up: jax.Array,              # (G, K, N)
    *,
    bm: int,
    bn: int,
    bk: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Grouped fused SwiGLU pair: silu(x_g @ Wg_g) * (x_g @ Wu_g) per group
    in ONE launch — the capacity-mode MoE gate/up projections
    ``(E, C, D) @ (E, D, F)`` without the separate silu/mul XLA passes.
    ``x`` may be 2-D (shared rows against per-group panels)."""
    x_batched = x.ndim == 3
    g, kw, n = w_gate.shape
    m, k = x.shape[-2:]
    assert kw == k and w_up.shape == w_gate.shape, (
        x.shape, w_gate.shape, w_up.shape)
    if x_batched:
        assert x.shape[0] == g, (x.shape, w_gate.shape)
    out_dtype = out_dtype or x.dtype
    gm, gn, gk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk)
    if x_batched:
        x_spec = pl.BlockSpec((1, bm, bk), lambda g, i, j, k: (g, i, k))
    else:
        x_spec = pl.BlockSpec((bm, bk), lambda g, i, j, k: (i, k))
    w_spec = pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j))
    o_spec = pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j))
    return pl.pallas_call(
        functools.partial(_grouped_swiglu_kernel, nk=gk, bk=bk, k_total=k,
                          mask_k=bool(k % bk), x_batched=x_batched),
        grid=(g, gm, gn, gk),
        in_specs=[x_spec, w_spec, w_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((g, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(x, w_gate, w_up)
