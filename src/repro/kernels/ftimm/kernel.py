"""ftIMM Pallas TPU kernels: shape-specialized tiled GEMM.

Paper mapping (Yin et al., 2022):

* The paper auto-generates assembly micro-kernels per (m_s, k_a, n_a) so that
  small-N GEMMs neither waste AM space nor compute padded lanes.  Here the
  "generator" is a parametric ``pl.pallas_call`` factory: block shapes
  (bm, bn, bk), the grid order, and the split-K factor are free parameters
  chosen by the CMR tuner (``repro.core.gemm``), and Mosaic plays the role of
  the assembler.  The DMA ping-pong double buffering of the paper is the
  Pallas grid pipeline (automatic double-buffering of input blocks between
  sequential grid steps).

* M-parallel strategy (paper Alg. 4)  -> ``ftimm_gemm``: grid over
  (M/bm, N/bn) "parallel" dims with the K loop innermost ("arbitrary"), the
  fp32 accumulator resident in VMEM scratch across K steps (the role GSM/AM
  reuse plays in the paper).

* K-parallel strategy (paper Alg. 5)  -> ``ftimm_gemm_splitk``: the grid
  splits K into ``nsplit`` independent partial products; partials land in an
  fp32 buffer that is reduced afterwards (the paper reduces through GSM; on
  TPU the reduction is an XLA add — and across chips it is a psum over ICI,
  see ``repro.core.gemm.distributed``).

All kernels accumulate in fp32 regardless of input dtype.  Block shapes must
be multiples of the TPU register tiling — (8,128) fp32 / (16,128) bf16 — a
constraint the tuner enforces; the kernels themselves only require that the
(padded) operand shapes divide into the blocks.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import pallas_compiler_params

DimOrder = Literal["mn", "nm"]


def _accum_body(a_blk, b_blk, c_ref, acc_ref, *, k, nk, dims):
    """Shared accumulate-and-flush epilogue across all kernel variants."""

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_blk, b_blk, (dims, ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


def _nn_kernel(a_ref, b_ref, c_ref, acc_ref, *, nk):
    _accum_body(a_ref[...], b_ref[...], c_ref, acc_ref,
                k=pl.program_id(2), nk=nk, dims=((1,), (0,)))


def _tn_kernel(a_ref, b_ref, c_ref, acc_ref, *, nk):
    # A is (K, M): contract dim 0 of both operands.
    _accum_body(a_ref[...], b_ref[...], c_ref, acc_ref,
                k=pl.program_id(2), nk=nk, dims=((0,), (0,)))


def _nt_kernel(a_ref, b_ref, c_ref, acc_ref, *, nk):
    # B is (N, K): contract dim 1 of both operands.
    _accum_body(a_ref[...], b_ref[...], c_ref, acc_ref,
                k=pl.program_id(2), nk=nk, dims=((1,), (1,)))


_KERNELS = {"nn": _nn_kernel, "tn": _tn_kernel, "nt": _nt_kernel}


def _specs(trans: str, bm: int, bn: int, bk: int, order: DimOrder):
    """BlockSpecs for each operand layout under a given grid order.

    Grid is (outer, inner, k) with k innermost so the fp32 accumulator block
    is revisited across K steps (paper: C_a stays in AM during the k_g loop).
    ``order`` decides whether the M or the N dimension is the outer parallel
    loop — the paper's loop-order-for-reuse discussion: the operand indexed
    by the *inner* dim is re-fetched per outer step, the other is reused.
    """
    if order == "mn":
        i_of = lambda i, j, k: i   # noqa: E731
        j_of = lambda i, j, k: j   # noqa: E731
    else:
        i_of = lambda i, j, k: j   # noqa: E731
        j_of = lambda i, j, k: i   # noqa: E731
    if trans == "nn":
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i_of(i, j, k), k))
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j_of(i, j, k)))
    elif trans == "tn":
        a_spec = pl.BlockSpec((bk, bm), lambda i, j, k: (k, i_of(i, j, k)))
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j_of(i, j, k)))
    elif trans == "nt":
        a_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i_of(i, j, k), k))
        b_spec = pl.BlockSpec((bn, bk), lambda i, j, k: (j_of(i, j, k), k))
    else:  # pragma: no cover
        raise ValueError(trans)
    c_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i_of(i, j, k), j_of(i, j, k)))
    return a_spec, b_spec, c_spec


def _mkn(trans: str, a_shape, b_shape):
    if trans == "nn":
        (m, k), (_, n) = a_shape, b_shape
    elif trans == "tn":
        (k, m), (_, n) = a_shape, b_shape
    else:  # nt
        (m, k), (n, _) = a_shape, b_shape
    return m, k, n


def ftimm_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    trans: str = "nn",
    dim_order: DimOrder = "mn",
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """M-parallel ftIMM GEMM. Shapes must already be padded to block multiples.

    trans: "nn" A(M,K)@B(K,N); "tn" A(K,M).T@B(K,N); "nt" A(M,K)@B(N,K).T.
    """
    m, k, n = _mkn(trans, a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, k, n, bm, bn, bk)
    out_dtype = out_dtype or a.dtype
    gm, gn, gk = m // bm, n // bn, k // bk
    grid = (gm, gn, gk) if dim_order == "mn" else (gn, gm, gk)
    a_spec, b_spec, c_spec = _specs(trans, bm, bn, bk, dim_order)
    return pl.pallas_call(
        functools.partial(_KERNELS[trans], nk=gk),
        grid=grid,
        in_specs=[a_spec, b_spec],
        out_specs=c_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)


_DIMS = {"nn": ((1,), (0,)), "tn": ((0,), (0,)), "nt": ((1,), (1,))}


def _batched_kernel(a_ref, b_ref, c_ref, acc_ref, *, nk, dims,
                    a_batched, b_batched):
    a_blk = a_ref[0] if a_batched else a_ref[...]
    b_blk = b_ref[0] if b_batched else b_ref[...]
    _accum_body(a_blk, b_blk, c_ref.at[0], acc_ref,
                k=pl.program_id(3), nk=nk, dims=dims)


def _batched_specs(trans: str, bm: int, bn: int, bk: int, order: DimOrder,
                   a_batched: bool, b_batched: bool):
    """BlockSpecs for the (g, outer, inner, k) grid.

    Batched operands carry a leading size-1 block indexed by the batch grid
    dim; a *shared* (2-D) operand's index map simply omits ``g`` — the Pallas
    pipeline then keeps its block resident across consecutive batch entries
    whenever the rest of the index map is constant (the grouped-GEMM analogue
    of the paper's "B panel cached in GSM" reuse, now across the batch)."""
    if order == "mn":
        i_of = lambda g, i, j, k: i   # noqa: E731
        j_of = lambda g, i, j, k: j   # noqa: E731
    else:
        i_of = lambda g, i, j, k: j   # noqa: E731
        j_of = lambda g, i, j, k: i   # noqa: E731

    def spec(batched: bool, shape2, idx2):
        if batched:
            return pl.BlockSpec(
                (1,) + shape2, lambda g, i, j, k: (g,) + idx2(g, i, j, k))
        return pl.BlockSpec(shape2, lambda g, i, j, k: idx2(g, i, j, k))

    if trans == "nn":
        a_spec = spec(a_batched, (bm, bk),
                      lambda g, i, j, k: (i_of(g, i, j, k), k))
        b_spec = spec(b_batched, (bk, bn),
                      lambda g, i, j, k: (k, j_of(g, i, j, k)))
    elif trans == "tn":
        a_spec = spec(a_batched, (bk, bm),
                      lambda g, i, j, k: (k, i_of(g, i, j, k)))
        b_spec = spec(b_batched, (bk, bn),
                      lambda g, i, j, k: (k, j_of(g, i, j, k)))
    elif trans == "nt":
        a_spec = spec(a_batched, (bm, bk),
                      lambda g, i, j, k: (i_of(g, i, j, k), k))
        b_spec = spec(b_batched, (bn, bk),
                      lambda g, i, j, k: (j_of(g, i, j, k), k))
    else:  # pragma: no cover
        raise ValueError(trans)
    c_spec = pl.BlockSpec(
        (1, bm, bn),
        lambda g, i, j, k: (g, i_of(g, i, j, k), j_of(g, i, j, k)))
    return a_spec, b_spec, c_spec


def ftimm_gemm_grouped(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    trans: str = "nn",
    dim_order: DimOrder = "mn",
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Grouped ftIMM GEMM: per-group operands with optional sharing.

    Either operand may be 3-D ``(G, ., .)`` (one panel per group — the MoE
    expert-weight case ``(E, C, D) @ (E, D, F)``) or 2-D (one panel shared by
    every group, e.g. a common activation against per-group weights or vice
    versa).  At least one operand must be 3-D.  Per-group shapes must already
    be padded to block multiples; returns ``(G, M, N)``.
    """
    a_batched, b_batched = a.ndim == 3, b.ndim == 3
    assert a_batched or b_batched, (a.shape, b.shape)
    if a_batched and b_batched:
        assert a.shape[0] == b.shape[0], (a.shape, b.shape)
    gsize = a.shape[0] if a_batched else b.shape[0]
    m, k, n = _mkn(trans, a.shape[-2:], b.shape[-2:])
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, k, n, bm, bn, bk)
    out_dtype = out_dtype or a.dtype
    gm, gn, gk = m // bm, n // bn, k // bk
    grid = ((gsize, gm, gn, gk) if dim_order == "mn"
            else (gsize, gn, gm, gk))
    a_spec, b_spec, c_spec = _batched_specs(
        trans, bm, bn, bk, dim_order, a_batched, b_batched)
    return pl.pallas_call(
        functools.partial(_batched_kernel, nk=gk, dims=_DIMS[trans],
                          a_batched=a_batched, b_batched=b_batched),
        grid=grid,
        in_specs=[a_spec, b_spec],
        out_specs=c_spec,
        out_shape=jax.ShapeDtypeStruct((gsize, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)


def ftimm_gemm_batched(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    trans: str = "nn",
    dim_order: DimOrder = "mn",
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Batched ftIMM GEMM: leading batch grid dim over independent per-entry
    GEMMs, ``(G, M, K) @ (G, K, N) -> (G, M, N)`` (trans variants as in
    ``ftimm_gemm``).  The fp32 accumulator is revisited across the innermost
    K steps exactly as in the 2-D kernel; each batch entry owns its own
    output block so the batch dim is fully parallel."""
    assert a.ndim == 3 and b.ndim == 3, (a.shape, b.shape)
    return ftimm_gemm_grouped(
        a, b, bm=bm, bn=bn, bk=bk, trans=trans, dim_order=dim_order,
        out_dtype=out_dtype, interpret=interpret)


def _splitk_kernel(a_ref, b_ref, c_ref, acc_ref, *, nk, dims):
    _accum_body(a_ref[...], b_ref[...], c_ref.at[0], acc_ref,
                k=pl.program_id(3), nk=nk, dims=dims)


def ftimm_gemm_splitk(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int,
    bn: int,
    bk: int,
    nsplit: int,
    trans: str = "nn",
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """K-parallel ftIMM GEMM (paper Alg. 5).

    Returns the REDUCED (M, N) result; the fp32 partials buffer
    (nsplit, M, N) is produced by the kernel and summed outside it — the
    TPU analogue of the paper's reduction of per-core partial C through GSM.
    K must divide into nsplit * bk-multiples.
    """
    m, k, n = _mkn(trans, a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    assert k % nsplit == 0, (k, nsplit)
    ks = k // nsplit
    assert m % bm == 0 and n % bn == 0 and ks % bk == 0, (m, ks, n, bm, bn, bk)
    gm, gn, gk = m // bm, n // bn, ks // bk
    dims = {"nn": ((1,), (0,)), "tn": ((0,), (0,)), "nt": ((1,), (1,))}[trans]

    # Index maps: split s owns K blocks [s*gk, (s+1)*gk).
    if trans == "nn":
        a_spec = pl.BlockSpec((bm, bk), lambda s, i, j, k: (i, s * gk + k))
        b_spec = pl.BlockSpec((bk, bn), lambda s, i, j, k: (s * gk + k, j))
    elif trans == "tn":
        a_spec = pl.BlockSpec((bk, bm), lambda s, i, j, k: (s * gk + k, i))
        b_spec = pl.BlockSpec((bk, bn), lambda s, i, j, k: (s * gk + k, j))
    else:  # nt
        a_spec = pl.BlockSpec((bm, bk), lambda s, i, j, k: (i, s * gk + k))
        b_spec = pl.BlockSpec((bn, bk), lambda s, i, j, k: (j, s * gk + k))
    c_spec = pl.BlockSpec((1, bm, bn), lambda s, i, j, k: (s, i, j))

    partials = pl.pallas_call(
        functools.partial(_splitk_kernel, nk=gk, dims=dims),
        grid=(nsplit, gm, gn, gk),
        in_specs=[a_spec, b_spec],
        out_specs=c_spec,
        out_shape=jax.ShapeDtypeStruct((nsplit, m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
    return jnp.sum(partials, axis=0).astype(out_dtype)
