from .kernel import ftimm_gemm, ftimm_gemm_splitk
from .ops import gemm
from . import ref

__all__ = ["ftimm_gemm", "ftimm_gemm_splitk", "gemm", "ref"]
