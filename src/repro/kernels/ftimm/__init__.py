from .kernel import (ftimm_gemm, ftimm_gemm_batched, ftimm_gemm_grouped,
                     ftimm_gemm_splitk)
from .ops import batched_gemm, gemm
from . import ref

__all__ = ["ftimm_gemm", "ftimm_gemm_batched", "ftimm_gemm_grouped",
           "ftimm_gemm_splitk", "batched_gemm", "gemm", "ref"]
