from .kernel import (ftimm_gemm, ftimm_gemm_batched, ftimm_gemm_grouped,
                     ftimm_gemm_ragged, ftimm_gemm_ragged_dw,
                     ftimm_gemm_ragged_swiglu, ftimm_gemm_splitk)
from .ops import (batched_gemm, gemm, ragged_gemm, ragged_gemm_dw,
                  ragged_gemm_swiglu, sublane)
from . import ref

__all__ = ["ftimm_gemm", "ftimm_gemm_batched", "ftimm_gemm_grouped",
           "ftimm_gemm_ragged", "ftimm_gemm_ragged_dw",
           "ftimm_gemm_ragged_swiglu", "ftimm_gemm_splitk",
           "batched_gemm", "gemm", "ragged_gemm", "ragged_gemm_dw",
           "ragged_gemm_swiglu", "sublane", "ref"]
