from .kernel import (Epilogue, ftimm_gemm, ftimm_gemm_batched,
                     ftimm_gemm_grouped, ftimm_gemm_grouped_swiglu,
                     ftimm_gemm_ragged, ftimm_gemm_ragged_dw,
                     ftimm_gemm_ragged_swiglu, ftimm_gemm_splitk,
                     ftimm_gemm_swiglu)
from .ops import (batched_gemm, batched_gemm_swiglu, gemm, gemm_swiglu,
                  ragged_gemm, ragged_gemm_dw, ragged_gemm_swiglu, sublane)
from . import ref

__all__ = ["Epilogue", "ftimm_gemm", "ftimm_gemm_batched",
           "ftimm_gemm_grouped", "ftimm_gemm_grouped_swiglu",
           "ftimm_gemm_ragged", "ftimm_gemm_ragged_dw",
           "ftimm_gemm_ragged_swiglu", "ftimm_gemm_splitk",
           "ftimm_gemm_swiglu",
           "batched_gemm", "batched_gemm_swiglu", "gemm", "gemm_swiglu",
           "ragged_gemm", "ragged_gemm_dw", "ragged_gemm_swiglu",
           "sublane", "ref"]
