"""The fused-epilogue spec shared by every GEMM engine.

Lives in its own leaf module (imports nothing from the package) so the
kernel layer, the ops wrappers, the dispatch layer and ``core.gemm`` can all
import it without participating in the kernels <-> core import cycle.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

_ACTIVATIONS = ("none", "silu", "gelu")


@dataclass(frozen=True)
class Epilogue:
    """What to fuse into the accumulator flush of a GEMM.

    Applied in fp32 VMEM before the output cast, in this order:

        y = act(acc * scale_vec * scale + bias) + residual

    ``bias`` / ``residual`` / ``scale_vec`` are flags — the operands
    themselves ride along as extra kernel inputs (bias and scale_vec are
    (N,)-wide vectors broadcast over rows, residual shaped like the output).
    ``scale_vec`` is the quantized paths' dequant: the per-channel (or
    broadcast per-tensor) scale multiplying the raw accumulator.  It is
    LINEAR, so unlike activations it is split-K legal — the split-K engine
    applies it post-reduction.  ``scale`` stays the static scalar knob.
    Hashable, so it can key jit static arguments and the dispatch-level
    function caches."""
    bias: bool = False
    activation: str = "none"        # none | silu | gelu
    residual: bool = False
    scale: float | None = None
    scale_vec: bool = False

    def __post_init__(self):
        if self.activation not in _ACTIVATIONS:
            raise ValueError(
                f"unknown epilogue activation: {self.activation!r} "
                f"(expected one of {_ACTIVATIONS})")

    @property
    def is_identity(self) -> bool:
        return (not self.bias and not self.residual and not self.scale_vec
                and self.activation == "none" and self.scale is None)

    @property
    def num_ops(self) -> int:
        """How many separate elementwise output passes the unfused path runs
        — what fusing saves (each pass re-reads and re-writes C in HBM)."""
        return (int(self.scale_vec) + int(self.scale is not None)
                + int(self.bias) + int(self.activation != "none")
                + int(self.residual))

    def unpack(self, extras):
        """Split a positional ``extras`` tuple back into
        (bias, residual, scale).

        The packing convention — bias, then residual, then the scale vector,
        each present only when its flag is set — is used by every
        fixed-arity carrier of epilogue operands (the dispatch custom-VJP
        args, the shard_map bodies in ``dist_matmul``); this is its ONE
        inverse."""
        i = 0
        bias = residual = scale = None
        if self.bias:
            bias = extras[i]
            i += 1
        if self.residual:
            residual = extras[i]
            i += 1
        if self.scale_vec:
            scale = extras[i]
        return bias, residual, scale

    def decompose(self) -> tuple["Epilogue", ...]:
        """The tail as single-op specs, in application order — what the
        UNFUSED path executes: one separate pass over the output per op.
        Applying them sequentially reproduces ``apply`` exactly."""
        ops = []
        if self.scale_vec:
            ops.append(Epilogue(scale_vec=True))
        if self.scale is not None:
            ops.append(Epilogue(scale=self.scale))
        if self.bias:
            ops.append(Epilogue(bias=True))
        if self.activation != "none":
            ops.append(Epilogue(activation=self.activation))
        if self.residual:
            ops.append(Epilogue(residual=True))
        return tuple(ops)

    def apply(self, acc: jax.Array, bias=None, residual=None,
              scale=None) -> jax.Array:
        """fp32 in / fp32 out.  Shared by the in-kernel flush, the split-K
        post-reduction, and the XLA fallback — ONE definition of the math so
        every engine stays bit-comparable.  ``scale`` is the runtime
        (N,)-wide dequant vector (``scale_vec``); it multiplies the raw
        accumulator FIRST so integer accumulators decode before any affine
        tail."""
        if self.scale_vec:
            acc = acc * scale.astype(jnp.float32)
        if self.scale is not None:
            acc = acc * jnp.float32(self.scale)
        if self.bias:
            acc = acc + bias.astype(jnp.float32)
        if self.activation == "silu":
            acc = acc * jax.nn.sigmoid(acc)
        elif self.activation == "gelu":
            acc = jax.nn.gelu(acc)
        if self.residual:
            acc = acc + residual.astype(jnp.float32)
        return acc


IDENTITY = Epilogue()
