"""Top-level model API: init / train forward / prefill / decode for every
assigned architecture family.

Batch dict convention (see ``launch.dryrun.input_specs`` for the abstract
stand-ins):
    tokens:       (B, S) int32 — always present
    labels:       (B, S) int32 — training only
    loss_mask:    (B, S) f32   — training only (masks pad / patch positions)
    frames:       (B, S_enc, D) — encdec stub frontend (precomputed audio
                  frame embeddings; the conv frontend is OUT of scope)
    patch_embeds: (B, P, D)     — vlm stub frontend (precomputed patch
                  embeddings from the anyres tiler; OUT of scope)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.dist import shard_act
from .layers import dense, embed, rms_norm, unembed
from .transformer import (init_cache, init_lm_params, stack_cached,
                          stack_train, layer_windows, dense_block)
from .attention import attention

__all__ = ["init_params", "forward_train", "loss_fn", "prefill",
           "prefill_bucket", "decode_step", "make_cache", "encode"]


def init_params(cfg: ModelConfig, key) -> dict:
    return init_lm_params(cfg, key)


def _embed_inputs(params, cfg: ModelConfig, batch) -> tuple[jax.Array, jax.Array]:
    """Token (+frontend) embeddings and positions. Returns (h, positions)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    h = embed(batch["tokens"], params["embed"], cdt)
    if cfg.num_patches and "patch_embeds" in batch:
        patches = dense(batch["patch_embeds"].astype(cdt),
                        params["patch_proj"], cdt)
        h = jnp.concatenate([patches, h], axis=1)
    h = shard_act(h, "dp", None, None)
    positions = jnp.arange(h.shape[1])
    return h, positions


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Encoder stack over precomputed frame embeddings (whisper stub)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    h = dense(frames.astype(cdt), params["frame_proj"], cdt)
    positions = jnp.arange(h.shape[1])
    windows = layer_windows(cfg)

    def body(hh, xs):
        p, w = xs
        hh, _, _ = dense_block(hh, p, cfg, positions=positions, window=w,
                               causal=False)
        return hh, None

    h, _ = jax.lax.scan(body, h, (params["encoder"],
                                  windows[:cfg.encoder_layers]),
                        unroll=True if cfg.scan_unroll else 1)
    return rms_norm(h, params["enc_norm"])


def _cross_kv_stack(params, cfg: ModelConfig, enc_out: jax.Array):
    """Per-decoder-layer cross K/V from encoder output (computed once)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = enc_out.shape

    def per_layer(p):
        k = dense(enc_out, p["cross"]["wk"], cdt).reshape(
            b, s, cfg.num_kv_heads, cfg.head_dim_)
        v = dense(enc_out, p["cross"]["wv"], cdt).reshape(
            b, s, cfg.num_kv_heads, cfg.head_dim_)
        return k, v

    return jax.vmap(per_layer)(params["layers"])


def forward_train(params, cfg: ModelConfig, batch) -> tuple[jax.Array, jax.Array]:
    """Full-sequence logits for training. Returns (logits, aux_loss)."""
    h, positions = _embed_inputs(params, cfg, batch)
    if cfg.family == "encdec":
        enc_out = encode(params, cfg, batch["frames"])
        cross = _cross_kv_stack(params, cfg, enc_out)
        h, aux = stack_train(params, cfg, h, positions, cross_kv_stack=cross)
    else:
        h, aux = stack_train(params, cfg, h, positions)
    h = rms_norm(h, params["final_norm"])
    if cfg.num_patches:
        h = h[:, cfg.num_patches:]        # logits over text positions only
    logits = unembed(h, params["embed"], cfg.vocab_size,
                     jnp.dtype(cfg.compute_dtype))
    logits = shard_act(logits, "dp", None, "model")
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch,
            aux_weight: float = 0.01) -> tuple[jax.Array, dict]:
    logits, aux = forward_train(params, cfg, batch)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    # Fused CE over the (vocab-sharded) logits: logsumexp + masked pick, no
    # gather / log_softmax materialization — keeps the vocab dim sharded over
    # the model axis end-to-end (a take_along_axis here would force an
    # all-gather of (B, S, V) f32 on every chip).
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                     axis=-1)
    nll = lse - picked
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    total = ce + aux_weight * aux
    return total, {"loss": ce, "aux_loss": aux,
                   "tokens": jnp.sum(mask)}


def make_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    """KV/SSM cache sized for ``max_len`` positions (VLM: includes patches)."""
    extra = cfg.num_patches or 0
    return init_cache(cfg, batch_size, max_len + extra)


def prefill(params, cfg: ModelConfig, batch, cache) -> tuple[jax.Array, dict]:
    """Run the prompt through the stack, filling the cache.
    Returns (last-position logits, cache)."""
    h, positions = _embed_inputs(params, cfg, batch)
    if cfg.family == "encdec":
        enc_out = encode(params, cfg, batch["frames"])
        ck, cv = _cross_kv_stack(params, cfg, enc_out)
        cache = dict(cache)
        cache.update({"cross_k": ck, "cross_v": cv})
    h, new_cache, _ = stack_cached(params, cfg, h, positions, cache,
                                   cache_index=jnp.int32(0))
    h = rms_norm(h[:, -1:], params["final_norm"])
    logits = unembed(h, params["embed"], cfg.vocab_size,
                     jnp.dtype(cfg.compute_dtype))
    return logits[:, 0], new_cache


def prefill_bucket(params, cfg: ModelConfig, batch, cache,
                   lens: jax.Array) -> tuple[jax.Array, dict]:
    """Length-bucketed batch prefill: the whole bucket of right-padded
    prompts runs through ONE compiled stack pass into a bucket-sized
    contiguous cache, and each row's logits are taken at ITS last valid
    position (``lens`` (B,) = true prompt lengths, tokens padded to the
    bucket on the right).  Causality makes this exact: K/V at position i
    depend only on token i, and row r's logits at lens[r]-1 attend only to
    positions <= lens[r]-1 — pad tokens never influence a valid row.
    Returns ((B, V) logits, cache).  Attention-cache families only (SSM
    state is recurrent — pad tokens would contaminate it)."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"bucketed prefill unsupported for {cfg.family}")
    h, positions = _embed_inputs(params, cfg, batch)
    h, new_cache, _ = stack_cached(params, cfg, h, positions, cache,
                                   cache_index=jnp.int32(0))
    extra = cfg.num_patches or 0
    idx = jnp.asarray(lens, jnp.int32) - 1 + extra       # (B,)
    last = jnp.take_along_axis(h, idx[:, None, None], axis=1)
    last = rms_norm(last, params["final_norm"])
    logits = unembed(last, params["embed"], cfg.vocab_size,
                     jnp.dtype(cfg.compute_dtype))
    return logits[:, 0], new_cache


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, cache: dict,
                pos: jax.Array, page_table: jax.Array | None = None,
                ) -> tuple[jax.Array, dict]:
    """One-token decode. tokens: (B, 1) int32; pos: scalar int32 = number of
    positions already in the cache (VLM: including patches), or a (B,)
    vector of PER-SLOT depths — continuous batching serves slots at mixed
    lengths in one fused step, each writing/masking at its own position.
    ``page_table`` (B, max_pages): ``cache`` holds paged KV pools shared by
    every slot (see ``serve.kv_pages``) instead of per-slot dense buffers.
    Returns (logits (B, V), new cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    h = embed(tokens, params["embed"], cdt)
    pos = jnp.asarray(pos)
    positions = pos[:, None] if pos.ndim else pos + jnp.arange(1)
    h, new_cache, _ = stack_cached(params, cfg, h, positions, cache,
                                   cache_index=pos, page_table=page_table)
    h = rms_norm(h, params["final_norm"])
    logits = unembed(h, params["embed"], cfg.vocab_size, cdt)
    return logits[:, 0], new_cache
