from . import attention, layers, moe, model, ssm, transformer
from .model import (decode_step, forward_train, init_params, loss_fn,
                    make_cache, prefill)

__all__ = [
    "attention", "layers", "moe", "model", "ssm", "transformer",
    "decode_step", "forward_train", "init_params", "loss_fn", "make_cache",
    "prefill",
]
