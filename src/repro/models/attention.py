"""Attention: GQA with qk-norm, RoPE, sliding-window / chunked / global masks,
blockwise (memory-efficient) computation, and KV-cache decode.

The KV-block scan keeps prefill memory sub-quadratic (required for the 32k
prefill cells) and keeps the HLO small under scan-over-layers.  Per-layer
attention patterns are encoded in one traced scalar ``window`` so a single
scanned stack serves gemma3's 5:1 local:global, mixtral's SWA and llama4's
chunked layers:

    window > 0  : sliding window of that size (SWA)
    window == 0 : global attention
    window < 0  : chunked/local attention with chunk size |window| (iRoPE)

Decode attention over a long KV cache is the paper's T2 GEMM
(K = cache_len >> M = batch, N = head_dim); its cross-chip K-parallel
treatment (flash-decoding) lives in ``repro.serve.decode``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from ..core.compat import shard_map
from ..core.dist import current_dist
from ..core.gemm import batched_matmul
from .layers import dense, rms_norm, rope

NEG_INF = -1e30


def _bmm_qk(qg: jax.Array, k_blk: jax.Array) -> jax.Array:
    """(B, Sq, KVH, G, D) x (B, Skv, KVH, D) -> (B, Sq, KVH, G, Skv) scores.

    The attention score BMM flattened into the planner's batched GEMM: the
    (batch, kv-head) dims fold into the batch grid dim and the (query, group)
    dims into M, so each entry is the paper's "nt" GEMM with N = kv-block and
    K = head_dim <= 128 — irregular by the §III-A taxonomy, and previously a
    raw einsum the tuner never saw."""
    b, sq, kvh, g, d = qg.shape
    skv = k_blk.shape[1]
    qf = qg.transpose(0, 2, 1, 3, 4).reshape(b * kvh, sq * g, d)
    kf = k_blk.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        b * kvh, skv, d)
    s = batched_matmul(qf, kf, trans="nt", out_dtype=jnp.float32)
    return s.reshape(b, kvh, sq, g, skv).transpose(0, 2, 1, 3, 4)


def _bmm_pv(p: jax.Array, v_blk: jax.Array) -> jax.Array:
    """(B, Sq, KVH, G, Skv) x (B, Skv, KVH, D) -> (B, Sq, KVH, G, D)."""
    b, sq, kvh, g, skv = p.shape
    d = v_blk.shape[-1]
    pf = p.transpose(0, 2, 1, 3, 4).reshape(b * kvh, sq * g, skv)
    vf = v_blk.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        b * kvh, skv, d)
    o = batched_matmul(pf, vf, trans="nn", out_dtype=jnp.float32)
    return o.reshape(b, kvh, sq, g, d).transpose(0, 2, 1, 3, 4)


def init_attention_params(key, d_model: int, num_heads: int,
                          num_kv_heads: int, head_dim: int,
                          qk_norm: bool = False, cross: bool = False,
                          dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    scale = (2.0 / d_model) ** 0.5
    p = {
        "wq": jax.random.normal(ks[0], (d_model, num_heads * head_dim), dtype) * scale,
        "wk": jax.random.normal(ks[1], (d_model, num_kv_heads * head_dim), dtype) * scale,
        "wv": jax.random.normal(ks[2], (d_model, num_kv_heads * head_dim), dtype) * scale,
        "wo": jax.random.normal(ks[3], (num_heads * head_dim, d_model), dtype)
              * (2.0 / (num_heads * head_dim)) ** 0.5,
    }
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), dtype)
        p["k_norm"] = jnp.zeros((head_dim,), dtype)
    return p


def _mask(q_pos: jax.Array, kv_pos: jax.Array, window: jax.Array,
          causal: bool) -> jax.Array:
    """(Sq, Skv) boolean mask from positions and the window encoding."""
    q = q_pos[:, None].astype(jnp.int32)
    k = kv_pos[None, :].astype(jnp.int32)
    ok = jnp.ones(q.shape[:1] + k.shape[1:], dtype=bool)
    if causal:
        ok = k <= q
    w = jnp.asarray(window, jnp.int32)
    aw = jnp.maximum(jnp.abs(w), 1)
    sliding_ok = jnp.where(w > 0, k > q - aw, True)
    chunk_ok = jnp.where(w < 0, (q // aw) == (k // aw), True)
    return ok & sliding_ok & chunk_ok


def blockwise_attention(
    q: jax.Array,             # (B, Sq, H, D)
    k: jax.Array,             # (B, Skv, KVH, D)
    v: jax.Array,             # (B, Skv, KVH, D)
    *,
    q_positions: jax.Array,   # (Sq,)
    kv_positions: jax.Array,  # (Skv,)
    window: jax.Array | int = 0,
    causal: bool = True,
    kv_valid_len: jax.Array | None = None,
    block_kv: int = 1024,
    unroll: bool = False,
) -> jax.Array:
    """Memory-efficient attention with running-max/denominator over KV blocks."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d).astype(jnp.float32)
    scale = d ** -0.5

    pad = (-skv) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad),
                               constant_values=jnp.iinfo(jnp.int32).max // 2)
    nb = k.shape[1] // block_kv
    kb = k.reshape(b, nb, block_kv, kvh, d).swapaxes(0, 1)
    vb = v.reshape(b, nb, block_kv, kvh, d).swapaxes(0, 1)
    pb = kv_positions.reshape(nb, block_kv)
    valid = kv_valid_len if kv_valid_len is not None else skv

    def step(carry, xs):
        acc, m, l = carry
        k_blk, v_blk, pos_blk = xs
        s = _bmm_qk(qg, k_blk) * scale
        msk = _mask(q_positions, pos_blk, window, causal)
        msk = msk & (pos_blk < valid)[None, :]
        s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + _bmm_pv(p, v_blk)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, sq, kvh, g, d), jnp.float32)
    m0 = jnp.full((b, sq, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    # Flash-attention-style backward: recompute per-block scores/probs from
    # q/k instead of saving (nb, B, Sq, H, block) residuals across steps.
    (acc, _, l), _ = jax.lax.scan(jax.checkpoint(step), (acc0, m0, l0),
                                  (kb, vb, pb), unroll=True if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention(q, ck, cv, *, q_pos, window):
    """Single-token decode over the full cache with PER-ROW positions.

    ``q`` (B, 1, H, D); ``ck``/``cv`` (B, S, KVH, D); ``q_pos`` (B,) — the
    cache row each batch entry just wrote.  ``_mask`` broadcasts the (B,)
    query positions against the (S,) cache positions into a (B, S) per-row
    mask, so slots at different depths coexist in one fused decode batch:
    row b attends exactly k <= q_pos[b] under its own window, and rows
    beyond its depth (zeros, or a previous occupant's remnants) are
    excluded instead of inflating the softmax denominator."""
    b, sq, h, d = q.shape
    kvh = ck.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d).astype(jnp.float32)
    s_ = _bmm_qk(qg, ck) * (d ** -0.5)           # (B, 1, KVH, G, Skv)
    kv_pos = jnp.arange(ck.shape[1])
    msk = _mask(q_pos, kv_pos, window, causal=True)       # (B, Skv)
    s_ = jnp.where(msk[:, None, None, None, :], s_, NEG_INF)
    m = jnp.max(s_, axis=-1, keepdims=True)
    p = jnp.exp(s_ - m)
    out = _bmm_pv(p, cv) / jnp.maximum(jnp.sum(p, axis=-1)[..., None],
                                       1e-30)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def flash_decode(
    q: jax.Array,                # (B, 1, H, D) — replicated over model axis
    ck: jax.Array,               # (B, S, KVH, D) — S sharded over model axis
    cv: jax.Array,
    *,
    pos: jax.Array,              # scalar: index of the newest valid token
    window: jax.Array | int,
    dist,
) -> jax.Array:
    """Sequence-parallel decode attention — the paper's K-parallel strategy
    (Alg. 5) at cluster scale, a.k.a. flash-decoding.

    The KV cache's sequence dim is sharded over the model axis; each chip
    computes a partial softmax-attention (acc, running max, denominator)
    over its K-chunk, and partials are reduced over ICI with a log-sum-exp
    correction — the GSM reduction of the paper with the numerically-safe
    merge softmax needs.  The decode GEMMs q@K^T / p@V are T2-shaped
    (K = cache_len >> M = batch, N = head_dim).
    """
    b, _, h, d = q.shape
    _, s, kvh, _ = ck.shape
    axis = dist.model_axis
    dp = dist.dp_axes
    bshard = dp if (b % dist.dp_size == 0 and b >= dist.dp_size) else None
    g = h // kvh
    scale = d ** -0.5

    def kernel(q_l, k_l, v_l):
        s_loc = k_l.shape[1]
        shard = jax.lax.axis_index(axis)
        kv_pos = shard * s_loc + jnp.arange(s_loc)
        bl = q_l.shape[0]
        qg = q_l[:, 0].reshape(bl, kvh, g, d).astype(jnp.float32)
        # The decode score/value BMMs are T2-shaped per (batch, kv-head)
        # entry (K = cache shard >> M = q-group); flatten them into the
        # planner's batched GEMM like the prefill path does.
        kf = k_l.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
            bl * kvh, s_loc, d)
        s_ = batched_matmul(qg.reshape(bl * kvh, g, d), kf, trans="nt",
                            out_dtype=jnp.float32
                            ).reshape(bl, kvh, g, s_loc) * scale
        msk = _mask(pos[None], kv_pos, window, causal=True)[0]
        msk = msk & (kv_pos <= pos)
        s_ = jnp.where(msk[None, None, None, :], s_, NEG_INF)
        m = jnp.max(s_, axis=-1)
        p = jnp.exp(s_ - m[..., None])
        l = jnp.sum(p, axis=-1)
        vf = v_l.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
            bl * kvh, s_loc, d)
        acc = batched_matmul(p.reshape(bl * kvh, g, s_loc), vf, trans="nn",
                             out_dtype=jnp.float32).reshape(bl, kvh, g, d)
        # LSE-corrected reduction over the model axis (paper Alg. 5 line 12).
        gm = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - gm)
        l_g = jax.lax.psum(l * corr, axis)
        acc_g = jax.lax.psum(acc * corr[..., None], axis)
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out.reshape(-1, 1, h, d).astype(q_l.dtype)

    fn = shard_map(
        kernel, mesh=dist.mesh,
        in_specs=(P(bshard, None, None, None),
                  P(bshard, axis, None, None),
                  P(bshard, axis, None, None)),
        out_specs=P(bshard, None, None, None),
    )
    return fn(q, ck, cv)


def attention(
    x: jax.Array,                  # (B, S, D_model)
    params: dict,
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    positions: jax.Array,          # (S,)
    window: jax.Array | int = 0,
    causal: bool = True,
    qk_norm: bool = False,
    rope_theta: float = 10000.0,
    use_rope: bool = True,
    kv_cache: tuple[jax.Array, jax.Array] | None = None,
    cache_index: jax.Array | None = None,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
    compute_dtype=jnp.bfloat16,
    block_kv: int = 1024,
    unroll: bool = False,
    residual: jax.Array | None = None,
    page_table: jax.Array | None = None,
):
    """Full attention layer. Returns (out, new_kv_cache | None).

    * training/prefill: kv from x, optionally written into a fresh cache.
    * decode: ``kv_cache`` given + ``cache_index`` = current position; the
      new token's K/V are inserted and attention runs over the whole buffer.
      ``cache_index`` may be a (B,) vector (continuous batching at mixed
      depths): each row writes its own cache row and masks under its own
      causal horizon; ``positions`` is then (B, S).
    * cross-attention: ``cross_kv`` precomputed (B, S_enc, KVH, D) pair.
    * ``residual``: the block's residual stream (B, S, D_model), added in
      the out-projection's fused epilogue — the transformer's ``h + attn``
      without a separate elementwise pass over the output.
    * paged decode: ``page_table`` (B, max_pages) given, ``kv_cache`` is the
      PHYSICAL page pool (num_pages, page_size, KVH, D) shared by every
      slot (see ``serve.kv_pages``).  The new token's K/V scatter at the
      slot's physical row (table[b, idx//page] * page + idx%page) and each
      slot's logical view is gathered back out of the pool; the reserved
      null page 0 absorbs inactive slots' writes and is excluded by the
      per-row position masks (positions past a slot's depth never attend).
    """
    b, s, _ = x.shape
    q = dense(x, params["wq"], compute_dtype).reshape(b, s, num_heads, head_dim)
    # (S,) positions broadcast over the batch; (B, S) are per-row (vector
    # cache_index decode) and feed rope directly.
    pos2 = positions if positions.ndim == 2 else positions[None, :]

    if cross_kv is not None:
        k, v = cross_kv
        kv_pos = jnp.arange(k.shape[1])
        if qk_norm:
            q = rms_norm(q, params["q_norm"])
        if use_rope:
            q = rope(q, pos2, rope_theta)
        out = blockwise_attention(
            q, k, v, q_positions=positions, kv_positions=kv_pos,
            window=0, causal=False, block_kv=block_kv, unroll=unroll)
        new_cache = None
    else:
        k = dense(x, params["wk"], compute_dtype).reshape(b, s, num_kv_heads, head_dim)
        v = dense(x, params["wv"], compute_dtype).reshape(b, s, num_kv_heads, head_dim)
        if qk_norm:
            q = rms_norm(q, params["q_norm"])
            k = rms_norm(k, params["k_norm"])
        if use_rope:
            q = rope(q, pos2, rope_theta)
            k = rope(k, pos2, rope_theta)
        if kv_cache is not None and page_table is not None:
            # Paged single-token decode: scatter the new K/V at the slot's
            # physical row, gather the logical per-slot view, run the
            # per-row-masked decode attention over it.
            ck, cv = kv_cache                  # (num_pages, page, KVH, D)
            assert cache_index is not None and s == 1
            idx = jnp.asarray(cache_index)
            nump, page = ck.shape[0], ck.shape[1]
            phys = (page_table[jnp.arange(b), idx // page] * page
                    + idx % page)
            flat_k = ck.reshape(nump * page, num_kv_heads, head_dim)
            flat_v = cv.reshape(nump * page, num_kv_heads, head_dim)
            flat_k = flat_k.at[phys].set(k[:, 0].astype(flat_k.dtype))
            flat_v = flat_v.at[phys].set(v[:, 0].astype(flat_v.dtype))

            def view(flat):
                paged = flat.reshape(nump, page, num_kv_heads, head_dim)
                return paged[page_table].reshape(
                    b, -1, num_kv_heads, head_dim)

            out = decode_attention(q, view(flat_k), view(flat_v),
                                   q_pos=idx, window=window)
            new_cache = (flat_k.reshape(ck.shape), flat_v.reshape(cv.shape))
        elif kv_cache is not None:
            ck, cv = kv_cache
            assert cache_index is not None
            idx = jnp.asarray(cache_index)
            if idx.ndim:
                # Per-row insert: slot b's token lands at ITS depth idx[b],
                # not at the batch max.
                upd = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
                    c, u, (i, 0, 0)))
                ck = upd(ck, k.astype(ck.dtype), idx)
                cv = upd(cv, v.astype(cv.dtype), idx)
            else:
                ck = jax.lax.dynamic_update_slice(
                    ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
            dist = current_dist()
            if s > 1:
                # Prefill from an empty cache: the freshly computed K/V span
                # the whole valid range, so attend over them directly (keeps
                # the scan over KV blocks off the sharded cache buffer).
                out = blockwise_attention(
                    q, k, v, q_positions=positions, kv_positions=positions,
                    window=window, causal=causal, block_kv=block_kv, unroll=unroll)
            elif idx.ndim:
                # Mixed-depth fused decode: per-row masks from the (B,)
                # positions.
                out = decode_attention(q, ck, cv, q_pos=idx, window=window)
            elif dist is not None and dist.sp_decode and dist.model_size > 1:
                # K-parallel decode across chips (paper Alg. 5).
                out = flash_decode(q, ck, cv, pos=cache_index + s - 1,
                                   window=window, dist=dist)
            else:
                kv_pos = jnp.arange(ck.shape[1])
                out = blockwise_attention(
                    q, ck, cv, q_positions=positions, kv_positions=kv_pos,
                    window=window, causal=causal,
                    kv_valid_len=cache_index + s, block_kv=block_kv,
                    unroll=unroll)
            new_cache = (ck, cv)
        else:
            out = blockwise_attention(
                q, k, v, q_positions=positions, kv_positions=positions,
                window=window, causal=causal, block_kv=block_kv, unroll=unroll)
            new_cache = None

    out = out.reshape(b, s, num_heads * head_dim)
    return dense(out, params["wo"], compute_dtype,
                 residual=residual), new_cache
