"""Composable transformer stacks: dense / MoE / SSM / hybrid / enc-dec / VLM.

All stacks scan over layers (``jax.lax.scan`` with stacked params as xs) so
the lowered HLO stays compact for the 512-device dry-run, and activation
rematerialization policies apply uniformly to the scan body.

Per-layer attention patterns ride along as a scanned int32 array (see
``models.attention`` for the window encoding), which lets gemma3 (5:1
local:global), mixtral (SWA) and llama4 (chunked local 3:1) share one stack.

The hybrid (zamba2) stack is an outer scan over groups of ``attn_every``
Mamba2 layers followed by ONE shared attention+MLP block (single param set
reused at every application — faithful to Zamba2's shared-block design),
plus a trailing remainder scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.dist import shard_act
from .attention import attention, init_attention_params
from .layers import rms_norm, swiglu, he_init
from .moe import init_moe_params, moe_mlp
from .ssm import (CONV_WIDTH, HEADDIM, init_ssm_params, init_ssm_state,
                  ssd_decode_step, ssd_forward, ssm_dims)


def _cdt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ------------------------------ param init ------------------------------

def init_dense_block(key, cfg: ModelConfig, *, moe: bool = False,
                     cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    dt = _pdt(cfg)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "attn": init_attention_params(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.head_dim_, qk_norm=cfg.qk_norm, dtype=dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
    }
    if cross:
        p["ln_cross"] = jnp.zeros((cfg.d_model,), dt)
        p["cross"] = init_attention_params(
            ks[1], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.head_dim_, qk_norm=False, dtype=dt)
    if moe:
        p["moe"] = init_moe_params(ks[2], cfg.d_model, cfg.d_ff,
                                   cfg.num_experts, dtype=dt)
    else:
        p["mlp"] = {
            "w_gate": he_init(ks[2], (cfg.d_model, cfg.d_ff), dt),
            "w_up": he_init(jax.random.fold_in(ks[2], 1),
                            (cfg.d_model, cfg.d_ff), dt),
            "w_down": he_init(ks[3], (cfg.d_ff, cfg.d_model), dt,
                              fan_in=cfg.d_ff),
        }
    return p


def init_ssm_block(key, cfg: ModelConfig) -> dict:
    return {
        "ln": jnp.zeros((cfg.d_model,), _pdt(cfg)),
        "ssm": init_ssm_params(key, cfg.d_model, cfg.ssm_state, _pdt(cfg)),
    }


def _init_stack(key, n: int, block_init):
    keys = jax.random.split(key, n)
    return jax.vmap(block_init)(keys)


def init_lm_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    dt = _pdt(cfg)
    params: dict = {
        "embed": jax.random.normal(
            ks[0], (cfg.vocab_padded, cfg.d_model), dt) * 0.02,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = _init_stack(
            ks[1], cfg.num_layers, lambda k: init_dense_block(k, cfg))
    elif fam == "moe":
        params["layers"] = _init_stack(
            ks[1], cfg.num_layers, lambda k: init_dense_block(k, cfg, moe=True))
    elif fam == "ssm":
        params["layers"] = _init_stack(
            ks[1], cfg.num_layers, lambda k: init_ssm_block(k, cfg))
    elif fam == "hybrid":
        params["layers"] = _init_stack(
            ks[1], cfg.num_layers, lambda k: init_ssm_block(k, cfg))
        params["shared_attn"] = init_dense_block(ks[2], cfg)
    elif fam == "encdec":
        params["encoder"] = _init_stack(
            ks[3], cfg.encoder_layers, lambda k: init_dense_block(k, cfg))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
        params["layers"] = _init_stack(
            ks[1], cfg.num_layers,
            lambda k: init_dense_block(k, cfg, cross=True))
    else:
        raise ValueError(fam)
    if cfg.num_patches:
        params["patch_proj"] = he_init(ks[4], (cfg.d_model, cfg.d_model), dt)
    if cfg.encoder_seq:
        params["frame_proj"] = he_init(ks[5], (cfg.d_model, cfg.d_model), dt)
    return params


# ------------------------------ block fwd -------------------------------

def _mlp_or_moe(h, p, cfg: ModelConfig):
    x = _gathered(rms_norm(h, p["ln2"]), cfg)
    if "moe" in p:
        b, s, d = x.shape
        y, aux = moe_mlp(x.reshape(b * s, d), p["moe"],
                         num_experts=cfg.num_experts, top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor,
                         compute_dtype=_cdt(cfg),
                         dispatch=cfg.moe_dispatch,
                         quant=getattr(cfg, "quant", "none"))
        return h + y.reshape(b, s, d), aux
    # Residual add fused into the down projection's epilogue (and the
    # gate/up pair is one fused kernel launch inside swiglu).
    return swiglu(x, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                  p["mlp"]["w_down"], _cdt(cfg),
                  residual=h), jnp.float32(0.0)


def _gathered(x, cfg):
    """Explicit sequence-parallel all-gather point (Megatron-SP style):
    norm inputs are gathered over the model axis, so GSPMD places ONE
    bf16 all-gather here and a reduce-scatter at the block boundary instead
    of improvising f32 gathers + activation-scale all-reduces in backward."""
    from ..core.dist import current_dist
    ctx = current_dist()
    if ctx is not None and ctx.sp_inputs and x.shape[1] > 1:
        x = shard_act(x, "dp", None, None)
    return x


def dense_block(h, p, cfg: ModelConfig, *, positions, window,
                kv=None, cache_index=None, cross_kv=None, causal=True,
                use_rope=True, page_table=None):
    """Returns (h, new_kv, aux).  The residual adds around attention (and
    the MLP, see ``_mlp_or_moe``) ride the out-projections' fused epilogues
    instead of separate elementwise passes over the block output.
    ``page_table`` switches decode to the paged KV pool (serve.kv_pages)."""
    h, new_kv = attention(
        _gathered(rms_norm(h, p["ln1"]), cfg), p["attn"],
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim_, positions=positions, window=window,
        causal=causal, qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        use_rope=use_rope, kv_cache=kv, cache_index=cache_index,
        compute_dtype=_cdt(cfg), unroll=cfg.scan_unroll, residual=h,
        page_table=page_table)
    if cross_kv is not None:
        h, _ = attention(
            rms_norm(h, p["ln_cross"]), p["cross"],
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim_, positions=positions, window=0,
            causal=False, qk_norm=False, rope_theta=cfg.rope_theta,
            use_rope=False, cross_kv=cross_kv, compute_dtype=_cdt(cfg),
            unroll=cfg.scan_unroll, residual=h)
    h, aux = _mlp_or_moe(h, p, cfg)
    # Sequence parallelism on the residual stream (training): the layer-scan
    # carry is the dominant live activation (L x B x S x D saved for the
    # backward); sharding S over the model axis cuts it by the TP degree.
    # Decode (S == 1) falls back to replicated automatically.
    h = shard_act(h, "dp", "model" if h.shape[1] > 1 else None, None)
    return h, new_kv, aux


def ssm_block(h, p, cfg: ModelConfig, state=None):
    """Returns (h, new_state)."""
    x = rms_norm(h, p["ln"])
    if state is None:
        y, _ = ssd_forward(x, p["ssm"], ssm_state=cfg.ssm_state,
                           chunk=cfg.ssm_chunk, compute_dtype=_cdt(cfg),
                           unroll=cfg.scan_unroll)
        return shard_act(h + y, "dp",
                         "model" if h.shape[1] > 1 else None, None), None
    if x.shape[1] == 1:
        y, new_state = ssd_decode_step(x, p["ssm"], state,
                                       ssm_state=cfg.ssm_state,
                                       compute_dtype=_cdt(cfg))
        return h + y, new_state
    # prefill: chunked scan, return final state (+ fresh conv tail)
    y, h_final = ssd_forward(x, p["ssm"], ssm_state=cfg.ssm_state,
                             chunk=cfg.ssm_chunk, compute_dtype=_cdt(cfg),
                             initial_state=state["h"], unroll=cfg.scan_unroll)
    d_inner, _, n = ssm_dims(cfg.d_model, cfg.ssm_state)
    # conv tail = silu-input window of the last (W-1) positions
    zxbcdt_tail = x[:, -(CONV_WIDTH - 1):]
    # recompute the conv input channels for the tail (cheap: W-1 positions)
    from .layers import dense as _dense
    tail = _dense(zxbcdt_tail, p["ssm"]["in_proj"], _cdt(cfg))
    xbc_tail = jnp.concatenate(
        [tail[..., d_inner:2 * d_inner],
         tail[..., 2 * d_inner:2 * d_inner + 2 * n]], axis=-1)
    return h + y, {"h": h_final, "conv": xbc_tail}


def _unroll(cfg: ModelConfig):
    return True if cfg.scan_unroll else 1


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def layer_windows(cfg: ModelConfig) -> jax.Array:
    return jnp.asarray(cfg.windows(), jnp.int32)


# ------------------------------ stacks ----------------------------------

def stack_train(params, cfg: ModelConfig, h, positions, *,
                cross_kv_stack=None, causal=True, use_rope=True):
    """Scan a dense/moe/ssm/hybrid stack without caches. -> (h, aux)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "encdec"):
        windows = layer_windows(cfg)

        def body(carry, xs):
            hh, aux = carry
            if cross_kv_stack is not None:
                p, w, ckv = xs
            else:
                p, w = xs
                ckv = None
            hh, _, a = dense_block(hh, p, cfg, positions=positions, window=w,
                                   cross_kv=ckv, causal=causal,
                                   use_rope=use_rope)
            return (hh, aux + a), None

        xs = (params["layers"], windows)
        if cross_kv_stack is not None:
            xs = xs + (cross_kv_stack,)
        (h, aux), _ = jax.lax.scan(_remat(body, cfg), (h, jnp.float32(0.0)), xs,
                                   unroll=_unroll(cfg))
        return h, aux

    if fam == "ssm":
        def body(hh, p):
            hh, _ = ssm_block(hh, p, cfg)
            return hh, None
        h, _ = jax.lax.scan(_remat(body, cfg), h, params["layers"],
                            unroll=_unroll(cfg))
        return h, jnp.float32(0.0)

    if fam == "hybrid":
        return _hybrid_train(params, cfg, h, positions)

    raise ValueError(fam)


def _hybrid_split(cfg: ModelConfig, stack):
    e = cfg.attn_every
    g = cfg.num_layers // e
    r = cfg.num_layers - g * e
    grouped = jax.tree.map(
        lambda a: a[:g * e].reshape((g, e) + a.shape[1:]), stack)
    rem = jax.tree.map(lambda a: a[g * e:], stack) if r else None
    return grouped, rem, g, r


def _hybrid_train(params, cfg: ModelConfig, h, positions):
    grouped, rem, g, r = _hybrid_split(cfg, params["layers"])
    shared = params["shared_attn"]

    def inner(hh, p):
        hh, _ = ssm_block(hh, p, cfg)
        return hh, None

    def group_body(hh, p_group):
        hh, _ = jax.lax.scan(inner, hh, p_group, unroll=_unroll(cfg))
        hh, _, _ = dense_block(hh, shared, cfg, positions=positions, window=0)
        return hh, None

    h, _ = jax.lax.scan(_remat(group_body, cfg), h, grouped,
                        unroll=_unroll(cfg))
    if r:
        h, _ = jax.lax.scan(inner, h, rem, unroll=_unroll(cfg))
    return h, jnp.float32(0.0)


def stack_cached(params, cfg: ModelConfig, h, positions, cache, cache_index,
                 *, causal=True, use_rope=True, page_table=None):
    """Scan with KV/SSM caches (prefill & decode). -> (h, new_cache, aux).
    ``page_table`` (B, max_pages): the cache leaves are paged pools shared
    across slots (one table for every layer — it rides the scan closure)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        windows = layer_windows(cfg)

        def body(carry, xs):
            hh, aux = carry
            p, w, k_l, v_l = xs
            hh, new_kv, a = dense_block(
                hh, p, cfg, positions=positions, window=w,
                kv=(k_l, v_l), cache_index=cache_index, causal=causal,
                use_rope=use_rope, page_table=page_table)
            return (hh, aux + a), new_kv

        (h, aux), (nk, nv) = jax.lax.scan(
            body, (h, jnp.float32(0.0)),
            (params["layers"], windows, cache["k"], cache["v"]),
            unroll=_unroll(cfg))
        return h, {"k": nk, "v": nv}, aux

    if fam == "encdec":
        windows = layer_windows(cfg)

        def body(carry, xs):
            hh, aux = carry
            p, w, k_l, v_l, ck_l, cv_l = xs
            hh, new_kv, a = dense_block(
                hh, p, cfg, positions=positions, window=w,
                kv=(k_l, v_l), cache_index=cache_index,
                cross_kv=(ck_l, cv_l))
            return (hh, aux + a), new_kv

        (h, aux), (nk, nv) = jax.lax.scan(
            body, (h, jnp.float32(0.0)),
            (params["layers"], windows, cache["k"], cache["v"],
             cache["cross_k"], cache["cross_v"]), unroll=_unroll(cfg))
        new_cache = dict(cache)
        new_cache.update({"k": nk, "v": nv})
        return h, new_cache, aux

    if fam == "ssm":
        def body(hh, xs):
            p, st_h, st_conv = xs
            hh, new_state = ssm_block(hh, p, cfg,
                                      state={"h": st_h, "conv": st_conv})
            return hh, (new_state["h"], new_state["conv"])

        h, (nh, nconv) = jax.lax.scan(
            body, h, (params["layers"], cache["h"], cache["conv"]),
            unroll=_unroll(cfg))
        return h, {"h": nh, "conv": nconv}, jnp.float32(0.0)

    if fam == "hybrid":
        return _hybrid_cached(params, cfg, h, positions, cache, cache_index)

    raise ValueError(fam)


def _hybrid_cached(params, cfg: ModelConfig, h, positions, cache, cache_index):
    grouped, rem, g, r = _hybrid_split(cfg, params["layers"])
    shared = params["shared_attn"]
    e = cfg.attn_every

    def split_state(tree, count, width):
        return jax.tree.map(
            lambda a: a[:count * width].reshape((count, width) + a.shape[1:]),
            tree)

    ssm_state = {"h": cache["ssm_h"], "conv": cache["ssm_conv"]}
    grouped_state = split_state(ssm_state, g, e)
    rem_state = jax.tree.map(lambda a: a[g * e:], ssm_state) if r else None

    def inner(hh, xs):
        p, st_h, st_conv = xs
        hh, ns = ssm_block(hh, p, cfg, state={"h": st_h, "conv": st_conv})
        return hh, (ns["h"], ns["conv"])

    def group_body(hh, xs):
        p_group, st_h, st_conv, ak, av = xs
        hh, (nh, nconv) = jax.lax.scan(inner, hh, (p_group, st_h, st_conv),
                                       unroll=_unroll(cfg))
        hh, new_kv, _ = dense_block(hh, shared, cfg, positions=positions,
                                    window=0, kv=(ak, av),
                                    cache_index=cache_index)
        return hh, (nh, nconv, new_kv[0], new_kv[1])

    h, (nh_g, nconv_g, nak, nav) = jax.lax.scan(
        group_body, h,
        (grouped, grouped_state["h"], grouped_state["conv"],
         cache["attn_k"], cache["attn_v"]), unroll=_unroll(cfg))
    nh = nh_g.reshape((g * e,) + nh_g.shape[2:])
    nconv = nconv_g.reshape((g * e,) + nconv_g.shape[2:])
    if r:
        h, (nh_r, nconv_r) = jax.lax.scan(
            inner, h, (rem, rem_state["h"], rem_state["conv"]),
            unroll=_unroll(cfg))
        nh = jnp.concatenate([nh, nh_r], axis=0)
        nconv = jnp.concatenate([nconv, nconv_r], axis=0)
    new_cache = {"ssm_h": nh, "ssm_conv": nconv, "attn_k": nak, "attn_v": nav}
    return h, new_cache, jnp.float32(0.0)


# ------------------------------ caches ----------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    dtype = dtype or _cdt(cfg)
    fam = cfg.family
    kvh, hd = cfg.num_kv_heads, cfg.head_dim_
    L = cfg.num_layers
    if fam in ("dense", "moe", "vlm"):
        return {
            "k": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
            "v": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
        }
    if fam == "encdec":
        return {
            "k": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
            "v": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
            "cross_k": jnp.zeros((L, batch, cfg.encoder_seq, kvh, hd), dtype),
            "cross_v": jnp.zeros((L, batch, cfg.encoder_seq, kvh, hd), dtype),
        }
    if fam == "ssm":
        st = init_ssm_state(batch, cfg.d_model, cfg.ssm_state, dtype)
        return {
            "h": jnp.zeros((L,) + st["h"].shape, st["h"].dtype),
            "conv": jnp.zeros((L,) + st["conv"].shape, st["conv"].dtype),
        }
    if fam == "hybrid":
        st = init_ssm_state(batch, cfg.d_model, cfg.ssm_state, dtype)
        g = cfg.num_layers // cfg.attn_every
        return {
            "ssm_h": jnp.zeros((L,) + st["h"].shape, st["h"].dtype),
            "ssm_conv": jnp.zeros((L,) + st["conv"].shape, st["conv"].dtype),
            "attn_k": jnp.zeros((g, batch, max_len, kvh, hd), dtype),
            "attn_v": jnp.zeros((g, batch, max_len, kvh, hd), dtype),
        }
    raise ValueError(fam)
