"""Shared building blocks: norms, projections, rotary embeddings, MLPs.

All dense contractions route through ``repro.core.gemm.project`` so the
ftIMM planner sees every GEMM in the framework (and dispatches to the Pallas
kernels on TPU).  Weights are kept in ``param_dtype`` (fp32 master) and cast
to ``compute_dtype`` at use.

Elementwise layer tails fuse into their producing GEMM: ``dense`` takes
optional ``bias`` / ``residual`` / ``activation`` (an ``Epilogue`` applied at
the fp32 accumulator flush instead of separate XLA passes over the output),
and ``swiglu`` runs its gate/up pair as ONE fused kernel launch
(``project_swiglu``) with the residual add fused into the down projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.dist import shard_act
from ..core.gemm import Epilogue, project, project_swiglu


def dense(x: jax.Array, w: jax.Array, compute_dtype=jnp.bfloat16, *,
          bias: jax.Array | None = None,
          residual: jax.Array | None = None,
          activation: str = "none",
          quant: str | None = None) -> jax.Array:
    """y = act(x @ w + bias) + residual with fp32 accumulation; w cast to
    compute dtype at use.  The bias/activation/residual tail (when present)
    is a fused GEMM epilogue — applied to the fp32 accumulator in VMEM, not
    as separate passes over the stored output.  ``quant`` (a ``core.quant``
    mode) routes through the managed quantized GEMM: the panel is quantized
    per channel in-trace, dequant fused at the flush, straight-through
    backward."""
    epi = Epilogue(bias=bias is not None, activation=activation,
                   residual=residual is not None)
    if epi.is_identity:
        return project(x.astype(compute_dtype), w.astype(compute_dtype),
                       out_dtype=compute_dtype, quant=quant)
    return project(
        x.astype(compute_dtype), w.astype(compute_dtype),
        out_dtype=compute_dtype, epilogue=epi,
        bias=None if bias is None else bias.astype(compute_dtype),
        residual=None if residual is None
        else residual.astype(compute_dtype), quant=quant)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    from ..core.dist import current_dist
    ctx = current_dist()
    if ctx is not None and ctx.rms_bf16:
        # Fusion-friendly form: variance reduced in f32, normalization kept
        # in the input dtype so the residual stream is never converted to a
        # full f32 tensor (XLA convert-motion otherwise stores the layer-scan
        # carries as f32 — 2x the checkpoint memory).
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        inv = (jax.lax.rsqrt(var + eps)).astype(x.dtype)
        return x * inv * (1.0 + scale.astype(x.dtype))
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
           compute_dtype=jnp.bfloat16,
           residual: jax.Array | None = None) -> jax.Array:
    """SwiGLU MLP: down(silu(gate(x)) * up(x)) [+ residual].  gate/up are
    T3-shaped GEMMs in training (tokens x d_model x d_ff), run as ONE fused
    kernel launch (x streamed once against both panels, silu(gate)*up at the
    accumulator flush); the residual add fuses into the down projection's
    epilogue instead of a separate pass over the layer output."""
    h = project_swiglu(x.astype(compute_dtype),
                       w_gate.astype(compute_dtype),
                       w_up.astype(compute_dtype), out_dtype=compute_dtype)
    return dense(h, w_down, compute_dtype, residual=residual)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary position embedding. x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    angles = angles[..., None, :]                             # (..., S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed(tokens: jax.Array, table: jax.Array,
          compute_dtype=jnp.bfloat16) -> jax.Array:
    return table.astype(compute_dtype)[tokens]


def unembed(x: jax.Array, table: jax.Array, vocab_size: int,
            compute_dtype=jnp.bfloat16) -> jax.Array:
    """Logits = x @ E^T over the (padded) vocab table; padded slots masked.

    The table arrives (vocab/model, d_model/dp)-sharded (ZeRO-3); constrain
    the transposed operand to (None, model) so GSPMD all-gathers the small
    D dim instead of all-reducing a (tokens x vocab) partial product."""
    wt = shard_act(table.astype(compute_dtype).T, None, "model")
    logits = project(x.astype(compute_dtype), wt, out_dtype=jnp.float32)
    pad = logits.shape[-1] - vocab_size
    if pad > 0:
        mask = jnp.arange(logits.shape[-1]) < vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


# ----------------------------- initializers -----------------------------

def he_init(key, shape, dtype=jnp.float32, fan_in=None):
    fan_in = fan_in or shape[0]
    return jax.random.normal(key, shape, dtype) * (2.0 / fan_in) ** 0.5


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)
